"""L2 model tests: recovery plans and histogram shapes/semantics."""

import jax.numpy as jnp
import numpy as np
import pytest

# See test_kernels.py: skip cleanly when hypothesis is unavailable.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

flags = st.integers(min_value=0, max_value=1)


def _plane(draw, n, strat=flags):
    return jnp.asarray(draw(st.lists(strat, min_size=n, max_size=n)), dtype=jnp.int32)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_recovery_plan_soft(data):
    n = 256
    vs = _plane(data.draw, n)
    ve = _plane(data.draw, n)
    dl = _plane(data.draw, n)
    keys = jnp.asarray(
        data.draw(st.lists(st.integers(0, 2**62), min_size=n, max_size=n)),
        dtype=jnp.int64,
    )
    mask = jnp.asarray([63], dtype=jnp.int64)
    member, bucket = model.recovery_plan_soft(vs, ve, dl, keys, mask, block=64)
    np.testing.assert_array_equal(np.asarray(member), np.asarray(ref.classify_soft(vs, ve, dl)))
    np.testing.assert_array_equal(np.asarray(bucket), np.asarray(ref.bucket_of(keys, mask)))


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_recovery_plan_linkfree(data):
    n = 256
    validity = _plane(data.draw, n, st.integers(0, 3))
    marked = _plane(data.draw, n)
    keys = jnp.asarray(
        data.draw(st.lists(st.integers(0, 2**62), min_size=n, max_size=n)),
        dtype=jnp.int64,
    )
    mask = jnp.asarray([127], dtype=jnp.int64)
    member, bucket = model.recovery_plan_linkfree(validity, marked, keys, mask, block=64)
    np.testing.assert_array_equal(
        np.asarray(member), np.asarray(ref.classify_linkfree(validity, marked))
    )
    np.testing.assert_array_equal(np.asarray(bucket), np.asarray(ref.bucket_of(keys, mask)))


def test_histogram_counts_members_only():
    member = jnp.asarray([1, 0, 1, 1, 0, 1], dtype=jnp.int32)
    bucket = jnp.asarray([0, 0, 1, 1, 2, 3], dtype=jnp.int32)
    h = model.bucket_histogram(member, bucket, nbuckets=4)
    np.testing.assert_array_equal(np.asarray(h), [1, 2, 0, 1])
    assert int(np.asarray(h).sum()) == int(np.asarray(member).sum())


def test_histogram_random_mass_conservation():
    rng = np.random.default_rng(0)
    member = jnp.asarray(rng.integers(0, 2, 4096), dtype=jnp.int32)
    bucket = jnp.asarray(rng.integers(0, 32, 4096), dtype=jnp.int32)
    h = model.bucket_histogram(member, bucket, nbuckets=32)
    assert int(np.asarray(h).sum()) == int(np.asarray(member).sum())
