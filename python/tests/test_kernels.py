"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes and values; every mismatch here would be a wrong
recovery decision or a wrong benchmark op stream on the Rust side.
"""

import jax.numpy as jnp
import numpy as np
import pytest

# Property sweeps need hypothesis; environments without it (e.g. the
# offline CI image) skip this module rather than erroring at collection.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import bucket_hash, membership, ref
from compile.kernels import workload as wl

# Shapes: powers of two so tiling divides evenly, plus the no-grid path.
SIZES = st.sampled_from([8, 64, 256, 1024, 4096])
BLOCKS = st.sampled_from([None, 64, 256])

flags = st.integers(min_value=0, max_value=1)


def _plane(draw, n, strat):
    return jnp.asarray(draw(st.lists(strat, min_size=n, max_size=n)), dtype=jnp.int32)


@settings(max_examples=60, deadline=None)
@given(data=st.data(), n=SIZES, block=BLOCKS)
def test_classify_soft_matches_ref(data, n, block):
    if block is not None and n % block != 0:
        block = None
    vs = _plane(data.draw, n, flags)
    ve = _plane(data.draw, n, flags)
    dl = _plane(data.draw, n, flags)
    got = membership.classify_soft(vs, ve, dl, block=block)
    want = ref.classify_soft(vs, ve, dl)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=60, deadline=None)
@given(data=st.data(), n=SIZES, block=BLOCKS)
def test_classify_linkfree_matches_ref(data, n, block):
    if block is not None and n % block != 0:
        block = None
    validity = _plane(data.draw, n, st.integers(min_value=0, max_value=3))
    marked = _plane(data.draw, n, flags)
    got = membership.classify_linkfree(validity, marked, block=block)
    want = ref.classify_linkfree(validity, marked)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=40, deadline=None)
@given(data=st.data(), n=SIZES, block=BLOCKS, nbits=st.integers(min_value=0, max_value=22))
def test_bucket_of_matches_ref(data, n, block, nbits):
    if block is not None and n % block != 0:
        block = None
    keys = jnp.asarray(
        data.draw(
            st.lists(
                st.integers(min_value=-(2**63), max_value=2**63 - 1),
                min_size=n,
                max_size=n,
            )
        ),
        dtype=jnp.int64,
    )
    mask = jnp.asarray([(1 << nbits) - 1], dtype=jnp.int64)
    got = bucket_hash.bucket_of(keys, mask, block=block)
    want = ref.bucket_of(keys, mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mix64_matches_rust_vector():
    # rust/src/util/mod.rs asserts mix64(0) == 0xE220A8397B1DCDAF.
    assert ref.np_mix64(0) == 0xE220A8397B1DCDAF
    got = ref.mix64(jnp.asarray([0], dtype=jnp.uint64))
    assert int(got[0]) == 0xE220A8397B1DCDAF


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**32),
    base=st.integers(min_value=0, max_value=2**20),
    key_range=st.integers(min_value=1, max_value=2**20),
    read_micros=st.integers(min_value=0, max_value=1_000_000),
)
def test_workload_kernel_matches_ref(seed, base, key_range, read_micros):
    n = 256
    params = jnp.asarray([seed, base, key_range, read_micros], dtype=jnp.int64)
    got_keys, got_ops = wl.workload(params, n, block=64)
    want_keys, want_ops = ref.workload(seed, base, n, key_range, read_micros)
    np.testing.assert_array_equal(np.asarray(got_keys), np.asarray(want_keys))
    np.testing.assert_array_equal(np.asarray(got_ops), np.asarray(want_ops))


def test_workload_read_fraction_statistics():
    n = 65536
    params = jnp.asarray([7, 0, 1024, 900_000], dtype=jnp.int64)
    keys, ops = wl.workload(params, n, block=4096)
    reads = int((np.asarray(ops) == 0).sum())
    frac = reads / n
    assert 0.88 < frac < 0.92, f"90% read mix off: {frac}"
    assert int(np.asarray(keys).max()) < 1024
    assert int(np.asarray(keys).min()) >= 0
    # Inserts vs removes roughly balanced among updates.
    ins = int((np.asarray(ops) == 1).sum())
    rem = int((np.asarray(ops) == 2).sum())
    assert abs(ins - rem) < 0.1 * (ins + rem)


def test_workload_batches_are_disjoint_continuations():
    # Batch (seed, base) then (seed, base+n) == one big batch split in two.
    params_a = jnp.asarray([3, 0, 4096, 500_000], dtype=jnp.int64)
    params_b = jnp.asarray([3, 256, 4096, 500_000], dtype=jnp.int64)
    ka, oa = wl.workload(params_a, 256, block=64)
    kb, ob = wl.workload(params_b, 256, block=64)
    kw, ow = ref.workload(3, 0, 512, 4096, 500_000)
    np.testing.assert_array_equal(np.concatenate([ka, kb]), np.asarray(kw))
    np.testing.assert_array_equal(np.concatenate([oa, ob]), np.asarray(ow))


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
