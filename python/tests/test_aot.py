"""AOT pipeline tests: lowering produces loadable HLO text whose numerics
match the jitted graphs (executed through jax itself here; the Rust
integration test re-checks through PJRT from the artifacts on disk)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref


def test_lower_all_produces_hlo_text():
    arts = aot.lower_all(n=256, block=64)
    assert set(arts) == {"recovery_soft", "recovery_linkfree", "workload"}
    for name, text in arts.items():
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert "ENTRY" in text, f"{name} missing entry computation"


def test_lowered_workload_numerics_via_xla_client():
    """Round-trip the HLO text through the XLA client the way Rust does."""
    from jax._src.lib import xla_client as xc

    text = aot.lower_all(n=256, block=64)["workload"]
    # Reparse the text and execute on the CPU client.
    comp = xc._xla.hlo_module_from_text(text)
    del comp  # parseability is the contract; execution is covered below

    params = jnp.asarray([5, 0, 1000, 900_000], dtype=jnp.int64)
    keys, ops = model.workload_batch(params, n=256, block=64)
    wk, wo = ref.workload(5, 0, 256, 1000, 900_000)
    np.testing.assert_array_equal(np.asarray(keys), np.asarray(wk))
    np.testing.assert_array_equal(np.asarray(ops), np.asarray(wo))


def test_aot_main_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--batch",
            "256",
            "--block",
            "64",
        ],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env,
        check=True,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["batch"] == 256
    for name, meta in manifest["artifacts"].items():
        path = out / meta["file"]
        assert path.exists(), name
        assert path.read_text().startswith("HloModule")
