"""Pytest bootstrap for the python/ tree.

Puts this directory on sys.path so the test modules can `from compile
import ...` regardless of the invocation directory (`pytest python/tests`,
`pytest`, or running from within python/).
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
