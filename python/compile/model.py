"""L2 JAX model: the recovery-analytics graphs and the workload graph.

These compose the L1 Pallas kernels into the computations the Rust runtime
executes from the artifacts:

* `recovery_plan_soft` / `recovery_plan_linkfree` — one batch of durable
  slots in, (member plane, bucket plane) out. The Rust recovery path feeds
  slot planes in fixed-size batches and relinks members into their buckets.
* `bucket_histogram` — per-bucket member counts (used by python tests and
  the analysis tooling; Rust computes its histogram during relink).
* `workload_batch` — one batch of deterministic (key, op) pairs.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import bucket_hash, membership, workload as wl

#: Batch size baked into the AOT artifacts. Rust pads the tail batch.
AOT_BATCH = 65536
#: Pallas tile size (elements per VMEM block).
AOT_BLOCK = 4096


@functools.partial(jax.jit, static_argnames=("block",))
def recovery_plan_soft(valid_start, valid_end, deleted, keys, bucket_mask, block=AOT_BLOCK):
    """(member int32[N], bucket int32[N]) for one batch of SOFT PNodes.

    Non-members still get a bucket id; consumers must gate on `member`.
    """
    member = membership.classify_soft(valid_start, valid_end, deleted, block=block)
    bucket = bucket_hash.bucket_of(keys, bucket_mask, block=block)
    return member, bucket


@functools.partial(jax.jit, static_argnames=("block",))
def recovery_plan_linkfree(validity, marked, keys, bucket_mask, block=AOT_BLOCK):
    """(member int32[N], bucket int32[N]) for one batch of link-free nodes."""
    member = membership.classify_linkfree(validity, marked, block=block)
    bucket = bucket_hash.bucket_of(keys, bucket_mask, block=block)
    return member, bucket


@functools.partial(jax.jit, static_argnames=("nbuckets",))
def bucket_histogram(member, bucket, nbuckets):
    """Members per bucket (scatter-add); `nbuckets` static."""
    return jnp.zeros(nbuckets, dtype=jnp.int32).at[bucket].add(member)


@functools.partial(jax.jit, static_argnames=("n", "block"))
def workload_batch(params, n=AOT_BATCH, block=AOT_BLOCK):
    """(keys int64[n], ops int32[n]) from params [seed, base, range, read_micros]."""
    return wl.workload(params, n, block=block)
