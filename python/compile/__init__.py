"""Build-time compile path (L2 JAX model + L1 Pallas kernels + AOT).

Nothing in this package runs at serving time: `aot.py` lowers the graphs to
HLO text once (`make artifacts`), and the Rust runtime executes the
artifacts via PJRT.
"""

import jax

# The durable-slot planes are 64-bit words on the Rust side; everything in
# the compile path runs with x64 enabled so key hashing matches bit-for-bit.
jax.config.update("jax_enable_x64", True)
