"""AOT lowering: L2 graphs -> artifacts/*.hlo.txt (HLO TEXT).

HLO *text* is the interchange format, NOT `lowered.compile()` /
serialized protos: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the rust side's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via `make artifacts` (no-op when artifacts are newer than sources).

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(n: int, block: int):
    """Lower every artifact graph for batch size `n`. Returns name->text."""
    i32 = jax.ShapeDtypeStruct((n,), jnp.int32)
    i64 = jax.ShapeDtypeStruct((n,), jnp.int64)
    s64 = jax.ShapeDtypeStruct((1,), jnp.int64)
    p64 = jax.ShapeDtypeStruct((4,), jnp.int64)

    arts = {}
    arts["recovery_soft"] = to_hlo_text(
        jax.jit(
            lambda vs, ve, dl, keys, mask: model.recovery_plan_soft(
                vs, ve, dl, keys, mask, block=block
            )
        ).lower(i32, i32, i32, i64, s64)
    )
    arts["recovery_linkfree"] = to_hlo_text(
        jax.jit(
            lambda v, m, keys, mask: model.recovery_plan_linkfree(
                v, m, keys, mask, block=block
            )
        ).lower(i32, i32, i64, s64)
    )
    arts["workload"] = to_hlo_text(
        jax.jit(lambda p: model.workload_batch(p, n=n, block=block)).lower(p64)
    )
    return arts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=model.AOT_BATCH)
    ap.add_argument("--block", type=int, default=model.AOT_BLOCK)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    arts = lower_all(args.batch, args.block)
    manifest = {"batch": args.batch, "block": args.block, "artifacts": {}}
    for name, text in arts.items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {"file": f"{name}.hlo.txt", "chars": len(text)}
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
