"""L1 Pallas kernels: durable-slot membership classification.

Recovery's bulk hot spot (DESIGN.md §Why L1/L2): given structure-of-arrays
flag planes extracted from the durable areas, decide for every slot whether
it is a live set member.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation): the planes are int32
vectors (densest supported element type for this data on the VPU); tiles of
`block` elements map HBM→VMEM via BlockSpec; the body is pure element-wise
VPU work (no MXU). `interpret=True` everywhere — the CPU PJRT plugin cannot
run Mosaic custom-calls; lowered HLO is plain elementwise ops.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _soft_kernel(vs_ref, ve_ref, dl_ref, out_ref):
    vs = vs_ref[...]
    ve = ve_ref[...]
    dl = dl_ref[...]
    out_ref[...] = ((vs == ve) & (dl != vs)).astype(jnp.int32)


def _linkfree_kernel(validity_ref, marked_ref, out_ref):
    v = validity_ref[...]
    v1 = v & 1
    v2 = (v >> 1) & 1
    out_ref[...] = ((v1 == v2) & (marked_ref[...] == 0)).astype(jnp.int32)


def _tiled(kernel, n_in, n, block):
    """Build a 1-D tiled pallas_call for `n` elements in `block` chunks."""
    if block is None or block >= n:
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
            interpret=True,
        )
    assert n % block == 0, f"n={n} must be a multiple of block={block}"
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[spec] * n_in,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )


@functools.partial(jax.jit, static_argnames=("block",))
def classify_soft(valid_start, valid_end, deleted, block=4096):
    """SOFT membership plane: 1 where validStart == validEnd != deleted."""
    n = valid_start.shape[0]
    return _tiled(_soft_kernel, 3, n, block)(valid_start, valid_end, deleted)


@functools.partial(jax.jit, static_argnames=("block",))
def classify_linkfree(validity, marked, block=4096):
    """Link-free membership plane: 1 where valid (v1==v2) and unmarked."""
    n = validity.shape[0]
    return _tiled(_linkfree_kernel, 2, n, block)(validity, marked)
