"""L1 Pallas kernel: splitmix64 bucket hashing.

Maps recovered member keys to hash buckets with exactly the same
`mix64(key) & mask` the Rust hash sets use, so the XLA-produced recovery
plan and the Rust structures agree on placement bit-for-bit.

Integer-only VPU work on uint64 lanes; tiled 1-D like the membership
kernels.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Plain ints: materialised as scalars *inside* the kernel body — pallas
# rejects kernels that close over traced array constants.
_C1 = 0x9E3779B97F4A7C15
_C2 = 0xBF58476D1CE4E5B9
_C3 = 0x94D049BB133111EB


def mix64_u(z):
    """splitmix64 finalizer on a uint64 vector (in-kernel version)."""
    z = (z + jnp.uint64(_C1)).astype(jnp.uint64)
    z = ((z ^ (z >> jnp.uint64(30))) * jnp.uint64(_C2)).astype(jnp.uint64)
    z = ((z ^ (z >> jnp.uint64(27))) * jnp.uint64(_C3)).astype(jnp.uint64)
    return z ^ (z >> jnp.uint64(31))


def _bucket_kernel(keys_ref, mask_ref, out_ref):
    # Keys arrive as int64 (the Rust FFI type); hash their bit pattern.
    k = jax.lax.bitcast_convert_type(keys_ref[...], jnp.uint64)
    m = jax.lax.bitcast_convert_type(mask_ref[...], jnp.uint64)[0]
    out_ref[...] = (mix64_u(k) & m).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block",))
def bucket_of(keys, bucket_mask, block=4096):
    """Bucket plane: mix64(key) & mask, as int32.

    `bucket_mask` is an int64[1] array (nbuckets-1, nbuckets a power of 2).
    """
    n = keys.shape[0]
    if block is None or block >= n:
        return pl.pallas_call(
            _bucket_kernel,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
            interpret=True,
        )(keys, bucket_mask)
    assert n % block == 0
    spec = pl.BlockSpec((block,), lambda i: (i,))
    # The mask is broadcast to every tile.
    mask_spec = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _bucket_kernel,
        grid=(n // block,),
        in_specs=[spec, mask_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(keys, bucket_mask)
