"""Pure-jnp/numpy oracles for every L1 kernel.

These are the correctness ground truth: `pytest python/tests` sweeps the
Pallas kernels against them (hypothesis-driven shapes and values), and the
Rust integration test cross-checks the AOT artifacts against the Rust
recovery scan, which mirrors this logic.
"""

import jax
import jax.numpy as jnp
import numpy as np

# splitmix64 finalizer — must match rust/src/util/mod.rs::mix64 bit-for-bit.
_C1 = 0x9E3779B97F4A7C15
_C2 = 0xBF58476D1CE4E5B9
_C3 = 0x94D049BB133111EB


def mix64(z):
    """Vectorised splitmix64 finalizer over uint64."""
    z = jnp.asarray(z).astype(jnp.uint64)
    z = (z + jnp.uint64(_C1)).astype(jnp.uint64)
    z = ((z ^ (z >> jnp.uint64(30))) * jnp.uint64(_C2)).astype(jnp.uint64)
    z = ((z ^ (z >> jnp.uint64(27))) * jnp.uint64(_C3)).astype(jnp.uint64)
    return z ^ (z >> jnp.uint64(31))


def classify_soft(valid_start, valid_end, deleted):
    """SOFT PNode membership: validStart == validEnd != deleted (paper §4.6).

    Flag planes are int32 0/1 vectors (one per PNode slot).
    """
    vs = jnp.asarray(valid_start)
    ve = jnp.asarray(valid_end)
    dl = jnp.asarray(deleted)
    return ((vs == ve) & (dl != vs)).astype(jnp.int32)


def classify_linkfree(validity, marked):
    """Link-free membership: v1 == v2 and next unmarked (paper §3.5).

    `validity` holds the raw 2-bit validity byte, `marked` the next-pointer
    mark bit, both as int32 planes.
    """
    v = jnp.asarray(validity)
    v1 = v & 1
    v2 = (v >> 1) & 1
    return ((v1 == v2) & (jnp.asarray(marked) == 0)).astype(jnp.int32)


def to_u64(keys):
    """Bit-preserving view of an int64/uint64 vector as uint64."""
    keys = jnp.asarray(keys)
    if keys.dtype == jnp.int64:
        return jax.lax.bitcast_convert_type(keys, jnp.uint64)
    return keys.astype(jnp.uint64)


def bucket_of(keys, bucket_mask):
    """Bucket index = mix64(key) & mask (matches LfHash/SoftHash)."""
    m = jnp.asarray(bucket_mask).astype(jnp.uint64).reshape(-1)[0]
    return (mix64(to_u64(keys)) & m).astype(jnp.int32)


def workload(seed, base, n, key_range, read_micros):
    """Counter-based op stream: key[i], op[i] for i in [base, base+n).

    op = 0 (read) with probability read_micros/1e6, else 1 (insert) or
    2 (remove) with equal probability. Deterministic in (seed, base).
    """
    idx = jnp.arange(n, dtype=jnp.uint64) + jnp.uint64(base)
    h1 = mix64(idx ^ mix64(jnp.uint64(seed)))
    h2 = mix64(h1)
    keys = h1 % jnp.uint64(key_range)
    draw = (h2 % jnp.uint64(1_000_000)).astype(jnp.int64)
    is_read = draw < jnp.int64(read_micros)
    upd_kind = ((h2 >> jnp.uint64(32)) & jnp.uint64(1)).astype(jnp.int64)  # 0/1
    ops = jnp.where(is_read, 0, 1 + upd_kind).astype(jnp.int32)
    return keys.astype(jnp.int64), ops


def np_mix64(z: int) -> int:
    """Scalar reference (independent of jax) for sanity tests."""
    z = (int(z) + _C1) % (1 << 64)
    z = ((z ^ (z >> 30)) * _C2) % (1 << 64)
    z = ((z ^ (z >> 27)) * _C3) % (1 << 64)
    return z ^ (z >> 31)


# np is re-exported for tests importing this module's helpers.
assert np is not None
