"""L1 Pallas kernels (interpret=True: CPU-PJRT executable HLO)."""
