"""L1 Pallas kernel: counter-based workload generation.

Generates the benchmark op stream (key + op kind) from a stateless counter,
so Rust benchmark threads can pull deterministic batches with no shared RNG
state: batch i of thread t is a pure function of (seed, t, i).

op encoding: 0 = contains, 1 = insert, 2 = remove. The read fraction is
`read_micros` per million (e.g. 900_000 = the paper's 90%-reads workload).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bucket_hash import mix64_u


def _workload_kernel(params_ref, keys_ref, ops_ref, *, block):
    # params: [seed, base, key_range, read_micros] as int64.
    seed = jax.lax.bitcast_convert_type(params_ref[0], jnp.uint64)
    base = jax.lax.bitcast_convert_type(params_ref[1], jnp.uint64)
    key_range = jax.lax.bitcast_convert_type(params_ref[2], jnp.uint64)
    read_micros = params_ref[3]
    i = pl.program_id(0).astype(jnp.uint64)
    idx = jnp.arange(block, dtype=jnp.uint64) + base + i * jnp.uint64(block)
    h1 = mix64_u(idx ^ mix64_u(seed))
    h2 = mix64_u(h1)
    keys = h1 % key_range
    draw = (h2 % jnp.uint64(1_000_000)).astype(jnp.int64)
    is_read = draw < read_micros
    upd_kind = ((h2 >> jnp.uint64(32)) & jnp.uint64(1)).astype(jnp.int64)
    keys_ref[...] = keys.astype(jnp.int64)
    ops_ref[...] = jnp.where(is_read, 0, 1 + upd_kind).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n", "block"))
def workload(params, n, block=4096):
    """Generate `n` (key, op) pairs from int64 params
    [seed, base, key_range, read_micros]."""
    block = min(block, n)
    assert n % block == 0
    import functools as ft

    kernel = ft.partial(_workload_kernel, block=block)
    params_spec = pl.BlockSpec((4,), lambda i: (0,))
    out_spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[params_spec],
        out_specs=(out_spec, out_spec),
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.int64),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ),
        interpret=True,
    )(params)
