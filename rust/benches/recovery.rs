//! Recovery bench: rebuild cost vs durable-set size, pure-Rust scan vs
//! XLA-accelerated classification (the L1/L2 pipeline), for SOFT and
//! link-free hash sets. Validates the §2.1 recovery design and gives the
//! slots/s numbers recorded in EXPERIMENTS.md.
mod common;

use durasets::coordinator::DuraKv;
use durasets::config::Config;
use durasets::pmem::{self, CrashPolicy};
use durasets::sets::Family;
use std::time::Instant;

fn bench_family(family: Family, keys: u64) {
    let mut cfg = Config::default();
    cfg.family = family;
    cfg.shards = 1;
    cfg.key_range = keys * 2;
    cfg.sim = true;
    cfg.psync_ns = 0;
    let kv = DuraKv::create(cfg);
    for k in 0..keys {
        kv.put(k * 2, k);
    }
    let ticket = kv.crash(CrashPolicy::PESSIMISTIC);
    let t0 = Instant::now();
    let (kv2, rep) = ticket.recover().unwrap();
    let rust_wall = t0.elapsed();

    let ticket = kv2.crash(CrashPolicy::PESSIMISTIC);
    let t0 = Instant::now();
    let (kv3, rep2) = ticket.recover_accel().unwrap();
    let accel_wall = t0.elapsed();
    assert_eq!(rep.members, rep2.members);
    let slots = (rep.members + rep.reclaimed) as f64;
    println!(
        "{:>10} {:>9} keys | rust {:>10.3?} ({:>6.1} Mslots/s) | accel {:>10.3?} ({:>6.1} Mslots/s)",
        family.to_string(),
        rep.members,
        rust_wall,
        slots / rust_wall.as_secs_f64() / 1e6,
        accel_wall,
        slots / accel_wall.as_secs_f64() / 1e6,
    );
    drop(kv3);
    pmem::set_mode(pmem::Mode::Perf);
}

fn main() {
    let cfg = common::setup();
    // Warm the thread-local planner cache so PJRT compilation (~150ms,
    // once per process) is not charged to the first data point. Without
    // the accel feature this reports "disabled" and the bench still runs
    // (both columns then measure the exact Rust recovery).
    if let Err(e) = durasets::runtime::RecoveryPlanner::with_cached(|_| Ok(())) {
        eprintln!("note: {e}");
    }
    let sizes: &[u64] = if cfg.full {
        &[10_000, 100_000, 1_000_000, 4_000_000]
    } else {
        &[10_000, 100_000, 500_000]
    };
    println!("== recovery: rebuild cost vs durable-set size (hash, 1 shard) ==");
    for &n in sizes {
        for family in [Family::Soft, Family::LinkFree] {
            bench_family(family, n);
        }
    }
}
