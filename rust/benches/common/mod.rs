//! Shared glue for the figure benches (custom harness, no criterion in
//! the offline crate set): set the psync model, print paper-style tables.

use durasets::bench::{report, Row, SweepCfg};

pub fn setup() -> SweepCfg {
    // The paper's clflush-class psync cost; override via env.
    let psync_ns = std::env::var("DURASETS_PSYNC_NS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100);
    durasets::pmem::set_psync_ns(psync_ns);
    let cfg = SweepCfg::from_env();
    println!(
        "# testbed: {} hw threads; full={} point={}ms psync_ns={psync_ns}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        cfg.full,
        cfg.duration.as_millis()
    );
    cfg
}

pub fn emit(title: &str, x_label: &str, rows: &[Row]) {
    print!("{}", report::render(title, x_label, rows));
    if let Some((f, x, imp)) = report::peak_improvement(rows) {
        println!("peak improvement vs log-free: {f} at {x_label}={x}: {imp:.2}x\n");
    }
}
