//! Ablations for the design choices DESIGN.md calls out:
//!
//! 1. **psync-latency regime sweep** — the paper's performance argument is
//!    that durable-set cost is flush-bound; sweeping the modelled clflush
//!    latency moves the workload from compute-bound (all families equal)
//!    to psync-bound (ranking follows psyncs/op: SOFT < link-free <
//!    log-free). This is the knob that reproduces the paper's *shape* on
//!    hardware without persistence instructions.
//! 2. **key distribution** — uniform (the paper) vs zipfian 0.99 (YCSB
//!    default): skew concentrates flush-flag hits and helping.
//! 3. **durability tax** — durable families vs the volatile Harris
//!    baseline at equal workloads.
mod common;

use durasets::bench::{build_set, run_phase, Row, FAMILIES};
use durasets::config::Structure;
use durasets::sets::Family;
use durasets::workload::{KeyDist, WorkloadSpec};
use std::time::Duration;

fn main() {
    let cfg = common::setup();
    let dur = cfg.duration;

    // 1. psync latency sweep (hash, 50% reads = YCSB A, 2 threads).
    let lats: Vec<u64> = vec![0, 100, 250, 500, 1000];
    let rows: Vec<Row> = lats
        .iter()
        .map(|&ns| {
            durasets::pmem::set_psync_ns(ns);
            let samples = FAMILIES
                .iter()
                .map(|&f| {
                    let set = build_set(f, Structure::Hash, 1 << 14);
                    let spec = WorkloadSpec::uniform(1 << 14, 50, 0xAB1);
                    (f, run_phase(set.as_ref(), spec, 2, dur))
                })
                .collect();
            Row { x: format!("{ns}ns"), samples }
        })
        .collect();
    common::emit(
        "Ablation 1: psync latency regime (hash 16K keys, 50% reads)",
        "psync_ns",
        &rows,
    );
    durasets::pmem::set_psync_ns(100);

    // 2. uniform vs zipfian.
    let rows: Vec<Row> = [("uniform", KeyDist::Uniform), ("zipf-0.99", KeyDist::Zipfian(0.99))]
        .iter()
        .map(|(name, dist)| {
            let samples = FAMILIES
                .iter()
                .map(|&f| {
                    let set = build_set(f, Structure::Hash, 1 << 14);
                    let spec = WorkloadSpec {
                        key_range: 1 << 14,
                        read_micros: 900_000,
                        dist: *dist,
                        seed: 0xAB2,
                    };
                    (f, run_phase(set.as_ref(), spec, 2, dur))
                })
                .collect();
            Row { x: name.to_string(), samples }
        })
        .collect();
    common::emit("Ablation 2: key distribution (hash 16K keys, 90% reads)", "dist", &rows);

    // 3. durability tax vs volatile Harris.
    let all = [Family::Volatile, Family::Soft, Family::LinkFree, Family::LogFree];
    let rows: Vec<Row> = [100u32, 50]
        .iter()
        .map(|&pct| {
            let samples = all
                .iter()
                .map(|&f| {
                    let set = build_set(f, Structure::Hash, 1 << 14);
                    let spec = WorkloadSpec::uniform(1 << 14, pct, 0xAB3);
                    (f, run_phase(set.as_ref(), spec, 2, Duration::from_millis(dur.as_millis() as u64)))
                })
                .collect();
            Row { x: format!("{pct}% reads"), samples }
        })
        .collect();
    common::emit("Ablation 3: durability tax vs volatile baseline", "mix", &rows);
}
