//! Paper Fig 1a/1b: list throughput + improvement vs #threads
//! (key ranges 256 and 1024, 90% reads, half-range pre-fill).
mod common;

fn main() {
    let cfg = common::setup();
    let rows = durasets::bench::fig1_lists(&cfg, 256, 0xF161A);
    common::emit("Fig 1a: list vs #threads (range 256, 90% reads)", "threads", &rows);
    let rows = durasets::bench::fig1_lists(&cfg, 1024, 0xF161B);
    common::emit("Fig 1b: list vs #threads (range 1024, 90% reads)", "threads", &rows);
}
