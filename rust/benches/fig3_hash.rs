//! Paper Fig 3c: hash throughput vs read fraction (50..100%; covers
//! YCSB A/B/C at 50/95/100).
mod common;

fn main() {
    let cfg = common::setup();
    let threads = (*cfg.threads.last().unwrap() / 2).max(1);
    let rows = durasets::bench::fig3_hash(&cfg, threads, 0xF163C);
    common::emit(
        &format!("Fig 3c: hash vs read% ({threads} threads)"),
        "read_pct",
        &rows,
    );
}
