//! Paper Fig 3a/3b: list throughput vs read fraction (50..100%,
//! ranges 256 and 1024; covers YCSB A/B/C).
mod common;

fn main() {
    let cfg = common::setup();
    let threads = *cfg.threads.last().unwrap();
    let rows = durasets::bench::fig3_lists(&cfg, threads, 256, 0xF163A);
    common::emit(
        &format!("Fig 3a: list vs read% (range 256, {threads} threads)"),
        "read_pct",
        &rows,
    );
    let rows = durasets::bench::fig3_lists(&cfg, threads, 1024, 0xF163B);
    common::emit(
        &format!("Fig 3b: list vs read% (range 1024, {threads} threads)"),
        "read_pct",
        &rows,
    );
}
