//! Profiling driver (not a figure): sustained update-heavy phase on one
//! family, for `perf record` / flamegraphs during the perf pass.
//! Usage: cargo bench --bench profile_target -- is ignored; env:
//!   DURASETS_PROFILE_FAMILY=soft|link-free|log-free|volatile
//!   DURASETS_PROFILE_MS=3000  DURASETS_PSYNC_NS=100  DURASETS_PROFILE_READPCT=0
mod common;

use durasets::config::Structure;
use durasets::sets::Family;
use durasets::workload::WorkloadSpec;
use std::time::Duration;

fn main() {
    let _ = common::setup();
    let family = Family::parse(
        &std::env::var("DURASETS_PROFILE_FAMILY").unwrap_or_else(|_| "soft".into()),
    )
    .unwrap();
    let ms: u64 = std::env::var("DURASETS_PROFILE_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(3000);
    let pct: u32 = std::env::var("DURASETS_PROFILE_READPCT").ok().and_then(|v| v.parse().ok()).unwrap_or(0);
    let range = 1 << 14;
    let set = durasets::bench::build_set(family, Structure::Hash, range);
    let spec = WorkloadSpec::uniform(range, pct, 1);
    let s = durasets::bench::run_phase(set.as_ref(), spec, 2, Duration::from_millis(ms));
    println!(
        "{family}: {:.3} Mops/s, {:.3} psync/op over {:?}",
        s.mops(),
        s.psync_per_op(),
        s.elapsed
    );
}
