//! Extension bench: skip lists vs flat lists across key ranges (90%
//! reads). The paper claims both schemes extend to skip lists — this
//! shows the volatile index turning the O(n) list walk into O(log n)
//! while durability costs (psyncs/op) stay identical, since only
//! bottom-level nodes are durable.
mod common;

use durasets::bench::{run_phase, Row};
use durasets::sets::{linkfree, soft};
use durasets::workload::{prefill, WorkloadSpec};

fn main() {
    let cfg = common::setup();
    let ranges = [256u64, 1024, 4096, 16384, 65536];
    let rows: Vec<Row> = ranges
        .iter()
        .map(|&range| {
            let spec = WorkloadSpec::uniform(range, 90, 0x5C1A);
            let list = linkfree::LfList::new();
            prefill(&list, range);
            let flat = run_phase(&list, spec, 2, cfg.duration);
            let skip = linkfree::LfSkipList::new();
            prefill(&skip, range);
            let lf_skip = run_phase(&skip, spec, 2, cfg.duration);
            let sskip = soft::SoftSkipList::new();
            prefill(&sskip, range);
            let soft_skip = run_phase(&sskip, spec, 2, cfg.duration);
            Row {
                x: range.to_string(),
                samples: vec![
                    (durasets::sets::Family::LinkFree, flat),
                    // Label reuse: volatile column = LF SKIP LIST,
                    // soft column = SOFT SKIP LIST.
                    (durasets::sets::Family::Volatile, lf_skip),
                    (durasets::sets::Family::Soft, soft_skip),
                ],
            }
        })
        .collect();
    println!("(label reuse: link-free = flat LF list, volatile = LF SKIP LIST, soft = SOFT SKIP LIST)");
    common::emit("Extension: skip lists vs flat list (90% reads)", "key_range", &rows);
}
