//! Paper Fig 1c: hash-set throughput + improvement vs #threads
//! (load factor 1, 90% reads; paper range 1M, scaled by default).
mod common;

fn main() {
    let cfg = common::setup();
    let rows = durasets::bench::fig1_hash(&cfg, 0xF161C);
    common::emit("Fig 1c: hash vs #threads (90% reads)", "threads", &rows);
}
