//! Paper Fig 2a: list throughput vs key range (16..16K x4, 90% reads,
//! max threads — paper used 64). Shows the SOFT/link-free crossover.
mod common;

fn main() {
    let cfg = common::setup();
    let threads = *cfg.threads.last().unwrap();
    let rows = durasets::bench::fig2_lists(&cfg, threads, 0xF162A);
    common::emit(
        &format!("Fig 2a: list vs key range ({threads} threads, 90% reads)"),
        "key_range",
        &rows,
    );
}
