//! Micro table: per-op psync counts + single-threaded latency for every
//! family and op kind — the cost model behind the paper's §6 analysis
//! (SOFT: 1 psync/update 0/read at the theoretical bound; link-free ~1;
//! log-free ~2 plus reader-side flushes of dirty links).
mod common;

use durasets::config::Structure;
use durasets::pmem::stats;
use durasets::sets::{ConcurrentSet, Family};
use std::time::Instant;

fn measure(family: Family) {
    let set = durasets::bench::build_set(family, Structure::Hash, 1 << 14);
    let n = 10_000u64;
    let base = 1 << 20; // keys outside the prefill range

    let mut line = format!("{:>10}", family.to_string());
    // insert (fresh keys)
    let s0 = stats::snapshot();
    let t0 = Instant::now();
    for k in 0..n {
        set.insert(base + k, k);
    }
    let dt = t0.elapsed();
    let d = stats::snapshot().since(&s0);
    line += &format!(
        " | insert {:>7.0}ns {:>5.2}psync",
        dt.as_nanos() as f64 / n as f64,
        d.fences as f64 / n as f64
    );
    // contains (hit)
    let s0 = stats::snapshot();
    let t0 = Instant::now();
    for k in 0..n {
        set.contains(base + k);
    }
    let dt = t0.elapsed();
    let d = stats::snapshot().since(&s0);
    line += &format!(
        " | read {:>7.0}ns {:>5.2}psync",
        dt.as_nanos() as f64 / n as f64,
        d.fences as f64 / n as f64
    );
    // remove (hit)
    let s0 = stats::snapshot();
    let t0 = Instant::now();
    for k in 0..n {
        set.remove(base + k);
    }
    let dt = t0.elapsed();
    let d = stats::snapshot().since(&s0);
    line += &format!(
        " | remove {:>7.0}ns {:>5.2}psync",
        dt.as_nanos() as f64 / n as f64,
        d.fences as f64 / n as f64
    );
    println!("{line}");
}

fn main() {
    let _ = common::setup();
    println!("== micro: per-op latency + exact psyncs/op (successful ops, no contention) ==");
    for f in [Family::Soft, Family::LinkFree, Family::LogFree, Family::Volatile] {
        measure(f);
    }
    println!(
        "\nexpected psyncs/op: soft 1/0/1, link-free 1/0/1 (flag-elided), log-free 2/0/2, volatile 0/0/0"
    );
}
