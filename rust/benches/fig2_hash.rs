//! Paper Fig 2b: hash throughput vs key range (paper 1K..4M x16,
//! 32 threads; scaled by default — DURASETS_FULL=1 for paper scale).
mod common;

fn main() {
    let cfg = common::setup();
    let threads = (*cfg.threads.last().unwrap() / 2).max(1);
    let rows = durasets::bench::fig2_hash(&cfg, threads, 0xF162B);
    common::emit(
        &format!("Fig 2b: hash vs key range ({threads} threads, 90% reads)"),
        "key_range",
        &rows,
    );
}
