//! Integration: XLA-accelerated recovery == pure-Rust recovery,
//! bit-for-bit, through a real crash/recovery cycle.

use durasets::pmem::{self, CrashPolicy};
use durasets::runtime::recovery_accel::{
    recover_linkfree_hash_accel, recover_soft_hash_accel,
};
use durasets::runtime::RecoveryPlanner;
use durasets::sets::{linkfree, soft, ConcurrentSet};
use durasets::util::rng::Xoshiro256;

fn have_artifacts() -> bool {
    durasets::runtime::artifacts_dir().join("manifest.json").exists()
}

/// Whole-process serialisation: crash() is global.
static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[test]
fn soft_accel_recovery_matches_rust_recovery() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let _g = LOCK.lock().unwrap();
    let _sim = pmem::sim_session();

    // Two identical structures, driven by the same op sequence.
    let a = soft::SoftHash::new(64);
    let b = soft::SoftHash::new(64);
    let mut rng = Xoshiro256::new(0xACCE1);
    for _ in 0..5000 {
        let k = rng.below(512);
        match rng.below(3) {
            0 => {
                a.insert(k, k * 3);
                b.insert(k, k * 3);
            }
            1 => {
                a.remove(k);
                b.remove(k);
            }
            _ => {}
        }
    }
    let (ida, idb) = (a.pool_id(), b.pool_id());
    a.crash_preserve();
    b.crash_preserve();
    drop(a);
    drop(b);
    pmem::crash_pools(CrashPolicy::random(0.2, 3), &[ida, idb]);

    let planner = RecoveryPlanner::load().unwrap();
    let (ha, sa) = recover_soft_hash_accel(&planner, ida, 64).unwrap();
    let (hb, sb) = soft::recover_hash(idb, 64);

    assert_eq!(sa.members, sb.members, "accel vs rust member count");
    let mut snap_a = ha.snapshot();
    let mut snap_b = hb.snapshot();
    snap_a.sort_unstable();
    snap_b.sort_unstable();
    assert_eq!(snap_a, snap_b, "recovered contents differ");

    // Both recovered structures stay fully operational.
    for k in 0..100u64 {
        assert_eq!(ha.insert(10_000 + k, k), hb.insert(10_000 + k, k));
    }
}

#[test]
fn linkfree_accel_recovery_matches_rust_recovery() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let _g = LOCK.lock().unwrap();
    let _sim = pmem::sim_session();

    let a = linkfree::LfHash::new(32);
    let b = linkfree::LfHash::new(32);
    let mut rng = Xoshiro256::new(0xACCE2);
    for _ in 0..5000 {
        let k = rng.below(400);
        match rng.below(3) {
            0 => {
                a.insert(k, k + 9);
                b.insert(k, k + 9);
            }
            1 => {
                a.remove(k);
                b.remove(k);
            }
            _ => {}
        }
    }
    let (ida, idb) = (a.pool_id(), b.pool_id());
    a.crash_preserve();
    b.crash_preserve();
    drop(a);
    drop(b);
    pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[ida, idb]);

    let planner = RecoveryPlanner::load().unwrap();
    let (ha, sa) = recover_linkfree_hash_accel(&planner, ida, 32).unwrap();
    let (hb, sb) = linkfree::recover_hash(idb, 32);

    assert_eq!(sa.members, sb.members);
    let mut snap_a = ha.snapshot();
    let mut snap_b = hb.snapshot();
    snap_a.sort_unstable();
    snap_b.sort_unstable();
    assert_eq!(snap_a, snap_b);
}

#[test]
fn workload_accel_stream_is_deterministic_and_plausible() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let gen = durasets::runtime::WorkloadGen::load().unwrap();
    let (k1, o1) = gen.batch(42, 0, 1024, 900_000).unwrap();
    let (k2, o2) = gen.batch(42, 0, 1024, 900_000).unwrap();
    assert_eq!(k1, k2, "same params => same stream");
    assert_eq!(o1, o2);
    let (k3, _) = gen.batch(42, gen.batch_len() as u64, 1024, 900_000).unwrap();
    assert_ne!(k1, k3, "different base => different stream");
    assert!(k1.iter().all(|&k| k < 1024));
    let reads = o1.iter().filter(|&&o| o == 0).count() as f64 / o1.len() as f64;
    assert!((0.88..0.92).contains(&reads));
}
