//! Integration: XLA-accelerated recovery == pure-Rust recovery,
//! bit-for-bit, through a real crash/recovery cycle.

use durasets::pmem::{self, CrashPolicy};
use durasets::runtime::recovery_accel::{
    recover_linkfree_hash_accel, recover_resizable_linkfree_accel, recover_resizable_soft_accel,
    recover_soft_hash_accel,
};
use durasets::runtime::RecoveryPlanner;
use durasets::sets::{linkfree, resizable, soft, ConcurrentSet};
use durasets::util::rng::Xoshiro256;

fn have_artifacts() -> bool {
    durasets::runtime::artifacts_dir().join("manifest.json").exists()
}

/// Whole-process serialisation: crash() is global.
static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[test]
fn soft_accel_recovery_matches_rust_recovery() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let _g = LOCK.lock().unwrap();
    let _sim = pmem::sim_session();

    // Two identical structures, driven by the same op sequence.
    let a = soft::SoftHash::new(64);
    let b = soft::SoftHash::new(64);
    let mut rng = Xoshiro256::new(0xACCE1);
    for _ in 0..5000 {
        let k = rng.below(512);
        match rng.below(3) {
            0 => {
                a.insert(k, k * 3);
                b.insert(k, k * 3);
            }
            1 => {
                a.remove(k);
                b.remove(k);
            }
            _ => {}
        }
    }
    let (ida, idb) = (a.pool_id(), b.pool_id());
    a.crash_preserve();
    b.crash_preserve();
    drop(a);
    drop(b);
    pmem::crash_pools(CrashPolicy::random(0.2, 3), &[ida, idb]);

    let planner = RecoveryPlanner::load().unwrap();
    let (ha, sa) = recover_soft_hash_accel(&planner, ida, 64).unwrap();
    let (hb, sb) = soft::recover_hash(idb, 64);

    assert_eq!(sa.members, sb.members, "accel vs rust member count");
    let mut snap_a = ha.snapshot();
    let mut snap_b = hb.snapshot();
    snap_a.sort_unstable();
    snap_b.sort_unstable();
    assert_eq!(snap_a, snap_b, "recovered contents differ");

    // Both recovered structures stay fully operational.
    for k in 0..100u64 {
        assert_eq!(ha.insert(10_000 + k, k), hb.insert(10_000 + k, k));
    }
}

#[test]
fn linkfree_accel_recovery_matches_rust_recovery() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let _g = LOCK.lock().unwrap();
    let _sim = pmem::sim_session();

    let a = linkfree::LfHash::new(32);
    let b = linkfree::LfHash::new(32);
    let mut rng = Xoshiro256::new(0xACCE2);
    for _ in 0..5000 {
        let k = rng.below(400);
        match rng.below(3) {
            0 => {
                a.insert(k, k + 9);
                b.insert(k, k + 9);
            }
            1 => {
                a.remove(k);
                b.remove(k);
            }
            _ => {}
        }
    }
    let (ida, idb) = (a.pool_id(), b.pool_id());
    a.crash_preserve();
    b.crash_preserve();
    drop(a);
    drop(b);
    pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[ida, idb]);

    let planner = RecoveryPlanner::load().unwrap();
    let (ha, sa) = recover_linkfree_hash_accel(&planner, ida, 32).unwrap();
    let (hb, sb) = linkfree::recover_hash(idb, 32);

    assert_eq!(sa.members, sb.members);
    let mut snap_a = ha.snapshot();
    let mut snap_b = hb.snapshot();
    snap_a.sort_unstable();
    snap_b.sort_unstable();
    assert_eq!(snap_a, snap_b);
}

/// The store path's actual layout: resizable hashes persist one family
/// list in okey order. The artifact path (classification kernel, mask 0)
/// must match the exact Rust recovery bit-for-bit — members, stats, and
/// the restored bucket-count epoch.
#[test]
fn resizable_accel_recovery_matches_rust_recovery() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let _g = LOCK.lock().unwrap();
    let _sim = pmem::sim_session();

    // Link-free pair.
    let a = resizable::ResizableHash::new_linkfree(2);
    let b = resizable::ResizableHash::new_linkfree(2);
    // SOFT pair.
    let c = resizable::ResizableHash::new_soft(2);
    let d = resizable::ResizableHash::new_soft(2);
    let mut rng = Xoshiro256::new(0xACCE3);
    for _ in 0..5000 {
        let k = rng.below(512);
        match rng.below(3) {
            0 => {
                a.insert(k, k * 3);
                b.insert(k, k * 3);
                c.insert(k, k * 3);
                d.insert(k, k * 3);
            }
            1 => {
                a.remove(k);
                b.remove(k);
                c.remove(k);
                d.remove(k);
            }
            _ => {}
        }
    }
    let grown_lf = a.nbuckets();
    let grown_soft = c.nbuckets();
    assert!(grown_lf >= 8 && grown_soft >= 8, "must exercise growth");
    let ids = [a.pool_id(), b.pool_id(), c.pool_id(), d.pool_id()];
    a.crash_preserve();
    b.crash_preserve();
    c.crash_preserve();
    d.crash_preserve();
    drop((a, b, c, d));
    pmem::crash_pools(CrashPolicy::random(0.2, 17), &ids);

    let planner = RecoveryPlanner::load().unwrap();
    let (ha, sa, _) = recover_resizable_linkfree_accel(&planner, ids[0], 2, 8).unwrap();
    let (hb, sb) = resizable::recover_linkfree(ids[1], 2);
    assert_eq!(sa.members, sb.members, "linkfree accel vs rust member count");
    assert_eq!(sa.reclaimed, sb.reclaimed);
    assert_eq!(ha.nbuckets(), grown_lf, "accel path must restore the epoch");
    assert_eq!(hb.nbuckets(), grown_lf);
    let (mut snap_a, mut snap_b) = (ha.snapshot(), hb.snapshot());
    snap_a.sort_unstable();
    snap_b.sort_unstable();
    assert_eq!(snap_a, snap_b, "linkfree recovered contents differ");

    let (hc, sc, _) = recover_resizable_soft_accel(&planner, ids[2], 2, 1).unwrap();
    let (hd, sd) = resizable::recover_soft(ids[3], 2);
    assert_eq!(sc.members, sd.members, "soft accel vs rust member count");
    assert_eq!(hc.nbuckets(), grown_soft);
    assert_eq!(hd.nbuckets(), grown_soft);
    let (mut snap_c, mut snap_d) = (hc.snapshot(), hd.snapshot());
    snap_c.sort_unstable();
    snap_d.sort_unstable();
    assert_eq!(snap_c, snap_d, "soft recovered contents differ");

    // Both recovered tables stay fully operational (growth included).
    for k in 10_000..10_200u64 {
        assert_eq!(ha.insert(k, k), hb.insert(k, k));
        assert_eq!(hc.insert(k, k), hd.insert(k, k));
    }
}

/// Offline / artifact-less builds must fall back to the exact Rust path
/// through the same entry point, without claiming acceleration. (This
/// test runs in every configuration; with artifacts present it instead
/// pins that the store path now *does* claim acceleration.)
#[test]
fn recover_accel_store_path_engages_or_falls_back() {
    let _g = LOCK.lock().unwrap();
    let _sim = pmem::sim_session();
    let mut cfg = durasets::config::Config::default();
    cfg.family = durasets::sets::Family::LinkFree;
    cfg.shards = 2;
    cfg.key_range = 4096;
    cfg.sim = true;
    cfg.psync_ns = 0;
    let kv = durasets::coordinator::DuraKv::create(cfg);
    for k in 0..400u64 {
        assert!(kv.put(k, k + 3));
    }
    let ticket = kv.crash(CrashPolicy::PESSIMISTIC);
    let (kv2, report) = ticket.recover_accel().unwrap();
    assert_eq!(report.members, 400);
    let planner_available = RecoveryPlanner::with_cached(|_| Ok(())).is_ok();
    assert_eq!(
        report.accelerated, planner_available,
        "accelerated flag must reflect whether the artifact path actually ran"
    );
    for k in 0..400u64 {
        assert_eq!(kv2.get(k), Some(k + 3), "key {k}");
    }
    assert!(kv2.put(9999, 1), "store writable after accel/fallback recovery");
}

#[test]
fn workload_accel_stream_is_deterministic_and_plausible() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let gen = durasets::runtime::WorkloadGen::load().unwrap();
    let (k1, o1) = gen.batch(42, 0, 1024, 900_000).unwrap();
    let (k2, o2) = gen.batch(42, 0, 1024, 900_000).unwrap();
    assert_eq!(k1, k2, "same params => same stream");
    assert_eq!(o1, o2);
    let (k3, _) = gen.batch(42, gen.batch_len() as u64, 1024, 900_000).unwrap();
    assert_ne!(k1, k3, "different base => different stream");
    assert!(k1.iter().all(|&k| k < 1024));
    let reads = o1.iter().filter(|&&o| o == 0).count() as f64 / o1.len() as f64;
    assert!((0.88..0.92).contains(&reads));
}
