//! True linearizability checking of concurrent histories (Wing & Gong /
//! WGL style), applied to every set family.
//!
//! Worker threads record timestamped invocation/response pairs for random
//! ops over a tiny key space. The checker searches for a linearization:
//! a total order that (a) respects real-time order (if resp(q) < inv(p),
//! q precedes p), (b) respects per-thread program order, and (c) replays
//! correctly against the sequential set specification.
//!
//! Tractability: per-thread subhistories are sequential, so the DFS state
//! is (per-thread progress vector, abstract set state) — memoizable and
//! tiny for small key spaces. This checks the *volatile* linearizability
//! claims (paper Appendix B/C assume them); durable linearizability under
//! crashes is covered by `crash_durability.rs`.

use durasets::sets::{self, ConcurrentSet, Family, OpResult, SetOp};
use durasets::util::rng::Xoshiro256;
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Insert,
    Remove,
    Contains,
}

#[derive(Clone, Copy, Debug)]
struct Event {
    kind: Kind,
    key: u64,
    result: bool,
    inv: u64,
    resp: u64,
}

/// One thread's recorded (sequential) subhistory.
type ThreadHistory = Vec<Event>;

/// Record histories; with `batch_prob_pct > 0`, a slice of each thread's
/// ops is issued as small `apply_batch` calls. A batch's constituent ops
/// are recorded as individual events sharing the batch's inv/resp
/// interval, in batch order (program order within the thread) — the batch
/// is linearizable iff each op linearizes individually inside it.
fn record_mixed(
    family: Family,
    threads: usize,
    ops_per_thread: usize,
    keys: u64,
    seed: u64,
    batch_prob_pct: u64,
) -> Vec<ThreadHistory> {
    let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_hash(family, 4));
    let clock = Arc::new(AtomicU64::new(0));
    let barrier = Arc::new(Barrier::new(threads));
    let handles: Vec<_> = (0..threads as u64)
        .map(|t| {
            let set = set.clone();
            let clock = clock.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let mut rng = Xoshiro256::new(seed ^ (t * 0x9E37));
                let mut hist = Vec::with_capacity(ops_per_thread);
                barrier.wait();
                while hist.len() < ops_per_thread {
                    if rng.below(100) < batch_prob_pct {
                        // A small explicit batch (2-4 ops).
                        let n = 2 + rng.below(3) as usize;
                        let mut ops = Vec::with_capacity(n);
                        let mut kinds = Vec::with_capacity(n);
                        for _ in 0..n {
                            let key = rng.below(keys);
                            match rng.below(3) {
                                0 => {
                                    ops.push(SetOp::Insert(key, key));
                                    kinds.push((Kind::Insert, key));
                                }
                                1 => {
                                    ops.push(SetOp::Remove(key));
                                    kinds.push((Kind::Remove, key));
                                }
                                _ => {
                                    ops.push(SetOp::Contains(key));
                                    kinds.push((Kind::Contains, key));
                                }
                            }
                        }
                        let inv = clock.fetch_add(1, Ordering::SeqCst);
                        let results = set.apply_batch(&ops);
                        let resp = clock.fetch_add(1, Ordering::SeqCst);
                        for ((kind, key), res) in kinds.into_iter().zip(results) {
                            let result = match res {
                                OpResult::Applied(b) | OpResult::Found(b) => b,
                                OpResult::Value(v) => v.is_some(),
                            };
                            hist.push(Event { kind, key, result, inv, resp });
                        }
                    } else {
                        let key = rng.below(keys);
                        let kind = match rng.below(3) {
                            0 => Kind::Insert,
                            1 => Kind::Remove,
                            _ => Kind::Contains,
                        };
                        let inv = clock.fetch_add(1, Ordering::SeqCst);
                        let result = match kind {
                            Kind::Insert => set.insert(key, key),
                            Kind::Remove => set.remove(key),
                            Kind::Contains => set.contains(key),
                        };
                        let resp = clock.fetch_add(1, Ordering::SeqCst);
                        hist.push(Event { kind, key, result, inv, resp });
                    }
                }
                hist
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

fn record(
    family: Family,
    threads: usize,
    ops_per_thread: usize,
    keys: u64,
    seed: u64,
) -> Vec<ThreadHistory> {
    record_mixed(family, threads, ops_per_thread, keys, seed, 0)
}

/// Replay `e` against the abstract set state (bitmask over keys < 64).
/// Returns the new state, or None if the observed result contradicts the
/// sequential specification.
fn step(state: u64, e: &Event) -> Option<u64> {
    let bit = 1u64 << e.key;
    match e.kind {
        Kind::Insert => {
            let fresh = state & bit == 0;
            if e.result != fresh {
                return None;
            }
            Some(state | bit)
        }
        Kind::Remove => {
            let present = state & bit != 0;
            if e.result != present {
                return None;
            }
            Some(state & !bit)
        }
        Kind::Contains => {
            if e.result != (state & bit != 0) {
                return None;
            }
            Some(state)
        }
    }
}

/// WGL search: is there a valid linearization?
fn linearizable(hist: &[ThreadHistory]) -> bool {
    let n = hist.len();
    let mut memo: HashSet<(Vec<usize>, u64)> = HashSet::new();
    // Iterative DFS over (progress vector, state).
    let mut stack = vec![(vec![0usize; n], 0u64)];
    while let Some((prog, state)) = stack.pop() {
        if prog.iter().zip(hist).all(|(&i, h)| i == h.len()) {
            return true;
        }
        if !memo.insert((prog.clone(), state)) {
            continue;
        }
        // Candidate next op from each thread: its front unlinearized op p
        // is admissible iff no other unlinearized op q responded before
        // p's invocation (real-time order).
        for t in 0..n {
            let i = prog[t];
            if i == hist[t].len() {
                continue;
            }
            let p = &hist[t][i];
            let mut admissible = true;
            for (u, h) in hist.iter().enumerate() {
                for q in &h[prog[u]..] {
                    if (u != t || q.inv != p.inv) && q.resp < p.inv {
                        admissible = false;
                        break;
                    }
                }
                if !admissible {
                    break;
                }
            }
            if !admissible {
                continue;
            }
            if let Some(next_state) = step(state, p) {
                let mut next_prog = prog.clone();
                next_prog[t] += 1;
                stack.push((next_prog, next_state));
            }
        }
    }
    false
}

fn check_family(family: Family, rounds: u64) {
    for round in 0..rounds {
        let hist = record(family, 3, 60, 4, 0xC0DE ^ round);
        let total: usize = hist.iter().map(|h| h.len()).sum();
        assert!(
            linearizable(&hist),
            "{family}: history of {total} ops is NOT linearizable (round {round}): {hist:#?}"
        );
    }
}

#[test]
fn linkfree_hash_is_linearizable() {
    check_family(Family::LinkFree, 8);
}

#[test]
fn soft_hash_is_linearizable() {
    check_family(Family::Soft, 8);
}

#[test]
fn logfree_hash_is_linearizable() {
    check_family(Family::LogFree, 8);
}

#[test]
fn volatile_hash_is_linearizable() {
    check_family(Family::Volatile, 8);
}

/// Mixed batch/single-op histories: group-committed batches must
/// linearize as their constituent ops (batching defers only the issuer's
/// fence, never the linearization point).
#[test]
fn mixed_batch_histories_are_linearizable() {
    for family in [Family::Soft, Family::LinkFree, Family::LogFree] {
        for round in 0..4u64 {
            let hist = record_mixed(family, 3, 60, 4, 0xBA7C4 ^ round, 35);
            let total: usize = hist.iter().map(|h| h.len()).sum();
            assert!(
                linearizable(&hist),
                "{family}: mixed batch history of {total} ops NOT linearizable (round {round}): {hist:#?}"
            );
        }
    }
}

/// Record histories against a sharded `DuraKv`, mixing three issue paths
/// per thread: plain single ops, plain (per-shard-atomic) batches, and
/// **atomic cross-shard batches** (`apply_batch_atomic`). Batch
/// constituents are recorded as individual events sharing the batch's
/// inv/resp interval — an atomic batch serializes against the store-wide
/// txn lock, but its ops must still linearize individually like any
/// other batch (atomicity is a *crash* guarantee; the volatile
/// linearization contract is unchanged).
fn record_kv_mixed(
    family: Family,
    threads: usize,
    ops_per_thread: usize,
    keys: u64,
    seed: u64,
) -> Vec<ThreadHistory> {
    use durasets::config::Config;
    use durasets::coordinator::DuraKv;
    let mut cfg = Config::default();
    cfg.family = family;
    cfg.shards = 3;
    cfg.key_range = 1 << 10;
    cfg.psync_ns = 0;
    let kv = Arc::new(DuraKv::create(cfg));
    let clock = Arc::new(AtomicU64::new(0));
    let barrier = Arc::new(Barrier::new(threads));
    let handles: Vec<_> = (0..threads as u64)
        .map(|t| {
            let kv = kv.clone();
            let clock = clock.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let mut rng = Xoshiro256::new(seed ^ (t * 0xA7C));
                let mut hist = Vec::with_capacity(ops_per_thread);
                barrier.wait();
                while hist.len() < ops_per_thread {
                    let style = rng.below(100);
                    if style < 40 {
                        // Plain single op.
                        let key = rng.below(keys);
                        let kind = match rng.below(3) {
                            0 => Kind::Insert,
                            1 => Kind::Remove,
                            _ => Kind::Contains,
                        };
                        let inv = clock.fetch_add(1, Ordering::SeqCst);
                        let result = match kind {
                            Kind::Insert => kv.put(key, key),
                            Kind::Remove => kv.del(key),
                            Kind::Contains => kv.contains(key),
                        };
                        let resp = clock.fetch_add(1, Ordering::SeqCst);
                        hist.push(Event { kind, key, result, inv, resp });
                    } else {
                        // A small batch, plain or atomic (cross-shard:
                        // with 3 shards and 2-4 ops it regularly spans
                        // several shards).
                        let atomic = style >= 70;
                        let n = 2 + rng.below(3) as usize;
                        let mut ops = Vec::with_capacity(n);
                        let mut kinds = Vec::with_capacity(n);
                        for _ in 0..n {
                            let key = rng.below(keys);
                            match rng.below(3) {
                                0 => {
                                    ops.push(SetOp::Insert(key, key));
                                    kinds.push((Kind::Insert, key));
                                }
                                1 => {
                                    ops.push(SetOp::Remove(key));
                                    kinds.push((Kind::Remove, key));
                                }
                                _ => {
                                    ops.push(SetOp::Contains(key));
                                    kinds.push((Kind::Contains, key));
                                }
                            }
                        }
                        let inv = clock.fetch_add(1, Ordering::SeqCst);
                        let results = if atomic {
                            kv.apply_batch_atomic(&ops)
                        } else {
                            kv.apply_batch(&ops)
                        };
                        let resp = clock.fetch_add(1, Ordering::SeqCst);
                        for ((kind, key), res) in kinds.into_iter().zip(results) {
                            let result = match res {
                                OpResult::Applied(b) | OpResult::Found(b) => b,
                                OpResult::Value(v) => v.is_some(),
                            };
                            hist.push(Event { kind, key, result, inv, resp });
                        }
                    }
                }
                hist
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Mixed atomic/plain batch histories over the sharded store: atomic
/// batches must linearize exactly like plain ones (the txn machinery —
/// record publish, worker exclusion on the wire path, roll-forward —
/// must never change what concurrent readers can observe).
#[test]
fn mixed_atomic_and_plain_batches_are_linearizable() {
    for family in [Family::Soft, Family::LinkFree, Family::LogFree] {
        for round in 0..3u64 {
            let hist = record_kv_mixed(family, 3, 48, 4, 0xA70_71C ^ round);
            let total: usize = hist.iter().map(|h| h.len()).sum();
            assert!(
                linearizable(&hist),
                "{family}: atomic/plain history of {total} ops NOT linearizable \
                 (round {round}): {hist:#?}"
            );
        }
    }
}

/// Record histories against a skip-list set, mixing point updates with
/// **ordered reads** (`OrderedSet::range`/`scan`). A scan is recorded as
/// one `Contains` event per key of its window — present iff the key
/// appeared in the result — all sharing the scan's inv/resp interval.
/// That is exactly the guarantee a single-pass walk provides: each key's
/// membership was observed at *some* point inside the scan's interval
/// (no atomic-snapshot claim), and each observation must still respect
/// real-time order against every other thread's acked ops. `scan` is
/// issued with `n = keys` so a key missing from the result set means
/// "absent", never "truncated".
fn record_scan_mixed(
    family: Family,
    threads: usize,
    ops_per_thread: usize,
    keys: u64,
    seed: u64,
) -> Vec<ThreadHistory> {
    let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_skiplist(family));
    let clock = Arc::new(AtomicU64::new(0));
    let barrier = Arc::new(Barrier::new(threads));
    let handles: Vec<_> = (0..threads as u64)
        .map(|t| {
            let set = set.clone();
            let clock = clock.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let ord = set.as_ordered().expect("skip lists are ordered");
                let mut rng = Xoshiro256::new(seed ^ (t * 0x5CA));
                let mut hist = Vec::with_capacity(ops_per_thread);
                barrier.wait();
                while hist.len() < ops_per_thread {
                    let style = rng.below(100);
                    if style < 55 {
                        let key = rng.below(keys);
                        let kind = match rng.below(3) {
                            0 => Kind::Insert,
                            1 => Kind::Remove,
                            _ => Kind::Contains,
                        };
                        let inv = clock.fetch_add(1, Ordering::SeqCst);
                        let result = match kind {
                            Kind::Insert => set.insert(key, key),
                            Kind::Remove => set.remove(key),
                            Kind::Contains => set.contains(key),
                        };
                        let resp = clock.fetch_add(1, Ordering::SeqCst);
                        hist.push(Event { kind, key, result, inv, resp });
                    } else if style < 80 {
                        // RANGE over a random window.
                        let a = rng.below(keys);
                        let b = rng.below(keys);
                        let (lo, hi) = (a.min(b), a.max(b));
                        let inv = clock.fetch_add(1, Ordering::SeqCst);
                        let pairs = ord.range(lo, hi);
                        let resp = clock.fetch_add(1, Ordering::SeqCst);
                        let got: HashSet<u64> = pairs
                            .iter()
                            .map(|&(k, v)| {
                                assert_eq!(v, k, "scan surfaced a torn value");
                                k
                            })
                            .collect();
                        assert!(pairs.windows(2).all(|w| w[0].0 < w[1].0), "unsorted range");
                        for k in lo..=hi {
                            let result = got.contains(&k);
                            hist.push(Event { kind: Kind::Contains, key: k, result, inv, resp });
                        }
                    } else {
                        // SCAN past a random cursor, n wide enough to
                        // cover the whole key space (no truncation).
                        let cursor = rng.below(keys);
                        let inv = clock.fetch_add(1, Ordering::SeqCst);
                        let pairs = ord.scan(cursor, keys as usize);
                        let resp = clock.fetch_add(1, Ordering::SeqCst);
                        let got: HashSet<u64> = pairs.iter().map(|&(k, _)| k).collect();
                        assert!(got.iter().all(|&k| k > cursor), "scan ignored its cursor");
                        for k in cursor + 1..keys {
                            let result = got.contains(&k);
                            hist.push(Event { kind: Kind::Contains, key: k, result, inv, resp });
                        }
                    }
                }
                hist
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Ordered reads must linearize against concurrent point updates for
/// both skip-list families: every key-membership a RANGE/SCAN reports
/// must be explainable at some point inside the scan's interval.
#[test]
fn skiplist_scans_are_linearizable() {
    for family in [Family::Soft, Family::LinkFree] {
        for round in 0..3u64 {
            let hist = record_scan_mixed(family, 3, 48, 4, 0x5CA_11C ^ round);
            let total: usize = hist.iter().map(|h| h.len()).sum();
            assert!(
                linearizable(&hist),
                "{family}: scan history of {total} ops NOT linearizable (round {round}): {hist:#?}"
            );
        }
    }
}

/// The checker itself must reject broken histories (meta-test).
#[test]
fn checker_rejects_impossible_history() {
    // Thread A: insert(1) -> true, completing before thread B starts;
    // thread B: contains(1) -> false. No linearization exists.
    let a = vec![Event { kind: Kind::Insert, key: 1, result: true, inv: 0, resp: 1 }];
    let b = vec![Event { kind: Kind::Contains, key: 1, result: false, inv: 2, resp: 3 }];
    assert!(!linearizable(&[a, b]));

    // Overlapping version IS linearizable (contains may precede insert).
    let a = vec![Event { kind: Kind::Insert, key: 1, result: true, inv: 0, resp: 3 }];
    let b = vec![Event { kind: Kind::Contains, key: 1, result: false, inv: 1, resp: 2 }];
    assert!(linearizable(&[a, b]));

    // Double-successful insert of the same key with no remove: impossible.
    let a = vec![Event { kind: Kind::Insert, key: 2, result: true, inv: 0, resp: 1 }];
    let b = vec![Event { kind: Kind::Insert, key: 2, result: true, inv: 2, resp: 3 }];
    assert!(!linearizable(&[a, b]));
}

/// Larger memoization sanity: states dedup across interleavings.
#[test]
fn memoization_keeps_search_tractable() {
    use std::time::Instant;
    let hist = record(Family::Soft, 3, 100, 3, 0xFEED0);
    let t0 = Instant::now();
    assert!(linearizable(&hist));
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "checker blew up: {:?}",
        t0.elapsed()
    );
}
