//! Helpers shared by the crash/fault-injection integration-test binaries.

/// Silence the injected power-loss panics (keep real ones loud). Process-
/// wide and idempotent; every binary that arms `pmem::arm_flush_fault`
/// installs this hook before catching the unwind.
pub fn quiet_power_loss_panics() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let default_hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<&str>() != Some(&durasets::pmem::POWER_LOSS) {
                default_hook(info);
            }
        }));
    });
}
