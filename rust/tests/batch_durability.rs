//! Durability of batched (group-committed) operations.
//!
//! The batch contract (DESIGN.md §Batching): `apply_batch` returns — i.e.
//! acks — only after its trailing fence, so
//!
//!   * a crash *after* the batch returned must preserve every op in it;
//!   * a crash *mid-batch* means the batch was never acked; because
//!     flushes still happen per-op in submission order, the recovered
//!     state is a **prefix-closed** subset of the batch (if op i's effect
//!     survived, so did every earlier op's) — never a torn ack.

use durasets::pmem::{self, CrashPolicy, PoolId};
use durasets::sets::{self, ConcurrentSet, Family, OpResult, SetOp};
use std::panic::AssertUnwindSafe;

fn recover(family: Family, pool: PoolId) -> Box<dyn ConcurrentSet> {
    match family {
        Family::LinkFree => Box::new(sets::resizable::recover_linkfree(pool, 16).0),
        Family::Soft => Box::new(sets::resizable::recover_soft(pool, 16).0),
        Family::LogFree => Box::new(sets::resizable::recover_logfree(pool, 16).0),
        Family::NvTraverse => Box::new(sets::resizable::recover_nvtraverse(pool, 16).0),
        Family::Volatile => unreachable!("volatile sets have no recovery"),
    }
}

mod common;
use common::quiet_power_loss_panics;

#[test]
fn acked_batch_survives_crash_for_every_family() {
    let _sim = pmem::sim_session();
    pmem::set_psync_ns(0);
    for family in Family::DURABLE {
        let set = sets::new_hash(family, 16);
        let pool = set.durable_pool().unwrap();
        let inserts: Vec<SetOp> = (0..300u64).map(|k| SetOp::Insert(k, k * 5)).collect();
        let res = set.apply_batch(&inserts);
        assert!(res.iter().all(|r| *r == OpResult::Applied(true)), "{family}");
        // A second acked batch mixing kinds.
        let mixed: Vec<SetOp> = (0..50u64)
            .map(SetOp::Remove)
            .chain((300..320u64).map(|k| SetOp::Insert(k, 1)))
            .collect();
        let res2 = set.apply_batch(&mixed);
        assert!(res2.iter().all(|r| *r == OpResult::Applied(true)), "{family}");

        // Both batches returned => both are acked => crash must keep them.
        set.prepare_crash();
        drop(set);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[pool]);
        let rec = recover(family, pool);
        for k in 0..300u64 {
            let expect = if k < 50 { None } else { Some(k * 5) };
            assert_eq!(rec.get(k), expect, "{family}: key {k} after acked batches");
        }
        for k in 300..320u64 {
            assert_eq!(rec.get(k), Some(1), "{family}: key {k} from second batch");
        }
    }
}

#[test]
fn mid_batch_crash_recovers_prefix_closed_state() {
    let _sim = pmem::sim_session();
    quiet_power_loss_panics();
    pmem::set_psync_ns(0);
    for family in Family::DURABLE {
        let set = sets::new_hash(family, 16);
        let pool = set.durable_pool().unwrap();
        // Warm up allocator areas so the armed fault lands on op flushes,
        // not on area initialisation.
        for k in 10_000..10_064u64 {
            assert!(set.insert(k, 1), "{family} warmup {k}");
        }
        let keys: Vec<u64> = (0..64u64).collect();
        let ops: Vec<SetOp> = keys.iter().map(|&k| SetOp::Insert(k, k + 9)).collect();
        // Die on the ~30th flush: mid-batch, before the trailing fence.
        pmem::arm_flush_fault(30);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| set.apply_batch(&ops)));
        pmem::disarm_flush_fault();
        assert!(result.is_err(), "{family}: power loss must interrupt the batch");

        // The batch never returned => nothing in it was acked. Crash.
        set.prepare_crash();
        drop(set);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[pool]);
        let rec = recover(family, pool);

        // No torn ack: survivors form a prefix of submission order (the
        // op at the boundary may have gone either way on its own).
        let present: Vec<bool> = keys.iter().map(|&k| rec.contains(k)).collect();
        for w in present.windows(2) {
            assert!(w[0] || !w[1], "{family}: non-prefix survival pattern {present:?}");
        }
        let survived = present.iter().filter(|&&p| p).count();
        assert!(
            survived >= 5 && survived < 64,
            "{family}: fault must land mid-batch (survived {survived}/64)"
        );
        // Surviving ops carry their batch values; the warmup is intact.
        for (i, &k) in keys.iter().enumerate() {
            if present[i] {
                assert_eq!(rec.get(k), Some(k + 9), "{family}: torn value for {k}");
            }
        }
        for k in 10_000..10_064u64 {
            assert_eq!(rec.get(k), Some(1), "{family}: pre-batch ack lost ({k})");
        }
        // The recovered structure stays fully operational.
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(rec.insert(k, 7), !present[i], "{family}: post-recovery insert {k}");
        }
    }
}

/// End-to-end: a served pipelined burst is acked only once durable — stop
/// the server after the acks, crash, recover, and every acked PUT is
/// there. (The wire-level complement of the in-process tests above.)
#[test]
fn served_batch_acks_are_durable() {
    use durasets::config::Config;
    use durasets::coordinator::{server, DuraKv};
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::Arc;

    let _sim = pmem::sim_session();
    let mut cfg = Config::default();
    cfg.family = Family::LinkFree;
    cfg.shards = 2;
    cfg.key_range = 1 << 12;
    cfg.sim = true;
    cfg.psync_ns = 0;
    let kv = Arc::new(DuraKv::create(cfg));
    let srv = server::serve(kv.clone(), 0).unwrap();

    let stream = TcpStream::connect(srv.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    // One pipelined burst of 120 PUTs plus a MULTI frame.
    let mut burst = String::new();
    for k in 0..120u64 {
        burst.push_str(&format!("PUT {k} {}\n", k + 3));
    }
    burst.push_str("MULTI 2\nPUT 500 501\nDEL 0\nEXEC\n");
    writer.write_all(burst.as_bytes()).unwrap();
    writer.flush().unwrap();
    // 120 pipelined PUT replies + 2 MULTI-op replies (MULTI/EXEC lines
    // themselves produce none).
    let mut line = String::new();
    for i in 0..122 {
        line.clear();
        reader.read_line(&mut line).unwrap();
        let want = if i == 121 { "OK DELETED" } else { "OK NEW" };
        assert_eq!(line.trim_end(), want, "reply {i}");
    }

    // Close the connection (handler exits on BYE/EOF and releases its kv
    // Arc), stop the server, then wait for every Arc to come home.
    writer.write_all(b"QUIT\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "BYE");
    drop(reader);
    drop(writer);
    drop(srv);
    let kv = {
        let mut arc = kv;
        let mut tries = 0;
        loop {
            match Arc::try_unwrap(arc) {
                Ok(inner) => break inner,
                Err(still_shared) => {
                    arc = still_shared;
                    tries += 1;
                    assert!(tries < 1000, "connection handler never released the store");
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
            }
        }
    };
    let ticket = kv.crash(CrashPolicy::PESSIMISTIC);
    let (kv2, _report) = ticket.recover().unwrap();
    assert_eq!(kv2.get(0), None, "acked DEL survives");
    for k in 1..120u64 {
        assert_eq!(kv2.get(k), Some(k + 3), "acked PUT {k} survives");
    }
    assert_eq!(kv2.get(500), Some(501), "acked MULTI op survives");
}
