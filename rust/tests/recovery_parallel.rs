//! Differential recovery tests: for every durable shape (list, fixed
//! hash, skip list, resizable hash) of every family, sequential
//! (`threads = 1`) and parallel (`threads = 8`) recovery of identically
//! crashed images must produce the same member set, the same
//! `RecoveredStats`, and — pinned exactly — the same fence/flush counts:
//! the engine's worker pool classifies and relinks without a single
//! additional psync (all recovery psyncs are the final bulk persists on
//! the coordinating thread). Both crash policies are exercised: the
//! pessimistic one (only psync'd lines survive) and random eviction
//! (extra unflushed lines may survive — acked state must be identical
//! either way, since no completed op ever depends on eviction luck).

use durasets::coordinator::DuraKv;
use durasets::pmem::{self, stats, CrashPolicy, PoolId};
use durasets::sets::recovery::PhaseTimings;
use durasets::sets::{linkfree, logfree, resizable, soft, ConcurrentSet, RecoveredStats};
use durasets::util::rng::Xoshiro256;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Exact global fence/flush deltas must be attributable to one recovery
/// at a time: every test in this binary serialises on this lock (cargo
/// runs test binaries one after another, so only this file's threads
/// touch the counters meanwhile).
static LOCK: Mutex<()> = Mutex::new(());

const PAR_THREADS: usize = 8;
const KEYSPACE: u64 = 500;

/// Deterministic single-threaded churn; returns the exact model.
fn churn<S: ConcurrentSet + ?Sized>(s: &S, seed: u64) -> BTreeMap<u64, u64> {
    let mut rng = Xoshiro256::new(seed);
    let mut model = BTreeMap::new();
    for _ in 0..4000 {
        let k = rng.below(KEYSPACE);
        match rng.below(3) {
            0 | 1 => {
                let v = k.wrapping_mul(0x9E37) ^ 0xBEEF;
                assert_eq!(s.insert(k, v), model.insert(k, v).is_none(), "insert {k}");
            }
            _ => {
                assert_eq!(s.remove(k), model.remove(&k).is_some(), "remove {k}");
            }
        }
    }
    model
}

/// Build two identical structures, crash both, recover one sequentially
/// and one with the worker pool, and compare everything.
fn diff_case<S, T, FB, FR>(name: &str, policy: CrashPolicy, build: FB, recover: FR)
where
    S: ConcurrentSet,
    T: ConcurrentSet,
    FB: Fn() -> S,
    FR: Fn(PoolId, usize) -> (T, RecoveredStats, PhaseTimings),
{
    let _sim = pmem::sim_session();
    let a = build();
    let b = build();
    let model = churn(&a, 0xD1FF);
    let model_b = churn(&b, 0xD1FF);
    assert_eq!(model, model_b, "{name}: identical op streams diverged");
    let (ida, idb) = (a.durable_pool().unwrap(), b.durable_pool().unwrap());
    a.prepare_crash();
    b.prepare_crash();
    drop(a);
    drop(b);
    pmem::crash_pools(policy, &[ida, idb]);

    let f0 = stats::snapshot();
    let (ra, sa, _) = recover(ida, 1);
    let f1 = stats::snapshot();
    let (rb, sb, _) = recover(idb, PAR_THREADS);
    let f2 = stats::snapshot();

    assert_eq!(sa, sb, "{name}: sequential vs parallel RecoveredStats");
    assert_eq!(sa.members, model.len(), "{name}: member count vs model");
    let (seq, par) = (f1.since(&f0), f2.since(&f1));
    assert_eq!(seq.fences, par.fences, "{name}: parallel recovery must not add psyncs");
    assert_eq!(seq.flushes, par.flushes, "{name}: parallel recovery must not add flushes");

    for k in 0..KEYSPACE {
        let want = model.get(&k).copied();
        assert_eq!(ra.get(k), want, "{name}: sequential recovery, key {k}");
        assert_eq!(rb.get(k), want, "{name}: parallel recovery, key {k}");
    }
    // Both recovered structures stay fully operational.
    assert!(ra.insert(KEYSPACE + 1, 1), "{name}: seq insert after recovery");
    assert!(rb.insert(KEYSPACE + 1, 1), "{name}: par insert after recovery");
}

/// Both crash policies per shape; random eviction may persist *extra*
/// lines, never fewer, so all four recoveries agree on the acked state.
fn diff_both<S, T>(
    name: &str,
    build: impl Fn() -> S,
    recover: impl Fn(PoolId, usize) -> (T, RecoveredStats, PhaseTimings),
) where
    S: ConcurrentSet,
    T: ConcurrentSet,
{
    let _g = LOCK.lock().unwrap();
    diff_case(&format!("{name}/pessimistic"), CrashPolicy::PESSIMISTIC, &build, &recover);
    diff_case(&format!("{name}/evict"), CrashPolicy::random(0.4, 0x5EED), &build, &recover);
}

#[test]
fn lists_sequential_vs_parallel() {
    diff_both("linkfree-list", linkfree::LfList::new, linkfree::recover_list_timed);
    diff_both("soft-list", soft::SoftList::new, soft::recover_list_timed);
    diff_both("logfree-list", logfree::LogFreeList::new, logfree::recover_list_timed);
}

#[test]
fn fixed_hashes_sequential_vs_parallel() {
    diff_both(
        "linkfree-hash",
        || linkfree::LfHash::new(32),
        |id, t| linkfree::recover_hash_timed(id, 32, t),
    );
    diff_both(
        "soft-hash",
        || soft::SoftHash::new(16),
        |id, t| soft::recover_hash_timed(id, 16, t),
    );
    diff_both(
        "logfree-hash",
        || logfree::LogFreeHash::new(16),
        logfree::recover_hash_timed,
    );
}

#[test]
fn skiplists_sequential_vs_parallel() {
    diff_both(
        "linkfree-skiplist",
        linkfree::LfSkipList::new,
        linkfree::recover_skiplist_timed,
    );
    diff_both("soft-skiplist", soft::SoftSkipList::new, soft::recover_skiplist_timed);
}

#[test]
fn resizable_hashes_sequential_vs_parallel() {
    diff_both(
        "resizable-linkfree",
        || resizable::ResizableHash::new_linkfree(2),
        |id, t| resizable::recover_linkfree_timed(id, 2, t),
    );
    diff_both(
        "resizable-soft",
        || resizable::ResizableHash::new_soft(2),
        |id, t| resizable::recover_soft_timed(id, 2, t),
    );
    diff_both(
        "resizable-logfree",
        || resizable::ResizableHash::new_logfree(2),
        |id, t| resizable::recover_logfree_timed(id, 2, t),
    );
}

/// The small-keyspace cases above fit one allocator area, where the
/// engine short-circuits to the sequential path by design — so this case
/// makes the parallel machinery *actually* engage: >2 areas (multi-worker
/// scan over the area cursor) and >4096 members (segmented chain relink
/// with boundary stitching), then pins the same stats / contents / exact
/// psync-count equalities.
#[test]
fn large_pool_parallel_engine_engages() {
    let _g = LOCK.lock().unwrap();
    let _sim = pmem::sim_session();
    const N: u64 = 10_000;
    let mk = || {
        let h = resizable::ResizableHash::new_linkfree(2);
        for k in 0..N {
            assert!(h.insert(k, k ^ 0xABCD));
        }
        for k in 0..1000u64 {
            assert!(h.remove(k * 7));
        }
        h
    };
    let (a, b) = (mk(), mk());
    let (ida, idb) = (a.pool_id(), b.pool_id());
    a.crash_preserve();
    b.crash_preserve();
    drop(a);
    drop(b);
    pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[ida, idb]);

    let f0 = stats::snapshot();
    let (ra, sa, _) = resizable::recover_linkfree_timed(ida, 2, 1);
    let f1 = stats::snapshot();
    let (rb, sb, _) = resizable::recover_linkfree_timed(idb, 2, 8);
    let f2 = stats::snapshot();

    assert_eq!(sa.members, (N - 1000) as usize, "9000 members survive");
    assert!(sa.members > 4096, "must cross the parallel-relink threshold");
    assert_eq!(sa, sb, "large pool: sequential vs parallel stats");
    let (seq, par) = (f1.since(&f0), f2.since(&f1));
    assert_eq!(seq.fences, par.fences, "large pool: parallel recovery added psyncs");
    assert_eq!(seq.flushes, par.flushes, "large pool: parallel recovery added flushes");
    for k in 0..N {
        // Removed keys were exactly 7*i for i in 0..1000.
        let removed = k % 7 == 0 && k / 7 < 1000;
        let want = if removed { None } else { Some(k ^ 0xABCD) };
        assert_eq!(ra.get(k), want, "seq key {k}");
        assert_eq!(rb.get(k), want, "par key {k}");
    }
}

/// The member-run sort is now a parallel merge sort past its engagement
/// threshold (PAR_SORT_MIN = 4096, same scale as the relink threshold).
/// Differential pin: a >4096-member SOFT image — SOFT exercises the
/// sort's handle side hardest, every member handle is a freshly
/// materialised volatile SNode — recovered sequentially vs with 8
/// workers must agree on members, stats, contents, order (every key
/// readable ⇒ the relinked chain is correctly sorted) and, exactly, on
/// fence/flush counts: sorting is pure volatile compute and owes zero
/// psyncs no matter how many threads it fans out to.
#[test]
fn parallel_member_sort_engages_and_adds_no_psyncs() {
    let _g = LOCK.lock().unwrap();
    let _sim = pmem::sim_session();
    const N: u64 = 9_000;
    let mk = || {
        let h = resizable::ResizableHash::new_soft(2);
        for k in 0..N {
            assert!(h.insert(k, k.wrapping_mul(31) + 1));
        }
        for k in 0..800u64 {
            assert!(h.remove(k * 11));
        }
        h
    };
    let (a, b) = (mk(), mk());
    let (ida, idb) = (a.pool_id(), b.pool_id());
    a.crash_preserve();
    b.crash_preserve();
    drop(a);
    drop(b);
    pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[ida, idb]);

    let f0 = stats::snapshot();
    let (ra, sa, ta) = resizable::recover_soft_timed(ida, 2, 1);
    let f1 = stats::snapshot();
    let (rb, sb, tb) = resizable::recover_soft_timed(idb, 2, 8);
    let f2 = stats::snapshot();

    assert_eq!(sa.members, (N - 800) as usize);
    assert!(sa.members > 4096, "must cross the parallel-sort threshold");
    assert_eq!(sa, sb, "parallel sort changed what recovery found");
    let (seq, par) = (f1.since(&f0), f2.since(&f1));
    assert_eq!(seq.fences, par.fences, "parallel sort added psyncs");
    assert_eq!(seq.flushes, par.flushes, "parallel sort added flushes");
    assert!(ta.sort > std::time::Duration::ZERO, "sort phase must be timed");
    assert!(tb.sort > std::time::Duration::ZERO);
    for k in 0..N {
        let removed = k % 11 == 0 && k / 11 < 800;
        let want = if removed { None } else { Some(k.wrapping_mul(31) + 1) };
        assert_eq!(ra.get(k), want, "seq key {k}");
        assert_eq!(rb.get(k), want, "par key {k}");
    }
}

/// The skip-list tower rebuild is now parallel past its engagement
/// threshold (PAR_INDEX_MIN = 4096). Differential pin for both skip
/// families: a >4096-member image recovered sequentially vs with 8
/// workers must agree on members, stats, contents (every key readable
/// through the rebuilt towers) and, exactly, on fence/flush counts —
/// towers are pure volatile compute (CAS-built, key-deterministic
/// heights), so the rebuild owes zero psyncs at any thread count.
#[test]
fn parallel_skiplist_index_rebuild_engages_and_adds_no_psyncs() {
    fn case<S: ConcurrentSet>(
        name: &str,
        mk: impl Fn() -> S,
        recover: impl Fn(PoolId, usize) -> (S, RecoveredStats, PhaseTimings),
    ) {
        let _sim = pmem::sim_session();
        const N: u64 = 9_000;
        let build = || {
            let s = mk();
            for k in 0..N {
                assert!(s.insert(k, k.wrapping_mul(13) ^ 0x51C));
            }
            for k in 0..700u64 {
                assert!(s.remove(k * 9));
            }
            s
        };
        let (a, b) = (build(), build());
        let (ida, idb) = (a.durable_pool().unwrap(), b.durable_pool().unwrap());
        a.prepare_crash();
        b.prepare_crash();
        drop(a);
        drop(b);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[ida, idb]);

        let f0 = stats::snapshot();
        let (ra, sa, _) = recover(ida, 1);
        let f1 = stats::snapshot();
        let (rb, sb, _) = recover(idb, PAR_THREADS);
        let f2 = stats::snapshot();

        assert_eq!(sa.members, (N - 700) as usize, "{name}: members");
        assert!(sa.members > 4096, "{name}: must cross the parallel-rebuild threshold");
        assert_eq!(sa, sb, "{name}: sequential vs parallel stats");
        let (seq, par) = (f1.since(&f0), f2.since(&f1));
        assert_eq!(seq.fences, par.fences, "{name}: parallel tower rebuild added psyncs");
        assert_eq!(seq.flushes, par.flushes, "{name}: parallel tower rebuild added flushes");
        for k in 0..N {
            let removed = k % 9 == 0 && k / 9 < 700;
            let want = if removed { None } else { Some(k.wrapping_mul(13) ^ 0x51C) };
            assert_eq!(ra.get(k), want, "{name}: seq key {k}");
            assert_eq!(rb.get(k), want, "{name}: par key {k}");
        }
        // The rebuilt towers must keep the lists fully operational.
        assert!(ra.insert(N + 1, 1), "{name}: seq insert after rebuild");
        assert!(rb.insert(N + 1, 1), "{name}: par insert after rebuild");
    }
    let _g = LOCK.lock().unwrap();
    case("linkfree-skiplist", linkfree::LfSkipList::new, linkfree::recover_skiplist_timed);
    case("soft-skiplist", soft::SoftSkipList::new, soft::recover_skiplist_timed);
}

/// The resizable differential must also preserve the bucket-count epoch
/// identically on both paths (growth happened pre-crash).
#[test]
fn resizable_epoch_identical_on_both_paths() {
    let _g = LOCK.lock().unwrap();
    let _sim = pmem::sim_session();
    let mk = || {
        let h = resizable::ResizableHash::new_soft(2);
        for k in 0..300u64 {
            assert!(h.insert(k, k));
        }
        h
    };
    let (a, b) = (mk(), mk());
    assert!(a.nbuckets() >= 8, "test must exercise growth");
    let (ida, idb) = (a.pool_id(), b.pool_id());
    let grown = a.nbuckets();
    a.crash_preserve();
    b.crash_preserve();
    drop(a);
    drop(b);
    pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[ida, idb]);
    let (ra, _, _) = resizable::recover_soft_timed(ida, 2, 1);
    let (rb, _, _) = resizable::recover_soft_timed(idb, 2, PAR_THREADS);
    assert_eq!(ra.nbuckets(), grown);
    assert_eq!(rb.nbuckets(), grown);
}

/// Satellite: the measured RTO reaches operators — a recovered store's
/// wire `STATS` line carries the recovery report (wall, phase breakdown,
/// threads) instead of dropping it with the recover() return value.
#[test]
fn stats_wire_line_carries_recovery_report() {
    use std::io::{BufRead, BufReader, Write};
    let _g = LOCK.lock().unwrap();
    let _sim = pmem::sim_session();
    let mut cfg = durasets::config::Config::default();
    cfg.shards = 2;
    cfg.key_range = 4096;
    cfg.sim = true;
    cfg.psync_ns = 0;
    let kv = DuraKv::create(cfg);
    for k in 0..200u64 {
        assert!(kv.put(k, k));
    }
    let (kv2, report) = kv.crash(CrashPolicy::PESSIMISTIC).recover().unwrap();
    assert_eq!(report.members, 200);

    let server = durasets::coordinator::server::serve(std::sync::Arc::new(kv2), 0).unwrap();
    let stream = std::net::TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "STATS").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("STATS ops="), "{line}");
    assert!(line.contains("recovery=["), "STATS must carry the recovery report: {line}");
    assert!(line.contains("members=200"), "{line}");
    assert!(line.contains("wall="), "{line}");
    drop(server);
}
