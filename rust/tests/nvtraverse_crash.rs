//! NVTraverse crash coverage: a power loss at **every** flush boundary of
//! insert/remove, and mid-way through a K=64 coalesced batch.
//!
//! The family's whole bet is that traversals never flush and updates
//! flush only the destination window — so the adversarial instants are
//! exactly the update-path flushes. The singles sweep arms the flush
//! fault at 1, 2, 3, … until a round survives the full deterministic
//! sequence, crashing pessimistically (only flushed lines survive) and
//! recovering each time. Recovery must reproduce the acked member set
//! *exactly*: every op acked before the fault is reflected, the single
//! in-flight op may have gone either way, untouched keys stay absent.
//!
//! The batch half mirrors DESIGN.md §Batching for the coalesced path:
//! a fault mid-`apply_batch` means nothing in the batch was acked, and
//! the survivors must form a prefix of submission order (per-op flushes
//! are issued in order; only the trailing fence is deferred); an *acked*
//! batch must survive wholesale.

use durasets::pmem::{self, CrashPolicy};
use durasets::sets::{self, ConcurrentSet, Family, OpResult, SetOp};
use std::collections::HashMap;
use std::panic::AssertUnwindSafe;

mod common;
use common::quiet_power_loss_panics;

/// Deterministic single-op script: (key, is_insert, value). Inserts over
/// a small range, a wave of removes, reinserts with new values, then a
/// second remove wave that also hits some already-absent keys (acked
/// failures must not perturb the durable image).
fn op_script() -> Vec<(u64, bool, u64)> {
    let mut ops = Vec::new();
    for k in 0..24u64 {
        ops.push((k, true, k * 3 + 1));
    }
    for k in (0..24u64).step_by(3) {
        ops.push((k, false, 0));
    }
    for k in (0..24u64).step_by(6) {
        ops.push((k, true, k * 7 + 2));
    }
    for k in (1..24u64).step_by(4) {
        ops.push((k, false, 0));
    }
    ops
}

/// Exact expected state after the first `n` script ops (set semantics:
/// insert on a present key is a no-op failure, like the real sets).
fn model_after(ops: &[(u64, bool, u64)], n: usize) -> HashMap<u64, u64> {
    let mut m = HashMap::new();
    for &(k, ins, v) in &ops[..n] {
        if ins {
            m.entry(k).or_insert(v);
        } else {
            m.remove(&k);
        }
    }
    m
}

/// The singles sweep: crash at every flush the script issues.
#[test]
fn nvtraverse_crash_at_every_flush_of_insert_remove_keeps_acked_set() {
    let _sim = pmem::sim_session();
    quiet_power_loss_panics();
    pmem::set_psync_ns(0);
    let ops = op_script();

    let mut crashes = 0u32;
    let mut fault = 1u64;
    loop {
        let set = sets::new_hash(Family::NvTraverse, 2);
        let pool = set.durable_pool().unwrap();
        // Warm up allocator areas on a disjoint range so the armed fault
        // lands on the script's own insert/remove flushes.
        for k in 5_000..5_008u64 {
            assert!(set.insert(k, 1), "warmup {k}");
        }

        // `progress` counts fully acked ops; the op at index `progress`
        // (if any) is the one the power loss caught in flight.
        let progress = std::cell::Cell::new(0usize);
        pmem::arm_flush_fault(fault);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            for (i, &(k, ins, v)) in ops.iter().enumerate() {
                if ins {
                    set.insert(k, v);
                } else {
                    set.remove(k);
                }
                progress.set(i + 1);
            }
        }));
        pmem::disarm_flush_fault();
        let crashed = result.is_err();
        let progress = progress.get();
        if crashed {
            crashes += 1;
            assert!(progress < ops.len(), "fault {fault}: panic after the last ack");
        } else {
            assert_eq!(progress, ops.len(), "fault {fault}: clean round must ack everything");
        }

        set.prepare_crash();
        drop(set);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[pool]);
        let rec = sets::resizable::recover_nvtraverse(pool, 2).0;

        // Exact acked set: every script key checked both ways against the
        // model; the in-flight op's key may reflect either side of it.
        let pre = model_after(&ops, progress);
        let post = model_after(&ops, (progress + 1).min(ops.len()));
        for k in 0..24u64 {
            if crashed && ops[progress].0 == k {
                let got = rec.get(k);
                assert!(
                    got == pre.get(&k).copied() || got == post.get(&k).copied(),
                    "fault {fault}: in-flight key {k} has impossible state {got:?}"
                );
            } else {
                assert_eq!(
                    rec.get(k),
                    pre.get(&k).copied(),
                    "fault {fault}: acked state of key {k} (progress {progress})"
                );
            }
        }
        for k in 5_000..5_008u64 {
            assert_eq!(rec.get(k), Some(1), "fault {fault}: warmup key {k} lost");
        }
        for k in 1_000..1_010u64 {
            assert!(!rec.contains(k), "fault {fault}: phantom key {k}");
        }

        if !crashed {
            break; // fault count outran the script: full coverage reached
        }
        fault += 1;
    }
    // Each successful single is ~1 flush, so the sweep must have crashed
    // at least once per successful script op before running clean.
    assert!(crashes >= 30, "sweep too weak: only {crashes} crashing rounds");
}

/// Mid-K=64-batch power loss: the batch was never acked, so recovery owes
/// only the warmup — batch survivors must be a prefix in submission order
/// with the right values. A second, *acked* K=64 batch must then survive
/// a crash wholesale.
#[test]
fn nvtraverse_mid_k64_batch_crash_recovers_acked_set_exactly() {
    let _sim = pmem::sim_session();
    quiet_power_loss_panics();
    pmem::set_psync_ns(0);

    let set = sets::new_hash(Family::NvTraverse, 16);
    let pool = set.durable_pool().unwrap();
    for k in 10_000..10_064u64 {
        assert!(set.insert(k, 1), "warmup {k}");
    }
    let keys: Vec<u64> = (0..64u64).collect();
    let ops: Vec<SetOp> = keys.iter().map(|&k| SetOp::Insert(k, k + 9)).collect();
    // Die on the ~30th flush after arming: mid-batch, before the
    // trailing fence that would have acked it.
    pmem::arm_flush_fault(30);
    let result = std::panic::catch_unwind(AssertUnwindSafe(|| set.apply_batch(&ops)));
    pmem::disarm_flush_fault();
    assert!(result.is_err(), "power loss must interrupt the coalesced batch");

    set.prepare_crash();
    drop(set);
    pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[pool]);
    let rec = sets::resizable::recover_nvtraverse(pool, 16).0;

    // Never a torn ack: survivors form a prefix of submission order.
    let present: Vec<bool> = keys.iter().map(|&k| rec.contains(k)).collect();
    for w in present.windows(2) {
        assert!(w[0] || !w[1], "non-prefix survival pattern {present:?}");
    }
    let survived = present.iter().filter(|&&p| p).count();
    assert!(
        survived >= 5 && survived < 64,
        "fault must land mid-batch (survived {survived}/64)"
    );
    for (i, &k) in keys.iter().enumerate() {
        if present[i] {
            assert_eq!(rec.get(k), Some(k + 9), "torn value for batch key {k}");
        }
    }
    // The acked member set — the warmup — is reproduced exactly.
    for k in 10_000..10_064u64 {
        assert_eq!(rec.get(k), Some(1), "acked warmup key {k} lost");
    }

    // Round 2 on the recovered structure: an acked K=64 batch (fill in
    // the missing prefix keys, overwrite nothing) followed by a crash
    // keeps all 64 — ack means durable, coalesced fences notwithstanding.
    let refill: Vec<SetOp> = keys
        .iter()
        .filter(|&&k| !present[k as usize])
        .map(|&k| SetOp::Insert(k, k + 9))
        .collect();
    let res = rec.apply_batch(&refill);
    assert!(res.iter().all(|r| *r == OpResult::Applied(true)), "refill batch");
    rec.prepare_crash();
    drop(rec);
    pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[pool]);
    let rec2 = sets::resizable::recover_nvtraverse(pool, 16).0;
    for &k in &keys {
        assert_eq!(rec2.get(k), Some(k + 9), "acked batch key {k} after crash");
    }
    for k in 10_000..10_064u64 {
        assert_eq!(rec2.get(k), Some(1), "warmup key {k} after second crash");
    }
}
