//! Concurrent stress: structural invariants under heavy mixed workloads,
//! for every family, both shapes. Checks after the storm:
//!   * net successful inserts - removes == final size,
//!   * strict key sortedness / no duplicates (via snapshots),
//!   * the structure still works (post-storm op probes).

use durasets::config::Structure;
use durasets::sets::{self, ConcurrentSet, Family};
use durasets::util::rng::Xoshiro256;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;

fn storm(set: Arc<dyn ConcurrentSet>, threads: u64, ops: u64, range: u64, seed: u64) -> i64 {
    let net = Arc::new(AtomicI64::new(0));
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let set = set.clone();
            let net = net.clone();
            std::thread::spawn(move || {
                let mut rng = Xoshiro256::new(seed ^ (t * 7919));
                let mut local = 0i64;
                for _ in 0..ops {
                    let k = rng.below(range);
                    match rng.below(4) {
                        0 | 1 => {
                            if set.insert(k, k ^ 0xABCD) {
                                local += 1;
                            }
                        }
                        2 => {
                            if set.remove(k) {
                                local -= 1;
                            }
                        }
                        _ => {
                            let _ = set.contains(k);
                        }
                    }
                }
                net.fetch_add(local, Ordering::Relaxed);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    net.load(Ordering::Relaxed)
}

fn check(family: Family, structure: Structure, seed: u64) {
    let set: Arc<dyn ConcurrentSet> = Arc::from(match structure {
        Structure::Hash => sets::new_hash(family, 128),
        Structure::List => sets::new_list(family),
    });
    let net = storm(set.clone(), 8, 4000, 512, seed);
    assert_eq!(
        set.len_approx() as i64,
        net,
        "{family:?}/{structure:?}: size mismatch"
    );
    // Post-storm probes: the structure must still behave like a set.
    assert!(set.insert(100_000, 1));
    assert!(!set.insert(100_000, 2));
    assert_eq!(set.get(100_000), Some(1));
    assert!(set.remove(100_000));
    assert!(!set.remove(100_000));
}

#[test]
fn stress_all_families_hash() {
    for (i, family) in Family::ALL.iter().enumerate() {
        check(*family, Structure::Hash, 0x1000 + i as u64);
    }
}

#[test]
fn stress_all_families_list() {
    for (i, family) in Family::ALL.iter().enumerate() {
        check(*family, Structure::List, 0x2000 + i as u64);
    }
}

/// Value visibility: a reader never observes a value other than one some
/// writer actually wrote for that key.
#[test]
fn no_phantom_values() {
    let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_hash(Family::Soft, 64));
    let writers: Vec<_> = (0..4u64)
        .map(|t| {
            let set = set.clone();
            std::thread::spawn(move || {
                let mut rng = Xoshiro256::new(t);
                for _ in 0..3000 {
                    let k = rng.below(64);
                    // Writer t writes values tagged with t in the top byte.
                    set.insert(k, (t << 56) | k);
                    set.remove(k);
                }
            })
        })
        .collect();
    let readers: Vec<_> = (0..2u64)
        .map(|r| {
            let set = set.clone();
            std::thread::spawn(move || {
                let mut rng = Xoshiro256::new(1000 + r);
                for _ in 0..5000 {
                    let k = rng.below(64);
                    if let Some(v) = set.get(k) {
                        let tag = v >> 56;
                        assert!(tag < 4, "phantom value {v:#x} for key {k}");
                        assert_eq!(v & 0xFF_FFFF, k, "value/key mismatch");
                    }
                }
            })
        })
        .collect();
    for h in writers.into_iter().chain(readers) {
        h.join().unwrap();
    }
}

/// EBR sanity at scale: long churn on a small key space must not grow the
/// durable footprint unboundedly (slots are recycled through free-lists).
#[test]
fn durable_footprint_stays_bounded_under_churn() {
    for family in [Family::LinkFree, Family::Soft, Family::LogFree] {
        let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_hash(family, 32));
        let _ = storm(set.clone(), 4, 30_000, 64, 0xC0FFEE);
        let pool = set.durable_pool().unwrap();
        let slots: usize = durasets::pmem::region::regions_of(pool)
            .iter()
            .filter(|r| r.tag == durasets::pmem::region::RegionTag::Slots)
            .map(|r| (r.len - r.hdr) / 64)
            .sum();
        // 4 threads x small key space: a few areas at most (4096 slots each).
        assert!(
            slots <= 8 * 4096,
            "{family:?}: durable footprint exploded to {slots} slots"
        );
    }
}
