//! Durable linearizability under concurrency and mid-operation power loss.
//!
//! Protocol: worker threads own disjoint key stripes and log every *acked*
//! op. A flush-fault is armed so one thread dies by simulated power loss
//! in the middle of an update (at a psync boundary — the adversarial
//! instant); everyone else stops at an op boundary. Then the machine
//! "crashes" (only flushed lines survive, plus random evictions), recovery
//! runs, and we check, per stripe:
//!
//!   * every key whose last acked op was a successful insert is present
//!     with the right value;
//!   * every key whose last acked op was a successful remove is absent;
//!   * the single in-flight op (the power-loss victim's) may have gone
//!     either way — both outcomes are checked for consistency.
//!
//! This is Definition A.2 instantiated: acked ops happened-before the
//! crash and must be reflected; the pending op may be linearized or not.

use durasets::pmem::{self, CrashPolicy, POWER_LOSS};
use durasets::sets::{self, ConcurrentSet, Family};
use durasets::util::rng::Xoshiro256;
use std::collections::HashMap;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};

/// Last acked state of a key: Some(value) = inserted, None = removed.
type StripeLog = HashMap<u64, Option<u64>>;

struct Outcome {
    log: StripeLog,
    /// The op that was in flight when the power died, if this thread was
    /// the victim: (key, was_insert, value).
    in_flight: Option<(u64, bool, u64)>,
}

fn worker(
    set: &dyn ConcurrentSet,
    stripe: u64,
    nstripes: u64,
    range: u64,
    seed: u64,
    stop: &AtomicBool,
) -> Outcome {
    let mut rng = Xoshiro256::new(seed ^ stripe);
    let mut log: StripeLog = HashMap::new();
    while !stop.load(Ordering::Relaxed) {
        let k = rng.below(range / nstripes) * nstripes + stripe; // stripe-owned key
        let ins = rng.below(2) == 0;
        let v = rng.next_u64() >> 1;
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            if ins {
                set.insert(k, v)
            } else {
                set.remove(k)
            }
        }));
        match result {
            Ok(success) => {
                if success {
                    log.insert(k, if ins { Some(v) } else { None });
                }
            }
            Err(payload) => {
                // Power loss mid-op: record the pending op and die.
                assert_eq!(
                    payload.downcast_ref::<&str>().copied(),
                    Some(POWER_LOSS),
                    "unexpected panic in lock-free op"
                );
                return Outcome { log, in_flight: Some((k, ins, v)) };
            }
        }
    }
    Outcome { log, in_flight: None }
}

mod common;
use common::quiet_power_loss_panics;

fn run_torture(family: Family, evict_prob: f64, seed: u64) {
    let _sim = pmem::sim_session();
    quiet_power_loss_panics();
    pmem::set_psync_ns(0);
    let range = 4096u64;
    let nthreads = 4u64;

    let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_hash(family, 256));
    let pool = set.durable_pool().unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let barrier = Arc::new(Barrier::new(nthreads as usize + 1));
    let handles: Vec<_> = (0..nthreads)
        .map(|t| {
            let set = set.clone();
            let stop = stop.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                worker(set.as_ref(), t, nthreads, range, seed, &stop)
            })
        })
        .collect();
    barrier.wait();
    // Let them run, then kill one thread mid-psync and stop the rest.
    std::thread::sleep(std::time::Duration::from_millis(30));
    pmem::arm_flush_fault(50);
    std::thread::sleep(std::time::Duration::from_millis(30));
    stop.store(true, Ordering::Relaxed);
    let outcomes: Vec<Outcome> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    pmem::disarm_flush_fault();

    let victims = outcomes.iter().filter(|o| o.in_flight.is_some()).count();
    assert!(victims <= 1, "at most one thread dies per armed fault");

    // Crash + recover (scoped: other tests' pools stay untouched). The
    // hash shards are resizable, so recovery goes through the resizable
    // entry points (family list + bucket-count epoch).
    set.prepare_crash();
    drop(set);
    pmem::crash_pools(CrashPolicy::random(evict_prob, seed), &[pool]);
    let recovered: Box<dyn ConcurrentSet> = match family {
        Family::LinkFree => Box::new(sets::resizable::recover_linkfree(pool, 256).0),
        Family::Soft => Box::new(sets::resizable::recover_soft(pool, 256).0),
        Family::LogFree => Box::new(sets::resizable::recover_logfree(pool, 256).0),
        Family::NvTraverse => Box::new(sets::resizable::recover_nvtraverse(pool, 256).0),
        Family::Volatile => unreachable!(),
    };

    // Check every stripe's acked history.
    let mut checked = 0;
    for o in &outcomes {
        for (&k, &state) in &o.log {
            if let Some((fk, _, _)) = o.in_flight {
                if fk == k {
                    continue; // pending op on this key: either way is legal
                }
            }
            match state {
                Some(v) => {
                    assert_eq!(
                        recovered.get(k),
                        Some(v),
                        "{family}: acked insert of {k} lost (evict={evict_prob})"
                    );
                }
                None => {
                    assert!(
                        !recovered.contains(k),
                        "{family}: acked remove of {k} resurrected"
                    );
                }
            }
            checked += 1;
        }
        // Pending op: membership may be either, but if present the value
        // must be the pending insert's value or the last acked value.
        if let Some((k, ins, v)) = o.in_flight {
            if let Some(got) = recovered.get(k) {
                let last_acked = o.log.get(&k).copied().flatten();
                let legal = (ins && got == v) || last_acked == Some(got);
                assert!(legal, "{family}: key {k} has impossible value {got}");
            }
        }
    }
    assert!(checked > 100, "{family}: torture too weak ({checked} checks)");
}

#[test]
fn linkfree_torture_pessimistic() {
    run_torture(Family::LinkFree, 0.0, 0x71);
}

#[test]
fn linkfree_torture_random_eviction() {
    run_torture(Family::LinkFree, 0.5, 0x72);
}

#[test]
fn soft_torture_pessimistic() {
    run_torture(Family::Soft, 0.0, 0x73);
}

#[test]
fn soft_torture_random_eviction() {
    run_torture(Family::Soft, 0.5, 0x74);
}

#[test]
fn logfree_torture_pessimistic() {
    run_torture(Family::LogFree, 0.0, 0x75);
}

#[test]
fn logfree_torture_random_eviction() {
    run_torture(Family::LogFree, 0.5, 0x76);
}

#[test]
fn nvtraverse_torture_pessimistic() {
    run_torture(Family::NvTraverse, 0.0, 0x77);
}

#[test]
fn nvtraverse_torture_random_eviction() {
    run_torture(Family::NvTraverse, 0.5, 0x78);
}

/// The §3.3 validity-race scenario: two threads race inserts of the same
/// key; under random eviction the loser's node may hit NVRAM without an
/// explicit flush. Recovery must never see two members with one key.
#[test]
fn section_3_3_two_insert_race_no_duplicates() {
    let _sim = pmem::sim_session();
    pmem::set_psync_ns(0);
    for round in 0..20u64 {
        let set = sets::linkfree::LfHash::new(8);
        let pool = set.pool_id();
        let set = Arc::new(set);
        let barrier = Arc::new(Barrier::new(2));
        let handles: Vec<_> = (0..2u64)
            .map(|t| {
                let set = set.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    for k in 0..64u64 {
                        set.insert(k, t * 1000 + k);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        set.crash_preserve();
        drop(set);
        pmem::crash_pools(CrashPolicy::random(1.0, round), &[pool]); // everything persists
        let (recovered, stats) = sets::linkfree::recover_hash(pool, 8);
        assert_eq!(stats.members, 64, "round {round}");
        for k in 0..64u64 {
            assert!(recovered.contains(k));
        }
    }
}

/// Crash while the resizable table is mid-migration. Migration is lazy
/// hint population, so "mid-migration" is any instant after a doubling
/// published: hints are part-filled, the epoch cell records the new size,
/// and none of that is load-bearing for durability — the family list plus
/// the epoch must reproduce the exact set and table size.
#[test]
fn resizable_crash_during_migration_recovers_exactly() {
    let _sim = pmem::sim_session();
    pmem::set_psync_ns(0);
    for (name, mk, recover) in [
        (
            "link-free",
            (|| sets::new_hash(Family::LinkFree, 2)) as fn() -> Box<dyn ConcurrentSet>,
            (|p, n| {
                Box::new(sets::resizable::recover_linkfree(p, n).0) as Box<dyn ConcurrentSet>
            }) as fn(durasets::pmem::PoolId, usize) -> Box<dyn ConcurrentSet>,
        ),
        (
            "soft",
            || sets::new_hash(Family::Soft, 2),
            |p, n| Box::new(sets::resizable::recover_soft(p, n).0) as Box<dyn ConcurrentSet>,
        ),
        (
            "log-free",
            || sets::new_hash(Family::LogFree, 2),
            |p, n| Box::new(sets::resizable::recover_logfree(p, n).0) as Box<dyn ConcurrentSet>,
        ),
        (
            "nvtraverse",
            || sets::new_hash(Family::NvTraverse, 2),
            |p, n| {
                Box::new(sets::resizable::recover_nvtraverse(p, n).0) as Box<dyn ConcurrentSet>
            },
        ),
    ] {
        let set = mk();
        let pool = set.durable_pool().unwrap();
        // Drive straight through several doublings from 4 concurrent
        // threads, then crash with no quiesce point: whatever hint
        // population was in flight is lost with the volatile heap.
        let set: Arc<dyn ConcurrentSet> = Arc::from(set);
        let handles: Vec<_> = (0..4u64)
            .map(|t| {
                let set = set.clone();
                std::thread::spawn(move || {
                    for i in 0..400u64 {
                        let k = i * 4 + t; // disjoint stripes: exact model
                        assert!(set.insert(k, k * 7), "{t}/{i}");
                        if i % 3 == 0 {
                            assert!(set.remove(k));
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        set.prepare_crash();
        drop(set);
        pmem::crash_pools(CrashPolicy::random(0.3, 0xB00), &[pool]);
        let recovered = recover(pool, 2);
        for k in 0..1600u64 {
            let expect = (k / 4) % 3 != 0;
            assert_eq!(recovered.contains(k), expect, "{name} key {k}");
        }
        // Still fully operational, including further growth.
        for k in 10_000..10_200u64 {
            assert!(recovered.insert(k, k), "{name} post-recovery insert {k}");
        }
    }
}
