//! All-or-nothing recovery of atomic cross-shard batches (DESIGN.md
//! §Transactions).
//!
//! The protocol's durable footprint is a fixed psync sequence: publish
//! the op list (bulk psync), flip the commit record's state word
//! (psync), apply per-shard sub-batches (per-op flushes + trailing
//! fences), retire the record (psync). The sweep below arms the
//! simulated power loss at flush 1, 2, 3, … of that sequence until a run
//! completes unfaulted — every boundary, and therefore every
//! prepare/commit interleaving a crash can produce, is hit for all three
//! families. (The wire path adds worker parking around the identical
//! record psyncs, so the record-state coverage is the same; its
//! acked-durability is checked end-to-end below.)
//!
//! Expected recovery outcome at every fault point:
//! * record not committed at the crash → **nothing** of the batch
//!   (rollback = discard);
//! * record committed → **everything** (roll-forward re-applies the op
//!   list);
//! and never anything in between — that's the claim `MULTI <n> ATOMIC`
//! acks are durable under.

use durasets::config::Config;
use durasets::coordinator::DuraKv;
use durasets::pmem::{self, CrashPolicy};
use durasets::sets::{Family, OpResult, SetOp};
use std::panic::AssertUnwindSafe;

mod common;
use common::quiet_power_loss_panics;

fn crash_cfg(family: Family) -> Config {
    let mut cfg = Config::default();
    cfg.family = family;
    cfg.shards = 3;
    cfg.key_range = 1 << 12;
    cfg.sim = true;
    cfg.psync_ns = 0;
    cfg
}

/// Keys of round `r`: 20 inserts + 10 removes, spread across shards.
fn round_ops(r: u64) -> (Vec<u64>, Vec<u64>, Vec<SetOp>) {
    let inserts: Vec<u64> = (0..20u64).map(|i| 10_000 + r * 100 + i).collect();
    let victims: Vec<u64> = (0..10u64).map(|i| 500 + i).collect();
    let ops: Vec<SetOp> = inserts
        .iter()
        .map(|&k| SetOp::Insert(k, k * 2))
        .chain(victims.iter().map(|&k| SetOp::Remove(k)))
        .collect();
    (inserts, victims, ops)
}

#[test]
fn crash_at_every_flush_of_an_atomic_batch_recovers_all_or_nothing() {
    let _sim = pmem::sim_session();
    quiet_power_loss_panics();
    pmem::set_psync_ns(0);
    for family in Family::DURABLE {
        let mut kv = DuraKv::create(crash_cfg(family));
        // Stable pre-state the batch never touches.
        for k in 0..50u64 {
            assert!(kv.put(k, k + 1), "{family}: pre-state {k}");
        }
        let (mut saw_none, mut saw_all, mut rolled_total) = (false, false, 0usize);
        let mut fault = 1u64;
        let mut round = 0u64;
        loop {
            let (inserts, victims, ops) = round_ops(round);
            // (Re-)install the victims; acked before the fault arms.
            for &k in &victims {
                kv.put(k, k + 7);
            }
            pmem::arm_flush_fault(fault);
            let outcome =
                std::panic::catch_unwind(AssertUnwindSafe(|| kv.apply_batch_atomic(&ops)));
            pmem::disarm_flush_fault();
            let completed = outcome.is_ok();
            if let Ok(results) = &outcome {
                for (i, r) in results.iter().enumerate().take(20) {
                    assert_eq!(*r, OpResult::Applied(true), "{family}: insert {i}");
                }
            }
            let ticket = kv.crash(CrashPolicy::PESSIMISTIC);
            let (kv2, report) = ticket.recover().unwrap();
            kv = kv2;
            let applied = kv.get(inserts[0]) == Some(inserts[0] * 2);
            if applied {
                for &k in &inserts {
                    assert_eq!(kv.get(k), Some(k * 2), "{family}: torn batch (insert {k})");
                }
                for &k in &victims {
                    assert_eq!(kv.get(k), None, "{family}: torn batch (victim {k})");
                }
                saw_all = true;
            } else {
                for &k in &inserts {
                    assert_eq!(kv.get(k), None, "{family}: torn batch (ghost insert {k})");
                }
                for &k in &victims {
                    assert_eq!(kv.get(k), Some(k + 7), "{family}: torn batch (lost victim {k})");
                }
                saw_none = true;
            }
            // An acked (completed) batch must have survived in full.
            if completed {
                assert!(applied, "{family}: acked atomic batch lost");
            }
            // Pre-state is never collateral damage.
            for k in 0..50u64 {
                assert_eq!(kv.get(k), Some(k + 1), "{family}: pre-state {k} damaged");
            }
            if report.txn_rolled_forward > 0 {
                rolled_total += report.txn_rolled_forward;
                assert!(applied, "{family}: roll-forward must yield the full batch");
                assert!(
                    kv.metrics.report().contains("rolled_forward=1"),
                    "roll-forward must surface on STATS"
                );
            }
            // Clean up applied rounds so each round starts from a known
            // state (removes are plain acked ops).
            if applied {
                for &k in &inserts {
                    assert!(kv.del(k), "{family}: cleanup {k}");
                }
            }
            if completed {
                break;
            }
            fault += 1;
            round += 1;
        }
        assert!(
            saw_none && saw_all && rolled_total > 0,
            "{family}: the fault sweep must hit discard ({saw_none}), roll-forward \
             ({rolled_total}) and full-apply ({saw_all}) outcomes"
        );
    }
}

/// Wire-level complement: `MULTI <n> ATOMIC` acks are durable — stop the
/// server after the replies, crash, recover, and the whole batch (and
/// nothing torn) is there.
#[test]
fn served_atomic_batch_acks_are_durable() {
    use durasets::coordinator::server;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::Arc;

    let _sim = pmem::sim_session();
    let mut cfg = crash_cfg(Family::LinkFree);
    cfg.shards = 2;
    let kv = Arc::new(DuraKv::create(cfg));
    let srv = server::serve(kv.clone(), 0).unwrap();

    let stream = TcpStream::connect(srv.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writer.write_all(b"PUT 7 70\nMULTI 4 ATOMIC\nPUT 1 11\nPUT 2 22\nDEL 7\nGET 1\nEXEC\n").unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    let want = ["OK NEW", "OK NEW", "OK NEW", "OK DELETED", "FOUND 11"];
    for (i, w) in want.iter().enumerate() {
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), *w, "reply {i}");
    }
    writer.write_all(b"QUIT\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "BYE");
    drop(reader);
    drop(writer);
    drop(srv);
    let kv = {
        let mut arc = kv;
        let mut tries = 0;
        loop {
            match Arc::try_unwrap(arc) {
                Ok(inner) => break inner,
                Err(still_shared) => {
                    arc = still_shared;
                    tries += 1;
                    assert!(tries < 1000, "connection handler never released the store");
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
            }
        }
    };
    let (kv2, _) = kv.crash(CrashPolicy::PESSIMISTIC).recover().unwrap();
    assert_eq!(kv2.get(1), Some(11), "acked atomic insert survives");
    assert_eq!(kv2.get(2), Some(22), "acked atomic insert survives");
    assert_eq!(kv2.get(7), None, "acked atomic delete survives");
}
