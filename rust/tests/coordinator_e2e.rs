//! End-to-end coordinator test: TCP clients drive the sharded durable KV
//! service, the machine crashes mid-service, recovery restores it, and a
//! fresh server serves the recovered state.

use durasets::config::Config;
use durasets::coordinator::{server, DuraKv};
use durasets::pmem::{self, CrashPolicy};
use durasets::sets::Family;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { writer: stream, reader }
    }

    fn send(&mut self, line: &str) -> String {
        writeln!(self.writer, "{line}").unwrap();
        let mut out = String::new();
        self.reader.read_line(&mut out).unwrap();
        out.trim_end().to_string()
    }
}

#[test]
fn serve_crash_recover_serve() {
    let _g = LOCK.lock().unwrap();
    let _sim = pmem::sim_session();
    let mut cfg = Config::default();
    cfg.family = Family::Soft;
    cfg.shards = 3;
    cfg.key_range = 1 << 14;
    cfg.sim = true;
    cfg.psync_ns = 0;

    let kv = Arc::new(DuraKv::create(cfg));
    let srv = server::serve(kv.clone(), 0).unwrap();
    let addr = srv.addr;

    // Phase 1: concurrent clients write through the wire.
    let handles: Vec<_> = (0..3u64)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                for i in 0..200u64 {
                    let k = t * 10_000 + i;
                    assert_eq!(c.send(&format!("PUT {k} {}", k * 7)), "OK NEW");
                }
                // Delete the last 50.
                for i in 150..200u64 {
                    let k = t * 10_000 + i;
                    assert_eq!(c.send(&format!("DEL {k}")), "OK DELETED");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(kv.len_approx(), 3 * 150);
    let served_ops = kv.metrics.ops_total();
    assert_eq!(served_ops, 3 * 250);

    // Phase 2: stop the server, crash the machine, recover.
    drop(srv);
    let kv = Arc::try_unwrap(kv).map_err(|_| ()).expect("server released all refs");
    let ticket = kv.crash(CrashPolicy::random(0.3, 99));
    let (kv2, report) = ticket.recover().unwrap();
    assert_eq!(report.members, 3 * 150);

    // Phase 3: fresh server over the recovered store.
    let kv2 = Arc::new(kv2);
    let srv2 = server::serve(kv2.clone(), 0).unwrap();
    let mut c = Client::connect(srv2.addr);
    for t in 0..3u64 {
        for i in 0..150u64 {
            let k = t * 10_000 + i;
            assert_eq!(c.send(&format!("GET {k}")), format!("FOUND {}", k * 7));
        }
        for i in 150..200u64 {
            let k = t * 10_000 + i;
            assert_eq!(c.send(&format!("GET {k}")), "MISSING");
        }
    }
    assert_eq!(c.send("LEN"), format!("LEN {}", 3 * 150));
    assert_eq!(c.send("QUIT"), "BYE");
    drop(srv2);
}

#[test]
fn backpressure_queue_survives_burst() {
    let _g = LOCK.lock().unwrap();
    let mut cfg = Config::default();
    cfg.shards = 1; // single queue: the burst must be absorbed in order
    cfg.key_range = 1 << 12;
    cfg.psync_ns = 0;
    cfg.sim = false;
    let kv = Arc::new(DuraKv::create(cfg));
    let srv = server::serve(kv.clone(), 0).unwrap();
    // Blast >QUEUE_CAP pipelined requests down one connection.
    let mut c = Client::connect(srv.addr);
    for i in 0..3000u64 {
        writeln!(c.writer, "PUT {i} {i}").unwrap();
    }
    c.writer.flush().unwrap();
    let mut ok = 0;
    for _ in 0..3000 {
        let mut line = String::new();
        c.reader.read_line(&mut line).unwrap();
        if line.starts_with("OK NEW") {
            ok += 1;
        }
    }
    assert_eq!(ok, 3000);
    assert_eq!(kv.len_approx(), 3000);
    drop(srv);
}
