//! Crash-during-compaction: the acked member set is exact at **every**
//! flush boundary of the migration pipeline (DESIGN.md §Allocator).
//!
//! Compaction migrates survivors between areas with the families' own
//! durable-copy machinery, so a power loss can land between any two of
//! its flushes: after a copy but before the original's delete record
//! (link-free — the duplicate window recovery dedup closes), between a
//! fresh `PNode`'s validity flush and the old one's destroy (SOFT), or
//! around a link-and-persist pred swing (log-free — atomic handoff, no
//! window). The sweep below arms the simulated power loss at flush 1, 2,
//! 3, … of a full maintenance pass and, after every crash, recovers and
//! checks the *exact* acked member set — every surviving key with its
//! value, every deleted key absent, nothing torn, no ghosts — until a
//! whole pass completes unfaulted. All four resizable families
//! (NVTraverse shares the link-free durable-copy machinery, so its
//! duplicate window is closed the same way).

use durasets::pmem::{self, CrashPolicy, PoolId};
use durasets::sets::resizable::{
    recover_linkfree, recover_logfree, recover_nvtraverse, recover_soft, ResizableFamily,
    ResizableHash,
};
use durasets::sets::{ConcurrentSet, RecoveredStats};
use std::panic::AssertUnwindSafe;

mod common;
use common::quiet_power_loss_panics;

/// Two areas' worth of keys; survivors are 1 in 32 (the mass delete
/// leaves both areas far below the compaction claim threshold).
const FILL: u64 = 2 * 4096;
const KEEP: u64 = 32;

/// Maintenance ticks per attempted pass — enough for every pipeline
/// phase (claims, EBR grace periods, finish, retire) to run dry.
const TICKS: usize = 64;

fn value(k: u64) -> u64 {
    k * 2 + 1
}

/// Assert the exact acked member set: every kept key present with its
/// value, every deleted key absent.
fn check_members<F: ResizableFamily>(h: &ResizableHash<F>, ctx: &str) {
    for k in 0..FILL {
        let want = (k % KEEP == 0).then(|| value(k));
        assert_eq!(h.get(k), want, "{}: {ctx}: key {k}", F::FAMILY);
    }
    assert_eq!(h.len_approx() as u64, FILL / KEEP, "{}: {ctx}: size", F::FAMILY);
}

fn sweep<F: ResizableFamily>(
    make: impl Fn() -> ResizableHash<F>,
    recover: impl Fn(PoolId, usize) -> (ResizableHash<F>, RecoveredStats),
) {
    let _sim = pmem::sim_session();
    quiet_power_loss_panics();
    pmem::set_psync_ns(0);

    let mut h = make();
    let id = h.pool_id();
    for k in 0..FILL {
        assert!(h.insert(k, value(k)), "{}: fill {k}", F::FAMILY);
    }
    for k in 0..FILL {
        if k % KEEP != 0 {
            assert!(h.remove(k), "{}: delete {k}", F::FAMILY);
        }
    }
    check_members(&h, "pre-sweep");

    let mut fault = 1u64;
    let mut crashes = 0u64;
    loop {
        pmem::arm_flush_fault(fault);
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
            for _ in 0..TICKS {
                let _ = h.maintain_tick();
            }
        }));
        pmem::disarm_flush_fault();
        let completed = outcome.is_ok();

        // Crash (whether the pass completed or was cut mid-flush) and
        // recover: the acked member set must be exact either way.
        h.crash_preserve();
        drop(h);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (h2, _stats) = recover(id, 2);
        h = h2;
        check_members(&h, if completed { "post-pass" } else { "mid-migration crash" });

        if completed {
            break;
        }
        crashes += 1;
        fault += 1;
        assert!(fault < 20_000, "{}: fault sweep did not converge", F::FAMILY);
    }
    assert!(
        crashes > 0,
        "{}: the sweep never crashed mid-migration — compaction did no durable work",
        F::FAMILY
    );

    // The recovered, compacted store still serves updates.
    for k in FILL..FILL + 100 {
        assert!(h.insert(k, value(k)), "{}: post-sweep insert {k}", F::FAMILY);
        assert_eq!(h.get(k), Some(value(k)), "{}: post-sweep get {k}", F::FAMILY);
    }
}

#[test]
fn linkfree_crash_at_every_flush_of_compaction_keeps_exact_members() {
    sweep(|| ResizableHash::new_linkfree(2), recover_linkfree);
}

#[test]
fn soft_crash_at_every_flush_of_compaction_keeps_exact_members() {
    sweep(|| ResizableHash::new_soft(2), recover_soft);
}

#[test]
fn logfree_crash_at_every_flush_of_compaction_keeps_exact_members() {
    sweep(|| ResizableHash::new_logfree(2), recover_logfree);
}

#[test]
fn nvtraverse_crash_at_every_flush_of_compaction_keeps_exact_members() {
    sweep(|| ResizableHash::new_nvtraverse(2), recover_nvtraverse);
}
