//! Concurrency stress for the resizable durable hash sets: 8 threads on
//! disjoint key stripes drive each table across several doublings while
//! every op's result is checked against a per-stripe BTreeSet model
//! (disjoint stripes make the models exact even under concurrency); the
//! final snapshot must equal the union of the models, and reads must stay
//! psync-free afterwards.

use durasets::pmem::stats;
use durasets::sets::resizable::{ResizableFamily, ResizableHash};
use durasets::sets::ConcurrentSet;
use durasets::util::rng::Xoshiro256;
use std::collections::BTreeSet;
use std::sync::Arc;

const THREADS: u64 = 8;
const OPS: u64 = 6_000;
const STRIPE_KEYS: u64 = 256;

fn stress<F: ResizableFamily>(h: ResizableHash<F>, seed: u64) {
    let initial = h.nbuckets();
    let h = Arc::new(h);
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut rng = Xoshiro256::new(seed ^ (t * 0x9E37));
                let mut model = BTreeSet::new();
                for _ in 0..OPS {
                    // Stripe-owned key: k ≡ t (mod THREADS).
                    let k = rng.below(STRIPE_KEYS) * THREADS + t;
                    match rng.below(4) {
                        0 | 1 => assert_eq!(h.insert(k, k ^ t), model.insert(k), "insert {k}"),
                        2 => assert_eq!(h.remove(k), model.remove(&k), "remove {k}"),
                        _ => assert_eq!(h.contains(k), model.contains(&k), "contains {k}"),
                    }
                }
                model
            })
        })
        .collect();
    let mut want = BTreeSet::new();
    for hnd in handles {
        want.extend(hnd.join().unwrap());
    }

    assert_eq!(h.len_approx(), want.len());
    let mut snap: Vec<u64> = h.snapshot().iter().map(|kv| kv.0).collect();
    snap.sort_unstable();
    let want: Vec<u64> = want.into_iter().collect();
    assert_eq!(snap, want, "snapshot must equal the union of stripe models");

    assert!(
        h.nbuckets() >= initial * 4,
        "table must cross >= 2 doublings under load: {} -> {}",
        initial,
        h.nbuckets()
    );

    // Steady state reached: reads over the grown table stay psync-free.
    let probe: Vec<u64> = want.iter().copied().take(64).collect();
    let a = stats::thread_snapshot();
    for &k in &probe {
        assert!(h.contains(k));
    }
    let d = stats::thread_snapshot().since(&a);
    assert_eq!(d.fences, 0, "reads must not psync after growth");
}

#[test]
fn linkfree_concurrent_growth_matches_models() {
    stress(ResizableHash::new_linkfree(2), 0xA11);
}

#[test]
fn soft_concurrent_growth_matches_models() {
    stress(ResizableHash::new_soft(2), 0xA22);
}

#[test]
fn logfree_concurrent_growth_matches_models() {
    stress(ResizableHash::new_logfree(2), 0xA33);
}
