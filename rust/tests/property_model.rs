//! Randomized property tests (in-repo harness; proptest is not in the
//! offline crate set). Each property runs across many seeds; a failure
//! reports the seed for deterministic reproduction.
//!
//! Properties:
//!   P1 sequential model equivalence — any op sequence on any family ==
//!      BTreeMap model (list + hash).
//!   P2 crash idempotence — recover(crash(S)) == persisted view of S, and
//!      recovering twice yields the same set.
//!   P3 router/stripe composition — DuraKv over N shards == one flat model.
//!   P4 config roundtrip — every generated config re-parses to itself.

use durasets::config::{Config, Structure};
use durasets::coordinator::DuraKv;
use durasets::pmem::{self, CrashPolicy};
use durasets::sets::{self, ConcurrentSet, Family};
use durasets::util::rng::Xoshiro256;
use std::collections::BTreeMap;

const SEEDS: u64 = 12;

fn families() -> [Family; 4] {
    Family::ALL
}

#[test]
fn p1_model_equivalence_all_families() {
    for family in families() {
        for structure in [Structure::Hash, Structure::List] {
            for seed in 0..SEEDS {
                let set: Box<dyn ConcurrentSet> = match structure {
                    Structure::Hash => sets::new_hash(family, 16),
                    Structure::List => sets::new_list(family),
                };
                let mut model: BTreeMap<u64, u64> = BTreeMap::new();
                let mut rng = Xoshiro256::new(0xAA ^ seed.wrapping_mul(0x9E37));
                for step in 0..3000 {
                    let k = rng.below(48);
                    let ctx = format!("{family:?}/{structure:?} seed={seed} step={step} key={k}");
                    match rng.below(4) {
                        0 | 1 => {
                            let v = rng.next_u64();
                            assert_eq!(
                                set.insert(k, v),
                                !model.contains_key(&k),
                                "insert {ctx}"
                            );
                            model.entry(k).or_insert(v);
                        }
                        2 => {
                            assert_eq!(set.remove(k), model.remove(&k).is_some(), "remove {ctx}");
                        }
                        _ => {
                            assert_eq!(set.get(k), model.get(&k).copied(), "get {ctx}");
                        }
                    }
                }
                assert_eq!(set.len_approx(), model.len());
            }
        }
    }
}

#[test]
fn p2_crash_idempotence() {
    let _sim = pmem::sim_session();
    pmem::set_psync_ns(0);
    for family in [Family::LinkFree, Family::Soft, Family::LogFree] {
        for seed in 0..SEEDS {
            let set = sets::new_hash(family, 32);
            let pool = set.durable_pool().unwrap();
            let mut model: BTreeMap<u64, u64> = BTreeMap::new();
            let mut rng = Xoshiro256::new(0xBB ^ seed);
            for _ in 0..2000 {
                let k = rng.below(128);
                if rng.below(2) == 0 {
                    let v = rng.next_u64();
                    if set.insert(k, v) {
                        model.insert(k, v);
                    }
                } else if set.remove(k) {
                    model.remove(&k);
                }
            }
            set.prepare_crash();
            drop(set);
            pmem::crash_pools(CrashPolicy::random((seed % 3) as f64 * 0.4, seed), &[pool]);

            // Hash shards are resizable: recover through the resizable
            // entry points (family list + bucket-count epoch).
            let recover = |pool| -> Box<dyn ConcurrentSet> {
                match family {
                    Family::LinkFree => Box::new(sets::resizable::recover_linkfree(pool, 32).0),
                    Family::Soft => Box::new(sets::resizable::recover_soft(pool, 32).0),
                    Family::LogFree => Box::new(sets::resizable::recover_logfree(pool, 32).0),
                    Family::Volatile => unreachable!(),
                }
            };
            let r1 = recover(pool);
            // All ops completed before the crash => exact match.
            assert_eq!(r1.len_approx(), model.len(), "{family:?} seed={seed}");
            for (&k, &v) in &model {
                assert_eq!(r1.get(k), Some(v), "{family:?} seed={seed} key={k}");
            }
            // Crash again with NO ops in between: recovery must be
            // idempotent.
            r1.prepare_crash();
            drop(r1);
            pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[pool]);
            let r2 = recover(pool);
            assert_eq!(r2.len_approx(), model.len(), "{family:?} seed={seed} (2nd)");
            for (&k, &v) in &model {
                assert_eq!(r2.get(k), Some(v), "{family:?} seed={seed} key={k} (2nd)");
            }
        }
    }
}

#[test]
fn p3_sharded_kv_equals_flat_model() {
    for seed in 0..SEEDS {
        let mut cfg = Config::default();
        cfg.shards = 1 + (seed as usize % 5);
        cfg.key_range = 1024;
        cfg.psync_ns = 0;
        let kv = DuraKv::create(cfg);
        let mut model: BTreeMap<u64, u64> = BTreeMap::new();
        let mut rng = Xoshiro256::new(0xCC ^ seed);
        for _ in 0..3000 {
            let k = rng.below(512);
            match rng.below(4) {
                0 | 1 => {
                    let v = rng.next_u64();
                    assert_eq!(kv.put(k, v), !model.contains_key(&k), "seed={seed}");
                    model.entry(k).or_insert(v);
                }
                2 => {
                    assert_eq!(kv.del(k), model.remove(&k).is_some(), "seed={seed}");
                }
                _ => {
                    assert_eq!(kv.get(k), model.get(&k).copied(), "seed={seed}");
                }
            }
        }
        assert_eq!(kv.len_approx(), model.len(), "seed={seed}");
    }
}

#[test]
fn p4_config_values_roundtrip() {
    for seed in 0..SEEDS {
        let mut rng = Xoshiro256::new(0xDD ^ seed);
        let families = ["soft", "link-free", "log-free", "volatile"];
        let fam = families[rng.below(4) as usize];
        let shards = 1 + rng.below(8);
        let range = 1 + rng.below(1 << 20);
        let pct = rng.below(101);
        let overrides = vec![
            format!("family={fam}"),
            format!("shards={shards}"),
            format!("key_range={range}"),
            format!("read_pct={pct}"),
        ];
        let cfg = Config::load(None, &overrides).unwrap();
        assert_eq!(cfg.family, Family::parse(fam).unwrap(), "seed={seed}");
        assert_eq!(cfg.shards as u64, shards);
        assert_eq!(cfg.key_range, range);
        assert_eq!(cfg.read_pct as u64, pct);
    }
}
