//! Reclamation-churn stress: hammer free→alloc slot turnover underneath
//! live hints and towers, and prove the generation-tag validation never
//! misreads a reincarnated slot.
//!
//! Every thread owns a key stripe (k ≡ t mod THREADS) and drives waves of
//! insert → remove-most → mixed ops, model-checked per op against a
//! per-stripe BTreeSet (disjoint stripes make the models exact even under
//! concurrency). The remove waves push thousands of nodes through EBR
//! retire into the per-thread free-lists; the next wave's inserts reuse
//! exactly those slots while other threads still traverse through bucket
//! hints (resizable hashes) or towers (skip lists) published against the
//! previous incarnations. Any misvalidation — accepting a stale hint to a
//! reincarnated slot as a window start — corrupts a traversal or an
//! unlink and surfaces as a model mismatch, a lost key, or a broken sort
//! order. The tables must also cross ≥ 2 doublings under the churn and
//! keep reads psync-free afterwards.
//!
//! Negative control: `cargo test --features untagged-hints` compiles the
//! generation checks out, restoring the old state-only heuristic. The
//! deterministic ABA-replay unit tests
//! (`sets::resizable::tests::stale_hint_to_reallocated_slot_is_rejected_by_generation`,
//! `sets::linkfree::skiplist::tests::stale_tower_to_reallocated_slot_is_rejected_by_generation`)
//! then demonstrably *accept* the reincarnated slot under the exact same
//! schedule the tagged build rejects.

use durasets::pmem::{self, stats, CrashPolicy};
use durasets::sets::linkfree::LfSkipList;
use durasets::sets::resizable::{recover_linkfree, ResizableFamily, ResizableHash};
use durasets::sets::soft::SoftSkipList;
use durasets::sets::ConcurrentSet;
use durasets::util::rng::Xoshiro256;
use std::collections::BTreeSet;
use std::sync::{Arc, Mutex, MutexGuard};

/// Serialize the tests of this binary: the fault-injection test arms the
/// process-global flush countdown, which a concurrently running churn
/// test would otherwise decrement (and catch the power loss meant for
/// the armed test).
fn exclusive() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

mod common;
use common::quiet_power_loss_panics;

const THREADS: u64 = 8;
const STRIPE_KEYS: u64 = 512;
const ROUNDS: u64 = 3;
const MIXED_OPS: u64 = 600;

/// One thread's churn over its own stripe, model-checked per op.
fn churn_stripe<S: ConcurrentSet + ?Sized>(s: &S, t: u64, seed: u64) -> BTreeSet<u64> {
    let mut rng = Xoshiro256::new(seed ^ (t.wrapping_mul(0x9E37_79B9)));
    let mut model: BTreeSet<u64> = BTreeSet::new();
    for round in 0..ROUNDS {
        // Insert wave: reuses the slots the previous round freed, while
        // other threads' hints/towers still reference old incarnations.
        for i in 0..STRIPE_KEYS {
            let k = i * THREADS + t;
            assert_eq!(s.insert(k, k ^ round), model.insert(k), "insert {k} r{round}");
        }
        // Remove wave: retire most of the stripe through EBR so the
        // free-lists are hot for the next wave.
        for i in 0..STRIPE_KEYS {
            let k = i * THREADS + t;
            if rng.below(8) != 0 {
                assert_eq!(s.remove(k), model.remove(&k), "remove {k} r{round}");
            }
        }
        // Mixed tail: interleaved lookups catch a stale window start the
        // moment it skips or resurrects a stripe key.
        for _ in 0..MIXED_OPS {
            let k = rng.below(STRIPE_KEYS) * THREADS + t;
            match rng.below(4) {
                0 => assert_eq!(s.insert(k, k), model.insert(k), "insert {k}"),
                1 => assert_eq!(s.remove(k), model.remove(&k), "remove {k}"),
                _ => assert_eq!(s.contains(k), model.contains(&k), "contains {k}"),
            }
        }
    }
    model
}

fn hash_churn<F: ResizableFamily>(h: ResizableHash<F>, seed: u64) {
    let _x = exclusive();
    let initial = h.nbuckets();
    let h = Arc::new(h);
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let h = h.clone();
            std::thread::spawn(move || churn_stripe(&*h, t, seed))
        })
        .collect();
    let mut want = BTreeSet::new();
    for hnd in handles {
        want.extend(hnd.join().unwrap());
    }

    // Zero misvalidations end-to-end: the table equals the stripe union.
    let mut snap: Vec<u64> = h.snapshot().iter().map(|kv| kv.0).collect();
    snap.sort_unstable();
    let want: Vec<u64> = want.into_iter().collect();
    assert_eq!(snap, want, "snapshot must equal the union of stripe models");

    // The insert waves load the table far past the growth trigger.
    assert!(
        h.nbuckets() >= initial * 4,
        "churn must cross >= 2 doublings: {} -> {}",
        initial,
        h.nbuckets()
    );

    // Gen checks ride the read path without adding any persistence cost.
    let probe: Vec<u64> = want.iter().copied().take(64).collect();
    let a = stats::thread_snapshot();
    for &k in &probe {
        assert!(h.contains(k));
    }
    let d = stats::thread_snapshot().since(&a);
    assert_eq!(d.fences, 0, "contains must stay psync-free under churned hints");
    assert_eq!(d.flushes, 0, "contains must stay flush-free under churned hints");
}

#[test]
fn linkfree_hash_reclaim_churn() {
    hash_churn(ResizableHash::new_linkfree(2), 0x4EC1);
}

#[test]
fn soft_hash_reclaim_churn() {
    hash_churn(ResizableHash::new_soft(2), 0x4EC2);
}

#[test]
fn logfree_hash_reclaim_churn() {
    hash_churn(ResizableHash::new_logfree(2), 0x4EC3);
}

fn skiplist_churn<S: ConcurrentSet + 'static>(
    s: S,
    seed: u64,
    snapshot: fn(&S) -> Vec<(u64, u64)>,
) {
    let _x = exclusive();
    let s = Arc::new(s);
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let s = s.clone();
            std::thread::spawn(move || churn_stripe(&*s, t, seed))
        })
        .collect();
    let mut want = BTreeSet::new();
    for hnd in handles {
        want.extend(hnd.join().unwrap());
    }
    let snap: Vec<u64> = snapshot(&s).iter().map(|kv| kv.0).collect();
    let want: Vec<u64> = want.into_iter().collect();
    assert_eq!(snap, want, "bottom level must equal the union of stripe models");
    for w in snap.windows(2) {
        assert!(w[0] < w[1], "bottom level must stay strictly sorted");
    }
}

#[test]
fn linkfree_skiplist_tower_reclaim_churn() {
    skiplist_churn(LfSkipList::new(), 0x70E1, LfSkipList::snapshot);
}

#[test]
fn soft_skiplist_tower_reclaim_churn() {
    skiplist_churn(SoftSkipList::new(), 0x70E2, SoftSkipList::snapshot);
}

/// Fault injection over the churn: a simulated power loss lands mid-op
/// (between flushes), the pool crashes pessimistically, and recovery must
/// reproduce exactly the acked state — at most the single in-flight key
/// may land either way. This is the crash-during-reclamation discipline
/// end to end: frees and gen bumps that were not persisted simply roll
/// back with the slots.
#[test]
fn fault_injected_crash_during_churn_recovers_acked_state() {
    let _x = exclusive();
    let _sim = pmem::sim_session();
    quiet_power_loss_panics();
    let h = ResizableHash::new_linkfree(2);
    let id = h.pool_id();

    let acked = std::cell::RefCell::new(BTreeSet::<u64>::new());
    let in_flight = std::cell::Cell::new(u64::MAX);
    pmem::arm_flush_fault(1500);
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut rng = Xoshiro256::new(0xFA17);
        loop {
            let k = rng.below(256);
            in_flight.set(k);
            if rng.below(3) > 0 {
                let ok = h.insert(k, k + 1);
                assert_eq!(ok, acked.borrow_mut().insert(k));
            } else {
                let ok = h.remove(k);
                assert_eq!(ok, acked.borrow_mut().remove(&k));
            }
        }
    }));
    pmem::disarm_flush_fault();
    let err = outcome.expect_err("the armed fault must fire");
    assert_eq!(
        err.downcast_ref::<&str>().copied(),
        Some(pmem::POWER_LOSS),
        "only the simulated power loss may abort the churn"
    );

    h.crash_preserve();
    drop(h);
    pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);

    let (h2, _stats) = recover_linkfree(id, 2);
    let acked = acked.into_inner();
    let torn = in_flight.get();
    for k in 0..256u64 {
        if k == torn {
            continue; // unacked in-flight op: either outcome is legal
        }
        assert_eq!(
            h2.contains(k),
            acked.contains(&k),
            "acked state of key {k} must survive the mid-churn power loss"
        );
    }
    // Fully operational post-recovery.
    assert!(h2.insert(100_000, 1));
    assert!(h2.remove(100_000));
}
