//! durcheck integration suite (DESIGN.md §Checking).
//!
//! Two halves, mirroring the ISSUE-8 acceptance criteria:
//!
//! * **Pins** — the real families' fast paths (insert / remove / contains,
//!   and the K=64 batch path) run under the armed checker with
//!   `redundant_flushes == 0` and zero violations. Any flush of an
//!   already-clean line on a fast path is now a test failure, not a perf
//!   smell; any ack of an unpersisted store is a `DurabilityRace`.
//! * **Negative controls** — a deliberately buggy mini-structure (one
//!   durable slot region + a volatile head link, the smallest thing with
//!   a persist protocol) is driven through a missing-flush, a
//!   missing-fence, and a pre-fence-publish insert, and the checker must
//!   flag each with the *correct* violation type — in the style of the
//!   `untagged-hints` ABA control: the checker's value is only proven by
//!   watching it fire.
//!
//! Everything here takes `pmem::sim_session()` (the checker only observes
//! sim mode), which also serializes the armed windows across the binary,
//! making per-thread counter deltas exact.

use durasets::pmem::check::{self, ViolationKind};
use durasets::pmem::region::{alloc_region, release_pool, RegionTag};
use durasets::pmem::{self, PoolId};
use durasets::sets::{self, ConcurrentSet, Family, SetOp};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, Ordering};

/// Drive one structure's single-op and batch fast paths; return nothing,
/// assert the checker deltas inline.
fn pin_fast_paths(label: &str, set: &dyn ConcurrentSet) {
    let before = check::thread_snapshot();
    for k in 0..200u64 {
        assert!(set.insert(k, k + 1), "{label}: insert {k}");
    }
    for k in 0..200u64 {
        assert!(set.contains(k), "{label}: contains {k}");
        assert_eq!(set.get(k), Some(k + 1), "{label}: get {k}");
    }
    for k in 0..100u64 {
        assert!(set.remove(k), "{label}: remove {k}");
    }
    for k in 0..100u64 {
        assert!(!set.contains(k), "{label}: removed {k} still present");
    }
    // The batch fast path at the pinned group size (K = 64): one
    // PsyncScope, per-op flushes, one trailing fence.
    let ops: Vec<SetOp> = (1_000..1_064u64).map(|k| SetOp::Insert(k, 7)).collect();
    let res = set.apply_batch(&ops);
    assert_eq!(res.len(), 64, "{label}");
    let d = check::thread_snapshot().since(&before);
    assert!(d.events > 0, "{label}: armed checker saw no events");
    assert_eq!(d.redundant_flushes, 0, "{label}: clean-line flush on a fast path");
    assert_eq!(d.violations, 0, "{label}: checker violations on a fast path");
    // The ack-boundary assertion the coordinator uses at scatter time.
    check::assert_persisted(label);
}

#[test]
fn hash_fast_paths_pin_zero_redundant_flushes() {
    let _sim = pmem::sim_session();
    pmem::set_psync_ns(0);
    let _c = check::session();
    for family in Family::DURABLE {
        let set = sets::new_hash(family, 64);
        pin_fast_paths(&format!("hash/{family}"), set.as_ref());
    }
}

#[test]
fn list_fast_paths_pin_zero_redundant_flushes() {
    let _sim = pmem::sim_session();
    pmem::set_psync_ns(0);
    let _c = check::session();
    for family in Family::DURABLE {
        let set = sets::new_list(family);
        pin_fast_paths(&format!("list/{family}"), set.as_ref());
    }
}

#[test]
fn skiplist_fast_paths_pin_zero_redundant_flushes() {
    let _sim = pmem::sim_session();
    pmem::set_psync_ns(0);
    let _c = check::session();
    for family in [Family::LinkFree, Family::Soft] {
        let set = sets::new_skiplist(family);
        pin_fast_paths(&format!("skiplist/{family}"), set.as_ref());
    }
}

// ---------------------------------------------------------------------
// Negative controls.
// ---------------------------------------------------------------------

/// Which step of the persist protocol the buggy insert skips.
#[derive(Clone, Copy)]
enum Bug {
    /// Correct protocol: store → flush → publish → fence → ack.
    None,
    /// Store → fence → ack: the fence persists nothing it never flushed.
    MissingFlush,
    /// Store → flush → ack: durable-at-issue in the sim model, but the
    /// ack ordering is exactly what the trailing fence provides.
    MissingFence,
    /// Store → publish → flush → fence → ack: the link made the node
    /// reachable while its line was still dirty.
    PreFencePublish,
}

/// The smallest structure with a persist protocol: fixed durable slots
/// holding one key word each, published through a volatile head link.
struct MiniList {
    pool: PoolId,
    base: *mut u8,
    head: AtomicU64,
    next_slot: std::cell::Cell<usize>,
}

impl MiniList {
    fn new() -> Self {
        let pool = PoolId::fresh();
        let base = alloc_region(pool, 64 * 64, RegionTag::Slots, 64);
        MiniList { pool, base, head: AtomicU64::new(0), next_slot: std::cell::Cell::new(0) }
    }

    /// One insert, honest about `bug`, acked via `release_check` — the
    /// same drain the coordinator's `assert_persisted` performs.
    fn insert(&self, key: u64, bug: Bug) -> Vec<check::Violation> {
        let i = self.next_slot.get();
        self.next_slot.set(i + 1);
        let slot = unsafe { self.base.add(i * 64) };
        let word = unsafe { &*(slot as *const AtomicU64) };
        word.store(key, Ordering::Release);
        check::note_store(slot);
        match bug {
            Bug::None => {
                pmem::flush_line(slot);
                check::note_publish(slot);
                self.head.store(slot as u64, Ordering::Release);
                pmem::fence();
            }
            Bug::MissingFlush => {
                self.head.store(slot as u64, Ordering::Release);
                pmem::fence();
            }
            Bug::MissingFence => {
                pmem::flush_line(slot);
                self.head.store(slot as u64, Ordering::Release);
            }
            Bug::PreFencePublish => {
                check::note_publish(slot);
                self.head.store(slot as u64, Ordering::Release);
                // Repair the persist so the *only* finding is the publish
                // ordering — keeps each control's signature distinct.
                pmem::psync(slot, 8);
            }
        }
        check::release_check("minilist.ack")
    }
}

impl Drop for MiniList {
    fn drop(&mut self) {
        release_pool(self.pool);
    }
}

#[test]
fn negative_controls_fire_with_the_correct_violation_type() {
    let _sim = pmem::sim_session();
    pmem::set_psync_ns(0);
    let _c = check::session();
    let list = MiniList::new();

    // Sanity: the correct protocol acks clean.
    let v = list.insert(1, Bug::None);
    assert!(v.is_empty(), "correct insert must ack clean: {v:?}");

    let v = list.insert(2, Bug::MissingFlush);
    assert_eq!(v.len(), 1, "missing flush: {v:?}");
    assert_eq!(v[0].kind, ViolationKind::DurabilityRace { flushed: false });

    let v = list.insert(3, Bug::MissingFence);
    assert_eq!(v.len(), 1, "missing fence: {v:?}");
    assert_eq!(v[0].kind, ViolationKind::DurabilityRace { flushed: true });

    let v = list.insert(4, Bug::PreFencePublish);
    assert_eq!(v.len(), 1, "pre-fence publish: {v:?}");
    assert_eq!(v[0].kind, ViolationKind::UnfencedPublish);

    // And clean again after the buggy ones — no lingering state leaks
    // into later acks (the buggy slots were drained at their own acks).
    let v = list.insert(5, Bug::None);
    assert!(v.is_empty(), "post-bug insert must ack clean: {v:?}");
}

#[test]
fn assert_persisted_panics_at_a_dirty_ack_boundary() {
    let _sim = pmem::sim_session();
    pmem::set_psync_ns(0);
    let _c = check::session();
    let list = MiniList::new();
    let slot = unsafe { list.base.add(63 * 64) };
    unsafe { &*(slot as *const AtomicU64) }.store(9, Ordering::Release);
    check::note_store(slot);
    let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
        check::assert_persisted("durcheck.test.dirty_ack");
    }));
    assert!(r.is_err(), "assert_persisted must panic on an unpersisted ack");
    // Fix the protocol; the same boundary now passes.
    pmem::psync(slot, 8);
    check::assert_persisted("durcheck.test.after_fix");
}

/// The STATS gauge surfaces checker counters without log scraping
/// (satellite: `check=[events/violations/redundant_flushes]`).
#[test]
fn stats_gauge_reports_checker_counters_when_armed() {
    let _sim = pmem::sim_session();
    pmem::set_psync_ns(0);
    let _c = check::session();
    let set = sets::new_hash(Family::LinkFree, 16);
    for k in 0..32u64 {
        assert!(set.insert(k, 1));
    }
    let snap = check::snapshot();
    assert!(snap.events > 0, "armed run must accumulate checker events");
    let metrics = durasets::coordinator::metrics::Metrics::new();
    let report = metrics.report();
    assert!(
        report.contains("check=[events="),
        "STATS must carry the check gauge when events exist: {report}"
    );
}
