//! Zipfian key sampler (Gray et al. "Quickly generating billion-record
//! synthetic databases", the YCSB ZipfianGenerator formula): constant-time
//! sampling after an O(n) zeta precomputation.

/// Zipfian distribution over `[0, n)` with skew `theta` (0 < theta < 1;
/// YCSB default 0.99).
pub struct Zipf {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
}

impl Zipf {
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0 && theta > 0.0 && theta < 1.0);
        let zetan = zeta(n, theta);
        let zeta2 = zeta(2.min(n), theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Zipf { n, theta, alpha, zetan, eta }
    }

    /// Map a uniform 64-bit hash to a zipf-distributed rank. Rank 0 is the
    /// hottest key; callers typically scatter ranks via a fixed
    /// permutation to avoid clustering hot keys in one hash bucket.
    pub fn sample(&self, hash: u64) -> u64 {
        let u = (hash >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let r = ((self.n as f64) * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64;
        r.min(self.n - 1)
    }
}

fn zeta(n: u64, theta: f64) -> f64 {
    // Direct sum for small n; Euler-Maclaurin tail estimate for large n
    // keeps construction O(1e6) worst-case instead of O(n).
    const DIRECT: u64 = 1_000_000;
    if n <= DIRECT {
        (1..=n).map(|i| 1.0 / (i as f64).powf(theta)).sum()
    } else {
        let head: f64 = (1..=DIRECT).map(|i| 1.0 / (i as f64).powf(theta)).sum();
        // integral_{DIRECT}^{n} x^-theta dx
        let a = DIRECT as f64;
        let b = n as f64;
        head + (b.powf(1.0 - theta) - a.powf(1.0 - theta)) / (1.0 - theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn samples_in_range() {
        let z = Zipf::new(1000, 0.99);
        let mut rng = Xoshiro256::new(1);
        for _ in 0..10_000 {
            assert!(z.sample(rng.next_u64()) < 1000);
        }
    }

    #[test]
    fn is_actually_skewed() {
        let z = Zipf::new(10_000, 0.99);
        let mut rng = Xoshiro256::new(2);
        let n = 100_000;
        let hot = (0..n).filter(|_| z.sample(rng.next_u64()) < 10).count();
        let frac = hot as f64 / n as f64;
        // Top-10 of 10k keys should draw a large share under theta=.99.
        assert!(frac > 0.2, "zipf not skewed: top-10 share {frac}");
        // ...and rank 0 must dominate rank 9.
        let mut counts = [0usize; 10];
        let mut rng = Xoshiro256::new(3);
        for _ in 0..n {
            let s = z.sample(rng.next_u64());
            if s < 10 {
                counts[s as usize] += 1;
            }
        }
        assert!(counts[0] > counts[9] * 2, "{counts:?}");
    }

    #[test]
    fn zeta_tail_estimate_is_close() {
        // Compare direct vs estimated on a size just above the cutoff.
        let direct: f64 = (1..=1_100_000u64).map(|i| 1.0 / (i as f64).powf(0.9)).sum();
        let est = super::zeta(1_100_000, 0.9);
        assert!((direct - est).abs() / direct < 1e-3);
    }
}
