//! Workload engine: the op streams of the paper's evaluation (§6.1).
//!
//! Streams are *stateless*: op `i` of thread `t` is a pure function of
//! `(seed, t, i)` using the same splitmix64 chain as the L1 workload
//! kernel, so the pure-Rust generator and the AOT artifact produce
//! identical streams (checked by tests) and every run is reproducible.
//!
//! The paper's workloads: uniform keys over a range, the set pre-filled to
//! half the range, read fractions 50–100% (YCSB A/B/C at 50/95/100).

pub mod ycsb;
pub mod zipf;

use crate::util::mix64;

/// One generated operation.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Op {
    Contains(u64),
    Insert(u64),
    Remove(u64),
}

impl Op {
    pub fn key(&self) -> u64 {
        match *self {
            Op::Contains(k) | Op::Insert(k) | Op::Remove(k) => k,
        }
    }

    pub fn is_read(&self) -> bool {
        matches!(self, Op::Contains(_))
    }
}

/// Key distribution.
#[derive(Clone, Copy, PartialEq, Debug)]
pub enum KeyDist {
    Uniform,
    /// Zipfian with the given skew (YCSB default 0.99).
    Zipfian(f64),
}

/// Workload definition.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadSpec {
    /// Keys are drawn from `[0, key_range)`.
    pub key_range: u64,
    /// Reads per million ops (900_000 = the paper's default 90%).
    pub read_micros: u64,
    pub dist: KeyDist,
    pub seed: u64,
}

impl WorkloadSpec {
    pub fn uniform(key_range: u64, read_pct: u32, seed: u64) -> Self {
        WorkloadSpec {
            key_range,
            read_micros: read_pct as u64 * 10_000,
            dist: KeyDist::Uniform,
            seed,
        }
    }

    /// The contains-heavy skewed preset: 99% reads over a Zipfian(0.99)
    /// key distribution — the serving pattern the read fast path targets
    /// (hot-key lookups dominating wire traffic; YCSB-C-shaped with
    /// YCSB's default skew). Used by `bench --fig rwpath`'s highest read
    /// fraction.
    pub fn contains_heavy_zipf(key_range: u64, seed: u64) -> Self {
        WorkloadSpec {
            key_range,
            read_micros: 990_000,
            dist: KeyDist::Zipfian(0.99),
            seed,
        }
    }

    /// Stream for one thread. Matches `kernels/workload.py` exactly in the
    /// uniform case (same mix64 chain, same op thresholds).
    pub fn stream(&self, thread: u64) -> OpStream {
        OpStream {
            spec: *self,
            seed_mix: mix64(self.seed ^ thread.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            idx: 0,
            zipf: match self.dist {
                KeyDist::Zipfian(theta) => Some(zipf::Zipf::new(self.key_range, theta)),
                KeyDist::Uniform => None,
            },
        }
    }

    /// The stream the AOT workload artifact produces for `(seed, base)` —
    /// thread streams use `seed ^ t*phi` as the artifact seed.
    pub fn artifact_seed(&self, thread: u64) -> u64 {
        self.seed ^ thread.wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }
}

/// Infinite deterministic op stream.
pub struct OpStream {
    spec: WorkloadSpec,
    seed_mix: u64,
    idx: u64,
    zipf: Option<zipf::Zipf>,
}

impl OpStream {
    /// The `i`-th op of this stream (random access).
    pub fn op_at(&mut self, i: u64) -> Op {
        let h1 = mix64(i ^ self.seed_mix);
        let h2 = mix64(h1);
        let key = match &mut self.zipf {
            None => h1 % self.spec.key_range,
            Some(z) => z.sample(h1),
        };
        let draw = h2 % 1_000_000;
        if draw < self.spec.read_micros {
            Op::Contains(key)
        } else if (h2 >> 32) & 1 == 0 {
            Op::Insert(key)
        } else {
            Op::Remove(key)
        }
    }

    /// Next op (sequential use).
    #[inline]
    pub fn next_op(&mut self) -> Op {
        let op = self.op_at(self.idx);
        self.idx += 1;
        op
    }
}

/// Pre-fill a set with half the key range (every even key), the paper's
/// setup for a 50-50 insert/remove success split. Returns #inserted.
pub fn prefill(set: &dyn crate::sets::ConcurrentSet, key_range: u64) -> usize {
    let mut n = 0;
    for k in (0..key_range).step_by(2) {
        if set.insert(k, k) {
            n += 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_is_deterministic_and_in_range() {
        let spec = WorkloadSpec::uniform(1024, 90, 7);
        let mut a = spec.stream(3);
        let mut b = spec.stream(3);
        for i in 0..1000 {
            let (x, y) = (a.op_at(i), b.op_at(i));
            assert_eq!(x, y);
            assert!(x.key() < 1024);
        }
        let mut c = spec.stream(4);
        let diff = (0..1000).filter(|&i| a.op_at(i) != c.op_at(i)).count();
        assert!(diff > 900, "different threads must get different streams");
    }

    #[test]
    fn read_fraction_is_respected() {
        for pct in [50u32, 90, 95, 100] {
            let spec = WorkloadSpec::uniform(4096, pct, 11);
            let mut s = spec.stream(0);
            let n = 40_000;
            let reads = (0..n).filter(|&i| s.op_at(i).is_read()).count();
            let frac = reads as f64 / n as f64;
            assert!(
                (frac - pct as f64 / 100.0).abs() < 0.01,
                "pct={pct} got {frac}"
            );
        }
    }

    #[test]
    fn updates_split_evenly() {
        let spec = WorkloadSpec::uniform(4096, 50, 13);
        let mut s = spec.stream(0);
        let mut ins = 0;
        let mut rem = 0;
        for i in 0..40_000 {
            match s.op_at(i) {
                Op::Insert(_) => ins += 1,
                Op::Remove(_) => rem += 1,
                _ => {}
            }
        }
        let ratio = ins as f64 / (ins + rem) as f64;
        assert!((0.48..0.52).contains(&ratio), "insert/remove ratio {ratio}");
    }

    #[test]
    fn contains_heavy_zipf_preset_is_read_heavy_and_skewed() {
        let spec = WorkloadSpec::contains_heavy_zipf(10_000, 17);
        let mut s = spec.stream(0);
        let n = 40_000u64;
        let mut reads = 0usize;
        let mut counts = std::collections::HashMap::new();
        for i in 0..n {
            let op = s.op_at(i);
            if op.is_read() {
                reads += 1;
            }
            *counts.entry(op.key()).or_insert(0usize) += 1;
        }
        let frac = reads as f64 / n as f64;
        assert!((frac - 0.99).abs() < 0.005, "read fraction {frac}");
        // Zipf(0.99): the hottest key must dwarf the uniform expectation
        // (n / range = 4 hits).
        let hottest = counts.values().copied().max().unwrap();
        assert!(hottest > 200, "skew missing: hottest key seen {hottest} times");
        assert!(counts.len() < 9_000, "skew must concentrate the key mass");
    }

    #[test]
    fn prefill_half_range() {
        let set = crate::sets::new_hash(crate::sets::Family::Volatile, 64);
        let n = prefill(set.as_ref(), 100);
        assert_eq!(n, 50);
        assert_eq!(set.len_approx(), 50);
    }

    #[test]
    fn matches_workload_kernel_math() {
        // Mirror of kernels/workload.py: h1 = mix64(i ^ mix64(seed)).
        let spec = WorkloadSpec {
            key_range: 1000,
            read_micros: 900_000,
            dist: KeyDist::Uniform,
            seed: 42,
        };
        // artifact stream for thread t uses seed' = artifact_seed(t); the
        // rust stream hashes i ^ mix64(seed'), same as the kernel.
        let mut s = spec.stream(0);
        let seed_mix = crate::util::mix64(spec.artifact_seed(0));
        for i in 0..100u64 {
            let h1 = crate::util::mix64(i ^ seed_mix);
            let expect_key = h1 % 1000;
            assert_eq!(s.op_at(i).key(), expect_key);
        }
    }
}
