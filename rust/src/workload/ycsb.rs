//! YCSB workload presets (Cooper et al., SoCC'10), as referenced by the
//! paper's §6.1: A = 50% reads, B = 95% reads, C = 100% reads. Updates are
//! split evenly between inserts and removes (set semantics).

use super::{KeyDist, WorkloadSpec};

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum YcsbWorkload {
    A,
    B,
    C,
}

impl YcsbWorkload {
    pub fn read_pct(&self) -> u32 {
        match self {
            YcsbWorkload::A => 50,
            YcsbWorkload::B => 95,
            YcsbWorkload::C => 100,
        }
    }

    /// Uniform-key variant (the paper's configuration).
    pub fn uniform(&self, key_range: u64, seed: u64) -> WorkloadSpec {
        WorkloadSpec::uniform(key_range, self.read_pct(), seed)
    }

    /// Zipfian-key variant (YCSB's default request distribution).
    pub fn zipfian(&self, key_range: u64, seed: u64) -> WorkloadSpec {
        WorkloadSpec {
            key_range,
            read_micros: self.read_pct() as u64 * 10_000,
            dist: KeyDist::Zipfian(0.99),
            seed,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_uppercase().as_str() {
            "A" => Some(YcsbWorkload::A),
            "B" => Some(YcsbWorkload::B),
            "C" => Some(YcsbWorkload::C),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_have_paper_read_fractions() {
        assert_eq!(YcsbWorkload::A.read_pct(), 50);
        assert_eq!(YcsbWorkload::B.read_pct(), 95);
        assert_eq!(YcsbWorkload::C.read_pct(), 100);
        assert_eq!(YcsbWorkload::parse("a"), Some(YcsbWorkload::A));
        assert_eq!(YcsbWorkload::parse("x"), None);
    }

    #[test]
    fn zipfian_variant_samples_hot_keys() {
        let spec = YcsbWorkload::B.zipfian(10_000, 5);
        let mut s = spec.stream(0);
        let n = 20_000u64;
        let hot = (0..n).filter(|&i| s.op_at(i).key() < 100).count();
        assert!(hot as f64 / n as f64 > 0.2);
    }
}
