//! YCSB workload presets (Cooper et al., SoCC'10), as referenced by the
//! paper's §6.1: A = 50% reads, B = 95% reads, C = 100% reads. Updates are
//! split evenly between inserts and removes (set semantics).
//!
//! E is the *ordered-tier* preset: 95% short scans / 5% inserts. The
//! point-op streams (`WorkloadSpec`) cannot express a scan — `Op` is a
//! closed point-op enum — so the E mix has its own generator
//! ([`YcsbWorkload::scan_mix_at`], consumed by `bench --fig scan`), on
//! the same stateless mix64 chain as everything else.

use super::{KeyDist, WorkloadSpec};
use crate::util::mix64;

/// Longest scan YCSB-E draws (uniform in `1..=E_SCAN_LEN_MAX`).
pub const E_SCAN_LEN_MAX: usize = 100;

/// One op of the YCSB-E scan mix.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ScanMixOp {
    /// Return up to `len` keys strictly above `cursor` (the wire SCAN).
    Scan { cursor: u64, len: usize },
    Insert(u64),
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum YcsbWorkload {
    A,
    B,
    C,
    /// 95% short scans / 5% inserts (scan lengths uniform in 1..=100).
    E,
}

impl YcsbWorkload {
    pub fn read_pct(&self) -> u32 {
        match self {
            YcsbWorkload::A => 50,
            YcsbWorkload::B => 95,
            YcsbWorkload::C => 100,
            YcsbWorkload::E => 95,
        }
    }

    /// The `i`-th op of thread `t`'s YCSB-E stream: a pure function of
    /// `(seed, t, i)` like [`WorkloadSpec::stream`], so scan benchmarks
    /// are exactly reproducible. The read fraction decides scan vs
    /// insert; scan cursors draw uniform over the key range.
    pub fn scan_mix_at(&self, key_range: u64, seed: u64, thread: u64, i: u64) -> ScanMixOp {
        let seed_mix = mix64(seed ^ thread.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let h1 = mix64(i ^ seed_mix);
        let h2 = mix64(h1);
        let key = h1 % key_range;
        if h2 % 100 < self.read_pct() as u64 {
            let len = 1 + ((h2 >> 32) as usize % E_SCAN_LEN_MAX);
            ScanMixOp::Scan { cursor: key, len }
        } else {
            ScanMixOp::Insert(key)
        }
    }

    /// Uniform-key variant (the paper's configuration).
    pub fn uniform(&self, key_range: u64, seed: u64) -> WorkloadSpec {
        WorkloadSpec::uniform(key_range, self.read_pct(), seed)
    }

    /// Zipfian-key variant (YCSB's default request distribution).
    pub fn zipfian(&self, key_range: u64, seed: u64) -> WorkloadSpec {
        WorkloadSpec {
            key_range,
            read_micros: self.read_pct() as u64 * 10_000,
            dist: KeyDist::Zipfian(0.99),
            seed,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_uppercase().as_str() {
            "A" => Some(YcsbWorkload::A),
            "B" => Some(YcsbWorkload::B),
            "C" => Some(YcsbWorkload::C),
            "E" => Some(YcsbWorkload::E),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_have_paper_read_fractions() {
        assert_eq!(YcsbWorkload::A.read_pct(), 50);
        assert_eq!(YcsbWorkload::B.read_pct(), 95);
        assert_eq!(YcsbWorkload::C.read_pct(), 100);
        assert_eq!(YcsbWorkload::E.read_pct(), 95);
        assert_eq!(YcsbWorkload::parse("a"), Some(YcsbWorkload::A));
        assert_eq!(YcsbWorkload::parse("e"), Some(YcsbWorkload::E));
        assert_eq!(YcsbWorkload::parse("x"), None);
    }

    #[test]
    fn ycsb_e_mixes_short_scans_with_inserts_deterministically() {
        let n = 20_000u64;
        let mut scans = 0usize;
        for i in 0..n {
            let op = YcsbWorkload::E.scan_mix_at(10_000, 9, 0, i);
            assert_eq!(op, YcsbWorkload::E.scan_mix_at(10_000, 9, 0, i));
            match op {
                ScanMixOp::Scan { cursor, len } => {
                    scans += 1;
                    assert!(cursor < 10_000);
                    assert!((1..=E_SCAN_LEN_MAX).contains(&len), "len {len}");
                }
                ScanMixOp::Insert(k) => assert!(k < 10_000),
            }
        }
        let frac = scans as f64 / n as f64;
        assert!((frac - 0.95).abs() < 0.01, "scan fraction {frac}");
        let other = YcsbWorkload::E.scan_mix_at(10_000, 9, 1, 0);
        assert_ne!(other, YcsbWorkload::E.scan_mix_at(10_000, 9, 0, 0));
    }

    #[test]
    fn zipfian_variant_samples_hot_keys() {
        let spec = YcsbWorkload::B.zipfian(10_000, 5);
        let mut s = spec.stream(0);
        let n = 20_000u64;
        let hot = (0..n).filter(|&i| s.op_at(i).key() < 100).count();
        assert!(hot as f64 / n as f64 > 0.2);
    }
}
