//! durlint — the static half of durcheck (DESIGN.md §Checking).
//!
//! A deliberately conservative, std-only source scanner that mechanically
//! enforces the repo conventions ROADMAP.md §"Conventions that must hold"
//! used to enforce by reviewer discipline:
//!
//! * **R1 crash blast radius** — whole-process `pmem::crash(` appears only
//!   in single-purpose binaries (`src/bin/`, `examples/`); library code and
//!   tests must use the pool-scoped `pmem::crash_pools`.
//! * **R2 publish orderings** — no `Ordering::Relaxed` on mutations of the
//!   tagged durable/link words (`.next`, `.nexts[..]`, `.cells[..]`,
//!   `slot_gen(..)`) in `src/sets/` and `src/alloc/`. Recovery relink
//!   modules (single-threaded rebuild) and the volatile family are exempt,
//!   as is test code.
//! * **R3 crash-sim discipline** — every file that calls `crash_pools(`
//!   holds the global sim session (`sim_session`), which serializes armed
//!   crash windows across the test binary.
//! * **R4 fence-pin pairing** — every durable-family file carries a pinned
//!   fence/flush-count assertion (`.fences`) in its test module, so a
//!   persistency-protocol change cannot land without re-pinning budgets.
//! * **R5 allocator ownership** — raw region carving (`alloc_region(`,
//!   `alloc_region_with_hdr(`) appears only under `src/alloc/` and
//!   `src/pmem/`; everything else allocates through `DurablePool`, so
//!   every durable byte sits under an occupancy bitmap that recovery's
//!   classify scan rebuilds and compaction can migrate. Test code is
//!   exempt (harnesses may carve scratch regions).
//!
//! Findings are suppressed by `durlint.allow` (next to `Cargo.toml`):
//! one entry per line, `RULE <path-suffix> <line-substring…>`. Entries
//! that suppress nothing are themselves an error — the allowlist only
//! shrinks. Text-level scanning is the point: it cannot be silenced by
//! cfg tricks, and false positives are cheap to allowlist explicitly.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Durable-family files that must carry a pinned fence assertion (R4).
const FENCE_PINNED_FILES: &[&str] = &[
    "src/sets/linkfree/list.rs",
    "src/sets/linkfree/skiplist.rs",
    "src/sets/soft/list.rs",
    "src/sets/soft/skiplist.rs",
    "src/sets/logfree/list.rs",
    "src/sets/nvtraverse/list.rs",
    "src/sets/resizable.rs",
];

struct Finding {
    rule: &'static str,
    file: String,
    line: usize,
    text: String,
    msg: String,
}

struct Allow {
    rule: String,
    path_suffix: String,
    substring: String,
    used: std::cell::Cell<bool>,
}

fn main() -> ExitCode {
    // Root = argv[1] if given, else the crate dir baked in at build time
    // (CI builds and runs on the same checkout).
    let root = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    let allows = load_allowlist(&root.join("durlint.allow"));
    let mut files = Vec::new();
    for top in ["src", "tests"] {
        collect_rs(&root.join(top), &mut files);
    }
    files.sort();

    let mut findings = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let Ok(src) = fs::read_to_string(path) else {
            eprintln!("durlint: unreadable file {rel}");
            return ExitCode::FAILURE;
        };
        scan_file(&rel, &src, &mut findings);
    }

    let mut failed = 0usize;
    for f in &findings {
        let suppressed = allows.iter().any(|a| {
            a.rule == f.rule && f.file.ends_with(&a.path_suffix) && f.text.contains(&a.substring)
        });
        if suppressed {
            for a in &allows {
                if a.rule == f.rule
                    && f.file.ends_with(&a.path_suffix)
                    && f.text.contains(&a.substring)
                {
                    a.used.set(true);
                }
            }
            continue;
        }
        failed += 1;
        eprintln!("durlint: {} {}:{}: {}", f.rule, f.file, f.line, f.msg);
        eprintln!("    {}", f.text.trim());
    }
    for a in &allows {
        if !a.used.get() {
            failed += 1;
            eprintln!(
                "durlint: stale allowlist entry suppresses nothing: {} {} {}",
                a.rule, a.path_suffix, a.substring
            );
        }
    }
    if failed > 0 {
        eprintln!("durlint: {failed} finding(s) across {} files", files.len());
        ExitCode::FAILURE
    } else {
        println!("durlint: clean ({} files, {} allowlist entries)", files.len(), allows.len());
        ExitCode::SUCCESS
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for e in entries.flatten() {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
}

fn load_allowlist(path: &Path) -> Vec<Allow> {
    let Ok(src) = fs::read_to_string(path) else { return Vec::new() };
    src.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .filter_map(|l| {
            let mut it = l.splitn(3, char::is_whitespace);
            Some(Allow {
                rule: it.next()?.to_string(),
                path_suffix: it.next()?.to_string(),
                substring: it.next()?.trim().to_string(),
                used: std::cell::Cell::new(false),
            })
        })
        .collect()
}

/// First line (0-based) of the trailing `#[cfg(test)]` module, or EOF.
/// Conservative: everything from the first `#[cfg(test)]` on is test code.
fn test_region_start(lines: &[&str]) -> usize {
    lines
        .iter()
        .position(|l| l.trim_start().starts_with("#[cfg(test)]"))
        .unwrap_or(lines.len())
}

fn scan_file(rel: &str, src: &str, findings: &mut Vec<Finding>) {
    let lines: Vec<&str> = src.lines().collect();
    let tests_at = test_region_start(&lines);
    let in_bin = rel.starts_with("src/bin/") || rel.starts_with("examples/");
    let in_pmem = rel.starts_with("src/pmem/");
    let push = |findings: &mut Vec<Finding>, rule, line: usize, text: &str, msg: String| {
        findings.push(Finding {
            rule,
            file: rel.to_string(),
            line: line + 1,
            text: text.to_string(),
            msg,
        });
    };

    // R1: whole-process crash only in single-purpose bins (the definition
    // site in pmem is exempt).
    if !in_bin && !in_pmem {
        for (i, l) in lines.iter().enumerate() {
            if l.contains("pmem::crash(") {
                push(
                    findings,
                    "R1",
                    i,
                    l,
                    String::from(
                        "whole-process pmem::crash outside src/bin/ — use pmem::crash_pools",
                    ),
                );
            }
        }
    }

    // R2: relaxed mutations of tagged durable/link words in sets/ + alloc/.
    let r2_scope = (rel.starts_with("src/sets/") || rel.starts_with("src/alloc/"))
        && !rel.ends_with("/recovery.rs")
        && !rel.contains("/volatile/");
    if r2_scope {
        const WORDS: &[&str] = &[".next.", ".nexts[", ".cells[", "slot_gen("];
        const MUTS: &[&str] = &[".store(", ".compare_exchange", ".fetch_"];
        for (i, l) in lines.iter().enumerate().take(tests_at) {
            if l.contains("Ordering::Relaxed")
                && WORDS.iter().any(|w| l.contains(w))
                && MUTS.iter().any(|m| l.contains(m))
            {
                push(
                    findings,
                    "R2",
                    i,
                    l,
                    String::from(
                        "relaxed mutation of a tagged durable/link word — use Release (or allowlist)",
                    ),
                );
            }
        }
    }

    // R3: crash-sim callers must hold the global sim session.
    if !in_bin && !in_pmem && src.contains("crash_pools(") && !src.contains("sim_session") {
        push(
            findings,
            "R3",
            0,
            "",
            String::from("calls crash_pools without taking pmem::sim_session()"),
        );
    }

    // R4: durable-family files must pin fence budgets.
    if FENCE_PINNED_FILES.contains(&rel) && !src.contains(".fences") {
        push(
            findings,
            "R4",
            0,
            "",
            String::from("durable-family file without a pinned fence-count assertion"),
        );
    }

    // R5: raw region carving is the allocator's and pmem's business only.
    // Library code goes through DurablePool/VolatilePool so every durable
    // byte sits under an occupancy bitmap the recovery scan can rebuild;
    // a stray alloc_region elsewhere would be invisible to compaction and
    // the classify pass. Test code (tests/ and #[cfg(test)] tails) is
    // exempt — harnesses may carve scratch regions.
    let r5_scope = rel.starts_with("src/")
        && !rel.starts_with("src/alloc/")
        && !rel.starts_with("src/pmem/")
        && !in_bin;
    if r5_scope {
        for (i, l) in lines.iter().enumerate().take(tests_at) {
            if l.contains("alloc_region(") || l.contains("alloc_region_with_hdr(") {
                push(
                    findings,
                    "R5",
                    i,
                    l,
                    String::from(
                        "raw alloc_region outside src/alloc//src/pmem/ — allocate through DurablePool",
                    ),
                );
            }
        }
    }
}
