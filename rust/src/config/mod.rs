//! Configuration system: a typed schema over `key = value` files plus
//! CLI-style `key=value` overrides (no TOML/serde in the offline crate
//! set; the format is the subset every deployment tool can write).
//!
//! ```text
//! # durasets.conf
//! family      = soft        # link-free | soft | log-free | nvtraverse | volatile
//! structure   = hash        # hash | list | skiplist
//! shards      = 4
//! key_range   = 1048576
//! read_pct    = 90
//! psync_ns    = 100
//! port        = 7878
//! ```

use crate::sets::Family;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Which container shape the service uses.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Structure {
    Hash,
    List,
    /// Key-ordered skip list: the only structure serving `RANGE`/`SCAN`.
    SkipList,
}

impl Structure {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "hash" | "hashmap" | "hashset" => Some(Structure::Hash),
            "list" | "linkedlist" => Some(Structure::List),
            "skiplist" | "skip-list" | "skip_list" => Some(Structure::SkipList),
            _ => None,
        }
    }
}

/// Full service/benchmark configuration with defaults mirroring the
/// paper's hash-set evaluation (§6).
#[derive(Clone, Debug)]
pub struct Config {
    pub family: Family,
    pub structure: Structure,
    /// Number of coordinator shards (each owns one set instance).
    pub shards: usize,
    /// Key range; hash sets get `key_range / shards` buckets per shard
    /// (the paper's load factor 1).
    pub key_range: u64,
    pub read_pct: u32,
    pub threads: usize,
    /// Injected psync latency (ns); models clflush cost. 0 disables.
    pub psync_ns: u64,
    /// pmem mode: "perf" or "sim" (sim enables crash()).
    pub sim: bool,
    pub seed: u64,
    /// TCP port for `durasets serve`.
    pub port: u16,
    /// Max concurrent TCP connections, enforced by the acceptor across
    /// the reactor pool; 0 = unlimited. Excess connections are refused
    /// with an ERR line.
    pub max_conns: usize,
    /// Event-plane reactor workers serving all connections
    /// (DESIGN.md §ConnectionPlane), 1..=64. The default honors
    /// `DURASETS_EVENT_WORKERS` so CI can size the pool; unset, it is 2.
    pub event_workers: usize,
    /// Adaptive group commit: floor of a shard worker's drain bound
    /// (light load converges here — lowest commit latency).
    pub group_k_min: usize,
    /// Adaptive group commit: ceiling of the drain bound (saturated load
    /// converges here — widest fence amortization).
    pub group_k_max: usize,
    /// Benchmark phase length (milliseconds).
    pub duration_ms: u64,
    /// Zipfian skew; 0 = uniform.
    pub zipf_theta: f64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            family: Family::Soft,
            structure: Structure::Hash,
            shards: 1,
            key_range: 1 << 20,
            read_pct: 90,
            threads: 4,
            psync_ns: 100,
            sim: false,
            seed: 0xD0_5E7,
            port: 7878,
            max_conns: 1024,
            event_workers: std::env::var("DURASETS_EVENT_WORKERS")
                .ok()
                .and_then(|v| v.parse().ok())
                .filter(|n: &usize| (1..=64).contains(n))
                .unwrap_or(2),
            group_k_min: 1,
            group_k_max: 512,
            duration_ms: 1000,
            zipf_theta: 0.0,
        }
    }
}

impl Config {
    /// Parse a config file (ignored if `path` is None) and then apply
    /// `key=value` overrides in order.
    pub fn load(path: Option<&str>, overrides: &[String]) -> Result<Config> {
        let mut map = BTreeMap::new();
        if let Some(p) = path {
            let text = std::fs::read_to_string(p).map_err(|e| anyhow!("reading {p}: {e}"))?;
            for (lineno, line) in text.lines().enumerate() {
                let line = line.split('#').next().unwrap_or("").trim();
                if line.is_empty() {
                    continue;
                }
                let (k, v) = line
                    .split_once('=')
                    .ok_or_else(|| anyhow!("{p}:{}: expected key = value", lineno + 1))?;
                map.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        for ov in overrides {
            let (k, v) = ov
                .split_once('=')
                .ok_or_else(|| anyhow!("override '{ov}': expected key=value"))?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        let mut cfg = Config::default();
        for (k, v) in &map {
            cfg.set(k, v)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply one key.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "family" => {
                self.family =
                    Family::parse(value).ok_or_else(|| anyhow!("unknown family '{value}'"))?
            }
            "structure" => {
                self.structure =
                    Structure::parse(value).ok_or_else(|| anyhow!("unknown structure '{value}'"))?
            }
            "shards" => self.shards = value.parse()?,
            "key_range" => self.key_range = parse_u64_with_suffix(value)?,
            "read_pct" => self.read_pct = value.parse()?,
            "threads" => self.threads = value.parse()?,
            "psync_ns" => self.psync_ns = value.parse()?,
            "sim" => self.sim = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "port" => self.port = value.parse()?,
            "max_conns" => self.max_conns = value.parse()?,
            "event_workers" => self.event_workers = value.parse()?,
            "group_k_min" => self.group_k_min = value.parse()?,
            "group_k_max" => self.group_k_max = value.parse()?,
            "duration_ms" => self.duration_ms = value.parse()?,
            "zipf_theta" => self.zipf_theta = value.parse()?,
            _ => bail!("unknown config key '{key}'"),
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            bail!("shards must be >= 1");
        }
        if self.key_range == 0 {
            bail!("key_range must be >= 1");
        }
        if self.read_pct > 100 {
            bail!("read_pct must be <= 100");
        }
        if self.threads == 0 || self.threads > crate::util::MAX_THREADS - 8 {
            bail!("threads must be in 1..={}", crate::util::MAX_THREADS - 8);
        }
        if !(0.0..1.0).contains(&self.zipf_theta) {
            bail!("zipf_theta must be in [0, 1)");
        }
        if self.group_k_min == 0 || self.group_k_min > self.group_k_max {
            bail!("group_k_min must be in 1..=group_k_max");
        }
        if self.group_k_max > 4096 {
            bail!("group_k_max must be <= 4096");
        }
        if self.event_workers == 0 || self.event_workers > 64 {
            bail!("event_workers must be in 1..=64 (the legacy thread-per-connection plane is gone)");
        }
        if self.structure == Structure::SkipList
            && !matches!(self.family, Family::LinkFree | Family::Soft)
        {
            bail!("structure=skiplist requires family link-free or soft (no durable skip list for {})", self.family);
        }
        Ok(())
    }

    /// Buckets per shard at the paper's load factor 1.
    pub fn buckets_per_shard(&self) -> usize {
        ((self.key_range as usize / self.shards).max(1)).next_power_of_two()
    }

    /// Workload spec for this config.
    pub fn workload(&self) -> crate::workload::WorkloadSpec {
        let mut spec =
            crate::workload::WorkloadSpec::uniform(self.key_range, self.read_pct, self.seed);
        if self.zipf_theta > 0.0 {
            spec.dist = crate::workload::KeyDist::Zipfian(self.zipf_theta);
        }
        spec
    }

    /// Apply the pmem-level settings (mode + psync latency) globally.
    ///
    /// Only *enables* Sim mode; it never downgrades to Perf. The mode is a
    /// process-global, and a non-sim store created while a crash test (or
    /// another sim store) is live must not silently stop its shadowing —
    /// the seed did exactly that and made the crash suites flaky. Leaving
    /// Sim on merely costs a shadow copy per flush.
    pub fn apply_pmem(&self) {
        crate::pmem::set_psync_ns(self.psync_ns);
        if self.sim {
            crate::pmem::set_mode(crate::pmem::Mode::Sim);
        }
    }
}

/// `1048576`, `1M`, `64K`, `4m` etc.
fn parse_u64_with_suffix(s: &str) -> Result<u64> {
    let s = s.trim();
    let (num, mult) = match s.chars().last() {
        Some('k') | Some('K') => (&s[..s.len() - 1], 1024u64),
        Some('m') | Some('M') => (&s[..s.len() - 1], 1024 * 1024),
        Some('g') | Some('G') => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (s, 1),
    };
    Ok(num.trim().parse::<u64>()? * mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn parse_file_and_overrides() {
        let dir = std::env::temp_dir().join(format!("durasets-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.conf");
        std::fs::write(
            &path,
            "# comment\nfamily = link-free\nkey_range = 64K # inline comment\nshards=2\n",
        )
        .unwrap();
        let cfg = Config::load(Some(path.to_str().unwrap()), &["read_pct=95".into()]).unwrap();
        assert_eq!(cfg.family, Family::LinkFree);
        assert_eq!(cfg.key_range, 64 * 1024);
        assert_eq!(cfg.shards, 2);
        assert_eq!(cfg.read_pct, 95);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_values() {
        assert!(Config::load(None, &["family=quantum".into()]).is_err());
        assert!(Config::load(None, &["shards=0".into()]).is_err());
        assert!(Config::load(None, &["read_pct=101".into()]).is_err());
        assert!(Config::load(None, &["no_such_key=1".into()]).is_err());
        assert!(Config::load(None, &["zipf_theta=1.5".into()]).is_err());
    }

    #[test]
    fn max_conns_key_parses() {
        let cfg = Config::load(None, &["max_conns=2".into()]).unwrap();
        assert_eq!(cfg.max_conns, 2);
        assert_eq!(Config::default().max_conns, 1024);
        assert!(Config::load(None, &["max_conns=x".into()]).is_err());
    }

    #[test]
    fn event_workers_key_parses_and_validates() {
        let cfg = Config::load(None, &["event_workers=4".into()]).unwrap();
        assert_eq!(cfg.event_workers, 4);
        assert!(
            Config::load(None, &["event_workers=0".into()]).is_err(),
            "the legacy thread-per-connection plane was removed; 0 is no longer a plane selector"
        );
        assert!(Config::load(None, &["event_workers=65".into()]).is_err());
        assert!(Config::load(None, &["event_workers=x".into()]).is_err());
        // The default is env-driven (CI can size the pool), so assert
        // only that it is valid — not a specific number.
        let dflt = Config::default().event_workers;
        assert!((1..=64).contains(&dflt));
    }

    #[test]
    fn skiplist_structure_parses_and_gates_families() {
        for alias in ["skiplist", "skip-list", "skip_list", "SKIPLIST"] {
            assert_eq!(Structure::parse(alias), Some(Structure::SkipList));
        }
        let cfg = Config::load(None, &["structure=skiplist".into()]).unwrap();
        assert_eq!(cfg.structure, Structure::SkipList); // soft default: ok
        let cfg =
            Config::load(None, &["structure=skiplist".into(), "family=link-free".into()])
                .unwrap();
        assert_eq!(cfg.family, Family::LinkFree);
        for fam in ["log-free", "nvtraverse", "volatile"] {
            assert!(
                Config::load(
                    None,
                    &["structure=skiplist".into(), format!("family={fam}")],
                )
                .is_err(),
                "{fam} has no durable skip list and must be rejected"
            );
        }
    }

    #[test]
    fn group_k_keys_parse_and_validate() {
        let cfg =
            Config::load(None, &["group_k_min=4".into(), "group_k_max=64".into()]).unwrap();
        assert_eq!(cfg.group_k_min, 4);
        assert_eq!(cfg.group_k_max, 64);
        assert_eq!(Config::default().group_k_min, 1);
        assert_eq!(Config::default().group_k_max, 512);
        assert!(Config::load(None, &["group_k_min=0".into()]).is_err());
        assert!(
            Config::load(None, &["group_k_min=64".into(), "group_k_max=8".into()]).is_err(),
            "min above max must be rejected"
        );
        assert!(Config::load(None, &["group_k_max=100000".into()]).is_err());
    }

    #[test]
    fn suffix_parsing() {
        assert_eq!(parse_u64_with_suffix("10").unwrap(), 10);
        assert_eq!(parse_u64_with_suffix("4K").unwrap(), 4096);
        assert_eq!(parse_u64_with_suffix("1M").unwrap(), 1 << 20);
        assert!(parse_u64_with_suffix("x").is_err());
    }

    #[test]
    fn buckets_per_shard_load_factor_one() {
        let mut cfg = Config::default();
        cfg.key_range = 1 << 20;
        cfg.shards = 4;
        assert_eq!(cfg.buckets_per_shard(), 1 << 18);
    }
}
