//! Minimal CLI argument handling (no clap in the offline crate set).
//!
//! Grammar: `durasets <command> [--config FILE] [--flag value]... [key=value]...`
//! `--flag value` pairs and bare `key=value` tokens both become config
//! overrides; command-specific flags are read via [`Args::flag`].

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    /// `key=value` config overrides, in order.
    pub overrides: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut it = argv.into_iter();
        let mut args = Args { command: it.next().unwrap_or_default(), ..Default::default() };
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let Some(value) = it.next() else {
                    bail!("flag --{name} expects a value");
                };
                args.flags.insert(name.to_string(), value);
            } else if tok.contains('=') {
                args.overrides.push(tok);
            } else {
                bail!("unexpected argument '{tok}' (expected --flag value or key=value)");
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    /// Load the config honoring `--config` plus all `key=value` overrides.
    pub fn config(&self) -> Result<crate::config::Config> {
        crate::config::Config::load(self.flag("config"), &self.overrides)
    }
}

pub const USAGE: &str = "\
durasets — efficient lock-free durable sets (OOPSLA'19 reproduction)

USAGE:
  durasets <command> [--config FILE] [--flag value]... [key=value]...

COMMANDS:
  serve         run the sharded durable KV service (TCP line protocol)
  bench         regenerate a paper figure:
                --fig 1a|1b|1c|2a|2b|3a|3b|3c|psync|batch|recovery|rwpath|scan|connscale|alloc|fences|all
                --json FILE writes machine-readable data points
                --fig recovery sweeps rebuild wall-clock over recovery
                threads x pool sizes (--keys N, or DURASETS_RECOVERY_KEYS
                as a comma list; DURASETS_FULL=1 adds a 1M-node pool)
                --fig rwpath sweeps the served two-lane path: read
                fraction {50,90,99} x pipeline depth, reporting read-lane
                psyncs (pinned 0) and the adaptive-K gauge per point
                --fig scan sweeps the ordered tier: scan length {1,16,100}
                x burst depth {1,16,128} per skip-list family, reporting
                merge-walk vs N-probe speedup and scan-lane psyncs
                (pinned 0)
                --fig connscale sweeps live connections x active fraction
                over the event plane, reporting RSS/threads per point
                (smoke sizes by default; DURASETS_FULL=1 goes to 10k)
                --fig alloc runs the allocator lifecycle per durable
                family: fill (1M under DURASETS_FULL) -> delete 90% ->
                maintain to steady state -> Zipf churn, reporting areas
                returned, RSS delta and the alloc-path psync meter
                (pinned 0)
                --fig fences runs the fences/op ablation: all four
                durable families x {insert-heavy, zipf-mixed,
                contains-heavy, batch K in {1,64}, traversal-zipf-miss},
                reporting fences/op, flushes/op, elided/op and the
                NVTraverse-below-link-free traversal verdict (CI-gated)
  crash-test    run ops, crash (sim), recover, verify — end to end
  recover-demo  build a store, crash it, time rust vs XLA-accelerated recovery
  workload      print a sample of the deterministic op stream
  help          this text

PROTOCOL (serve): PUT/GET/HAS/DEL/RANGE/SCAN/LEN/STATS/QUIT. Updates
  are group-committed per shard (adaptive K; see STATS adaptk=[..]);
  pipelined pure reads (GET/HAS) run on a psync-free direct path.
  RANGE <lo> <hi> and SCAN <cursor> <n> (skiplist stores only) return a
  count header then <key> <value> lines in key order; SCAN is cursor-
  exclusive — page by passing the last key of the previous page (key 0
  is reachable via RANGE). A burst of ordered reads resolves as one
  merge-walk per shard, after the connection's writes drain (read-your-
  writes). MULTI <n> + n ops + EXEC frames an explicit batch;
  MULTI <n> ATOMIC makes the frame an atomic cross-shard batch
  (all-or-nothing under crashes).

CONFIG KEYS (file or key=value):
  family=soft|link-free|log-free|nvtraverse|volatile   structure=hash|list|skiplist
  (skiplist requires family soft or link-free; serves RANGE/SCAN)
  shards=N  key_range=N[K|M]  read_pct=0..100  threads=N
  psync_ns=N  sim=true|false  seed=N  port=N  max_conns=N  duration_ms=N
  zipf_theta=F  group_k_min=N  group_k_max=N  event_workers=N (1..=64)

EXAMPLES:
  durasets serve family=soft shards=4 key_range=1M port=7878 max_conns=512
  durasets serve family=link-free structure=skiplist shards=2 port=7878
  durasets bench --fig rwpath --json BENCH_rwpath.json
  durasets bench --fig scan --json BENCH_scan.json
  durasets crash-test family=link-free key_range=64K
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args> {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_flags_and_overrides() {
        let a = parse("bench --fig 1c family=soft threads=8").unwrap();
        assert_eq!(a.command, "bench");
        assert_eq!(a.flag("fig"), Some("1c"));
        assert_eq!(a.overrides, vec!["family=soft", "threads=8"]);
    }

    #[test]
    fn rejects_dangling_flag_and_garbage() {
        assert!(parse("bench --fig").is_err());
        assert!(parse("bench loosetoken").is_err());
    }

    #[test]
    fn config_integration() {
        let a = parse("serve family=link-free shards=2").unwrap();
        let cfg = a.config().unwrap();
        assert_eq!(cfg.family, crate::sets::Family::LinkFree);
        assert_eq!(cfg.shards, 2);
    }
}
