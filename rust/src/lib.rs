//! # durasets
//!
//! Production-shaped reproduction of **“Efficient Lock-Free Durable
//! Sets”** (Zuriel, Friedman, Sheffi, Cohen, Petrank — OOPSLA 2019):
//! lock-free, durably-linearizable sets for non-volatile memory.
//!
//! The crate provides:
//!
//! * [`pmem`] — a simulated persistent-memory substrate (durable regions,
//!   metered `psync`, adversarial crash/recovery semantics);
//! * [`alloc`] — the ssmem-style durable-area allocator + epoch-based
//!   reclamation of paper §5;
//! * [`sets`] — the paper's **link-free** and **SOFT** lists and hash
//!   sets, the **log-free** baseline (David et al., ATC'18) and a
//!   volatile Harris baseline, all behind one [`sets::ConcurrentSet`]
//!   trait, plus the recovery procedures;
//! * [`runtime`] — PJRT loading/execution of the AOT-compiled JAX/Pallas
//!   recovery-analytics and workload kernels (`artifacts/*.hlo.txt`);
//! * [`coordinator`] — a sharded durable key-value service built on the
//!   sets (router, shard workers, TCP server, crash/recovery
//!   orchestration, metrics);
//! * [`workload`] / [`bench`] — the workload engine and the harness that
//!   regenerates every figure of the paper's evaluation (§6).
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for measured
//! results.

pub mod alloc;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod pmem;
pub mod runtime;
pub mod sets;
pub mod util;
pub mod workload;
