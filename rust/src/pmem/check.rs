//! durcheck — the online persistency-order checker (DESIGN.md §Checking).
//!
//! A per-cacheline state machine over the simulated durable regions:
//!
//! ```text
//!   Clean (absent) --store--> Dirty --flush--> Flushed --fence--> Clean
//! ```
//!
//! Protocol code reports three event kinds through tiny hooks: *stores*
//! of durable payload words (`note_store`, placed at the family-level
//! mutation sites), *publishes* that make a durable line reachable
//! (`note_publish`, placed at link CASes / state transitions), and the
//! pmem layer itself reports flushes and fences from inside `flush_line`
//! / `psync` / `fence`. From those events the checker detects:
//!
//! * **DurabilityRace** — an ack boundary (group-commit scatter, txn
//!   commit, read-lane reply) depends on a durable store of the acking
//!   thread that is still Dirty (never flushed) or Flushed-but-unfenced.
//!   Asserted via [`assert_persisted`] at every ack point.
//! * **UnfencedPublish** — a durable line made reachable while still
//!   Dirty. (Flushed-unfenced publishes are legal under the sim cost
//!   model: a flush is durable at issue, the fence orders the *ack*; a
//!   `PsyncScope` batch flushes per op and fences once before acking.)
//! * **RedundantFlush** — a flush of a line whose content already equals
//!   its shadow (persisted image). A perf lint, not a hard failure:
//!   racing helpers legitimately double-flush (both observed the
//!   unflushed state), so it is a counter + capped sample log, pinned to
//!   zero only by the single-threaded fast-path tests.
//!
//! Dirty-vs-clean is decided by *content diff* against the region shadow,
//! not by write interception. That makes idempotent helping stores
//! (`make_valid`, SOFT `create`/`destroy` races — everyone stores the
//! same value) self-cleaning, and it lets deliberately-volatile metadata
//! riding durable lines (log-free DIRTY tag clears, link-free flush
//! flags) stay simply *unhooked*: the map, not the raw bytes, is what ack
//! assertions consult.
//!
//! Epochs close the store→flush→store→fence gap: every dirtying store
//! bumps the line's epoch, a flush records the epoch it covered, and a
//! fence only discharges obligations up to that epoch — a re-store after
//! the flush keeps the line (and the storing thread's outstanding set)
//! dirty through the fence.
//!
//! Arming: the checker observes only in [`Mode::Sim`] (Perf mode has no
//! shadow to diff against), and only when a [`session`] is active or the
//! `DURCHECK=1` environment variable is set (the CI tier-1 gate). With
//! the `durcheck` cargo feature off every hook compiles to nothing.
//! Under env arming with no session ("strict" mode) an `UnfencedPublish`
//! panics at the detection site; inside a session violations collect for
//! inspection via [`release_check`] / [`take_violations`] — that is what
//! the negative-control suite uses to prove the checker fires.

use super::region::{find_region, REGISTRY};
use super::Mode;
use crate::util::{line_down, tid::tid, CACHE_LINE, MAX_THREADS};
use crossbeam_utils::CachePadded;
use once_cell::sync::Lazy;
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;

/// Map shards (line-keyed): Clean lines are *absent*, so the map only
/// ever holds in-flight Dirty/Flushed lines and stays small.
const NSHARDS: usize = 64;

/// Cap on the retained violation / redundant-sample logs.
const LOG_CAP: usize = 256;

#[derive(Clone, Copy)]
struct Entry {
    /// Monotone per-line store epoch: bumped on every dirtying store.
    epoch: u64,
    /// The latest content reached the shadow (awaiting a fence).
    flushed: bool,
}

static MAP: Lazy<Box<[Mutex<HashMap<usize, Entry>>]>> =
    Lazy::new(|| (0..NSHARDS).map(|_| Mutex::new(HashMap::new())).collect());

#[inline]
fn shard(line: usize) -> std::sync::MutexGuard<'static, HashMap<usize, Entry>> {
    MAP[(line / CACHE_LINE) % NSHARDS].lock().unwrap_or_else(|e| e.into_inner())
}

thread_local! {
    /// Lines this thread dirtied, with the epoch of its last store: the
    /// obligations [`assert_persisted`] checks at an ack boundary.
    static OUT: RefCell<HashMap<usize, u64>> = RefCell::new(HashMap::new());
    /// Flushes this thread issued since its last fence: `(line, epoch)`.
    static PENDING: RefCell<Vec<(usize, u64)>> = RefCell::new(Vec::new());
}

struct Slot {
    events: AtomicU64,
    violations: AtomicU64,
    redundant: AtomicU64,
}

static SLOTS: Lazy<Box<[CachePadded<Slot>]>> = Lazy::new(|| {
    (0..MAX_THREADS)
        .map(|_| {
            CachePadded::new(Slot {
                events: AtomicU64::new(0),
                violations: AtomicU64::new(0),
                redundant: AtomicU64::new(0),
            })
        })
        .collect()
});

static SESSIONS: AtomicU32 = AtomicU32::new(0);

static LOG: Lazy<Mutex<Vec<Violation>>> = Lazy::new(|| Mutex::new(Vec::new()));
static REDUNDANT_LOG: Lazy<Mutex<Vec<Violation>>> = Lazy::new(|| Mutex::new(Vec::new()));

fn env_armed() -> bool {
    static ENV: Lazy<bool> = Lazy::new(|| {
        std::env::var("DURCHECK")
            .map(|v| v == "1" || v.eq_ignore_ascii_case("on"))
            .unwrap_or(false)
    });
    *ENV
}

/// Whether the checker is currently observing events. Requires the
/// `durcheck` feature, sim mode, and a [`session`] or `DURCHECK=1`.
#[inline(always)]
pub fn armed() -> bool {
    if !cfg!(feature = "durcheck") {
        return false;
    }
    (SESSIONS.load(Ordering::Relaxed) > 0 || env_armed()) && super::mode() == Mode::Sim
}

/// Strict mode: env-armed with no collecting session — a detected
/// `UnfencedPublish` panics at the site instead of queueing.
fn strict() -> bool {
    env_armed() && SESSIONS.load(Ordering::Relaxed) == 0
}

/// What the checker found.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ViolationKind {
    /// An ack boundary depended on an unpersisted durable store.
    /// `flushed = false`: never flushed; `true`: flushed but unfenced.
    DurabilityRace { flushed: bool },
    /// A Dirty durable line was made reachable before its flush.
    UnfencedPublish,
    /// A flush of an already-clean line (sample-log entries only; the
    /// hard signal is the `redundant_flushes` counter).
    RedundantFlush,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    pub kind: ViolationKind,
    /// Line (or word) address the violation anchors to.
    pub addr: usize,
    pub ctx: String,
}

/// Checker counter snapshot (see also [`thread_snapshot`] for pins).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CheckStats {
    pub events: u64,
    pub violations: u64,
    pub redundant_flushes: u64,
}

impl CheckStats {
    pub fn since(&self, earlier: &CheckStats) -> CheckStats {
        CheckStats {
            events: self.events - earlier.events,
            violations: self.violations - earlier.violations,
            redundant_flushes: self.redundant_flushes - earlier.redundant_flushes,
        }
    }
}

/// Sum of all threads' checker counters (the `STATS check=[..]` gauge).
pub fn snapshot() -> CheckStats {
    let mut out = CheckStats::default();
    for s in SLOTS.iter() {
        out.events += s.events.load(Ordering::Relaxed);
        out.violations += s.violations.load(Ordering::Relaxed);
        out.redundant_flushes += s.redundant.load(Ordering::Relaxed);
    }
    out
}

/// Calling thread's counters only — exact deltas for the
/// `redundant_flushes == 0` fast-path pins, immune to parallel tests.
pub fn thread_snapshot() -> CheckStats {
    let s = &SLOTS[tid()];
    CheckStats {
        events: s.events.load(Ordering::Relaxed),
        violations: s.violations.load(Ordering::Relaxed),
        redundant_flushes: s.redundant.load(Ordering::Relaxed),
    }
}

/// RAII arming for tests: collects violations instead of panicking.
/// Requires sim mode (take `pmem::sim_session()` first — it also
/// serializes armed sessions across the test binary).
pub struct CheckSession {
    _not_send: std::marker::PhantomData<*const ()>,
}

pub fn session() -> CheckSession {
    assert!(cfg!(feature = "durcheck"), "the durcheck feature is compiled out");
    assert_eq!(
        super::mode(),
        Mode::Sim,
        "durcheck sessions require sim mode (take pmem::sim_session() first)"
    );
    SESSIONS.fetch_add(1, Ordering::SeqCst);
    CheckSession { _not_send: std::marker::PhantomData }
}

impl Drop for CheckSession {
    fn drop(&mut self) {
        if SESSIONS.fetch_sub(1, Ordering::SeqCst) == 1 && !env_armed() {
            // Last session out: drop all in-flight state so the next
            // armed window starts from a clean map.
            for m in MAP.iter() {
                m.lock().unwrap_or_else(|e| e.into_inner()).clear();
            }
            LOG.lock().unwrap_or_else(|e| e.into_inner()).clear();
            REDUNDANT_LOG.lock().unwrap_or_else(|e| e.into_inner()).clear();
            let _ = OUT.try_with(|o| o.borrow_mut().clear());
            let _ = PENDING.try_with(|p| p.borrow_mut().clear());
        }
    }
}

/// Working-vs-shadow content diff of one line. `None`: not durable memory.
fn line_clean(line: usize) -> Option<bool> {
    let reg = REGISTRY.read().unwrap_or_else(|e| e.into_inner());
    let r = find_region(&reg, line)?;
    let off = line - r.base;
    unsafe {
        for w in (0..CACHE_LINE).step_by(8) {
            let a = &*((line + w) as *const AtomicU64);
            let b = &*(r.shadow.add(off + w) as *const AtomicU64);
            if a.load(Ordering::Relaxed) != b.load(Ordering::Relaxed) {
                return Some(false);
            }
        }
    }
    Some(true)
}

/// Report a store of durable payload at `ptr` (one line).
#[inline]
pub fn note_store(ptr: *const u8) {
    if !armed() {
        return;
    }
    note_line_store(line_down(ptr as usize));
}

/// Report a store of durable payload spanning `[ptr, ptr + len)`.
#[inline]
pub fn note_store_range(ptr: *const u8, len: usize) {
    if !armed() || len == 0 {
        return;
    }
    let mut line = line_down(ptr as usize);
    let last = line_down(ptr as usize + len - 1);
    while line <= last {
        note_line_store(line);
        line += CACHE_LINE;
    }
}

fn note_line_store(line: usize) {
    let Some(clean) = line_clean(line) else { return };
    SLOTS[tid()].events.fetch_add(1, Ordering::Relaxed);
    if clean {
        // Idempotent store (racy helping) or content revert: the line
        // equals its persisted image, so no obligation remains.
        shard(line).remove(&line);
        let _ = OUT.try_with(|o| o.borrow_mut().remove(&line));
        return;
    }
    let ep = {
        let mut m = shard(line);
        let e = m.entry(line).or_insert(Entry { epoch: 0, flushed: false });
        e.epoch += 1;
        e.flushed = false;
        e.epoch
    };
    let _ = OUT.try_with(|o| o.borrow_mut().insert(line, ep));
}

/// Hook (pmem-internal): a line flush is about to copy working → shadow.
/// Must run *before* the shadow copy — the diff decides redundancy.
#[inline]
pub(crate) fn note_flush(ptr: *const u8) {
    if !armed() {
        return;
    }
    let line = line_down(ptr as usize);
    let Some(clean) = line_clean(line) else { return };
    let s = &SLOTS[tid()];
    s.events.fetch_add(1, Ordering::Relaxed);
    if clean {
        s.redundant.fetch_add(1, Ordering::Relaxed);
        let mut log = REDUNDANT_LOG.lock().unwrap_or_else(|e| e.into_inner());
        if log.len() < LOG_CAP {
            log.push(Violation {
                kind: ViolationKind::RedundantFlush,
                addr: line,
                ctx: String::from("flush of a clean line"),
            });
        }
        drop(log);
        shard(line).remove(&line);
        return;
    }
    let ep = {
        let mut m = shard(line);
        let e = m.entry(line).or_insert(Entry { epoch: 1, flushed: false });
        e.flushed = true;
        e.epoch
    };
    let _ = PENDING.try_with(|p| p.borrow_mut().push((line, ep)));
}

/// Hook (pmem-internal): the calling thread executed a real (non-elided)
/// fence — its pending flushes become persisted up to their epochs.
#[inline]
pub(crate) fn note_fence() {
    if !cfg!(feature = "durcheck") {
        return;
    }
    let _ = PENDING.try_with(|p| {
        let mut p = p.borrow_mut();
        if !armed() {
            p.clear();
            return;
        }
        for (line, ep) in p.drain(..) {
            {
                let mut m = shard(line);
                if let Some(e) = m.get(&line) {
                    if e.flushed && e.epoch <= ep {
                        m.remove(&line);
                    }
                }
            }
            let _ = OUT.try_with(|o| {
                let mut o = o.borrow_mut();
                if o.get(&line).is_some_and(|&my| my <= ep) {
                    o.remove(&line);
                }
            });
        }
    });
}

/// Report that a durable line was made reachable (link CAS, state-word
/// publish). Dirty at publish = **UnfencedPublish**; Flushed-unfenced is
/// legal (see the module docs).
#[inline]
pub fn note_publish(ptr: *const u8) {
    if !armed() {
        return;
    }
    let line = line_down(ptr as usize);
    let dirty = shard(line).get(&line).map(|e| !e.flushed).unwrap_or(false);
    SLOTS[tid()].events.fetch_add(1, Ordering::Relaxed);
    if dirty {
        record_violation(Violation {
            kind: ViolationKind::UnfencedPublish,
            addr: ptr as usize,
            ctx: String::from("durable line published before its flush"),
        });
    }
}

/// Report that `[ptr, ptr + len)` was freed back to its allocator: an
/// unreachable slot forfeits its durability obligations (a failed insert
/// legitimately frees a written-but-never-flushed node).
#[inline]
pub fn note_freed(ptr: *const u8, len: usize) {
    if !armed() {
        return;
    }
    let mut line = line_down(ptr as usize);
    let last = line_down(ptr as usize + len.max(1) - 1);
    while line <= last {
        shard(line).remove(&line);
        let _ = OUT.try_with(|o| o.borrow_mut().remove(&line));
        line += CACHE_LINE;
    }
}

/// Hook (pmem-internal): `[base, base + len)` became identical to its
/// shadow wholesale (bulk region persist, crash revert) — drop every
/// tracked line in the range.
pub(crate) fn purge_range(base: usize, len: usize) {
    if !armed() || len == 0 {
        return;
    }
    let end = base + len;
    for m in MAP.iter() {
        m.lock().unwrap_or_else(|e| e.into_inner()).retain(|&line, _| line < base || line >= end);
    }
}

fn record_violation(v: Violation) {
    SLOTS[tid()].violations.fetch_add(1, Ordering::Relaxed);
    if strict() {
        panic!("durcheck: {v:?}");
    }
    let mut log = LOG.lock().unwrap_or_else(|e| e.into_inner());
    if log.len() < LOG_CAP {
        log.push(v);
    }
}

/// Drain the collected (non-ack) violation log.
pub fn take_violations() -> Vec<Violation> {
    std::mem::take(&mut *LOG.lock().unwrap_or_else(|e| e.into_inner()))
}

/// Drain the redundant-flush sample log.
pub fn take_redundant_samples() -> Vec<Violation> {
    std::mem::take(&mut *REDUNDANT_LOG.lock().unwrap_or_else(|e| e.into_inner()))
}

/// Non-panicking ack check: drain the calling thread's outstanding
/// stores against the map, plus any queued violations. Empty = every
/// durable store this thread authored is flushed *and* fenced (or its
/// line was legitimately discharged — freed, crash-reverted, or fenced
/// by the thread that overwrote it).
pub fn release_check(ctx: &str) -> Vec<Violation> {
    if !armed() {
        let _ = OUT.try_with(|o| o.borrow_mut().clear());
        return Vec::new();
    }
    let mut found = take_violations();
    let _ = OUT.try_with(|o| {
        for (line, my_ep) in o.borrow_mut().drain() {
            let state = shard(line).get(&line).map(|e| (e.flushed, e.epoch));
            if let Some((flushed, ep)) = state {
                if ep >= my_ep {
                    SLOTS[tid()].violations.fetch_add(1, Ordering::Relaxed);
                    found.push(Violation {
                        kind: ViolationKind::DurabilityRace { flushed },
                        addr: line,
                        ctx: format!(
                            "{ctx}: acked store is {}",
                            if flushed { "flushed but unfenced" } else { "not flushed" }
                        ),
                    });
                }
            }
        }
    });
    found
}

/// The ack-boundary assertion (ISSUE 8 API): panic if any durable store
/// the acking thread authored is still unpersisted, or a violation is
/// queued. Called at every ack point — group-commit scatter, txn commit,
/// read-/scan-lane replies. No-op when the checker is disarmed.
pub fn assert_persisted(ctx: &str) {
    if !armed() {
        return;
    }
    let found = release_check(ctx);
    assert!(
        found.is_empty(),
        "durcheck: {} persistency violation(s) at ack boundary '{ctx}': {:#?}",
        found.len(),
        found
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem;
    use std::sync::atomic::Ordering;

    // All tests drive the state machine through a root cell (a real
    // registered durable region, so diffs have a shadow to compare
    // against) under the global sim session.

    #[test]
    fn store_flush_fence_cycle_is_clean_and_second_flush_is_redundant() {
        let _sim = pmem::sim_session();
        let _c = session();
        let cell = pmem::root::root_cell("durcheck.test.cycle");
        let before = thread_snapshot();
        cell.word().fetch_add(1, Ordering::SeqCst);
        note_store(cell.word() as *const _ as *const u8);
        cell.persist(); // flush + fence: discharges the obligation
        assert!(release_check("test").is_empty(), "persisted store must release");
        let d = thread_snapshot().since(&before);
        assert_eq!(d.redundant_flushes, 0, "first persist is genuine");
        // Persisting again without a store flushes a clean line.
        cell.persist();
        let d = thread_snapshot().since(&before);
        assert_eq!(d.redundant_flushes, 1, "clean-line flush must count");
        assert!(release_check("test").is_empty());
    }

    #[test]
    fn missing_flush_is_a_durability_race() {
        let _sim = pmem::sim_session();
        let _c = session();
        let cell = pmem::root::root_cell("durcheck.test.noflush");
        cell.word().fetch_add(1, Ordering::SeqCst);
        note_store(cell.word() as *const _ as *const u8);
        pmem::fence(); // fence without flush persists nothing
        let v = release_check("test");
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].kind, ViolationKind::DurabilityRace { flushed: false });
        // The line is still dirty; clean up for later tests.
        cell.persist();
        assert!(release_check("test").is_empty());
    }

    #[test]
    fn missing_fence_is_a_durability_race() {
        let _sim = pmem::sim_session();
        let _c = session();
        let cell = pmem::root::root_cell("durcheck.test.nofence");
        cell.word().fetch_add(1, Ordering::SeqCst);
        note_store(cell.word() as *const _ as *const u8);
        pmem::flush_line(cell.word() as *const _ as *const u8);
        let v = release_check("test");
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].kind, ViolationKind::DurabilityRace { flushed: true });
        pmem::fence();
    }

    #[test]
    fn publish_of_dirty_line_is_unfenced_publish() {
        let _sim = pmem::sim_session();
        let _c = session();
        let cell = pmem::root::root_cell("durcheck.test.pub");
        cell.word().fetch_add(1, Ordering::SeqCst);
        let p = cell.word() as *const _ as *const u8;
        note_store(p);
        note_publish(p); // reachable before any flush
        let v = take_violations();
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].kind, ViolationKind::UnfencedPublish);
        cell.persist();
        assert!(release_check("test").is_empty());
        // Flushed-unfenced publish is legal (PsyncScope batching).
        cell.word().fetch_add(1, Ordering::SeqCst);
        note_store(p);
        pmem::flush_line(p);
        note_publish(p);
        assert!(take_violations().is_empty(), "flushed publish must pass");
        pmem::fence();
        assert!(release_check("test").is_empty());
    }

    #[test]
    fn restore_after_flush_keeps_the_obligation_through_the_fence() {
        let _sim = pmem::sim_session();
        let _c = session();
        let cell = pmem::root::root_cell("durcheck.test.epoch");
        let p = cell.word() as *const _ as *const u8;
        cell.word().fetch_add(1, Ordering::SeqCst);
        note_store(p);
        pmem::flush_line(p);
        // Re-dirty after the flush but before the fence: the earlier
        // flush must not discharge the newer store.
        cell.word().fetch_add(1, Ordering::SeqCst);
        note_store(p);
        pmem::fence();
        let v = release_check("test");
        assert_eq!(v.len(), 1, "epoch gap must be caught: {v:?}");
        cell.persist();
        assert!(release_check("test").is_empty());
    }

    #[test]
    fn idempotent_helping_store_leaves_no_obligation() {
        let _sim = pmem::sim_session();
        let _c = session();
        let cell = pmem::root::root_cell("durcheck.test.idem");
        let p = cell.word() as *const _ as *const u8;
        cell.word().store(42, Ordering::SeqCst);
        note_store(p);
        cell.persist();
        // A helper re-stores the identical value: content equals the
        // shadow, so the note must not create an obligation.
        cell.word().store(42, Ordering::SeqCst);
        note_store(p);
        assert!(release_check("test").is_empty(), "idempotent store must self-clean");
    }

    #[test]
    fn freed_lines_forfeit_obligations() {
        let _sim = pmem::sim_session();
        let _c = session();
        let cell = pmem::root::root_cell("durcheck.test.freed");
        let p = cell.word() as *const _ as *const u8;
        cell.word().fetch_add(1, Ordering::SeqCst);
        note_store(p);
        note_freed(p, 8); // e.g. a failed insert returning its slot
        assert!(release_check("test").is_empty());
        cell.persist(); // re-sync content so later tests start clean
    }

    #[test]
    fn disarmed_hooks_are_noops() {
        // No session, no env: every hook must return without effect.
        let p = 0xdead_beefusize as *const u8;
        if armed() {
            return; // DURCHECK=1 run: strict CI mode, skip
        }
        note_store(p);
        note_publish(p);
        note_freed(p, 64);
        assert!(release_check("noop").is_empty());
        assert_persisted("noop");
    }
}
