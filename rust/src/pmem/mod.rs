//! Simulated persistent memory (the paper's NVRAM substrate).
//!
//! The paper evaluates on DRAM, assumes stores become durable once they
//! reach the memory controller, and uses `clflush` (+ implied ordering) as
//! its `psync`. Real NVRAM and `clflush`-visible persistence do not exist
//! in this environment, so this module builds the closest synthetic
//! equivalent that exercises the same code paths (see DESIGN.md
//! §Substitutions):
//!
//! * **Durable areas are registered regions.** Every byte the algorithms
//!   are allowed to treat as persistent lives in a region allocated through
//!   [`region`], grouped by [`PoolId`] (one pool per structure instance).
//! * **`psync` is metered.** Each call bumps per-thread flush/fence
//!   counters ([`stats`]) and optionally busy-waits a calibrated
//!   `psync_ns` to model write-back latency, so psync-bound regimes are
//!   visible even without persistence hardware.
//! * **Crash semantics are adversarial.** In [`Mode::Sim`], `psync` copies
//!   the affected cache lines into a shadow image; [`crash`] throws away
//!   all working memory and keeps only the shadow — i.e. only explicitly
//!   flushed lines are guaranteed to survive, exactly the model the
//!   paper's proofs assume. A *random eviction* knob additionally persists
//!   arbitrary unflushed lines (caches write back whenever they like),
//!   which is the model that catches algorithms relying on, or broken by,
//!   implicit persistence (e.g. the §3.3 two-insert validity race).
//!
//! Granularity note: eviction persists the *current* content of a whole
//! cache line. Under TSO, writes to a single line reach memory in program
//! order, so any real write-back is a prefix of the line's write history;
//! persisting the latest content is one legal such outcome. The algorithms
//! under test only ever rely on same-line ordering (Cohen et al. 2017), so
//! this is sufficient to exercise their correctness arguments.

pub mod check;
pub mod region;
pub mod root;
pub mod shadow;
pub mod stats;

use crate::util::{spin::spin_ns, CACHE_LINE};
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicU8, Ordering};

/// Identifies the set of durable regions belonging to one structure
/// instance. Survives a simulated crash (it stands in for the paper's
/// persistent per-thread area lists, whose heads live in "persistent
/// thread-local space").
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct PoolId(pub u64);

static NEXT_POOL: AtomicU64 = AtomicU64::new(1);

impl PoolId {
    /// Allocate a fresh process-unique pool id.
    pub fn fresh() -> Self {
        PoolId(NEXT_POOL.fetch_add(1, Ordering::Relaxed))
    }
}

/// Persistence-simulation mode.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Mode {
    /// Benchmark mode: `psync` = counters + optional latency injection.
    /// No shadow copies; [`crash`] is not meaningful.
    Perf = 0,
    /// Correctness mode: `psync` additionally snapshots the flushed lines
    /// into the shadow image so [`crash`]/recovery can be tested.
    Sim = 1,
}

static MODE: AtomicU8 = AtomicU8::new(Mode::Perf as u8);
static PSYNC_NS: AtomicU64 = AtomicU64::new(0);

/// Set the global persistence mode. Call before creating structures.
pub fn set_mode(mode: Mode) {
    MODE.store(mode as u8, Ordering::SeqCst);
}

/// Current persistence mode.
#[inline(always)]
pub fn mode() -> Mode {
    if MODE.load(Ordering::Relaxed) == Mode::Sim as u8 {
        Mode::Sim
    } else {
        Mode::Perf
    }
}

/// Set the injected latency per `psync` (models `clflush` + fence cost;
/// the paper's clflush on their Opteron is in the ~100ns class). 0 = off.
pub fn set_psync_ns(ns: u64) {
    PSYNC_NS.store(ns, Ordering::Relaxed);
}

/// Injected psync latency in nanoseconds.
#[inline(always)]
pub fn psync_ns() -> u64 {
    PSYNC_NS.load(Ordering::Relaxed)
}

/// Fault injection: a countdown of flushes until a simulated power loss
/// (panic on the flushing thread). i64::MAX = disarmed.
static FLUSH_FAULT: AtomicI64 = AtomicI64::new(i64::MAX);

/// Arm a simulated power loss after `n` more flushes (any thread). The
/// unlucky thread panics with [`POWER_LOSS`] *before* the flush takes
/// effect — i.e. the line it was persisting did NOT reach the NVRAM.
/// Torture tests catch the unwind, treat the in-flight op as unacked, and
/// then [`crash`]. Call [`disarm_flush_fault`] to reset.
pub fn arm_flush_fault(n: u64) {
    FLUSH_FAULT.store(n as i64, Ordering::SeqCst);
}

/// Disarm fault injection.
pub fn disarm_flush_fault() {
    FLUSH_FAULT.store(i64::MAX, Ordering::SeqCst);
}

/// Panic payload used for simulated power loss.
pub const POWER_LOSS: &str = "durasets simulated power loss";

// ---------------- group commit (fence coalescing) ----------------

/// Modeled write-back parallelism inside a [`PsyncScope`]: flushes issued
/// within a scope behave like `clflushopt` (asynchronous), and the scope's
/// trailing fence drains them `WRITEBACK_PIPE` lines at a time (real
/// memory subsystems retire on the order of 10 concurrent write-backs —
/// the line fill buffers). Outside a scope every psync stays synchronous
/// `clflush`, exactly as before.
const WRITEBACK_PIPE: u64 = 8;

thread_local! {
    /// Nesting depth of [`PsyncScope`]s on this thread (0 = no scope).
    static SCOPE_DEPTH: std::cell::Cell<u32> = const { std::cell::Cell::new(0) };
    /// Flushed lines whose latency/serialization is deferred to the
    /// enclosing scope's trailing fence.
    static SCOPE_PENDING: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
    /// Whether any fence was elided in the current scope (a trailing
    /// fence is owed even if no lines were flushed).
    static SCOPE_DIRTY: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

#[inline(always)]
fn in_scope() -> bool {
    SCOPE_DEPTH.with(|d| d.get()) > 0
}

/// RAII guard for **group commit**: while alive on the current thread,
/// `psync`/`fence` still *flush* every line (shadow copies and fault
/// injection are per-flush, so per-op durability in the crash simulator is
/// untouched) but their serialization points are elided — counted in
/// [`stats::PmemStats::elided`] — and replaced by one trailing fence when
/// the outermost scope drops.
///
/// Soundness in this substrate's model (paper §2: stores are durable once
/// they reach the memory controller; `psync`'s flush pushes them there):
/// a flush is durable at issue, so eliding the *issuer's* fence defers
/// only the issuer's own completion/ack point. Concurrent helpers that
/// re-flush and fence outside the scope still pay (and get) their own
/// serialization before acking, so individual-ack durable linearizability
/// is preserved; only the batch issuer's acks wait for the trailing fence.
///
/// Scopes nest; only the outermost drop issues the trailing fence. The
/// guard is `!Send` (thread-local state).
pub struct PsyncScope {
    _not_send: std::marker::PhantomData<*const ()>,
}

/// Enter a group-commit scope (see [`PsyncScope`]).
pub fn psync_scope() -> PsyncScope {
    SCOPE_DEPTH.with(|d| d.set(d.get() + 1));
    PsyncScope { _not_send: std::marker::PhantomData }
}

impl Drop for PsyncScope {
    fn drop(&mut self) {
        let depth = SCOPE_DEPTH.with(|d| {
            let v = d.get() - 1;
            d.set(v);
            v
        });
        if depth > 0 {
            return;
        }
        let pending = SCOPE_PENDING.with(|p| p.replace(0));
        let dirty = SCOPE_DIRTY.with(|f| f.replace(false));
        if pending > 0 || dirty {
            // The group-commit point: one real fence for the whole scope,
            // draining the deferred write-backs WRITEBACK_PIPE at a time.
            spin_ns(psync_ns() * pending.div_ceil(WRITEBACK_PIPE));
            fence();
        }
    }
}

/// Write back one cache line (no fence). Counted, latency-injected, and in
/// sim mode copied to the shadow image. Inside a [`PsyncScope`] the flush
/// is issued asynchronously: its latency is deferred to the trailing fence.
#[inline]
pub fn flush_line(ptr: *const u8) {
    if FLUSH_FAULT.load(Ordering::Relaxed) != i64::MAX
        // One-shot: exactly the thread that decrements 1 -> 0 dies.
        && FLUSH_FAULT.fetch_sub(1, Ordering::SeqCst) == 1
    {
        std::panic::panic_any(POWER_LOSS);
    }
    stats::count_flush();
    if mode() == Mode::Sim {
        // durcheck observes the flush before the copy lands: the
        // working-vs-shadow diff is what decides redundancy.
        check::note_flush(ptr);
        shadow::shadow_copy_line(ptr);
    }
    if in_scope() {
        SCOPE_PENDING.with(|p| p.set(p.get() + 1));
    } else {
        spin_ns(psync_ns());
    }
}

/// Ordering fence paired with flushes (the paper's clflush is ordered wrt
/// stores, so psync == flush; we still count the logical fence the
/// algorithms express). Compiles to an SeqCst fence. Inside a
/// [`PsyncScope`] the fence is elided and deferred to the scope's single
/// trailing fence (group commit).
#[inline]
pub fn fence() {
    if in_scope() {
        stats::count_elided_fence();
        SCOPE_DIRTY.with(|f| f.set(true));
        return;
    }
    stats::count_fence();
    std::sync::atomic::fence(Ordering::SeqCst);
    check::note_fence();
}

/// `psync(addr, len)`: flush every cache line covering `[addr, addr+len)`,
/// then fence. This is the paper's `psync` primitive. (Fused accounting:
/// one counter access + one latency injection per call — the per-line
/// `flush_line` + `fence` pair costs two TLS lookups and two RMWs, which
/// profiles showed on the update hot path.)
#[inline]
pub fn psync(ptr: *const u8, len: usize) {
    let start = crate::util::line_down(ptr as usize);
    let end = ptr as usize + len.max(1);
    let nlines = (crate::util::line_up(end) - start) / CACHE_LINE;
    if FLUSH_FAULT.load(Ordering::Relaxed) != i64::MAX {
        for i in 0..nlines {
            let _ = i;
            if FLUSH_FAULT.fetch_sub(1, Ordering::SeqCst) == 1 {
                std::panic::panic_any(POWER_LOSS);
            }
        }
    }
    if mode() == Mode::Sim {
        let mut line = start;
        while line < end {
            check::note_flush(line as *const u8);
            shadow::shadow_copy_line(line as *const u8);
            line += CACHE_LINE;
        }
    }
    if in_scope() {
        // Group commit: the lines are flushed (above — durability in the
        // simulator is per-flush), but the serialization point is deferred
        // to the enclosing scope's trailing fence.
        stats::count_psync_elided(nlines as u64);
        SCOPE_PENDING.with(|p| p.set(p.get() + nlines as u64));
        SCOPE_DIRTY.with(|f| f.set(true));
        return;
    }
    stats::count_psync(nlines as u64);
    spin_ns(psync_ns() * nlines as u64);
    std::sync::atomic::fence(Ordering::SeqCst);
    check::note_fence();
}

/// Convenience: psync a whole typed record (used for the one-cache-line
/// durable nodes).
#[inline]
pub fn psync_obj<T>(obj: *const T) {
    psync(obj as *const u8, std::mem::size_of::<T>());
}

/// Crash policy for [`crash`].
#[derive(Clone, Copy, Debug)]
pub struct CrashPolicy {
    /// Probability that an *unflushed* cache line is persisted anyway
    /// (arbitrary cache eviction). 0.0 = pessimistic (only explicit
    /// flushes survive), 1.0 = everything survives.
    pub evict_prob: f64,
    /// RNG seed for the eviction choice (deterministic tests).
    pub seed: u64,
}

impl CrashPolicy {
    /// Only explicitly flushed lines survive.
    pub const PESSIMISTIC: CrashPolicy = CrashPolicy { evict_prob: 0.0, seed: 0 };

    /// Random-eviction crash with the given probability and seed.
    pub fn random(evict_prob: f64, seed: u64) -> Self {
        CrashPolicy { evict_prob, seed }
    }
}

/// Simulate a full-system crash: volatile state is the caller's to throw
/// away (drop your structures); this function reverts every registered
/// durable region to its persisted (shadow) image, after applying the
/// eviction policy. Requires [`Mode::Sim`] to have been active for the
/// whole run, otherwise the shadow is not a meaningful persisted image.
///
/// Returns the number of lines that survived via random eviction (0 under
/// the pessimistic policy).
///
/// Whole-process semantics: every registered region of every pool reverts,
/// so this is only safe when the process runs nothing else (demos, the
/// CLI). Concurrent test binaries must use [`crash_pools`] instead — the
/// seed suite called this from per-module tests and zeroed unrelated
/// live structures mid-test.
pub fn crash(policy: CrashPolicy) -> usize {
    assert_eq!(mode(), Mode::Sim, "crash() requires pmem Mode::Sim");
    shadow::crash_all(policy, None)
}

/// [`crash`], scoped to the durable regions of the given pools only.
///
/// This is the crash entry point for tests and for the coordinator (which
/// knows its shards' pools): other pools' regions — including structures
/// owned by concurrently running tests — are left untouched. Named root
/// cells live in their own registry pool and are *not* reverted; they are
/// write-through anchors (every update is immediately persisted), so their
/// working content is their persisted content outside a mid-op window.
pub fn crash_pools(policy: CrashPolicy, pools: &[PoolId]) -> usize {
    assert_eq!(mode(), Mode::Sim, "crash_pools() requires pmem Mode::Sim");
    shadow::crash_all(policy, Some(pools))
}

/// RAII guard serializing simulated-crash testing process-wide.
///
/// [`Mode`] is a process-global: two crash tests in different modules each
/// flipping Sim→Perf with only module-local locks corrupt each other (the
/// first test's flushes silently stop shadowing when the second restores
/// Perf). Every test that needs Sim mode takes this session instead; the
/// guard holds a global lock, enters Sim, and restores Perf on drop.
pub struct SimSession {
    _lock: std::sync::MutexGuard<'static, ()>,
}

impl Drop for SimSession {
    fn drop(&mut self) {
        set_mode(Mode::Perf);
    }
}

/// Enter [`Mode::Sim`] under the global crash-test lock (see [`SimSession`]).
pub fn sim_session() -> SimSession {
    static SIM_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    // A previous test may have panicked on an assertion while holding the
    // session; the poison carries no state worth propagating.
    let lock = SIM_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    set_mode(Mode::Sim);
    SimSession { _lock: lock }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn psync_counts_lines_and_fence() {
        let before = stats::thread_snapshot();
        let buf = vec![0u8; 256];
        // 130 bytes starting at an aligned base covers 3 lines.
        let base = crate::util::line_up(buf.as_ptr() as usize) as *const u8;
        psync(base, 130);
        let after = stats::thread_snapshot();
        assert_eq!(after.flushes - before.flushes, 3);
        assert_eq!(after.fences - before.fences, 1);
    }

    #[test]
    fn psync_scope_coalesces_fences() {
        let buf = vec![0u8; 256];
        let base = crate::util::line_up(buf.as_ptr() as usize) as *const u8;
        let a = stats::thread_snapshot();
        {
            let _scope = psync_scope();
            psync(base, 8);
            psync(base, 8);
            fence();
        }
        let d = stats::thread_snapshot().since(&a);
        assert_eq!(d.flushes, 2, "flushes still happen per-op inside a scope");
        assert_eq!(d.elided, 3, "two psync fences + one bare fence elided");
        assert_eq!(d.fences, 1, "exactly the trailing group-commit fence");
    }

    #[test]
    fn nested_scopes_issue_one_trailing_fence() {
        let buf = vec![0u8; 256];
        let base = crate::util::line_up(buf.as_ptr() as usize) as *const u8;
        let a = stats::thread_snapshot();
        {
            let _outer = psync_scope();
            psync(base, 8);
            {
                let _inner = psync_scope();
                psync(base, 8);
            }
            psync(base, 8);
        }
        let d = stats::thread_snapshot().since(&a);
        assert_eq!(d.elided, 3);
        assert_eq!(d.fences, 1, "only the outermost scope fences");
    }

    #[test]
    fn empty_scope_is_free() {
        let a = stats::thread_snapshot();
        {
            let _scope = psync_scope();
        }
        let d = stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 0, "a scope with no persistence work owes no fence");
        assert_eq!(d.elided, 0);
    }

    #[test]
    fn psync_unaligned_start_covers_spanned_lines() {
        let before = stats::thread_snapshot();
        let buf = vec![0u8; 256];
        let base = crate::util::line_up(buf.as_ptr() as usize) as *const u8;
        // 8 bytes starting 60 bytes into a line span two lines.
        unsafe {
            psync(base.add(60), 8);
        }
        let after = stats::thread_snapshot();
        assert_eq!(after.flushes - before.flushes, 2);
    }
}
