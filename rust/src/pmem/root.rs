//! Named persistent root cells and root arrays.
//!
//! The log-free baseline persists its structure (list heads, bucket
//! arrays), so it needs durable anchor words a recovery can find — the
//! equivalent of the paper's "persistent thread-local space" holding area
//! list heads. A root cell is one durable 8-byte word addressed by name;
//! the name → address map itself is process metadata (it stands in for a
//! fixed, well-known NVRAM layout).
//!
//! **Root arrays** extend the idea to multi-word records (the atomic-batch
//! commit record of `coordinator::txn`). Unlike plain cells — which share
//! the registry pool that `crash_pools` never reverts, because every cell
//! update is write-through — a root array lives in its **own pool**,
//! exposed via [`RootArray::pool`], so its owner can include it in the
//! crash set. That matters for records whose multi-word content is only
//! crash-consistent when the psync protocol around them is honored: the
//! simulator must be allowed to revert half-written, unfenced words.

use super::region::{alloc_region, RegionTag};
use super::PoolId;
use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::sync::atomic::AtomicU64;
use std::sync::Mutex;

const CELLS_PER_REGION: usize = 512;

struct RootSpace {
    pool: PoolId,
    map: HashMap<String, usize>, // name -> cell address
    current: *mut u8,
    used: usize,
}

unsafe impl Send for RootSpace {}

static ROOTS: Lazy<Mutex<RootSpace>> = Lazy::new(|| {
    Mutex::new(RootSpace {
        pool: PoolId::fresh(),
        map: HashMap::new(),
        current: std::ptr::null_mut(),
        used: CELLS_PER_REGION, // force first allocation
    })
});

/// Handle to a persistent 8-byte root word. `Copy`, shareable, and stable
/// across simulated crashes.
#[derive(Clone, Copy, Debug)]
pub struct RootCell(*const AtomicU64);

unsafe impl Send for RootCell {}
unsafe impl Sync for RootCell {}

impl RootCell {
    /// The underlying atomic word (durable memory).
    #[inline]
    pub fn word(&self) -> &AtomicU64 {
        unsafe { &*self.0 }
    }

    /// psync the cell.
    pub fn persist(&self) {
        super::check::note_store(self.0 as *const u8);
        super::psync(self.0 as *const u8, 8);
    }
}

/// Get (or create zero-initialised) the root cell with the given name.
pub fn root_cell(name: &str) -> RootCell {
    let mut space = ROOTS.lock().unwrap();
    if let Some(&addr) = space.map.get(name) {
        return RootCell(addr as *const AtomicU64);
    }
    if space.used == CELLS_PER_REGION {
        space.current = alloc_region(space.pool, CELLS_PER_REGION * 8, RegionTag::Root, 0);
        space.used = 0;
    }
    let addr = unsafe { space.current.add(space.used * 8) } as usize;
    space.used += 1;
    space.map.insert(name.to_string(), addr);
    RootCell(addr as *const AtomicU64)
}

/// Handle to a named persistent array of 8-byte words in its own pool.
/// `Copy`, shareable, and stable across simulated crashes (the owner
/// carries it over a crash the same way shard metas are carried).
#[derive(Clone, Copy, Debug)]
pub struct RootArray {
    base: *const AtomicU64,
    words: usize,
    pool: PoolId,
}

unsafe impl Send for RootArray {}
unsafe impl Sync for RootArray {}

impl RootArray {
    /// Word `i` of the array (durable memory).
    #[inline]
    pub fn word(&self, i: usize) -> &AtomicU64 {
        assert!(i < self.words, "root array index {i} out of {}", self.words);
        unsafe { &*self.base.add(i) }
    }

    pub fn words(&self) -> usize {
        self.words
    }

    /// The array's dedicated pool — include it in `crash_pools` so the
    /// simulator reverts unfenced writes like any other durable region.
    pub fn pool(&self) -> PoolId {
        self.pool
    }

    /// psync words `[start, start + n)`.
    pub fn persist_range(&self, start: usize, n: usize) {
        assert!(start + n <= self.words);
        let ptr = unsafe { self.base.add(start) } as *const u8;
        super::check::note_store_range(ptr, n * 8);
        super::psync(ptr, n * 8);
    }
}

static ARRAYS: Lazy<Mutex<HashMap<String, (usize, usize, PoolId)>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

/// Get (or create zero-initialised) the named root array of `words`
/// 8-byte words. Re-requesting a name returns the same array; the word
/// count must match.
pub fn root_array(name: &str, words: usize) -> RootArray {
    assert!(words > 0);
    let mut map = ARRAYS.lock().unwrap();
    if let Some(&(base, w, pool)) = map.get(name) {
        assert_eq!(w, words, "root array '{name}' re-requested with a different size");
        return RootArray { base: base as *const AtomicU64, words, pool };
    }
    let pool = PoolId::fresh();
    let base = alloc_region(pool, words * 8, RegionTag::Root, 0) as usize;
    map.insert(name.to_string(), (base, words, pool));
    RootArray { base: base as *const AtomicU64, words, pool }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn same_name_same_cell() {
        let a = root_cell("test.cell.a");
        let b = root_cell("test.cell.a");
        assert_eq!(a.0 as usize, b.0 as usize);
        let c = root_cell("test.cell.b");
        assert_ne!(a.0 as usize, c.0 as usize);
    }

    #[test]
    fn cell_is_durable_memory() {
        let a = root_cell("test.cell.durable");
        a.word().store(77, Ordering::SeqCst);
        a.persist();
        assert_eq!(a.word().load(Ordering::SeqCst), 77);
    }

    #[test]
    fn root_array_roundtrip_and_identity() {
        let a = root_array("test.arr.a", 16);
        let b = root_array("test.arr.a", 16);
        assert_eq!(a.base as usize, b.base as usize);
        assert_ne!(a.pool(), PoolId(0));
        for i in 0..16 {
            a.word(i).store(i as u64 * 3, Ordering::Relaxed);
        }
        a.persist_range(0, 16);
        for i in 0..16 {
            assert_eq!(b.word(i).load(Ordering::Relaxed), i as u64 * 3);
        }
        // Distinct names get distinct pools (crash isolation).
        let c = root_array("test.arr.c", 4);
        assert_ne!(a.pool(), c.pool());
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn root_array_bounds_checked() {
        let a = root_array("test.arr.bounds", 2);
        a.word(2);
    }

    #[test]
    fn many_cells_span_regions() {
        for i in 0..(super::CELLS_PER_REGION + 4) {
            let c = root_cell(&format!("test.cell.many.{i}"));
            c.word().store(i as u64, Ordering::Relaxed);
        }
        for i in 0..(super::CELLS_PER_REGION + 4) {
            let c = root_cell(&format!("test.cell.many.{i}"));
            assert_eq!(c.word().load(Ordering::Relaxed), i as u64);
        }
    }
}
