//! Named persistent root cells.
//!
//! The log-free baseline persists its structure (list heads, bucket
//! arrays), so it needs durable anchor words a recovery can find — the
//! equivalent of the paper's "persistent thread-local space" holding area
//! list heads. A root cell is one durable 8-byte word addressed by name;
//! the name → address map itself is process metadata (it stands in for a
//! fixed, well-known NVRAM layout).

use super::region::{alloc_region, RegionTag};
use super::PoolId;
use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::sync::atomic::AtomicU64;
use std::sync::Mutex;

const CELLS_PER_REGION: usize = 512;

struct RootSpace {
    pool: PoolId,
    map: HashMap<String, usize>, // name -> cell address
    current: *mut u8,
    used: usize,
}

unsafe impl Send for RootSpace {}

static ROOTS: Lazy<Mutex<RootSpace>> = Lazy::new(|| {
    Mutex::new(RootSpace {
        pool: PoolId::fresh(),
        map: HashMap::new(),
        current: std::ptr::null_mut(),
        used: CELLS_PER_REGION, // force first allocation
    })
});

/// Handle to a persistent 8-byte root word. `Copy`, shareable, and stable
/// across simulated crashes.
#[derive(Clone, Copy, Debug)]
pub struct RootCell(*const AtomicU64);

unsafe impl Send for RootCell {}
unsafe impl Sync for RootCell {}

impl RootCell {
    /// The underlying atomic word (durable memory).
    #[inline]
    pub fn word(&self) -> &AtomicU64 {
        unsafe { &*self.0 }
    }

    /// psync the cell.
    pub fn persist(&self) {
        super::psync(self.0 as *const u8, 8);
    }
}

/// Get (or create zero-initialised) the root cell with the given name.
pub fn root_cell(name: &str) -> RootCell {
    let mut space = ROOTS.lock().unwrap();
    if let Some(&addr) = space.map.get(name) {
        return RootCell(addr as *const AtomicU64);
    }
    if space.used == CELLS_PER_REGION {
        space.current = alloc_region(space.pool, CELLS_PER_REGION * 8, RegionTag::Root, 0);
        space.used = 0;
    }
    let addr = unsafe { space.current.add(space.used * 8) } as usize;
    space.used += 1;
    space.map.insert(name.to_string(), addr);
    RootCell(addr as *const AtomicU64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn same_name_same_cell() {
        let a = root_cell("test.cell.a");
        let b = root_cell("test.cell.a");
        assert_eq!(a.0 as usize, b.0 as usize);
        let c = root_cell("test.cell.b");
        assert_ne!(a.0 as usize, c.0 as usize);
    }

    #[test]
    fn cell_is_durable_memory() {
        let a = root_cell("test.cell.durable");
        a.word().store(77, Ordering::SeqCst);
        a.persist();
        assert_eq!(a.word().load(Ordering::SeqCst), 77);
    }

    #[test]
    fn many_cells_span_regions() {
        for i in 0..(super::CELLS_PER_REGION + 4) {
            let c = root_cell(&format!("test.cell.many.{i}"));
            c.word().store(i as u64, Ordering::Relaxed);
        }
        for i in 0..(super::CELLS_PER_REGION + 4) {
            let c = root_cell(&format!("test.cell.many.{i}"));
            assert_eq!(c.word().load(Ordering::Relaxed), i as u64);
        }
    }
}
