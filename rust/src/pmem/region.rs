//! Durable region registry.
//!
//! Every byte the algorithms treat as persistent is allocated here: the
//! 64-byte-slot durable areas of the ssmem-style allocator, the log-free
//! baseline's persistent bucket arrays, and the named root cells. Regions
//! are grouped by [`PoolId`] (one pool per structure instance) and survive
//! a simulated crash — the registry stands in for the paper's persistent
//! per-thread area lists, which are reachable after a real power failure
//! via persistent thread-local roots.
//!
//! Regions are cache-line aligned, never move, and are only returned to
//! the OS by [`release_pool`] (the paper likewise only frees areas "at the
//! end of the execution" or during recovery when fully empty).

use super::PoolId;
use crate::util::CACHE_LINE;
use std::alloc::{alloc_zeroed, dealloc, Layout};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// What a region is used for; recovery and debug tooling dispatch on this.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RegionTag {
    /// Fixed-size durable slots (link-free nodes / SOFT PNodes / log-free
    /// nodes). `slot_size` recorded separately.
    Slots,
    /// A persistent array of link words (log-free bucket arrays).
    Links,
    /// Named root cells.
    Root,
}

pub(crate) struct Region {
    pub base: usize,
    pub len: usize,
    pub pool: PoolId,
    pub tag: RegionTag,
    /// Size of each slot for `Slots` regions (0 otherwise).
    pub slot_size: usize,
    /// Bytes of per-region durable metadata (occupancy bitmap words)
    /// preceding the first slot. Slot iteration skips it; bulk persists
    /// cover it (the header lives inside the region image on purpose).
    pub hdr: usize,
    /// Persisted image, same length as the region. Allocated eagerly and
    /// zero-initialised (lazily paged by the OS, so the perf-mode cost is
    /// nil). Only touched in sim mode / at crash time.
    pub shadow: *mut u8,
}

unsafe impl Send for Region {}
unsafe impl Sync for Region {}

/// Registry sorted by base address for O(log n) line lookup at flush time.
pub(crate) static REGISTRY: RwLock<Vec<Region>> = RwLock::new(Vec::new());

/// A handle to one registered durable region.
#[derive(Clone, Copy, Debug)]
pub struct RegionRef {
    pub base: *mut u8,
    pub len: usize,
    pub tag: RegionTag,
    pub slot_size: usize,
    /// Header bytes (occupancy bitmap) before the first slot; 0 for
    /// headerless regions (links, roots, pre-bitmap images).
    pub hdr: usize,
}

unsafe impl Send for RegionRef {}
unsafe impl Sync for RegionRef {}

impl RegionRef {
    /// Iterate the slot base pointers of a `Slots` region (header skipped).
    pub fn slots(&self) -> impl Iterator<Item = *mut u8> + '_ {
        assert!(self.tag == RegionTag::Slots && self.slot_size > 0);
        let n = (self.len - self.hdr) / self.slot_size;
        let base = self.base as usize + self.hdr;
        let sz = self.slot_size;
        (0..n).map(move |i| (base + i * sz) as *mut u8)
    }
}

fn layout(len: usize) -> Layout {
    Layout::from_size_align(len, CACHE_LINE).expect("region layout")
}

/// Allocate and register a durable region of `len` bytes (rounded up to a
/// cache line), zero-initialised. Returns the working-memory base pointer.
pub fn alloc_region(pool: PoolId, len: usize, tag: RegionTag, slot_size: usize) -> *mut u8 {
    alloc_region_with_hdr(pool, len, tag, slot_size, 0)
}

/// [`alloc_region`] with `hdr` bytes of in-image metadata (the area
/// occupancy bitmap) before the first slot. `hdr` must be line-aligned so
/// slots keep their cache-line alignment.
pub fn alloc_region_with_hdr(
    pool: PoolId,
    len: usize,
    tag: RegionTag,
    slot_size: usize,
    hdr: usize,
) -> *mut u8 {
    assert_eq!(hdr % CACHE_LINE, 0, "region header must be line-aligned");
    let len = crate::util::line_up(len.max(CACHE_LINE));
    let base = unsafe { alloc_zeroed(layout(len)) };
    assert!(!base.is_null(), "durable region allocation failed");
    let shadow = unsafe { alloc_zeroed(layout(len)) };
    assert!(!shadow.is_null(), "shadow allocation failed");
    let region = Region { base: base as usize, len, pool, tag, slot_size, hdr, shadow };
    let mut reg = REGISTRY.write().unwrap();
    let pos = reg.partition_point(|r| r.base < region.base);
    reg.insert(pos, region);
    base
}

/// All regions belonging to `pool` (recovery iterates these).
pub fn regions_of(pool: PoolId) -> Vec<RegionRef> {
    REGISTRY
        .read()
        .unwrap()
        .iter()
        .filter(|r| r.pool == pool)
        .map(|r| RegionRef {
            base: r.base as *mut u8,
            len: r.len,
            tag: r.tag,
            slot_size: r.slot_size,
            hdr: r.hdr,
        })
        .collect()
}

/// Unregister and free ONE region by base address (area compaction's
/// memory return). The caller owns the ordering argument: nothing may
/// reference the region when this runs — the allocator only calls it from
/// an EBR-deferred callback after the area has drained to empty and every
/// hint cell covering its range has been cleared.
pub fn release_region(base: *mut u8) -> bool {
    let mut reg = REGISTRY.write().unwrap();
    let Some(i) = reg.iter().position(|r| r.base == base as usize) else {
        return false;
    };
    let r = reg.remove(i);
    super::check::purge_range(r.base, r.len);
    unsafe {
        dealloc(r.base as *mut u8, layout(r.len));
        dealloc(r.shadow, layout(r.len));
    }
    true
}

/// Unregister and free all regions of a pool (normal shutdown only — a
/// crashed pool must stay allocated for recovery).
pub fn release_pool(pool: PoolId) {
    let mut reg = REGISTRY.write().unwrap();
    let mut i = 0;
    while i < reg.len() {
        if reg[i].pool == pool {
            let r = reg.remove(i);
            super::check::purge_range(r.base, r.len);
            unsafe {
                dealloc(r.base as *mut u8, layout(r.len));
                dealloc(r.shadow, layout(r.len));
            }
        } else {
            i += 1;
        }
    }
}

/// Copy the whole working region into its shadow without going through the
/// metered per-line path. Used when a freshly created area's canonical
/// slot pattern is persisted in bulk (amortised, one psync in the paper's
/// accounting — the caller meters it).
pub(crate) fn persist_region_bulk(base: *mut u8) {
    let reg = REGISTRY.read().unwrap();
    if let Some(r) = find_region(&reg, base as usize) {
        unsafe { copy_atomic_u64s(r.base as *const u8, r.shadow, r.len) };
        super::check::purge_range(r.base, r.len);
    }
}

/// Binary-search the registry for the region containing `addr`.
pub(crate) fn find_region<'a>(reg: &'a [Region], addr: usize) -> Option<&'a Region> {
    let i = reg.partition_point(|r| r.base <= addr);
    if i == 0 {
        return None;
    }
    let r = &reg[i - 1];
    if addr < r.base + r.len {
        Some(r)
    } else {
        None
    }
}

/// Copy `len` bytes (multiple of 8, both sides 8-aligned) using relaxed
/// atomic word accesses — source words may be concurrently written by the
/// lock-free structures, and torn 64-byte snapshots are exactly what real
/// cache-line write-back produces (word-level atomicity preserved).
pub(crate) unsafe fn copy_atomic_u64s(src: *const u8, dst: *mut u8, len: usize) {
    debug_assert_eq!(len % 8, 0);
    let words = len / 8;
    let s = src as *const AtomicU64;
    let d = dst as *const AtomicU64;
    for i in 0..words {
        let v = (*s.add(i)).load(Ordering::Relaxed);
        (*d.add(i)).store(v, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_register_lookup_release() {
        let pool = PoolId::fresh();
        let base = alloc_region(pool, 1000, RegionTag::Slots, 64);
        assert_eq!(base as usize % CACHE_LINE, 0);
        let rs = regions_of(pool);
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].len, crate::util::line_up(1000));
        assert_eq!(rs[0].slots().count(), crate::util::line_up(1000) / 64);
        {
            let reg = REGISTRY.read().unwrap();
            let r = find_region(&reg, base as usize + 10).unwrap();
            assert_eq!(r.base, base as usize);
            assert!(find_region(&reg, base as usize + r.len).map(|f| f.base) != Some(r.base));
        }
        release_pool(pool);
        assert!(regions_of(pool).is_empty());
    }

    #[test]
    fn regions_are_zeroed() {
        let pool = PoolId::fresh();
        let base = alloc_region(pool, 256, RegionTag::Links, 0);
        for i in 0..256 {
            assert_eq!(unsafe { *base.add(i) }, 0);
        }
        release_pool(pool);
    }

    #[test]
    fn header_region_skips_bitmap_in_slot_iteration() {
        let pool = PoolId::fresh();
        let base = alloc_region_with_hdr(pool, 512 + 16 * 64, RegionTag::Slots, 64, 512);
        let rs = regions_of(pool);
        assert_eq!(rs[0].hdr, 512);
        let slots: Vec<_> = rs[0].slots().collect();
        assert_eq!(slots.len(), 16);
        assert_eq!(slots[0] as usize, base as usize + 512, "first slot follows the header");
        release_pool(pool);
    }

    #[test]
    fn release_region_frees_one_area_only() {
        let pool = PoolId::fresh();
        let a = alloc_region(pool, 256, RegionTag::Slots, 64);
        let _b = alloc_region(pool, 256, RegionTag::Slots, 64);
        assert_eq!(regions_of(pool).len(), 2);
        assert!(release_region(a));
        assert!(!release_region(a), "double release is a no-op");
        let rs = regions_of(pool);
        assert_eq!(rs.len(), 1, "only the released area left the registry");
        release_pool(pool);
    }

    #[test]
    fn multiple_regions_same_pool() {
        let pool = PoolId::fresh();
        for _ in 0..5 {
            alloc_region(pool, 256, RegionTag::Slots, 64);
        }
        assert_eq!(regions_of(pool).len(), 5);
        release_pool(pool);
    }
}
