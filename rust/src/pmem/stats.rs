//! Per-thread flush/fence counters.
//!
//! The paper's key efficiency metric is the number of `psync` operations
//! (flush + fence) per data-structure operation: SOFT is designed to hit
//! the theoretical lower bound of one fence per update and zero per read.
//! Every benchmark in this repo reports psyncs/op next to throughput, so
//! the counters must be exact and must not introduce contention —
//! cache-padded per-thread slots, summed only at snapshot time.

use crate::util::{tid::tid, MAX_THREADS};
use crossbeam_utils::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};

struct Slot {
    flushes: AtomicU64,
    fences: AtomicU64,
    elided: AtomicU64,
}

static SLOTS: once_cell::sync::Lazy<Box<[CachePadded<Slot>]>> = once_cell::sync::Lazy::new(|| {
    (0..MAX_THREADS)
        .map(|_| {
            CachePadded::new(Slot {
                flushes: AtomicU64::new(0),
                fences: AtomicU64::new(0),
                elided: AtomicU64::new(0),
            })
        })
        .collect()
});

#[inline(always)]
pub(crate) fn count_flush() {
    SLOTS[tid()].flushes.fetch_add(1, Ordering::Relaxed);
}

#[inline(always)]
pub(crate) fn count_fence() {
    SLOTS[tid()].fences.fetch_add(1, Ordering::Relaxed);
}

/// A fence elided by an enclosing [`crate::pmem::PsyncScope`] (group
/// commit): the op expressed a serialization point that was deferred to
/// the scope's single trailing fence.
#[inline(always)]
pub(crate) fn count_elided_fence() {
    SLOTS[tid()].elided.fetch_add(1, Ordering::Relaxed);
}

/// One psync = `lines` flushes + one fence, with a single tid lookup (the
/// hot-path accounting; two separate lookups showed up in profiles).
#[inline(always)]
pub(crate) fn count_psync(lines: u64) {
    let s = &SLOTS[tid()];
    s.flushes.fetch_add(lines, Ordering::Relaxed);
    s.fences.fetch_add(1, Ordering::Relaxed);
}

/// An in-scope psync: `lines` flushes issued, the fence elided (single
/// tid lookup, mirroring [`count_psync`]).
#[inline(always)]
pub(crate) fn count_psync_elided(lines: u64) {
    let s = &SLOTS[tid()];
    s.flushes.fetch_add(lines, Ordering::Relaxed);
    s.elided.fetch_add(1, Ordering::Relaxed);
}

/// Aggregated counter snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PmemStats {
    pub flushes: u64,
    pub fences: u64,
    /// Fences elided by a [`crate::pmem::PsyncScope`] (group commit).
    pub elided: u64,
}

impl PmemStats {
    /// Difference since an earlier snapshot.
    pub fn since(&self, earlier: &PmemStats) -> PmemStats {
        PmemStats {
            flushes: self.flushes - earlier.flushes,
            fences: self.fences - earlier.fences,
            elided: self.elided - earlier.elided,
        }
    }
}

impl std::ops::Sub for PmemStats {
    type Output = PmemStats;
    fn sub(self, rhs: PmemStats) -> PmemStats {
        self.since(&rhs)
    }
}

/// Counters of the calling thread only. Tests asserting exact psync
/// counts use this so concurrently running tests cannot pollute the delta.
pub fn thread_snapshot() -> PmemStats {
    let s = &SLOTS[tid()];
    PmemStats {
        flushes: s.flushes.load(Ordering::Relaxed),
        fences: s.fences.load(Ordering::Relaxed),
        elided: s.elided.load(Ordering::Relaxed),
    }
}

/// Sum all threads' counters.
pub fn snapshot() -> PmemStats {
    let mut out = PmemStats::default();
    for s in SLOTS.iter() {
        out.flushes += s.flushes.load(Ordering::Relaxed);
        out.fences += s.fences.load(Ordering::Relaxed);
        out.elided += s.elided.load(Ordering::Relaxed);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        // Thread-scoped snapshot: the global sum moves under parallel
        // tests, so exact deltas are only assertable per thread.
        let a = thread_snapshot();
        count_flush();
        count_flush();
        count_fence();
        let b = thread_snapshot();
        let d = b.since(&a);
        assert_eq!(d.flushes, 2);
        assert_eq!(d.fences, 1);
    }

    #[test]
    fn counters_sum_across_threads() {
        let a = snapshot();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(|| {
                    for _ in 0..100 {
                        count_flush();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let d = snapshot().since(&a);
        // Concurrently running tests may add flushes of their own — the
        // global sum must reflect at least everything these threads did.
        assert!(d.flushes >= 400, "lost flushes: {}", d.flushes);
    }
}
