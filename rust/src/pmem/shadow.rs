//! Shadow images and crash simulation.
//!
//! In [`Mode::Sim`](super::Mode::Sim), every explicit flush copies the
//! affected cache line from working memory into the region's shadow image.
//! [`crash_all`] then reverts working memory to the shadow, optionally
//! first "evicting" random unflushed lines (persisting their current
//! content), which models caches writing back whenever they please.

use super::region::{copy_atomic_u64s, find_region, REGISTRY};
use super::CrashPolicy;
use crate::util::{line_down, rng::Xoshiro256, CACHE_LINE};

/// Copy one cache line (containing `ptr`) working → shadow, if the line
/// belongs to a registered durable region. Flushes of non-durable memory
/// (e.g. stack temporaries in tests) are silently ignored — a real
/// `clflush` of DRAM-backed volatile memory is likewise a no-op for
/// persistence purposes.
pub(crate) fn shadow_copy_line(ptr: *const u8) {
    let line = line_down(ptr as usize);
    let reg = REGISTRY.read().unwrap();
    if let Some(r) = find_region(&reg, line) {
        let off = line - r.base;
        // The last line of a region is always complete: regions are
        // line-aligned and line-rounded.
        unsafe {
            copy_atomic_u64s((r.base + off) as *const u8, r.shadow.add(off), CACHE_LINE);
        }
    }
}

/// Revert registered regions to their persisted image, applying the
/// eviction policy first. `pools = None` reverts everything (whole-process
/// crash); `Some(pools)` scopes the blast radius to those pools' regions.
/// Returns how many unflushed lines survived via random eviction.
pub(crate) fn crash_all(policy: CrashPolicy, pools: Option<&[super::PoolId]>) -> usize {
    let reg = REGISTRY.write().unwrap();
    let mut rng = Xoshiro256::new(policy.seed ^ 0xC5A5_17E0_D00D_F00D);
    let mut evicted = 0usize;
    for r in reg.iter() {
        if let Some(pools) = pools {
            if !pools.contains(&r.pool) {
                continue;
            }
        }
        let lines = r.len / CACHE_LINE;
        if policy.evict_prob > 0.0 {
            for l in 0..lines {
                if rng.f64() < policy.evict_prob {
                    let off = l * CACHE_LINE;
                    unsafe {
                        copy_atomic_u64s(
                            (r.base + off) as *const u8,
                            r.shadow.add(off),
                            CACHE_LINE,
                        );
                    }
                    evicted += 1;
                }
            }
        }
        // Working memory <- shadow (the persisted view is all that's left).
        unsafe {
            copy_atomic_u64s(r.shadow as *const u8, r.base as *mut u8, r.len);
        }
        // A crash discharges every outstanding persist obligation in the
        // blast radius: post-crash working memory *is* the persisted image.
        super::check::purge_range(r.base, r.len);
    }
    evicted
}

#[cfg(test)]
mod tests {
    use crate::pmem::{self, region, CrashPolicy, PoolId};

    #[test]
    fn unflushed_data_dies_flushed_survives() {
        let _sim = pmem::sim_session();
        let pool = PoolId::fresh();
        let base = region::alloc_region(pool, 256, region::RegionTag::Links, 0);
        unsafe {
            // Line 0: written and flushed. Line 1: written, not flushed.
            *(base as *mut u64) = 0xAAAA;
            *(base.add(64) as *mut u64) = 0xBBBB;
            pmem::psync(base, 8);
            pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[pool]);
            assert_eq!(*(base as *const u64), 0xAAAA, "flushed line must survive");
            assert_eq!(*(base.add(64) as *const u64), 0, "unflushed line must die");
        }
        region::release_pool(pool);
    }

    #[test]
    fn eviction_probability_one_persists_everything() {
        let _sim = pmem::sim_session();
        let pool = PoolId::fresh();
        let base = region::alloc_region(pool, 256, region::RegionTag::Links, 0);
        unsafe {
            *(base.add(128) as *mut u64) = 0xCCCC;
            let evicted = pmem::crash_pools(CrashPolicy::random(1.0, 1), &[pool]);
            assert!(evicted > 0);
            assert_eq!(*(base.add(128) as *const u64), 0xCCCC);
        }
        region::release_pool(pool);
    }

    #[test]
    fn crash_reverts_to_last_flushed_version() {
        let _sim = pmem::sim_session();
        let pool = PoolId::fresh();
        let base = region::alloc_region(pool, 64, region::RegionTag::Links, 0);
        unsafe {
            *(base as *mut u64) = 1;
            pmem::psync(base, 8);
            *(base as *mut u64) = 2; // newer, unflushed
            pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[pool]);
            assert_eq!(*(base as *const u64), 1);
        }
        region::release_pool(pool);
    }

    #[test]
    fn scoped_crash_leaves_other_pools_alone() {
        let _sim = pmem::sim_session();
        let a = PoolId::fresh();
        let b = PoolId::fresh();
        let pa = region::alloc_region(a, 64, region::RegionTag::Links, 0);
        let pb = region::alloc_region(b, 64, region::RegionTag::Links, 0);
        unsafe {
            *(pa as *mut u64) = 7; // unflushed
            *(pb as *mut u64) = 9; // unflushed
            pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[a]);
            assert_eq!(*(pa as *const u64), 0, "scoped pool reverts");
            assert_eq!(*(pb as *const u64), 9, "unscoped pool untouched");
        }
        region::release_pool(a);
        region::release_pool(b);
    }
}
