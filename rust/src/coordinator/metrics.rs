//! Service metrics: op counters + log2 latency histogram + group-commit
//! batch stats, lock-free on the record path (per-thread slots would be
//! overkill here — shard workers are few; plain relaxed atomics are
//! uncontended in practice).

use crate::sets::{GrowthStats, OpResult, SetOp};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

const BUCKETS: usize = 40; // 2^0 .. 2^39 ns (~0.5s)

pub struct Metrics {
    pub gets: AtomicU64,
    pub get_hits: AtomicU64,
    pub puts: AtomicU64,
    pub put_new: AtomicU64,
    pub dels: AtomicU64,
    pub del_hit: AtomicU64,
    /// Group commits executed by shard workers.
    pub batches: AtomicU64,
    /// Ops served through group commits (avg batch = batch_ops/batches).
    pub batch_ops: AtomicU64,
    /// Largest group commit observed.
    pub max_batch: AtomicU64,
    latency: [AtomicU64; BUCKETS],
    // Last recovery, as recorded by `CrashTicket` (0 shards = never
    // recovered; see `record_recovery`). Durations in microseconds.
    rec_shards: AtomicU64,
    rec_members: AtomicU64,
    rec_reclaimed: AtomicU64,
    rec_wall_us: AtomicU64,
    rec_scan_us: AtomicU64,
    rec_sort_us: AtomicU64,
    rec_relink_us: AtomicU64,
    rec_threads: AtomicU64,
    rec_accelerated: AtomicU64,
    rec_evicted: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const Z: AtomicU64 = AtomicU64::new(0);
        Metrics {
            gets: Z,
            get_hits: Z,
            puts: Z,
            put_new: Z,
            dels: Z,
            del_hit: Z,
            batches: Z,
            batch_ops: Z,
            max_batch: Z,
            latency: [Z; BUCKETS],
            rec_shards: Z,
            rec_members: Z,
            rec_reclaimed: Z,
            rec_wall_us: Z,
            rec_scan_us: Z,
            rec_sort_us: Z,
            rec_relink_us: Z,
            rec_threads: Z,
            rec_accelerated: Z,
            rec_evicted: Z,
        }
    }

    /// Record the last crash recovery so operators can read the measured
    /// RTO (wall + per-phase breakdown) off the `STATS` wire line instead
    /// of losing it with the recovery call's return value.
    pub fn record_recovery(&self, r: &super::recovery::RecoveryReport) {
        self.rec_shards.store(r.shards as u64, Ordering::Relaxed);
        self.rec_members.store(r.members as u64, Ordering::Relaxed);
        self.rec_reclaimed.store(r.reclaimed as u64, Ordering::Relaxed);
        self.rec_wall_us.store(r.wall.as_micros() as u64, Ordering::Relaxed);
        self.rec_scan_us.store(r.scan.as_micros() as u64, Ordering::Relaxed);
        self.rec_sort_us.store(r.sort.as_micros() as u64, Ordering::Relaxed);
        self.rec_relink_us.store(r.relink.as_micros() as u64, Ordering::Relaxed);
        self.rec_threads.store(r.threads as u64, Ordering::Relaxed);
        self.rec_accelerated.store(r.accelerated as u64, Ordering::Relaxed);
        self.rec_evicted.store(r.evicted_lines as u64, Ordering::Relaxed);
    }

    /// Count one batched op with its result (shard worker scatter path).
    #[inline]
    pub fn record_op(&self, op: SetOp, res: OpResult) {
        match op {
            SetOp::Get(_) | SetOp::Contains(_) => {
                self.gets.fetch_add(1, Ordering::Relaxed);
                if matches!(res, OpResult::Value(Some(_)) | OpResult::Found(true)) {
                    self.get_hits.fetch_add(1, Ordering::Relaxed);
                }
            }
            SetOp::Insert(..) => {
                self.puts.fetch_add(1, Ordering::Relaxed);
                if res == OpResult::Applied(true) {
                    self.put_new.fetch_add(1, Ordering::Relaxed);
                }
            }
            SetOp::Remove(_) => {
                self.dels.fetch_add(1, Ordering::Relaxed);
                if res == OpResult::Applied(true) {
                    self.del_hit.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Count one group commit of `n` ops.
    #[inline]
    pub fn record_group(&self, n: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_ops.fetch_add(n, Ordering::Relaxed);
        self.max_batch.fetch_max(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_latency(&self, d: Duration) {
        let ns = d.as_nanos().max(1) as u64;
        let b = (63 - ns.leading_zeros() as usize).min(BUCKETS - 1);
        self.latency[b].fetch_add(1, Ordering::Relaxed);
    }

    pub fn ops_total(&self) -> u64 {
        self.gets.load(Ordering::Relaxed)
            + self.puts.load(Ordering::Relaxed)
            + self.dels.load(Ordering::Relaxed)
    }

    /// Latency quantile estimate from the histogram (upper bucket bound).
    pub fn latency_quantile(&self, q: f64) -> Duration {
        let total: u64 = self.latency.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.latency.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_nanos(1 << (i + 1));
            }
        }
        Duration::from_nanos(1 << BUCKETS)
    }

    pub fn report(&self) -> String {
        let batches = self.batches.load(Ordering::Relaxed);
        let batch_ops = self.batch_ops.load(Ordering::Relaxed);
        let avg_batch = if batches > 0 { batch_ops as f64 / batches as f64 } else { 0.0 };
        let mut out = format!(
            "ops={} gets={} (hits {}) puts={} (new {}) dels={} (hit {}) p50<={:?} p99<={:?} batches={} avg_batch={:.1} max_batch={}",
            self.ops_total(),
            self.gets.load(Ordering::Relaxed),
            self.get_hits.load(Ordering::Relaxed),
            self.puts.load(Ordering::Relaxed),
            self.put_new.load(Ordering::Relaxed),
            self.dels.load(Ordering::Relaxed),
            self.del_hit.load(Ordering::Relaxed),
            self.latency_quantile(0.5),
            self.latency_quantile(0.99),
            batches,
            avg_batch,
            self.max_batch.load(Ordering::Relaxed),
        );
        if self.rec_shards.load(Ordering::Relaxed) > 0 {
            let ms = |c: &AtomicU64| c.load(Ordering::Relaxed) as f64 / 1000.0;
            out.push_str(&format!(
                " recovery=[shards={} members={} reclaimed={} wall={:.1}ms scan={:.1}ms sort={:.1}ms relink={:.1}ms threads={} accel={} evicted={}]",
                self.rec_shards.load(Ordering::Relaxed),
                self.rec_members.load(Ordering::Relaxed),
                self.rec_reclaimed.load(Ordering::Relaxed),
                ms(&self.rec_wall_us),
                ms(&self.rec_scan_us),
                ms(&self.rec_sort_us),
                ms(&self.rec_relink_us),
                self.rec_threads.load(Ordering::Relaxed),
                self.rec_accelerated.load(Ordering::Relaxed) != 0,
                self.rec_evicted.load(Ordering::Relaxed),
            ));
        }
        out
    }

    /// [`Metrics::report`] plus per-shard resizable-hash growth stats
    /// (`None` entries — volatile or list shards — are skipped).
    pub fn report_with_growth(&self, growth: &[Option<GrowthStats>]) -> String {
        let mut out = self.report();
        let mut any = false;
        for (i, g) in growth.iter().enumerate() {
            if let Some(g) = g {
                out.push_str(if any { "; " } else { " growth=[" });
                any = true;
                out.push_str(&format!(
                    "s{}:buckets={} doublings={} load={:.2}",
                    i,
                    g.buckets,
                    g.doublings,
                    g.chain_load()
                ));
            }
        }
        if any {
            out.push(']');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let m = Metrics::new();
        for i in 0..1000u64 {
            m.record_latency(Duration::from_nanos(100 + i * 10));
        }
        let p50 = m.latency_quantile(0.5);
        let p99 = m.latency_quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= Duration::from_nanos(100));
        assert!(p99 <= Duration::from_millis(1));
    }

    #[test]
    fn counters_report() {
        let m = Metrics::new();
        m.gets.fetch_add(3, Ordering::Relaxed);
        m.puts.fetch_add(2, Ordering::Relaxed);
        m.dels.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.ops_total(), 6);
        assert!(m.report().contains("ops=6"));
    }

    #[test]
    fn empty_histogram_quantile_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn record_op_classifies_results() {
        let m = Metrics::new();
        m.record_op(SetOp::Insert(1, 1), OpResult::Applied(true));
        m.record_op(SetOp::Insert(1, 1), OpResult::Applied(false));
        m.record_op(SetOp::Get(1), OpResult::Value(Some(1)));
        m.record_op(SetOp::Contains(2), OpResult::Found(false));
        m.record_op(SetOp::Remove(1), OpResult::Applied(true));
        assert_eq!(m.ops_total(), 5);
        assert_eq!(m.puts.load(Ordering::Relaxed), 2);
        assert_eq!(m.put_new.load(Ordering::Relaxed), 1);
        assert_eq!(m.gets.load(Ordering::Relaxed), 2);
        assert_eq!(m.get_hits.load(Ordering::Relaxed), 1);
        assert_eq!(m.del_hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn recovery_report_renders_after_record() {
        let m = Metrics::new();
        assert!(!m.report().contains("recovery=["), "no recovery recorded yet");
        let r = crate::coordinator::recovery::RecoveryReport {
            shards: 2,
            members: 10,
            reclaimed: 4,
            wall: Duration::from_millis(5),
            threads: 8,
            scan: Duration::from_millis(3),
            sort: Duration::from_millis(1),
            relink: Duration::from_millis(1),
            accelerated: false,
            evicted_lines: 7,
        };
        m.record_recovery(&r);
        let s = m.report();
        assert!(s.contains("recovery=[shards=2 members=10 reclaimed=4 wall=5.0ms"), "{s}");
        assert!(s.contains("threads=8 accel=false evicted=7]"), "{s}");
    }

    #[test]
    fn group_and_growth_reporting() {
        let m = Metrics::new();
        m.record_group(10);
        m.record_group(30);
        let r = m.report();
        assert!(r.contains("batches=2"), "{r}");
        assert!(r.contains("avg_batch=20.0"), "{r}");
        assert!(r.contains("max_batch=30"), "{r}");
        let growth = vec![
            Some(GrowthStats { buckets: 64, doublings: 5, items: 128 }),
            None,
            Some(GrowthStats { buckets: 32, doublings: 4, items: 32 }),
        ];
        let rg = m.report_with_growth(&growth);
        assert!(rg.contains("growth=[s0:buckets=64 doublings=5 load=2.00; s2:buckets=32"), "{rg}");
        assert!(m.report_with_growth(&[None, None]).ends_with("max_batch=30"));
    }
}
