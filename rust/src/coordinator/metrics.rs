//! Service metrics: op counters + log2 latency histogram, lock-free on the
//! record path (per-thread slots would be overkill here — shard workers
//! are few; plain relaxed atomics are uncontended in practice).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

const BUCKETS: usize = 40; // 2^0 .. 2^39 ns (~0.5s)

pub struct Metrics {
    pub gets: AtomicU64,
    pub get_hits: AtomicU64,
    pub puts: AtomicU64,
    pub put_new: AtomicU64,
    pub dels: AtomicU64,
    pub del_hit: AtomicU64,
    latency: [AtomicU64; BUCKETS],
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const Z: AtomicU64 = AtomicU64::new(0);
        Metrics {
            gets: Z,
            get_hits: Z,
            puts: Z,
            put_new: Z,
            dels: Z,
            del_hit: Z,
            latency: [Z; BUCKETS],
        }
    }

    #[inline]
    pub fn record_latency(&self, d: Duration) {
        let ns = d.as_nanos().max(1) as u64;
        let b = (63 - ns.leading_zeros() as usize).min(BUCKETS - 1);
        self.latency[b].fetch_add(1, Ordering::Relaxed);
    }

    pub fn ops_total(&self) -> u64 {
        self.gets.load(Ordering::Relaxed)
            + self.puts.load(Ordering::Relaxed)
            + self.dels.load(Ordering::Relaxed)
    }

    /// Latency quantile estimate from the histogram (upper bucket bound).
    pub fn latency_quantile(&self, q: f64) -> Duration {
        let total: u64 = self.latency.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.latency.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_nanos(1 << (i + 1));
            }
        }
        Duration::from_nanos(1 << BUCKETS)
    }

    pub fn report(&self) -> String {
        format!(
            "ops={} gets={} (hits {}) puts={} (new {}) dels={} (hit {}) p50<={:?} p99<={:?}",
            self.ops_total(),
            self.gets.load(Ordering::Relaxed),
            self.get_hits.load(Ordering::Relaxed),
            self.puts.load(Ordering::Relaxed),
            self.put_new.load(Ordering::Relaxed),
            self.dels.load(Ordering::Relaxed),
            self.del_hit.load(Ordering::Relaxed),
            self.latency_quantile(0.5),
            self.latency_quantile(0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let m = Metrics::new();
        for i in 0..1000u64 {
            m.record_latency(Duration::from_nanos(100 + i * 10));
        }
        let p50 = m.latency_quantile(0.5);
        let p99 = m.latency_quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= Duration::from_nanos(100));
        assert!(p99 <= Duration::from_millis(1));
    }

    #[test]
    fn counters_report() {
        let m = Metrics::new();
        m.gets.fetch_add(3, Ordering::Relaxed);
        m.puts.fetch_add(2, Ordering::Relaxed);
        m.dels.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.ops_total(), 6);
        assert!(m.report().contains("ops=6"));
    }

    #[test]
    fn empty_histogram_quantile_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile(0.99), Duration::ZERO);
    }
}
