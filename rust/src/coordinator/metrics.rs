//! Service metrics: op counters + log2 latency histogram + group-commit
//! batch stats, lock-free on the record path (per-thread slots would be
//! overkill here — shard workers are few; plain relaxed atomics are
//! uncontended in practice).

use crate::pmem::stats::PmemStats;
use crate::sets::{GrowthStats, OpResult, SetOp};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

const BUCKETS: usize = 40; // 2^0 .. 2^39 ns (~0.5s)

pub struct Metrics {
    pub gets: AtomicU64,
    pub get_hits: AtomicU64,
    pub puts: AtomicU64,
    pub put_new: AtomicU64,
    pub dels: AtomicU64,
    pub del_hit: AtomicU64,
    /// Group commits executed by shard workers.
    pub batches: AtomicU64,
    /// Ops served through group commits (avg batch = batch_ops/batches).
    pub batch_ops: AtomicU64,
    /// Largest group commit observed.
    pub max_batch: AtomicU64,
    /// Read-lane bursts executed (server fast path, one per burst with
    /// reads).
    pub rl_runs: AtomicU64,
    /// Ops served through the read lane.
    pub rl_ops: AtomicU64,
    /// Fences the read lane issued (pinned 0 for SOFT; link-free/log-free
    /// may pay read-side helping psyncs when racing updates).
    pub rl_fences: AtomicU64,
    /// Flushes the read lane issued (same pin as `rl_fences`).
    pub rl_flushes: AtomicU64,
    /// Scan-lane bursts executed (ordered `RANGE`/`SCAN` merge-walks; one
    /// per burst with ordered reads).
    pub sl_runs: AtomicU64,
    /// Ordered queries served through the scan lane.
    pub sl_ops: AtomicU64,
    /// Fences the scan lane issued — pinned 0 for both skip-list families
    /// (`walk_from` never helps-flush; the CI scan gate enforces this).
    pub sl_fences: AtomicU64,
    /// Flushes the scan lane issued (same pin as `sl_fences`).
    pub sl_flushes: AtomicU64,
    /// Ops covered by the worker-path fence gauge (group commits +
    /// atomic sub-batches; the fences/op ablation's serving-path mirror).
    pub fence_ops: AtomicU64,
    /// Fences those ops paid (each group's trailing fence, mostly).
    pub fences_total: AtomicU64,
    /// Cache-line flushes those ops issued.
    pub flushes_total: AtomicU64,
    /// Per-op fences elided into a group's single trailing fence
    /// (`PsyncScope` coalescing) — `elided / fences` is the amortization.
    pub fences_elided: AtomicU64,
    /// Atomic cross-shard batches executed.
    pub atomics: AtomicU64,
    /// Ops inside atomic batches.
    pub atomic_ops: AtomicU64,
    /// Committed-but-unretired atomic batches recovery rolled forward.
    pub rolled_forward: AtomicU64,
    // Connection-plane gauges (DESIGN.md §ConnectionPlane). `cp_workers`
    // doubles as the "event plane is on" flag for STATS rendering;
    // `cp_conns` is a live gauge (opened − closed), the rest cumulative.
    pub cp_workers: AtomicU64,
    pub cp_conns: AtomicU64,
    /// Reactor wakeups delivered (batch completions, injected accepts,
    /// atomic-helper results — anything that unparked a reactor).
    pub cp_wakeups: AtomicU64,
    /// Write stalls: a connection's flush hit `WouldBlock` and re-armed
    /// write interest (counted once per stall, not per retry).
    pub cp_partial_writes: AtomicU64,
    // Adaptive-K gauge: `k_last` is the most recent bound any worker
    // reported (plain store — a gauge); `k_lo`/`k_hi` are the cumulative
    // envelope (fetch_min / fetch_max), so concurrent STATS readers see
    // monotone values and the envelope proves K actually moved.
    k_last: AtomicU64,
    k_lo: AtomicU64,
    k_hi: AtomicU64,
    latency: [AtomicU64; BUCKETS],
    // Last recovery, as recorded by `CrashTicket` (0 shards = never
    // recovered; see `record_recovery`). Durations in microseconds.
    rec_shards: AtomicU64,
    rec_members: AtomicU64,
    rec_reclaimed: AtomicU64,
    rec_wall_us: AtomicU64,
    rec_scan_us: AtomicU64,
    rec_sort_us: AtomicU64,
    rec_relink_us: AtomicU64,
    rec_threads: AtomicU64,
    rec_accelerated: AtomicU64,
    rec_evicted: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const Z: AtomicU64 = AtomicU64::new(0);
        Metrics {
            gets: Z,
            get_hits: Z,
            puts: Z,
            put_new: Z,
            dels: Z,
            del_hit: Z,
            batches: Z,
            batch_ops: Z,
            max_batch: Z,
            rl_runs: Z,
            rl_ops: Z,
            rl_fences: Z,
            rl_flushes: Z,
            sl_runs: Z,
            sl_ops: Z,
            sl_fences: Z,
            sl_flushes: Z,
            fence_ops: Z,
            fences_total: Z,
            flushes_total: Z,
            fences_elided: Z,
            atomics: Z,
            atomic_ops: Z,
            rolled_forward: Z,
            cp_workers: Z,
            cp_conns: Z,
            cp_wakeups: Z,
            cp_partial_writes: Z,
            k_last: Z,
            k_lo: AtomicU64::new(u64::MAX),
            k_hi: Z,
            latency: [Z; BUCKETS],
            rec_shards: Z,
            rec_members: Z,
            rec_reclaimed: Z,
            rec_wall_us: Z,
            rec_scan_us: Z,
            rec_sort_us: Z,
            rec_relink_us: Z,
            rec_threads: Z,
            rec_accelerated: Z,
            rec_evicted: Z,
        }
    }

    /// Record the last crash recovery so operators can read the measured
    /// RTO (wall + per-phase breakdown) off the `STATS` wire line instead
    /// of losing it with the recovery call's return value.
    pub fn record_recovery(&self, r: &super::recovery::RecoveryReport) {
        self.rec_shards.store(r.shards as u64, Ordering::Relaxed);
        self.rec_members.store(r.members as u64, Ordering::Relaxed);
        self.rec_reclaimed.store(r.reclaimed as u64, Ordering::Relaxed);
        self.rec_wall_us.store(r.wall.as_micros() as u64, Ordering::Relaxed);
        self.rec_scan_us.store(r.scan.as_micros() as u64, Ordering::Relaxed);
        self.rec_sort_us.store(r.sort.as_micros() as u64, Ordering::Relaxed);
        self.rec_relink_us.store(r.relink.as_micros() as u64, Ordering::Relaxed);
        self.rec_threads.store(r.threads as u64, Ordering::Relaxed);
        self.rec_accelerated.store(r.accelerated as u64, Ordering::Relaxed);
        self.rec_evicted.store(r.evicted_lines as u64, Ordering::Relaxed);
        self.record_rolled_forward(r.txn_rolled_forward as u64);
    }

    /// Count one batched op with its result (shard worker scatter path).
    #[inline]
    pub fn record_op(&self, op: SetOp, res: OpResult) {
        match op {
            SetOp::Get(_) | SetOp::Contains(_) => {
                self.gets.fetch_add(1, Ordering::Relaxed);
                if matches!(res, OpResult::Value(Some(_)) | OpResult::Found(true)) {
                    self.get_hits.fetch_add(1, Ordering::Relaxed);
                }
            }
            SetOp::Insert(..) => {
                self.puts.fetch_add(1, Ordering::Relaxed);
                if res == OpResult::Applied(true) {
                    self.put_new.fetch_add(1, Ordering::Relaxed);
                }
            }
            SetOp::Remove(_) => {
                self.dels.fetch_add(1, Ordering::Relaxed);
                if res == OpResult::Applied(true) {
                    self.del_hit.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Count one group commit of `n` ops. Ordering matters for concurrent
    /// `STATS` readers: the writer goes `max_batch` → `batches` →
    /// `batch_ops`, and a reader derives `avg_batch` by loading in the
    /// *reverse* order (`batch_ops`, then `batches`, then `max_batch` —
    /// see [`Metrics::batch_view`]). Any ops a reader sees were added by
    /// a writer that had already counted its batch, so the read `batches`
    /// covers every batch inside the read `batch_ops`; and every such
    /// batch ran `fetch_max` before that, so the later-read max bounds
    /// them all. Hence avg ≤ max always, with every counter a plain
    /// cumulative monotone word.
    #[inline]
    pub fn record_group(&self, n: u64) {
        self.max_batch.fetch_max(n, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        // Release: pairs with `batch_view`'s Acquire load, so a reader
        // that observes these ops also observes the max/batches updates
        // sequenced before them.
        self.batch_ops.fetch_add(n, Ordering::Release);
    }

    /// Race-safe snapshot of `(batches, batch_ops, max_batch)` for
    /// derived statistics: loads in the reverse of [`Metrics::record_group`]'s
    /// write order (Acquire on the ops word), so `batch_ops / batches`
    /// never exceeds `max_batch` (see the ordering argument there).
    pub fn batch_view(&self) -> (u64, u64, u64) {
        let batch_ops = self.batch_ops.load(Ordering::Acquire);
        let batches = self.batches.load(Ordering::Relaxed);
        let max_batch = self.max_batch.load(Ordering::Relaxed);
        (batches, batch_ops, max_batch)
    }

    /// Count one read-lane burst of `n` ops plus the fences/flushes its
    /// sweep issued (the server meters its own thread around the sweep).
    #[inline]
    pub fn record_read_lane(&self, n: u64, fences: u64, flushes: u64) {
        self.rl_ops.fetch_add(n, Ordering::Relaxed);
        self.rl_fences.fetch_add(fences, Ordering::Relaxed);
        self.rl_flushes.fetch_add(flushes, Ordering::Relaxed);
        self.rl_runs.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one scan-lane burst of `n` ordered queries plus the
    /// fences/flushes its merge-walk issued (metered like the read lane).
    #[inline]
    pub fn record_scan_lane(&self, n: u64, fences: u64, flushes: u64) {
        self.sl_ops.fetch_add(n, Ordering::Relaxed);
        self.sl_fences.fetch_add(fences, Ordering::Relaxed);
        self.sl_flushes.fetch_add(flushes, Ordering::Relaxed);
        self.sl_runs.fetch_add(1, Ordering::Relaxed);
    }

    /// Count `ops` committed ops against the worker-path fence gauge,
    /// with the pmem counter delta their commit measured around
    /// `apply_batch` (the worker meters its own thread).
    #[inline]
    pub fn record_fences(&self, ops: u64, d: &PmemStats) {
        self.fence_ops.fetch_add(ops, Ordering::Relaxed);
        self.fences_total.fetch_add(d.fences, Ordering::Relaxed);
        self.flushes_total.fetch_add(d.flushes, Ordering::Relaxed);
        self.fences_elided.fetch_add(d.elided, Ordering::Relaxed);
    }

    /// Count one atomic cross-shard batch of `n` ops.
    #[inline]
    pub fn record_atomic(&self, n: u64) {
        self.atomic_ops.fetch_add(n, Ordering::Relaxed);
        self.atomics.fetch_add(1, Ordering::Relaxed);
    }

    /// Count atomic batches recovery rolled forward.
    #[inline]
    pub fn record_rolled_forward(&self, n: u64) {
        self.rolled_forward.fetch_add(n, Ordering::Relaxed);
    }

    /// The server started an event plane with `n` reactor workers (also
    /// switches the `connplane=` STATS section on).
    pub fn set_conn_workers(&self, n: u64) {
        self.cp_workers.store(n, Ordering::Relaxed);
    }

    /// A reactor registered a new connection.
    #[inline]
    pub fn conn_opened(&self) {
        self.cp_conns.fetch_add(1, Ordering::Relaxed);
    }

    /// A reactor retired a connection.
    #[inline]
    pub fn conn_closed(&self) {
        self.cp_conns.fetch_sub(1, Ordering::Relaxed);
    }

    /// A reactor was unparked by `n` wakeup deliveries.
    #[inline]
    pub fn record_wakeups(&self, n: u64) {
        self.cp_wakeups.fetch_add(n, Ordering::Relaxed);
    }

    /// A connection's write buffer hit `WouldBlock` and re-armed write
    /// interest (one count per stall).
    #[inline]
    pub fn record_partial_write(&self) {
        self.cp_partial_writes.fetch_add(1, Ordering::Relaxed);
    }

    /// A shard worker retuned its adaptive drain bound.
    #[inline]
    pub fn record_adaptive_k(&self, k: u64) {
        self.k_last.store(k, Ordering::Relaxed);
        self.k_lo.fetch_min(k, Ordering::Relaxed);
        self.k_hi.fetch_max(k, Ordering::Relaxed);
    }

    /// Smallest adaptive drain bound any worker ever reported (0 before
    /// the first report).
    pub fn k_lo(&self) -> u64 {
        let v = self.k_lo.load(Ordering::Relaxed);
        if v == u64::MAX {
            0
        } else {
            v
        }
    }

    /// Largest adaptive drain bound any worker ever reported.
    pub fn k_hi(&self) -> u64 {
        self.k_hi.load(Ordering::Relaxed)
    }

    /// Most recent adaptive drain bound (gauge).
    pub fn k_last(&self) -> u64 {
        self.k_last.load(Ordering::Relaxed)
    }

    #[inline]
    pub fn record_latency(&self, d: Duration) {
        let ns = d.as_nanos().max(1) as u64;
        let b = (63 - ns.leading_zeros() as usize).min(BUCKETS - 1);
        self.latency[b].fetch_add(1, Ordering::Relaxed);
    }

    pub fn ops_total(&self) -> u64 {
        self.gets.load(Ordering::Relaxed)
            + self.puts.load(Ordering::Relaxed)
            + self.dels.load(Ordering::Relaxed)
    }

    /// Latency quantile estimate from the histogram (upper bucket bound).
    pub fn latency_quantile(&self, q: f64) -> Duration {
        let total: u64 = self.latency.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.latency.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_nanos(1 << (i + 1));
            }
        }
        Duration::from_nanos(1 << BUCKETS)
    }

    pub fn report(&self) -> String {
        let (batches, batch_ops, max_batch) = self.batch_view();
        let avg_batch = if batches > 0 { batch_ops as f64 / batches as f64 } else { 0.0 };
        let mut out = format!(
            "ops={} gets={} (hits {}) puts={} (new {}) dels={} (hit {}) p50<={:?} p99<={:?} batches={} avg_batch={:.1} max_batch={}",
            self.ops_total(),
            self.gets.load(Ordering::Relaxed),
            self.get_hits.load(Ordering::Relaxed),
            self.puts.load(Ordering::Relaxed),
            self.put_new.load(Ordering::Relaxed),
            self.dels.load(Ordering::Relaxed),
            self.del_hit.load(Ordering::Relaxed),
            self.latency_quantile(0.5),
            self.latency_quantile(0.99),
            batches,
            avg_batch,
            max_batch,
        );
        if self.k_hi.load(Ordering::Relaxed) > 0 {
            out.push_str(&format!(
                " adaptk=[last={} lo={} hi={}]",
                self.k_last(),
                self.k_lo(),
                self.k_hi()
            ));
        }
        if self.rl_runs.load(Ordering::Relaxed) > 0 {
            out.push_str(&format!(
                " readlane=[runs={} ops={} fences={} flushes={}]",
                self.rl_runs.load(Ordering::Relaxed),
                self.rl_ops.load(Ordering::Relaxed),
                self.rl_fences.load(Ordering::Relaxed),
                self.rl_flushes.load(Ordering::Relaxed),
            ));
        }
        if self.sl_runs.load(Ordering::Relaxed) > 0 {
            out.push_str(&format!(
                " scanlane=[runs={} ops={} fences={} flushes={}]",
                self.sl_runs.load(Ordering::Relaxed),
                self.sl_ops.load(Ordering::Relaxed),
                self.sl_fences.load(Ordering::Relaxed),
                self.sl_flushes.load(Ordering::Relaxed),
            ));
        }
        if self.fence_ops.load(Ordering::Relaxed) > 0 {
            out.push_str(&format!(
                " fences=[ops={} fences={} flushes={} elided={}]",
                self.fence_ops.load(Ordering::Relaxed),
                self.fences_total.load(Ordering::Relaxed),
                self.flushes_total.load(Ordering::Relaxed),
                self.fences_elided.load(Ordering::Relaxed),
            ));
        }
        if self.cp_workers.load(Ordering::Relaxed) > 0 {
            out.push_str(&format!(
                " connplane=[workers={} conns={} wakeups={} partial_writes={}]",
                self.cp_workers.load(Ordering::Relaxed),
                self.cp_conns.load(Ordering::Relaxed),
                self.cp_wakeups.load(Ordering::Relaxed),
                self.cp_partial_writes.load(Ordering::Relaxed),
            ));
        }
        let rolled = self.rolled_forward.load(Ordering::Relaxed);
        if self.atomics.load(Ordering::Relaxed) > 0 || rolled > 0 {
            out.push_str(&format!(
                " txn=[atomics={} ops={} rolled_forward={}]",
                self.atomics.load(Ordering::Relaxed),
                self.atomic_ops.load(Ordering::Relaxed),
                rolled,
            ));
        }
        // durcheck gauge: only non-zero when the checker is armed (sim
        // mode), so served Perf runs never show it.
        let chk = crate::pmem::check::snapshot();
        if chk.events > 0 {
            out.push_str(&format!(
                " check=[events={} violations={} redundant_flushes={}]",
                chk.events, chk.violations, chk.redundant_flushes,
            ));
        }
        // Allocator gauge: live areas / slots + the compaction counters
        // (process-wide, like the durcheck gauge). Silent until the first
        // durable area exists, so pure-volatile servers don't show it.
        let al = crate::alloc::gauge();
        if al.areas > 0 || al.returned > 0 {
            out.push_str(&format!(
                " alloc=[areas={} live_slots={} frag_pct={} compactions={} returned={}]",
                al.areas,
                al.live_slots,
                al.frag_pct(),
                al.compactions,
                al.returned,
            ));
        }
        if self.rec_shards.load(Ordering::Relaxed) > 0 {
            let ms = |c: &AtomicU64| c.load(Ordering::Relaxed) as f64 / 1000.0;
            out.push_str(&format!(
                " recovery=[shards={} members={} reclaimed={} wall={:.1}ms scan={:.1}ms sort={:.1}ms relink={:.1}ms threads={} accel={} evicted={}]",
                self.rec_shards.load(Ordering::Relaxed),
                self.rec_members.load(Ordering::Relaxed),
                self.rec_reclaimed.load(Ordering::Relaxed),
                ms(&self.rec_wall_us),
                ms(&self.rec_scan_us),
                ms(&self.rec_sort_us),
                ms(&self.rec_relink_us),
                self.rec_threads.load(Ordering::Relaxed),
                self.rec_accelerated.load(Ordering::Relaxed) != 0,
                self.rec_evicted.load(Ordering::Relaxed),
            ));
        }
        out
    }

    /// [`Metrics::report`] plus per-shard resizable-hash growth stats
    /// (`None` entries — volatile or list shards — are skipped).
    pub fn report_with_growth(&self, growth: &[Option<GrowthStats>]) -> String {
        let mut out = self.report();
        let mut any = false;
        for (i, g) in growth.iter().enumerate() {
            if let Some(g) = g {
                out.push_str(if any { "; " } else { " growth=[" });
                any = true;
                out.push_str(&format!(
                    "s{}:buckets={} doublings={} load={:.2}",
                    i,
                    g.buckets,
                    g.doublings,
                    g.chain_load()
                ));
            }
        }
        if any {
            out.push(']');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let m = Metrics::new();
        for i in 0..1000u64 {
            m.record_latency(Duration::from_nanos(100 + i * 10));
        }
        let p50 = m.latency_quantile(0.5);
        let p99 = m.latency_quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= Duration::from_nanos(100));
        assert!(p99 <= Duration::from_millis(1));
    }

    #[test]
    fn counters_report() {
        let m = Metrics::new();
        m.gets.fetch_add(3, Ordering::Relaxed);
        m.puts.fetch_add(2, Ordering::Relaxed);
        m.dels.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.ops_total(), 6);
        assert!(m.report().contains("ops=6"));
    }

    #[test]
    fn empty_histogram_quantile_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn record_op_classifies_results() {
        let m = Metrics::new();
        m.record_op(SetOp::Insert(1, 1), OpResult::Applied(true));
        m.record_op(SetOp::Insert(1, 1), OpResult::Applied(false));
        m.record_op(SetOp::Get(1), OpResult::Value(Some(1)));
        m.record_op(SetOp::Contains(2), OpResult::Found(false));
        m.record_op(SetOp::Remove(1), OpResult::Applied(true));
        assert_eq!(m.ops_total(), 5);
        assert_eq!(m.puts.load(Ordering::Relaxed), 2);
        assert_eq!(m.put_new.load(Ordering::Relaxed), 1);
        assert_eq!(m.gets.load(Ordering::Relaxed), 2);
        assert_eq!(m.get_hits.load(Ordering::Relaxed), 1);
        assert_eq!(m.del_hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn recovery_report_renders_after_record() {
        let m = Metrics::new();
        assert!(!m.report().contains("recovery=["), "no recovery recorded yet");
        let r = crate::coordinator::recovery::RecoveryReport {
            shards: 2,
            members: 10,
            reclaimed: 4,
            wall: Duration::from_millis(5),
            threads: 8,
            scan: Duration::from_millis(3),
            sort: Duration::from_millis(1),
            relink: Duration::from_millis(1),
            accelerated: false,
            evicted_lines: 7,
            txn_rolled_forward: 0,
        };
        m.record_recovery(&r);
        let s = m.report();
        assert!(s.contains("recovery=[shards=2 members=10 reclaimed=4 wall=5.0ms"), "{s}");
        assert!(s.contains("threads=8 accel=false evicted=7]"), "{s}");
    }

    #[test]
    fn scan_lane_counters_record_and_render() {
        let m = Metrics::new();
        assert!(!m.report().contains("scanlane=["), "silent until first burst");
        m.record_scan_lane(16, 0, 0);
        m.record_scan_lane(3, 0, 0);
        assert_eq!(m.sl_runs.load(Ordering::Relaxed), 2);
        assert_eq!(m.sl_ops.load(Ordering::Relaxed), 19);
        assert_eq!(m.sl_fences.load(Ordering::Relaxed), 0);
        assert_eq!(m.sl_flushes.load(Ordering::Relaxed), 0);
        let s = m.report();
        assert!(s.contains("scanlane=[runs=2 ops=19 fences=0 flushes=0]"), "{s}");
    }

    /// Regression companion to the resizable `len_approx` churn test:
    /// batch metrics and the adaptive-K gauge must stay cumulative and
    /// race-free while `STATS` is polled concurrently — no torn averages,
    /// no shrinking maxima, no envelope inversions.
    #[test]
    fn stats_counters_stay_cumulative_under_concurrent_polling() {
        use std::sync::atomic::AtomicBool;
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        // 3 writers: group commits of growing size + adaptive-K walks.
        let writers: Vec<_> = (0..3u64)
            .map(|t| {
                let m = m.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut n = 1 + t;
                    let mut iters = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        m.record_group(n % 512 + 1);
                        m.record_adaptive_k(((n % 9) + 1) * 8);
                        m.record_op(SetOp::Insert(n, n), OpResult::Applied(true));
                        m.record_read_lane(4, 0, 0);
                        n = n.wrapping_mul(7).wrapping_add(3);
                        iters += 1;
                    }
                    iters
                })
            })
            .collect();
        // 4 pollers: every sampled value must be monotone vs the previous
        // sample of the same poller, and internally consistent.
        let pollers: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let (mut last_batches, mut last_ops, mut last_max) = (0u64, 0u64, 0u64);
                    let mut last_hi = 0u64;
                    let mut last_lo = u64::MAX;
                    while !stop.load(Ordering::Relaxed) {
                        let (b, o, mx) = m.batch_view();
                        assert!(b >= last_batches, "batches went backwards");
                        assert!(o >= last_ops, "batch_ops went backwards");
                        assert!(mx >= last_max, "max_batch went backwards");
                        if b > 0 {
                            // batch_view loads in the reverse of
                            // record_group's write order, so the derived
                            // average can never exceed the cumulative max.
                            let avg = o as f64 / b as f64;
                            assert!(
                                avg <= mx as f64 + 1e-9,
                                "torn avg {avg} > max {mx} (b={b} o={o})"
                            );
                        }
                        let hi = m.k_hi();
                        let lo = m.k_lo();
                        assert!(hi >= last_hi, "k_hi went backwards");
                        if lo > 0 {
                            assert!(lo <= last_lo, "k_lo went forwards");
                            // Envelope check once both ends exist (the very
                            // first record's min can land before its max).
                            if hi > 0 {
                                assert!(lo <= hi, "gauge envelope inverted");
                            }
                            last_lo = lo;
                        }
                        last_hi = hi;
                        (last_batches, last_ops, last_max) = (b, o, mx);
                        // The rendered line must never panic or tear.
                        let r = m.report();
                        assert!(r.contains("adaptk=[") || hi == 0, "{r}");
                    }
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(120));
        stop.store(true, Ordering::Relaxed);
        let total: u64 = writers.into_iter().map(|w| w.join().unwrap()).sum();
        for p in pollers {
            p.join().unwrap();
        }
        assert!(total > 0);
        assert_eq!(m.batches.load(Ordering::Relaxed), total);
        assert_eq!(m.rl_runs.load(Ordering::Relaxed), total);
        assert_eq!(m.rl_ops.load(Ordering::Relaxed), total * 4);
    }

    #[test]
    fn fence_gauge_renders_only_after_update_commits() {
        let m = Metrics::new();
        assert!(!m.report().contains("fences=["), "silent until first commit");
        m.record_fences(64, &PmemStats { flushes: 64, fences: 1, elided: 64 });
        m.record_fences(1, &PmemStats { flushes: 1, fences: 1, elided: 1 });
        let r = m.report();
        assert!(r.contains("fences=[ops=65 fences=2 flushes=65 elided=65]"), "{r}");
    }

    #[test]
    fn alloc_gauge_renders_once_areas_exist() {
        // The gauge is process-global: force at least one durable area,
        // then the STATS line must carry the alloc section in its fixed
        // field order. (Exact numbers depend on sibling tests.)
        let set = crate::sets::new_hash(crate::sets::Family::LinkFree, 16);
        assert!(set.insert(1, 1));
        let r = Metrics::new().report();
        assert!(r.contains(" alloc=[areas="), "{r}");
        assert!(r.contains(" live_slots="), "{r}");
        assert!(r.contains(" frag_pct="), "{r}");
        assert!(r.contains(" compactions="), "{r}");
        assert!(r.contains(" returned="), "{r}");
    }

    #[test]
    fn connplane_gauge_renders_only_when_event_plane_is_on() {
        let m = Metrics::new();
        assert!(!m.report().contains("connplane=["), "off by default");
        m.conn_opened();
        m.record_wakeups(3);
        m.record_partial_write();
        assert!(!m.report().contains("connplane=["), "gated on workers, not traffic");
        m.set_conn_workers(4);
        m.conn_opened();
        m.conn_closed();
        let r = m.report();
        assert!(r.contains("connplane=[workers=4 conns=1 wakeups=3 partial_writes=1]"), "{r}");
    }

    #[test]
    fn group_and_growth_reporting() {
        let m = Metrics::new();
        m.record_group(10);
        m.record_group(30);
        let r = m.report();
        assert!(r.contains("batches=2"), "{r}");
        assert!(r.contains("avg_batch=20.0"), "{r}");
        assert!(r.contains("max_batch=30"), "{r}");
        let growth = vec![
            Some(GrowthStats { buckets: 64, doublings: 5, items: 128 }),
            None,
            Some(GrowthStats { buckets: 32, doublings: 4, items: 32 }),
        ];
        let rg = m.report_with_growth(&growth);
        assert!(rg.contains("growth=[s0:buckets=64 doublings=5 load=2.00; s2:buckets=32"), "{rg}");
        // No growth section when no shard reports stats (the line may
        // still carry process-global gauges like alloc=[…]).
        assert!(!m.report_with_growth(&[None, None]).contains("growth=["));
    }
}
