//! TCP front: line protocol over the queued shard workers.
//!
//! ```text
//! PUT <key> <value>   ->  OK NEW | OK EXISTS
//! GET <key>           ->  FOUND <value> | MISSING
//! DEL <key>           ->  OK DELETED | OK ABSENT
//! LEN                 ->  LEN <n>
//! STATS               ->  STATS <metrics line>
//! QUIT                ->  BYE (closes connection)
//! ```
//!
//! Thread-per-connection (std::net; the offline crate set has no async
//! runtime), routing each request onto the owning shard's bounded queue —
//! the queue bound is the service's backpressure.

use super::shard::{Request, Response, ShardWorker};
use super::{DuraKv, Router};
use anyhow::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Arc;

/// Adapter giving a shard's set a `'static` handle via the Arc'd store.
struct ShardRef {
    kv: Arc<DuraKv>,
    index: usize,
}

impl crate::sets::ConcurrentSet for ShardRef {
    fn insert(&self, key: u64, value: u64) -> bool {
        self.kv.shard_set(self.index).insert(key, value)
    }
    fn remove(&self, key: u64) -> bool {
        self.kv.shard_set(self.index).remove(key)
    }
    fn contains(&self, key: u64) -> bool {
        self.kv.shard_set(self.index).contains(key)
    }
    fn get(&self, key: u64) -> Option<u64> {
        self.kv.shard_set(self.index).get(key)
    }
    fn len_approx(&self) -> usize {
        self.kv.shard_set(self.index).len_approx()
    }
}

/// A running server; dropping it stops the accept loop and the workers.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_join: Option<std::thread::JoinHandle<()>>,
    _workers: Vec<ShardWorker>,
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
    }
}

/// Start serving `kv` on `127.0.0.1:port` (port 0 = ephemeral, for tests).
pub fn serve(kv: Arc<DuraKv>, port: u16) -> Result<Server> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;

    let workers: Vec<ShardWorker> = (0..kv.config().shards)
        .map(|i| {
            let set: Arc<dyn crate::sets::ConcurrentSet> =
                Arc::new(ShardRef { kv: kv.clone(), index: i });
            ShardWorker::spawn(set, kv.metrics.clone())
        })
        .collect();
    let senders: Arc<Vec<SyncSender<Request>>> =
        Arc::new(workers.iter().map(|w| w.tx.clone()).collect());

    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let kv2 = kv.clone();
    let accept_join = std::thread::spawn(move || {
        let router = kv2.router();
        while !stop2.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let senders = senders.clone();
                    let kv = kv2.clone();
                    std::thread::spawn(move || {
                        let _ = handle_conn(stream, router, &senders, &kv);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    });

    Ok(Server { addr, stop, accept_join: Some(accept_join), _workers: workers })
}

fn handle_conn(
    stream: TcpStream,
    router: Router,
    senders: &[SyncSender<Request>],
    kv: &DuraKv,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let (rtx, rrx) = sync_channel::<Response>(1);
    for line in reader.lines() {
        let line = line?;
        let mut parts = line.split_ascii_whitespace();
        let cmd = parts.next().unwrap_or("").to_ascii_uppercase();
        let reply = match cmd.as_str() {
            "PUT" => match (parse_u64(parts.next()), parse_u64(parts.next())) {
                (Some(k), Some(v)) => {
                    senders[router.shard_of(k)].send(Request::Put(k, v, rtx.clone()))?;
                    match rrx.recv()? {
                        Response::Ok(true) => "OK NEW".to_string(),
                        _ => "OK EXISTS".to_string(),
                    }
                }
                _ => "ERR usage: PUT <key> <value>".to_string(),
            },
            "GET" => match parse_u64(parts.next()) {
                Some(k) => {
                    senders[router.shard_of(k)].send(Request::Get(k, rtx.clone()))?;
                    match rrx.recv()? {
                        Response::Found(v) => format!("FOUND {v}"),
                        _ => "MISSING".to_string(),
                    }
                }
                None => "ERR usage: GET <key>".to_string(),
            },
            "DEL" => match parse_u64(parts.next()) {
                Some(k) => {
                    senders[router.shard_of(k)].send(Request::Del(k, rtx.clone()))?;
                    match rrx.recv()? {
                        Response::Ok(true) => "OK DELETED".to_string(),
                        _ => "OK ABSENT".to_string(),
                    }
                }
                None => "ERR usage: DEL <key>".to_string(),
            },
            "LEN" => format!("LEN {}", kv.len_approx()),
            "STATS" => format!("STATS {}", kv.metrics.report()),
            "QUIT" => {
                writeln!(writer, "BYE")?;
                break;
            }
            "" => continue,
            other => format!("ERR unknown command '{other}'"),
        };
        writeln!(writer, "{reply}")?;
    }
    Ok(())
}

fn parse_u64(s: Option<&str>) -> Option<u64> {
    s.and_then(|x| x.parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use std::io::{BufRead, BufReader, Write};

    /// One connection: keep a single BufReader (read-ahead safe).
    struct Client {
        writer: TcpStream,
        reader: BufReader<TcpStream>,
    }

    impl Client {
        fn connect(addr: std::net::SocketAddr) -> Client {
            let stream = TcpStream::connect(addr).unwrap();
            let reader = BufReader::new(stream.try_clone().unwrap());
            Client { writer: stream, reader }
        }

        fn send(&mut self, line: &str) -> String {
            writeln!(self.writer, "{line}").unwrap();
            let mut out = String::new();
            self.reader.read_line(&mut out).unwrap();
            out.trim_end().to_string()
        }
    }

    #[test]
    fn tcp_protocol_round_trip() {
        let mut cfg = Config::default();
        cfg.shards = 2;
        cfg.key_range = 1024;
        cfg.psync_ns = 0;
        let kv = Arc::new(DuraKv::create(cfg));
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);

        assert_eq!(c.send("PUT 5 50"), "OK NEW");
        assert_eq!(c.send("PUT 5 51"), "OK EXISTS");
        assert_eq!(c.send("GET 5"), "FOUND 50");
        assert_eq!(c.send("DEL 5"), "OK DELETED");
        assert_eq!(c.send("DEL 5"), "OK ABSENT");
        assert_eq!(c.send("GET 5"), "MISSING");
        assert_eq!(c.send("PUT 7 70"), "OK NEW");
        assert_eq!(c.send("LEN"), "LEN 1");
        assert!(c.send("STATS").starts_with("STATS ops="));
        assert!(c.send("NOPE").starts_with("ERR"));
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    #[test]
    fn concurrent_tcp_clients() {
        let mut cfg = Config::default();
        cfg.shards = 2;
        cfg.key_range = 4096;
        cfg.psync_ns = 0;
        let kv = Arc::new(DuraKv::create(cfg));
        let server = serve(kv.clone(), 0).unwrap();
        let addr = server.addr;
        let handles: Vec<_> = (0..4u64)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr);
                    for i in 0..100u64 {
                        let k = t * 1000 + i;
                        assert_eq!(c.send(&format!("PUT {k} {i}")), "OK NEW");
                        assert_eq!(c.send(&format!("GET {k}")), format!("FOUND {i}"));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(kv.len_approx(), 400);
        drop(server);
    }
}
