//! TCP front: a typed **three-lane op plane** over the adaptive
//! group-committing shard workers.
//!
//! ```text
//! PUT <key> <value>   ->  OK NEW | OK EXISTS
//! GET <key>           ->  FOUND <value> | MISSING
//! HAS <key>           ->  YES | NO
//! DEL <key>           ->  OK DELETED | OK ABSENT
//! RANGE <lo> <hi>     ->  RANGE <n>, then n "<key> <value>" lines in
//!                         key order (inclusive bounds; skiplist only)
//! SCAN <cursor> <n>   ->  SCAN <m>, then m "<key> <value>" lines: the
//!                         first m <= n keys strictly above <cursor>
//! MULTI <n> [ATOMIC]  ->  (no reply; the next n lines are queued ops)
//! EXEC                ->  n reply lines, one per queued op, in order
//!                         (n = 0: a single "OK EMPTY" ack)
//! LEN                 ->  LEN <n>
//! STATS               ->  STATS <metrics + growth line>
//! QUIT                ->  BYE (closes connection)
//! ```
//!
//! **Pipelining.** A connection handler does not process one line per
//! socket read: after the first read it also consumes every further
//! complete line already buffered and parses the whole burst. Replies to
//! a burst are written (in line order) only after every op in it
//! resolved. `LEN`/`STATS` inside a burst are resolved after the burst's
//! data ops (both are approximate snapshots).
//!
//! **Write lane.** Updates (PUT/DEL) route as **one [`Request::Batch`]
//! per shard** through the worker queues; combined with the workers' own
//! adaptive draining, a busy connection pays one queue hop and ~1/K of a
//! fence per op instead of one each.
//!
//! **Read lane (DESIGN.md §ReadPath).** Pure reads (GET/HAS) never touch
//! a shard queue: after the burst's write batches have drained — which
//! preserves per-connection read-your-writes — the burst's reads execute
//! *directly* on the shared set handles via the coalesced
//! `contains_batch`/`get_batch` sweeps, one virtual call per shard per
//! kind. Reads are lock-free and fence-free in every family, so the lane
//! issues **zero psyncs** (metered per burst into `Metrics::rl_*` and
//! pinned by tests; SOFT unconditionally, link-free/log-free may pay
//! read-side helping psyncs only when racing in-flight updates). A
//! burst with no writes therefore costs no queue hop at all.
//!
//! **Scan lane (DESIGN.md §OrderedReads).** Ordered reads (RANGE/SCAN,
//! skiplist stores only) form a third lane resolved after the read lane:
//! the burst's ordered queries fan out as one **merge-walk**
//! (`OrderedSet::range_batch`) per shard — one EBR pin and one tower
//! descent serving every window — and the per-shard sorted runs are
//! k-way merged back into reply order. The walk is flush-free by
//! construction (`walk_from` never helps-flush), so the lane's
//! `Metrics::sl_fences`/`sl_flushes` are pinned at zero. It runs after
//! the burst's write batches drain, so read-your-writes extends to
//! ordered reads.
//!
//! **Explicit batches.** `MULTI <n>` queues the next `n` PUT/GET/HAS/DEL
//! lines without replying, `EXEC` routes them like a pipelined burst and
//! emits the `n` replies. A malformed frame yields a single ERR line.
//! `MULTI <n> ATOMIC` instead executes the frame as an **atomic
//! cross-shard batch** (two-phase commit over the persisted commit
//! record, `coordinator::txn`): a crash recovers all of its updates or
//! none. A malformed atomic frame aborts whole (one ERR line, nothing
//! executed).
//!
//! **Connection plane (DESIGN.md §ConnectionPlane).** Connections are
//! served by a small pool of event-loop reactor workers
//! (`event_workers`, validated into 1..=64) over nonblocking sockets:
//! the acceptor admits (one shared `max_conns` counter for the whole
//! pool) and round-robins sockets over the reactors; each reactor
//! multiplexes its connections' state machines ([`super::conn::Conn`]),
//! and shard completions wake the owning reactor
//! ([`super::shard::BatchSink`]) instead of unparking a per-connection
//! thread — so 10k idle connections cost buffers, not stacks. The
//! per-shard queue bound remains the service's backpressure.

use super::reactor::ReactorPool;
use super::shard::{GroupTuning, Request, ShardWorker};
use super::DuraKv;
use anyhow::Result;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::SyncSender;
use std::sync::Arc;

/// Adapter giving a shard's set a `'static` handle via the Arc'd store.
struct ShardRef {
    kv: Arc<DuraKv>,
    index: usize,
}

impl crate::sets::ConcurrentSet for ShardRef {
    fn insert(&self, key: u64, value: u64) -> bool {
        self.kv.shard_set(self.index).insert(key, value)
    }
    fn remove(&self, key: u64) -> bool {
        self.kv.shard_set(self.index).remove(key)
    }
    fn contains(&self, key: u64) -> bool {
        self.kv.shard_set(self.index).contains(key)
    }
    fn get(&self, key: u64) -> Option<u64> {
        self.kv.shard_set(self.index).get(key)
    }
    fn len_approx(&self) -> usize {
        self.kv.shard_set(self.index).len_approx()
    }
    fn apply_batch(&self, ops: &[crate::sets::SetOp]) -> Vec<crate::sets::OpResult> {
        // Forward as a batch so the underlying durable set coalesces the
        // fences (the default would loop over un-coalesced singles).
        self.kv.shard_set(self.index).apply_batch(ops)
    }
    fn contains_batch(&self, keys: &[u64]) -> Vec<bool> {
        // Forward the sweep for the same reason as apply_batch.
        self.kv.shard_set(self.index).contains_batch(keys)
    }
    fn get_batch(&self, keys: &[u64]) -> Vec<Option<u64>> {
        self.kv.shard_set(self.index).get_batch(keys)
    }
}

/// A running server; dropping it stops the accept loop, the reactors,
/// and the workers.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_join: Option<std::thread::JoinHandle<()>>,
    pool: Option<ReactorPool>,
    _workers: Vec<ShardWorker>,
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
        if let Some(p) = self.pool.take() {
            p.shutdown();
        }
    }
}

/// Start serving `kv` on `127.0.0.1:port` (port 0 = ephemeral, for tests).
pub fn serve(kv: Arc<DuraKv>, port: u16) -> Result<Server> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;

    let tuning = GroupTuning {
        k_min: kv.config().group_k_min,
        k_max: kv.config().group_k_max,
    };
    let workers: Vec<ShardWorker> = (0..kv.config().shards)
        .map(|i| {
            let set: Arc<dyn crate::sets::ConcurrentSet> =
                Arc::new(ShardRef { kv: kv.clone(), index: i });
            ShardWorker::spawn_with(set, kv.metrics.clone(), tuning)
        })
        .collect();
    let senders: Arc<Vec<SyncSender<Request>>> =
        Arc::new(workers.iter().map(|w| w.tx.clone()).collect());

    let max_conns = kv.config().max_conns;
    let event_workers = kv.config().event_workers;
    let live_conns = Arc::new(AtomicUsize::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    kv.metrics.set_conn_workers(event_workers as u64);
    let pool = ReactorPool::spawn(
        event_workers,
        kv.clone(),
        senders,
        live_conns.clone(),
        stop.clone(),
    );
    let handle = pool.handle();

    let stop2 = stop.clone();
    let accept_join = std::thread::spawn(move || {
        while !stop2.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _)) => {
                    // Admission control lives in the acceptor: one shared
                    // counter bounds the whole reactor pool, and a reactor
                    // decrements it when a connection retires.
                    if max_conns > 0 && live_conns.load(Ordering::SeqCst) >= max_conns {
                        reject_conn(stream, max_conns);
                        continue;
                    }
                    live_conns.fetch_add(1, Ordering::SeqCst);
                    handle.dispatch(stream);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    });

    Ok(Server { addr, stop, accept_join: Some(accept_join), pool: Some(pool), _workers: workers })
}

/// Refuse a connection over the `max_conns` limit with one ERR line that
/// actually reaches the client. A bare `write + drop` turns into a TCP
/// RST whenever the client already sent bytes we never read (its first
/// command raced our refusal), and an RST discards the in-flight reply —
/// the client saw a naked reset instead of the ERR. So: write the line,
/// half-close our sending side (FIN ⇒ the reply + EOF are delivered in
/// order), then briefly drain the client's data so the final close finds
/// an empty receive buffer. The whole exchange runs on a short-lived
/// helper thread (bounded lifetime: ≤ ~20 ms of read timeouts) so a
/// burst of rejections never serializes the accept loop. Deliberate
/// trade-off: a sustained reject flood holds ~rate × 20 ms concurrent
/// drain threads; if the OS refuses a thread we degrade to write+drop
/// (the pre-PR behaviour) rather than killing the accept loop.
fn reject_conn(stream: TcpStream, max_conns: usize) {
    let spawned = std::thread::Builder::new()
        .name("reject-drain".into())
        .spawn(move || {
            use std::io::Read;
            let mut s = stream;
            let _ = writeln!(s, "ERR too many connections (max {max_conns})");
            let _ = s.flush();
            let _ = s.shutdown(std::net::Shutdown::Write);
            let _ = s.set_read_timeout(Some(std::time::Duration::from_millis(10)));
            let mut sink = [0u8; 512];
            // One read for whatever raced the refusal, one for the EOF of
            // a well-behaved client; slower clients forfeit the clean
            // close.
            for _ in 0..2 {
                match s.read(&mut sink) {
                    // EOF: the client closed after reading the ERR — a
                    // clean close on our side cannot RST anything now.
                    Ok(0) | Err(_) => break,
                    Ok(_) => {}
                }
            }
        });
    // Out of threads (the overload this limit exists for): the stream was
    // moved into the failed closure and is dropped with it — the client
    // gets a reset, which is the pre-PR behaviour, and the accept loop
    // stays alive (a bare `thread::spawn` would have panicked it dead).
    let _ = spawned;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use std::io::{BufRead, BufReader, Write};

    /// One connection: keep a single BufReader (read-ahead safe).
    struct Client {
        writer: TcpStream,
        reader: BufReader<TcpStream>,
    }

    impl Client {
        fn connect(addr: std::net::SocketAddr) -> Client {
            let stream = TcpStream::connect(addr).unwrap();
            let reader = BufReader::new(stream.try_clone().unwrap());
            Client { writer: stream, reader }
        }

        fn send(&mut self, line: &str) -> String {
            writeln!(self.writer, "{line}").unwrap();
            self.recv()
        }

        fn recv(&mut self) -> String {
            let mut out = String::new();
            self.reader.read_line(&mut out).unwrap();
            out.trim_end().to_string()
        }
    }

    fn test_kv(shards: usize) -> Arc<DuraKv> {
        let mut cfg = Config::default();
        cfg.shards = shards;
        cfg.key_range = 4096;
        cfg.psync_ns = 0;
        Arc::new(DuraKv::create(cfg))
    }

    #[test]
    fn tcp_protocol_round_trip() {
        let kv = test_kv(2);
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);

        assert_eq!(c.send("PUT 5 50"), "OK NEW");
        assert_eq!(c.send("PUT 5 51"), "OK EXISTS");
        assert_eq!(c.send("GET 5"), "FOUND 50");
        assert_eq!(c.send("DEL 5"), "OK DELETED");
        assert_eq!(c.send("DEL 5"), "OK ABSENT");
        assert_eq!(c.send("GET 5"), "MISSING");
        assert_eq!(c.send("PUT 7 70"), "OK NEW");
        assert_eq!(c.send("LEN"), "LEN 1");
        assert!(c.send("STATS").starts_with("STATS ops="));
        assert!(c.send("STATS").contains("growth=["), "growth stats on STATS");
        assert!(c.send("NOPE").starts_with("ERR"));
        assert!(c.send("PUT x").starts_with("ERR usage"));
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    #[test]
    fn has_verb_round_trip() {
        let kv = test_kv(2);
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);
        assert_eq!(c.send("PUT 9 90"), "OK NEW");
        assert_eq!(c.send("HAS 9"), "YES");
        assert_eq!(c.send("HAS 10"), "NO");
        assert_eq!(c.send("DEL 9"), "OK DELETED");
        assert_eq!(c.send("HAS 9"), "NO");
        assert!(c.send("HAS x").starts_with("ERR usage: HAS"));
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    /// Ordered-tier read-your-writes over the wire: RANGE/SCAN pipelined
    /// behind PUTs must observe them — the scan lane resolves only after
    /// the burst's write batches drained, and replies keep line order
    /// under any TCP burst split.
    #[test]
    fn range_reads_observe_pipelined_writes() {
        let mut cfg = Config::default();
        cfg.shards = 4;
        cfg.key_range = 4096;
        cfg.psync_ns = 0;
        cfg.family = crate::sets::Family::LinkFree;
        cfg.structure = crate::config::Structure::SkipList;
        let kv = Arc::new(DuraKv::create(cfg));
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);
        let mut burst = String::new();
        for k in 10..30u64 {
            burst.push_str(&format!("PUT {k} {}\n", k + 100));
        }
        burst.push_str("RANGE 15 20\nSCAN 25 3\n");
        c.writer.write_all(burst.as_bytes()).unwrap();
        c.writer.flush().unwrap();
        for _ in 10..30 {
            assert_eq!(c.recv(), "OK NEW");
        }
        assert_eq!(c.recv(), "RANGE 6");
        for k in 15..=20u64 {
            assert_eq!(c.recv(), format!("{k} {}", k + 100), "RYW for key {k}");
        }
        assert_eq!(c.recv(), "SCAN 3");
        for k in 26..=28u64 {
            assert_eq!(c.recv(), format!("{k} {}", k + 100), "RYW past cursor for key {k}");
        }
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    /// The ordered-tier pin, through the wire: a pure-scan burst must
    /// resolve on the scan lane (no shard queue) with **zero** psyncs —
    /// asserted on the `Metrics::sl_*` counters the scan-bench CI gate
    /// also enforces.
    #[test]
    fn scan_lane_burst_is_psync_free() {
        let mut cfg = Config::default();
        cfg.shards = 2;
        cfg.key_range = 4096;
        cfg.psync_ns = 0;
        cfg.family = crate::sets::Family::Soft;
        cfg.structure = crate::config::Structure::SkipList;
        let kv = Arc::new(DuraKv::create(cfg));
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);
        for k in 0..64u64 {
            assert_eq!(c.send(&format!("PUT {k} {}", k + 1)), "OK NEW");
        }
        let batches_before = kv.metrics.batches.load(std::sync::atomic::Ordering::Relaxed);
        let mut burst = String::new();
        for start in 0..32u64 {
            burst.push_str(&format!("RANGE {start} {}\n", start + 1));
        }
        c.writer.write_all(burst.as_bytes()).unwrap();
        c.writer.flush().unwrap();
        for start in 0..32u64 {
            assert_eq!(c.recv(), "RANGE 2", "window {start}");
            assert_eq!(c.recv(), format!("{start} {}", start + 1));
            assert_eq!(c.recv(), format!("{} {}", start + 1, start + 2));
        }
        use std::sync::atomic::Ordering;
        assert_eq!(
            kv.metrics.batches.load(Ordering::Relaxed),
            batches_before,
            "a pure-scan burst must not touch the shard workers"
        );
        assert!(kv.metrics.sl_runs.load(Ordering::Relaxed) >= 1, "scan lane engaged");
        assert_eq!(kv.metrics.sl_ops.load(Ordering::Relaxed), 32);
        assert_eq!(kv.metrics.sl_fences.load(Ordering::Relaxed), 0, "scan lane fenced!");
        assert_eq!(kv.metrics.sl_flushes.load(Ordering::Relaxed), 0, "scan lane flushed!");
        let stats = c.send("STATS");
        assert!(stats.contains("scanlane=[runs="), "{stats}");
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    /// The tentpole pin: a pure-read burst must execute on the read lane
    /// (no shard queue) and issue **zero** psyncs — asserted through the
    /// wire on the `STATS` read-lane counters (SOFT: reads are
    /// unconditionally fence-free).
    #[test]
    fn read_lane_burst_is_psync_free_and_bypasses_workers() {
        let mut cfg = Config::default();
        cfg.shards = 2;
        cfg.key_range = 4096;
        cfg.psync_ns = 0;
        cfg.family = crate::sets::Family::Soft;
        let kv = Arc::new(DuraKv::create(cfg));
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);
        for k in 0..64u64 {
            assert_eq!(c.send(&format!("PUT {k} {}", k + 1)), "OK NEW");
        }
        let batches_before = kv.metrics.batches.load(std::sync::atomic::Ordering::Relaxed);
        // One pure-read pipelined burst: GET + HAS interleaved.
        let mut burst = String::new();
        for k in 0..128u64 {
            if k % 2 == 0 {
                burst.push_str(&format!("GET {k}\n"));
            } else {
                burst.push_str(&format!("HAS {k}\n"));
            }
        }
        c.writer.write_all(burst.as_bytes()).unwrap();
        c.writer.flush().unwrap();
        for k in 0..128u64 {
            let want = match (k % 2 == 0, k < 64) {
                (true, true) => format!("FOUND {}", k + 1),
                (true, false) => "MISSING".to_string(),
                (false, true) => "YES".to_string(),
                (false, false) => "NO".to_string(),
            };
            assert_eq!(c.recv(), want, "reply {k}");
        }
        use std::sync::atomic::Ordering;
        assert_eq!(
            kv.metrics.batches.load(Ordering::Relaxed),
            batches_before,
            "a pure-read burst must not touch the shard workers"
        );
        assert!(kv.metrics.rl_runs.load(Ordering::Relaxed) >= 1, "read lane engaged");
        assert_eq!(kv.metrics.rl_ops.load(Ordering::Relaxed), 128);
        assert_eq!(kv.metrics.rl_fences.load(Ordering::Relaxed), 0, "read lane fenced!");
        assert_eq!(kv.metrics.rl_flushes.load(Ordering::Relaxed), 0, "read lane flushed!");
        let stats = c.send("STATS");
        assert!(stats.contains("readlane=[runs="), "{stats}");
        assert!(stats.contains("ops=") && stats.contains("fences=0 flushes=0]"), "{stats}");
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    /// Per-connection read-your-writes across pipelined bursts: reads
    /// pipelined behind writes — in the same burst and across burst
    /// boundaries — must observe those writes.
    #[test]
    fn read_your_writes_across_pipelined_bursts() {
        let kv = test_kv(4);
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);
        // Mixed burst: every read is pipelined behind the writes it must
        // observe (no later same-key writes, so the expected replies are
        // invariant under any TCP burst split).
        c.writer
            .write_all(b"PUT 1 11\nPUT 2 22\nDEL 2\nGET 1\nHAS 2\nHAS 1\n")
            .unwrap();
        c.writer.flush().unwrap();
        assert_eq!(c.recv(), "OK NEW");
        assert_eq!(c.recv(), "OK NEW");
        assert_eq!(c.recv(), "OK DELETED");
        assert_eq!(c.recv(), "FOUND 11", "read sees this connection's PUT");
        assert_eq!(c.recv(), "NO", "read sees this connection's DEL");
        assert_eq!(c.recv(), "YES");
        // Across bursts: write burst fully acked before the read burst's
        // replies, so the reads must see every write.
        let mut writes = String::new();
        for k in 100..200u64 {
            writes.push_str(&format!("PUT {k} {}\n", k * 2));
        }
        c.writer.write_all(writes.as_bytes()).unwrap();
        c.writer.flush().unwrap();
        let mut reads = String::new();
        for k in 100..200u64 {
            reads.push_str(&format!("GET {k}\n"));
        }
        c.writer.write_all(reads.as_bytes()).unwrap();
        c.writer.flush().unwrap();
        for _ in 100..200 {
            assert_eq!(c.recv(), "OK NEW");
        }
        for k in 100..200u64 {
            assert_eq!(c.recv(), format!("FOUND {}", k * 2), "RYW for key {k}");
        }
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    #[test]
    fn multi_atomic_executes_and_replies_in_order() {
        let kv = test_kv(4);
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);
        writeln!(c.writer, "MULTI 4 ATOMIC").unwrap();
        writeln!(c.writer, "PUT 10 100").unwrap();
        writeln!(c.writer, "PUT 20 200").unwrap();
        writeln!(c.writer, "GET 10").unwrap();
        writeln!(c.writer, "DEL 99").unwrap();
        writeln!(c.writer, "EXEC").unwrap();
        assert_eq!(c.recv(), "OK NEW");
        assert_eq!(c.recv(), "OK NEW");
        assert_eq!(c.recv(), "FOUND 100");
        assert_eq!(c.recv(), "OK ABSENT");
        use std::sync::atomic::Ordering;
        assert_eq!(kv.metrics.atomics.load(Ordering::Relaxed), 1);
        assert_eq!(kv.metrics.atomic_ops.load(Ordering::Relaxed), 4);
        // The record is retired; workers resumed: plain traffic flows.
        assert_eq!(c.send("GET 20"), "FOUND 200");
        // Atomic frames embedded in a pipelined burst keep line order.
        c.writer
            .write_all(b"PUT 30 300\nMULTI 2 ATOMIC\nPUT 40 400\nGET 30\nEXEC\nGET 40\n")
            .unwrap();
        c.writer.flush().unwrap();
        assert_eq!(c.recv(), "OK NEW");
        assert_eq!(c.recv(), "OK NEW");
        assert_eq!(c.recv(), "FOUND 300", "atomic frame reads see prior burst writes");
        assert_eq!(c.recv(), "FOUND 400");
        // Malformed atomic frames abort whole: one ERR, nothing applied.
        writeln!(c.writer, "MULTI 2 ATOMIC").unwrap();
        writeln!(c.writer, "PUT 50 500").unwrap();
        writeln!(c.writer, "LEN").unwrap();
        writeln!(c.writer, "EXEC").unwrap();
        assert!(c.recv().starts_with("ERR ATOMIC aborted"));
        assert_eq!(c.send("HAS 50"), "NO", "aborted frame must apply nothing");
        // Empty atomic frame acks like MULTI 0.
        writeln!(c.writer, "MULTI 0 ATOMIC").unwrap();
        writeln!(c.writer, "EXEC").unwrap();
        assert_eq!(c.recv(), "OK EMPTY");
        assert!(c.send("MULTI 2 NOPE").starts_with("ERR usage: MULTI"));
        let stats = c.send("STATS");
        assert!(stats.contains("txn=[atomics=2"), "{stats}");
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    #[test]
    fn multi_exec_batches() {
        let kv = test_kv(2);
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);

        // MULTI itself and the queued lines produce no replies; EXEC
        // yields one reply per op, in order.
        writeln!(c.writer, "MULTI 3").unwrap();
        writeln!(c.writer, "PUT 1 10").unwrap();
        writeln!(c.writer, "PUT 2 20").unwrap();
        writeln!(c.writer, "GET 1").unwrap();
        writeln!(c.writer, "EXEC").unwrap();
        assert_eq!(c.recv(), "OK NEW");
        assert_eq!(c.recv(), "OK NEW");
        assert_eq!(c.recv(), "FOUND 10");
        assert_eq!(kv.len_approx(), 2);

        // Malformed frames: missing EXEC, non-data op inside the frame.
        writeln!(c.writer, "MULTI 1").unwrap();
        writeln!(c.writer, "PUT 3 30").unwrap();
        writeln!(c.writer, "PUT 4 40").unwrap();
        assert!(c.recv().starts_with("ERR MULTI: expected EXEC"));
        writeln!(c.writer, "MULTI 1").unwrap();
        writeln!(c.writer, "LEN").unwrap();
        writeln!(c.writer, "EXEC").unwrap();
        assert!(c.recv().starts_with("ERR MULTI: not a data op"));
        assert!(c.send("MULTI zzz").starts_with("ERR usage: MULTI"));
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    #[test]
    fn multi_zero_acks_empty_batch() {
        let kv = test_kv(2);
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);
        // `MULTI 0` + EXEC used to queue no ops and emit no reply — the
        // client hung waiting for its EXEC ack.
        writeln!(c.writer, "MULTI 0").unwrap();
        writeln!(c.writer, "EXEC").unwrap();
        assert_eq!(c.recv(), "OK EMPTY", "empty batch must ack, not stall");
        // The connection stays fully usable afterwards.
        assert_eq!(c.send("PUT 1 10"), "OK NEW");
        // And an empty frame embedded in a pipelined burst keeps reply
        // order for the surrounding commands.
        c.writer.write_all(b"PUT 2 20\nMULTI 0\nEXEC\nGET 2\n").unwrap();
        c.writer.flush().unwrap();
        assert_eq!(c.recv(), "OK NEW");
        assert_eq!(c.recv(), "OK EMPTY");
        assert_eq!(c.recv(), "FOUND 20");
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    /// Satellite pin: `reject_conn`'s flush-and-half-close path under the
    /// *reactor* acceptor — excess connections still get the one ERR
    /// line, not a bare RST, with admission enforced per-pool by the
    /// acceptor's shared counter.
    #[test]
    fn rejected_connection_gets_the_err_line_even_if_it_sent_first() {
        let mut cfg = Config::default();
        cfg.shards = 1;
        cfg.key_range = 1024;
        cfg.psync_ns = 0;
        cfg.max_conns = 1;
        let kv = Arc::new(DuraKv::create(cfg));
        let server = serve(kv, 0).unwrap();
        let mut a = Client::connect(server.addr);
        assert_eq!(a.send("PUT 1 1"), "OK NEW"); // connection established
        // Saturated listener: each refused client *sends before reading*
        // — the schedule where a bare write+drop refusal turns into a TCP
        // reset that discards the ERR line mid-flight.
        for i in 0..5 {
            let mut c = Client::connect(server.addr);
            writeln!(c.writer, "GET 1").unwrap();
            let reply = c.recv();
            assert!(
                reply.starts_with("ERR too many connections"),
                "rejected client {i} must read the ERR line, got '{reply}'"
            );
        }
        assert_eq!(a.send("QUIT"), "BYE");
        drop(a);
        drop(server);
    }

    #[test]
    fn slow_multi_frame_does_not_withhold_earlier_replies() {
        // A burst whose tail is an incomplete MULTI frame: the commands
        // before it must be executed and answered before the server
        // blocks waiting for the rest of the frame.
        let kv = test_kv(2);
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);
        c.writer.write_all(b"PUT 1 11\nMULTI 2\n").unwrap();
        c.writer.flush().unwrap();
        assert_eq!(c.recv(), "OK NEW", "pre-MULTI command must not be held hostage");
        c.writer.write_all(b"PUT 2 22\nGET 1\nEXEC\n").unwrap();
        c.writer.flush().unwrap();
        assert_eq!(c.recv(), "OK NEW");
        assert_eq!(c.recv(), "FOUND 11");
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    #[test]
    fn pipelined_burst_replies_in_order() {
        let kv = test_kv(4);
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);
        // Fire the whole burst as one write so it lands in the server's
        // read buffer together: the server must parse it as one burst,
        // batch per shard, and still reply strictly in line order.
        let mut burst = String::new();
        for k in 0..200u64 {
            burst.push_str(&format!("PUT {k} {}\n", k * 2));
        }
        burst.push_str("LEN\n");
        c.writer.write_all(burst.as_bytes()).unwrap();
        c.writer.flush().unwrap();
        for _ in 0..200 {
            assert_eq!(c.recv(), "OK NEW");
        }
        assert_eq!(c.recv(), "LEN 200");
        // Group commit actually engaged: far fewer commits than ops (one
        // per shard per burst; TCP may split the burst a few times).
        let batches = kv.metrics.batches.load(std::sync::atomic::Ordering::Relaxed);
        assert!(batches * 4 <= 200, "200 pipelined puts took {batches} group commits");
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    /// State-machine satellite: a burst fragmented at arbitrary byte
    /// boundaries — including mid-line and mid-burst across separate TCP
    /// sends — must reassemble into the same replies.
    #[test]
    fn partial_line_reads_reassemble_across_tcp_fragments() {
        let kv = test_kv(2);
        let server = serve(kv, 0).unwrap();
        let mut c = Client::connect(server.addr);
        let pause = std::time::Duration::from_millis(30);
        c.writer.write_all(b"PU").unwrap();
        c.writer.flush().unwrap();
        std::thread::sleep(pause);
        c.writer.write_all(b"T 5 50\nGE").unwrap();
        c.writer.flush().unwrap();
        assert_eq!(c.recv(), "OK NEW", "complete line executes; the fragment waits");
        std::thread::sleep(pause);
        c.writer.write_all(b"T 5\n").unwrap();
        c.writer.flush().unwrap();
        assert_eq!(c.recv(), "FOUND 50", "fragmented GET reassembles");
        // A pipelined burst spanning two reads, split mid-line.
        c.writer.write_all(b"HAS 5\nHAS 6\nDEL").unwrap();
        c.writer.flush().unwrap();
        assert_eq!(c.recv(), "YES");
        assert_eq!(c.recv(), "NO");
        std::thread::sleep(pause);
        c.writer.write_all(b" 5\n").unwrap();
        c.writer.flush().unwrap();
        assert_eq!(c.recv(), "OK DELETED");
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    /// State-machine satellite: a slow consumer pipelining far past the
    /// socket buffers (and the server's write high-water mark) must get
    /// every reply, in order — backpressure, not truncation or reorder.
    #[test]
    fn slow_consumer_backpressure_preserves_order() {
        let kv = test_kv(2);
        let server = serve(kv, 0).unwrap();
        let mut c = Client::connect(server.addr);
        assert_eq!(c.send("PUT 7 70"), "OK NEW");
        const N: usize = 60_000;
        let mut w = c.writer.try_clone().unwrap();
        let writer = std::thread::spawn(move || {
            let mut buf = String::with_capacity(N * 6);
            for _ in 0..N {
                buf.push_str("GET 7\n");
            }
            w.write_all(buf.as_bytes()).unwrap();
            w.flush().unwrap();
        });
        // Don't read yet: replies pile up against the socket + the
        // server-side write buffer until its high-water mark pauses
        // reading — then drain and verify order.
        std::thread::sleep(std::time::Duration::from_millis(100));
        for i in 0..N {
            assert_eq!(c.recv(), "FOUND 70", "reply {i}");
        }
        writer.join().unwrap();
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    /// Tentpole gauge: `STATS` reports the connection plane, and a
    /// write's completion demonstrably crossed a reactor wakeup while
    /// read-your-writes held.
    #[test]
    fn connplane_gauge_reports_workers_conns_and_wakeups() {
        let mut cfg = Config::default();
        cfg.shards = 2;
        cfg.key_range = 4096;
        cfg.psync_ns = 0;
        cfg.event_workers = 2;
        let kv = Arc::new(DuraKv::create(cfg));
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);
        assert_eq!(c.send("PUT 1 11"), "OK NEW");
        assert_eq!(c.send("GET 1"), "FOUND 11", "RYW across the completion wakeup");
        use std::sync::atomic::Ordering;
        assert!(
            kv.metrics.cp_wakeups.load(Ordering::Relaxed) >= 1,
            "the write batch must have woken its reactor"
        );
        let stats = c.send("STATS");
        assert!(stats.contains("connplane=[workers=2 conns=1 wakeups="), "{stats}");
        assert!(stats.contains("partial_writes="), "{stats}");
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    /// The scaling claim, in miniature: piling idle connections onto the
    /// event plane must not grow the process's thread count with them
    /// (the legacy plane would add one thread per connection). Measured
    /// as a delta between two batch sizes so concurrent tests only add
    /// noise, not bias.
    #[cfg(target_os = "linux")]
    #[test]
    fn idle_connections_do_not_cost_threads() {
        fn os_threads() -> i64 {
            let s = std::fs::read_to_string("/proc/self/status").unwrap();
            s.lines()
                .find_map(|l| l.strip_prefix("Threads:"))
                .and_then(|v| v.trim().parse().ok())
                .unwrap()
        }
        let mut cfg = Config::default();
        cfg.shards = 2;
        cfg.key_range = 4096;
        cfg.psync_ns = 0;
        cfg.event_workers = 2;
        let kv = Arc::new(DuraKv::create(cfg));
        let server = serve(kv, 0).unwrap();
        let mut held = Vec::new();
        for _ in 0..8 {
            let mut c = Client::connect(server.addr);
            assert_eq!(c.send("HAS 1"), "NO"); // served ⇒ registered
            held.push(c);
        }
        let t1 = os_threads();
        for _ in 0..192 {
            let mut c = Client::connect(server.addr);
            assert_eq!(c.send("HAS 1"), "NO");
            held.push(c);
        }
        let t2 = os_threads();
        assert!(
            t2 - t1 <= 96,
            "+192 idle conns grew the thread count by {} — thread-per-conn is back",
            t2 - t1
        );
        drop(held);
        drop(server);
    }

    #[test]
    fn concurrent_tcp_clients() {
        let kv = test_kv(2);
        let server = serve(kv.clone(), 0).unwrap();
        let addr = server.addr;
        let handles: Vec<_> = (0..4u64)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr);
                    for i in 0..100u64 {
                        let k = t * 1000 + i;
                        assert_eq!(c.send(&format!("PUT {k} {i}")), "OK NEW");
                        assert_eq!(c.send(&format!("GET {k}")), format!("FOUND {i}"));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(kv.len_approx(), 400);
        drop(server);
    }

    #[test]
    fn max_conns_bounds_fanout() {
        let mut cfg = Config::default();
        cfg.shards = 1;
        cfg.key_range = 1024;
        cfg.psync_ns = 0;
        cfg.max_conns = 2;
        let kv = Arc::new(DuraKv::create(cfg));
        let server = serve(kv, 0).unwrap();
        let mut a = Client::connect(server.addr);
        let mut b = Client::connect(server.addr);
        // Establish both connections before probing the limit.
        assert_eq!(a.send("PUT 1 1"), "OK NEW");
        assert_eq!(b.send("GET 1"), "FOUND 1");
        let mut c = Client::connect(server.addr);
        assert!(
            c.recv().starts_with("ERR too many connections"),
            "third connection must be refused"
        );
        // Closing one slot frees capacity for a new connection. The
        // serving side decrements its slot after QUIT, so poll briefly; a
        // still-refused attempt may error on either side of the socket.
        assert_eq!(a.send("QUIT"), "BYE");
        drop(a);
        let mut freed = None;
        for _ in 0..200 {
            let mut d = Client::connect(server.addr);
            let ok = writeln!(d.writer, "GET 1").is_ok();
            let mut reply = String::new();
            if ok && d.reader.read_line(&mut reply).is_ok() && reply.trim_end() == "FOUND 1" {
                freed = Some(d);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let mut d = freed.expect("a freed slot must admit a new connection");
        assert_eq!(d.send("QUIT"), "BYE");
        drop(b);
        drop(server);
    }
}
