//! TCP front: pipelined line protocol over the group-committing shard
//! workers.
//!
//! ```text
//! PUT <key> <value>   ->  OK NEW | OK EXISTS
//! GET <key>           ->  FOUND <value> | MISSING
//! DEL <key>           ->  OK DELETED | OK ABSENT
//! MULTI <n>           ->  (no reply; the next n lines are queued ops)
//! EXEC                ->  n reply lines, one per queued op, in order
//!                         (n = 0: a single "OK EMPTY" ack)
//! LEN                 ->  LEN <n>
//! STATS               ->  STATS <metrics + growth line>
//! QUIT                ->  BYE (closes connection)
//! ```
//!
//! **Pipelining.** A connection handler does not process one line per
//! socket read: after the first blocking read it also consumes every
//! further complete line already buffered, parses the whole burst, routes
//! all its data ops as **one [`Request::Batch`] per shard**, and writes
//! all replies (in line order) with a single flush. Combined with the
//! workers' own queue draining, a busy connection pays one queue hop and
//! ~1/K of a fence per op instead of one each. Replies to a burst are
//! written only after every op in it is durable. `LEN`/`STATS` inside a
//! burst are resolved after the burst's data ops (both are approximate
//! snapshots; see `ConcurrentSet::len_approx`).
//!
//! **Explicit batches.** `MULTI <n>` queues the next `n` PUT/GET/DEL
//! lines without replying, `EXEC` routes them like a pipelined burst and
//! emits the `n` replies. A malformed frame yields a single ERR line.
//!
//! Thread-per-connection (std::net; the offline crate set has no async
//! runtime), bounded by `Config::max_conns`: excess connections get one
//! ERR line and are closed. The per-shard queue bound remains the
//! service's backpressure.

use super::shard::{Request, Response, ShardWorker};
use super::{DuraKv, Router};
use crate::sets::SetOp;
use anyhow::Result;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;

/// Largest accepted `MULTI <n>` frame.
const MULTI_MAX: u64 = 4096;

/// Adapter giving a shard's set a `'static` handle via the Arc'd store.
struct ShardRef {
    kv: Arc<DuraKv>,
    index: usize,
}

impl crate::sets::ConcurrentSet for ShardRef {
    fn insert(&self, key: u64, value: u64) -> bool {
        self.kv.shard_set(self.index).insert(key, value)
    }
    fn remove(&self, key: u64) -> bool {
        self.kv.shard_set(self.index).remove(key)
    }
    fn contains(&self, key: u64) -> bool {
        self.kv.shard_set(self.index).contains(key)
    }
    fn get(&self, key: u64) -> Option<u64> {
        self.kv.shard_set(self.index).get(key)
    }
    fn len_approx(&self) -> usize {
        self.kv.shard_set(self.index).len_approx()
    }
    fn apply_batch(&self, ops: &[crate::sets::SetOp]) -> Vec<crate::sets::OpResult> {
        // Forward as a batch so the underlying durable set coalesces the
        // fences (the default would loop over un-coalesced singles).
        self.kv.shard_set(self.index).apply_batch(ops)
    }
}

/// A running server; dropping it stops the accept loop and the workers.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_join: Option<std::thread::JoinHandle<()>>,
    _workers: Vec<ShardWorker>,
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
    }
}

/// Start serving `kv` on `127.0.0.1:port` (port 0 = ephemeral, for tests).
pub fn serve(kv: Arc<DuraKv>, port: u16) -> Result<Server> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;

    let workers: Vec<ShardWorker> = (0..kv.config().shards)
        .map(|i| {
            let set: Arc<dyn crate::sets::ConcurrentSet> =
                Arc::new(ShardRef { kv: kv.clone(), index: i });
            ShardWorker::spawn(set, kv.metrics.clone())
        })
        .collect();
    let senders: Arc<Vec<SyncSender<Request>>> =
        Arc::new(workers.iter().map(|w| w.tx.clone()).collect());

    let max_conns = kv.config().max_conns;
    let live_conns = Arc::new(AtomicUsize::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let kv2 = kv.clone();
    let accept_join = std::thread::spawn(move || {
        let router = kv2.router();
        while !stop2.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _)) => {
                    if max_conns > 0 && live_conns.load(Ordering::SeqCst) >= max_conns {
                        // Bounded fan-out: refuse instead of spawning an
                        // unbounded thread per connection.
                        reject_conn(stream, max_conns);
                        continue;
                    }
                    live_conns.fetch_add(1, Ordering::SeqCst);
                    let senders = senders.clone();
                    let kv = kv2.clone();
                    let live = live_conns.clone();
                    std::thread::spawn(move || {
                        let _ = handle_conn(stream, router, &senders, &kv);
                        live.fetch_sub(1, Ordering::SeqCst);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    });

    Ok(Server { addr, stop, accept_join: Some(accept_join), _workers: workers })
}

/// Refuse a connection over the `max_conns` limit with one ERR line that
/// actually reaches the client. A bare `write + drop` turns into a TCP
/// RST whenever the client already sent bytes we never read (its first
/// command raced our refusal), and an RST discards the in-flight reply —
/// the client saw a naked reset instead of the ERR. So: write the line,
/// half-close our sending side (FIN ⇒ the reply + EOF are delivered in
/// order), then briefly drain the client's data so the final close finds
/// an empty receive buffer. The whole exchange runs on a short-lived
/// helper thread (bounded lifetime: ≤ ~20 ms of read timeouts) so a
/// burst of rejections never serializes the accept loop. Deliberate
/// trade-off: a sustained reject flood holds ~rate × 20 ms concurrent
/// drain threads; if the OS refuses a thread we degrade to write+drop
/// (the pre-PR behaviour) rather than killing the accept loop.
fn reject_conn(stream: TcpStream, max_conns: usize) {
    let spawned = std::thread::Builder::new()
        .name("reject-drain".into())
        .spawn(move || {
            use std::io::Read;
            let mut s = stream;
            let _ = writeln!(s, "ERR too many connections (max {max_conns})");
            let _ = s.flush();
            let _ = s.shutdown(std::net::Shutdown::Write);
            let _ = s.set_read_timeout(Some(std::time::Duration::from_millis(10)));
            let mut sink = [0u8; 512];
            // One read for whatever raced the refusal, one for the EOF of
            // a well-behaved client; slower clients forfeit the clean
            // close.
            for _ in 0..2 {
                match s.read(&mut sink) {
                    // EOF: the client closed after reading the ERR — a
                    // clean close on our side cannot RST anything now.
                    Ok(0) | Err(_) => break,
                    Ok(_) => {}
                }
            }
        });
    // Out of threads (the overload this limit exists for): the stream was
    // moved into the failed closure and is dropped with it — the client
    // gets a reset, which is the pre-PR behaviour, and the accept loop
    // stays alive (a bare `thread::spawn` would have panicked it dead).
    let _ = spawned;
}

/// A routed data command (needed again at reply-formatting time).
#[derive(Clone, Copy)]
enum DataCmd {
    Put,
    Get,
    Del,
}

/// One reply slot of a burst, in line order.
enum Slot {
    /// Already-resolved reply line.
    Text(String),
    /// Data op `idx` of shard `shard`'s sub-batch.
    Pending(DataCmd, usize, usize),
    /// Resolved after the burst's data ops (approximate snapshots).
    Len,
    Stats,
    Quit,
}

fn data_reply(cmd: DataCmd, resp: Response) -> String {
    match (cmd, resp) {
        (DataCmd::Put, Response::Ok(true)) => "OK NEW".to_string(),
        (DataCmd::Put, _) => "OK EXISTS".to_string(),
        (DataCmd::Get, Response::Found(v)) => format!("FOUND {v}"),
        (DataCmd::Get, _) => "MISSING".to_string(),
        (DataCmd::Del, Response::Ok(true)) => "OK DELETED".to_string(),
        (DataCmd::Del, _) => "OK ABSENT".to_string(),
    }
}

/// Parse a PUT/GET/DEL line. `Ok(None)` = not a data command;
/// `Err(line)` = data command with bad arguments (the ERR reply).
fn parse_data(line: &str) -> std::result::Result<Option<(DataCmd, SetOp)>, String> {
    let mut parts = line.split_ascii_whitespace();
    let cmd = parts.next().unwrap_or("").to_ascii_uppercase();
    match cmd.as_str() {
        "PUT" => match (parse_u64(parts.next()), parse_u64(parts.next())) {
            (Some(k), Some(v)) => Ok(Some((DataCmd::Put, SetOp::Insert(k, v)))),
            _ => Err("ERR usage: PUT <key> <value>".to_string()),
        },
        "GET" => match parse_u64(parts.next()) {
            Some(k) => Ok(Some((DataCmd::Get, SetOp::Get(k)))),
            None => Err("ERR usage: GET <key>".to_string()),
        },
        "DEL" => match parse_u64(parts.next()) {
            Some(k) => Ok(Some((DataCmd::Del, SetOp::Remove(k)))),
            None => Err("ERR usage: DEL <key>".to_string()),
        },
        _ => Ok(None),
    }
}

/// Read one line; `Ok(None)` on a clean EOF.
fn read_line(reader: &mut BufReader<TcpStream>) -> Result<Option<String>> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    Ok(Some(line.trim().to_string()))
}

/// Route a data op into the burst's per-shard sub-batches.
fn route(
    op: SetOp,
    cmd: DataCmd,
    router: Router,
    slots: &mut Vec<Slot>,
    per_shard: &mut [Vec<SetOp>],
) {
    let shard = router.shard_of(op.key());
    slots.push(Slot::Pending(cmd, shard, per_shard[shard].len()));
    per_shard[shard].push(op);
}

/// Dispatch a gathered burst (one `Request::Batch` per shard), then write
/// every reply in line order with a single flush. Returns true on QUIT.
fn flush_burst(
    slots: &mut Vec<Slot>,
    per_shard: &mut [Vec<SetOp>],
    senders: &[SyncSender<Request>],
    writer: &mut BufWriter<TcpStream>,
    kv: &DuraKv,
) -> Result<bool> {
    let mut waiting: Vec<(usize, Receiver<Vec<Response>>)> = Vec::new();
    for (shard, ops) in per_shard.iter_mut().enumerate() {
        if ops.is_empty() {
            continue;
        }
        let (btx, brx) = sync_channel(1);
        senders[shard].send(Request::Batch(std::mem::take(ops), btx))?;
        waiting.push((shard, brx));
    }
    let mut shard_results: Vec<Vec<Response>> = vec![Vec::new(); senders.len()];
    for (shard, brx) in waiting {
        shard_results[shard] = brx.recv()?;
    }

    let mut quit = false;
    for slot in slots.drain(..) {
        match slot {
            Slot::Text(s) => writeln!(writer, "{s}")?,
            Slot::Pending(cmd, shard, idx) => {
                writeln!(writer, "{}", data_reply(cmd, shard_results[shard][idx]))?
            }
            Slot::Len => writeln!(writer, "LEN {}", kv.len_approx())?,
            Slot::Stats => writeln!(
                writer,
                "STATS {}",
                kv.metrics.report_with_growth(&kv.growth_stats())
            )?,
            Slot::Quit => {
                writeln!(writer, "BYE")?;
                quit = true;
                break;
            }
        }
    }
    writer.flush()?;
    Ok(quit)
}

fn handle_conn(
    stream: TcpStream,
    router: Router,
    senders: &[SyncSender<Request>],
    kv: &DuraKv,
) -> Result<()> {
    let mut writer = BufWriter::new(stream.try_clone()?);
    let mut reader = BufReader::new(stream);
    loop {
        // ---- gather one pipelined burst ----
        let Some(first) = read_line(&mut reader)? else {
            return Ok(()); // EOF
        };
        let mut slots: Vec<Slot> = Vec::new();
        let mut per_shard: Vec<Vec<SetOp>> = vec![Vec::new(); senders.len()];
        let mut line = first;
        let mut quit = false;
        loop {
            match parse_data(&line) {
                Ok(Some((cmd, op))) => route(op, cmd, router, &mut slots, &mut per_shard),
                Err(usage) => slots.push(Slot::Text(usage)),
                Ok(None) => {
                    let mut parts = line.split_ascii_whitespace();
                    let cmd = parts.next().unwrap_or("").to_ascii_uppercase();
                    match cmd.as_str() {
                        "MULTI" => match parse_u64(parts.next()).filter(|&n| n <= MULTI_MAX) {
                            None => slots.push(Slot::Text(format!(
                                "ERR usage: MULTI <n> (n <= {MULTI_MAX})"
                            ))),
                            Some(n) => {
                                // Gather the next n op lines + EXEC. Reading
                                // may block on the client, so first flush
                                // what the burst already holds — earlier
                                // commands must not have their replies (or
                                // execution) held hostage by a slow frame.
                                let buffered_lines =
                                    reader.buffer().iter().filter(|&&b| b == b'\n').count() as u64;
                                if buffered_lines < n + 1
                                    && !slots.is_empty()
                                    && flush_burst(
                                        &mut slots,
                                        &mut per_shard,
                                        senders,
                                        &mut writer,
                                        kv,
                                    )?
                                {
                                    return Ok(());
                                }
                                let mut frame = Vec::with_capacity(n as usize + 1);
                                for _ in 0..=n {
                                    match read_line(&mut reader)? {
                                        Some(l) => frame.push(l),
                                        None => return Ok(()), // EOF mid-frame
                                    }
                                }
                                let exec = frame.pop().expect("n+1 lines read");
                                if !exec.eq_ignore_ascii_case("EXEC") {
                                    slots.push(Slot::Text(format!(
                                        "ERR MULTI: expected EXEC after {n} ops, got '{exec}'"
                                    )));
                                } else if frame.is_empty() {
                                    // `MULTI 0` + EXEC: a valid empty batch.
                                    // It queues no ops and would otherwise
                                    // produce zero reply lines — the client,
                                    // waiting for its EXEC ack, would hang.
                                    slots.push(Slot::Text("OK EMPTY".to_string()));
                                } else {
                                    for l in &frame {
                                        match parse_data(l) {
                                            Ok(Some((cmd, op))) => {
                                                route(op, cmd, router, &mut slots, &mut per_shard)
                                            }
                                            Err(usage) => slots.push(Slot::Text(usage)),
                                            Ok(None) => slots.push(Slot::Text(format!(
                                                "ERR MULTI: not a data op: '{l}'"
                                            ))),
                                        }
                                    }
                                }
                            }
                        },
                        "LEN" => slots.push(Slot::Len),
                        "STATS" => slots.push(Slot::Stats),
                        "QUIT" => {
                            slots.push(Slot::Quit);
                            quit = true;
                        }
                        "" => {}
                        other => slots.push(Slot::Text(format!("ERR unknown command '{other}'"))),
                    }
                }
            }
            // Extend the burst with lines already buffered (never blocks).
            if !quit && reader.buffer().contains(&b'\n') {
                match read_line(&mut reader)? {
                    Some(l) => {
                        line = l;
                        continue;
                    }
                    None => break,
                }
            }
            break;
        }
        if flush_burst(&mut slots, &mut per_shard, senders, &mut writer, kv)? {
            return Ok(());
        }
    }
}

fn parse_u64(s: Option<&str>) -> Option<u64> {
    s.and_then(|x| x.parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use std::io::{BufRead, BufReader, Write};

    /// One connection: keep a single BufReader (read-ahead safe).
    struct Client {
        writer: TcpStream,
        reader: BufReader<TcpStream>,
    }

    impl Client {
        fn connect(addr: std::net::SocketAddr) -> Client {
            let stream = TcpStream::connect(addr).unwrap();
            let reader = BufReader::new(stream.try_clone().unwrap());
            Client { writer: stream, reader }
        }

        fn send(&mut self, line: &str) -> String {
            writeln!(self.writer, "{line}").unwrap();
            self.recv()
        }

        fn recv(&mut self) -> String {
            let mut out = String::new();
            self.reader.read_line(&mut out).unwrap();
            out.trim_end().to_string()
        }
    }

    fn test_kv(shards: usize) -> Arc<DuraKv> {
        let mut cfg = Config::default();
        cfg.shards = shards;
        cfg.key_range = 4096;
        cfg.psync_ns = 0;
        Arc::new(DuraKv::create(cfg))
    }

    #[test]
    fn tcp_protocol_round_trip() {
        let kv = test_kv(2);
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);

        assert_eq!(c.send("PUT 5 50"), "OK NEW");
        assert_eq!(c.send("PUT 5 51"), "OK EXISTS");
        assert_eq!(c.send("GET 5"), "FOUND 50");
        assert_eq!(c.send("DEL 5"), "OK DELETED");
        assert_eq!(c.send("DEL 5"), "OK ABSENT");
        assert_eq!(c.send("GET 5"), "MISSING");
        assert_eq!(c.send("PUT 7 70"), "OK NEW");
        assert_eq!(c.send("LEN"), "LEN 1");
        assert!(c.send("STATS").starts_with("STATS ops="));
        assert!(c.send("STATS").contains("growth=["), "growth stats on STATS");
        assert!(c.send("NOPE").starts_with("ERR"));
        assert!(c.send("PUT x").starts_with("ERR usage"));
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    #[test]
    fn multi_exec_batches() {
        let kv = test_kv(2);
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);

        // MULTI itself and the queued lines produce no replies; EXEC
        // yields one reply per op, in order.
        writeln!(c.writer, "MULTI 3").unwrap();
        writeln!(c.writer, "PUT 1 10").unwrap();
        writeln!(c.writer, "PUT 2 20").unwrap();
        writeln!(c.writer, "GET 1").unwrap();
        writeln!(c.writer, "EXEC").unwrap();
        assert_eq!(c.recv(), "OK NEW");
        assert_eq!(c.recv(), "OK NEW");
        assert_eq!(c.recv(), "FOUND 10");
        assert_eq!(kv.len_approx(), 2);

        // Malformed frames: missing EXEC, non-data op inside the frame.
        writeln!(c.writer, "MULTI 1").unwrap();
        writeln!(c.writer, "PUT 3 30").unwrap();
        writeln!(c.writer, "PUT 4 40").unwrap();
        assert!(c.recv().starts_with("ERR MULTI: expected EXEC"));
        writeln!(c.writer, "MULTI 1").unwrap();
        writeln!(c.writer, "LEN").unwrap();
        writeln!(c.writer, "EXEC").unwrap();
        assert!(c.recv().starts_with("ERR MULTI: not a data op"));
        assert!(c.send("MULTI zzz").starts_with("ERR usage: MULTI"));
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    #[test]
    fn multi_zero_acks_empty_batch() {
        let kv = test_kv(2);
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);
        // `MULTI 0` + EXEC used to queue no ops and emit no reply — the
        // client hung waiting for its EXEC ack.
        writeln!(c.writer, "MULTI 0").unwrap();
        writeln!(c.writer, "EXEC").unwrap();
        assert_eq!(c.recv(), "OK EMPTY", "empty batch must ack, not stall");
        // The connection stays fully usable afterwards.
        assert_eq!(c.send("PUT 1 10"), "OK NEW");
        // And an empty frame embedded in a pipelined burst keeps reply
        // order for the surrounding commands.
        c.writer.write_all(b"PUT 2 20\nMULTI 0\nEXEC\nGET 2\n").unwrap();
        c.writer.flush().unwrap();
        assert_eq!(c.recv(), "OK NEW");
        assert_eq!(c.recv(), "OK EMPTY");
        assert_eq!(c.recv(), "FOUND 20");
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    #[test]
    fn rejected_connection_gets_the_err_line_even_if_it_sent_first() {
        let mut cfg = Config::default();
        cfg.shards = 1;
        cfg.key_range = 1024;
        cfg.psync_ns = 0;
        cfg.max_conns = 1;
        let kv = Arc::new(DuraKv::create(cfg));
        let server = serve(kv, 0).unwrap();
        let mut a = Client::connect(server.addr);
        assert_eq!(a.send("PUT 1 1"), "OK NEW"); // handler established
        // Saturated listener: each refused client *sends before reading*
        // — the schedule where a bare write+drop refusal turns into a TCP
        // reset that discards the ERR line mid-flight.
        for i in 0..5 {
            let mut c = Client::connect(server.addr);
            writeln!(c.writer, "GET 1").unwrap();
            let reply = c.recv();
            assert!(
                reply.starts_with("ERR too many connections"),
                "rejected client {i} must read the ERR line, got '{reply}'"
            );
        }
        assert_eq!(a.send("QUIT"), "BYE");
        drop(a);
        drop(server);
    }

    #[test]
    fn slow_multi_frame_does_not_withhold_earlier_replies() {
        // A burst whose tail is an incomplete MULTI frame: the commands
        // before it must be executed and answered before the server
        // blocks waiting for the rest of the frame.
        let kv = test_kv(2);
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);
        c.writer.write_all(b"PUT 1 11\nMULTI 2\n").unwrap();
        c.writer.flush().unwrap();
        assert_eq!(c.recv(), "OK NEW", "pre-MULTI command must not be held hostage");
        c.writer.write_all(b"PUT 2 22\nGET 1\nEXEC\n").unwrap();
        c.writer.flush().unwrap();
        assert_eq!(c.recv(), "OK NEW");
        assert_eq!(c.recv(), "FOUND 11");
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    #[test]
    fn pipelined_burst_replies_in_order() {
        let kv = test_kv(4);
        let server = serve(kv.clone(), 0).unwrap();
        let mut c = Client::connect(server.addr);
        // Fire the whole burst as one write so it lands in the server's
        // read buffer together: the server must parse it as one burst,
        // batch per shard, and still reply strictly in line order.
        let mut burst = String::new();
        for k in 0..200u64 {
            burst.push_str(&format!("PUT {k} {}\n", k * 2));
        }
        burst.push_str("LEN\n");
        c.writer.write_all(burst.as_bytes()).unwrap();
        c.writer.flush().unwrap();
        for _ in 0..200 {
            assert_eq!(c.recv(), "OK NEW");
        }
        assert_eq!(c.recv(), "LEN 200");
        // Group commit actually engaged: far fewer commits than ops (one
        // per shard per burst; TCP may split the burst a few times).
        let batches = kv.metrics.batches.load(std::sync::atomic::Ordering::Relaxed);
        assert!(batches * 4 <= 200, "200 pipelined puts took {batches} group commits");
        assert_eq!(c.send("QUIT"), "BYE");
        drop(server);
    }

    #[test]
    fn concurrent_tcp_clients() {
        let kv = test_kv(2);
        let server = serve(kv.clone(), 0).unwrap();
        let addr = server.addr;
        let handles: Vec<_> = (0..4u64)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr);
                    for i in 0..100u64 {
                        let k = t * 1000 + i;
                        assert_eq!(c.send(&format!("PUT {k} {i}")), "OK NEW");
                        assert_eq!(c.send(&format!("GET {k}")), format!("FOUND {i}"));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(kv.len_approx(), 400);
        drop(server);
    }

    #[test]
    fn max_conns_bounds_fanout() {
        let mut cfg = Config::default();
        cfg.shards = 1;
        cfg.key_range = 1024;
        cfg.psync_ns = 0;
        cfg.max_conns = 2;
        let kv = Arc::new(DuraKv::create(cfg));
        let server = serve(kv, 0).unwrap();
        let mut a = Client::connect(server.addr);
        let mut b = Client::connect(server.addr);
        // Establish both handlers before probing the limit.
        assert_eq!(a.send("PUT 1 1"), "OK NEW");
        assert_eq!(b.send("GET 1"), "FOUND 1");
        let mut c = Client::connect(server.addr);
        assert!(
            c.recv().starts_with("ERR too many connections"),
            "third connection must be refused"
        );
        // Closing one slot frees capacity for a new connection. The
        // handler decrements its slot after QUIT, so poll briefly; a
        // still-refused attempt may error on either side of the socket.
        assert_eq!(a.send("QUIT"), "BYE");
        drop(a);
        let mut freed = None;
        for _ in 0..200 {
            let mut d = Client::connect(server.addr);
            let ok = writeln!(d.writer, "GET 1").is_ok();
            let mut reply = String::new();
            if ok && d.reader.read_line(&mut reply).is_ok() && reply.trim_end() == "FOUND 1" {
                freed = Some(d);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let mut d = freed.expect("a freed slot must admit a new connection");
        assert_eq!(d.send("QUIT"), "BYE");
        drop(b);
        drop(server);
    }
}
