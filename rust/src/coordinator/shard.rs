//! Shard: one durable set instance plus the metadata needed to rebuild it
//! after a crash, and an optional worker-queue front for the TCP server.
//!
//! The sets themselves are lock-free and `Sync`, so the *data path* never
//! needs a worker hop — `DuraKv` calls straight into the set from any
//! thread. The queued front exists for the network server: it batches
//! requests per shard (bounded queue = backpressure) and keeps per-shard
//! metrics, the vLLM-router-style shape without pretending the structures
//! need serialisation.

use crate::config::{Config, Structure};
use crate::pmem::PoolId;
use crate::sets::{self, ConcurrentSet, Family};
use anyhow::Result;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use super::metrics::Metrics;

/// Everything needed to re-create a shard's volatile handle from its
/// durable areas.
#[derive(Clone, Copy, Debug)]
pub struct ShardMeta {
    pub index: usize,
    pub family: Family,
    pub structure: Structure,
    pub nbuckets: usize,
    pub pool: Option<PoolId>,
}

/// A shard of the KV service.
pub struct Shard {
    pub set: Box<dyn ConcurrentSet>,
    pub meta: ShardMeta,
}

impl Shard {
    /// Build a fresh shard per the config.
    pub fn create(cfg: &Config, index: usize) -> Shard {
        let nbuckets = cfg.buckets_per_shard();
        let set: Box<dyn ConcurrentSet> = match cfg.structure {
            Structure::Hash => sets::new_hash(cfg.family, nbuckets),
            Structure::List => sets::new_list(cfg.family),
        };
        let meta = ShardMeta {
            index,
            family: cfg.family,
            structure: cfg.structure,
            nbuckets,
            pool: set.durable_pool(),
        };
        Shard { set, meta }
    }

    /// Rebuild this shard from its durable areas (post-crash). Volatile
    /// shards come back empty.
    pub fn recover(meta: ShardMeta) -> Result<Shard> {
        let set: Box<dyn ConcurrentSet> = match (meta.family, meta.structure, meta.pool) {
            (Family::Volatile, Structure::Hash, _) => {
                sets::new_hash(Family::Volatile, meta.nbuckets)
            }
            (Family::Volatile, Structure::List, _) => sets::new_list(Family::Volatile),
            (family, structure, Some(pool)) => match (family, structure) {
                // Hash shards are resizable: recover the family list and
                // re-wrap it, restoring the persisted bucket-count epoch
                // (meta.nbuckets is only the pre-epoch fallback).
                (Family::LinkFree, Structure::Hash) => {
                    Box::new(sets::resizable::recover_linkfree(pool, meta.nbuckets).0)
                }
                (Family::LinkFree, Structure::List) => {
                    Box::new(sets::linkfree::recover_list(pool).0)
                }
                (Family::Soft, Structure::Hash) => {
                    Box::new(sets::resizable::recover_soft(pool, meta.nbuckets).0)
                }
                (Family::Soft, Structure::List) => Box::new(sets::soft::recover_list(pool).0),
                (Family::LogFree, Structure::Hash) => {
                    Box::new(sets::resizable::recover_logfree(pool, meta.nbuckets).0)
                }
                (Family::LogFree, Structure::List) => {
                    Box::new(sets::logfree::recover_list(pool).0)
                }
                (Family::Volatile, _) => unreachable!(),
            },
            (f, s, None) => anyhow::bail!("shard {:?}/{:?} has no durable pool", f, s),
        };
        // The recovered set has a fresh pool handle adopting the same id.
        let meta = ShardMeta { pool: set.durable_pool().or(meta.pool), ..meta };
        Ok(Shard { set, meta })
    }
}

/// A queued request (server path).
pub enum Request {
    Get(u64, SyncSender<Response>),
    Put(u64, u64, SyncSender<Response>),
    Del(u64, SyncSender<Response>),
    Shutdown,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Response {
    Found(u64),
    Missing,
    Ok(bool),
}

/// Worker-queue front over a shard set: bounded channel + one worker
/// thread per shard.
pub struct ShardWorker {
    pub tx: SyncSender<Request>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ShardWorker {
    /// Queue capacity per shard (backpressure bound for the TCP server).
    pub const QUEUE_CAP: usize = 1024;

    pub fn spawn(set: Arc<dyn ConcurrentSet>, metrics: Arc<Metrics>) -> ShardWorker {
        let (tx, rx): (SyncSender<Request>, Receiver<Request>) = sync_channel(Self::QUEUE_CAP);
        let join = std::thread::spawn(move || {
            while let Ok(req) = rx.recv() {
                let t0 = Instant::now();
                match req {
                    Request::Get(k, reply) => {
                        metrics.gets.fetch_add(1, Ordering::Relaxed);
                        let resp = match set.get(k) {
                            Some(v) => {
                                metrics.get_hits.fetch_add(1, Ordering::Relaxed);
                                Response::Found(v)
                            }
                            None => Response::Missing,
                        };
                        let _ = reply.send(resp);
                    }
                    Request::Put(k, v, reply) => {
                        metrics.puts.fetch_add(1, Ordering::Relaxed);
                        let fresh = set.insert(k, v);
                        if fresh {
                            metrics.put_new.fetch_add(1, Ordering::Relaxed);
                        }
                        let _ = reply.send(Response::Ok(fresh));
                    }
                    Request::Del(k, reply) => {
                        metrics.dels.fetch_add(1, Ordering::Relaxed);
                        let hit = set.remove(k);
                        if hit {
                            metrics.del_hit.fetch_add(1, Ordering::Relaxed);
                        }
                        let _ = reply.send(Response::Ok(hit));
                    }
                    Request::Shutdown => break,
                }
                metrics.record_latency(t0.elapsed());
            }
        });
        ShardWorker { tx, join: Some(join) }
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ShardWorker {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_round_trip() {
        let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_hash(Family::Volatile, 16));
        let metrics = Arc::new(Metrics::new());
        let w = ShardWorker::spawn(set, metrics.clone());
        let (rtx, rrx) = sync_channel(1);
        w.tx.send(Request::Put(1, 10, rtx.clone())).unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Ok(true));
        w.tx.send(Request::Get(1, rtx.clone())).unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Found(10));
        w.tx.send(Request::Del(1, rtx.clone())).unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Ok(true));
        w.tx.send(Request::Get(1, rtx)).unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Missing);
        assert_eq!(metrics.ops_total(), 4);
        w.shutdown();
    }

    #[test]
    fn shard_create_has_pool_for_durable_families() {
        let cfg = Config::default();
        let s = Shard::create(&cfg, 0);
        assert!(s.meta.pool.is_some());
        let mut vcfg = Config::default();
        vcfg.family = Family::Volatile;
        let v = Shard::create(&vcfg, 0);
        assert!(v.meta.pool.is_none());
    }
}
