//! Shard: one durable set instance plus the metadata needed to rebuild it
//! after a crash, and an optional worker-queue front for the TCP server.
//!
//! The sets themselves are lock-free and `Sync`, so the *data path* never
//! needs a worker hop — `DuraKv` calls straight into the set from any
//! thread. The queued front exists for the network server: it batches
//! requests per shard (bounded queue = backpressure) and keeps per-shard
//! metrics, the vLLM-router-style shape without pretending the structures
//! need serialisation.
//!
//! **Adaptive group commit.** A worker does not process one request per
//! wakeup: it drains everything queued (up to its current drain bound
//! `k`) into a single [`ConcurrentSet::apply_batch`] call, so all the
//! drained updates share one trailing fence (pmem's `PsyncScope`), and
//! only then fans the results back out to the per-request responders.
//! The bound `k` is no longer static: each commit feeds EWMAs of the
//! observed drain depth and the commit latency, and the controller moves
//! `k` multiplicatively between [`GroupTuning::k_min`] and
//! [`GroupTuning::k_max`] — saturation (the drain hit the bound) doubles
//! it, persistently light queues halve it, and a commit-latency EWMA past
//! the budget halves it regardless (slow fences must not buy throughput
//! with unbounded tail latency). Once depth warrants it, the worker also
//! *holds* briefly (bounded by the commit-latency EWMA) to fill a batch —
//! the classic group-commit latency/throughput trade, now load-driven:
//! light load commits immediately with the identical per-op durability
//! guarantee, heavy load converges to the K≈64-style fence amortization
//! (every response is still sent strictly after its batch's trailing
//! fence). `k` movements surface as the `adaptk` gauge on `STATS`.
//!
//! **Atomic batches.** A [`Request::Prepare`] parks the worker for a
//! two-phase cross-shard batch: it finishes the group it was draining,
//! signals readiness, then obeys the coordinator — apply the sub-batch
//! (one `PsyncScope`), report results, stay parked until released. See
//! `coordinator::txn` for the protocol and DESIGN.md §Transactions for
//! why the parking window is what makes recovery's roll-forward sound.
//!
//! **Idle maintenance.** A worker that sees no traffic for [`IDLE_TICK`]
//! spends the wakeup on [`ConcurrentSet::maintain`]: one step of area
//! compaction / memory return / bucket-array shrink (DESIGN.md
//! §Allocator). Because every wire update for a shard flows through its
//! worker, the worker thread is the shard's sole updater — precisely the
//! serialization `maintain` demands; concurrent readers (the psync-free
//! read lane) are always safe against it.

use crate::config::{Config, Structure};
use crate::pmem::PoolId;
use crate::sets::recovery::{PhaseTimings, RecoveredStats};
use crate::sets::{self, ConcurrentSet, Family, OpResult, SetOp};
use anyhow::Result;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::metrics::Metrics;

/// Everything needed to re-create a shard's volatile handle from its
/// durable areas.
#[derive(Clone, Copy, Debug)]
pub struct ShardMeta {
    pub index: usize,
    pub family: Family,
    pub structure: Structure,
    pub nbuckets: usize,
    pub pool: Option<PoolId>,
}

/// A shard of the KV service.
pub struct Shard {
    pub set: Box<dyn ConcurrentSet>,
    pub meta: ShardMeta,
}

impl Shard {
    /// Build a fresh shard per the config.
    pub fn create(cfg: &Config, index: usize) -> Shard {
        let nbuckets = cfg.buckets_per_shard();
        let set: Box<dyn ConcurrentSet> = match cfg.structure {
            Structure::Hash => sets::new_hash(cfg.family, nbuckets),
            Structure::List => sets::new_list(cfg.family),
            Structure::SkipList => sets::new_skiplist(cfg.family),
        };
        let meta = ShardMeta {
            index,
            family: cfg.family,
            structure: cfg.structure,
            nbuckets,
            pool: set.durable_pool(),
        };
        Shard { set, meta }
    }

    /// Rebuild this shard from its durable areas (post-crash) with the
    /// default recovery worker count. Volatile shards come back empty.
    pub fn recover(meta: ShardMeta) -> Result<Shard> {
        Ok(Self::recover_timed(meta, crate::sets::recovery::default_threads())?.0)
    }

    /// [`Shard::recover`] with an explicit engine worker count, returning
    /// the engine's stats + per-phase timings for `RecoveryReport`.
    pub fn recover_timed(meta: ShardMeta, threads: usize) -> Result<(Shard, ShardRecovery)> {
        let mut rec = ShardRecovery::default();
        let set: Box<dyn ConcurrentSet> = match (meta.family, meta.structure, meta.pool) {
            (Family::Volatile, Structure::Hash, _) => {
                sets::new_hash(Family::Volatile, meta.nbuckets)
            }
            (Family::Volatile, Structure::List, _) => sets::new_list(Family::Volatile),
            (family, structure, Some(pool)) => {
                let (set, stats, timings): (Box<dyn ConcurrentSet>, _, _) =
                    match (family, structure) {
                        // Hash shards are resizable: recover the family list
                        // and re-wrap it, restoring the persisted bucket-count
                        // epoch (meta.nbuckets is only the pre-epoch fallback).
                        (Family::LinkFree, Structure::Hash) => {
                            let (h, s, t) =
                                sets::resizable::recover_linkfree_timed(pool, meta.nbuckets, threads);
                            (Box::new(h), s, t)
                        }
                        (Family::LinkFree, Structure::List) => {
                            let (l, s, t) = sets::linkfree::recover_list_timed(pool, threads);
                            (Box::new(l), s, t)
                        }
                        (Family::Soft, Structure::Hash) => {
                            let (h, s, t) =
                                sets::resizable::recover_soft_timed(pool, meta.nbuckets, threads);
                            (Box::new(h), s, t)
                        }
                        (Family::Soft, Structure::List) => {
                            let (l, s, t) = sets::soft::recover_list_timed(pool, threads);
                            (Box::new(l), s, t)
                        }
                        (Family::LogFree, Structure::Hash) => {
                            let (h, s, t) =
                                sets::resizable::recover_logfree_timed(pool, meta.nbuckets, threads);
                            (Box::new(h), s, t)
                        }
                        (Family::LogFree, Structure::List) => {
                            let (l, s, t) = sets::logfree::recover_list_timed(pool, threads);
                            (Box::new(l), s, t)
                        }
                        (Family::NvTraverse, Structure::Hash) => {
                            let (h, s, t) = sets::resizable::recover_nvtraverse_timed(
                                pool,
                                meta.nbuckets,
                                threads,
                            );
                            (Box::new(h), s, t)
                        }
                        (Family::NvTraverse, Structure::List) => {
                            let (l, s, t) = sets::nvtraverse::recover_list_timed(pool, threads);
                            (Box::new(l), s, t)
                        }
                        (Family::LinkFree, Structure::SkipList) => {
                            let (l, s, t) = sets::linkfree::recover_skiplist_timed(pool, threads);
                            (Box::new(l), s, t)
                        }
                        (Family::Soft, Structure::SkipList) => {
                            let (l, s, t) = sets::soft::recover_skiplist_timed(pool, threads);
                            (Box::new(l), s, t)
                        }
                        // Config validation rejects skip lists for the
                        // remaining families before a shard can exist.
                        (Family::LogFree | Family::NvTraverse, Structure::SkipList) => {
                            unreachable!()
                        }
                        (Family::Volatile, _) => unreachable!(),
                    };
                rec.stats = stats;
                rec.timings = timings;
                set
            }
            (f, s, None) => anyhow::bail!("shard {:?}/{:?} has no durable pool", f, s),
        };
        // The recovered set has a fresh pool handle adopting the same id.
        let meta = ShardMeta { pool: set.durable_pool().or(meta.pool), ..meta };
        Ok((Shard { set, meta }, rec))
    }

    /// Recover this shard through the XLA classification artifacts where
    /// the layout is modelled (resizable link-free / SOFT hash shards);
    /// everything else — and any artifact failure *before the durable
    /// image is touched* — falls back to the exact Rust path. Returns
    /// whether the artifact path was actually used.
    pub fn recover_accel(meta: ShardMeta, threads: usize) -> Result<(Shard, ShardRecovery, bool)> {
        use crate::runtime::recovery_accel as accel;
        use crate::runtime::RecoveryPlanner;
        if let (Structure::Hash, Some(pool)) = (meta.structure, meta.pool) {
            let planned = match meta.family {
                Family::LinkFree => Some(RecoveryPlanner::with_cached(|p| {
                    accel::recover_resizable_linkfree_accel(p, pool, meta.nbuckets, threads)
                        .map(|(h, s, t)| (Box::new(h) as Box<dyn ConcurrentSet>, s, t))
                })),
                Family::Soft => Some(RecoveryPlanner::with_cached(|p| {
                    accel::recover_resizable_soft_accel(p, pool, meta.nbuckets, threads)
                        .map(|(h, s, t)| (Box::new(h) as Box<dyn ConcurrentSet>, s, t))
                })),
                // No classification kernel for log-free (its membership is
                // reachability, not a per-slot rule) or volatile shards.
                _ => None,
            };
            if let Some(Ok((set, stats, timings))) = planned {
                let meta = ShardMeta { pool: set.durable_pool().or(meta.pool), ..meta };
                return Ok((Shard { set, meta }, ShardRecovery { stats, timings }, true));
            }
        }
        let (shard, rec) = Self::recover_timed(meta, threads)?;
        Ok((shard, rec, false))
    }
}

/// What recovering one shard found and cost (zeroed for volatile shards).
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardRecovery {
    pub stats: RecoveredStats,
    pub timings: PhaseTimings,
}

/// A queued request (server path).
pub enum Request {
    /// One routed op + its responder.
    Op(SetOp, SyncSender<Response>),
    /// A pre-routed batch (pipelined connection / `MULTI`): one responder
    /// for the whole vector, results in op order.
    Batch(Vec<SetOp>, BatchSink),
    /// Park this worker for an atomic cross-shard batch (`coordinator::txn`).
    Prepare(TxnHandle),
    Shutdown,
}

/// Where a completed batch's results go, plus (on the event plane) the
/// reactor to wake. The channel holds one slot, so the worker's `send`
/// after the trailing fence never blocks: a blocking caller (tests,
/// embedded use) is parked in `recv`, an event-plane connection picks
/// the results up on its reactor's next wakeup — which `wake` delivers.
pub struct BatchSink {
    pub tx: SyncSender<Vec<Response>>,
    pub wake: Option<Arc<super::reactor::Waker>>,
}

impl BatchSink {
    /// Blocking responder (tests / embedded callers): the sender blocks
    /// in `recv`, no wakeup needed.
    pub fn blocking(tx: SyncSender<Vec<Response>>) -> BatchSink {
        BatchSink { tx, wake: None }
    }

    /// Event-plane responder: completions wake the owning reactor.
    pub fn waking(tx: SyncSender<Vec<Response>>, waker: Arc<super::reactor::Waker>) -> BatchSink {
        BatchSink { tx, wake: Some(waker) }
    }
}

/// The coordinator ⇄ parked-worker channel bundle of one atomic batch.
pub struct TxnHandle {
    /// Worker → coordinator: "drained my group, now parked".
    pub ready: SyncSender<()>,
    /// Coordinator → worker: apply / release.
    pub go: Receiver<TxnCmd>,
    /// Worker → coordinator: the sub-batch's results.
    pub done: SyncSender<Vec<Response>>,
}

/// Coordinator commands to a parked worker.
pub enum TxnCmd {
    /// Apply this sub-batch (one `PsyncScope`), report results, stay
    /// parked.
    Apply(Vec<SetOp>),
    /// The record is retired: resume normal draining.
    Release,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Response {
    Found(u64),
    Missing,
    Ok(bool),
}

impl Response {
    fn from_result(r: OpResult) -> Response {
        match r {
            OpResult::Applied(b) | OpResult::Found(b) => Response::Ok(b),
            OpResult::Value(Some(v)) => Response::Found(v),
            OpResult::Value(None) => Response::Missing,
        }
    }
}

/// Where one drained request's results go back to.
enum Sink {
    One(SyncSender<Response>),
    Many(usize, BatchSink),
}

/// Adaptive-K bounds for a shard worker's group commit (config keys
/// `group_k_min` / `group_k_max`).
#[derive(Clone, Copy, Debug)]
pub struct GroupTuning {
    /// Floor of the drain bound: light load converges here (commit
    /// immediately, lowest latency).
    pub k_min: usize,
    /// Ceiling of the drain bound: saturated load converges here (widest
    /// fence amortization). Also the starting value, so a cold worker
    /// never splits an already-queued burst.
    pub k_max: usize,
}

impl Default for GroupTuning {
    fn default() -> Self {
        GroupTuning { k_min: 1, k_max: 512 }
    }
}

/// EWMA smoothing factor (new sample weight 1/4) for the controller's
/// depth and commit-latency estimates.
const EWMA_W: f64 = 0.25;

/// Commit-latency budget: once the per-commit latency EWMA exceeds this,
/// the controller halves `k` regardless of depth — fence amortization
/// must not buy throughput with unbounded group-commit tails.
const COMMIT_BUDGET_NS: f64 = 2_000_000.0;

/// Ceiling on the fill-hold wait (the hold is otherwise bounded by the
/// commit-latency EWMA: holding longer than one commit costs more
/// latency than it amortizes).
const HOLD_MAX: Duration = Duration::from_millis(1);

/// Queue-depth EWMA above which the worker may hold to fill a batch;
/// below it, commits go out immediately (single-client latency).
const HOLD_DEPTH: f64 = 4.0;

/// How long a worker waits for traffic before spending the idle wakeup
/// on one [`ConcurrentSet::maintain`] step (area compaction + memory
/// return + table shrink). All wire *updates* for a shard flow through
/// its worker, so the worker thread is the shard's sole updater — which
/// is exactly the serialization `maintain` requires; the psync-free read
/// lane that bypasses the queue is reader-only and maintenance-safe.
const IDLE_TICK: Duration = Duration::from_millis(50);

/// Worker-queue front over a shard set: bounded channel + one worker
/// thread per shard, draining the queue into adaptive group commits.
pub struct ShardWorker {
    pub tx: SyncSender<Request>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ShardWorker {
    /// Queue capacity per shard (backpressure bound for the TCP server).
    pub const QUEUE_CAP: usize = 1024;

    /// Spawn with default tuning (K adapts in [1, 512]).
    pub fn spawn(set: Arc<dyn ConcurrentSet>, metrics: Arc<Metrics>) -> ShardWorker {
        Self::spawn_with(set, metrics, GroupTuning::default())
    }

    /// Spawn with explicit adaptive-K bounds.
    pub fn spawn_with(
        set: Arc<dyn ConcurrentSet>,
        metrics: Arc<Metrics>,
        tuning: GroupTuning,
    ) -> ShardWorker {
        let (tx, rx): (SyncSender<Request>, Receiver<Request>) = sync_channel(Self::QUEUE_CAP);
        let join = std::thread::spawn(move || worker_loop(rx, set, metrics, tuning));
        ShardWorker { tx, join: Some(join) }
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Gather one request into the pending group. `Prepare` and `Shutdown`
/// end the gather: the current group must commit (and scatter) before the
/// worker parks or exits.
fn gather(
    req: Request,
    ops: &mut Vec<SetOp>,
    sinks: &mut Vec<Sink>,
    parked: &mut Option<TxnHandle>,
    shutdown: &mut bool,
) {
    match req {
        Request::Op(op, tx) => {
            ops.push(op);
            sinks.push(Sink::One(tx));
        }
        Request::Batch(batch, tx) => {
            sinks.push(Sink::Many(batch.len(), tx));
            ops.extend(batch);
        }
        Request::Prepare(handle) => *parked = Some(handle),
        Request::Shutdown => *shutdown = true,
    }
}

/// Commit one gathered group: apply as a batch (one trailing fence),
/// record metrics, scatter results. Returns the commit wall time.
fn commit_group(
    set: &dyn ConcurrentSet,
    metrics: &Metrics,
    ops: &[SetOp],
    sinks: &mut Vec<Sink>,
) -> Duration {
    let t0 = Instant::now();
    let pm0 = crate::pmem::stats::thread_snapshot();
    // The group commit: results become claimable only after the batch's
    // trailing fence, i.e. when apply_batch returns.
    let results = set.apply_batch(ops);
    // Ack boundary: every durable store this group authored must be
    // flushed + fenced before a single result is scattered.
    crate::pmem::check::assert_persisted("shard.commit_group");
    let elapsed = t0.elapsed();
    if !ops.is_empty() {
        metrics.record_group(ops.len() as u64);
        // The worker thread ran the whole batch, so its counter delta is
        // exactly this commit's fence/flush bill (the STATS `fences=`
        // gauge, mirroring `bench --fig fences` on the serving path).
        metrics.record_fences(
            ops.len() as u64,
            &crate::pmem::stats::thread_snapshot().since(&pm0),
        );
        // One histogram entry per group commit: the histogram tracks
        // commit latency (every request in the group waited this long),
        // not per-op cost repeated N times.
        metrics.record_latency(elapsed);
    }
    for (&op, &res) in ops.iter().zip(results.iter()) {
        metrics.record_op(op, res);
    }
    let mut i = 0;
    for sink in sinks.drain(..) {
        match sink {
            Sink::One(tx) => {
                let _ = tx.send(Response::from_result(results[i]));
                i += 1;
            }
            Sink::Many(n, sink) => {
                let group: Vec<Response> =
                    results[i..i + n].iter().map(|&r| Response::from_result(r)).collect();
                // Results land in the one-slot channel strictly after the
                // trailing fence, then the owning reactor (if any) is
                // woken — the ack-after-durability point.
                let _ = sink.tx.send(group);
                if let Some(w) = &sink.wake {
                    w.wake();
                }
                i += n;
            }
        }
    }
    elapsed
}

/// Serve one atomic-batch parking window (see `coordinator::txn`): signal
/// readiness, then apply-and-report under coordinator control until
/// released. A dropped coordinator channel releases the worker without
/// applying — the abort path, consistent with an uncommitted record.
fn serve_txn(set: &dyn ConcurrentSet, metrics: &Metrics, handle: TxnHandle) {
    if handle.ready.send(()).is_err() {
        return;
    }
    loop {
        match handle.go.recv() {
            Ok(TxnCmd::Apply(ops)) => {
                let t0 = Instant::now();
                let pm0 = crate::pmem::stats::thread_snapshot();
                // One PsyncScope per participating shard: this is the
                // "prepare-apply" of the two-phase protocol, running
                // strictly after the coordinator's commit point.
                let results = set.apply_batch(&ops);
                // Ack boundary: the coordinator treats `done` as durable.
                crate::pmem::check::assert_persisted("shard.serve_txn");
                metrics.record_group(ops.len() as u64);
                metrics.record_fences(
                    ops.len() as u64,
                    &crate::pmem::stats::thread_snapshot().since(&pm0),
                );
                metrics.record_latency(t0.elapsed());
                for (&op, &res) in ops.iter().zip(results.iter()) {
                    metrics.record_op(op, res);
                }
                let resp: Vec<Response> =
                    results.into_iter().map(Response::from_result).collect();
                if handle.done.send(resp).is_err() {
                    return;
                }
            }
            Ok(TxnCmd::Release) | Err(_) => return,
        }
    }
}

/// The adaptive group-commit loop: block for one request, drain up to the
/// current bound `k` (holding briefly for stragglers when the depth EWMA
/// says load is heavy), commit the group, retune `k`, park for atomic
/// batches when asked.
fn worker_loop(
    rx: Receiver<Request>,
    set: Arc<dyn ConcurrentSet>,
    metrics: Arc<Metrics>,
    tuning: GroupTuning,
) {
    let k_min = tuning.k_min.max(1);
    let k_max = tuning.k_max.max(k_min);
    // Start at the ceiling: a cold worker facing a pre-queued burst must
    // drain it whole (the PR-2 behavior); light load shrinks from there.
    let mut k = k_max;
    let mut depth_ewma = 0.0f64;
    let mut commit_ns_ewma = 0.0f64;
    metrics.record_adaptive_k(k as u64);
    let mut ops: Vec<SetOp> = Vec::new();
    let mut sinks: Vec<Sink> = Vec::new();
    loop {
        ops.clear();
        sinks.clear();
        let mut parked: Option<TxnHandle> = None;
        let mut shutdown = false;
        match rx.recv_timeout(IDLE_TICK) {
            Ok(req) => gather(req, &mut ops, &mut sinks, &mut parked, &mut shutdown),
            Err(RecvTimeoutError::Timeout) => {
                // Idle: no request arrived for a whole tick. Spend the
                // wakeup on background maintenance instead — the worker
                // is the shard's sole updater, so compaction/shrink run
                // exactly under the serialization they require.
                let _ = set.maintain();
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => return,
        }
        // Opportunistic drain up to k; when the depth EWMA says load is
        // heavy, hold (bounded by the commit-latency EWMA) to fill the
        // batch instead of fencing a fragment.
        let hold_until = (depth_ewma >= HOLD_DEPTH && k > k_min).then(|| {
            Instant::now()
                + Duration::from_nanos(commit_ns_ewma as u64).min(HOLD_MAX)
        });
        while !shutdown && parked.is_none() && ops.len() < k {
            match rx.try_recv() {
                Ok(req) => gather(req, &mut ops, &mut sinks, &mut parked, &mut shutdown),
                Err(_) => {
                    let Some(deadline) = hold_until else { break };
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(req) => {
                            gather(req, &mut ops, &mut sinks, &mut parked, &mut shutdown)
                        }
                        Err(_) => break,
                    }
                }
            }
        }
        if !sinks.is_empty() {
            let drained = ops.len();
            let commit = commit_group(set.as_ref(), &metrics, &ops, &mut sinks);
            // Controller: latency budget first, then saturation/lightness.
            depth_ewma += (drained as f64 - depth_ewma) * EWMA_W;
            commit_ns_ewma += (commit.as_nanos() as f64 - commit_ns_ewma) * EWMA_W;
            k = if commit_ns_ewma > COMMIT_BUDGET_NS {
                (k / 2).max(k_min)
            } else if drained >= k {
                (k * 2).min(k_max)
            } else if drained * 2 <= k && depth_ewma * 2.0 <= k as f64 {
                (k / 2).max(k_min)
            } else {
                k
            };
            metrics.record_adaptive_k(k as u64);
        }
        if let Some(handle) = parked {
            serve_txn(set.as_ref(), &metrics, handle);
        }
        if shutdown {
            return;
        }
    }
}

impl Drop for ShardWorker {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_round_trip() {
        let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_hash(Family::Volatile, 16));
        let metrics = Arc::new(Metrics::new());
        let w = ShardWorker::spawn(set, metrics.clone());
        let (rtx, rrx) = sync_channel(1);
        w.tx.send(Request::Op(SetOp::Insert(1, 10), rtx.clone())).unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Ok(true));
        w.tx.send(Request::Op(SetOp::Get(1), rtx.clone())).unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Found(10));
        w.tx.send(Request::Op(SetOp::Remove(1), rtx.clone())).unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Ok(true));
        w.tx.send(Request::Op(SetOp::Get(1), rtx)).unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Missing);
        assert_eq!(metrics.ops_total(), 4);
        w.shutdown();
    }

    #[test]
    fn worker_batch_round_trip_and_group_metrics() {
        let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_hash(Family::Volatile, 16));
        let metrics = Arc::new(Metrics::new());
        let w = ShardWorker::spawn(set, metrics.clone());
        let (btx, brx) = sync_channel(1);
        let batch = vec![
            SetOp::Insert(1, 10),
            SetOp::Insert(2, 20),
            SetOp::Get(1),
            SetOp::Remove(2),
            SetOp::Get(2),
        ];
        w.tx.send(Request::Batch(batch, BatchSink::blocking(btx))).unwrap();
        assert_eq!(
            brx.recv().unwrap(),
            vec![
                Response::Ok(true),
                Response::Ok(true),
                Response::Found(10),
                Response::Ok(true),
                Response::Missing,
            ]
        );
        assert_eq!(metrics.ops_total(), 5);
        assert!(metrics.batches.load(std::sync::atomic::Ordering::Relaxed) >= 1);
        assert_eq!(
            metrics.fence_ops.load(std::sync::atomic::Ordering::Relaxed),
            5,
            "every committed op is covered by the fence gauge"
        );
        w.shutdown();
    }

    #[test]
    fn worker_groups_queued_requests_into_one_commit() {
        // Pre-load the queue, then start the loop: its first wakeup must
        // drain the whole burst into a single group commit.
        let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_hash(Family::Soft, 1 << 10));
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Request>(256);
        let (rtx, rrx) = sync_channel::<Response>(256);
        for k in 0..128u64 {
            tx.send(Request::Op(SetOp::Insert(k, k), rtx.clone())).unwrap();
        }
        let m2 = metrics.clone();
        let handle =
            std::thread::spawn(move || worker_loop(rx, set, m2, GroupTuning::default()));
        for _ in 0..128 {
            assert_eq!(rrx.recv().unwrap(), Response::Ok(true));
        }
        drop(tx);
        handle.join().unwrap();
        use std::sync::atomic::Ordering;
        assert_eq!(metrics.batches.load(Ordering::Relaxed), 1, "one group commit");
        assert_eq!(metrics.batch_ops.load(Ordering::Relaxed), 128);
        assert_eq!(metrics.max_batch.load(Ordering::Relaxed), 128);
        assert_eq!(metrics.ops_total(), 128);
    }

    #[test]
    fn adaptive_k_shrinks_under_light_load_and_recovers_under_bursts() {
        use std::sync::atomic::Ordering;
        let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_hash(Family::Volatile, 64));
        let metrics = Arc::new(Metrics::new());
        let w = ShardWorker::spawn_with(
            set,
            metrics.clone(),
            GroupTuning { k_min: 1, k_max: 64 },
        );
        let (rtx, rrx) = sync_channel(4);
        // Light load: strictly one op in flight at a time. The controller
        // must walk k down to k_min (visible through the cumulative lo
        // gauge).
        for i in 0..64u64 {
            w.tx.send(Request::Op(SetOp::Insert(i, i), rtx.clone())).unwrap();
            assert_eq!(rrx.recv().unwrap(), Response::Ok(true));
        }
        assert_eq!(
            metrics.k_lo(),
            1,
            "single-op load must shrink the drain bound to k_min"
        );
        // Saturated load: a pre-queued burst. k ramps back up (doubling on
        // every saturated commit), so the cumulative hi gauge re-hits the
        // ceiling it started at and the burst completes.
        let (btx, brx) = sync_channel(64);
        for i in 1000..1512u64 {
            w.tx.send(Request::Op(SetOp::Insert(i, i), btx.clone())).unwrap();
        }
        for _ in 0..512 {
            assert_eq!(brx.recv().unwrap(), Response::Ok(true));
        }
        assert_eq!(metrics.k_hi(), 64, "saturation must grow the bound back");
        assert_eq!(metrics.ops_total(), 64 + 512);
        assert!(metrics.max_batch.load(Ordering::Relaxed) <= 64, "bound respected");
        w.shutdown();
    }

    #[test]
    fn prepare_parks_worker_and_applies_under_coordinator_control() {
        let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_hash(Family::Volatile, 64));
        let metrics = Arc::new(Metrics::new());
        let w = ShardWorker::spawn(set, metrics.clone());
        // Work queued before the Prepare must commit before the park.
        let (rtx, rrx) = sync_channel(4);
        w.tx.send(Request::Op(SetOp::Insert(1, 10), rtx.clone())).unwrap();
        let (ready_tx, ready_rx) = sync_channel(1);
        let (go_tx, go_rx) = sync_channel(2);
        let (done_tx, done_rx) = sync_channel(1);
        w.tx.send(Request::Prepare(TxnHandle { ready: ready_tx, go: go_rx, done: done_tx }))
            .unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Ok(true), "pre-park op committed");
        ready_rx.recv().expect("worker parks");
        // While parked, new requests queue but are NOT served.
        let (xtx, xrx) = sync_channel(1);
        w.tx.send(Request::Op(SetOp::Get(1), xtx)).unwrap();
        assert!(
            xrx.recv_timeout(std::time::Duration::from_millis(50)).is_err(),
            "a parked worker must not serve foreign requests"
        );
        // Coordinator-driven apply, then release.
        go_tx.send(TxnCmd::Apply(vec![SetOp::Insert(2, 20), SetOp::Get(1)])).unwrap();
        assert_eq!(
            done_rx.recv().unwrap(),
            vec![Response::Ok(true), Response::Found(10)]
        );
        go_tx.send(TxnCmd::Release).unwrap();
        // The queued request is served after release.
        assert_eq!(xrx.recv().unwrap(), Response::Found(10));
        w.shutdown();
    }

    #[test]
    fn dropped_coordinator_releases_parked_worker() {
        let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_hash(Family::Volatile, 16));
        let metrics = Arc::new(Metrics::new());
        let w = ShardWorker::spawn(set, metrics.clone());
        let (ready_tx, ready_rx) = sync_channel(1);
        let (go_tx, go_rx) = sync_channel::<TxnCmd>(1);
        let (done_tx, _done_rx) = sync_channel(1);
        w.tx.send(Request::Prepare(TxnHandle { ready: ready_tx, go: go_rx, done: done_tx }))
            .unwrap();
        ready_rx.recv().unwrap();
        drop(go_tx); // coordinator dies: abort path
        let (rtx, rrx) = sync_channel(1);
        w.tx.send(Request::Op(SetOp::Insert(5, 5), rtx)).unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Ok(true), "worker resumes after abort");
        w.shutdown();
    }

    fn slots_regions(pool: PoolId) -> usize {
        crate::pmem::region::regions_of(pool)
            .iter()
            .filter(|r| r.tag == crate::pmem::region::RegionTag::Slots)
            .count()
    }

    #[test]
    fn idle_worker_runs_maintenance_and_returns_areas() {
        // Fill several areas through the worker, delete 90%, then go
        // idle: the worker's IDLE_TICK wakeups must drive the compaction
        // pipeline until at least one area is handed back.
        let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_hash(Family::LinkFree, 16));
        let pool = set.durable_pool().unwrap();
        let metrics = Arc::new(Metrics::new());
        let w = ShardWorker::spawn(set.clone(), metrics);
        let (btx, brx) = sync_channel(1);
        let inserts: Vec<SetOp> = (0..9000u64).map(|k| SetOp::Insert(k, k)).collect();
        w.tx.send(Request::Batch(inserts, BatchSink::blocking(btx.clone()))).unwrap();
        assert!(brx.recv().unwrap().iter().all(|r| *r == Response::Ok(true)));
        let peak = slots_regions(pool);
        assert!(peak >= 3, "test must span several areas (got {peak})");
        let removes: Vec<SetOp> =
            (0..9000u64).filter(|k| k % 10 != 0).map(SetOp::Remove).collect();
        w.tx.send(Request::Batch(removes, BatchSink::blocking(btx))).unwrap();
        assert!(brx.recv().unwrap().iter().all(|r| *r == Response::Ok(true)));
        let deadline = Instant::now() + Duration::from_secs(20);
        while slots_regions(pool) >= peak {
            assert!(
                Instant::now() < deadline,
                "idle maintenance never returned an area ({} still live)",
                slots_regions(pool)
            );
            std::thread::sleep(Duration::from_millis(50));
        }
        // Survivors are intact and the shard still serves traffic.
        let (rtx, rrx) = sync_channel(1);
        w.tx.send(Request::Op(SetOp::Get(20), rtx.clone())).unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Found(20));
        w.tx.send(Request::Op(SetOp::Insert(1_000_000, 7), rtx)).unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Ok(true));
        w.shutdown();
    }

    #[test]
    fn shard_create_has_pool_for_durable_families() {
        let cfg = Config::default();
        let s = Shard::create(&cfg, 0);
        assert!(s.meta.pool.is_some());
        let mut vcfg = Config::default();
        vcfg.family = Family::Volatile;
        let v = Shard::create(&vcfg, 0);
        assert!(v.meta.pool.is_none());
    }

    #[test]
    fn skiplist_shard_serves_ordered_reads() {
        for family in [Family::LinkFree, Family::Soft] {
            let mut cfg = Config::default();
            cfg.family = family;
            cfg.structure = Structure::SkipList;
            let s = Shard::create(&cfg, 0);
            assert!(s.meta.pool.is_some());
            let ord = s.set.as_ordered().expect("skip-list shards are ordered");
            for k in 0..100u64 {
                s.set.insert(k, k + 1);
            }
            assert_eq!(ord.range(10, 12), vec![(10, 11), (11, 12), (12, 13)]);
            assert_eq!(ord.scan(97, 10), vec![(98, 99), (99, 100)]);
        }
        // Hash shards have no ordered view: the wire layer rejects RANGE.
        assert!(Shard::create(&Config::default(), 0).set.as_ordered().is_none());
    }
}
