//! Shard: one durable set instance plus the metadata needed to rebuild it
//! after a crash, and an optional worker-queue front for the TCP server.
//!
//! The sets themselves are lock-free and `Sync`, so the *data path* never
//! needs a worker hop — `DuraKv` calls straight into the set from any
//! thread. The queued front exists for the network server: it batches
//! requests per shard (bounded queue = backpressure) and keeps per-shard
//! metrics, the vLLM-router-style shape without pretending the structures
//! need serialisation.
//!
//! **Group commit.** A worker does not process one request per wakeup: it
//! drains everything queued (up to [`ShardWorker::GROUP_MAX`] ops) into a
//! single [`ConcurrentSet::apply_batch`] call, so all the drained updates
//! share one trailing fence (pmem's `PsyncScope`), and only then fans the
//! results back out to the per-request responders. Under load the fence
//! cost per op approaches 1/K; an idle queue degenerates to the old
//! one-op path with the identical per-op durability guarantee (every
//! response is sent strictly after the batch's trailing fence).

use crate::config::{Config, Structure};
use crate::pmem::PoolId;
use crate::sets::recovery::{PhaseTimings, RecoveredStats};
use crate::sets::{self, ConcurrentSet, Family, OpResult, SetOp};
use anyhow::Result;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use super::metrics::Metrics;

/// Everything needed to re-create a shard's volatile handle from its
/// durable areas.
#[derive(Clone, Copy, Debug)]
pub struct ShardMeta {
    pub index: usize,
    pub family: Family,
    pub structure: Structure,
    pub nbuckets: usize,
    pub pool: Option<PoolId>,
}

/// A shard of the KV service.
pub struct Shard {
    pub set: Box<dyn ConcurrentSet>,
    pub meta: ShardMeta,
}

impl Shard {
    /// Build a fresh shard per the config.
    pub fn create(cfg: &Config, index: usize) -> Shard {
        let nbuckets = cfg.buckets_per_shard();
        let set: Box<dyn ConcurrentSet> = match cfg.structure {
            Structure::Hash => sets::new_hash(cfg.family, nbuckets),
            Structure::List => sets::new_list(cfg.family),
        };
        let meta = ShardMeta {
            index,
            family: cfg.family,
            structure: cfg.structure,
            nbuckets,
            pool: set.durable_pool(),
        };
        Shard { set, meta }
    }

    /// Rebuild this shard from its durable areas (post-crash) with the
    /// default recovery worker count. Volatile shards come back empty.
    pub fn recover(meta: ShardMeta) -> Result<Shard> {
        Ok(Self::recover_timed(meta, crate::sets::recovery::default_threads())?.0)
    }

    /// [`Shard::recover`] with an explicit engine worker count, returning
    /// the engine's stats + per-phase timings for `RecoveryReport`.
    pub fn recover_timed(meta: ShardMeta, threads: usize) -> Result<(Shard, ShardRecovery)> {
        let mut rec = ShardRecovery::default();
        let set: Box<dyn ConcurrentSet> = match (meta.family, meta.structure, meta.pool) {
            (Family::Volatile, Structure::Hash, _) => {
                sets::new_hash(Family::Volatile, meta.nbuckets)
            }
            (Family::Volatile, Structure::List, _) => sets::new_list(Family::Volatile),
            (family, structure, Some(pool)) => {
                let (set, stats, timings): (Box<dyn ConcurrentSet>, _, _) =
                    match (family, structure) {
                        // Hash shards are resizable: recover the family list
                        // and re-wrap it, restoring the persisted bucket-count
                        // epoch (meta.nbuckets is only the pre-epoch fallback).
                        (Family::LinkFree, Structure::Hash) => {
                            let (h, s, t) =
                                sets::resizable::recover_linkfree_timed(pool, meta.nbuckets, threads);
                            (Box::new(h), s, t)
                        }
                        (Family::LinkFree, Structure::List) => {
                            let (l, s, t) = sets::linkfree::recover_list_timed(pool, threads);
                            (Box::new(l), s, t)
                        }
                        (Family::Soft, Structure::Hash) => {
                            let (h, s, t) =
                                sets::resizable::recover_soft_timed(pool, meta.nbuckets, threads);
                            (Box::new(h), s, t)
                        }
                        (Family::Soft, Structure::List) => {
                            let (l, s, t) = sets::soft::recover_list_timed(pool, threads);
                            (Box::new(l), s, t)
                        }
                        (Family::LogFree, Structure::Hash) => {
                            let (h, s, t) =
                                sets::resizable::recover_logfree_timed(pool, meta.nbuckets, threads);
                            (Box::new(h), s, t)
                        }
                        (Family::LogFree, Structure::List) => {
                            let (l, s, t) = sets::logfree::recover_list_timed(pool, threads);
                            (Box::new(l), s, t)
                        }
                        (Family::Volatile, _) => unreachable!(),
                    };
                rec.stats = stats;
                rec.timings = timings;
                set
            }
            (f, s, None) => anyhow::bail!("shard {:?}/{:?} has no durable pool", f, s),
        };
        // The recovered set has a fresh pool handle adopting the same id.
        let meta = ShardMeta { pool: set.durable_pool().or(meta.pool), ..meta };
        Ok((Shard { set, meta }, rec))
    }

    /// Recover this shard through the XLA classification artifacts where
    /// the layout is modelled (resizable link-free / SOFT hash shards);
    /// everything else — and any artifact failure *before the durable
    /// image is touched* — falls back to the exact Rust path. Returns
    /// whether the artifact path was actually used.
    pub fn recover_accel(meta: ShardMeta, threads: usize) -> Result<(Shard, ShardRecovery, bool)> {
        use crate::runtime::recovery_accel as accel;
        use crate::runtime::RecoveryPlanner;
        if let (Structure::Hash, Some(pool)) = (meta.structure, meta.pool) {
            let planned = match meta.family {
                Family::LinkFree => Some(RecoveryPlanner::with_cached(|p| {
                    accel::recover_resizable_linkfree_accel(p, pool, meta.nbuckets, threads)
                        .map(|(h, s, t)| (Box::new(h) as Box<dyn ConcurrentSet>, s, t))
                })),
                Family::Soft => Some(RecoveryPlanner::with_cached(|p| {
                    accel::recover_resizable_soft_accel(p, pool, meta.nbuckets, threads)
                        .map(|(h, s, t)| (Box::new(h) as Box<dyn ConcurrentSet>, s, t))
                })),
                // No classification kernel for log-free (its membership is
                // reachability, not a per-slot rule) or volatile shards.
                _ => None,
            };
            if let Some(Ok((set, stats, timings))) = planned {
                let meta = ShardMeta { pool: set.durable_pool().or(meta.pool), ..meta };
                return Ok((Shard { set, meta }, ShardRecovery { stats, timings }, true));
            }
        }
        let (shard, rec) = Self::recover_timed(meta, threads)?;
        Ok((shard, rec, false))
    }
}

/// What recovering one shard found and cost (zeroed for volatile shards).
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardRecovery {
    pub stats: RecoveredStats,
    pub timings: PhaseTimings,
}

/// A queued request (server path).
pub enum Request {
    /// One routed op + its responder.
    Op(SetOp, SyncSender<Response>),
    /// A pre-routed batch (pipelined connection / `MULTI`): one responder
    /// for the whole vector, results in op order.
    Batch(Vec<SetOp>, SyncSender<Vec<Response>>),
    Shutdown,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Response {
    Found(u64),
    Missing,
    Ok(bool),
}

impl Response {
    fn from_result(r: OpResult) -> Response {
        match r {
            OpResult::Applied(b) | OpResult::Found(b) => Response::Ok(b),
            OpResult::Value(Some(v)) => Response::Found(v),
            OpResult::Value(None) => Response::Missing,
        }
    }
}

/// Where one drained request's results go back to.
enum Sink {
    One(SyncSender<Response>),
    Many(usize, SyncSender<Vec<Response>>),
}

/// Worker-queue front over a shard set: bounded channel + one worker
/// thread per shard, draining the queue into group commits.
pub struct ShardWorker {
    pub tx: SyncSender<Request>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ShardWorker {
    /// Queue capacity per shard (backpressure bound for the TCP server).
    pub const QUEUE_CAP: usize = 1024;

    /// Drain bound per group commit: once this many ops are gathered the
    /// batch is applied even if the queue still has requests (latency
    /// bound; a single oversized `Request::Batch` is never split).
    pub const GROUP_MAX: usize = 512;

    pub fn spawn(set: Arc<dyn ConcurrentSet>, metrics: Arc<Metrics>) -> ShardWorker {
        let (tx, rx): (SyncSender<Request>, Receiver<Request>) = sync_channel(Self::QUEUE_CAP);
        let join = std::thread::spawn(move || worker_loop(rx, set, metrics));
        ShardWorker { tx, join: Some(join) }
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Gather one request into the pending group.
fn gather(req: Request, ops: &mut Vec<SetOp>, sinks: &mut Vec<Sink>, shutdown: &mut bool) {
    match req {
        Request::Op(op, tx) => {
            ops.push(op);
            sinks.push(Sink::One(tx));
        }
        Request::Batch(batch, tx) => {
            sinks.push(Sink::Many(batch.len(), tx));
            ops.extend(batch);
        }
        Request::Shutdown => *shutdown = true,
    }
}

/// The group-commit loop: block for one request, drain whatever else is
/// already queued, apply everything as one batch (one trailing fence),
/// then scatter results back to the responders.
fn worker_loop(rx: Receiver<Request>, set: Arc<dyn ConcurrentSet>, metrics: Arc<Metrics>) {
    let mut ops: Vec<SetOp> = Vec::new();
    let mut sinks: Vec<Sink> = Vec::new();
    loop {
        ops.clear();
        sinks.clear();
        let mut shutdown = false;
        match rx.recv() {
            Ok(req) => gather(req, &mut ops, &mut sinks, &mut shutdown),
            Err(_) => return,
        }
        while !shutdown && ops.len() < ShardWorker::GROUP_MAX {
            match rx.try_recv() {
                Ok(req) => gather(req, &mut ops, &mut sinks, &mut shutdown),
                Err(_) => break,
            }
        }
        if !sinks.is_empty() {
            let t0 = Instant::now();
            // The group commit: results become claimable only after the
            // batch's trailing fence, i.e. when apply_batch returns.
            let results = set.apply_batch(&ops);
            if !ops.is_empty() {
                metrics.record_group(ops.len() as u64);
                // One histogram entry per group commit: the histogram
                // tracks commit latency (every request in the group
                // waited this long), not per-op cost repeated N times.
                metrics.record_latency(t0.elapsed());
            }
            for (&op, &res) in ops.iter().zip(results.iter()) {
                metrics.record_op(op, res);
            }
            let mut i = 0;
            for sink in sinks.drain(..) {
                match sink {
                    Sink::One(tx) => {
                        let _ = tx.send(Response::from_result(results[i]));
                        i += 1;
                    }
                    Sink::Many(n, tx) => {
                        let group: Vec<Response> =
                            results[i..i + n].iter().map(|&r| Response::from_result(r)).collect();
                        let _ = tx.send(group);
                        i += n;
                    }
                }
            }
        }
        if shutdown {
            return;
        }
    }
}

impl Drop for ShardWorker {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_round_trip() {
        let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_hash(Family::Volatile, 16));
        let metrics = Arc::new(Metrics::new());
        let w = ShardWorker::spawn(set, metrics.clone());
        let (rtx, rrx) = sync_channel(1);
        w.tx.send(Request::Op(SetOp::Insert(1, 10), rtx.clone())).unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Ok(true));
        w.tx.send(Request::Op(SetOp::Get(1), rtx.clone())).unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Found(10));
        w.tx.send(Request::Op(SetOp::Remove(1), rtx.clone())).unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Ok(true));
        w.tx.send(Request::Op(SetOp::Get(1), rtx)).unwrap();
        assert_eq!(rrx.recv().unwrap(), Response::Missing);
        assert_eq!(metrics.ops_total(), 4);
        w.shutdown();
    }

    #[test]
    fn worker_batch_round_trip_and_group_metrics() {
        let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_hash(Family::Volatile, 16));
        let metrics = Arc::new(Metrics::new());
        let w = ShardWorker::spawn(set, metrics.clone());
        let (btx, brx) = sync_channel(1);
        let batch = vec![
            SetOp::Insert(1, 10),
            SetOp::Insert(2, 20),
            SetOp::Get(1),
            SetOp::Remove(2),
            SetOp::Get(2),
        ];
        w.tx.send(Request::Batch(batch, btx)).unwrap();
        assert_eq!(
            brx.recv().unwrap(),
            vec![
                Response::Ok(true),
                Response::Ok(true),
                Response::Found(10),
                Response::Ok(true),
                Response::Missing,
            ]
        );
        assert_eq!(metrics.ops_total(), 5);
        assert!(metrics.batches.load(std::sync::atomic::Ordering::Relaxed) >= 1);
        w.shutdown();
    }

    #[test]
    fn worker_groups_queued_requests_into_one_commit() {
        // Pre-load the queue, then start the loop: its first wakeup must
        // drain the whole burst into a single group commit.
        let set: Arc<dyn ConcurrentSet> = Arc::from(sets::new_hash(Family::Soft, 1 << 10));
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Request>(256);
        let (rtx, rrx) = sync_channel::<Response>(256);
        for k in 0..128u64 {
            tx.send(Request::Op(SetOp::Insert(k, k), rtx.clone())).unwrap();
        }
        let m2 = metrics.clone();
        let handle = std::thread::spawn(move || worker_loop(rx, set, m2));
        for _ in 0..128 {
            assert_eq!(rrx.recv().unwrap(), Response::Ok(true));
        }
        drop(tx);
        handle.join().unwrap();
        use std::sync::atomic::Ordering;
        assert_eq!(metrics.batches.load(Ordering::Relaxed), 1, "one group commit");
        assert_eq!(metrics.batch_ops.load(Ordering::Relaxed), 128);
        assert_eq!(metrics.max_batch.load(Ordering::Relaxed), 128);
        assert_eq!(metrics.ops_total(), 128);
    }

    #[test]
    fn shard_create_has_pool_for_durable_families() {
        let cfg = Config::default();
        let s = Shard::create(&cfg, 0);
        assert!(s.meta.pool.is_some());
        let mut vcfg = Config::default();
        vcfg.family = Family::Volatile;
        let v = Shard::create(&vcfg, 0);
        assert!(v.meta.pool.is_none());
    }
}
