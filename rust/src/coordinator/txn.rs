//! Atomic cross-shard batches: two-phase group commit over a persisted
//! commit record (DESIGN.md §Transactions).
//!
//! A plain `MULTI`/`EXEC` batch is only per-shard atomic: each shard's
//! sub-batch is one group commit, but a crash can keep shard A's half and
//! lose shard B's. `ATOMIC` batches close that gap with a redo-record
//! protocol whose invariant is simple to state:
//!
//! > **No sub-batch effect may become durable before the commit record
//! > does; no foreign update may interleave between the applies and the
//! > record's retirement.**
//!
//! Protocol (wire path; phases named after the two-phase-commit roles):
//!
//! 1. **Prepare.** The coordinator (the connection thread) takes the
//!    store-wide txn lock and sends `Request::Prepare` to every
//!    participating shard worker. Each worker finishes the group it was
//!    draining, signals readiness, and **parks** — the participating
//!    shards are now *update*-quiescent for the whole window, because
//!    all wire **updates** flow through their workers. (The read lane
//!    deliberately does not: concurrent GET/HAS bursts may observe a
//!    half-applied atomic batch mid-window, which is linearizable — the
//!    batch's ops linearize individually; atomicity here is a *crash*
//!    guarantee, not an isolation level. Only update exclusion is needed
//!    for roll-forward idempotence.)
//! 2. **Commit point.** The coordinator writes the full op list into the
//!    persisted commit record ([`TxnLog`], a `pmem::root::root_array` in
//!    its own crash-reverted pool), psyncs it, then flips the record's
//!    state word to `COMMITTED` and psyncs that. Ops-before-state
//!    ordering means a torn record can never read as committed.
//! 3. **Apply.** Each parked worker applies its sub-batch inside one
//!    `PsyncScope` (per-op flushes, one trailing fence) and reports its
//!    results — but stays parked.
//! 4. **Retire + release.** The coordinator flips the record back to
//!    `FREE`, psyncs, releases the workers, and only then acks.
//!
//! Crash analysis (the rollback-vs-rollforward rule recovery applies):
//! * record not `COMMITTED` → nothing was applied (applies only start
//!   after the commit point) → **discard**: the batch happened-never.
//! * record `COMMITTED` → applies may be partial → **roll forward**:
//!   recovery re-applies the full op list from the record. Re-application
//!   is idempotent here precisely because the parked workers excluded
//!   every other wire update between the applies and retirement — no
//!   acked foreign op can be undone by the redo.
//!
//! The in-process path ([`super::DuraKv::apply_batch_atomic`]) runs the
//! same record protocol but applies sub-batches directly; callers must
//! not race conflicting direct-path updates during the call (the wire
//! plane enforces that exclusion via the parked workers).

use super::metrics::Metrics;
use super::shard::{Request, Response, TxnCmd, TxnHandle};
use super::Router;
use crate::pmem::root::{root_array, RootArray};
use crate::pmem::PoolId;
use crate::sets::{OpResult, SetOp};
use anyhow::{anyhow, Result};
use once_cell::sync::Lazy;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Mutex;

/// Largest atomic batch (matches the server's `MULTI` bound).
pub const TXN_OPS_MAX: usize = 4096;

/// Record layout: `[state, nops, batch_id, reserved]` + 3 words per op.
const HDR_WORDS: usize = 4;
const WORDS_PER_OP: usize = 3;

const STATE_FREE: u64 = 0;
const STATE_COMMITTED: u64 = 2;

/// Process-unique names for per-store commit records.
static NEXT_LOG: AtomicU64 = AtomicU64::new(1);
/// Process-unique atomic-batch ids (diagnostics).
static NEXT_BATCH: AtomicU64 = AtomicU64::new(1);

/// Everything recovery needs to find a store's commit record after a
/// crash (carried by `CrashTicket` like the shard metas).
#[derive(Clone, Copy, Debug)]
pub struct TxnLogMeta {
    arr: RootArray,
}

/// The store's persisted commit record + the store-wide atomic-batch
/// lock. One in-flight atomic batch per store: cross-shard atomicity is
/// the deliberate slow path (it update-quiesces its shards), so
/// serialising the batches keeps the worker-parking protocol
/// deadlock-free by construction.
pub struct TxnLog {
    arr: RootArray,
    lock: Mutex<()>,
    /// Return the record to the process-wide free pool on drop. Cleared
    /// by `detach` when a crash ticket takes ownership of the record
    /// across the store's death (recovery re-adopts it).
    recycle: std::sync::atomic::AtomicBool,
}

/// Retired commit records available for reuse: a store's record is ~98 KB
/// of (simulated) durable memory, and the global region registry never
/// frees — without recycling every `DuraKv::create` (tests, bench points)
/// would leak one. Only records whose state word reads `FREE` are pooled;
/// anything else (a fault-injection panic left mid-protocol bytes) is
/// deliberately leaked rather than handed to a new store.
static FREE_LOGS: Lazy<Mutex<Vec<RootArray>>> = Lazy::new(|| Mutex::new(Vec::new()));

impl Drop for TxnLog {
    fn drop(&mut self) {
        if self.recycle.load(Ordering::Relaxed)
            && self.arr.word(0).load(Ordering::Acquire) == STATE_FREE
        {
            FREE_LOGS.lock().unwrap_or_else(|e| e.into_inner()).push(self.arr);
        }
    }
}

fn encode(op: SetOp) -> (u64, u64, u64) {
    match op {
        SetOp::Insert(k, v) => (0, k, v),
        SetOp::Remove(k) => (1, k, 0),
        SetOp::Contains(k) => (2, k, 0),
        SetOp::Get(k) => (3, k, 0),
    }
}

fn decode(kind: u64, key: u64, value: u64) -> SetOp {
    match kind {
        0 => SetOp::Insert(key, value),
        1 => SetOp::Remove(key),
        2 => SetOp::Contains(key),
        _ => SetOp::Get(key),
    }
}

impl TxnLog {
    /// A commit record in its own durable pool: recycled from the free
    /// pool when available, freshly allocated otherwise.
    pub fn create() -> TxnLog {
        let pooled = FREE_LOGS.lock().unwrap_or_else(|e| e.into_inner()).pop();
        let arr = pooled.unwrap_or_else(|| {
            let name = format!("txn.log.{}", NEXT_LOG.fetch_add(1, Ordering::Relaxed));
            root_array(&name, HDR_WORDS + WORDS_PER_OP * TXN_OPS_MAX)
        });
        TxnLog { arr, lock: Mutex::new(()), recycle: std::sync::atomic::AtomicBool::new(true) }
    }

    /// Re-attach to a record carried over a crash.
    pub fn adopt(meta: TxnLogMeta) -> TxnLog {
        TxnLog {
            arr: meta.arr,
            lock: Mutex::new(()),
            recycle: std::sync::atomic::AtomicBool::new(true),
        }
    }

    /// Hand record ownership to a crash ticket: the store is about to
    /// drop, but the record must survive for recovery to consult.
    pub(crate) fn detach(&self) {
        self.recycle.store(false, Ordering::Relaxed);
    }

    pub fn meta(&self) -> TxnLogMeta {
        TxnLogMeta { arr: self.arr }
    }

    /// The record's pool — must be part of the store's crash set so the
    /// simulator reverts unfenced record writes.
    pub fn pool(&self) -> PoolId {
        self.arr.pool()
    }

    /// Take the store-wide atomic-batch lock (poison carries no state
    /// worth propagating: a poisoned lock means a fault-injection test
    /// unwound mid-batch, which is exactly what recovery handles).
    fn lock(&self) -> std::sync::MutexGuard<'_, ()> {
        self.lock.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Publish the redo record and commit. Ops (and the header) are
    /// durable strictly before the state word flips to `COMMITTED`: a
    /// crash between the two psyncs reads as an uncommitted record.
    /// Deliberately *not* under a `PsyncScope` — the state psync is the
    /// batch's commit point and must be a real fence.
    fn publish(&self, ops: &[SetOp], batch_id: u64) {
        assert!(ops.len() <= TXN_OPS_MAX, "atomic batch exceeds TXN_OPS_MAX");
        debug_assert_eq!(self.arr.word(0).load(Ordering::Relaxed), STATE_FREE);
        for (i, &op) in ops.iter().enumerate() {
            let (kind, key, value) = encode(op);
            let base = HDR_WORDS + i * WORDS_PER_OP;
            self.arr.word(base).store(kind, Ordering::Relaxed);
            self.arr.word(base + 1).store(key, Ordering::Relaxed);
            self.arr.word(base + 2).store(value, Ordering::Relaxed);
        }
        self.arr.word(1).store(ops.len() as u64, Ordering::Relaxed);
        self.arr.word(2).store(batch_id, Ordering::Relaxed);
        // Header (minus state) + ops in one bulk psync, then the state.
        self.arr.persist_range(1, HDR_WORDS - 1 + ops.len() * WORDS_PER_OP);
        self.arr.word(0).store(STATE_COMMITTED, Ordering::Release);
        self.arr.persist_range(0, 1);
    }

    /// Retire the record (the batch is fully applied and fenced).
    fn retire(&self) {
        self.arr.word(0).store(STATE_FREE, Ordering::Release);
        self.arr.persist_range(0, 1);
    }

    /// Recovery's view: the committed-but-unretired batch, if any.
    pub fn pending(&self) -> Option<(u64, Vec<SetOp>)> {
        if self.arr.word(0).load(Ordering::Acquire) != STATE_COMMITTED {
            return None;
        }
        let nops = (self.arr.word(1).load(Ordering::Relaxed) as usize).min(TXN_OPS_MAX);
        let batch_id = self.arr.word(2).load(Ordering::Relaxed);
        let ops = (0..nops)
            .map(|i| {
                let base = HDR_WORDS + i * WORDS_PER_OP;
                decode(
                    self.arr.word(base).load(Ordering::Relaxed),
                    self.arr.word(base + 1).load(Ordering::Relaxed),
                    self.arr.word(base + 2).load(Ordering::Relaxed),
                )
            })
            .collect();
        Some((batch_id, ops))
    }

    /// Roll a committed-but-unretired batch forward through `apply`
    /// (recovery path: re-apply the full op list per shard, then retire).
    /// Returns the number of batches rolled forward (0 or 1).
    pub fn roll_forward(
        &self,
        router: Router,
        mut apply: impl FnMut(usize, &[SetOp]) -> Vec<OpResult>,
    ) -> usize {
        let Some((_, ops)) = self.pending() else {
            return 0;
        };
        for (shard, sub) in router.partition(&ops).into_iter().enumerate() {
            if sub.is_empty() {
                continue;
            }
            let sub_ops: Vec<SetOp> = sub.iter().map(|&(_, op)| op).collect();
            let _ = apply(shard, &sub_ops);
        }
        self.retire();
        1
    }

    /// In-process atomic batch: publish → apply per shard → retire.
    /// All-or-nothing versus crashes at any flush (see the module docs'
    /// crash analysis); `apply` must group-commit durably per shard
    /// (`ConcurrentSet::apply_batch` does). Concurrent conflicting
    /// updates outside this lock void the roll-forward idempotence — the
    /// wire path parks the shard workers instead.
    pub fn execute_inproc(
        &self,
        router: Router,
        ops: &[SetOp],
        metrics: &Metrics,
        mut apply: impl FnMut(usize, &[SetOp]) -> Vec<OpResult>,
    ) -> Vec<OpResult> {
        let _g = self.lock();
        let batch_id = NEXT_BATCH.fetch_add(1, Ordering::Relaxed);
        let per_shard = router.partition(ops);
        self.publish(ops, batch_id);
        let mut out = vec![OpResult::Found(false); ops.len()];
        for (shard, sub) in per_shard.iter().enumerate() {
            if sub.is_empty() {
                continue;
            }
            let sub_ops: Vec<SetOp> = sub.iter().map(|&(_, op)| op).collect();
            let results = apply(shard, &sub_ops);
            for (&(i, _), r) in sub.iter().zip(results) {
                out[i] = r;
            }
        }
        self.retire();
        // Ack boundary: the batch's results leave this call as committed.
        crate::pmem::check::assert_persisted("txn.execute_inproc");
        metrics.record_atomic(ops.len() as u64);
        out
    }

    /// Wire-path atomic batch over parked shard workers (the full
    /// four-step protocol in the module docs). Returns responses in op
    /// order. `apply_direct` is the degraded-mode escape hatch: if a
    /// participating worker dies after the commit point (only reachable
    /// when its thread panicked or was shut down), the batch is completed
    /// *directly* on this thread and the record retired before the error
    /// is returned — the store must never resume service with a stale
    /// `COMMITTED` record, or a later crash would roll the old batch
    /// forward over subsequently-acked ops. Completing (rather than
    /// undoing) is sound: re-applying is idempotent inside the window
    /// (surviving workers stay parked, the dead one serves no one), and
    /// "fully applied but unacked" is an allowed outcome for an errored
    /// frame.
    pub fn execute_via_workers(
        &self,
        router: Router,
        senders: &[SyncSender<Request>],
        ops: &[SetOp],
        metrics: &Metrics,
        apply_direct: impl Fn(usize, &[SetOp]) -> Vec<OpResult>,
    ) -> Result<Vec<Response>> {
        let _g = self.lock();
        let batch_id = NEXT_BATCH.fetch_add(1, Ordering::Relaxed);
        let per_shard = router.partition(ops);

        // Phase 1: park every participating worker. Errors here abort
        // cleanly: nothing is published, dropping the handles releases
        // any already-parked workers without applying.
        struct Participant {
            shard: usize,
            go: SyncSender<TxnCmd>,
            ready: std::sync::mpsc::Receiver<()>,
            done: std::sync::mpsc::Receiver<Vec<Response>>,
        }
        let mut parts: Vec<Participant> = Vec::new();
        for (shard, sub) in per_shard.iter().enumerate() {
            if sub.is_empty() {
                continue;
            }
            let (ready_tx, ready_rx) = sync_channel(1);
            let (go_tx, go_rx) = sync_channel(2);
            let (done_tx, done_rx) = sync_channel(1);
            senders[shard]
                .send(Request::Prepare(TxnHandle {
                    ready: ready_tx,
                    go: go_rx,
                    done: done_tx,
                }))
                .map_err(|_| anyhow!("shard {shard} worker is gone"))?;
            parts.push(Participant { shard, go: go_tx, ready: ready_rx, done: done_rx });
        }
        for p in &parts {
            p.ready
                .recv()
                .map_err(|_| anyhow!("shard {} never parked", p.shard))?;
        }

        // Phase 2: the commit point. Every participating shard's *update*
        // traffic is excluded (reads never mutate); nothing of the batch
        // is durable yet. From here on the record MUST reach `retire`
        // before this function returns on every path.
        self.publish(ops, batch_id);

        // Phase 3: apply on the parked workers (one PsyncScope each).
        let mut failed: Option<anyhow::Error> = None;
        let mut out = vec![Response::Missing; ops.len()];
        for p in &parts {
            let sub_ops: Vec<SetOp> =
                per_shard[p.shard].iter().map(|&(_, op)| op).collect();
            if p.go.send(TxnCmd::Apply(sub_ops)).is_err() {
                failed = Some(anyhow!("shard {} worker died pre-apply", p.shard));
                break;
            }
        }
        if failed.is_none() {
            for p in &parts {
                match p.done.recv() {
                    Ok(results) => {
                        for (&(i, _), r) in per_shard[p.shard].iter().zip(results) {
                            out[i] = r;
                        }
                    }
                    Err(_) => {
                        failed = Some(anyhow!("shard {} worker died mid-apply", p.shard));
                        break;
                    }
                }
            }
        }
        if failed.is_some() {
            // Degraded completion: re-apply every sub-batch directly
            // (idempotent; partial worker applies are completed, finished
            // ones are no-ops), so the committed record can be retired.
            for (shard, sub) in per_shard.iter().enumerate() {
                if sub.is_empty() {
                    continue;
                }
                let sub_ops: Vec<SetOp> = sub.iter().map(|&(_, op)| op).collect();
                let _ = apply_direct(shard, &sub_ops);
            }
        }

        // Phase 4: retire, then release the workers, then (caller) ack.
        self.retire();
        // Ack boundary: responses leave this call as a committed batch.
        crate::pmem::check::assert_persisted("txn.execute_via_workers");
        for p in &parts {
            let _ = p.go.send(TxnCmd::Release);
        }
        if let Some(e) = failed {
            return Err(e);
        }
        metrics.record_atomic(ops.len() as u64);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sets::{self, ConcurrentSet, Family};

    #[test]
    fn encode_decode_roundtrip() {
        for op in [
            SetOp::Insert(7, 9),
            SetOp::Remove(3),
            SetOp::Contains(11),
            SetOp::Get(u64::MAX),
        ] {
            let (k, a, b) = encode(op);
            assert_eq!(decode(k, a, b), op);
        }
    }

    #[test]
    fn retired_records_are_recycled_not_leaked() {
        // 50 create→drop cycles must not allocate 50 fresh records: the
        // free pool is shared with concurrent tests, so assert reuse via
        // the fresh-allocation counter instead of record identity.
        let before = NEXT_LOG.load(Ordering::Relaxed);
        for _ in 0..50 {
            let log = TxnLog::create();
            drop(log); // state FREE -> pooled
        }
        let fresh = NEXT_LOG.load(Ordering::Relaxed) - before;
        assert!(fresh < 50, "recycling never engaged ({fresh} fresh allocations in 50 cycles)");

        // A record left mid-protocol (COMMITTED) must never reach the
        // pool: nothing but its own drop could add it, so this check is
        // race-free.
        let b = TxnLog::create();
        b.publish(&[SetOp::Insert(1, 1)], 9);
        let base_b = b.arr.word(0) as *const AtomicU64 as usize;
        drop(b); // deliberately leaked
        let pooled = FREE_LOGS.lock().unwrap_or_else(|e| e.into_inner());
        assert!(
            !pooled.iter().any(|a| a.word(0) as *const AtomicU64 as usize == base_b),
            "a committed (mid-protocol) record must never be recycled"
        );
    }

    #[test]
    fn publish_pending_retire_cycle() {
        let log = TxnLog::create();
        assert!(log.pending().is_none(), "fresh record is free");
        let ops = vec![SetOp::Insert(1, 10), SetOp::Remove(2), SetOp::Get(3)];
        log.publish(&ops, 42);
        let (id, got) = log.pending().expect("committed record is pending");
        assert_eq!(id, 42);
        assert_eq!(got, ops);
        log.retire();
        assert!(log.pending().is_none(), "retired record is free again");
    }

    #[test]
    fn execute_inproc_applies_and_retires() {
        let router = Router::new(2);
        let sets: Vec<Box<dyn ConcurrentSet>> =
            (0..2).map(|_| sets::new_hash(Family::Soft, 64)).collect();
        let log = TxnLog::create();
        let metrics = Metrics::new();
        let ops: Vec<SetOp> = (0..40u64)
            .map(|k| SetOp::Insert(k, k + 1))
            .chain([SetOp::Get(5), SetOp::Remove(6), SetOp::Contains(6)])
            .collect();
        let res = log.execute_inproc(router, &ops, &metrics, |s, sub| sets[s].apply_batch(sub));
        for r in res.iter().take(40) {
            assert_eq!(*r, OpResult::Applied(true));
        }
        assert_eq!(res[40], OpResult::Value(Some(6)));
        assert_eq!(res[41], OpResult::Applied(true));
        assert_eq!(res[42], OpResult::Found(false));
        assert!(log.pending().is_none(), "record retired after a clean batch");
        assert_eq!(
            metrics.atomics.load(std::sync::atomic::Ordering::Relaxed),
            1,
            "atomic batch counted"
        );
        let total: usize = sets.iter().map(|s| s.len_approx()).sum();
        assert_eq!(total, 39);
    }

    #[test]
    fn roll_forward_reapplies_then_retires() {
        let router = Router::new(2);
        let sets: Vec<Box<dyn ConcurrentSet>> =
            (0..2).map(|_| sets::new_hash(Family::LinkFree, 64)).collect();
        let log = TxnLog::create();
        let ops: Vec<SetOp> = (100..140u64).map(|k| SetOp::Insert(k, k)).collect();
        log.publish(&ops, 7);
        // Simulate a partial pre-crash apply: only shard 0's sub-batch ran.
        let per_shard = router.partition(&ops);
        let sub0: Vec<SetOp> = per_shard[0].iter().map(|&(_, op)| op).collect();
        let _ = sets[0].apply_batch(&sub0);
        // Roll forward must complete the batch idempotently.
        let rolled = log.roll_forward(router, |s, sub| sets[s].apply_batch(sub));
        assert_eq!(rolled, 1);
        assert!(log.pending().is_none());
        for k in 100..140u64 {
            let s = router.shard_of(k);
            assert_eq!(sets[s].get(k), Some(k), "key {k} after roll-forward");
        }
        assert_eq!(log.roll_forward(router, |s, sub| sets[s].apply_batch(sub)), 0);
    }
}
