//! Key → shard routing.
//!
//! The router hashes with a salt *different* from the in-shard bucket hash
//! (which uses `mix64(key)` low bits): taking the shard index from the
//! same bits would leave each shard's hash table with systematically
//! empty buckets.

use crate::sets::SetOp;
use crate::util::mix64;

/// Deterministic router over a fixed shard count.
#[derive(Clone, Copy, Debug)]
pub struct Router {
    shards: usize,
}

impl Router {
    pub fn new(shards: usize) -> Self {
        assert!(shards >= 1);
        Router { shards }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Shard owning `key`.
    #[inline]
    pub fn shard_of(&self, key: u64) -> usize {
        // Upper 32 bits of a salted mix: independent of the bucket hash.
        ((mix64(key ^ 0x5EED_0F12_0373_0AD5) >> 32) as usize) % self.shards
    }

    /// Partition a mixed batch into per-shard sub-batches, tagging each
    /// op with its original index so callers can reassemble results in
    /// op order. Relative order within a shard is preserved (the
    /// per-shard sub-batch is the op sequence that shard observes). The
    /// one routing plan shared by `DuraKv::apply_batch`, the server's
    /// burst dispatch and the atomic-batch coordinator.
    pub fn partition(&self, ops: &[SetOp]) -> Vec<Vec<(usize, SetOp)>> {
        let mut per_shard: Vec<Vec<(usize, SetOp)>> = vec![Vec::new(); self.shards];
        for (i, &op) in ops.iter().enumerate() {
            per_shard[self.shard_of(op.key())].push((i, op));
        }
        per_shard
    }

    /// Shards an *ordered* query must visit: all of them. Point keys
    /// hash-distribute across shards, so any key interval is spread over
    /// every shard — an ordered burst fans out as one `range_batch`
    /// (merge-walk) per shard and the caller k-way merges the per-shard
    /// sorted runs back into key order (`conn::merge_sorted_runs`).
    #[inline]
    pub fn all_shards(&self) -> std::ops::Range<usize> {
        0..self.shards
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_deterministic_and_total() {
        let r = Router::new(7);
        for k in 0..10_000u64 {
            let s = r.shard_of(k);
            assert!(s < 7);
            assert_eq!(s, r.shard_of(k));
        }
    }

    #[test]
    fn routing_is_balanced() {
        let r = Router::new(8);
        let mut counts = [0usize; 8];
        let n = 80_000u64;
        for k in 0..n {
            counts[r.shard_of(k)] += 1;
        }
        for &c in &counts {
            let expect = n as usize / 8;
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "imbalanced: {counts:?}"
            );
        }
    }

    #[test]
    fn partition_covers_all_ops_in_shard_order() {
        let r = Router::new(3);
        let ops: Vec<SetOp> = (0..100u64).map(|k| SetOp::Insert(k, k)).collect();
        let parts = r.partition(&ops);
        assert_eq!(parts.len(), 3);
        let mut seen = vec![false; ops.len()];
        for (s, sub) in parts.iter().enumerate() {
            let mut prev = None;
            for &(i, op) in sub {
                assert_eq!(r.shard_of(op.key()), s, "op {i} routed to wrong shard");
                assert_eq!(op, ops[i]);
                assert!(!std::mem::replace(&mut seen[i], true), "op {i} duplicated");
                assert!(prev.map(|p| p < i).unwrap_or(true), "in-shard order broken");
                prev = Some(i);
            }
        }
        assert!(seen.iter().all(|&s| s), "every op lands in exactly one shard");
    }

    #[test]
    fn router_hash_is_independent_of_bucket_hash() {
        // If shard index and bucket index were correlated, all keys of a
        // shard would land in a fraction of its buckets. Check that keys
        // routed to shard 0 still cover most of a 64-bucket space.
        let r = Router::new(4);
        let mut buckets = std::collections::HashSet::new();
        for k in 0..100_000u64 {
            if r.shard_of(k) == 0 {
                buckets.insert(mix64(k) & 63);
            }
        }
        assert!(buckets.len() >= 60, "only {} buckets covered", buckets.len());
    }
}
