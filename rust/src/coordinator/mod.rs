//! DuraKv — the sharded durable key-value service built on the paper's
//! sets.
//!
//! Architecture (DESIGN.md):
//!
//! ```text
//!   clients ──► server (TCP, line protocol) ──► router ──► shard queues
//!                                        │                    │
//!   DuraKv::get/put/del (in-process) ────┴── direct lock-free calls
//!                                                             │
//!   crash ─► pmem::crash ─► recovery (per-shard, rust or XLA-accelerated)
//! ```
//!
//! The sets are lock-free and `Sync`, so the in-process data path routes
//! and calls directly; the queued path (bounded per-shard queues + worker
//! threads) serves the network front with backpressure and metrics, and
//! group-commits each queue drain through `ConcurrentSet::apply_batch`
//! so concurrent wire traffic shares trailing fences (DESIGN.md
//! §Batching).

pub mod conn;
pub mod metrics;
pub mod reactor;
pub mod recovery;
pub mod router;
pub mod server;
pub mod shard;
pub mod txn;

use crate::config::Config;
use crate::pmem::CrashPolicy;
use crate::sets::{GrowthStats, OpResult, SetOp};
use std::sync::Arc;

pub use metrics::Metrics;
pub use router::Router;
pub use shard::{Shard, ShardMeta};
pub use txn::TxnLog;

/// The sharded durable KV store.
pub struct DuraKv {
    cfg: Config,
    router: Router,
    shards: Vec<Shard>,
    /// Persisted commit record + lock for atomic cross-shard batches.
    pub(crate) txn: TxnLog,
    pub metrics: Arc<Metrics>,
}

impl DuraKv {
    /// Create a fresh store per the config (also applies the pmem-level
    /// settings from the config).
    pub fn create(cfg: Config) -> DuraKv {
        cfg.apply_pmem();
        let shards = (0..cfg.shards).map(|i| Shard::create(&cfg, i)).collect();
        DuraKv {
            router: Router::new(cfg.shards),
            shards,
            cfg,
            txn: TxnLog::create(),
            metrics: Arc::new(Metrics::new()),
        }
    }

    pub fn config(&self) -> &Config {
        &self.cfg
    }

    pub fn router(&self) -> Router {
        self.router
    }

    pub fn shard_metas(&self) -> Vec<ShardMeta> {
        self.shards.iter().map(|s| s.meta).collect()
    }

    #[inline]
    fn shard(&self, key: u64) -> &Shard {
        &self.shards[self.router.shard_of(key)]
    }

    // ----- direct (in-process) data path -----

    pub fn put(&self, key: u64, value: u64) -> bool {
        self.shard(key).set.insert(key, value)
    }

    pub fn get(&self, key: u64) -> Option<u64> {
        self.shard(key).set.get(key)
    }

    pub fn del(&self, key: u64) -> bool {
        self.shard(key).set.remove(key)
    }

    pub fn contains(&self, key: u64) -> bool {
        self.shard(key).set.contains(key)
    }

    pub fn len_approx(&self) -> usize {
        self.shards.iter().map(|s| s.set.len_approx()).sum()
    }

    /// Apply a mixed batch in-process: ops are routed per shard (via
    /// [`Router::partition`]), each shard's sub-batch runs as one group
    /// commit (one trailing fence), and the results are reassembled in op
    /// order. Every result is durable when this returns — but a crash
    /// mid-call keeps completed shards' sub-batches and loses the rest
    /// (per-shard atomicity only). Use [`DuraKv::apply_batch_atomic`] for
    /// all-or-nothing cross-shard semantics.
    pub fn apply_batch(&self, ops: &[SetOp]) -> Vec<OpResult> {
        let mut out = vec![OpResult::Found(false); ops.len()];
        for (si, sub) in self.router.partition(ops).iter().enumerate() {
            if sub.is_empty() {
                continue;
            }
            let sub_ops: Vec<SetOp> = sub.iter().map(|&(_, op)| op).collect();
            let results = self.shards[si].set.apply_batch(&sub_ops);
            for (&(i, _), r) in sub.iter().zip(results) {
                out[i] = r;
            }
        }
        out
    }

    /// Apply a mixed batch **atomically across shards**: the full op list
    /// is published to the store's persisted commit record before any
    /// shard applies, so a crash anywhere in the call recovers
    /// all-or-nothing (record committed → recovery rolls the batch
    /// forward; record not committed → the batch happened-never). See
    /// `coordinator::txn` / DESIGN.md §Transactions. Callers must not
    /// race conflicting direct-path updates during the call; the wire
    /// plane (`MULTI <n> ATOMIC`) additionally parks the participating
    /// shard workers to enforce that exclusion.
    pub fn apply_batch_atomic(&self, ops: &[SetOp]) -> Vec<OpResult> {
        self.txn.execute_inproc(self.router, ops, &self.metrics, |si, sub| {
            self.shards[si].set.apply_batch(sub)
        })
    }

    /// Per-shard resizable-hash growth stats (`None` for volatile or list
    /// shards). Rendered by `Metrics::report_with_growth` / `STATS`.
    pub fn growth_stats(&self) -> Vec<Option<GrowthStats>> {
        self.shards.iter().map(|s| s.set.growth_stats()).collect()
    }

    /// Borrow a shard's set (benchmark drivers pin threads to shards).
    pub fn shard_set(&self, i: usize) -> &dyn crate::sets::ConcurrentSet {
        self.shards[i].set.as_ref()
    }

    // ----- crash / recovery orchestration -----

    /// Simulate a whole-process crash: durable areas survive, every
    /// volatile handle dies. Returns the recovery ticket. Requires the
    /// config to have been created with `sim = true`.
    pub fn crash(self, policy: CrashPolicy) -> recovery::CrashTicket {
        recovery::crash(self, policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sets::Family;

    #[test]
    fn basic_kv_roundtrip() {
        let mut cfg = Config::default();
        cfg.shards = 4;
        cfg.key_range = 1 << 12;
        cfg.family = Family::Soft;
        let kv = DuraKv::create(cfg);
        assert!(kv.put(1, 100));
        assert!(!kv.put(1, 101), "duplicate put reports existing");
        assert_eq!(kv.get(1), Some(100));
        assert!(kv.del(1));
        assert_eq!(kv.get(1), None);
        assert_eq!(kv.len_approx(), 0);
    }

    #[test]
    fn apply_batch_routes_and_reassembles_in_order() {
        let mut cfg = Config::default();
        cfg.shards = 4;
        cfg.key_range = 1 << 12;
        let kv = DuraKv::create(cfg);
        let mut ops: Vec<SetOp> = (0..200u64).map(|k| SetOp::Insert(k, k + 7)).collect();
        ops.push(SetOp::Remove(13));
        ops.push(SetOp::Get(13));
        ops.push(SetOp::Get(14));
        let res = kv.apply_batch(&ops);
        for (i, r) in res.iter().take(200).enumerate() {
            assert_eq!(*r, OpResult::Applied(true), "insert {i}");
        }
        assert_eq!(res[200], OpResult::Applied(true));
        assert_eq!(res[201], OpResult::Value(None));
        assert_eq!(res[202], OpResult::Value(Some(21)));
        assert_eq!(kv.len_approx(), 199);
        // Growth stats surface per shard for resizable hash shards.
        let growth = kv.growth_stats();
        assert_eq!(growth.len(), 4);
        assert!(growth.iter().all(|g| g.is_some()));
    }

    #[test]
    fn apply_batch_atomic_matches_plain_semantics_and_counts() {
        let mut cfg = Config::default();
        cfg.shards = 4;
        cfg.key_range = 1 << 12;
        let kv = DuraKv::create(cfg);
        let ops: Vec<SetOp> = (0..100u64)
            .map(|k| SetOp::Insert(k, k * 2))
            .chain([SetOp::Get(7), SetOp::Remove(8), SetOp::Contains(8)])
            .collect();
        let res = kv.apply_batch_atomic(&ops);
        for (i, r) in res.iter().take(100).enumerate() {
            assert_eq!(*r, OpResult::Applied(true), "insert {i}");
        }
        assert_eq!(res[100], OpResult::Value(Some(14)));
        assert_eq!(res[101], OpResult::Applied(true));
        assert_eq!(res[102], OpResult::Found(false));
        assert_eq!(kv.len_approx(), 99);
        use std::sync::atomic::Ordering;
        assert_eq!(kv.metrics.atomics.load(Ordering::Relaxed), 1);
        assert_eq!(kv.metrics.atomic_ops.load(Ordering::Relaxed), 103);
        assert!(kv.metrics.report().contains("txn=[atomics=1 ops=103"));
    }

    #[test]
    fn keys_spread_over_shards() {
        let mut cfg = Config::default();
        cfg.shards = 4;
        cfg.key_range = 1 << 12;
        let kv = DuraKv::create(cfg);
        for k in 0..1000 {
            kv.put(k, k);
        }
        for i in 0..4 {
            let n = kv.shard_set(i).len_approx();
            assert!(n > 150, "shard {i} only has {n} keys");
        }
    }

    #[test]
    fn concurrent_clients() {
        let mut cfg = Config::default();
        cfg.shards = 2;
        cfg.key_range = 4096;
        let kv = Arc::new(DuraKv::create(cfg));
        let handles: Vec<_> = (0..6u64)
            .map(|t| {
                let kv = kv.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::util::rng::Xoshiro256::new(t + 70);
                    let mut net = 0i64;
                    for _ in 0..3000 {
                        let k = rng.below(512);
                        match rng.below(3) {
                            0 => {
                                if kv.put(k, t) {
                                    net += 1;
                                }
                            }
                            1 => {
                                if kv.del(k) {
                                    net -= 1;
                                }
                            }
                            _ => {
                                let _ = kv.get(k);
                            }
                        }
                    }
                    net
                })
            })
            .collect();
        let net: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(kv.len_approx() as i64, net);
    }
}
