//! DuraKv — the sharded durable key-value service built on the paper's
//! sets.
//!
//! Architecture (DESIGN.md):
//!
//! ```text
//!   clients ──► server (TCP, line protocol) ──► router ──► shard queues
//!                                        │                    │
//!   DuraKv::get/put/del (in-process) ────┴── direct lock-free calls
//!                                                             │
//!   crash ─► pmem::crash ─► recovery (per-shard, rust or XLA-accelerated)
//! ```
//!
//! The sets are lock-free and `Sync`, so the in-process data path routes
//! and calls directly; the queued path (bounded per-shard queues + worker
//! threads) serves the network front with backpressure and metrics, and
//! group-commits each queue drain through `ConcurrentSet::apply_batch`
//! so concurrent wire traffic shares trailing fences (DESIGN.md
//! §Batching).

pub mod metrics;
pub mod recovery;
pub mod router;
pub mod server;
pub mod shard;

use crate::config::Config;
use crate::pmem::CrashPolicy;
use crate::sets::{GrowthStats, OpResult, SetOp};
use std::sync::Arc;

pub use metrics::Metrics;
pub use router::Router;
pub use shard::{Shard, ShardMeta};

/// The sharded durable KV store.
pub struct DuraKv {
    cfg: Config,
    router: Router,
    shards: Vec<Shard>,
    pub metrics: Arc<Metrics>,
}

impl DuraKv {
    /// Create a fresh store per the config (also applies the pmem-level
    /// settings from the config).
    pub fn create(cfg: Config) -> DuraKv {
        cfg.apply_pmem();
        let shards = (0..cfg.shards).map(|i| Shard::create(&cfg, i)).collect();
        DuraKv {
            router: Router::new(cfg.shards),
            shards,
            cfg,
            metrics: Arc::new(Metrics::new()),
        }
    }

    pub fn config(&self) -> &Config {
        &self.cfg
    }

    pub fn router(&self) -> Router {
        self.router
    }

    pub fn shard_metas(&self) -> Vec<ShardMeta> {
        self.shards.iter().map(|s| s.meta).collect()
    }

    #[inline]
    fn shard(&self, key: u64) -> &Shard {
        &self.shards[self.router.shard_of(key)]
    }

    // ----- direct (in-process) data path -----

    pub fn put(&self, key: u64, value: u64) -> bool {
        self.shard(key).set.insert(key, value)
    }

    pub fn get(&self, key: u64) -> Option<u64> {
        self.shard(key).set.get(key)
    }

    pub fn del(&self, key: u64) -> bool {
        self.shard(key).set.remove(key)
    }

    pub fn contains(&self, key: u64) -> bool {
        self.shard(key).set.contains(key)
    }

    pub fn len_approx(&self) -> usize {
        self.shards.iter().map(|s| s.set.len_approx()).sum()
    }

    /// Apply a mixed batch in-process: ops are routed per shard, each
    /// shard's sub-batch runs as one group commit (one trailing fence),
    /// and the results are reassembled in op order. Every result is
    /// durable when this returns.
    pub fn apply_batch(&self, ops: &[SetOp]) -> Vec<OpResult> {
        let mut per_shard: Vec<Vec<(usize, SetOp)>> = vec![Vec::new(); self.shards.len()];
        for (i, &op) in ops.iter().enumerate() {
            per_shard[self.router.shard_of(op.key())].push((i, op));
        }
        let mut out = vec![OpResult::Found(false); ops.len()];
        for (si, sub) in per_shard.iter().enumerate() {
            if sub.is_empty() {
                continue;
            }
            let sub_ops: Vec<SetOp> = sub.iter().map(|&(_, op)| op).collect();
            let results = self.shards[si].set.apply_batch(&sub_ops);
            for (&(i, _), r) in sub.iter().zip(results) {
                out[i] = r;
            }
        }
        out
    }

    /// Per-shard resizable-hash growth stats (`None` for volatile or list
    /// shards). Rendered by `Metrics::report_with_growth` / `STATS`.
    pub fn growth_stats(&self) -> Vec<Option<GrowthStats>> {
        self.shards.iter().map(|s| s.set.growth_stats()).collect()
    }

    /// Borrow a shard's set (benchmark drivers pin threads to shards).
    pub fn shard_set(&self, i: usize) -> &dyn crate::sets::ConcurrentSet {
        self.shards[i].set.as_ref()
    }

    // ----- crash / recovery orchestration -----

    /// Simulate a whole-process crash: durable areas survive, every
    /// volatile handle dies. Returns the recovery ticket. Requires the
    /// config to have been created with `sim = true`.
    pub fn crash(self, policy: CrashPolicy) -> recovery::CrashTicket {
        recovery::crash(self, policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sets::Family;

    #[test]
    fn basic_kv_roundtrip() {
        let mut cfg = Config::default();
        cfg.shards = 4;
        cfg.key_range = 1 << 12;
        cfg.family = Family::Soft;
        let kv = DuraKv::create(cfg);
        assert!(kv.put(1, 100));
        assert!(!kv.put(1, 101), "duplicate put reports existing");
        assert_eq!(kv.get(1), Some(100));
        assert!(kv.del(1));
        assert_eq!(kv.get(1), None);
        assert_eq!(kv.len_approx(), 0);
    }

    #[test]
    fn apply_batch_routes_and_reassembles_in_order() {
        let mut cfg = Config::default();
        cfg.shards = 4;
        cfg.key_range = 1 << 12;
        let kv = DuraKv::create(cfg);
        let mut ops: Vec<SetOp> = (0..200u64).map(|k| SetOp::Insert(k, k + 7)).collect();
        ops.push(SetOp::Remove(13));
        ops.push(SetOp::Get(13));
        ops.push(SetOp::Get(14));
        let res = kv.apply_batch(&ops);
        for (i, r) in res.iter().take(200).enumerate() {
            assert_eq!(*r, OpResult::Applied(true), "insert {i}");
        }
        assert_eq!(res[200], OpResult::Applied(true));
        assert_eq!(res[201], OpResult::Value(None));
        assert_eq!(res[202], OpResult::Value(Some(21)));
        assert_eq!(kv.len_approx(), 199);
        // Growth stats surface per shard for resizable hash shards.
        let growth = kv.growth_stats();
        assert_eq!(growth.len(), 4);
        assert!(growth.iter().all(|g| g.is_some()));
    }

    #[test]
    fn keys_spread_over_shards() {
        let mut cfg = Config::default();
        cfg.shards = 4;
        cfg.key_range = 1 << 12;
        let kv = DuraKv::create(cfg);
        for k in 0..1000 {
            kv.put(k, k);
        }
        for i in 0..4 {
            let n = kv.shard_set(i).len_approx();
            assert!(n > 150, "shard {i} only has {n} keys");
        }
    }

    #[test]
    fn concurrent_clients() {
        let mut cfg = Config::default();
        cfg.shards = 2;
        cfg.key_range = 4096;
        let kv = Arc::new(DuraKv::create(cfg));
        let handles: Vec<_> = (0..6u64)
            .map(|t| {
                let kv = kv.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::util::rng::Xoshiro256::new(t + 70);
                    let mut net = 0i64;
                    for _ in 0..3000 {
                        let k = rng.below(512);
                        match rng.below(3) {
                            0 => {
                                if kv.put(k, t) {
                                    net += 1;
                                }
                            }
                            1 => {
                                if kv.del(k) {
                                    net -= 1;
                                }
                            }
                            _ => {
                                let _ = kv.get(k);
                            }
                        }
                    }
                    net
                })
            })
            .collect();
        let net: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(kv.len_approx() as i64, net);
    }
}
