//! Event-plane core: a std-only readiness multiplexer plus the reactor
//! worker pool that replaced thread-per-connection serving (DESIGN.md
//! §ConnectionPlane).
//!
//! The data plane underneath scales by design — psync-free reads, one
//! trailing fence per write group — but a thread per socket caps the
//! front end at `max_conns` OS threads. Here a fixed pool of
//! `event_workers` reactor threads each owns a set of nonblocking
//! connections and drives their state machines ([`super::conn::Conn`])
//! from readiness + completion wakeups, so 10k idle connections cost
//! buffers, not stacks.
//!
//! ## The std-only poller contract
//!
//! Without `libc`/`mio` (the offline crate set has neither) there is no
//! portable way to ask the kernel which sockets are ready. [`Poller`] is
//! therefore *level-triggered with spurious readiness allowed*: `poll`
//! reports every armed token, and the connection's `step` discovers the
//! truth with try-I/O (`WouldBlock` ⇒ not actually ready). That is a
//! legal behaviour under the mio contract too ("readiness operations may
//! produce spurious events"), so the API — `register`/`reregister`/
//! `deregister`/`poll` + a cloneable [`Waker`] — is exactly the shape a
//! later mio or io_uring backend slots into; only `poll`'s body changes.
//!
//! The cost of the std backend is one cheap `WouldBlock` syscall per
//! armed idle connection per wakeup. The adaptive backoff below bounds
//! the wakeup rate when nothing is happening (a few yield spins, then
//! parking with a timeout that doubles 50µs → 10ms), so an idle reactor
//! converges to ~100 scans/second regardless of connection count, and a
//! busy one never sleeps. RSS and thread count — the scaling claims —
//! are independent of this choice.

use super::conn::{Conn, ConnCtx, StepOutcome};
use super::shard::Request;
use super::DuraKv;
use std::collections::{BTreeMap, HashMap, HashSet};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::SyncSender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Identifies one registered connection within one reactor.
pub type Token = usize;

/// What a connection wants to hear about. Empty interest (`!armed()`)
/// means the connection is parked waiting on completions, not the
/// socket — the reactor steps it on wakeups instead of readiness.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const NONE: Interest = Interest { readable: false, writable: false };
    pub const READ: Interest = Interest { readable: true, writable: false };

    pub fn armed(self) -> bool {
        self.readable || self.writable
    }
}

/// Cross-thread wakeup for one reactor: shard workers call [`Waker::wake`]
/// after sending a completed batch, the acceptor calls it after injecting
/// a connection, and the reactor parks on it when idle. The pending flag
/// makes wakeups level-triggered — a wake that lands between `poll` and
/// `park` is consumed immediately, never lost.
pub struct Waker {
    pending: Mutex<bool>,
    cv: Condvar,
}

impl Waker {
    pub fn new() -> Waker {
        Waker { pending: Mutex::new(false), cv: Condvar::new() }
    }

    pub fn wake(&self) {
        let mut p = self.pending.lock().unwrap();
        if !*p {
            *p = true;
            self.cv.notify_one();
        }
    }

    /// Consume a pending wake without blocking.
    pub fn consume(&self) -> bool {
        std::mem::take(&mut *self.pending.lock().unwrap())
    }

    /// Park until a wake arrives or `timeout` passes; consumes the wake.
    /// Returns whether a wake was pending.
    pub fn park(&self, timeout: Duration) -> bool {
        let mut p = self.pending.lock().unwrap();
        if !*p {
            let (g, _) = self.cv.wait_timeout(p, timeout).unwrap();
            p = g;
        }
        std::mem::take(&mut *p)
    }
}

impl Default for Waker {
    fn default() -> Self {
        Self::new()
    }
}

/// Yield-spin rounds before the poller starts parking.
const SPIN_ROUNDS: u32 = 8;
/// First park timeout once spinning gives up.
const PARK_MIN: Duration = Duration::from_micros(50);
/// Park timeout ceiling — also the worst-case idle scan period.
const PARK_MAX: Duration = Duration::from_millis(10);

/// The std-only readiness multiplexer. See the module docs for the
/// spurious-readiness contract and the backoff policy.
pub struct Poller {
    interests: BTreeMap<Token, Interest>,
    waker: Arc<Waker>,
    idle_rounds: u32,
}

impl Poller {
    pub fn new() -> Poller {
        Poller::with_waker(Arc::new(Waker::new()))
    }

    /// Build around an existing waker (the reactor shares its injector's).
    pub fn with_waker(waker: Arc<Waker>) -> Poller {
        Poller { interests: BTreeMap::new(), waker, idle_rounds: 0 }
    }

    pub fn waker(&self) -> Arc<Waker> {
        self.waker.clone()
    }

    pub fn register(&mut self, tok: Token, interest: Interest) {
        self.interests.insert(tok, interest);
    }

    pub fn reregister(&mut self, tok: Token, interest: Interest) {
        self.interests.insert(tok, interest);
    }

    pub fn deregister(&mut self, tok: Token) {
        self.interests.remove(&tok);
    }

    pub fn interest(&self, tok: Token) -> Interest {
        self.interests.get(&tok).copied().unwrap_or(Interest::NONE)
    }

    /// Fill `out` with every armed token (spurious readiness allowed —
    /// callers discover the truth via try-I/O). Returns whether a wakeup
    /// was consumed this round. `made_progress` is the caller's report on
    /// the previous round: progress resets the backoff, idleness walks it
    /// from yield-spins toward [`PARK_MAX`] parking.
    pub fn poll(&mut self, out: &mut Vec<Token>, made_progress: bool) -> bool {
        out.clear();
        let mut woke = false;
        if made_progress {
            self.idle_rounds = 0;
            woke = self.waker.consume();
        } else if self.idle_rounds < SPIN_ROUNDS {
            self.idle_rounds += 1;
            std::thread::yield_now();
            woke = self.waker.consume();
        } else {
            let exp = (self.idle_rounds - SPIN_ROUNDS).min(16);
            let timeout = PARK_MIN.saturating_mul(1 << exp).min(PARK_MAX);
            woke = self.waker.park(timeout);
            if woke {
                self.idle_rounds = 0;
            } else {
                self.idle_rounds += 1;
            }
        }
        out.extend(self.interests.iter().filter(|(_, i)| i.armed()).map(|(&t, _)| t));
        woke
    }
}

impl Default for Poller {
    fn default() -> Self {
        Self::new()
    }
}

/// Hand-off queue from the acceptor to one reactor: push + wake.
pub(crate) struct Injector {
    queue: Mutex<Vec<TcpStream>>,
    pub(crate) waker: Arc<Waker>,
}

impl Injector {
    pub(crate) fn push(&self, stream: TcpStream) {
        self.queue.lock().unwrap().push(stream);
        self.waker.wake();
    }

    pub(crate) fn drain(&self) -> Vec<TcpStream> {
        std::mem::take(&mut *self.queue.lock().unwrap())
    }
}

/// Cloneable front half of the pool: the acceptor round-robins accepted
/// sockets over the reactors through this.
#[derive(Clone)]
pub(crate) struct PoolHandle {
    injectors: Vec<Arc<Injector>>,
    next: Arc<AtomicUsize>,
}

impl PoolHandle {
    pub(crate) fn dispatch(&self, stream: TcpStream) {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.injectors.len();
        self.injectors[i].push(stream);
    }
}

/// The reactor worker pool. Owns the threads; `shutdown` (driven by
/// `Server::drop` after the shared stop flag is raised) wakes and joins
/// them, dropping any still-open connections.
pub(crate) struct ReactorPool {
    injectors: Vec<Arc<Injector>>,
    joins: Vec<std::thread::JoinHandle<()>>,
    next: Arc<AtomicUsize>,
}

impl ReactorPool {
    pub(crate) fn spawn(
        workers: usize,
        kv: Arc<DuraKv>,
        senders: Arc<Vec<SyncSender<Request>>>,
        live: Arc<AtomicUsize>,
        stop: Arc<AtomicBool>,
    ) -> ReactorPool {
        let router = kv.router();
        let mut injectors = Vec::with_capacity(workers);
        let mut joins = Vec::with_capacity(workers);
        for i in 0..workers {
            let inj = Arc::new(Injector {
                queue: Mutex::new(Vec::new()),
                waker: Arc::new(Waker::new()),
            });
            injectors.push(inj.clone());
            let ctx = ConnCtx {
                kv: kv.clone(),
                router,
                senders: senders.clone(),
                waker: inj.waker.clone(),
            };
            let (live, stop) = (live.clone(), stop.clone());
            joins.push(
                std::thread::Builder::new()
                    .name(format!("reactor-{i}"))
                    .spawn(move || reactor_loop(inj, ctx, live, stop))
                    .expect("spawn reactor worker"),
            );
        }
        ReactorPool { injectors, joins, next: Arc::new(AtomicUsize::new(0)) }
    }

    pub(crate) fn handle(&self) -> PoolHandle {
        PoolHandle { injectors: self.injectors.clone(), next: self.next.clone() }
    }

    /// Wake every reactor (they observe the shared stop flag) and join.
    pub(crate) fn shutdown(mut self) {
        for inj in &self.injectors {
            inj.waker.wake();
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// One reactor worker: poll → absorb injected connections → step every
/// token that is armed or parked-on-completions, retiring closed ones.
fn reactor_loop(
    inj: Arc<Injector>,
    ctx: ConnCtx,
    live: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
) {
    let metrics = ctx.kv.metrics.clone();
    let mut poller = Poller::with_waker(inj.waker.clone());
    let mut conns: HashMap<Token, Conn> = HashMap::new();
    // Connections whose progress comes from shard/atomic completions, not
    // the socket; stepped every round even with empty interest.
    let mut waiting: HashSet<Token> = HashSet::new();
    let mut ready: Vec<Token> = Vec::new();
    let mut next_tok: Token = 0;
    let mut made_progress = true;
    while !stop.load(Ordering::SeqCst) {
        let woke = poller.poll(&mut ready, made_progress);
        if woke {
            metrics.record_wakeups(1);
        }
        for stream in inj.drain() {
            let tok = next_tok;
            next_tok += 1;
            match Conn::new(stream, ctx.senders.len()) {
                Ok(c) => {
                    poller.register(tok, Interest::READ);
                    conns.insert(tok, c);
                    metrics.conn_opened();
                    ready.push(tok);
                }
                // set_nonblocking failed — the acceptor already counted it.
                Err(_) => {
                    live.fetch_sub(1, Ordering::SeqCst);
                }
            }
        }
        made_progress = false;
        let parked: Vec<Token> =
            waiting.iter().copied().filter(|&t| !poller.interest(t).armed()).collect();
        for tok in ready.drain(..).chain(parked) {
            let Some(conn) = conns.get_mut(&tok) else { continue };
            match conn.step(&ctx) {
                StepOutcome::Open { interest, progressed, waiting: w } => {
                    poller.reregister(tok, interest);
                    if progressed {
                        made_progress = true;
                    }
                    if w {
                        waiting.insert(tok);
                    } else {
                        waiting.remove(&tok);
                    }
                }
                StepOutcome::Closed => {
                    conns.remove(&tok);
                    poller.deregister(tok);
                    waiting.remove(&tok);
                    live.fetch_sub(1, Ordering::SeqCst);
                    metrics.conn_closed();
                    made_progress = true;
                }
            }
        }
    }
    let n = conns.len();
    drop(conns);
    for _ in 0..n {
        live.fetch_sub(1, Ordering::SeqCst);
        metrics.conn_closed();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waker_wake_before_park_is_not_lost() {
        let w = Waker::new();
        w.wake();
        assert!(w.park(Duration::from_millis(100)), "pending wake must be consumed");
        assert!(!w.consume(), "park consumed the wake");
    }

    #[test]
    fn waker_unblocks_parked_thread() {
        let w = Arc::new(Waker::new());
        let w2 = w.clone();
        let t = std::thread::spawn(move || w2.park(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(20));
        w.wake();
        assert!(t.join().unwrap(), "park must observe the wake");
    }

    #[test]
    fn poller_reports_armed_tokens_only() {
        let mut p = Poller::new();
        p.register(1, Interest::READ);
        p.register(2, Interest::NONE);
        p.register(3, Interest { readable: true, writable: true });
        let mut out = Vec::new();
        p.poll(&mut out, true);
        assert_eq!(out, vec![1, 3]);
        p.reregister(1, Interest::NONE);
        p.deregister(3);
        p.poll(&mut out, true);
        assert!(out.is_empty());
        assert_eq!(p.interest(2), Interest::NONE);
        assert_eq!(p.interest(99), Interest::NONE, "unknown token is unarmed");
    }

    #[test]
    fn idle_poller_parks_instead_of_spinning() {
        let mut p = Poller::new();
        p.register(1, Interest::READ);
        let mut out = Vec::new();
        // Burn the yield-spin budget, then time one idle round: it must
        // park (≥ PARK_MIN) rather than spin hot.
        for _ in 0..=SPIN_ROUNDS {
            p.poll(&mut out, false);
        }
        let t0 = std::time::Instant::now();
        p.poll(&mut out, false);
        assert!(t0.elapsed() >= PARK_MIN, "idle poll must park");
        assert_eq!(out, vec![1], "armed tokens still reported after parking");
    }
}
