//! Per-connection state machine for the event-driven connection plane
//! (DESIGN.md §ConnectionPlane), plus the wire-protocol helpers.
//!
//! One [`Conn`] is: read buffer → burst parser → lane classification →
//! pending-responder set → write buffer. A reactor drives it with
//! [`Conn::step`]; everything inside is try-only (nonblocking socket
//! I/O, `try_send` into shard queues, `try_recv` from completion
//! channels), so a step never blocks the reactor no matter what one
//! connection is doing.
//!
//! A burst routes into three lanes: updates as per-shard
//! [`Request::Batch`]es (write lane), point reads swept psync-free after
//! the burst's writes drain (read lane — the drain-first order is what
//! preserves per-connection read-your-writes), and ordered
//! `RANGE`/`SCAN` queries batched into one merge-walk per shard (scan
//! lane, DESIGN.md §OrderedReads) whose per-shard sorted runs are k-way
//! merged back into key order. Replies accumulate in `wbuf` and drain as
//! the socket accepts them (partial writes re-arm write interest), and
//! an atomic frame — whose two-phase commit blocks on the shard workers
//! by design — runs on a short-lived helper thread that wakes the
//! reactor with the reply lines instead of blocking it.

use super::reactor::{Interest, Waker};
use super::shard::{BatchSink, Request, Response};
use super::{DuraKv, Router};
use crate::pmem::stats;
use crate::sets::{ConcurrentSet, RangeQuery, SetOp};
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError, TrySendError};
use std::sync::Arc;

/// Largest accepted `MULTI <n>` frame (also the atomic-batch cap,
/// `txn::TXN_OPS_MAX`).
pub(crate) const MULTI_MAX: u64 = 4096;

/// Socket read granularity.
const READ_CHUNK: usize = 64 * 1024;
/// Backpressure: stop reading new commands from a connection whose
/// un-drained reply bytes exceed this (a slow consumer pipelining fast
/// would otherwise grow `wbuf` without bound).
const WBUF_HIGH_WATER: usize = 256 * 1024;
/// Largest buffer capacity an idle (fully quiescent) connection may keep
/// pinned; above it the Vecs are dropped so 10k idle connections cost
/// roughly their sockets, not their historical burst sizes.
const IDLE_BUF_CAP: usize = 4 * 1024;

// ---------------------------------------------------------------------
// Wire-protocol pieces
// ---------------------------------------------------------------------

/// A routed data command (needed again at reply-formatting time).
#[derive(Clone, Copy)]
pub(crate) enum DataCmd {
    Put,
    Get,
    Has,
    Del,
}

/// One reply slot of a burst, in line order.
pub(crate) enum Slot {
    /// Already-resolved reply line.
    Text(String),
    /// Write-lane op `idx` of shard `shard`'s worker sub-batch.
    Write(DataCmd, usize, usize),
    /// Read-lane op `idx` of shard `shard`'s direct sweep.
    Read(DataCmd, usize, usize),
    /// Scan-lane ordered query `idx` of the burst's merge-walk.
    Ordered(usize),
    /// Resolved after the burst's data ops (approximate snapshots).
    Len,
    Stats,
    Quit,
}

pub(crate) fn data_reply(cmd: DataCmd, resp: Response) -> String {
    match (cmd, resp) {
        (DataCmd::Put, Response::Ok(true)) => "OK NEW".to_string(),
        (DataCmd::Put, _) => "OK EXISTS".to_string(),
        (DataCmd::Get, Response::Found(v)) => format!("FOUND {v}"),
        (DataCmd::Get, _) => "MISSING".to_string(),
        (DataCmd::Has, Response::Ok(true)) => "YES".to_string(),
        (DataCmd::Has, _) => "NO".to_string(),
        (DataCmd::Del, Response::Ok(true)) => "OK DELETED".to_string(),
        (DataCmd::Del, _) => "OK ABSENT".to_string(),
    }
}

/// Parse a PUT/GET/HAS/DEL line. `Ok(None)` = not a data command;
/// `Err(line)` = data command with bad arguments (the ERR reply).
pub(crate) fn parse_data(line: &str) -> Result<Option<(DataCmd, SetOp)>, String> {
    let mut parts = line.split_ascii_whitespace();
    let cmd = parts.next().unwrap_or("").to_ascii_uppercase();
    match cmd.as_str() {
        "PUT" => match (parse_u64(parts.next()), parse_u64(parts.next())) {
            (Some(k), Some(v)) => Ok(Some((DataCmd::Put, SetOp::Insert(k, v)))),
            _ => Err("ERR usage: PUT <key> <value>".to_string()),
        },
        "GET" => match parse_u64(parts.next()) {
            Some(k) => Ok(Some((DataCmd::Get, SetOp::Get(k)))),
            None => Err("ERR usage: GET <key>".to_string()),
        },
        "HAS" => match parse_u64(parts.next()) {
            Some(k) => Ok(Some((DataCmd::Has, SetOp::Contains(k)))),
            None => Err("ERR usage: HAS <key>".to_string()),
        },
        "DEL" => match parse_u64(parts.next()) {
            Some(k) => Ok(Some((DataCmd::Del, SetOp::Remove(k)))),
            None => Err("ERR usage: DEL <key>".to_string()),
        },
        _ => Ok(None),
    }
}

pub(crate) fn parse_u64(s: Option<&str>) -> Option<u64> {
    s.and_then(|x| x.parse().ok())
}

/// Parse the arguments of `MULTI <n> [ATOMIC]` (the command token is
/// already consumed): `None` on any malformed tail.
pub(crate) fn parse_multi_args<'a>(
    parts: &mut impl Iterator<Item = &'a str>,
) -> Option<(u64, bool)> {
    let n = parse_u64(parts.next()).filter(|&n| n <= MULTI_MAX)?;
    let atomic = match parts.next() {
        None => false,
        Some(t) if t.eq_ignore_ascii_case("ATOMIC") => true,
        Some(_) => return None,
    };
    if parts.next().is_some() {
        return None;
    }
    Some((n, atomic))
}

/// Classify + route a data op into the burst's two lanes: updates join
/// shard `Request::Batch`es (write lane), pure reads join the direct
/// per-shard sweep (read lane).
pub(crate) fn route(
    op: SetOp,
    cmd: DataCmd,
    router: Router,
    slots: &mut Vec<Slot>,
    writes: &mut [Vec<SetOp>],
    reads: &mut [Vec<SetOp>],
) {
    let shard = router.shard_of(op.key());
    if op.is_update() {
        slots.push(Slot::Write(cmd, shard, writes[shard].len()));
        writes[shard].push(op);
    } else {
        slots.push(Slot::Read(cmd, shard, reads[shard].len()));
        reads[shard].push(op);
    }
}

/// Execute one shard's read-lane sweep directly on the shared set handle:
/// one `contains_batch` + one `get_batch` virtual call regardless of run
/// length, results in op order. Zero psyncs (the caller meters).
pub(crate) fn run_read_lane(set: &dyn ConcurrentSet, ops: &[SetOp]) -> Vec<Response> {
    let mut has_keys = Vec::new();
    let mut get_keys = Vec::new();
    for &op in ops {
        match op {
            SetOp::Contains(k) => has_keys.push(k),
            SetOp::Get(k) => get_keys.push(k),
            SetOp::Insert(..) | SetOp::Remove(_) => {
                unreachable!("write routed into the read lane")
            }
        }
    }
    let has_res = set.contains_batch(&has_keys);
    let get_res = set.get_batch(&get_keys);
    let (mut hi, mut gi) = (0, 0);
    ops.iter()
        .map(|&op| match op {
            SetOp::Contains(_) => {
                let r = Response::Ok(has_res[hi]);
                hi += 1;
                r
            }
            _ => {
                let r = match get_res[gi] {
                    Some(v) => Response::Found(v),
                    None => Response::Missing,
                };
                gi += 1;
                r
            }
        })
        .collect()
}

/// Execute a burst's scan lane: **one** [`crate::sets::OrderedSet::range_batch`]
/// call per shard (the merge-walk — one EBR pin + one tower descent per
/// shard regardless of burst depth), then a k-way merge of each query's
/// per-shard sorted runs back into key order. Keys hash-distribute over
/// shards ([`Router::all_shards`]), so every shard holds a slice of every
/// window; keys are globally unique across shards, so the merge needs no
/// dedup. `Scan` windows are re-capped after the merge: each shard
/// returns its first `n` keys past the cursor, and the global answer is
/// the first `n` of their union. Zero psyncs (the caller meters).
pub(crate) fn run_scan_lane(
    kv: &DuraKv,
    router: Router,
    queries: &[RangeQuery],
) -> Vec<Vec<(u64, u64)>> {
    let mut per_shard: Vec<Vec<Vec<(u64, u64)>>> = Vec::with_capacity(router.shards());
    for shard in router.all_shards() {
        let ord = kv
            .shard_set(shard)
            .as_ordered()
            .expect("scan lane is classification-gated to ordered stores");
        per_shard.push(ord.range_batch(queries));
    }
    (0..queries.len())
        .map(|qi| {
            let runs: Vec<&[(u64, u64)]> =
                per_shard.iter().map(|s| s[qi].as_slice()).collect();
            let mut merged = merge_sorted_runs(&runs);
            if let RangeQuery::Scan(_, n) = queries[qi] {
                merged.truncate(n);
            }
            merged
        })
        .collect()
}

/// K-way merge of key-sorted runs with pairwise-disjoint key sets.
pub(crate) fn merge_sorted_runs(runs: &[&[(u64, u64)]]) -> Vec<(u64, u64)> {
    let mut out = Vec::with_capacity(runs.iter().map(|r| r.len()).sum());
    let mut idx = vec![0usize; runs.len()];
    loop {
        let mut best: Option<usize> = None;
        for (r, run) in runs.iter().enumerate() {
            if idx[r] < run.len() && best.map_or(true, |b| run[idx[r]].0 < runs[b][idx[b]].0) {
                best = Some(r);
            }
        }
        match best {
            Some(r) => {
                out.push(runs[r][idx[r]]);
                idx[r] += 1;
            }
            None => return out,
        }
    }
}

/// Map a read-lane wire `Response` back to the `OpResult` shape
/// `Metrics::record_op` classifies on.
pub(crate) fn read_op_result(op: SetOp, r: Response) -> crate::sets::OpResult {
    use crate::sets::OpResult;
    match (op, r) {
        (SetOp::Contains(_), Response::Ok(b)) => OpResult::Found(b),
        (_, Response::Found(v)) => OpResult::Value(Some(v)),
        _ => OpResult::Value(None),
    }
}

/// Execute an atomic `MULTI <n> ATOMIC` frame and return its reply lines:
/// parse strictly (any bad line aborts the whole frame — all-or-nothing
/// starts at the parser), then run the two-phase protocol over the shard
/// workers. Blocks on the workers' Prepare/done handshake by design, so
/// the reactor calls this from a helper thread (inline only as the
/// out-of-threads overload fallback).
pub(crate) fn atomic_frame_lines(
    frame: &[String],
    router: Router,
    senders: &[SyncSender<Request>],
    kv: &DuraKv,
) -> Vec<String> {
    let mut cmds = Vec::with_capacity(frame.len());
    let mut ops = Vec::with_capacity(frame.len());
    for l in frame {
        match parse_data(l) {
            Ok(Some((cmd, op))) => {
                cmds.push(cmd);
                ops.push(op);
            }
            Err(usage) => {
                return vec![format!(
                    "ERR ATOMIC aborted: {}",
                    usage.trim_start_matches("ERR ")
                )];
            }
            Ok(None) => return vec![format!("ERR ATOMIC aborted: not a data op: '{l}'")],
        }
    }
    if ops.is_empty() {
        return vec!["OK EMPTY".to_string()];
    }
    let apply_direct = |si: usize, sub: &[SetOp]| kv.shard_set(si).apply_batch(sub);
    match kv.txn.execute_via_workers(router, senders, &ops, &kv.metrics, apply_direct) {
        Ok(results) => cmds
            .into_iter()
            .zip(results)
            .map(|(cmd, res)| data_reply(cmd, res))
            .collect(),
        Err(e) => vec![format!("ERR ATOMIC failed: {e}")],
    }
}

// ---------------------------------------------------------------------
// The reactor-driven connection state machine
// ---------------------------------------------------------------------

/// Everything a connection needs from its owning reactor's world.
pub(crate) struct ConnCtx {
    pub kv: Arc<DuraKv>,
    pub router: Router,
    pub senders: Arc<Vec<SyncSender<Request>>>,
    /// The owning reactor's waker: handed to shard workers (via
    /// [`BatchSink`]) and atomic helper threads so completions wake the
    /// reactor instead of unparking a per-connection thread.
    pub waker: Arc<Waker>,
}

/// Where a connection is in its burst cycle.
enum Phase {
    /// Reading + parsing; the burst accumulates.
    Gather,
    /// Burst dispatched; waiting for the shard write batches to complete.
    AwaitWrites,
    /// Waiting for an atomic frame's helper thread.
    AwaitAtomic,
}

/// An in-progress `MULTI` frame: the header is parsed, `lines` fills
/// until `n + 1` (ops + EXEC) have arrived.
struct Frame {
    n: u64,
    atomic: bool,
    lines: Vec<String>,
}

/// What one `step` tells the reactor.
pub(crate) enum StepOutcome {
    Open {
        interest: Interest,
        /// Whether anything advanced (resets the poller's idle backoff).
        progressed: bool,
        /// Waiting on completions (not the socket): step it every round
        /// even with empty interest.
        waiting: bool,
    },
    Closed,
}

pub(crate) struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    rstart: usize,
    wbuf: Vec<u8>,
    wstart: usize,
    phase: Phase,
    // ---- the gathered burst ----
    slots: Vec<Slot>,
    writes: Vec<Vec<SetOp>>,
    reads: Vec<Vec<SetOp>>,
    /// Ordered `RANGE`/`SCAN` queries of the burst, in slot order
    /// (scan lane; executed as one merge-walk per shard).
    ordered: Vec<RangeQuery>,
    /// Shards whose write sub-batch hit a full queue on `try_send`;
    /// retried each step (this is the queue-bound backpressure, made
    /// non-blocking).
    unsent: Vec<usize>,
    /// The pending-responder set: one completion channel per dispatched
    /// shard sub-batch.
    pending: Vec<(usize, Receiver<Vec<Response>>)>,
    write_results: Vec<Vec<Response>>,
    frame: Option<Frame>,
    /// A completed atomic frame, run after the current burst resolves.
    deferred_atomic: Option<Vec<String>>,
    atomic_rx: Option<Receiver<Vec<String>>>,
    closing: bool,
    peer_eof: bool,
    failed: bool,
    /// Suppresses double-counting `partial_writes` while one stall lasts.
    stalled: bool,
}

impl Conn {
    pub(crate) fn new(stream: TcpStream, nshards: usize) -> std::io::Result<Conn> {
        stream.set_nonblocking(true)?;
        Ok(Conn {
            stream,
            rbuf: Vec::new(),
            rstart: 0,
            wbuf: Vec::new(),
            wstart: 0,
            phase: Phase::Gather,
            slots: Vec::new(),
            writes: vec![Vec::new(); nshards],
            reads: vec![Vec::new(); nshards],
            ordered: Vec::new(),
            unsent: Vec::new(),
            pending: Vec::new(),
            write_results: vec![Vec::new(); nshards],
            frame: None,
            deferred_atomic: None,
            atomic_rx: None,
            closing: false,
            peer_eof: false,
            failed: false,
            stalled: false,
        })
    }

    /// Drive the connection as far as it can go without blocking.
    pub(crate) fn step(&mut self, ctx: &ConnCtx) -> StepOutcome {
        let metrics = &ctx.kv.metrics;
        if self.failed || self.flush_wbuf(metrics).is_err() {
            return StepOutcome::Closed;
        }
        let mut progressed = false;
        loop {
            let did = match self.phase {
                Phase::Gather => self.pump_gather(ctx),
                Phase::AwaitWrites => self.pump_awaiting(ctx),
                Phase::AwaitAtomic => self.pump_atomic(),
            };
            if self.failed {
                return StepOutcome::Closed;
            }
            if did {
                progressed = true;
            } else {
                break;
            }
        }
        if self.flush_wbuf(metrics).is_err() {
            return StepOutcome::Closed;
        }
        let drained = self.wstart >= self.wbuf.len();
        if drained && self.closing {
            let _ = self.stream.shutdown(std::net::Shutdown::Both);
            return StepOutcome::Closed;
        }
        let gathering = matches!(self.phase, Phase::Gather);
        if drained
            && self.peer_eof
            && gathering
            && self.slots.is_empty()
            && self.rstart >= self.rbuf.len()
        {
            // Clean EOF: input consumed, every reply delivered.
            return StepOutcome::Closed;
        }
        // Going quiescent (nothing buffered either way): give the burst
        // buffers back. `truncate` keeps capacity, so without this every
        // idle connection would pin the 64 KiB read chunk it once grew to
        // — the C10K flat-RSS claim dies by a thousand Vecs. Busy
        // connections re-grow in one realloc per burst, which the
        // allocator absorbs.
        if drained && self.rstart >= self.rbuf.len() {
            if self.rbuf.capacity() > IDLE_BUF_CAP {
                self.rbuf = Vec::new();
                self.rstart = 0;
            }
            if self.wbuf.capacity() > IDLE_BUF_CAP {
                self.wbuf = Vec::new();
                self.wstart = 0;
            }
        }
        let interest = Interest {
            readable: gathering
                && !self.closing
                && !self.peer_eof
                && self.wbuf.len() - self.wstart < WBUF_HIGH_WATER,
            writable: !drained,
        };
        StepOutcome::Open { interest, progressed, waiting: !gathering }
    }

    // ---- socket I/O ----

    /// Nonblocking read into `rbuf`. `Ok(0)` = no bytes (WouldBlock or
    /// EOF; EOF additionally sets `peer_eof`).
    fn fill_rbuf(&mut self) -> std::io::Result<usize> {
        if self.rstart > 0 {
            self.rbuf.drain(..self.rstart);
            self.rstart = 0;
        }
        let old = self.rbuf.len();
        self.rbuf.resize(old + READ_CHUNK, 0);
        match self.stream.read(&mut self.rbuf[old..]) {
            Ok(0) => {
                self.rbuf.truncate(old);
                self.peer_eof = true;
                Ok(0)
            }
            Ok(n) => {
                self.rbuf.truncate(old + n);
                Ok(n)
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::Interrupted =>
            {
                self.rbuf.truncate(old);
                Ok(0)
            }
            Err(e) => {
                self.rbuf.truncate(old);
                Err(e)
            }
        }
    }

    /// Drain `wbuf` as far as the socket accepts; a `WouldBlock` with
    /// bytes remaining is the partial-write case that re-arms write
    /// interest (metered once per stall).
    fn flush_wbuf(&mut self, metrics: &super::Metrics) -> std::io::Result<()> {
        while self.wstart < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wstart..]) {
                Ok(0) => return Err(ErrorKind::WriteZero.into()),
                Ok(n) => {
                    self.wstart += n;
                    self.stalled = false;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if !self.stalled {
                        metrics.record_partial_write();
                        self.stalled = true;
                    }
                    break;
                }
                Err(e) => return Err(e),
            }
        }
        if self.wstart >= self.wbuf.len() {
            self.wbuf.clear();
            self.wstart = 0;
            self.stalled = false;
        } else if self.wstart > READ_CHUNK {
            // Bound the dead prefix a long stall accumulates.
            self.wbuf.drain(..self.wstart);
            self.wstart = 0;
        }
        Ok(())
    }

    fn push_line(&mut self, s: &str) {
        self.wbuf.extend_from_slice(s.as_bytes());
        self.wbuf.push(b'\n');
    }

    // ---- parsing ----

    /// Next complete line out of `rbuf` (trimmed). At peer EOF a trailing
    /// unterminated line still counts as a line (`BufRead::read_line`
    /// parity).
    fn take_line(&mut self) -> Option<String> {
        let buf = &self.rbuf[self.rstart..];
        if let Some(i) = buf.iter().position(|&b| b == b'\n') {
            let line = String::from_utf8_lossy(&buf[..i]).trim().to_string();
            self.rstart += i + 1;
            Some(line)
        } else if self.peer_eof && !buf.is_empty() {
            let line = String::from_utf8_lossy(buf).trim().to_string();
            self.rstart = self.rbuf.len();
            Some(line)
        } else {
            None
        }
    }

    fn complete_lines_buffered(&self) -> usize {
        self.rbuf[self.rstart..].iter().filter(|&&b| b == b'\n').count()
    }

    // ---- phase pumps ----

    fn pump_gather(&mut self, ctx: &ConnCtx) -> bool {
        let mut progress = false;
        if !self.peer_eof && self.wbuf.len() - self.wstart < WBUF_HIGH_WATER {
            let was_eof = self.peer_eof;
            match self.fill_rbuf() {
                Ok(n) if n > 0 => progress = true,
                Ok(_) => {
                    if self.peer_eof && !was_eof {
                        progress = true;
                    }
                }
                Err(_) => {
                    self.failed = true;
                    return true;
                }
            }
        }
        let (consumed, dispatch) = self.gather_lines(ctx);
        if consumed {
            progress = true;
        }
        if dispatch {
            self.dispatch(ctx);
            progress = true;
        }
        progress
    }

    /// Consume complete lines into the burst. Returns (consumed anything,
    /// dispatch the burst now). Dispatch points: QUIT, an atomic/starved
    /// `MULTI` header with earlier commands pending (a slow frame must
    /// not withhold their replies), a completed atomic frame, or input
    /// exhausted with a non-empty burst.
    fn gather_lines(&mut self, ctx: &ConnCtx) -> (bool, bool) {
        let mut consumed = false;
        loop {
            if self.frame.is_some() {
                let Some(line) = self.take_line() else { break };
                consumed = true;
                let fr = self.frame.as_mut().expect("checked above");
                fr.lines.push(line);
                if fr.lines.len() as u64 == fr.n + 1 {
                    let fr = self.frame.take().expect("checked above");
                    self.finish_frame(fr, ctx);
                    if self.deferred_atomic.is_some() {
                        // Run the frame; lines pipelined behind it stay
                        // buffered until its replies are formatted.
                        return (consumed, true);
                    }
                }
                continue;
            }
            let Some(line) = self.take_line() else { break };
            consumed = true;
            match parse_data(&line) {
                Ok(Some((cmd, op))) => route(
                    op,
                    cmd,
                    ctx.router,
                    &mut self.slots,
                    &mut self.writes,
                    &mut self.reads,
                ),
                Err(usage) => self.slots.push(Slot::Text(usage)),
                Ok(None) => {
                    let mut parts = line.split_ascii_whitespace();
                    let cmd = parts.next().unwrap_or("").to_ascii_uppercase();
                    match cmd.as_str() {
                        "MULTI" => match parse_multi_args(&mut parts) {
                            None => self.slots.push(Slot::Text(format!(
                                "ERR usage: MULTI <n> [ATOMIC] (n <= {MULTI_MAX})"
                            ))),
                            Some((n, atomic)) => {
                                let buffered = self.complete_lines_buffered() as u64;
                                self.frame = Some(Frame {
                                    n,
                                    atomic,
                                    lines: Vec::with_capacity(n as usize + 1),
                                });
                                if (atomic || buffered < n + 1) && !self.slots.is_empty() {
                                    // Earlier commands must not have their
                                    // replies held hostage by a slow (or
                                    // out-of-band atomic) frame.
                                    return (consumed, true);
                                }
                            }
                        },
                        "RANGE" => {
                            match (parse_u64(parts.next()), parse_u64(parts.next()), parts.next())
                            {
                                (Some(lo), Some(hi), None) => {
                                    self.push_ordered(RangeQuery::Range(lo, hi), ctx)
                                }
                                _ => self
                                    .slots
                                    .push(Slot::Text("ERR usage: RANGE <lo> <hi>".to_string())),
                            }
                        }
                        "SCAN" => {
                            match (parse_u64(parts.next()), parse_u64(parts.next()), parts.next())
                            {
                                (Some(cursor), Some(n), None) if n <= MULTI_MAX => {
                                    self.push_ordered(RangeQuery::Scan(cursor, n as usize), ctx)
                                }
                                _ => self.slots.push(Slot::Text(format!(
                                    "ERR usage: SCAN <cursor> <n> (n <= {MULTI_MAX})"
                                ))),
                            }
                        }
                        "LEN" => self.slots.push(Slot::Len),
                        "STATS" => self.slots.push(Slot::Stats),
                        "QUIT" => {
                            self.slots.push(Slot::Quit);
                            return (consumed, true);
                        }
                        "" => {}
                        other => self
                            .slots
                            .push(Slot::Text(format!("ERR unknown command '{other}'"))),
                    }
                }
            }
        }
        let dispatch = !self.slots.is_empty();
        (consumed, dispatch)
    }

    /// Classify an ordered query into the scan lane — or reject it at
    /// classification time when the store has no ordered view (hash and
    /// list shards; every shard shares one structure, so shard 0 speaks
    /// for all).
    fn push_ordered(&mut self, q: RangeQuery, ctx: &ConnCtx) {
        if ctx.kv.shard_set(0).as_ordered().is_none() {
            self.slots.push(Slot::Text(
                "ERR ordered reads need structure=skiplist (this store is unordered)"
                    .to_string(),
            ));
            return;
        }
        self.slots.push(Slot::Ordered(self.ordered.len()));
        self.ordered.push(q);
    }

    /// A `MULTI` frame has all `n + 1` lines: validate EXEC, then either
    /// defer the atomic execution or splice the ops into the burst.
    fn finish_frame(&mut self, mut fr: Frame, ctx: &ConnCtx) {
        let exec = fr.lines.pop().expect("n+1 lines gathered");
        if !exec.eq_ignore_ascii_case("EXEC") {
            self.slots.push(Slot::Text(format!(
                "ERR MULTI: expected EXEC after {} ops, got '{exec}'",
                fr.n
            )));
        } else if fr.atomic {
            self.deferred_atomic = Some(fr.lines);
        } else if fr.lines.is_empty() {
            // `MULTI 0` + EXEC: a valid empty batch. It queues no ops and
            // would otherwise produce zero reply lines — the client,
            // waiting for its EXEC ack, would hang.
            self.slots.push(Slot::Text("OK EMPTY".to_string()));
        } else {
            for l in &fr.lines {
                match parse_data(l) {
                    Ok(Some((cmd, op))) => route(
                        op,
                        cmd,
                        ctx.router,
                        &mut self.slots,
                        &mut self.writes,
                        &mut self.reads,
                    ),
                    Err(usage) => self.slots.push(Slot::Text(usage)),
                    Ok(None) => self
                        .slots
                        .push(Slot::Text(format!("ERR MULTI: not a data op: '{l}'"))),
                }
            }
        }
    }

    /// Hand the burst's write sub-batches to the shard workers and move
    /// to `AwaitWrites`.
    fn dispatch(&mut self, ctx: &ConnCtx) {
        self.phase = Phase::AwaitWrites;
        for shard in 0..self.writes.len() {
            if !self.writes[shard].is_empty() {
                self.unsent.push(shard);
            }
        }
        self.pump_sends(ctx);
    }

    /// `try_send` each not-yet-queued sub-batch; a full queue keeps the
    /// shard in `unsent` for the next step.
    fn pump_sends(&mut self, ctx: &ConnCtx) -> bool {
        let mut progress = false;
        let unsent = std::mem::take(&mut self.unsent);
        for shard in unsent {
            let ops = std::mem::take(&mut self.writes[shard]);
            let (btx, brx) = sync_channel(1);
            let sink = BatchSink::waking(btx, ctx.waker.clone());
            match ctx.senders[shard].try_send(Request::Batch(ops, sink)) {
                Ok(()) => {
                    self.pending.push((shard, brx));
                    progress = true;
                }
                Err(TrySendError::Full(req)) => {
                    if let Request::Batch(ops, _) = req {
                        self.writes[shard] = ops;
                    }
                    self.unsent.push(shard);
                }
                Err(TrySendError::Disconnected(_)) => {
                    self.failed = true;
                    return true;
                }
            }
        }
        progress
    }

    fn pump_awaiting(&mut self, ctx: &ConnCtx) -> bool {
        let mut progress = self.pump_sends(ctx);
        if self.failed {
            return true;
        }
        let mut i = 0;
        while i < self.pending.len() {
            match self.pending[i].1.try_recv() {
                Ok(res) => {
                    let (shard, _) = self.pending.swap_remove(i);
                    self.write_results[shard] = res;
                    progress = true;
                }
                Err(TryRecvError::Empty) => i += 1,
                Err(TryRecvError::Disconnected) => {
                    self.failed = true;
                    return true;
                }
            }
        }
        if self.unsent.is_empty() && self.pending.is_empty() {
            self.resolve_burst(ctx);
            if self.closing {
                self.deferred_atomic = None;
            }
            if let Some(lines) = self.deferred_atomic.take() {
                if self.spawn_atomic(ctx, lines) {
                    self.phase = Phase::AwaitAtomic;
                } else {
                    self.phase = Phase::Gather;
                }
            } else {
                self.phase = Phase::Gather;
            }
            progress = true;
        }
        progress
    }

    /// Every sub-batch completed: run the read lane and the scan lane,
    /// then format every reply into `wbuf` in line order. All reads of a
    /// burst execute after all of its writes, which is what preserves
    /// per-connection read-your-writes no matter which reactor rounds
    /// (or wakeups) the burst's lifetime spans — `RANGE` after a
    /// pipelined `PUT` observes the write.
    fn resolve_burst(&mut self, ctx: &ConnCtx) {
        let kv = &ctx.kv;
        let nshards = ctx.senders.len();
        let mut read_results: Vec<Vec<Response>> = vec![Vec::new(); nshards];
        if self.reads.iter().any(|r| !r.is_empty()) {
            // Read lane: the burst's writes are drained (durable + acked
            // to us), so direct reads observe them. Metered around the
            // whole sweep — the psync-free claim is pinned on these
            // counters, reactor path included.
            let before = stats::thread_snapshot();
            let mut nops = 0u64;
            for (shard, ops) in self.reads.iter_mut().enumerate() {
                if ops.is_empty() {
                    continue;
                }
                nops += ops.len() as u64;
                let results = run_read_lane(kv.shard_set(shard), ops);
                for (&op, &res) in ops.iter().zip(results.iter()) {
                    kv.metrics.record_op(op, read_op_result(op, res));
                }
                read_results[shard] = results;
                ops.clear();
            }
            let d = stats::thread_snapshot().since(&before);
            kv.metrics.record_read_lane(nops, d.fences, d.flushes);
        }
        let ordered_queries = std::mem::take(&mut self.ordered);
        let mut ordered_results: Vec<Vec<(u64, u64)>> = Vec::new();
        if !ordered_queries.is_empty() {
            // Scan lane: same drain-first position as the read lane (RYW
            // holds for ordered reads too), metered around the whole
            // merge-walk — the zero-psync claim is pinned on these
            // counters by the scan-bench CI gate.
            let before = stats::thread_snapshot();
            ordered_results = run_scan_lane(kv, ctx.router, &ordered_queries);
            let d = stats::thread_snapshot().since(&before);
            kv.metrics.record_scan_lane(ordered_queries.len() as u64, d.fences, d.flushes);
        }
        // Ack boundary: replies formatted below leave the process; any
        // durable store this thread still owes is a DurabilityRace.
        crate::pmem::check::assert_persisted("conn.resolve_burst");
        let slots = std::mem::take(&mut self.slots);
        for slot in slots {
            match slot {
                Slot::Text(s) => self.push_line(&s),
                Slot::Write(cmd, shard, idx) => {
                    let r = self.write_results[shard][idx];
                    self.push_line(&data_reply(cmd, r));
                }
                Slot::Read(cmd, shard, idx) => {
                    let r = read_results[shard][idx];
                    self.push_line(&data_reply(cmd, r));
                }
                Slot::Ordered(idx) => {
                    // Count header, then one `<key> <value>` line per hit
                    // in key order; a SCAN client pages by re-issuing with
                    // cursor = last key of the previous page.
                    let pairs = std::mem::take(&mut ordered_results[idx]);
                    let verb = match ordered_queries[idx] {
                        RangeQuery::Range(..) => "RANGE",
                        RangeQuery::Scan(..) => "SCAN",
                    };
                    self.push_line(&format!("{verb} {}", pairs.len()));
                    for (k, v) in pairs {
                        self.push_line(&format!("{k} {v}"));
                    }
                }
                Slot::Len => self.push_line(&format!("LEN {}", kv.len_approx())),
                Slot::Stats => self.push_line(&format!(
                    "STATS {}",
                    kv.metrics.report_with_growth(&kv.growth_stats())
                )),
                Slot::Quit => {
                    self.push_line("BYE");
                    self.closing = true;
                    // Anything pipelined after QUIT is discarded.
                    self.rstart = self.rbuf.len();
                    self.frame = None;
                    break;
                }
            }
        }
        for r in self.write_results.iter_mut() {
            r.clear();
        }
    }

    /// Run an atomic frame on a helper thread (its 2PC blocks on the
    /// shard workers); the result returns over a channel + reactor wake.
    /// Returns false if the frame was resolved inline instead.
    fn spawn_atomic(&mut self, ctx: &ConnCtx, lines: Vec<String>) -> bool {
        let (tx, rx) = sync_channel(1);
        let kv = ctx.kv.clone();
        let senders = ctx.senders.clone();
        let router = ctx.router;
        let waker = ctx.waker.clone();
        let moved = lines.clone();
        let spawned = std::thread::Builder::new().name("conn-atomic".into()).spawn(move || {
            let out = atomic_frame_lines(&moved, router, &senders, &kv);
            let _ = tx.send(out);
            waker.wake();
        });
        match spawned {
            Ok(_) => {
                self.atomic_rx = Some(rx);
                true
            }
            Err(_) => {
                // Out of threads: run the frame inline. Blocks this
                // reactor for one frame — the overload path, still
                // correct.
                let out = atomic_frame_lines(&lines, router, &ctx.senders, &ctx.kv);
                for l in &out {
                    self.push_line(l);
                }
                false
            }
        }
    }

    fn pump_atomic(&mut self) -> bool {
        let r = match &self.atomic_rx {
            None => {
                self.phase = Phase::Gather;
                return true;
            }
            Some(rx) => rx.try_recv(),
        };
        match r {
            Ok(out) => {
                self.atomic_rx = None;
                for l in &out {
                    self.push_line(l);
                }
                self.phase = Phase::Gather;
                true
            }
            Err(TryRecvError::Empty) => false,
            Err(TryRecvError::Disconnected) => {
                self.failed = true;
                true
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use std::net::TcpListener;

    fn ctx_without_workers() -> (ConnCtx, Arc<DuraKv>) {
        let mut cfg = Config::default();
        cfg.shards = 1;
        cfg.key_range = 1024;
        cfg.psync_ns = 0;
        let kv = Arc::new(DuraKv::create(cfg));
        let ctx = ConnCtx {
            kv: kv.clone(),
            router: kv.router(),
            senders: Arc::new(Vec::new()),
            waker: Arc::new(Waker::new()),
        };
        (ctx, kv)
    }

    fn socket_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        (server, client)
    }

    /// The partial-write path: a reply burst far beyond the socket
    /// buffers must stall with write interest re-armed (and the stall
    /// metered once), then drain to completion as the client reads.
    #[test]
    fn partial_write_rearms_interest_and_drains() {
        let (server, mut client) = socket_pair();
        let (ctx, kv) = ctx_without_workers();
        let mut conn = Conn::new(server, ctx.senders.len()).unwrap();
        conn.wbuf = vec![b'x'; 8 << 20];

        match conn.step(&ctx) {
            StepOutcome::Open { interest, .. } => {
                assert!(interest.writable, "stalled write must re-arm write interest");
            }
            StepOutcome::Closed => panic!("connection closed on a full socket"),
        }
        use std::sync::atomic::Ordering;
        assert!(
            kv.metrics.cp_partial_writes.load(Ordering::Relaxed) >= 1,
            "partial write must be metered"
        );

        // Drain from the client side while stepping: the machine must
        // push the remaining bytes out and disarm write interest.
        client.set_nonblocking(true).unwrap();
        let mut got = 0usize;
        let mut sink = vec![0u8; 1 << 20];
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
        loop {
            match client.read(&mut sink) {
                Ok(0) => panic!("server closed early"),
                Ok(n) => got += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                Err(e) => panic!("client read: {e}"),
            }
            match conn.step(&ctx) {
                StepOutcome::Open { interest, .. } => {
                    if got == 8 << 20 && !interest.writable {
                        break;
                    }
                }
                StepOutcome::Closed => panic!("connection closed mid-drain"),
            }
            assert!(std::time::Instant::now() < deadline, "drain stalled: {got} bytes");
        }
        assert_eq!(got, 8 << 20, "every buffered byte must reach the client");
    }

    #[test]
    fn merge_sorted_runs_interleaves_disjoint_runs() {
        let a: Vec<(u64, u64)> = vec![(1, 10), (4, 40), (7, 70)];
        let b: Vec<(u64, u64)> = vec![(2, 20), (5, 50)];
        let c: Vec<(u64, u64)> = vec![];
        let merged = merge_sorted_runs(&[&a, &b, &c]);
        assert_eq!(merged, vec![(1, 10), (2, 20), (4, 40), (5, 50), (7, 70)]);
        assert!(merge_sorted_runs(&[]).is_empty());
    }

    /// Ordered verbs on an unordered (hash) store are rejected at
    /// classification time with an ERR line, not at execution time.
    #[test]
    fn range_on_hash_store_is_rejected_at_classification() {
        let (server, mut client) = socket_pair();
        let (ctx, _kv) = ctx_without_workers(); // structure=hash
        let mut conn = Conn::new(server, ctx.senders.len()).unwrap();
        client.write_all(b"RANGE 1 9\nSCAN 0 4\nRANGE nope\n").unwrap();
        client.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        conn.step(&ctx);
        let mut reply = [0u8; 256];
        client.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
        let n = client.read(&mut reply).unwrap();
        let text = std::str::from_utf8(&reply[..n]).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "{text}");
        assert!(lines[0].starts_with("ERR ordered reads need structure=skiplist"));
        assert!(lines[1].starts_with("ERR ordered reads need structure=skiplist"));
        assert!(lines[2].starts_with("ERR usage: RANGE"));
    }

    /// The scan lane end to end on a skip-list store (no shard workers
    /// needed — a pure-read burst resolves on the direct path): replies
    /// come back count-headed, key-sorted, merged across shards.
    #[test]
    fn ordered_burst_resolves_on_scan_lane_with_merged_replies() {
        use std::sync::atomic::Ordering;
        let (server, mut client) = socket_pair();
        let mut cfg = Config::default();
        cfg.shards = 2;
        cfg.key_range = 1024;
        cfg.psync_ns = 0;
        cfg.structure = crate::config::Structure::SkipList;
        let kv = Arc::new(DuraKv::create(cfg));
        for k in 0..64u64 {
            kv.shard_set(kv.router().shard_of(k)).insert(k, k + 100);
        }
        let ctx = ConnCtx {
            kv: kv.clone(),
            router: kv.router(),
            senders: Arc::new(Vec::new()),
            waker: Arc::new(Waker::new()),
        };
        let mut conn = Conn::new(server, ctx.senders.len()).unwrap();
        client.write_all(b"RANGE 10 13\nSCAN 60 8\n").unwrap();
        client.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        conn.step(&ctx);
        let mut reply = Vec::new();
        let mut buf = [0u8; 1024];
        client.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
        while !reply.ends_with(b"63 163\n") {
            let n = client.read(&mut buf).unwrap();
            assert!(n > 0, "server closed early");
            reply.extend_from_slice(&buf[..n]);
        }
        let text = String::from_utf8(reply).unwrap();
        assert_eq!(
            text,
            "RANGE 4\n10 110\n11 111\n12 112\n13 113\nSCAN 3\n61 161\n62 162\n63 163\n"
        );
        assert_eq!(kv.metrics.sl_runs.load(Ordering::Relaxed), 1, "one scan-lane burst");
        assert_eq!(kv.metrics.sl_ops.load(Ordering::Relaxed), 2);
        assert_eq!(kv.metrics.sl_fences.load(Ordering::Relaxed), 0);
        assert_eq!(kv.metrics.sl_flushes.load(Ordering::Relaxed), 0);
    }

    /// A fragmented burst — bytes arriving in arbitrary splits, including
    /// mid-line — must parse into the same burst once the newlines land.
    #[test]
    fn partial_line_fragments_reassemble() {
        let (server, mut client) = socket_pair();
        let (ctx, _kv) = ctx_without_workers();
        let mut conn = Conn::new(server, ctx.senders.len()).unwrap();

        client.write_all(b"LE").unwrap();
        client.flush().unwrap();
        // Give the bytes time to land, then step: no complete line yet —
        // nothing may be dispatched or replied.
        std::thread::sleep(std::time::Duration::from_millis(20));
        match conn.step(&ctx) {
            StepOutcome::Open { interest, .. } => {
                assert!(interest.readable, "mid-line: stay read-armed");
            }
            StepOutcome::Closed => panic!("closed on a partial line"),
        }
        assert!(conn.slots.is_empty(), "half a line must not become a slot");

        client.write_all(b"N\nLEN").unwrap();
        client.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        conn.step(&ctx);
        let mut reply = [0u8; 64];
        client.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
        let n = client.read(&mut reply).unwrap();
        assert_eq!(&reply[..n], b"LEN 0\n", "first LEN resolves, second still mid-line");

        client.write_all(b"\n").unwrap();
        client.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        conn.step(&ctx);
        let n = client.read(&mut reply).unwrap();
        assert_eq!(&reply[..n], b"LEN 0\n", "second LEN resolves once terminated");
    }
}
