//! Crash / recovery orchestration across shards.
//!
//! Per the paper (§2.1): recovery must complete before new operations are
//! admitted — the API encodes that by consuming the store on crash and
//! only returning a usable store from `recover()`.
//!
//! Recovery is parallel at both layers (DESIGN.md §Recovery): shards are
//! independent pools, so a worker pool rebuilds them concurrently, and
//! each shard's own scan/relink runs on the engine with whatever workers
//! are left over (`threads / shard-workers`). The total worker budget is
//! one knob — `recover_with_threads` — surfaced by `bench --fig recovery`
//! as the measured-RTO sweep.

use super::shard::{Shard, ShardMeta, ShardRecovery};
use super::txn::{TxnLog, TxnLogMeta};
use super::{DuraKv, Metrics, Router};
use crate::config::Config;
use crate::pmem::{self, CrashPolicy};
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Proof that a crash happened; the only way back is [`recover`] /
/// [`CrashTicket::recover`].
pub struct CrashTicket {
    cfg: Config,
    metas: Vec<ShardMeta>,
    /// The store's atomic-batch commit record, carried over the crash
    /// like the shard metas (its pool was reverted with the rest).
    txn: TxnLogMeta,
    /// Lines that survived only via random eviction (diagnostics).
    pub evicted_lines: usize,
}

/// Crash the store: preserve durable pools, drop volatile handles, revert
/// this store's durable regions to the persisted image — including the
/// atomic-batch commit record's pool, so an unfenced record write dies
/// with the crash exactly like any other durable write. Scoped to the
/// store's own pools so concurrent structures (other tests, other stores
/// in the process) are unaffected.
pub(super) fn crash(kv: DuraKv, policy: CrashPolicy) -> CrashTicket {
    let cfg = kv.cfg.clone();
    let metas = kv.shard_metas();
    for s in &kv.shards {
        s.set.prepare_crash();
    }
    let txn = kv.txn.meta();
    // The ticket owns the record across the store's death: recovery must
    // still be able to consult it, so don't let the drop recycle it.
    kv.txn.detach();
    let mut pools: Vec<_> = metas.iter().filter_map(|m| m.pool).collect();
    pools.push(kv.txn.pool());
    drop(kv); // volatile handles die here (limbo lists are abandoned)
    let evicted_lines = pmem::crash_pools(policy, &pools);
    CrashTicket { cfg, metas, txn, evicted_lines }
}

/// What recovery did, and what it cost per phase.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecoveryReport {
    pub shards: usize,
    pub members: usize,
    pub reclaimed: usize,
    /// End-to-end rebuild wall-clock (the measured RTO).
    pub wall: std::time::Duration,
    pub accelerated: bool,
    /// Total engine worker budget the rebuild ran with.
    pub threads: usize,
    /// Per-phase cost, summed across shards (CPU time, not wall — with
    /// concurrent shard workers the phases overlap).
    pub scan: std::time::Duration,
    pub sort: std::time::Duration,
    pub relink: std::time::Duration,
    /// Cache lines that survived the crash only because the random-
    /// eviction policy wrote them back — 0 under the pessimistic policy.
    /// Non-zero means this drill recovered a *lucky* image, not a
    /// guaranteed one (acked durability never depends on these lines).
    pub evicted_lines: usize,
    /// Committed-but-unretired atomic batches the rebuild rolled forward
    /// from the commit record (0 or 1; DESIGN.md §Transactions).
    pub txn_rolled_forward: usize,
}

impl RecoveryReport {
    fn absorb(&mut self, rec: &ShardRecovery) {
        self.members += rec.stats.members;
        self.reclaimed += rec.stats.reclaimed;
        self.scan += rec.timings.scan;
        self.sort += rec.timings.sort;
        self.relink += rec.timings.relink;
    }
}

impl CrashTicket {
    pub fn metas(&self) -> &[ShardMeta] {
        &self.metas
    }

    /// Rebuild every shard (pure-Rust recovery path) with the default
    /// worker budget.
    pub fn recover(self) -> Result<(DuraKv, RecoveryReport)> {
        self.recover_with_threads(crate::sets::recovery::default_threads())
    }

    /// Rebuild every shard with an explicit total worker budget: up to
    /// `threads` shards rebuild concurrently (shards are independent
    /// pools), each running the scan/relink engine with the remaining
    /// budget. `threads = 1` is the exact sequential path.
    pub fn recover_with_threads(self, threads: usize) -> Result<(DuraKv, RecoveryReport)> {
        let t0 = Instant::now();
        let threads = threads.max(1);
        let mut report = RecoveryReport {
            shards: self.metas.len(),
            threads,
            evicted_lines: self.evicted_lines,
            ..Default::default()
        };
        let n = self.metas.len();
        let shard_workers = threads.min(n.max(1));
        let engine_threads = (threads / shard_workers.max(1)).max(1);

        let mut slots: Vec<Option<(Shard, ShardRecovery)>> = (0..n).map(|_| None).collect();
        if shard_workers <= 1 {
            for (i, meta) in self.metas.iter().enumerate() {
                slots[i] = Some(Shard::recover_timed(*meta, engine_threads)?);
            }
        } else {
            let cursor = AtomicUsize::new(0);
            let metas = &self.metas;
            let outs: Vec<Vec<(usize, Result<(Shard, ShardRecovery)>)>> =
                std::thread::scope(|s| {
                    let handles: Vec<_> = (0..shard_workers)
                        .map(|_| {
                            let cursor = &cursor;
                            s.spawn(move || {
                                let mut out = Vec::new();
                                loop {
                                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                                    if i >= metas.len() {
                                        break;
                                    }
                                    out.push((i, Shard::recover_timed(metas[i], engine_threads)));
                                }
                                out
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });
            for (i, r) in outs.into_iter().flatten() {
                slots[i] = Some(r?);
            }
        }

        let mut shards = Vec::with_capacity(n);
        for slot in slots {
            let (shard, rec) = slot.expect("every shard index recovered exactly once");
            report.absorb(&rec);
            shards.push(shard);
        }
        report.wall = t0.elapsed();
        self.finish(shards, report)
    }

    /// Rebuild through the XLA recovery artifacts where applicable.
    ///
    /// The classification kernels model per-slot validity rules, which is
    /// exactly the resizable single-list/okey layout link-free and SOFT
    /// hash shards persist — those shards classify on the artifact and
    /// relink in okey order. Log-free (reachability-based membership),
    /// list shards and volatile shards take the exact Rust path, as does
    /// everything when the artifacts are absent or the `accel` feature is
    /// off (the offline stub): `recover_accel` then behaves exactly like
    /// [`CrashTicket::recover`] with `accelerated = false`.
    pub fn recover_accel(self) -> Result<(DuraKv, RecoveryReport)> {
        use crate::runtime::RecoveryPlanner;
        if RecoveryPlanner::with_cached(|_| Ok(())).is_err() {
            // Offline stub or missing artifacts: clean fallback.
            return self.recover();
        }
        // The PJRT handles are thread-local (neither Send nor Sync), so
        // the artifact path recovers shards sequentially on this thread;
        // each shard's Rust-side scan fallback still gets the full engine
        // budget.
        let threads = crate::sets::recovery::default_threads();
        let t0 = Instant::now();
        let mut report = RecoveryReport {
            shards: self.metas.len(),
            threads,
            evicted_lines: self.evicted_lines,
            ..Default::default()
        };
        let mut shards = Vec::with_capacity(self.metas.len());
        for meta in &self.metas {
            let (shard, rec, used_accel) = Shard::recover_accel(*meta, threads)?;
            report.absorb(&rec);
            report.accelerated |= used_accel;
            shards.push(shard);
        }
        report.wall = t0.elapsed();
        self.finish(shards, report)
    }

    fn finish(
        self,
        shards: Vec<Shard>,
        mut report: RecoveryReport,
    ) -> Result<(DuraKv, RecoveryReport)> {
        if report.evicted_lines > 0 {
            // Operator signal: this image survived partly by luck (random
            // cache write-back), not by the psync protocol alone — fine
            // for acked data (never depends on eviction), but the drill
            // did not exercise the pessimistic recovery path.
            eprintln!(
                "durasets: recovery adopted {} cache line(s) persisted only by random eviction \
                 (lucky image; pessimistic-crash coverage not exercised)",
                report.evicted_lines
            );
        }
        let kv = DuraKv {
            router: Router::new(self.cfg.shards),
            shards,
            cfg: self.cfg,
            txn: TxnLog::adopt(self.txn),
            metrics: Arc::new(Metrics::new()),
        };
        // The rollback-vs-rollforward rule: a committed-but-unretired
        // atomic batch is re-applied in full (idempotent — the parked
        // workers excluded interleavers pre-crash, and nothing ran since);
        // an uncommitted record is simply stale — nothing of its batch was
        // ever applied, so dropping it IS the rollback.
        report.txn_rolled_forward = kv
            .txn
            .roll_forward(kv.router, |si, sub| kv.shards[si].set.apply_batch(sub));
        kv.metrics.record_recovery(&report);
        Ok((kv, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::DuraKv;
    use crate::sets::Family;

    fn crash_cfg(family: Family) -> Config {
        let mut cfg = Config::default();
        cfg.family = family;
        cfg.shards = 3;
        cfg.key_range = 4096;
        cfg.sim = true;
        cfg.psync_ns = 0;
        cfg
    }

    #[test]
    fn kv_crash_recover_all_families() {
        let _sim = pmem::sim_session();
        for family in [Family::Soft, Family::LinkFree, Family::LogFree] {
            let kv = DuraKv::create(crash_cfg(family));
            for k in 0..500u64 {
                assert!(kv.put(k, k * 2));
            }
            for k in 0..100u64 {
                assert!(kv.del(k));
            }
            let ticket = kv.crash(CrashPolicy::PESSIMISTIC);
            let (kv2, report) = ticket.recover().unwrap();
            assert_eq!(report.shards, 3);
            assert_eq!(report.members, 400, "{family}");
            assert!(report.reclaimed > 0, "{family}: unused slots are reclaimed");
            assert_eq!(report.evicted_lines, 0, "pessimistic crash evicts nothing");
            for k in 0..500u64 {
                assert_eq!(kv2.get(k), if k < 100 { None } else { Some(k * 2) }, "{family} key {k}");
            }
            // Store is writable again.
            assert!(kv2.put(9999, 1));
            // The report surfaces through the service metrics (STATS line).
            let stats_line = kv2.metrics.report();
            assert!(stats_line.contains("recovery=["), "{stats_line}");
            assert!(stats_line.contains("members=400"), "{stats_line}");
        }
    }

    #[test]
    fn parallel_shard_recovery_matches_sequential() {
        let _sim = pmem::sim_session();
        let mk = || {
            let kv = DuraKv::create(crash_cfg(Family::LinkFree));
            for k in 0..600u64 {
                assert!(kv.put(k, k + 5));
            }
            for k in 0..150u64 {
                assert!(kv.del(k));
            }
            kv.crash(CrashPolicy::PESSIMISTIC)
        };
        let (kv_seq, rep_seq) = mk().recover_with_threads(1).unwrap();
        let (kv_par, rep_par) = mk().recover_with_threads(8).unwrap();
        assert_eq!(rep_seq.members, rep_par.members);
        assert_eq!(rep_seq.reclaimed, rep_par.reclaimed);
        assert_eq!(rep_par.threads, 8);
        for k in 0..600u64 {
            let want = if k < 150 { None } else { Some(k + 5) };
            assert_eq!(kv_seq.get(k), want, "seq key {k}");
            assert_eq!(kv_par.get(k), want, "par key {k}");
        }
    }

    #[test]
    fn recover_accel_falls_back_cleanly_without_artifacts() {
        // In the offline build (no `accel` feature / no artifacts) the
        // accel entry point must silently take the exact Rust path.
        let _sim = pmem::sim_session();
        let kv = DuraKv::create(crash_cfg(Family::Soft));
        for k in 0..300u64 {
            assert!(kv.put(k, k * 7));
        }
        let ticket = kv.crash(CrashPolicy::PESSIMISTIC);
        let (kv2, report) = ticket.recover_accel().unwrap();
        assert_eq!(report.members, 300);
        if crate::runtime::RecoveryPlanner::with_cached(|_| Ok(())).is_err() {
            assert!(!report.accelerated, "no artifacts => no acceleration claim");
        }
        for k in 0..300u64 {
            assert_eq!(kv2.get(k), Some(k * 7), "key {k}");
        }
    }

    #[test]
    fn evicted_lines_reach_the_report() {
        let _sim = pmem::sim_session();
        let kv = DuraKv::create(crash_cfg(Family::LogFree));
        for k in 0..400u64 {
            assert!(kv.put(k, k));
        }
        // Heavy eviction: with hundreds of touched lines, some unflushed
        // line (shadow mismatch) survives with overwhelming probability.
        let ticket = kv.crash(CrashPolicy::random(0.9, 1234));
        let evicted = ticket.evicted_lines;
        let (kv2, report) = ticket.recover().unwrap();
        assert_eq!(report.evicted_lines, evicted, "ticket count must reach the report");
        assert_eq!(report.members, 400);
        for k in 0..400u64 {
            assert_eq!(kv2.get(k), Some(k), "acked key {k} survives regardless of eviction");
        }
    }

    #[test]
    fn volatile_family_recovers_empty() {
        let _sim = pmem::sim_session();
        let kv = DuraKv::create(crash_cfg(Family::Volatile));
        for k in 0..100u64 {
            kv.put(k, k);
        }
        let (kv2, report) = kv.crash(CrashPolicy::PESSIMISTIC).recover().unwrap();
        assert_eq!(report.members, 0);
        assert_eq!(kv2.len_approx(), 0);
    }
}
