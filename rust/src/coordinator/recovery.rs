//! Crash / recovery orchestration across shards.
//!
//! Per the paper (§2.1): recovery must complete before new operations are
//! admitted — the API encodes that by consuming the store on crash and
//! only returning a usable store from `recover()`.

use super::shard::{Shard, ShardMeta};
use super::{DuraKv, Metrics, Router};
use crate::config::Config;
use crate::pmem::{self, CrashPolicy};
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

/// Proof that a crash happened; the only way back is [`recover`] /
/// [`CrashTicket::recover`].
pub struct CrashTicket {
    cfg: Config,
    metas: Vec<ShardMeta>,
    /// Lines that survived only via random eviction (diagnostics).
    pub evicted_lines: usize,
}

/// Crash the store: preserve durable pools, drop volatile handles, revert
/// this store's durable regions to the persisted image. Scoped to the
/// store's own pools so concurrent structures (other tests, other stores
/// in the process) are unaffected.
pub(super) fn crash(kv: DuraKv, policy: CrashPolicy) -> CrashTicket {
    let cfg = kv.cfg.clone();
    let metas = kv.shard_metas();
    for s in &kv.shards {
        s.set.prepare_crash();
    }
    let pools: Vec<_> = metas.iter().filter_map(|m| m.pool).collect();
    drop(kv); // volatile handles die here (limbo lists are abandoned)
    let evicted_lines = pmem::crash_pools(policy, &pools);
    CrashTicket { cfg, metas, evicted_lines }
}

/// What recovery did.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecoveryReport {
    pub shards: usize,
    pub members: usize,
    pub reclaimed: usize,
    pub wall: std::time::Duration,
    pub accelerated: bool,
}

impl CrashTicket {
    pub fn metas(&self) -> &[ShardMeta] {
        &self.metas
    }

    /// Rebuild every shard (pure-Rust recovery path).
    pub fn recover(self) -> Result<(DuraKv, RecoveryReport)> {
        let t0 = Instant::now();
        let mut shards = Vec::with_capacity(self.metas.len());
        let mut report = RecoveryReport {
            shards: self.metas.len(),
            accelerated: false,
            ..Default::default()
        };
        for meta in self.metas {
            let before = shard_slot_count(&meta);
            let shard = Shard::recover(meta)?;
            report.members += shard.set.len_approx();
            report.reclaimed += before.saturating_sub(shard.set.len_approx());
            shards.push(shard);
        }
        report.wall = t0.elapsed();
        Ok((
            DuraKv {
                router: Router::new(self.cfg.shards),
                shards,
                cfg: self.cfg,
                metrics: Arc::new(Metrics::new()),
            },
            report,
        ))
    }

    /// Rebuild through the XLA recovery artifacts where applicable.
    ///
    /// Hash shards are resizable now: their durable image is a single
    /// per-family list in hashed-key order plus a bucket-count epoch, a
    /// layout the fixed bucket-classification artifacts do not model. The
    /// store path therefore always routes through the exact Rust recovery;
    /// the accel kernels stay exercised against the fixed hash layouts in
    /// `rust/tests/runtime_accel.rs` and the recovery bench.
    pub fn recover_accel(self) -> Result<(DuraKv, RecoveryReport)> {
        let (kv, mut report) = self.recover()?;
        report.accelerated = false;
        Ok((kv, report))
    }
}

fn shard_slot_count(meta: &ShardMeta) -> usize {
    meta.pool
        .map(|p| {
            crate::pmem::region::regions_of(p)
                .iter()
                .filter(|r| r.tag == crate::pmem::region::RegionTag::Slots)
                .map(|r| r.len / 64)
                .sum()
        })
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::DuraKv;
    use crate::sets::Family;

    fn crash_cfg(family: Family) -> Config {
        let mut cfg = Config::default();
        cfg.family = family;
        cfg.shards = 3;
        cfg.key_range = 4096;
        cfg.sim = true;
        cfg.psync_ns = 0;
        cfg
    }

    #[test]
    fn kv_crash_recover_all_families() {
        let _sim = pmem::sim_session();
        for family in [Family::Soft, Family::LinkFree, Family::LogFree] {
            let kv = DuraKv::create(crash_cfg(family));
            for k in 0..500u64 {
                assert!(kv.put(k, k * 2));
            }
            for k in 0..100u64 {
                assert!(kv.del(k));
            }
            let ticket = kv.crash(CrashPolicy::PESSIMISTIC);
            let (kv2, report) = ticket.recover().unwrap();
            assert_eq!(report.shards, 3);
            assert_eq!(report.members, 400, "{family}");
            for k in 0..500u64 {
                assert_eq!(kv2.get(k), if k < 100 { None } else { Some(k * 2) }, "{family} key {k}");
            }
            // Store is writable again.
            assert!(kv2.put(9999, 1));
        }
    }

    #[test]
    fn volatile_family_recovers_empty() {
        let _sim = pmem::sim_session();
        let kv = DuraKv::create(crash_cfg(Family::Volatile));
        for k in 0..100u64 {
            kv.put(k, k);
        }
        let (kv2, report) = kv.crash(CrashPolicy::PESSIMISTIC).recover().unwrap();
        assert_eq!(report.members, 0);
        assert_eq!(kv2.len_approx(), 0);
    }
}
