//! Crash / recovery orchestration across shards.
//!
//! Per the paper (§2.1): recovery must complete before new operations are
//! admitted — the API encodes that by consuming the store on crash and
//! only returning a usable store from `recover()`.

use super::shard::{Shard, ShardMeta};
use super::{DuraKv, Metrics, Router};
use crate::config::{Config, Structure};
use crate::pmem::{self, CrashPolicy};
use crate::sets::Family;
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

/// Proof that a crash happened; the only way back is [`recover`] /
/// [`CrashTicket::recover`].
pub struct CrashTicket {
    cfg: Config,
    metas: Vec<ShardMeta>,
    /// Lines that survived only via random eviction (diagnostics).
    pub evicted_lines: usize,
}

/// Crash the store: preserve durable pools, drop volatile handles, revert
/// pmem to the persisted image.
pub(super) fn crash(kv: DuraKv, policy: CrashPolicy) -> CrashTicket {
    let cfg = kv.cfg.clone();
    let metas = kv.shard_metas();
    for s in &kv.shards {
        s.set.prepare_crash();
    }
    drop(kv); // volatile handles die here (limbo lists are abandoned)
    let evicted_lines = pmem::crash(policy);
    CrashTicket { cfg, metas, evicted_lines }
}

/// What recovery did.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecoveryReport {
    pub shards: usize,
    pub members: usize,
    pub reclaimed: usize,
    pub wall: std::time::Duration,
    pub accelerated: bool,
}

impl CrashTicket {
    pub fn metas(&self) -> &[ShardMeta] {
        &self.metas
    }

    /// Rebuild every shard (pure-Rust recovery path).
    pub fn recover(self) -> Result<(DuraKv, RecoveryReport)> {
        let t0 = Instant::now();
        let mut shards = Vec::with_capacity(self.metas.len());
        let mut report = RecoveryReport {
            shards: self.metas.len(),
            accelerated: false,
            ..Default::default()
        };
        for meta in self.metas {
            let before = shard_slot_count(&meta);
            let shard = Shard::recover(meta)?;
            report.members += shard.set.len_approx();
            report.reclaimed += before.saturating_sub(shard.set.len_approx());
            shards.push(shard);
        }
        report.wall = t0.elapsed();
        Ok((
            DuraKv {
                router: Router::new(self.cfg.shards),
                shards,
                cfg: self.cfg,
                metrics: Arc::new(Metrics::new()),
            },
            report,
        ))
    }

    /// Rebuild hash shards through the XLA recovery artifacts (falls back
    /// to the Rust path for list shards / volatile families).
    pub fn recover_accel(self) -> Result<(DuraKv, RecoveryReport)> {
        let t0 = Instant::now();
        crate::runtime::RecoveryPlanner::with_cached(move |planner| {
            self.recover_accel_with(planner, t0)
        })
    }

    fn recover_accel_with(
        self,
        planner: &crate::runtime::RecoveryPlanner,
        t0: Instant,
    ) -> Result<(DuraKv, RecoveryReport)> {
        let mut shards = Vec::with_capacity(self.metas.len());
        let mut report = RecoveryReport {
            shards: self.metas.len(),
            accelerated: true,
            ..Default::default()
        };
        for meta in self.metas {
            let shard = match (meta.family, meta.structure, meta.pool) {
                (Family::Soft, Structure::Hash, Some(pool)) => {
                    let (set, stats) = crate::runtime::recovery_accel::recover_soft_hash_accel(
                        &planner,
                        pool,
                        meta.nbuckets,
                    )?;
                    report.members += stats.members;
                    report.reclaimed += stats.reclaimed;
                    Shard { set: Box::new(set), meta }
                }
                (Family::LinkFree, Structure::Hash, Some(pool)) => {
                    let (set, stats) =
                        crate::runtime::recovery_accel::recover_linkfree_hash_accel(
                            &planner,
                            pool,
                            meta.nbuckets,
                        )?;
                    report.members += stats.members;
                    report.reclaimed += stats.reclaimed;
                    Shard { set: Box::new(set), meta }
                }
                _ => {
                    let shard = Shard::recover(meta)?;
                    report.members += shard.set.len_approx();
                    shard
                }
            };
            shards.push(shard);
        }
        report.wall = t0.elapsed();
        Ok((
            DuraKv {
                router: Router::new(self.cfg.shards),
                shards,
                cfg: self.cfg,
                metrics: Arc::new(Metrics::new()),
            },
            report,
        ))
    }
}

fn shard_slot_count(meta: &ShardMeta) -> usize {
    meta.pool
        .map(|p| {
            crate::pmem::region::regions_of(p)
                .iter()
                .filter(|r| r.tag == crate::pmem::region::RegionTag::Slots)
                .map(|r| r.len / 64)
                .sum()
        })
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::DuraKv;

    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn crash_cfg(family: Family) -> Config {
        let mut cfg = Config::default();
        cfg.family = family;
        cfg.shards = 3;
        cfg.key_range = 4096;
        cfg.sim = true;
        cfg.psync_ns = 0;
        cfg
    }

    #[test]
    fn kv_crash_recover_all_families() {
        let _g = LOCK.lock().unwrap();
        for family in [Family::Soft, Family::LinkFree, Family::LogFree] {
            let kv = DuraKv::create(crash_cfg(family));
            for k in 0..500u64 {
                assert!(kv.put(k, k * 2));
            }
            for k in 0..100u64 {
                assert!(kv.del(k));
            }
            let ticket = kv.crash(CrashPolicy::PESSIMISTIC);
            let (kv2, report) = ticket.recover().unwrap();
            assert_eq!(report.shards, 3);
            assert_eq!(report.members, 400, "{family}");
            for k in 0..500u64 {
                assert_eq!(kv2.get(k), if k < 100 { None } else { Some(k * 2) }, "{family} key {k}");
            }
            // Store is writable again.
            assert!(kv2.put(9999, 1));
            crate::pmem::set_mode(crate::pmem::Mode::Perf);
        }
    }

    #[test]
    fn volatile_family_recovers_empty() {
        let _g = LOCK.lock().unwrap();
        let kv = DuraKv::create(crash_cfg(Family::Volatile));
        for k in 0..100u64 {
            kv.put(k, k);
        }
        let (kv2, report) = kv.crash(CrashPolicy::PESSIMISTIC).recover().unwrap();
        assert_eq!(report.members, 0);
        assert_eq!(kv2.len_approx(), 0);
        crate::pmem::set_mode(crate::pmem::Mode::Perf);
    }
}
