//! Plain lock-free Harris list + hash over volatile slab nodes.

use crate::alloc::{Ebr, VolatilePool};
use crate::sets::tagged::{is_marked, ptr_of, MARK};
use crate::util::mix64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// 24-byte volatile node: key, value, markable next.
#[repr(C)]
struct VNode {
    key: u64,
    value: u64,
    next: AtomicU64,
}

const VNODE_SIZE: usize = std::mem::size_of::<VNode>();
const _: () = assert!(VNODE_SIZE == 24);

pub(crate) struct VolatileCore {
    pool: Arc<VolatilePool>,
    ebr: Arc<Ebr>,
}

unsafe fn free_vnode(ptr: *mut u8, ctx: usize) {
    (*(ctx as *const VolatilePool)).free(ptr);
}

impl VolatileCore {
    fn new() -> Self {
        VolatileCore {
            // Untagged: this family publishes no hints/towers, so it
            // skips the generation word and keeps the paper-comparison
            // node density exactly.
            pool: Arc::new(VolatilePool::new_untagged(VNODE_SIZE)),
            ebr: Arc::new(Ebr::new()),
        }
    }

    unsafe fn find(&self, head: *const AtomicU64, key: u64) -> (*const AtomicU64, *mut VNode) {
        'retry: loop {
            let mut pred_link = head;
            let mut curr = ptr_of::<VNode>((*pred_link).load(Ordering::Acquire));
            loop {
                if curr.is_null() {
                    return (pred_link, curr);
                }
                let succ_t = (*curr).next.load(Ordering::Acquire);
                if is_marked(succ_t) {
                    let succ = ptr_of::<VNode>(succ_t);
                    if (*pred_link)
                        .compare_exchange(
                            curr as u64,
                            succ as u64,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_err()
                    {
                        continue 'retry;
                    }
                    curr = succ;
                } else {
                    if (*curr).key >= key {
                        return (pred_link, curr);
                    }
                    pred_link = &(*curr).next as *const AtomicU64;
                    curr = ptr_of::<VNode>(succ_t);
                }
            }
        }
    }

    fn insert(&self, head: *const AtomicU64, key: u64, value: u64) -> bool {
        let _g = self.ebr.pin();
        let mut node: *mut VNode = std::ptr::null_mut();
        loop {
            unsafe {
                let (pred_link, curr) = self.find(head, key);
                if !curr.is_null() && (*curr).key == key {
                    if !node.is_null() {
                        self.pool.free(node as *mut u8);
                    }
                    return false;
                }
                if node.is_null() {
                    node = self.pool.alloc() as *mut VNode;
                    std::ptr::write(
                        node,
                        VNode { key, value, next: AtomicU64::new(0) },
                    );
                }
                (*node).next.store(curr as u64, Ordering::Relaxed);
                if (*pred_link)
                    .compare_exchange(curr as u64, node as u64, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    return true;
                }
            }
        }
    }

    fn remove(&self, head: *const AtomicU64, key: u64) -> bool {
        let _g = self.ebr.pin();
        loop {
            unsafe {
                let (pred_link, curr) = self.find(head, key);
                if curr.is_null() || (*curr).key != key {
                    return false;
                }
                let succ_t = (*curr).next.load(Ordering::Acquire);
                if is_marked(succ_t) {
                    continue;
                }
                if (*curr)
                    .next
                    .compare_exchange(succ_t, succ_t | MARK, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    let succ = ptr_of::<VNode>(succ_t);
                    if (*pred_link)
                        .compare_exchange(
                            curr as u64,
                            succ as u64,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_err()
                    {
                        let _ = self.find(head, key);
                    }
                    self.ebr.retire(
                        curr as *mut u8,
                        Arc::as_ptr(&self.pool) as usize,
                        free_vnode,
                    );
                    return true;
                }
            }
        }
    }

    fn get(&self, head: *const AtomicU64, key: u64) -> Option<u64> {
        let _g = self.ebr.pin();
        unsafe {
            let mut curr = ptr_of::<VNode>((*head).load(Ordering::Acquire));
            while !curr.is_null() && (*curr).key < key {
                curr = ptr_of::<VNode>((*curr).next.load(Ordering::Acquire));
            }
            if curr.is_null() || (*curr).key != key {
                return None;
            }
            if is_marked((*curr).next.load(Ordering::Acquire)) {
                return None;
            }
            Some((*curr).value)
        }
    }

    fn count(&self, head: *const AtomicU64) -> usize {
        let _g = self.ebr.pin();
        let mut n = 0;
        unsafe {
            let mut curr = ptr_of::<VNode>((*head).load(Ordering::Acquire));
            while !curr.is_null() {
                let v = (*curr).next.load(Ordering::Acquire);
                if !is_marked(v) {
                    n += 1;
                }
                curr = ptr_of::<VNode>(v);
            }
        }
        n
    }
}

/// Volatile Harris list.
pub struct VolatileList {
    head: AtomicU64,
    core: VolatileCore,
}

unsafe impl Send for VolatileList {}
unsafe impl Sync for VolatileList {}

impl VolatileList {
    pub fn new() -> Self {
        VolatileList { head: AtomicU64::new(0), core: VolatileCore::new() }
    }
}

impl Default for VolatileList {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for VolatileList {
    fn drop(&mut self) {
        unsafe { self.core.ebr.drain_all() };
    }
}

impl crate::sets::ConcurrentSet for VolatileList {
    fn insert(&self, key: u64, value: u64) -> bool {
        self.core.insert(&self.head, key, value)
    }
    fn remove(&self, key: u64) -> bool {
        self.core.remove(&self.head, key)
    }
    fn contains(&self, key: u64) -> bool {
        self.core.get(&self.head, key).is_some()
    }
    fn get(&self, key: u64) -> Option<u64> {
        self.core.get(&self.head, key)
    }
    fn len_approx(&self) -> usize {
        self.core.count(&self.head)
    }
}

/// Volatile Harris hash set.
pub struct VolatileHash {
    buckets: Box<[AtomicU64]>,
    core: VolatileCore,
}

unsafe impl Send for VolatileHash {}
unsafe impl Sync for VolatileHash {}

impl VolatileHash {
    pub fn new(nbuckets: usize) -> Self {
        let n = nbuckets.next_power_of_two().max(1);
        VolatileHash {
            buckets: (0..n).map(|_| AtomicU64::new(0)).collect(),
            core: VolatileCore::new(),
        }
    }

    #[inline(always)]
    fn bucket_of(&self, key: u64) -> &AtomicU64 {
        &self.buckets[(mix64(key) as usize) & (self.buckets.len() - 1)]
    }
}

impl Drop for VolatileHash {
    fn drop(&mut self) {
        unsafe { self.core.ebr.drain_all() };
    }
}

impl crate::sets::ConcurrentSet for VolatileHash {
    fn insert(&self, key: u64, value: u64) -> bool {
        self.core.insert(self.bucket_of(key), key, value)
    }
    fn remove(&self, key: u64) -> bool {
        self.core.remove(self.bucket_of(key), key)
    }
    fn contains(&self, key: u64) -> bool {
        self.core.get(self.bucket_of(key), key).is_some()
    }
    fn get(&self, key: u64) -> Option<u64> {
        self.core.get(self.bucket_of(key), key)
    }
    fn len_approx(&self) -> usize {
        self.buckets.iter().map(|b| self.core.count(b)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sets::ConcurrentSet;

    #[test]
    fn volatile_list_model_check() {
        use crate::util::rng::Xoshiro256;
        let l = VolatileList::new();
        let mut model = std::collections::BTreeSet::new();
        let mut rng = Xoshiro256::new(0x501);
        for _ in 0..10_000 {
            let k = rng.below(64);
            match rng.below(3) {
                0 => assert_eq!(l.insert(k, k), model.insert(k)),
                1 => assert_eq!(l.remove(k), model.remove(&k)),
                _ => assert_eq!(l.contains(k), model.contains(&k)),
            }
        }
        assert_eq!(l.len_approx(), model.len());
    }

    #[test]
    fn volatile_ops_never_psync() {
        let l = VolatileList::new();
        let h = VolatileHash::new(16);
        let a = crate::pmem::stats::thread_snapshot();
        for k in 0..100u64 {
            l.insert(k, k);
            h.insert(k, k);
        }
        for k in 0..50u64 {
            l.remove(k);
            h.remove(k);
            let _ = l.contains(k);
            let _ = h.contains(k + 50);
        }
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.flushes, 0);
        assert_eq!(d.fences, 0);
    }

    #[test]
    fn volatile_hash_concurrent() {
        use std::sync::Arc;
        let h = Arc::new(VolatileHash::new(32));
        let handles: Vec<_> = (0..6u64)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::util::rng::Xoshiro256::new(t);
                    let mut net = 0i64;
                    for _ in 0..4000 {
                        let k = rng.below(128);
                        if rng.below(2) == 0 {
                            if h.insert(k, k) {
                                net += 1;
                            }
                        } else if h.remove(k) {
                            net -= 1;
                        }
                    }
                    net
                })
            })
            .collect();
        let net: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(h.len_approx() as i64, net);
    }
}
