//! Volatile Harris list/hash (Harris 2001) — the non-durable ablation
//! baseline: what the durable algorithms would cost with every psync and
//! validity write removed. Nothing survives a crash.

mod list;

pub use list::{VolatileHash, VolatileList};
