//! Log-free sorted list: Harris list with persisted links
//! (link-and-persist) over durable link cells.

use crate::alloc::{DurablePool, Ebr};
use crate::pmem::{
    self,
    root::{root_cell, RootCell},
};
use crate::sets::tagged::{is_marked, ptr_of, DIRTY, MARK, PTR_MASK};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::node::{load_link_persisted, store_link_persisted, LogFreeNode};

pub(crate) struct LogFreeCore {
    pub pool: Arc<DurablePool>,
    pub ebr: Arc<Ebr>,
}

unsafe fn free_into_pool(ptr: *mut u8, ctx: usize) {
    // Reset to the free pattern so a stale persisted image of the slot can
    // never read as an unmarked member on a later recovery walk.
    LogFreeNode::init_free_pattern(ptr);
    (*(ctx as *const DurablePool)).free(ptr);
}

impl LogFreeCore {
    pub fn new() -> Self {
        LogFreeCore {
            pool: Arc::new(DurablePool::new(64, LogFreeNode::init_free_pattern)),
            ebr: Arc::new(Ebr::new()),
        }
    }

    pub fn from_parts(pool: Arc<DurablePool>, ebr: Arc<Ebr>) -> Self {
        LogFreeCore { pool, ebr }
    }

    unsafe fn retire_node(&self, node: *mut LogFreeNode) {
        self.ebr
            .retire(node as *mut u8, Arc::as_ptr(&self.pool) as usize, free_into_pool);
    }

    /// Unlink a marked node. Its mark was already persisted by the marking
    /// remover; the unlink itself is a persisted link update.
    unsafe fn trim(&self, pred_link: *const AtomicU64, curr: *mut LogFreeNode) -> bool {
        // The mark must be durable before the node becomes unreachable.
        let succ_v = load_link_persisted(&(*curr).next);
        debug_assert!(is_marked(succ_v));
        let succ = succ_v & PTR_MASK;
        store_link_persisted(&*pred_link, curr as u64, succ)
    }

    /// Find window; persists dirty links it traverses (link-and-persist:
    /// the structure an operation relies on must be durable).
    unsafe fn find(
        &self,
        head: *const AtomicU64,
        key: u64,
    ) -> (*const AtomicU64, *mut LogFreeNode) {
        self.find_from(head, head, key)
    }

    /// `find` starting from a validated hint link (resizable-hash fast
    /// path); retries fall back to `head`.
    unsafe fn find_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
    ) -> (*const AtomicU64, *mut LogFreeNode) {
        let mut from = start;
        'retry: loop {
            let mut pred_link = std::mem::replace(&mut from, head);
            // Hint staleness: a marked start cell belongs to a deleted
            // node (frozen suffix), a dirty one to an in-flight update —
            // either way restart from the head.
            if !std::ptr::eq(pred_link, head)
                && (*pred_link).load(Ordering::Acquire) & (MARK | DIRTY) != 0
            {
                continue 'retry;
            }
            let mut curr = ptr_of::<LogFreeNode>(load_link_persisted(&*pred_link));
            loop {
                if curr.is_null() {
                    return (pred_link, curr);
                }
                let succ_v = load_link_persisted(&(*curr).next);
                if is_marked(succ_v) {
                    if !self.trim(pred_link, curr) {
                        continue 'retry;
                    }
                    curr = ptr_of::<LogFreeNode>(succ_v);
                } else {
                    if (*curr).key.load(Ordering::Relaxed) >= key {
                        return (pred_link, curr);
                    }
                    pred_link = &(*curr).next as *const AtomicU64;
                    curr = ptr_of::<LogFreeNode>(succ_v);
                }
            }
        }
    }

    pub fn insert(&self, head: *const AtomicU64, key: u64, value: u64) -> bool {
        self.insert_from(head, head, key, value)
    }

    /// Insert whose first window search starts at a validated hint link.
    pub(crate) fn insert_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
        value: u64,
    ) -> bool {
        let _g = self.ebr.pin();
        let mut new_node: *mut LogFreeNode = std::ptr::null_mut();
        let mut from = start;
        loop {
            unsafe {
                let (pred_link, curr) =
                    self.find_from(std::mem::replace(&mut from, head), head, key);
                if !curr.is_null() && (*curr).key.load(Ordering::Relaxed) == key {
                    if !new_node.is_null() {
                        LogFreeNode::init_free_pattern(new_node as *mut u8);
                        self.pool.free(new_node as *mut u8);
                    }
                    // find() already persisted the links leading here, so
                    // the failure is durably justified.
                    return false;
                }
                if new_node.is_null() {
                    new_node = self.pool.alloc() as *mut LogFreeNode;
                    // Release: pairs with the Acquire key load in hint
                    // validation so a reader observing this incarnation's
                    // key also observes the allocator's gen bump (see
                    // DESIGN.md §Reclamation).
                    (*new_node).key.store(key, Ordering::Release);
                    (*new_node).value.store(value, Ordering::Relaxed);
                }
                // The unlinked node's own link keeps DIRTY until it is
                // published, so a stale bucket hint probing a recycled
                // slot can never mistake a mid-insert node for a linked
                // one. Recovery masks tag bits, so the persisted DIRTY is
                // harmless.
                // (Release for the durlint link-store rule; the content
                // psync below is what publication actually leans on.)
                (*new_node).next.store(curr as u64 | DIRTY, Ordering::Release);
                pmem::check::note_store(new_node as *const u8);
                // Persist node content BEFORE it becomes reachable.
                pmem::psync_obj(new_node);
                // Install + persist the link (psync #2 of the update).
                if store_link_persisted(&*pred_link, curr as u64, new_node as u64) {
                    // Published: clear the pre-link DIRTY (the pointer part
                    // was persisted by the content psync above; a racing
                    // reader that saw the bit first simply re-psyncs).
                    let _ = (*new_node).next.compare_exchange(
                        curr as u64 | DIRTY,
                        curr as u64,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    );
                    return true;
                }
            }
        }
    }

    pub fn remove(&self, head: *const AtomicU64, key: u64) -> bool {
        self.remove_from(head, head, key)
    }

    /// Remove whose window search starts at a validated hint link.
    pub(crate) fn remove_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
    ) -> bool {
        let _g = self.ebr.pin();
        let mut from = start;
        loop {
            unsafe {
                let (pred_link, curr) =
                    self.find_from(std::mem::replace(&mut from, head), head, key);
                if curr.is_null() || (*curr).key.load(Ordering::Relaxed) != key {
                    return false;
                }
                let succ_v = (*curr).next.load(Ordering::Acquire);
                if succ_v & (MARK | DIRTY) != 0 {
                    continue; // racing update on this node; re-find
                }
                // Mark + persist the logical delete (psync #1), then
                // physically unlink with a persisted link update (psync #2).
                if store_link_persisted(&(*curr).next, succ_v, succ_v | MARK) {
                    if !self.trim(pred_link, curr) {
                        let _ = self.find(head, key);
                    }
                    self.retire_node(curr);
                    return true;
                }
            }
        }
    }

    /// Wait-free read; persists any dirty link it depends on (this is the
    /// reader-side flushing cost of log-free that SOFT eliminates).
    pub fn get(&self, head: *const AtomicU64, key: u64) -> Option<u64> {
        self.get_from(head, head, key)
    }

    /// Wait-free read starting from a validated hint link (or the head).
    pub(crate) fn get_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
    ) -> Option<u64> {
        let _g = self.ebr.pin();
        unsafe {
            let mut from = start;
            // Same staleness screen as find_from (reads have no CAS net).
            if !std::ptr::eq(start, head)
                && (*start).load(Ordering::Acquire) & (MARK | DIRTY) != 0
            {
                from = head;
            }
            let mut curr = ptr_of::<LogFreeNode>(load_link_persisted(&*from));
            while !curr.is_null() && (*curr).key.load(Ordering::Relaxed) < key {
                curr = ptr_of::<LogFreeNode>(load_link_persisted(&(*curr).next));
            }
            if curr.is_null() || (*curr).key.load(Ordering::Relaxed) != key {
                return None;
            }
            if is_marked(load_link_persisted(&(*curr).next)) {
                return None;
            }
            Some((*curr).value.load(Ordering::Relaxed))
        }
    }

    /// Compaction: relocate every member node whose slot lies in
    /// `[lo, hi)` to a freshly allocated slot (the claimed area is off
    /// the allocation index).
    ///
    /// Per node: psync the copy's content (as an insert would), then
    /// `store_link_persisted` the predecessor from original to copy —
    /// the durable chain swings in a single persisted link update, so
    /// unlike the link-free family there is **no** crash window with two
    /// reachable same-key nodes (recovery's dedup stays a no-op). Crash
    /// before the link psync: the copy is durable but unreachable, and
    /// the reachability walk reclaims it. The original keeps its clean
    /// outgoing link for parked readers and is retired through EBR; it
    /// needs no delete record because recovery never reaches it.
    ///
    /// # Safety
    /// Caller must serialize this against *updates* on the list (the
    /// shard worker's idle tick does); concurrent readers are safe.
    pub(crate) unsafe fn migrate_range(
        &self,
        head: *const AtomicU64,
        lo: usize,
        hi: usize,
    ) -> usize {
        let mut moved = 0;
        let mut pred_link = head;
        let mut curr = ptr_of::<LogFreeNode>(load_link_persisted(&*pred_link));
        while !curr.is_null() {
            let succ_v = load_link_persisted(&(*curr).next);
            if is_marked(succ_v) {
                // Serialized updates trim before returning; see the
                // link-free twin for why a marked node means a broken
                // contract rather than something to repair here.
                debug_assert!(false, "marked node under serialized migration");
                break;
            }
            let addr = curr as usize;
            if addr >= lo && addr < hi {
                let y = self.pool.alloc() as *mut LogFreeNode;
                debug_assert!((y as usize) < lo || (y as usize) >= hi);
                (*y).key.store((*curr).key.load(Ordering::Relaxed), Ordering::Release);
                (*y).value.store((*curr).value.load(Ordering::Relaxed), Ordering::Relaxed);
                (*y).next.store(succ_v | DIRTY, Ordering::Release);
                pmem::check::note_store(y as *const u8);
                pmem::psync_obj(y);
                let ok = store_link_persisted(&*pred_link, curr as u64, y as u64);
                debug_assert!(ok, "serialized migration lost a link CAS");
                let _ = (*y).next.compare_exchange(
                    succ_v | DIRTY,
                    succ_v,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                );
                self.retire_node(curr);
                moved += 1;
                pred_link = &(*y).next as *const AtomicU64;
            } else {
                pred_link = &(*curr).next as *const AtomicU64;
            }
            curr = ptr_of::<LogFreeNode>(succ_v);
        }
        moved
    }

    pub fn count(&self, head: *const AtomicU64) -> usize {
        self.snapshot_from(head).len()
    }

    pub fn snapshot_from(&self, head: *const AtomicU64) -> Vec<(u64, u64)> {
        let _g = self.ebr.pin();
        let mut out = Vec::new();
        unsafe {
            let mut curr = ptr_of::<LogFreeNode>((*head).load(Ordering::Acquire));
            while !curr.is_null() {
                let v = (*curr).next.load(Ordering::Acquire);
                if !is_marked(v) {
                    out.push((
                        (*curr).key.load(Ordering::Relaxed),
                        (*curr).value.load(Ordering::Relaxed),
                    ));
                }
                curr = ptr_of::<LogFreeNode>(v);
            }
        }
        out
    }
}

/// The log-free sorted-list set. Its head is a named durable root cell so
/// recovery can find the persisted structure.
pub struct LogFreeList {
    pub(crate) head: RootCell,
    pub(crate) core: LogFreeCore,
}

unsafe impl Send for LogFreeList {}
unsafe impl Sync for LogFreeList {}

impl LogFreeList {
    pub fn new() -> Self {
        let core = LogFreeCore::new();
        let head = root_cell(&format!("logfree.list.{}", core.pool.id().0));
        head.word().store(0, Ordering::SeqCst);
        head.persist();
        LogFreeList { head, core }
    }

    pub(crate) fn from_parts(head: RootCell, core: LogFreeCore) -> Self {
        LogFreeList { head, core }
    }

    pub fn pool_id(&self) -> crate::pmem::PoolId {
        self.core.pool.id()
    }

    pub fn crash_preserve(&self) {
        self.core.pool.preserve();
    }

    pub fn snapshot(&self) -> Vec<(u64, u64)> {
        self.core.snapshot_from(self.head.word())
    }
}

impl Default for LogFreeList {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for LogFreeList {
    fn drop(&mut self) {
        unsafe { self.core.ebr.drain_all() };
    }
}

impl crate::sets::ConcurrentSet for LogFreeList {
    fn insert(&self, key: u64, value: u64) -> bool {
        self.core.insert(self.head.word(), key, value)
    }
    fn remove(&self, key: u64) -> bool {
        self.core.remove(self.head.word(), key)
    }
    fn contains(&self, key: u64) -> bool {
        self.core.get(self.head.word(), key).is_some()
    }
    fn get(&self, key: u64) -> Option<u64> {
        self.core.get(self.head.word(), key)
    }
    fn len_approx(&self) -> usize {
        self.core.count(self.head.word())
    }
    fn apply_batch(&self, ops: &[crate::sets::SetOp]) -> Vec<crate::sets::OpResult> {
        // Group commit: the link-and-persist protocol keeps flushing (and
        // clearing DIRTY) per link, so concurrent readers never depend on
        // an unflushed link; only the issuer's fences are coalesced.
        crate::sets::apply_batch_coalesced(self, ops)
    }
    fn durable_pool(&self) -> Option<crate::pmem::PoolId> {
        Some(self.pool_id())
    }
    fn prepare_crash(&self) {
        self.crash_preserve();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sets::ConcurrentSet;

    #[test]
    fn sequential_semantics() {
        let l = LogFreeList::new();
        assert!(l.insert(5, 50));
        assert!(!l.insert(5, 51));
        assert_eq!(l.get(5), Some(50));
        assert!(l.insert(3, 30));
        assert!(l.insert(7, 70));
        assert_eq!(l.snapshot(), vec![(3, 30), (5, 50), (7, 70)]);
        assert!(l.remove(5));
        assert!(!l.remove(5));
        assert_eq!(l.len_approx(), 2);
    }

    #[test]
    fn update_costs_two_psyncs() {
        let l = LogFreeList::new();
        for k in 0..16u64 {
            l.insert(k, k);
        }
        let a = crate::pmem::stats::thread_snapshot();
        assert!(l.insert(100, 1));
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 2, "log-free insert = node psync + link psync");
        let a = crate::pmem::stats::thread_snapshot();
        assert!(l.remove(100));
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        // mark psync + unlink psync (+ the mark re-check in trim is clean).
        assert_eq!(d.fences, 2, "log-free remove = mark psync + unlink psync");
        let a = crate::pmem::stats::thread_snapshot();
        for k in 0..16u64 {
            assert!(l.contains(k));
        }
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 0, "clean links: reads cost no psync");

        // Failed ops over clean links: find() traverses only persisted
        // links, so neither direction has anything left to flush.
        let a = crate::pmem::stats::thread_snapshot();
        assert!(!l.insert(5, 99), "duplicate insert fails");
        assert!(!l.remove(999), "absent remove fails");
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 0, "failed ops over clean links are psync-free");
    }

    #[test]
    fn matches_btreeset_model_random_ops() {
        use crate::util::rng::Xoshiro256;
        let l = LogFreeList::new();
        let mut model = std::collections::BTreeSet::new();
        let mut rng = Xoshiro256::new(0x10F5);
        for _ in 0..10_000 {
            let k = rng.below(48);
            match rng.below(3) {
                0 => assert_eq!(l.insert(k, k), model.insert(k)),
                1 => assert_eq!(l.remove(k), model.remove(&k)),
                _ => assert_eq!(l.contains(k), model.contains(&k)),
            }
        }
    }

    #[test]
    fn concurrent_contention_net_count() {
        use std::sync::Arc;
        let l = Arc::new(LogFreeList::new());
        let handles: Vec<_> = (0..6u64)
            .map(|t| {
                let l = l.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::util::rng::Xoshiro256::new(t + 5);
                    let mut net = 0i64;
                    for _ in 0..2000 {
                        let k = rng.below(24);
                        if rng.below(2) == 0 {
                            if l.insert(k, t) {
                                net += 1;
                            }
                        } else if l.remove(k) {
                            net -= 1;
                        }
                    }
                    net
                })
            })
            .collect();
        let net: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(l.len_approx() as i64, net);
    }
}
