//! **Log-free** durable sets — the state-of-the-art baseline the paper
//! compares against (David et al., "Log-Free Concurrent Data Structures",
//! USENIX ATC 2018).
//!
//! Unlike link-free/SOFT, the log-free approach persists the *structure*:
//! every link update is written back with the **link-and-persist**
//! technique — the CAS installs the new pointer with a *dirty* bit; the
//! updater (or any reader that needs the link durable) psyncs the line and
//! clears the bit. Durable anchor words (list head root cell / persistent
//! bucket array) let recovery walk the persisted links directly.
//!
//! Cost profile (what the paper's evaluation exercises): ~2 psyncs per
//! update (node content + link), plus reader-side psyncs when a dirty
//! link is observed — versus 1 (SOFT) / ~1 (link-free).

mod hash;
mod list;
mod node;
mod recovery;

pub(crate) use node::load_link_persisted;

pub use hash::LogFreeHash;
pub use list::LogFreeList;
pub use node::LogFreeNode;
pub use recovery::{
    recover_hash, recover_hash_timed, recover_list, recover_list_timed, RecoveredStats,
};
