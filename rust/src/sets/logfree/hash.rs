//! Log-free hash set: the bucket array is itself persistent memory (the
//! structure is durable), and bucket updates follow link-and-persist.

use crate::pmem::region::{alloc_region, RegionTag};
use crate::sets::ConcurrentSet;
use crate::util::mix64;
use std::sync::atomic::AtomicU64;

use super::list::LogFreeCore;

pub struct LogFreeHash {
    /// Durable bucket array (a `Links` region of the pool).
    pub(crate) buckets: *const AtomicU64,
    pub(crate) nbuckets: usize,
    pub(crate) core: LogFreeCore,
}

unsafe impl Send for LogFreeHash {}
unsafe impl Sync for LogFreeHash {}

impl LogFreeHash {
    pub fn new(nbuckets: usize) -> Self {
        let core = LogFreeCore::new();
        let n = nbuckets.next_power_of_two().max(1);
        // Zero-initialised durable region: empty buckets, already persisted
        // (fresh regions' shadows are zeroed too).
        let base = alloc_region(core.pool.id(), n * 8, RegionTag::Links, 0);
        LogFreeHash { buckets: base as *const AtomicU64, nbuckets: n, core }
    }

    pub(crate) fn from_parts(
        buckets: *const AtomicU64,
        nbuckets: usize,
        core: LogFreeCore,
    ) -> Self {
        LogFreeHash { buckets, nbuckets, core }
    }

    #[inline(always)]
    fn bucket_of(&self, key: u64) -> &AtomicU64 {
        unsafe { &*self.buckets.add((mix64(key) as usize) & (self.nbuckets - 1)) }
    }

    pub fn nbuckets(&self) -> usize {
        self.nbuckets
    }

    pub fn pool_id(&self) -> crate::pmem::PoolId {
        self.core.pool.id()
    }

    pub fn crash_preserve(&self) {
        self.core.pool.preserve();
    }

    pub fn snapshot(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        for i in 0..self.nbuckets {
            out.extend(self.core.snapshot_from(unsafe { &*self.buckets.add(i) }));
        }
        out
    }
}

impl Drop for LogFreeHash {
    fn drop(&mut self) {
        unsafe { self.core.ebr.drain_all() };
    }
}

impl ConcurrentSet for LogFreeHash {
    fn insert(&self, key: u64, value: u64) -> bool {
        self.core.insert(self.bucket_of(key), key, value)
    }
    fn remove(&self, key: u64) -> bool {
        self.core.remove(self.bucket_of(key), key)
    }
    fn contains(&self, key: u64) -> bool {
        self.core.get(self.bucket_of(key), key).is_some()
    }
    fn get(&self, key: u64) -> Option<u64> {
        self.core.get(self.bucket_of(key), key)
    }
    fn len_approx(&self) -> usize {
        (0..self.nbuckets)
            .map(|i| self.core.count(unsafe { &*self.buckets.add(i) }))
            .sum()
    }
    fn apply_batch(&self, ops: &[crate::sets::SetOp]) -> Vec<crate::sets::OpResult> {
        crate::sets::apply_batch_coalesced(self, ops)
    }
    fn durable_pool(&self) -> Option<crate::pmem::PoolId> {
        Some(self.pool_id())
    }
    fn prepare_crash(&self) {
        self.crash_preserve();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_logfree_hash() {
        let h = LogFreeHash::new(8);
        for k in 0..64u64 {
            assert!(h.insert(k, k + 1));
        }
        for k in 0..64u64 {
            assert_eq!(h.get(k), Some(k + 1));
        }
        for k in 0..32u64 {
            assert!(h.remove(k));
        }
        assert_eq!(h.len_approx(), 32);
    }

    #[test]
    fn bucket_array_is_registered_durable() {
        let h = LogFreeHash::new(16);
        let regions = h.core.pool.regions();
        assert!(regions.iter().any(|r| r.tag == RegionTag::Links && r.len >= 16 * 8));
    }
}
