//! Log-free node: one durable cache line; the `next` link itself is part
//! of the persistent state (bit 0 = Harris mark, bit 1 = dirty).

use crate::pmem;
use crate::sets::tagged::{DIRTY, MARK};
use std::sync::atomic::{AtomicU64, Ordering};

#[repr(C, align(64))]
pub struct LogFreeNode {
    pub key: AtomicU64,
    pub value: AtomicU64,
    /// Tagged durable link: bit 0 = mark, bit 1 = dirty (not yet persisted).
    pub next: AtomicU64,
}

const _: () = assert!(std::mem::size_of::<LogFreeNode>() == 64);
// Bytes 56..64 of the slot are the allocator's generation word (see
// `alloc::area`): the node payload must stay clear of it.
const _: () = assert!(std::mem::offset_of!(LogFreeNode, next) + 8 <= 56);

impl LogFreeNode {
    /// Free pattern: marked null link — never a member on a recovery walk
    /// (walks skip marked nodes), and never reachable anyway since links
    /// to free slots are not persisted.
    pub unsafe fn init_free_pattern(slot: *mut u8) {
        let n = &*(slot as *const LogFreeNode);
        n.key.store(0, Ordering::Relaxed);
        n.value.store(0, Ordering::Relaxed);
        n.next.store(MARK, Ordering::Relaxed);
    }
}

/// Link-and-persist read: if the loaded link is dirty, psync it and try to
/// clear the bit (any thread may; all write the same clean value). Returns
/// the clean view of the link.
#[inline]
pub fn load_link_persisted(link: &AtomicU64) -> u64 {
    let v = link.load(Ordering::Acquire);
    if v & DIRTY == 0 {
        return v;
    }
    pmem::psync(link as *const AtomicU64 as *const u8, 8);
    let clean = v & !DIRTY;
    let _ = link.compare_exchange(v, clean, Ordering::AcqRel, Ordering::Acquire);
    clean
}

/// Install-and-persist a link: CAS `expect_clean -> new | DIRTY`, then
/// psync and clear the dirty bit. Returns false if the CAS lost.
#[inline]
pub fn store_link_persisted(link: &AtomicU64, expect_clean: u64, new: u64) -> bool {
    debug_assert_eq!(expect_clean & DIRTY, 0);
    debug_assert_eq!(new & DIRTY, 0);
    if link
        .compare_exchange(expect_clean, new | DIRTY, Ordering::AcqRel, Ordering::Acquire)
        .is_err()
    {
        return false;
    }
    pmem::check::note_store(link as *const AtomicU64 as *const u8);
    // The CAS made `new`'s node reachable through a durable link: its
    // own line must already be flushed (psync_obj before linking).
    let target = new & !(MARK | DIRTY);
    if target != 0 {
        pmem::check::note_publish(target as *const u8);
    }
    pmem::psync(link as *const AtomicU64 as *const u8, 8);
    let _ = link.compare_exchange(new | DIRTY, new, Ordering::AcqRel, Ordering::Acquire);
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_then_load_is_clean_and_persisted() {
        let link = AtomicU64::new(0);
        let a = crate::pmem::stats::thread_snapshot();
        assert!(store_link_persisted(&link, 0, 0x100));
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 1, "install psyncs once");
        assert_eq!(link.load(Ordering::Relaxed), 0x100, "dirty bit cleared");
        let a = crate::pmem::stats::thread_snapshot();
        assert_eq!(load_link_persisted(&link), 0x100);
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 0, "clean link loads do not psync");
    }

    #[test]
    fn dirty_load_persists_and_clears() {
        let link = AtomicU64::new(0x100 | DIRTY);
        let a = crate::pmem::stats::thread_snapshot();
        assert_eq!(load_link_persisted(&link), 0x100);
        assert_eq!(link.load(Ordering::Relaxed), 0x100);
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 1);
    }

    #[test]
    fn stale_expectation_fails() {
        let link = AtomicU64::new(0x200);
        assert!(!store_link_persisted(&link, 0x100, 0x300));
        assert_eq!(link.load(Ordering::Relaxed), 0x200);
    }
}
