//! Log-free recovery: walk the *persisted* links from the durable anchors
//! (root cell / bucket array). Marked nodes are logically deleted; dirty
//! bits are stripped (a dirty-but-present link was persisted by the psync
//! that preceded the crash, or the value is the older clean one — either
//! way the walk sees a consistent state). Area slots not reached as
//! members (leaked by crashed inserts, or deleted) are reclaimed —
//! leak-freedom without logging, same scan trick as link-free.

use crate::alloc::{DurablePool, Ebr};
use crate::pmem::region::{regions_of, RegionTag};
use crate::pmem::root::root_cell;
use crate::pmem::PoolId;
use crate::sets::tagged::{is_marked, ptr_of, PTR_MASK};
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::list::{LogFreeCore, LogFreeList};
use super::node::LogFreeNode;
use super::LogFreeHash;

pub use crate::sets::linkfree::RecoveredStats;

/// Walk one persisted chain; returns member node pointers in chain order.
unsafe fn walk_chain(head_val: u64, members: &mut Vec<*mut LogFreeNode>) {
    let mut curr = ptr_of::<LogFreeNode>(head_val & PTR_MASK);
    while !curr.is_null() {
        let v = (*curr).next.load(Ordering::Relaxed);
        if !is_marked(v) {
            members.push(curr);
        }
        curr = ptr_of::<LogFreeNode>(v & PTR_MASK);
    }
}

/// Strip marks/dirt from the walked chains, reclaim unreached slots.
fn rebuild(
    pool: &DurablePool,
    chains: &[(u64, Vec<*mut LogFreeNode>)],
) -> RecoveredStats {
    let mut stats = RecoveredStats::default();
    let reached: HashSet<usize> = chains
        .iter()
        .flat_map(|(_, m)| m.iter().map(|&p| p as usize))
        .collect();
    stats.members = reached.len();
    for slot in pool.iter_slots() {
        if !reached.contains(&(slot as usize)) {
            unsafe { pool.normalize_slot(slot) };
            pool.free(slot);
            stats.reclaimed += 1;
        }
    }
    stats
}

/// Rewrite one chain cleanly (member -> member links, no marks, no dirt).
/// Persisted in bulk afterwards by `persist_all_regions`.
unsafe fn relink(members: &[*mut LogFreeNode]) -> u64 {
    let mut next = 0u64;
    for &n in members.iter().rev() {
        (*n).next.store(next, Ordering::Relaxed);
        next = n as u64;
    }
    next
}

/// Recover a log-free list from pool `id` (head = its named root cell).
pub fn recover_list(id: PoolId) -> (LogFreeList, RecoveredStats) {
    let pool = Arc::new(DurablePool::adopt(id, 64, LogFreeNode::init_free_pattern));
    let head = root_cell(&format!("logfree.list.{}", id.0));
    let mut members = Vec::new();
    unsafe { walk_chain(head.word().load(Ordering::Relaxed), &mut members) };
    let chains = vec![(0u64, members)];
    let stats = rebuild(&pool, &chains);
    let head_val = unsafe { relink(&chains[0].1) };
    head.word().store(head_val, Ordering::Relaxed);
    pool.persist_all_regions();
    head.persist();
    let core = LogFreeCore::from_parts(pool, Arc::new(Ebr::new()));
    (LogFreeList::from_parts(head, core), stats)
}

/// Recover a log-free hash set from pool `id` (buckets = its persistent
/// `Links` region).
pub fn recover_hash(id: PoolId) -> (LogFreeHash, RecoveredStats) {
    let pool = Arc::new(DurablePool::adopt(id, 64, LogFreeNode::init_free_pattern));
    let links = regions_of(id)
        .into_iter()
        .find(|r| r.tag == RegionTag::Links)
        .expect("log-free hash pool has no bucket region");
    let nbuckets = links.len / 8;
    let buckets = links.base as *const AtomicU64;
    let mut chains = Vec::with_capacity(nbuckets);
    for i in 0..nbuckets {
        let cell = unsafe { &*buckets.add(i) };
        let mut members = Vec::new();
        unsafe { walk_chain(cell.load(Ordering::Relaxed), &mut members) };
        chains.push((i as u64, members));
    }
    let stats = rebuild(&pool, &chains);
    for (i, members) in chains.iter() {
        let head_val = unsafe { relink(members) };
        unsafe { (*buckets.add(*i as usize)).store(head_val, Ordering::Relaxed) };
    }
    pool.persist_all_regions();
    let core = LogFreeCore::from_parts(pool, Arc::new(Ebr::new()));
    (LogFreeHash::from_parts(buckets, nbuckets, core), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{self, CrashPolicy};
    use crate::sets::ConcurrentSet;

    #[test]
    fn logfree_list_crash_recovery() {
        let _sim = pmem::sim_session();
        let l = LogFreeList::new();
        let id = l.pool_id();
        for k in 0..40u64 {
            assert!(l.insert(k, k + 7));
        }
        for k in (0..40u64).step_by(5) {
            assert!(l.remove(k));
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l2, stats) = recover_list(id);
        for k in 0..40u64 {
            if k % 5 == 0 {
                assert!(!l2.contains(k), "removed key {k} resurrected");
            } else {
                assert_eq!(l2.get(k), Some(k + 7), "key {k} lost");
            }
        }
        assert_eq!(stats.members, 32);
        assert!(l2.insert(500, 1));
    }

    #[test]
    fn logfree_hash_crash_recovery_with_eviction() {
        let _sim = pmem::sim_session();
        let h = LogFreeHash::new(16);
        let id = h.pool_id();
        for k in 0..120u64 {
            assert!(h.insert(k, k));
        }
        for k in 60..90u64 {
            assert!(h.remove(k));
        }
        h.crash_preserve();
        drop(h);
        pmem::crash_pools(CrashPolicy::random(0.4, 11), &[id]);
        let (h2, stats) = recover_hash(id);
        assert_eq!(h2.nbuckets(), 16);
        for k in 0..120u64 {
            let expect = !(60..90).contains(&k);
            assert_eq!(h2.contains(k), expect, "key {k}");
        }
        assert_eq!(stats.members, 90);
    }

    #[test]
    fn crash_during_reclamation_neither_leaks_nor_resurrects() {
        let _sim = pmem::sim_session();
        let l = LogFreeList::new();
        let id = l.pool_id();
        for k in 0..20u64 {
            assert!(l.insert(k, k + 1));
        }
        assert!(l.remove(7)); // mark + unlink both persisted
        // Complete reclamation: the slot is re-initialised to the free
        // pattern and freed, its generation bumped — neither the volatile
        // re-init nor the bump is persisted before the crash. The walk
        // from the root never reaches it (the unlink was persisted), so
        // recovery reclaims it regardless.
        unsafe { l.core.ebr.drain_all() };
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);

        let (l2, stats) = recover_list(id);
        assert!(!l2.contains(7), "freed slot re-linked as a member");
        assert_eq!(stats.members, 19);
        assert_eq!(
            stats.reclaimed,
            crate::alloc::area::SLOTS_PER_AREA - 19,
            "the freed slot must be reclaimed again, not leaked"
        );
        assert!(l2.insert(7, 700), "reclaimed slots must be reusable");
        assert_eq!(l2.get(7), Some(700));
    }

    #[test]
    fn leaked_node_is_reclaimed_not_resurrected() {
        let _sim = pmem::sim_session();
        let l = LogFreeList::new();
        let id = l.pool_id();
        assert!(l.insert(1, 1));
        // Crashed insert: node content psync'd, link never installed.
        unsafe {
            let n = l.core.pool.alloc() as *mut LogFreeNode;
            (*n).key.store(2, std::sync::atomic::Ordering::Relaxed);
            (*n).value.store(2, std::sync::atomic::Ordering::Relaxed);
            (*n).next.store(0, std::sync::atomic::Ordering::Relaxed);
            pmem::psync_obj(n);
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l2, stats) = recover_list(id);
        assert!(!l2.contains(2), "leaked node must not appear in the set");
        assert!(stats.reclaimed > 0);
    }
}
