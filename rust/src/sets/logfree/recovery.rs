//! Log-free recovery via the shared engine ([`crate::sets::recovery`]).
//! Membership is not a per-slot rule (a crashed insert may psync content
//! without installing the link), so a walk of the *persisted* links from
//! the durable anchors (root cell / bucket array) discovers reachability
//! first — marked nodes are deleted, dirty bits stripped — and the
//! engine's parallel scan then classifies **member ⇔ reached**,
//! reclaiming the rest (leak-freedom without logging) and rebuilding
//! clean chains with the partitioned relink.

use crate::alloc::{DurablePool, Ebr};
use crate::pmem::region::{regions_of, RegionTag};
use crate::pmem::root::root_cell;
use crate::pmem::PoolId;
use crate::sets::recovery::{self as engine, Classify, PhaseTimings};
use crate::sets::tagged::{is_marked, ptr_of, PTR_MASK};
use crate::util::mix64;
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::list::{LogFreeCore, LogFreeList};
use super::node::LogFreeNode;
use super::LogFreeHash;

pub use crate::sets::recovery::RecoveredStats;

/// Walk one persisted chain, adding member node addresses to `reached`.
unsafe fn walk_chain(head_val: u64, reached: &mut HashSet<usize>) {
    let mut curr = ptr_of::<LogFreeNode>(head_val & PTR_MASK);
    while !curr.is_null() {
        let v = (*curr).next.load(Ordering::Relaxed);
        if !is_marked(v) {
            reached.insert(curr as usize);
        }
        curr = ptr_of::<LogFreeNode>(v & PTR_MASK);
    }
}

/// The log-free rule for the engine: member ⇔ reached from a durable
/// anchor (the walk already excluded marked nodes).
pub(crate) struct LogFreeClassify<'a> {
    reached: &'a HashSet<usize>,
}

impl Classify for LogFreeClassify<'_> {
    const FAMILY: &'static str = "log-free";
    const NULL_LINK: u64 = 0;

    unsafe fn classify(&self, slot: *mut u8) -> Option<(u64, usize)> {
        if self.reached.contains(&(slot as usize)) {
            let node = slot as *mut LogFreeNode;
            Some(((*node).key.load(Ordering::Relaxed), slot as usize))
        } else {
            None
        }
    }

    unsafe fn link_word(&self, node: usize) -> u64 {
        node as u64
    }

    /// Rewrite the chain cleanly (member -> member links, no marks, no
    /// dirt). Persisted in bulk afterwards by `persist_all_regions`.
    unsafe fn link(&self, node: usize, next: u64) {
        (*(node as *mut LogFreeNode)).next.store(next, Ordering::Relaxed);
    }
}

/// Recover a log-free list from pool `id` (head = its named root cell).
pub fn recover_list(id: PoolId) -> (LogFreeList, RecoveredStats) {
    let (l, s, _) = recover_list_timed(id, engine::default_threads());
    (l, s)
}

/// Anchor walk + engine scan (walk cost folds into the scan phase).
fn walk_and_scan(
    pool: &Arc<DurablePool>,
    anchors: impl Iterator<Item = u64>,
    threads: usize,
) -> (HashSet<usize>, engine::Scan) {
    let t0 = Instant::now();
    let mut reached = HashSet::new();
    for head in anchors {
        unsafe { walk_chain(head, &mut reached) };
    }
    let walk = t0.elapsed();
    let mut rec = engine::scan(pool, &LogFreeClassify { reached: &reached }, threads);
    rec.timings.scan += walk;
    (reached, rec)
}

/// [`recover_list`] with an explicit recovery worker count.
pub fn recover_list_timed(
    id: PoolId,
    threads: usize,
) -> (LogFreeList, RecoveredStats, PhaseTimings) {
    let pool = Arc::new(DurablePool::adopt(id, 64, LogFreeNode::init_free_pattern));
    let head = root_cell(&format!("logfree.list.{}", id.0));
    let anchor = head.word().load(Ordering::Relaxed);
    let (reached, mut rec) = walk_and_scan(&pool, std::iter::once(anchor), threads);
    rec.sort_by_key();
    // Log-free migration links-and-persists atomically, so a crash never
    // leaves both copies reachable — dedup is a no-op uniformity gate.
    unsafe { rec.dedup_duplicates(&LogFreeClassify { reached: &reached }, &pool) };
    let head_val = unsafe { rec.relink_chain(&LogFreeClassify { reached: &reached }) };
    head.word().store(head_val, Ordering::Relaxed);
    pool.persist_all_regions();
    head.persist();
    let core = LogFreeCore::from_parts(pool, Arc::new(Ebr::new()));
    (LogFreeList::from_parts(head, core), rec.stats, rec.timings)
}

/// Recover a log-free hash set from pool `id` (buckets = its persistent
/// `Links` region).
pub fn recover_hash(id: PoolId) -> (LogFreeHash, RecoveredStats) {
    let (h, s, _) = recover_hash_timed(id, engine::default_threads());
    (h, s)
}

/// [`recover_hash`] with an explicit recovery worker count.
pub fn recover_hash_timed(id: PoolId, threads: usize) -> (LogFreeHash, RecoveredStats, PhaseTimings) {
    let pool = Arc::new(DurablePool::adopt(id, 64, LogFreeNode::init_free_pattern));
    let links = regions_of(id)
        .into_iter()
        .find(|r| r.tag == RegionTag::Links)
        .expect("log-free hash pool has no bucket region");
    let nbuckets = links.len / 8;
    let buckets = links.base as *const AtomicU64;
    let anchors = (0..nbuckets).map(|i| unsafe { (*buckets.add(i)).load(Ordering::Relaxed) });
    let (reached, mut rec) = walk_and_scan(&pool, anchors, threads);
    let mask = (nbuckets - 1) as u64;
    let bucket_of = |k: u64| (mix64(k) & mask) as usize;
    rec.sort_by_bucket(bucket_of);
    unsafe { rec.dedup_duplicates(&LogFreeClassify { reached: &reached }, &pool) };
    // Start from empty cells: a bucket whose members all died must not
    // keep its stale pre-crash chain.
    for i in 0..nbuckets {
        unsafe { (*buckets.add(i)).store(0, Ordering::Relaxed) };
    }
    for (b, head) in
        unsafe { rec.relink_buckets(&LogFreeClassify { reached: &reached }, &bucket_of) }
    {
        unsafe { (*buckets.add(b)).store(head, Ordering::Relaxed) };
    }
    pool.persist_all_regions();
    let core = LogFreeCore::from_parts(pool, Arc::new(Ebr::new()));
    (LogFreeHash::from_parts(buckets, nbuckets, core), rec.stats, rec.timings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{self, CrashPolicy};
    use crate::sets::ConcurrentSet;

    #[test]
    fn logfree_list_crash_recovery() {
        let _sim = pmem::sim_session();
        let l = LogFreeList::new();
        let id = l.pool_id();
        for k in 0..40u64 {
            assert!(l.insert(k, k + 7));
        }
        for k in (0..40u64).step_by(5) {
            assert!(l.remove(k));
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l2, stats) = recover_list(id);
        for k in 0..40u64 {
            if k % 5 == 0 {
                assert!(!l2.contains(k), "removed key {k} resurrected");
            } else {
                assert_eq!(l2.get(k), Some(k + 7), "key {k} lost");
            }
        }
        assert_eq!(stats.members, 32);
        assert!(l2.insert(500, 1));
    }

    #[test]
    fn logfree_hash_crash_recovery_with_eviction() {
        let _sim = pmem::sim_session();
        let h = LogFreeHash::new(16);
        let id = h.pool_id();
        for k in 0..120u64 {
            assert!(h.insert(k, k));
        }
        for k in 60..90u64 {
            assert!(h.remove(k));
        }
        h.crash_preserve();
        drop(h);
        pmem::crash_pools(CrashPolicy::random(0.4, 11), &[id]);
        let (h2, stats) = recover_hash(id);
        assert_eq!(h2.nbuckets(), 16);
        for k in 0..120u64 {
            let expect = !(60..90).contains(&k);
            assert_eq!(h2.contains(k), expect, "key {k}");
        }
        assert_eq!(stats.members, 90);
    }

    #[test]
    fn crash_during_reclamation_neither_leaks_nor_resurrects() {
        let _sim = pmem::sim_session();
        let l = LogFreeList::new();
        let id = l.pool_id();
        for k in 0..20u64 {
            assert!(l.insert(k, k + 1));
        }
        assert!(l.remove(7)); // mark + unlink both persisted
        // Complete reclamation: the slot is re-initialised to the free
        // pattern and freed, its generation bumped — neither the volatile
        // re-init nor the bump is persisted before the crash. The walk
        // from the root never reaches it (the unlink was persisted), so
        // recovery reclaims it regardless.
        unsafe { l.core.ebr.drain_all() };
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);

        let (l2, stats) = recover_list(id);
        assert!(!l2.contains(7), "freed slot re-linked as a member");
        assert_eq!(stats.members, 19);
        assert_eq!(
            stats.reclaimed,
            crate::alloc::area::SLOTS_PER_AREA - 19,
            "the freed slot must be reclaimed again, not leaked"
        );
        assert!(l2.insert(7, 700), "reclaimed slots must be reusable");
        assert_eq!(l2.get(7), Some(700));
    }

    #[test]
    fn leaked_node_is_reclaimed_not_resurrected() {
        let _sim = pmem::sim_session();
        let l = LogFreeList::new();
        let id = l.pool_id();
        assert!(l.insert(1, 1));
        // Crashed insert: node content psync'd, link never installed.
        unsafe {
            let n = l.core.pool.alloc() as *mut LogFreeNode;
            (*n).key.store(2, std::sync::atomic::Ordering::Relaxed);
            (*n).value.store(2, std::sync::atomic::Ordering::Relaxed);
            (*n).next.store(0, std::sync::atomic::Ordering::Relaxed);
            pmem::psync_obj(n);
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l2, stats) = recover_list(id);
        assert!(!l2.contains(2), "leaked node must not appear in the set");
        assert!(stats.reclaimed > 0);
    }
}
