//! The **NVTraverse** durable sets (Friedman et al., PLDI 2020: "the
//! destination is more important than the journey").
//!
//! Link-free durable format, NVTraverse traversal discipline: the
//! search prefix of every operation is flush-free (marked nodes are
//! skipped, not trimmed), and persistence work happens only at the
//! operation's destination window — one psync per update, zero per
//! read. The fences/op ablation (`bench --fig fences`) compares this
//! family against link-free/SOFT/log-free; DESIGN.md §Families has the
//! protocol and the durable-linearizability argument.

mod hash;
pub(crate) mod list;
mod recovery;

pub use hash::NvHash;
pub use list::NvList;
pub use recovery::{
    recover_hash, recover_hash_timed, recover_list, recover_list_timed, RecoveredStats,
};
