//! NVTraverse-transformed link-free sorted list.
//!
//! Same durable format as the link-free family (one [`LfNode`] cache
//! line, two-bit validity, flush flags, slot-scan recovery) but the
//! *traversal* discipline of NVTraverse ("the destination is more
//! important than the journey"): the search prefix of an operation
//! issues **zero** flushes and zero CASes. Marked nodes met on the way
//! are skipped, not trimmed; only at the operation's destination window
//! (pred/curr at the linearization point) is persistence work done —
//! the skipped run's delete records are flushed and the whole run
//! unlinked with one batch CAS. Updates keep the link-free shape of
//! exactly one psync at the destination; reads flush nothing at all
//! (they have no destination — the same contract as the scan lane's
//! [`super::super::linkfree::list::LfCore::walk_from`]: every *acked*
//! update was already persisted by its issuer). See DESIGN.md §Families
//! for the durable-linearizability argument.
//!
//! Invariant shared with link-free trim: a marked node's delete record
//! is `flush_delete`d **before** any unlink CAS makes it unreachable —
//! otherwise a same-key re-insert could put two valid copies of the key
//! in the durable image and recovery would see a duplicate it cannot
//! attribute to compaction.

use crate::alloc::{DurablePool, Ebr};
use crate::sets::linkfree::{LfCore, LfNode};
use crate::sets::tagged::{is_marked, ptr_of, MARK};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Shared engine for the NVTraverse containers: the link-free core's
/// pool/EBR/node machinery with the NVTraverse traversal discipline on
/// top. Neutral plumbing (count, snapshot, compaction migration)
/// delegates to the embedded [`LfCore`] — the durable format is
/// identical, only the hot paths differ.
pub(crate) struct NvCore {
    pub(crate) inner: LfCore,
}

impl NvCore {
    pub fn new() -> Self {
        NvCore { inner: LfCore::new() }
    }

    pub fn from_parts(pool: Arc<DurablePool>, ebr: Arc<Ebr>) -> Self {
        NvCore { inner: LfCore::from_parts(pool, ebr) }
    }

    /// Locate the first unmarked node with key >= `key`, flush-free on
    /// the journey. Returns the link cell of the last unmarked node with
    /// a smaller key and `curr` itself (null = end of list), with the
    /// window between them guaranteed clean of marked nodes at return:
    /// a skipped run is flushed and batch-unlinked at the destination.
    /// Caller must hold an EBR guard.
    unsafe fn find(&self, head: *const AtomicU64, key: u64) -> (*const AtomicU64, *mut LfNode) {
        self.find_from(head, head, key)
    }

    /// `find` starting from a *hint* link cell (resizable-hash fast
    /// path), with the same gen-validated-hint TOCTOU fallback as the
    /// link-free core.
    pub(crate) unsafe fn find_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
    ) -> (*const AtomicU64, *mut LfNode) {
        let mut from = start;
        'retry: loop {
            let mut pred_link = std::mem::replace(&mut from, head);
            let first = (*pred_link).load(Ordering::Acquire);
            // Hint staleness (TOCTOU): a hint marked after validation has
            // a frozen `next` that bypasses nodes inserted at its unlink
            // point. Restart from the head.
            if !std::ptr::eq(pred_link, head) && is_marked(first) {
                continue 'retry;
            }
            // Journey: pure reads. Marked nodes are skipped — no flush,
            // no CAS; `skipped` records whether the final window
            // [pred_link -> curr] still contains any.
            let mut curr = ptr_of::<LfNode>(first);
            let mut skipped = false;
            loop {
                if curr.is_null() {
                    break;
                }
                let succ_t = (*curr).next.load(Ordering::Acquire);
                if is_marked(succ_t) {
                    skipped = true;
                    curr = ptr_of::<LfNode>(succ_t);
                } else if (*curr).key.load(Ordering::Relaxed) >= key {
                    break;
                } else {
                    pred_link = &(*curr).next as *const AtomicU64;
                    skipped = false;
                    curr = ptr_of::<LfNode>(succ_t);
                }
            }
            if !skipped {
                return (pred_link, curr);
            }
            // Destination: persist the skipped run's delete records, then
            // detach the whole run with one CAS. Reload the window first —
            // it may have moved under the flush-free walk.
            let observed = (*pred_link).load(Ordering::Acquire);
            if is_marked(observed) {
                continue 'retry; // pred itself was deleted meanwhile
            }
            if ptr_of::<LfNode>(observed) == curr {
                return (pred_link, curr); // someone else unlinked the run
            }
            // Re-walk observed..curr verifying every intermediate node is
            // (still) marked: an unmarked one means a concurrent insert
            // landed inside the stale window — restart rather than detach
            // a live node. Each marked node is flushed BEFORE the unlink
            // (see the module invariant); the flags elide re-flushes.
            let mut run = ptr_of::<LfNode>(observed);
            loop {
                if std::ptr::eq(run, curr) {
                    break;
                }
                if run.is_null() {
                    continue 'retry;
                }
                let s = (*run).next.load(Ordering::Acquire);
                if !is_marked(s) {
                    continue 'retry;
                }
                (*run).flush_delete();
                run = ptr_of::<LfNode>(s);
            }
            if (*pred_link)
                .compare_exchange(observed, curr as u64, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                continue 'retry;
            }
            // The detached run is NOT retired here: reclamation stays
            // with each node's mark-CAS winner (its remover), exactly as
            // in the link-free core.
            return (pred_link, curr);
        }
    }

    pub fn insert(&self, head: *const AtomicU64, key: u64, value: u64) -> bool {
        self.insert_from(head, head, key, value)
    }

    /// Insert whose first window search starts at a validated hint link.
    /// Identical to the link-free insert except that the window search is
    /// the flush-free NVTraverse `find` — the destination work (helping
    /// an earlier same-key insert, or validate + flush the new node) is
    /// byte-for-byte the link-free protocol.
    pub(crate) fn insert_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
        value: u64,
    ) -> bool {
        let _g = self.inner.ebr.pin();
        let mut new_node: *mut LfNode = std::ptr::null_mut();
        let mut from = start;
        loop {
            unsafe {
                let (pred_link, curr) = self.find_from(std::mem::replace(&mut from, head), head, key);
                if !curr.is_null() && (*curr).key.load(Ordering::Relaxed) == key {
                    // Destination help (§3.3): the earlier insert of this
                    // key must be durable before this failed insert acks.
                    (*curr).make_valid();
                    (*curr).flush_insert();
                    if !new_node.is_null() {
                        LfNode::init_free_pattern(new_node as *mut u8);
                        self.inner.pool.free(new_node as *mut u8);
                    }
                    return false;
                }
                if new_node.is_null() {
                    new_node = self.inner.pool.alloc() as *mut LfNode;
                    // Invalid-before-init: a crash during initialisation
                    // must not let recovery see a half-written node.
                    (*new_node).make_invalid();
                    std::sync::atomic::fence(Ordering::Release);
                    (*new_node).reset_flush_flags();
                    // Release: a hint validator that reads THIS incarnation's
                    // key (Acquire) must also observe the allocator's gen
                    // bump (DESIGN.md §Reclamation — same rationale as the
                    // link-free insert).
                    (*new_node).key.store(key, Ordering::Release);
                    (*new_node).value.store(value, Ordering::Relaxed);
                }
                // Link (still invalid!), then validate, then persist —
                // the one psync of the operation, at the destination.
                (*new_node).next.store(curr as u64, Ordering::Release);
                if (*pred_link)
                    .compare_exchange(
                        curr as u64,
                        new_node as u64,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    (*new_node).make_valid();
                    (*new_node).flush_insert();
                    return true;
                }
            }
        }
    }

    pub fn remove(&self, head: *const AtomicU64, key: u64) -> bool {
        self.remove_from(head, head, key)
    }

    /// Remove whose first window search starts at a validated hint link.
    /// Destination shape: mark CAS, **flush the delete record**, then one
    /// unlink CAS — flush-before-unlink, so the record is durable before
    /// the node can become unreachable.
    pub(crate) fn remove_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
    ) -> bool {
        let _g = self.inner.ebr.pin();
        let mut from = start;
        loop {
            unsafe {
                let (pred_link, curr) = self.find_from(std::mem::replace(&mut from, head), head, key);
                if curr.is_null() || (*curr).key.load(Ordering::Relaxed) != key {
                    return false;
                }
                let succ_t = (*curr).next.load(Ordering::Acquire);
                if is_marked(succ_t) {
                    // Lost to another remover; converge via find (whose
                    // destination cleanup detaches it) and fail there.
                    continue;
                }
                // Invariant: a marked node is valid (same line, no psync
                // needed between the two stores — paper §3.4).
                (*curr).make_valid();
                if (*curr)
                    .next
                    .compare_exchange(succ_t, succ_t | MARK, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    // The mark is the durable delete record; persist it at
                    // the destination before any unlink can hide the node.
                    crate::pmem::check::note_store(curr as *const u8);
                    (*curr).flush_delete();
                    let succ = ptr_of::<LfNode>(succ_t);
                    if (*pred_link)
                        .compare_exchange(
                            curr as u64,
                            succ as u64,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_err()
                    {
                        // Window went stale; find's destination cleanup
                        // guarantees no marked node with this key stays
                        // reachable.
                        let _ = self.find(head, key);
                    }
                    self.inner.retire_node(curr);
                    return true;
                }
            }
        }
    }

    pub fn get(&self, head: *const AtomicU64, key: u64) -> Option<u64> {
        self.get_from(head, head, key)
    }

    /// Wait-free read, **unconditionally flush- and fence-free**: a read
    /// has no destination to persist (unlike the link-free read, which
    /// helps-flush in-flight state it depends on). Membership uses the
    /// same include-iff-unmarked rule as the scan lane; every acked
    /// update was persisted by its issuer, so the answer is durable for
    /// everything the client could have observed acked (DESIGN.md
    /// §Families).
    pub(crate) fn get_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
    ) -> Option<u64> {
        let _g = self.inner.ebr.pin();
        unsafe {
            let mut from = start;
            // Same hint TOCTOU as find_from (no CAS safety net on a read).
            if !std::ptr::eq(start, head) && is_marked((*start).load(Ordering::Acquire)) {
                from = head;
            }
            let mut curr = ptr_of::<LfNode>((*from).load(Ordering::Acquire));
            while !curr.is_null() && (*curr).key.load(Ordering::Relaxed) < key {
                curr = ptr_of::<LfNode>((*curr).next.load(Ordering::Acquire));
            }
            if curr.is_null() || (*curr).key.load(Ordering::Relaxed) != key {
                return None;
            }
            if is_marked((*curr).next.load(Ordering::Acquire)) {
                return None;
            }
            Some((*curr).value.load(Ordering::Relaxed))
        }
    }
}

/// The NVTraverse sorted-list set.
pub struct NvList {
    pub(crate) head: AtomicU64,
    pub(crate) core: NvCore,
}

unsafe impl Send for NvList {}
unsafe impl Sync for NvList {}

impl NvList {
    pub fn new() -> Self {
        NvList { head: AtomicU64::new(0), core: NvCore::new() }
    }

    pub(crate) fn from_parts(head_value: u64, core: NvCore) -> Self {
        NvList { head: AtomicU64::new(head_value), core }
    }

    /// The durable pool id (names the areas; needed to recover after a
    /// crash — see [`super::recover_list`]).
    pub fn pool_id(&self) -> crate::pmem::PoolId {
        self.core.inner.pool.id()
    }

    /// Prepare for a simulated crash: keep the durable regions alive when
    /// this (volatile) handle is dropped.
    pub fn crash_preserve(&self) {
        self.core.inner.pool.preserve();
    }

    /// Ordered snapshot (test/debug).
    pub fn snapshot(&self) -> Vec<(u64, u64)> {
        self.core.inner.snapshot(&self.head)
    }
}

impl Default for NvList {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for NvList {
    fn drop(&mut self) {
        // Flush deferred frees while the pool is still alive; after a
        // simulated crash the limbo lists are abandoned (recovery reclaims
        // the durable slots from the areas instead).
        unsafe { self.core.inner.ebr.drain_all() };
    }
}

impl crate::sets::ConcurrentSet for NvList {
    fn insert(&self, key: u64, value: u64) -> bool {
        self.core.insert(&self.head, key, value)
    }
    fn remove(&self, key: u64) -> bool {
        self.core.remove(&self.head, key)
    }
    fn contains(&self, key: u64) -> bool {
        self.core.get(&self.head, key).is_some()
    }
    fn get(&self, key: u64) -> Option<u64> {
        self.core.get(&self.head, key)
    }
    fn len_approx(&self) -> usize {
        self.core.inner.count(&self.head)
    }
    fn apply_batch(&self, ops: &[crate::sets::SetOp]) -> Vec<crate::sets::OpResult> {
        // Group commit: the batch issuer's fences collapse into one
        // trailing fence; per-op destination flushes stay flag-elided.
        crate::sets::apply_batch_coalesced(self, ops)
    }
    fn durable_pool(&self) -> Option<crate::pmem::PoolId> {
        Some(self.pool_id())
    }
    fn prepare_crash(&self) {
        self.crash_preserve();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sets::{ConcurrentSet, SetOp};

    #[test]
    fn sequential_semantics() {
        let l = NvList::new();
        assert!(!l.contains(5));
        assert!(l.insert(5, 50));
        assert!(!l.insert(5, 51), "duplicate insert must fail");
        assert!(l.contains(5));
        assert_eq!(l.get(5), Some(50));
        assert!(l.insert(3, 30));
        assert!(l.insert(7, 70));
        assert_eq!(l.snapshot(), vec![(3, 30), (5, 50), (7, 70)]);
        assert!(l.remove(5));
        assert!(!l.remove(5), "double remove must fail");
        assert!(!l.contains(5));
        assert_eq!(l.snapshot(), vec![(3, 30), (7, 70)]);
        assert_eq!(l.len_approx(), 2);
    }

    #[test]
    fn matches_btreeset_model_random_ops() {
        use crate::util::rng::Xoshiro256;
        let l = NvList::new();
        let mut model = std::collections::BTreeSet::new();
        let mut rng = Xoshiro256::new(0xBEEF);
        for _ in 0..20_000 {
            let k = rng.below(64);
            match rng.below(3) {
                0 => assert_eq!(l.insert(k, k), model.insert(k)),
                1 => assert_eq!(l.remove(k), model.remove(&k)),
                _ => assert_eq!(l.contains(k), model.contains(&k)),
            }
        }
        let snap: Vec<u64> = l.snapshot().iter().map(|kv| kv.0).collect();
        let want: Vec<u64> = model.iter().copied().collect();
        assert_eq!(snap, want);
    }

    #[test]
    fn contention_on_same_keys() {
        use std::sync::Arc;
        let l = Arc::new(NvList::new());
        let handles: Vec<_> = (0..8u64)
            .map(|t| {
                let l = l.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::util::rng::Xoshiro256::new(t + 0x9E);
                    let mut net = 0i64;
                    for _ in 0..3000 {
                        let k = rng.below(16);
                        if rng.below(2) == 0 {
                            if l.insert(k, t) {
                                net += 1;
                            }
                        } else if l.remove(k) {
                            net -= 1;
                        }
                    }
                    net
                })
            })
            .collect();
        let net: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(l.len_approx() as i64, net, "successful inserts - removes must equal size");
        let snap = l.snapshot();
        for w in snap.windows(2) {
            assert!(w[0].0 < w[1].0, "list must stay strictly sorted");
        }
    }

    #[test]
    fn pinned_fence_flush_budgets() {
        // The NVTraverse headline: the whole operation pays exactly one
        // psync at the destination — and a read pays none, ever.
        let l = NvList::new();
        for k in 0..8u64 {
            assert!(l.insert(k * 2, k)); // warm up allocator areas
        }
        let a = crate::pmem::stats::thread_snapshot();
        assert!(l.insert(100, 1));
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 1, "insert = 1 destination psync");
        assert_eq!(d.flushes, 1, "insert = 1 destination flush");

        let a = crate::pmem::stats::thread_snapshot();
        assert!(l.remove(100));
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 1, "remove = 1 destination psync");
        assert_eq!(d.flushes, 1, "remove = 1 destination flush");

        let a = crate::pmem::stats::thread_snapshot();
        assert!(l.contains(4));
        assert_eq!(l.get(4), Some(2));
        assert!(!l.contains(5), "miss walks the same flush-free path");
        assert!(l.get(999).is_none());
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 0, "reads never fence (hit or miss)");
        assert_eq!(d.flushes, 0, "reads never flush (hit or miss)");
    }

    #[test]
    fn failed_ops_flush_bounds() {
        // Same helping rule as link-free (§3.3): a failed insert helps the
        // earlier insert of the key become durable at the destination —
        // flag-elided when it already is; a failed remove needs nothing.
        let l = NvList::new();
        for k in 0..8u64 {
            assert!(l.insert(k, k));
        }
        let a = crate::pmem::stats::thread_snapshot();
        assert!(!l.insert(3, 99), "duplicate insert fails");
        assert!(!l.remove(999), "absent remove fails");
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 0, "failed ops over durable keys are psync-free");

        // Strip key 3's insert-flushed flag (as if its inserter has not
        // psync'd yet): the next failed insert must help-persist it.
        unsafe {
            let mut curr = ptr_of::<LfNode>(l.head.load(Ordering::Acquire));
            while !curr.is_null() && (*curr).key.load(Ordering::Relaxed) != 3 {
                curr = ptr_of::<LfNode>((*curr).next.load(Ordering::Acquire));
            }
            assert!(!curr.is_null());
            (*curr).reset_flush_flags();
        }
        let a = crate::pmem::stats::thread_snapshot();
        assert!(!l.insert(3, 99));
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 1, "helping a not-yet-durable insert costs its psync");
        let a = crate::pmem::stats::thread_snapshot();
        assert!(!l.insert(3, 99));
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 0, "the helped psync is flag-elided afterwards");
    }

    #[test]
    fn reads_stay_flush_free_over_unpersisted_state() {
        // The link-free reader helps-flush in-flight state it depends on;
        // the NVTraverse reader never does — strip a node's flags as if
        // its inserter has not psync'd yet and read right through it.
        let l = NvList::new();
        for k in 0..8u64 {
            assert!(l.insert(k, k + 10));
        }
        unsafe {
            let mut curr = ptr_of::<LfNode>(l.head.load(Ordering::Acquire));
            while !curr.is_null() && (*curr).key.load(Ordering::Relaxed) != 3 {
                curr = ptr_of::<LfNode>((*curr).next.load(Ordering::Acquire));
            }
            assert!(!curr.is_null());
            (*curr).reset_flush_flags();
        }
        let a = crate::pmem::stats::thread_snapshot();
        assert_eq!(l.get(3), Some(13));
        assert!(l.contains(3));
        assert!(l.contains(7), "walks past the unflushed node, still free");
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 0, "reads never help-flush");
        assert_eq!(d.flushes, 0);
    }

    #[test]
    fn traversal_skips_marked_nodes_and_cleans_only_the_destination() {
        // Hand-mark a linked node (a remover between its mark CAS and its
        // unlink): a read walks over it flush-free; the next *update*
        // whose destination window contains it flushes its delete record
        // and batch-unlinks it — flush-before-unlink.
        let l = NvList::new();
        for k in 0..8u64 {
            assert!(l.insert(k, k));
        }
        let marked = unsafe {
            let mut curr = ptr_of::<LfNode>(l.head.load(Ordering::Acquire));
            while !curr.is_null() && (*curr).key.load(Ordering::Relaxed) != 5 {
                curr = ptr_of::<LfNode>((*curr).next.load(Ordering::Acquire));
            }
            assert!(!curr.is_null());
            let succ = (*curr).next.load(Ordering::Acquire);
            assert!(!is_marked(succ));
            (*curr).next.store(succ | MARK, Ordering::Release);
            crate::pmem::check::note_store(curr as *const u8);
            (*curr).reset_flush_flags();
            curr
        };

        let a = crate::pmem::stats::thread_snapshot();
        assert!(!l.contains(5), "marked = absent");
        assert!(l.contains(6), "read walks over the marked node");
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 0, "the journey over a marked node flushes nothing");
        assert_eq!(d.flushes, 0);

        // Re-insert of the same key: its destination window contains the
        // marked node, so it is flushed (1) + unlinked, then the fresh
        // node pays its own destination psync (1).
        let a = crate::pmem::stats::thread_snapshot();
        assert!(l.insert(5, 55));
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 2, "destination cleanup + the insert's own psync");
        assert_eq!(d.flushes, 2);
        assert_eq!(l.get(5), Some(55));
        unsafe {
            assert!(
                is_marked((*marked).next.load(Ordering::Acquire)),
                "the stale node stays marked"
            );
        }
        let keys: Vec<u64> = l.snapshot().iter().map(|kv| kv.0).collect();
        assert_eq!(keys, (0..8u64).collect::<Vec<_>>(), "exactly one 5 reachable");
    }

    #[test]
    fn batched_updates_share_one_trailing_fence() {
        let l = NvList::new();
        for k in 0..8u64 {
            assert!(l.insert(k, k)); // warm up allocator areas
        }
        let ops: Vec<SetOp> = (100..164u64).map(|k| SetOp::Insert(k, k * 3)).collect();
        let a = crate::pmem::stats::thread_snapshot();
        let res = l.apply_batch(&ops);
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert!(res.iter().all(|r| *r == crate::sets::OpResult::Applied(true)));
        assert_eq!(d.fences, 1, "64 batched inserts = one trailing fence");
        assert_eq!(d.elided, 64, "each op's destination fence is elided");
        assert_eq!(d.flushes, 64, "destination flushes still happen per-op");
    }
}
