//! NVTraverse recovery via the shared engine ([`crate::sets::recovery`]).
//!
//! The durable format is byte-identical to link-free (same [`LfNode`]
//! validity scheme, same free pattern), so the classify rule is the
//! same: **valid & unmarked ⇒ member**. The family string differs only
//! so the resizable layer's epoch root cell and the recovery stats are
//! attributed to the right family. The traversal discipline changes
//! nothing here — what NVTraverse defers on the hot path (journey
//! flushes) was never durable state to begin with; every destination
//! flush lands before its op acks, so the engine sees the same class of
//! images link-free recovery proves exact.

use crate::alloc::{DurablePool, Ebr};
use crate::pmem::PoolId;
use crate::sets::linkfree::LfNode;
use crate::sets::recovery::{self as engine, Classify, PhaseTimings};
use crate::sets::tagged::MARK;
use crate::util::mix64;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::hash::NvHash;
use super::list::{NvCore, NvList};

pub use crate::sets::recovery::RecoveredStats;

/// The NVTraverse validity rule for the engine (the link-free rule under
/// the family's own name).
pub(crate) struct NvClassify;

impl Classify for NvClassify {
    const FAMILY: &'static str = "nvtraverse";
    const NULL_LINK: u64 = 0; // null, unmarked

    unsafe fn classify(&self, slot: *mut u8) -> Option<(u64, usize)> {
        let node = slot as *mut LfNode;
        if (*node).is_member() {
            Some(((*node).key.load(Ordering::Relaxed), node as usize))
        } else {
            None
        }
    }

    unsafe fn link_word(&self, node: usize) -> u64 {
        debug_assert_eq!(node as u64 & MARK, 0);
        node as u64
    }

    unsafe fn link(&self, node: usize, next: u64) {
        let n = node as *mut LfNode;
        (*n).next.store(next, Ordering::Relaxed);
        // Content is durable: arm the insert-flush flag so post-recovery
        // updates don't re-psync, and clear the delete flag.
        (*n).reset_flush_flags();
        (*n).set_insert_flushed();
    }
}

/// Rebuild an NVTraverse list from the durable areas of `id`.
pub fn recover_list(id: PoolId) -> (NvList, RecoveredStats) {
    let (l, s, _) = recover_list_timed(id, engine::default_threads());
    (l, s)
}

/// [`recover_list`] with an explicit recovery worker count.
pub fn recover_list_timed(id: PoolId, threads: usize) -> (NvList, RecoveredStats, PhaseTimings) {
    let pool = Arc::new(DurablePool::adopt(id, 64, LfNode::init_free_pattern));
    let mut rec = engine::scan(&pool, &NvClassify, threads);
    rec.sort_by_key();
    // A crash mid-compaction legitimately leaves a migrated copy AND its
    // source valid with the same key; keep one, demote the other.
    unsafe { rec.dedup_duplicates(&NvClassify, &pool) };
    let head = unsafe { rec.relink_chain(&NvClassify) };
    pool.persist_all_regions();
    let core = NvCore::from_parts(pool, Arc::new(Ebr::new()));
    (NvList::from_parts(head, core), rec.stats, rec.timings)
}

/// Rebuild an NVTraverse hash set from the durable areas of `id`.
pub fn recover_hash(id: PoolId, nbuckets: usize) -> (NvHash, RecoveredStats) {
    let (h, s, _) = recover_hash_timed(id, nbuckets, engine::default_threads());
    (h, s)
}

/// [`recover_hash`] with an explicit recovery worker count (bucket-
/// partitioned relink: no two workers touch the same chain).
pub fn recover_hash_timed(
    id: PoolId,
    nbuckets: usize,
    threads: usize,
) -> (NvHash, RecoveredStats, PhaseTimings) {
    let pool = Arc::new(DurablePool::adopt(id, 64, LfNode::init_free_pattern));
    let mut rec = engine::scan(&pool, &NvClassify, threads);
    let core = NvCore::from_parts(pool, Arc::new(Ebr::new()));
    let hash = NvHash::from_parts(nbuckets, core);
    let mask = (hash.nbuckets() - 1) as u64;
    let bucket_of = |k: u64| (mix64(k) & mask) as usize;
    rec.sort_by_bucket(bucket_of);
    unsafe { rec.dedup_duplicates(&NvClassify, &hash.core.inner.pool) };
    for (b, head) in unsafe { rec.relink_buckets(&NvClassify, &bucket_of) } {
        hash.buckets[b].store(head, Ordering::Relaxed);
    }
    hash.core.inner.pool.persist_all_regions();
    (hash, rec.stats, rec.timings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{self, CrashPolicy};
    use crate::sets::ConcurrentSet;

    #[test]
    fn recover_list_after_pessimistic_crash() {
        let _sim = pmem::sim_session();
        let l = NvList::new();
        let id = l.pool_id();
        for k in 0..50u64 {
            assert!(l.insert(k, k + 1000));
        }
        for k in (0..50u64).step_by(3) {
            assert!(l.remove(k));
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);

        let (l2, stats) = recover_list(id);
        // Every acked op's destination flush was psync'd before it
        // returned, so the recovered set must match exactly.
        for k in 0..50u64 {
            if k % 3 == 0 {
                assert!(!l2.contains(k), "removed key {k} resurrected");
            } else {
                assert_eq!(l2.get(k), Some(k + 1000), "key {k} lost");
            }
        }
        assert_eq!(stats.members as usize, (0..50).filter(|k| k % 3 != 0).count());
        // Post-recovery the structure is fully operational.
        assert!(l2.insert(999, 1));
        assert!(l2.remove(1));
    }

    #[test]
    fn recover_hash_after_random_eviction_crash() {
        let _sim = pmem::sim_session();
        let h = NvHash::new(32);
        let id = h.pool_id();
        for k in 0..200u64 {
            assert!(h.insert(k, k));
        }
        for k in 100..150u64 {
            assert!(h.remove(k));
        }
        h.crash_preserve();
        drop(h);
        // Random eviction may persist *extra* lines, never fewer: acked
        // ops must still be exact.
        pmem::crash_pools(CrashPolicy::random(0.5, 43), &[id]);

        let (h2, stats) = recover_hash(id, 32);
        for k in 0..200u64 {
            let expect = !(100..150).contains(&k);
            assert_eq!(h2.contains(k), expect, "key {k}");
        }
        assert_eq!(stats.members, 150);
        assert!(stats.reclaimed > 0);
        // Reclaimed slots are reusable.
        for k in 1000..1100u64 {
            assert!(h2.insert(k, k));
        }
    }

    #[test]
    fn unflushed_insert_does_not_survive_pessimistic_crash() {
        let _sim = pmem::sim_session();
        // Hand-craft an in-flight insert: linked and valid in volatile
        // memory but never psync'd (its destination flush never ran).
        let l = NvList::new();
        let id = l.pool_id();
        assert!(l.insert(1, 1)); // psync'd
        unsafe {
            let node = l.core.inner.pool.alloc() as *mut LfNode;
            (*node).make_invalid();
            (*node).reset_flush_flags();
            (*node).key.store(2, std::sync::atomic::Ordering::Relaxed);
            (*node).value.store(2, std::sync::atomic::Ordering::Relaxed);
            (*node).next.store(0, std::sync::atomic::Ordering::Relaxed);
            (*node).make_valid(); // valid in cache, never flushed
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l2, _) = recover_list(id);
        assert!(l2.contains(1));
        assert!(!l2.contains(2), "unflushed insert must not survive");
    }

    #[test]
    fn skipped_marked_run_is_durable_before_unlink() {
        let _sim = pmem::sim_session();
        // The module invariant under crash: hand-mark a linked node with
        // its flags stripped (a remover between mark CAS and destination
        // flush), let an insert's destination cleanup detach it, then
        // crash pessimistically. The cleanup flushed the delete record
        // BEFORE the unlink, so recovery must not resurrect the key with
        // its old value alongside the re-inserted one.
        use crate::sets::tagged::{is_marked, ptr_of, MARK};
        let l = NvList::new();
        let id = l.pool_id();
        for k in 0..8u64 {
            assert!(l.insert(k, k + 100));
        }
        unsafe {
            let mut curr = ptr_of::<LfNode>(l.head.load(std::sync::atomic::Ordering::Acquire));
            while !curr.is_null() && (*curr).key.load(Ordering::Relaxed) != 5 {
                curr = ptr_of::<LfNode>((*curr).next.load(std::sync::atomic::Ordering::Acquire));
            }
            assert!(!curr.is_null());
            let succ = (*curr).next.load(std::sync::atomic::Ordering::Acquire);
            assert!(!is_marked(succ));
            (*curr).next.store(succ | MARK, std::sync::atomic::Ordering::Release);
            crate::pmem::check::note_store(curr as *const u8);
            (*curr).reset_flush_flags();
        }
        assert!(l.insert(5, 555), "re-insert through the destination cleanup");
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l2, stats) = recover_list(id);
        assert_eq!(l2.get(5), Some(555), "exactly the re-inserted incarnation");
        assert_eq!(stats.members, 8, "no duplicate 5 in the durable image");
    }

    #[test]
    fn double_crash_no_ghosts() {
        let _sim = pmem::sim_session();
        let l = NvList::new();
        let id = l.pool_id();
        for k in 0..20u64 {
            l.insert(k, k);
        }
        for k in 0..10u64 {
            l.remove(k);
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l2, _) = recover_list(id);
        // Crash again immediately: normalisation of reclaimed slots was
        // persisted by recovery, so the second recovery sees the same set.
        l2.crash_preserve();
        drop(l2);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l3, stats) = recover_list(id);
        for k in 0..20u64 {
            assert_eq!(l3.contains(k), k >= 10, "key {k} after double crash");
        }
        assert_eq!(stats.members, 10);
    }
}
