//! NVTraverse fixed-bucket hash set (load-factor-1 evaluation shape,
//! like [`crate::sets::linkfree::LfHash`]). A bucket is one bare link
//! cell; the NVTraverse list core runs unchanged on it. The bucket
//! array is volatile — recovery rebuilds it from the durable areas.

use crate::sets::ConcurrentSet;
use crate::util::mix64;
use std::sync::atomic::AtomicU64;

use super::list::NvCore;

pub struct NvHash {
    pub(crate) buckets: Box<[AtomicU64]>,
    pub(crate) core: NvCore,
}

unsafe impl Send for NvHash {}
unsafe impl Sync for NvHash {}

impl NvHash {
    /// `nbuckets` is rounded up to a power of two.
    pub fn new(nbuckets: usize) -> Self {
        Self::from_parts(nbuckets, NvCore::new())
    }

    pub(crate) fn from_parts(nbuckets: usize, core: NvCore) -> Self {
        let n = nbuckets.next_power_of_two().max(1);
        let buckets = (0..n).map(|_| AtomicU64::new(0)).collect();
        NvHash { buckets, core }
    }

    #[inline(always)]
    pub(crate) fn bucket_of(&self, key: u64) -> &AtomicU64 {
        let i = (mix64(key) as usize) & (self.buckets.len() - 1);
        &self.buckets[i]
    }

    pub fn nbuckets(&self) -> usize {
        self.buckets.len()
    }

    pub fn pool_id(&self) -> crate::pmem::PoolId {
        self.core.inner.pool.id()
    }

    /// Keep durable regions alive across a simulated crash.
    pub fn crash_preserve(&self) {
        self.core.inner.pool.preserve();
    }

    /// All (key, value) pairs, unordered (test/debug only).
    pub fn snapshot(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        for b in self.buckets.iter() {
            out.extend(self.core.inner.snapshot(b));
        }
        out
    }
}

impl Drop for NvHash {
    fn drop(&mut self) {
        unsafe { self.core.inner.ebr.drain_all() };
    }
}

impl ConcurrentSet for NvHash {
    fn insert(&self, key: u64, value: u64) -> bool {
        self.core.insert(self.bucket_of(key), key, value)
    }
    fn remove(&self, key: u64) -> bool {
        self.core.remove(self.bucket_of(key), key)
    }
    fn contains(&self, key: u64) -> bool {
        self.core.get(self.bucket_of(key), key).is_some()
    }
    fn get(&self, key: u64) -> Option<u64> {
        self.core.get(self.bucket_of(key), key)
    }
    fn len_approx(&self) -> usize {
        self.buckets.iter().map(|b| self.core.inner.count(b)).sum()
    }
    fn apply_batch(&self, ops: &[crate::sets::SetOp]) -> Vec<crate::sets::OpResult> {
        crate::sets::apply_batch_coalesced(self, ops)
    }
    fn durable_pool(&self) -> Option<crate::pmem::PoolId> {
        Some(self.pool_id())
    }
    fn prepare_crash(&self) {
        self.crash_preserve();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_hash_ops() {
        let h = NvHash::new(16);
        assert_eq!(h.nbuckets(), 16);
        for k in 0..100u64 {
            assert!(h.insert(k, k * 10));
        }
        for k in 0..100u64 {
            assert!(h.contains(k));
            assert_eq!(h.get(k), Some(k * 10));
            assert!(!h.insert(k, 0));
        }
        assert_eq!(h.len_approx(), 100);
        for k in (0..100u64).step_by(2) {
            assert!(h.remove(k));
        }
        assert_eq!(h.len_approx(), 50);
        assert!(!h.contains(0));
        assert!(h.contains(1));
    }

    #[test]
    fn concurrent_hash_stress() {
        use std::sync::Arc;
        let h = Arc::new(NvHash::new(64));
        let handles: Vec<_> = (0..8u64)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::util::rng::Xoshiro256::new(t * 11 + 3);
                    let mut net = 0i64;
                    for _ in 0..5000 {
                        let k = rng.below(256);
                        match rng.below(3) {
                            0 => {
                                if h.insert(k, k) {
                                    net += 1;
                                }
                            }
                            1 => {
                                if h.remove(k) {
                                    net -= 1;
                                }
                            }
                            _ => {
                                let _ = h.contains(k);
                            }
                        }
                    }
                    net
                })
            })
            .collect();
        let net: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(h.len_approx() as i64, net);
        let snap = h.snapshot();
        let mut uniq: Vec<u64> = snap.iter().map(|kv| kv.0).collect();
        uniq.sort_unstable();
        let n = uniq.len();
        uniq.dedup();
        assert_eq!(n, uniq.len(), "no duplicate keys across buckets");
    }
}
