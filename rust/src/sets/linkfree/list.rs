//! Link-free sorted list (paper Listings 2–5).
//!
//! The list core operates on *link cells* (`AtomicU64` holding a tagged
//! node pointer): the list head, a hash bucket, or a node's `next`. There
//! is no tail sentinel; a null link means "key +∞".

use crate::alloc::{DurablePool, Ebr};
use crate::sets::tagged::{is_marked, ptr_of, MARK};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::node::LfNode;

/// Shared engine for all link-free containers (a list is one head cell; a
/// hash set is an array of them).
pub(crate) struct LfCore {
    pub pool: Arc<DurablePool>,
    pub ebr: Arc<Ebr>,
}

unsafe fn free_into_pool(ptr: *mut u8, ctx: usize) {
    let pool = &*(ctx as *const DurablePool);
    pool.free(ptr);
}

impl LfCore {
    pub fn new() -> Self {
        LfCore {
            pool: Arc::new(DurablePool::new(64, LfNode::init_free_pattern)),
            ebr: Arc::new(Ebr::new()),
        }
    }

    pub fn from_parts(pool: Arc<DurablePool>, ebr: Arc<Ebr>) -> Self {
        LfCore { pool, ebr }
    }

    /// Retire a logically-deleted, physically-unlinked node; its slot
    /// returns to a free-list after the grace period (still carrying the
    /// valid+marked pattern, i.e. recoverable-as-free).
    #[inline]
    pub(crate) unsafe fn retire_node(&self, node: *mut LfNode) {
        self.ebr
            .retire(node as *mut u8, Arc::as_ptr(&self.pool) as usize, free_into_pool);
    }

    /// Unlink `curr` from the position `pred_link`, persisting the delete
    /// mark first (paper Listing 2 `trim`: a marked node must be durable
    /// as deleted *before* it becomes unreachable, else recovery would
    /// resurrect it).
    #[inline]
    unsafe fn trim(&self, pred_link: *const AtomicU64, curr: *mut LfNode) -> bool {
        (*curr).flush_delete();
        let succ = ptr_of::<LfNode>((*curr).next.load(Ordering::Acquire));
        (*pred_link)
            .compare_exchange(curr as u64, succ as u64, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Locate the first node with key >= `key` (paper Listing 2 `find`),
    /// trimming marked nodes on the way. Returns the link cell preceding
    /// `curr` and `curr` itself (null = end of list). Caller must hold an
    /// EBR guard.
    unsafe fn find(&self, head: *const AtomicU64, key: u64) -> (*const AtomicU64, *mut LfNode) {
        self.find_from(head, head, key)
    }

    /// `find` starting from a *hint* link cell (skip-list fast path). The
    /// hint must have been validated reachable under the current EBR
    /// guard; if the window goes stale, retries fall back to `head`.
    pub(crate) unsafe fn find_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
    ) -> (*const AtomicU64, *mut LfNode) {
        let mut from = start;
        'retry: loop {
            let mut pred_link = std::mem::replace(&mut from, head);
            let first = (*pred_link).load(Ordering::Acquire);
            // Hint staleness (TOCTOU): a hint marked after validation has
            // a frozen `next` that bypasses nodes inserted at its unlink
            // point — a remove could then wrongly report "absent" without
            // any CAS to catch it. Restart from the head.
            if !std::ptr::eq(pred_link, head) && is_marked(first) {
                continue 'retry;
            }
            let mut curr = ptr_of::<LfNode>(first);
            loop {
                if curr.is_null() {
                    return (pred_link, curr);
                }
                let succ_t = (*curr).next.load(Ordering::Acquire);
                if is_marked(succ_t) {
                    // Physically remove the logically-deleted node. On CAS
                    // failure the window is stale; restart (lock-free: the
                    // failure implies another thread made progress).
                    (*curr).flush_delete();
                    let succ = ptr_of::<LfNode>(succ_t);
                    if (*pred_link)
                        .compare_exchange(
                            curr as u64,
                            succ as u64,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_err()
                    {
                        continue 'retry;
                    }
                    curr = succ;
                } else {
                    if (*curr).key.load(Ordering::Relaxed) >= key {
                        return (pred_link, curr);
                    }
                    pred_link = &(*curr).next as *const AtomicU64;
                    curr = ptr_of::<LfNode>(succ_t);
                }
            }
        }
    }

    /// Paper Listing 4.
    pub fn insert(&self, head: *const AtomicU64, key: u64, value: u64) -> bool {
        self.insert_from(head, head, key, value)
    }

    /// Insert whose first window search starts at a validated hint link.
    pub(crate) fn insert_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
        value: u64,
    ) -> bool {
        let _g = self.ebr.pin();
        let mut new_node: *mut LfNode = std::ptr::null_mut();
        let mut from = start;
        loop {
            unsafe {
                let (pred_link, curr) = self.find_from(std::mem::replace(&mut from, head), head, key);
                if !curr.is_null() && (*curr).key.load(Ordering::Relaxed) == key {
                    // Help the (possibly still invalid) earlier insert of
                    // this key become durable before reporting failure —
                    // otherwise a crash could reflect this failed insert
                    // but not the insert that caused it (§3.3).
                    (*curr).make_valid();
                    (*curr).flush_insert();
                    if !new_node.is_null() {
                        LfNode::init_free_pattern(new_node as *mut u8);
                        self.pool.free(new_node as *mut u8);
                    }
                    return false;
                }
                if new_node.is_null() {
                    new_node = self.pool.alloc() as *mut LfNode;
                    // Invalid-before-init: a crash during initialisation
                    // must not let recovery see a half-written node.
                    (*new_node).make_invalid();
                    std::sync::atomic::fence(Ordering::Release);
                    (*new_node).reset_flush_flags();
                    // Release: a hint validator that reads THIS incarnation's
                    // key (Acquire) must also observe the allocator's gen
                    // bump, which happened-before this store on the owning
                    // thread (free and alloc share the per-thread free-list)
                    // — closes the reincarnated-key seqlock gap, DESIGN.md
                    // §Reclamation.
                    (*new_node).key.store(key, Ordering::Release);
                    (*new_node).value.store(value, Ordering::Relaxed);
                }
                // Link (still invalid!), then validate, then persist.
                // Release: in the same-key reincarnation schedule the only
                // word that distinguishes the new incarnation to a hint
                // validator is this unmarked `next` — reading it (Acquire)
                // must carry the allocator's gen bump to the validator's
                // closing gen check (DESIGN.md §Reclamation; the fence in
                // the init block above serves crash-recovery of validity,
                // not this ordering, so don't lean on it).
                (*new_node).next.store(curr as u64, Ordering::Release);
                if (*pred_link)
                    .compare_exchange(
                        curr as u64,
                        new_node as u64,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    (*new_node).make_valid();
                    (*new_node).flush_insert();
                    return true;
                }
            }
        }
    }

    /// Paper Listing 5.
    pub fn remove(&self, head: *const AtomicU64, key: u64) -> bool {
        self.remove_from(head, head, key)
    }

    /// Remove whose first window search starts at a validated hint link.
    pub(crate) fn remove_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
    ) -> bool {
        let _g = self.ebr.pin();
        let mut from = start;
        loop {
            unsafe {
                let (pred_link, curr) = self.find_from(std::mem::replace(&mut from, head), head, key);
                if curr.is_null() || (*curr).key.load(Ordering::Relaxed) != key {
                    return false;
                }
                let succ_t = (*curr).next.load(Ordering::Acquire);
                if is_marked(succ_t) {
                    // Lost the race to another remover; converge via find
                    // (which trims + persists the deletion) and fail there.
                    continue;
                }
                // Invariant: a marked node is valid. makeValid and the
                // marking CAS hit the same cache line, so no psync is
                // needed between them (Cohen et al. 2017; paper §3.4).
                (*curr).make_valid();
                if (*curr)
                    .next
                    .compare_exchange(succ_t, succ_t | MARK, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    // The mark is the durable delete record: the remover
                    // now owes a psync of this line before acking.
                    crate::pmem::check::note_store(curr as *const u8);
                    if !self.trim(pred_link, curr) {
                        // Someone else unlinked it (or our window went
                        // stale); find() guarantees no marked node with
                        // this key stays reachable.
                        let _ = self.find(head, key);
                    }
                    self.retire_node(curr);
                    return true;
                }
            }
        }
    }

    /// Paper Listing 3 (wait-free, Heller et al.-style traversal).
    pub fn get(&self, head: *const AtomicU64, key: u64) -> Option<u64> {
        self.get_from(head, head, key)
    }

    /// Wait-free read starting from a validated hint link (or the head).
    pub(crate) fn get_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
    ) -> Option<u64> {
        let _g = self.ebr.pin();
        unsafe {
            let mut from = start;
            // Same TOCTOU as find_from (reads have no CAS safety net).
            if !std::ptr::eq(start, head) && is_marked((*start).load(Ordering::Acquire)) {
                from = head;
            }
            let mut curr = ptr_of::<LfNode>((*from).load(Ordering::Acquire));
            while !curr.is_null() && (*curr).key.load(Ordering::Relaxed) < key {
                curr = ptr_of::<LfNode>((*curr).next.load(Ordering::Acquire));
            }
            if curr.is_null() || (*curr).key.load(Ordering::Relaxed) != key {
                return None;
            }
            if is_marked((*curr).next.load(Ordering::Acquire)) {
                // The answer "absent" is only durable once the delete is.
                (*curr).flush_delete();
                return None;
            }
            // The answer "present" is only durable once the insert is.
            (*curr).make_valid();
            (*curr).flush_insert();
            Some((*curr).value.load(Ordering::Relaxed))
        }
    }

    /// Unmarked-node count from one head (test/metrics only).
    pub fn count(&self, head: *const AtomicU64) -> usize {
        let _g = self.ebr.pin();
        let mut n = 0;
        unsafe {
            let mut curr = ptr_of::<LfNode>((*head).load(Ordering::Acquire));
            while !curr.is_null() {
                let succ_t = (*curr).next.load(Ordering::Acquire);
                if !is_marked(succ_t) {
                    n += 1;
                }
                curr = ptr_of::<LfNode>(succ_t);
            }
        }
        n
    }

    /// Flush-free ordered walk from a validated hint link (or `head`):
    /// visits every unmarked `(key, value)` with `key >= lo` in key
    /// order until `visit` returns false. Unlike [`LfCore::get_from`],
    /// the walk never helps-flushes: an ordered read reports membership
    /// with the same include-iff-unmarked rule as [`LfCore::snapshot`],
    /// and every *acked* update was already persisted by its issuer, so
    /// a scan of any length costs zero fences and zero flushes
    /// (NVTraverse: persistence work belongs at the destination, and a
    /// read has none). Caller must hold an EBR guard across the walk.
    pub(crate) unsafe fn walk_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        lo: u64,
        mut visit: impl FnMut(u64, u64) -> bool,
    ) {
        let mut from = start;
        // Same hint TOCTOU as get_from (no CAS safety net on a pure read).
        if !std::ptr::eq(start, head) && is_marked((*start).load(Ordering::Acquire)) {
            from = head;
        }
        let mut curr = ptr_of::<LfNode>((*from).load(Ordering::Acquire));
        while !curr.is_null() {
            let succ_t = (*curr).next.load(Ordering::Acquire);
            if !is_marked(succ_t) {
                let k = (*curr).key.load(Ordering::Relaxed);
                if k >= lo && !visit(k, (*curr).value.load(Ordering::Relaxed)) {
                    return;
                }
            }
            curr = ptr_of::<LfNode>(succ_t);
        }
    }

    /// Compaction: relocate every member node whose slot lies in
    /// `[lo, hi)` to a freshly allocated slot (the claimed area is off
    /// the allocation index, so the copy always lands elsewhere).
    ///
    /// Per node: durably copy first (`flush_insert` of the valid copy),
    /// then swing the predecessor link volatilely. A crash between the
    /// two leaves the original *and* the copy valid with the same key —
    /// recovery's dedup keeps one, so the acked member set is exact at
    /// every flush point. The original is **not** marked here: a reader
    /// parked at it mid-traversal must keep seeing the key as present
    /// (the copy carries it). Its durable delete record is written by
    /// [`LfCore::finish_migration`] after a grace period, once no reader
    /// can still be positioned on it. Returns the unlinked originals.
    ///
    /// # Safety
    /// Caller must serialize this against *updates* on the list (the
    /// shard worker's idle tick does); concurrent readers are safe.
    pub(crate) unsafe fn migrate_range(
        &self,
        head: *const AtomicU64,
        lo: usize,
        hi: usize,
    ) -> Vec<usize> {
        let mut originals = Vec::new();
        let mut pred_link = head;
        let mut curr = ptr_of::<LfNode>((*pred_link).load(Ordering::Acquire));
        while !curr.is_null() {
            let succ_t = (*curr).next.load(Ordering::Acquire);
            if is_marked(succ_t) {
                // With updates serialized out, every remove trimmed its
                // node before returning — a marked node mid-chain means
                // the serialization contract is broken. Stop cleanly.
                debug_assert!(false, "marked node under serialized migration");
                break;
            }
            let addr = curr as usize;
            if addr >= lo && addr < hi {
                let y = self.pool.alloc() as *mut LfNode;
                debug_assert!((y as usize) < lo || (y as usize) >= hi);
                (*y).make_invalid();
                std::sync::atomic::fence(Ordering::Release);
                (*y).reset_flush_flags();
                (*y).key.store((*curr).key.load(Ordering::Relaxed), Ordering::Release);
                (*y).value.store((*curr).value.load(Ordering::Relaxed), Ordering::Relaxed);
                (*y).next.store(succ_t, Ordering::Release);
                (*y).make_valid();
                (*y).flush_insert();
                (*pred_link).store(y as u64, Ordering::Release);
                originals.push(addr);
                pred_link = &(*y).next as *const AtomicU64;
            } else {
                pred_link = &(*curr).next as *const AtomicU64;
            }
            curr = ptr_of::<LfNode>(succ_t);
        }
        originals
    }

    /// Second migration step: the unlinked originals' durable delete
    /// records. Safe to call only after a full EBR grace period since
    /// [`LfCore::migrate_range`] unlinked them (no reader can still be
    /// positioned on one), under the same serialization contract. Each
    /// node is marked + `flush_delete`d (so a crash can no longer revive
    /// it as a duplicate) and retired; its slot frees after one more
    /// grace period.
    pub(crate) unsafe fn finish_migration(&self, originals: &[usize]) {
        for &addr in originals {
            let n = addr as *mut LfNode;
            let succ_t = (*n).next.load(Ordering::Acquire);
            debug_assert!(!is_marked(succ_t));
            (*n).next.store(succ_t | MARK, Ordering::Release);
            crate::pmem::check::note_store(n as *const u8);
            (*n).flush_delete();
            self.retire_node(n);
        }
    }

    /// Snapshot of unmarked (key, value) pairs from one head, in order
    /// (test/debug only; not linearizable under concurrency).
    pub fn snapshot(&self, head: *const AtomicU64) -> Vec<(u64, u64)> {
        let _g = self.ebr.pin();
        let mut out = Vec::new();
        unsafe {
            let mut curr = ptr_of::<LfNode>((*head).load(Ordering::Acquire));
            while !curr.is_null() {
                let succ_t = (*curr).next.load(Ordering::Acquire);
                if !is_marked(succ_t) {
                    out.push((
                        (*curr).key.load(Ordering::Relaxed),
                        (*curr).value.load(Ordering::Relaxed),
                    ));
                }
                curr = ptr_of::<LfNode>(succ_t);
            }
        }
        out
    }
}

/// The link-free sorted-list set.
pub struct LfList {
    pub(crate) head: AtomicU64,
    pub(crate) core: LfCore,
}

unsafe impl Send for LfList {}
unsafe impl Sync for LfList {}

impl LfList {
    pub fn new() -> Self {
        LfList { head: AtomicU64::new(0), core: LfCore::new() }
    }

    pub(crate) fn from_parts(head_value: u64, core: LfCore) -> Self {
        LfList { head: AtomicU64::new(head_value), core }
    }

    /// The durable pool id (names the areas; needed to recover after a
    /// crash — see [`super::recover_list`]).
    pub fn pool_id(&self) -> crate::pmem::PoolId {
        self.core.pool.id()
    }

    /// Prepare for a simulated crash: keep the durable regions alive when
    /// this (volatile) handle is dropped.
    pub fn crash_preserve(&self) {
        self.core.pool.preserve();
    }

    /// Ordered snapshot (test/debug).
    pub fn snapshot(&self) -> Vec<(u64, u64)> {
        self.core.snapshot(&self.head)
    }
}

impl Default for LfList {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for LfList {
    fn drop(&mut self) {
        // Flush deferred frees while the pool is still alive; after a
        // simulated crash the limbo lists are abandoned (recovery reclaims
        // the durable slots from the areas instead).
        unsafe { self.core.ebr.drain_all() };
    }
}

impl crate::sets::ConcurrentSet for LfList {
    fn insert(&self, key: u64, value: u64) -> bool {
        self.core.insert(&self.head, key, value)
    }
    fn remove(&self, key: u64) -> bool {
        self.core.remove(&self.head, key)
    }
    fn contains(&self, key: u64) -> bool {
        self.core.get(&self.head, key).is_some()
    }
    fn get(&self, key: u64) -> Option<u64> {
        self.core.get(&self.head, key)
    }
    fn len_approx(&self) -> usize {
        self.core.count(&self.head)
    }
    fn apply_batch(&self, ops: &[crate::sets::SetOp]) -> Vec<crate::sets::OpResult> {
        // Group commit: flush flags still elide redundant flushes per-op;
        // the batch issuer's fences collapse into one trailing fence.
        crate::sets::apply_batch_coalesced(self, ops)
    }
    fn durable_pool(&self) -> Option<crate::pmem::PoolId> {
        Some(self.pool_id())
    }
    fn prepare_crash(&self) {
        self.crash_preserve();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sets::ConcurrentSet;

    #[test]
    fn sequential_semantics() {
        let l = LfList::new();
        assert!(!l.contains(5));
        assert!(l.insert(5, 50));
        assert!(!l.insert(5, 51), "duplicate insert must fail");
        assert!(l.contains(5));
        assert_eq!(l.get(5), Some(50));
        assert!(l.insert(3, 30));
        assert!(l.insert(7, 70));
        assert_eq!(l.snapshot(), vec![(3, 30), (5, 50), (7, 70)]);
        assert!(l.remove(5));
        assert!(!l.remove(5), "double remove must fail");
        assert!(!l.contains(5));
        assert_eq!(l.snapshot(), vec![(3, 30), (7, 70)]);
        assert_eq!(l.len_approx(), 2);
    }

    #[test]
    fn reinsert_after_remove() {
        let l = LfList::new();
        for round in 0..5 {
            assert!(l.insert(1, round));
            assert_eq!(l.get(1), Some(round));
            assert!(l.remove(1));
        }
        assert!(!l.contains(1));
    }

    #[test]
    fn boundary_keys() {
        let l = LfList::new();
        assert!(l.insert(0, 1));
        assert!(l.insert(u64::MAX, 2));
        assert!(l.contains(0));
        assert!(l.contains(u64::MAX));
        assert!(l.remove(0));
        assert!(l.remove(u64::MAX));
        assert_eq!(l.len_approx(), 0);
    }

    #[test]
    fn matches_btreeset_model_random_ops() {
        use crate::util::rng::Xoshiro256;
        let l = LfList::new();
        let mut model = std::collections::BTreeSet::new();
        let mut rng = Xoshiro256::new(0xFEED);
        for _ in 0..20_000 {
            let k = rng.below(64);
            match rng.below(3) {
                0 => assert_eq!(l.insert(k, k), model.insert(k)),
                1 => assert_eq!(l.remove(k), model.remove(&k)),
                _ => assert_eq!(l.contains(k), model.contains(&k)),
            }
        }
        let snap: Vec<u64> = l.snapshot().iter().map(|kv| kv.0).collect();
        let want: Vec<u64> = model.iter().copied().collect();
        assert_eq!(snap, want);
    }

    #[test]
    fn concurrent_stripes_no_interference() {
        use std::sync::Arc;
        let l = Arc::new(LfList::new());
        let handles: Vec<_> = (0..4u64)
            .map(|t| {
                let l = l.clone();
                std::thread::spawn(move || {
                    // Each thread owns keys = t (mod 4).
                    for i in 0..500u64 {
                        let k = i * 4 + t;
                        assert!(l.insert(k, k));
                        assert!(l.contains(k));
                        if i % 2 == 0 {
                            assert!(l.remove(k));
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // 4 threads x 250 surviving odd-i keys.
        assert_eq!(l.len_approx(), 4 * 250);
        let snap = l.snapshot();
        for w in snap.windows(2) {
            assert!(w[0].0 < w[1].0, "list must stay strictly sorted");
        }
    }

    #[test]
    fn failed_ops_flush_bounds() {
        // Link-free failed ops: a failed insert *helps* the earlier insert
        // of the key become durable (§3.3) — the flush flag elides the
        // psync when it already is; a failed remove needs nothing.
        let l = LfList::new();
        for k in 0..8u64 {
            assert!(l.insert(k, k));
        }
        let a = crate::pmem::stats::thread_snapshot();
        assert!(!l.insert(3, 99), "duplicate insert fails");
        assert!(!l.remove(999), "absent remove fails");
        for k in 0..8u64 {
            assert!(l.contains(k));
        }
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 0, "failed ops over durable keys are psync-free");

        // Strip key 3's insert-flushed flag (as if its inserter has not
        // psync'd yet): the next failed insert must help-persist it.
        unsafe {
            use crate::sets::tagged::ptr_of;
            let mut curr = ptr_of::<LfNode>(l.head.load(Ordering::Acquire));
            while !curr.is_null() && (*curr).key.load(Ordering::Relaxed) != 3 {
                curr = ptr_of::<LfNode>((*curr).next.load(Ordering::Acquire));
            }
            assert!(!curr.is_null());
            (*curr).reset_flush_flags();
        }
        let a = crate::pmem::stats::thread_snapshot();
        assert!(!l.insert(3, 99));
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 1, "helping a not-yet-durable insert costs its psync");
        let a = crate::pmem::stats::thread_snapshot();
        assert!(!l.insert(3, 99));
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 0, "the helped psync is flag-elided afterwards");
    }

    #[test]
    fn contention_on_same_keys() {
        use std::sync::Arc;
        let l = Arc::new(LfList::new());
        let handles: Vec<_> = (0..8u64)
            .map(|t| {
                let l = l.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::util::rng::Xoshiro256::new(t);
                    let mut net = 0i64;
                    for _ in 0..3000 {
                        let k = rng.below(16);
                        if rng.below(2) == 0 {
                            if l.insert(k, t) {
                                net += 1;
                            }
                        } else if l.remove(k) {
                            net -= 1;
                        }
                    }
                    net
                })
            })
            .collect();
        let net: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(l.len_approx() as i64, net, "successful inserts - removes must equal size");
        let snap = l.snapshot();
        for w in snap.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }
}
