//! Link-free node (paper Listing 1) — exactly one cache line.

use crate::pmem;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

/// Validity bit 0 (`v1`) and bit 1 (`v2`): the node is *valid* iff the two
/// bits are equal (Definition B.3 uses "both equal initial value" /
/// "both flipped"; equality is the invariant the recovery tests).
const V1: u8 = 0b01;
const V2: u8 = 0b10;

/// Flush flags (paper §3: `insertFlushFlag`, `deleteFlushFlag`).
const INSERT_FLUSHED: u8 = 0b01;
const DELETE_FLUSHED: u8 = 0b10;

/// A durable link-free node: key, value, validity bits, flush flags and a
/// markable volatile `next` link, all within one 64-byte line so a single
/// psync persists the logical record (the `next` value itself is *never
/// relied upon* after a crash — only its mark bit is).
#[repr(C, align(64))]
pub struct LfNode {
    validity: AtomicU8,
    flush_flags: AtomicU8,
    _pad: [u8; 6],
    pub key: AtomicU64,
    pub value: AtomicU64,
    /// Tagged link: bit 0 = Harris deletion mark.
    pub next: AtomicU64,
}

const _: () = assert!(std::mem::size_of::<LfNode>() == 64);
// Bytes 56..64 of the slot are the allocator's generation word (see
// `alloc::area`): the node payload must stay clear of it.
const _: () = assert!(std::mem::offset_of!(LfNode, next) + 8 <= 56);

impl LfNode {
    /// Canonical *free* pattern: valid (bits equal) **and marked** — i.e.
    /// recoverable-as-deleted. Fresh areas are initialised to this and
    /// bulk-persisted, so recovery never misreads an unallocated slot as a
    /// member (a plain zeroed slot would read valid + unmarked + key 0).
    ///
    /// # Safety
    /// `slot` must point to a writable 64-byte slot.
    pub unsafe fn init_free_pattern(slot: *mut u8) {
        let n = &*(slot as *const LfNode);
        n.validity.store(0, Ordering::Relaxed);
        n.flush_flags.store(0, Ordering::Relaxed);
        n.key.store(0, Ordering::Relaxed);
        n.value.store(0, Ordering::Relaxed);
        n.next.store(super::super::tagged::MARK, Ordering::Relaxed);
    }

    /// Make the node invalid (`flipV1`, generalised: set v1 ≠ v2). Called
    /// only by the allocating thread before publication, so a plain store
    /// suffices. Idempotent on an already-invalid node.
    #[inline]
    pub fn make_invalid(&self) {
        let v = self.validity.load(Ordering::Relaxed);
        let v2 = (v & V2) != 0;
        let want = (if v2 { V2 } else { 0 }) | (if v2 { 0 } else { V1 });
        self.validity.store(want, Ordering::Relaxed);
        pmem::check::note_store(self as *const _ as *const u8);
    }

    /// `makeValid`: equate v2 to v1. Racy calls all store the same value.
    #[inline]
    pub fn make_valid(&self) {
        let v = self.validity.load(Ordering::Relaxed);
        let v1 = (v & V1) != 0;
        let want = (if v1 { V1 | V2 } else { 0 }) as u8;
        if v != want {
            self.validity.store(want, Ordering::Release);
            pmem::check::note_store(self as *const _ as *const u8);
        }
    }

    /// Valid ⇔ the two validity bits are equal.
    #[inline]
    pub fn is_valid(&self) -> bool {
        let v = self.validity.load(Ordering::Acquire);
        ((v & V1) != 0) == ((v & V2) != 0)
    }

    /// Reset both flush flags (reused slot about to be re-initialised).
    #[inline]
    pub fn reset_flush_flags(&self) {
        self.flush_flags.store(0, Ordering::Relaxed);
    }

    /// `FLUSH_INSERT` (paper §3.1): psync the node unless an
    /// insert-persist already happened — the flag elides redundant psyncs.
    #[inline]
    pub fn flush_insert(&self) {
        if self.flush_flags.load(Ordering::Acquire) & INSERT_FLUSHED == 0 {
            pmem::psync_obj(self);
            self.flush_flags.fetch_or(INSERT_FLUSHED, Ordering::Release);
        }
    }

    /// `FLUSH_DELETE`: psync the node unless its deletion was already
    /// persisted.
    #[inline]
    pub fn flush_delete(&self) {
        if self.flush_flags.load(Ordering::Acquire) & DELETE_FLUSHED == 0 {
            pmem::psync_obj(self);
            self.flush_flags.fetch_or(DELETE_FLUSHED, Ordering::Release);
        }
    }

    /// Raw 2-bit validity byte for bulk plane extraction (XLA-accelerated
    /// recovery; member ⇔ bit0 == bit1 and next unmarked).
    #[inline]
    pub fn raw_validity(&self) -> u8 {
        self.validity.load(Ordering::Relaxed) & 0b11
    }

    /// Arm the insert-flushed flag without a psync — recovery uses this
    /// for relinked members whose content is already durable.
    #[inline]
    pub fn set_insert_flushed(&self) {
        self.flush_flags.fetch_or(INSERT_FLUSHED, Ordering::Relaxed);
    }

    /// Recovery-side classification of a raw slot: is it a set member
    /// (valid and unmarked)?
    #[inline]
    pub fn is_member(&self) -> bool {
        self.is_valid() && !super::super::tagged::is_marked(self.next.load(Ordering::Acquire))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh() -> Box<LfNode> {
        let mut b: Box<std::mem::MaybeUninit<LfNode>> = Box::new(std::mem::MaybeUninit::uninit());
        unsafe {
            LfNode::init_free_pattern(b.as_mut_ptr() as *mut u8);
            std::mem::transmute(b)
        }
    }

    #[test]
    fn free_pattern_is_valid_and_marked() {
        let n = fresh();
        assert!(n.is_valid());
        assert!(!n.is_member(), "free slot must not classify as member");
    }

    #[test]
    fn validity_lifecycle() {
        let n = fresh();
        assert!(n.is_valid());
        n.make_invalid();
        assert!(!n.is_valid());
        n.make_invalid(); // idempotent
        assert!(!n.is_valid());
        n.make_valid();
        assert!(n.is_valid());
        n.make_valid(); // idempotent
        assert!(n.is_valid());
        // next cycle (slot reuse) keeps working
        n.make_invalid();
        assert!(!n.is_valid());
        n.make_valid();
        assert!(n.is_valid());
    }

    #[test]
    fn flush_flags_elide_second_psync() {
        let n = fresh();
        n.reset_flush_flags();
        let a = crate::pmem::stats::thread_snapshot();
        n.flush_insert();
        n.flush_insert();
        n.flush_insert();
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 1, "only the first FLUSH_INSERT may psync");
        let a = crate::pmem::stats::thread_snapshot();
        n.flush_delete();
        n.flush_delete();
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 1, "only the first FLUSH_DELETE may psync");
    }

    #[test]
    fn member_iff_valid_and_unmarked() {
        let n = fresh();
        n.next.store(0, Ordering::Relaxed); // unmarked null
        assert!(n.is_member()); // valid + unmarked
        n.make_invalid();
        assert!(!n.is_member());
        n.make_valid();
        assert!(n.is_member());
        n.next.store(crate::sets::tagged::MARK, Ordering::Relaxed);
        assert!(!n.is_member());
    }
}
