//! Link-free durable **skip list** — the paper's §3 extension
//! ("Extending this algorithm to a skip list is straightforward").
//!
//! The paper's core idea applies directly: *only the bottom-level nodes
//! are durable* (the same one-cache-line [`LfNode`]s, same validity
//! scheme, same one-psync updates); every index level is pure volatile
//! acceleration and is rebuilt from scratch by recovery — which is why
//! the recovered structure "may have a different structure from the one
//! prior to the crash" (paper §2.1, noting randomized skip lists
//! explicitly).
//!
//! Index design: towers are volatile hint records pointing at durable
//! nodes, published as a `(node, gen)` pair — `gen` is the slot's
//! allocation generation at tower-build time (see `alloc::area`). A
//! search walks the tower levels to find the closest durable node with
//! key < target and validates it *under the EBR pin*: generation first
//! (a mismatch proves the slot was reclaimed and possibly reused since
//! the tower was built — the old key/mark heuristic only made that
//! misread unlikely), then key + mark, then generation again (seqlock
//! close; see DESIGN.md §Reclamation). A validated node is linked at its
//! key's position, so the bottom-level Harris `find` starts from its
//! link cell; any later staleness detected by CAS failure falls back to
//! the full head scan (`LfCore::*_from`). Stale towers (reclaimed,
//! marked or recycled targets) are unlinked lazily during traversal.

use crate::alloc::Ebr;
use crate::pmem::PoolId;
use crate::sets::tagged::{gen_validated, is_marked, ptr_of};
use crate::sets::RangeQuery;
use crate::util::rng::Xoshiro256;
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::list::LfCore;
use super::node::LfNode;
use super::recovery::RecoveredStats;

const MAX_LEVEL: usize = 16; // enough for ~4^16 keys at p = 1/4
const BRANCHING: u64 = 4;

/// A volatile index tower: a hint that `node` (with `key`) is (or was) a
/// member. Towers are immortal for the structure's lifetime (they are
/// tiny, allocation is rare at p=1/4, and immortality sidesteps index
/// reclamation races); stale towers are unlinked from the index lazily
/// but their memory is only reclaimed when the skip list drops.
struct Tower {
    key: u64,
    node: *mut LfNode,
    /// `node`'s slot generation when the tower was built: the target was
    /// linked then, so a later mismatch proves it was reclaimed.
    gen: u64,
    /// nexts[l] = tagged pointer to the next Tower at level l.
    nexts: [AtomicU64; MAX_LEVEL],
}

/// Current allocation generation of a durable node's slot.
#[inline(always)]
unsafe fn node_gen(node: *const LfNode) -> u64 {
    crate::alloc::slot_gen(node as *const u8, crate::util::CACHE_LINE).load(Ordering::Acquire)
}

/// Is the tower's `(node, gen)` target stale? The shared seqlock
/// protocol [`gen_validated`] (gen, then key + mark, then gen again):
/// with a stable matching gen the key/mark reads are certainly about the
/// incarnation the tower indexed. The Acquire key load pairs with the
/// Release key store at node init, so reading a reincarnation's key
/// makes the allocator's gen bump visible to the closing gen check.
#[inline]
unsafe fn tower_stale(t: *const Tower) -> bool {
    let node = (*t).node;
    gen_validated(
        || unsafe { node_gen(node) },
        (*t).gen,
        || unsafe {
            ((*node).key.load(Ordering::Acquire) == (*t).key
                && !is_marked((*node).next.load(Ordering::Acquire)))
            .then_some(())
        },
    )
    .is_none()
}

/// Durable lock-free skip list (link-free family).
pub struct LfSkipList {
    head: AtomicU64,
    /// Index head: nexts of a conceptual -∞ tower.
    index: [AtomicU64; MAX_LEVEL],
    core: LfCore,
    /// All towers ever allocated (reclaimed on drop).
    graveyard: UnsafeCell<Vec<*mut Tower>>,
    grave_lock: std::sync::Mutex<()>,
}

unsafe impl Send for LfSkipList {}
unsafe impl Sync for LfSkipList {}

impl LfSkipList {
    pub fn new() -> Self {
        Self::from_core(LfCore::new())
    }

    fn from_core(core: LfCore) -> Self {
        const Z: AtomicU64 = AtomicU64::new(0);
        LfSkipList {
            head: AtomicU64::new(0),
            index: [Z; MAX_LEVEL],
            core,
            graveyard: UnsafeCell::new(Vec::new()),
            grave_lock: std::sync::Mutex::new(()),
        }
    }

    pub fn pool_id(&self) -> PoolId {
        self.core.pool.id()
    }

    pub fn crash_preserve(&self) {
        self.core.pool.preserve();
    }

    /// Random tower height: level i with probability (1/BRANCHING)^i.
    fn random_height(key: u64) -> usize {
        // Deterministic in the key + a salt: rebuildable and test-friendly.
        let mut h = 1;
        let mut r = Xoshiro256::new(key ^ 0x5C1A_1157);
        while h < MAX_LEVEL && r.below(BRANCHING) == 0 {
            h += 1;
        }
        h
    }

    /// Walk the index; returns the best validated durable hint link for
    /// `key` (a link cell whose owner had key < `key` and was unmarked at
    /// observation time) — or the list head. Must run under an EBR pin.
    unsafe fn hint_link(&self, key: u64) -> *const AtomicU64 {
        let mut best: *const AtomicU64 = &self.head;
        let mut best_key = 0u64;
        let mut level = MAX_LEVEL;
        let mut pred_nexts: &[AtomicU64; MAX_LEVEL] = &self.index;
        while level > 0 {
            level -= 1;
            loop {
                let t_tag = pred_nexts[level].load(Ordering::Acquire);
                let t = ptr_of::<Tower>(t_tag);
                if t.is_null() {
                    break;
                }
                // Validate the tower's (node, gen) target.
                let node = (*t).node;
                if tower_stale(t) {
                    // Lazily unlink the dead tower at this level.
                    let succ = (*t).nexts[level].load(Ordering::Acquire) & !1;
                    let _ = pred_nexts[level].compare_exchange(
                        t_tag,
                        succ,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    );
                    continue;
                }
                if (*t).key >= key {
                    break;
                }
                // Unmarked at observation + EBR pin => reachable now; a
                // later marking only costs us a head fallback in the core.
                if (*t).key > best_key || best == &self.head as *const _ {
                    best = &(*node).next as *const AtomicU64;
                    best_key = (*t).key;
                }
                pred_nexts = &(*t).nexts;
            }
        }
        best
    }

    /// Link a new tower for (key, node) at a random height. `node` was
    /// observed linked under the caller's pin, so its slot generation
    /// read here names exactly that incarnation.
    unsafe fn index_insert(&self, key: u64, node: *mut LfNode) {
        let height = Self::random_height(key);
        if height <= 1 {
            return; // ~3/4 of keys get no tower at BRANCHING=4
        }
        const Z: AtomicU64 = AtomicU64::new(0);
        let tower = Box::into_raw(Box::new(Tower {
            key,
            node,
            gen: node_gen(node),
            nexts: [Z; MAX_LEVEL],
        }));
        {
            let _g = self.grave_lock.lock().unwrap();
            (*self.graveyard.get()).push(tower);
        }
        // Insert bottom-up at each level with CAS; losing a race just
        // retries at that level (towers are hints; order only needs to be
        // sorted per level, duplicates by key are tolerated and lazily
        // cleaned when stale).
        for level in 0..height {
            loop {
                // Find pred/succ at this level.
                let (pred_nexts, succ_tag) = self.index_window(key, level);
                (*tower).nexts[level].store(succ_tag & !1, Ordering::Release);
                if pred_nexts[level]
                    .compare_exchange(succ_tag, tower as u64, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    break;
                }
            }
        }
    }

    /// (pred.nexts, observed tagged successor) for `key` at `level`.
    unsafe fn index_window(
        &self,
        key: u64,
        level: usize,
    ) -> (&[AtomicU64; MAX_LEVEL], u64) {
        let mut pred_nexts: &[AtomicU64; MAX_LEVEL] = &self.index;
        loop {
            let t_tag = pred_nexts[level].load(Ordering::Acquire);
            let t = ptr_of::<Tower>(t_tag);
            if t.is_null() || (*t).key >= key {
                return (pred_nexts, t_tag);
            }
            pred_nexts = &(*t).nexts;
        }
    }

    pub fn snapshot(&self) -> Vec<(u64, u64)> {
        self.core.snapshot(&self.head)
    }
}

impl Default for LfSkipList {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for LfSkipList {
    fn drop(&mut self) {
        unsafe {
            self.core.ebr.drain_all();
            for &t in (*self.graveyard.get()).iter() {
                drop(Box::from_raw(t));
            }
        }
    }
}

impl crate::sets::ConcurrentSet for LfSkipList {
    fn insert(&self, key: u64, value: u64) -> bool {
        let g = self.core.ebr.pin();
        let start = unsafe { self.hint_link(key) };
        let inserted = self.core.insert_from(start, &self.head, key, value);
        if inserted {
            // Find the node we just linked to index it. A concurrent
            // remove may already have unlinked it; then the tower is
            // immediately stale and harmless.
            unsafe {
                let (_, curr) = self.core.find_from(start, &self.head, key);
                if !curr.is_null() && (*curr).key.load(Ordering::Relaxed) == key {
                    self.index_insert(key, curr);
                }
            }
        }
        drop(g);
        inserted
    }

    fn remove(&self, key: u64) -> bool {
        let g = self.core.ebr.pin();
        let start = unsafe { self.hint_link(key) };
        let r = self.core.remove_from(start, &self.head, key);
        drop(g);
        r
    }

    fn contains(&self, key: u64) -> bool {
        self.get(key).is_some()
    }

    fn get(&self, key: u64) -> Option<u64> {
        let g = self.core.ebr.pin();
        let start = unsafe { self.hint_link(key) };
        let r = self.core.get_from(start, &self.head, key);
        drop(g);
        r
    }

    fn len_approx(&self) -> usize {
        self.core.count(&self.head)
    }

    /// Coalesced membership burst: one EBR pin for the whole run, probes
    /// issued in sorted key order so consecutive tower descents walk
    /// warm index nodes (mirrors the `ResizableHash` override).
    fn contains_batch(&self, keys: &[u64]) -> Vec<bool> {
        let mut out = vec![false; keys.len()];
        let mut order: Vec<usize> = (0..keys.len()).collect();
        order.sort_unstable_by_key(|&i| keys[i]);
        let g = self.core.ebr.pin();
        for &i in &order {
            let start = unsafe { self.hint_link(keys[i]) };
            out[i] = self.core.get_from(start, &self.head, keys[i]).is_some();
        }
        drop(g);
        out
    }

    /// Coalesced lookup burst; see [`LfSkipList::contains_batch`].
    fn get_batch(&self, keys: &[u64]) -> Vec<Option<u64>> {
        let mut out = vec![None; keys.len()];
        let mut order: Vec<usize> = (0..keys.len()).collect();
        order.sort_unstable_by_key(|&i| keys[i]);
        let g = self.core.ebr.pin();
        for &i in &order {
            let start = unsafe { self.hint_link(keys[i]) };
            out[i] = self.core.get_from(start, &self.head, keys[i]);
        }
        drop(g);
        out
    }

    fn apply_batch(&self, ops: &[crate::sets::SetOp]) -> Vec<crate::sets::OpResult> {
        crate::sets::apply_batch_coalesced(self, ops)
    }

    fn as_ordered(&self) -> Option<&dyn crate::sets::OrderedSet> {
        Some(self)
    }

    fn durable_pool(&self) -> Option<PoolId> {
        Some(self.pool_id())
    }

    fn prepare_crash(&self) {
        self.crash_preserve();
    }
}

impl crate::sets::OrderedSet for LfSkipList {
    fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        if lo > hi {
            return out;
        }
        let g = self.core.ebr.pin();
        unsafe {
            let start = self.hint_link(lo);
            self.core.walk_from(start, &self.head, lo, |k, v| {
                if k > hi {
                    return false;
                }
                out.push((k, v));
                true
            });
        }
        drop(g);
        out
    }

    fn scan(&self, cursor: u64, n: usize) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        if n == 0 || cursor == u64::MAX {
            return out;
        }
        let lo = cursor + 1;
        let g = self.core.ebr.pin();
        unsafe {
            let start = self.hint_link(lo);
            self.core.walk_from(start, &self.head, lo, |k, v| {
                out.push((k, v));
                out.len() < n
            });
        }
        drop(g);
        out
    }

    /// The merge-walk: serve a whole ordered burst with **one** EBR pin,
    /// **one** tower descent (to the smallest window's `lo`) and a
    /// single forward pass of the bottom level. Windows are retired
    /// front-to-back in `lo` order; each visited key is offered to every
    /// window that could still contain it, so overlapping queries each
    /// collect it independently. Per-query output stays key-sorted
    /// because the bottom level is.
    fn range_batch(&self, queries: &[RangeQuery]) -> Vec<Vec<(u64, u64)>> {
        let mut results: Vec<Vec<(u64, u64)>> = vec![Vec::new(); queries.len()];
        let mut order: Vec<usize> = (0..queries.len())
            .filter(|&i| !matches!(queries[i], RangeQuery::Scan(u64::MAX, _) | RangeQuery::Scan(_, 0)))
            .collect();
        order.sort_unstable_by_key(|&i| queries[i].lo());
        if order.is_empty() {
            return results;
        }
        let min_lo = queries[order[0]].lo();
        let g = self.core.ebr.pin();
        unsafe {
            let start = self.hint_link(min_lo);
            // `front` = first window (in lo order) not yet retired.
            let mut front = 0usize;
            self.core.walk_from(start, &self.head, min_lo, |k, v| {
                while front < order.len() {
                    let qi = order[front];
                    if queries[qi].done(k, results[qi].len()) {
                        front += 1;
                    } else {
                        break;
                    }
                }
                if front >= order.len() {
                    return false; // every window retired: stop walking
                }
                for &qi in &order[front..] {
                    let q = &queries[qi];
                    if q.starts_after(k) {
                        break; // sorted by lo: no later window holds k
                    }
                    if q.accepts(k, results[qi].len()) {
                        results[qi].push((k, v));
                    }
                }
                true
            });
        }
        drop(g);
        results
    }
}

/// Recover a link-free skip list: the bottom durable level is rebuilt by
/// the standard link-free scan (zero psyncs); the index is reconstructed
/// from the recovered members — randomized afresh, exactly as §2.1
/// anticipates for skip lists.
pub fn recover_skiplist(id: PoolId) -> (LfSkipList, RecoveredStats) {
    let (s, stats, _) = recover_skiplist_timed(id, crate::sets::recovery::default_threads());
    (s, stats)
}

/// [`recover_skiplist`] with an explicit recovery worker count: the scan +
/// chain relink parallelise through the engine, and the tower index is
/// rebuilt across the same worker budget
/// ([`crate::sets::recovery::par_index_rebuild`] — CAS-based
/// `index_insert` with key-deterministic heights, so any interleaving
/// yields the same towers, with zero psyncs).
pub fn recover_skiplist_timed(
    id: PoolId,
    threads: usize,
) -> (LfSkipList, RecoveredStats, crate::sets::recovery::PhaseTimings) {
    let (list, stats, timings) = super::recover_list_timed(id, threads);
    // Steal the recovered chain + core into a skip list shell.
    let head_val = list.head.load(Ordering::Relaxed);
    let core = LfCore::from_parts(list.core.pool.clone(), Arc::new(Ebr::new()));
    // Dropping the intermediate list is safe: the pool Arc is shared (so
    // its regions survive) and the recovered list's EBR limbo is empty.
    drop(list);
    let skip = LfSkipList::from_core(core);
    skip.head.store(head_val, Ordering::Relaxed);
    // One cheap sequential pass collects (key, node) off the sorted
    // chain; the tower CASes — the actual O(n log n) work — fan out.
    let mut pairs: Vec<(u64, usize)> = Vec::new();
    unsafe {
        let mut curr = ptr_of::<LfNode>(head_val);
        while !curr.is_null() {
            pairs.push(((*curr).key.load(Ordering::Relaxed), curr as usize));
            curr = ptr_of::<LfNode>((*curr).next.load(Ordering::Relaxed));
        }
    }
    crate::sets::recovery::par_index_rebuild(&pairs, threads, |key, node| unsafe {
        skip.index_insert(key, node as *mut LfNode)
    });
    (skip, stats, timings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{self, CrashPolicy};
    use crate::sets::ConcurrentSet;

    #[test]
    fn sequential_semantics() {
        let s = LfSkipList::new();
        for k in (0..2000u64).rev() {
            assert!(s.insert(k, k * 3));
        }
        assert!(!s.insert(77, 0));
        for k in 0..2000u64 {
            assert_eq!(s.get(k), Some(k * 3));
        }
        for k in (0..2000u64).step_by(2) {
            assert!(s.remove(k));
        }
        assert_eq!(s.len_approx(), 1000);
        assert!(!s.contains(0));
        assert!(s.contains(1));
        let snap = s.snapshot();
        for w in snap.windows(2) {
            assert!(w[0].0 < w[1].0, "bottom level must stay sorted");
        }
    }

    #[test]
    fn index_actually_accelerates() {
        // Not a wall-clock test (flaky on shared CPUs): verify the hint
        // lands near the key, i.e. strictly past the head for far keys.
        let s = LfSkipList::new();
        for k in 0..10_000u64 {
            s.insert(k, k);
        }
        let _g = s.core.ebr.pin();
        let hint = unsafe { s.hint_link(9_999) };
        assert!(
            !std::ptr::eq(hint, &s.head),
            "hint for the largest key should come from the index"
        );
    }

    /// Deterministic tower-ABA replay: a tower whose target slot went
    /// through free→alloc with the *same key* re-fabricated passes the
    /// old key+mark heuristic (the classic ABA) but must be rejected by
    /// the generation tag. `--features untagged-hints` demonstrably
    /// accepts it.
    #[test]
    fn stale_tower_to_reallocated_slot_is_rejected_by_generation() {
        // A key whose deterministic tower height is >= 2 (so the index
        // actually holds a tower for it).
        let key = (0..10_000u64)
            .find(|&k| LfSkipList::random_height(k) >= 2)
            .unwrap();
        let s = LfSkipList::new();
        assert!(s.insert(key, 1));
        assert!(s.remove(key));
        unsafe { s.core.ebr.drain_all() }; // slot freed, gen bumped

        // Reincarnate the same slot with the same key, unmarked + valid —
        // exactly what a concurrent re-insert mid-flight can present.
        let slot = s.core.pool.alloc() as *mut LfNode;
        unsafe {
            (*slot).key.store(key, Ordering::Relaxed);
            (*slot).value.store(2, Ordering::Relaxed);
            (*slot).next.store(0, Ordering::Relaxed);
            (*slot).make_valid();
        }

        {
            let _g = s.core.ebr.pin();
            let hint = unsafe { s.hint_link(key + 1) };
            if cfg!(feature = "untagged-hints") {
                assert!(
                    std::ptr::eq(hint, unsafe { &(*slot).next } as *const AtomicU64),
                    "untagged tower validation accepts the reincarnated slot (the ABA hazard)"
                );
            } else {
                assert!(
                    std::ptr::eq(hint, &s.head),
                    "generation mismatch must make the tower stale"
                );
            }
        }

        unsafe { LfNode::init_free_pattern(slot as *mut u8) };
        s.core.pool.free(slot as *mut u8);
    }

    #[test]
    fn model_equivalence_random_ops() {
        use crate::util::rng::Xoshiro256;
        let s = LfSkipList::new();
        let mut model = std::collections::BTreeSet::new();
        let mut rng = Xoshiro256::new(0x5C1F);
        for _ in 0..30_000 {
            let k = rng.below(512);
            match rng.below(3) {
                0 => assert_eq!(s.insert(k, k), model.insert(k)),
                1 => assert_eq!(s.remove(k), model.remove(&k)),
                _ => assert_eq!(s.contains(k), model.contains(&k)),
            }
        }
        let snap: Vec<u64> = s.snapshot().iter().map(|kv| kv.0).collect();
        let want: Vec<u64> = model.iter().copied().collect();
        assert_eq!(snap, want);
    }

    #[test]
    fn concurrent_stress() {
        use std::sync::Arc;
        let s = Arc::new(LfSkipList::new());
        let handles: Vec<_> = (0..6u64)
            .map(|t| {
                let s = s.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::util::rng::Xoshiro256::new(t + 31);
                    let mut net = 0i64;
                    for _ in 0..4000 {
                        let k = rng.below(256);
                        match rng.below(3) {
                            0 => {
                                if s.insert(k, t) {
                                    net += 1;
                                }
                            }
                            1 => {
                                if s.remove(k) {
                                    net -= 1;
                                }
                            }
                            _ => {
                                let _ = s.contains(k);
                            }
                        }
                    }
                    net
                })
            })
            .collect();
        let net: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(s.len_approx() as i64, net);
        let snap = s.snapshot();
        for w in snap.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn merge_walk_matches_singles_and_stays_psync_free() {
        use crate::sets::OrderedSet;
        use crate::pmem::stats;
        let s = LfSkipList::new();
        for k in (0..4000u64).step_by(2) {
            assert!(s.insert(k, k + 1));
        }
        let queries = [
            RangeQuery::Range(100, 160),
            RangeQuery::Range(150, 150),
            RangeQuery::Scan(99, 7),
            RangeQuery::Range(3990, 5000),
            RangeQuery::Scan(u64::MAX, 4),
            RangeQuery::Scan(500, 0),
            RangeQuery::Range(9, 3),
        ];
        let singles: Vec<Vec<(u64, u64)>> = queries
            .iter()
            .map(|q| match *q {
                RangeQuery::Range(lo, hi) => s.range(lo, hi),
                RangeQuery::Scan(c, n) => s.scan(c, n),
            })
            .collect();
        let before = stats::thread_snapshot();
        let merged = s.range_batch(&queries);
        let d = stats::thread_snapshot().since(&before);
        assert_eq!(merged, singles, "merge-walk must equal per-query results");
        assert_eq!(
            merged[0],
            (100..=160).step_by(2).map(|k| (k, k + 1)).collect::<Vec<_>>()
        );
        assert_eq!(merged[1], vec![(150, 151)]);
        assert_eq!(
            merged[2],
            (100..114).step_by(2).map(|k| (k, k + 1)).collect::<Vec<_>>()
        );
        assert_eq!(merged[3], vec![(3990, 3991), (3992, 3993), (3994, 3995), (3996, 3997), (3998, 3999)]);
        assert!(merged[4].is_empty() && merged[5].is_empty() && merged[6].is_empty());
        assert_eq!((d.fences, d.flushes), (0, 0), "ordered reads must be psync-free");
    }

    #[test]
    fn scan_sees_concurrent_membership_consistently() {
        use crate::sets::OrderedSet;
        use std::sync::Arc;
        let s = Arc::new(LfSkipList::new());
        for k in 0..512u64 {
            s.insert(k, k);
        }
        let stop = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let churn = {
            let (s, stop) = (s.clone(), stop.clone());
            std::thread::spawn(move || {
                let mut rng = crate::util::rng::Xoshiro256::new(77);
                while stop.load(Ordering::Relaxed) == 0 {
                    let k = rng.below(512);
                    if rng.below(2) == 0 {
                        s.insert(k, k);
                    } else {
                        s.remove(k);
                    }
                }
            })
        };
        for _ in 0..2000 {
            let out = s.range(100, 200);
            // Sorted, deduplicated, in-window: the walk never yields a
            // torn view of the bottom level.
            for w in out.windows(2) {
                assert!(w[0].0 < w[1].0);
            }
            assert!(out.iter().all(|&(k, v)| (100..=200).contains(&k) && v == k));
        }
        stop.store(1, Ordering::Relaxed);
        churn.join().unwrap();
    }

    #[test]
    fn batched_point_reads_match_singles() {
        let s = LfSkipList::new();
        for k in (0..1000u64).step_by(3) {
            s.insert(k, k * 7);
        }
        let keys: Vec<u64> = vec![999, 0, 3, 500, 501, 3, 702, 1];
        assert_eq!(
            s.contains_batch(&keys),
            keys.iter().map(|&k| s.contains(k)).collect::<Vec<_>>()
        );
        assert_eq!(
            s.get_batch(&keys),
            keys.iter().map(|&k| s.get(k)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn scan_after_crash_recovery_matches_survivors() {
        use crate::sets::OrderedSet;
        let _sim = pmem::sim_session();
        let s = LfSkipList::new();
        let id = s.pool_id();
        for k in 0..500u64 {
            assert!(s.insert(k, k + 5));
        }
        for k in (0..500u64).step_by(3) {
            assert!(s.remove(k));
        }
        s.crash_preserve();
        drop(s);
        pmem::crash_pools(CrashPolicy::random(0.4, 22), &[id]);
        let (s2, _) = recover_skiplist(id);
        let survivors: Vec<(u64, u64)> =
            (0..500u64).filter(|k| k % 3 != 0).map(|k| (k, k + 5)).collect();
        assert_eq!(s2.range(0, u64::MAX), survivors, "recovered range scan");
        // Cursor paging over the recovered structure stitches back the
        // same ordered view.
        let mut paged = Vec::new();
        let mut cursor = 0u64; // survivors all have key > 0 (0 % 3 == 0 was removed)
        loop {
            let page = s2.scan(cursor, 64);
            if page.is_empty() {
                break;
            }
            cursor = page.last().unwrap().0;
            paged.extend(page);
        }
        assert_eq!(paged, survivors, "recovered cursor scan");
    }

    #[test]
    fn skiplist_crash_recovery() {
        let _sim = pmem::sim_session();
        let s = LfSkipList::new();
        let id = s.pool_id();
        for k in 0..500u64 {
            assert!(s.insert(k, k + 5));
        }
        for k in (0..500u64).step_by(3) {
            assert!(s.remove(k));
        }
        s.crash_preserve();
        drop(s);
        pmem::crash_pools(CrashPolicy::random(0.4, 21), &[id]);
        let (s2, stats) = recover_skiplist(id);
        assert_eq!(stats.members as usize, (0..500).filter(|k| k % 3 != 0).count());
        for k in 0..500u64 {
            if k % 3 == 0 {
                assert!(!s2.contains(k), "removed {k} resurrected");
            } else {
                assert_eq!(s2.get(k), Some(k + 5), "{k} lost");
            }
        }
        // Index works post-recovery and the structure is writable.
        assert!(s2.insert(10_000, 1));
        assert!(s2.remove(1));
    }
}
