//! The **link-free** durable sets (paper §3).
//!
//! The core idea of the paper: never persist links. Only node *content*
//! (key, value, validity) is written back to NVRAM; the linked structure
//! exists purely in volatile memory and is rebuilt by recovery from the
//! durable areas. A two-bit validity scheme distinguishes half-initialised
//! nodes from members, and two flush flags elide redundant psyncs
//! (the paper's extension of link-and-persist).

mod hash;
mod skiplist;
pub(crate) mod list;
mod node;
mod recovery;

pub(crate) use list::LfCore;

pub use hash::LfHash;
pub use list::LfList;
pub use node::LfNode;
// The accelerated recovery path reuses the family's relink rule.
#[cfg(feature = "accel")]
pub(crate) use recovery::LfClassify;
pub use recovery::{
    recover_hash, recover_hash_timed, recover_list, recover_list_timed, RecoveredStats,
};
pub use skiplist::{recover_skiplist, recover_skiplist_timed, LfSkipList};
