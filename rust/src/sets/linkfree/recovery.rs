//! Link-free recovery (paper §3.5).
//!
//! After a crash the durable areas hold every slot the structure ever
//! allocated. Classification is the validity scheme: **valid & unmarked ⇒
//! member**; everything else (invalid = interrupted insert, valid+marked =
//! deleted or never-used) is reclaimed. Members are relinked — reusing the
//! very same durable slots — into a fresh volatile structure with **zero
//! psyncs** (all member content is already durable). Reclaimed slots are
//! normalised back to the canonical free pattern and the areas are
//! persisted once in bulk, so a second crash cannot resurrect ghosts.
//!
//! The slot's trailing generation word (`alloc::area::slot_gen`) is
//! allocator metadata for hint/tower ABA validation: classification never
//! reads it (it is not validity or key bits), normalisation never writes
//! it, and it needs no restoration step — it survives in the adopted
//! regions and `free` re-bumps it for every reclaimed slot.

use crate::alloc::{DurablePool, Ebr};
use crate::pmem::PoolId;
use crate::sets::tagged::MARK;
use crate::util::mix64;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::list::{LfCore, LfList};
use super::node::LfNode;
use super::LfHash;

/// What recovery found in the durable areas.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveredStats {
    /// Slots relinked as set members.
    pub members: usize,
    /// Slots reclaimed to free-lists (never-used, deleted, or interrupted
    /// inserts — the paper's "memory leaks fixed by the validity scheme").
    pub reclaimed: usize,
}

/// Scan the pool and classify every slot. Returns member pointers (with
/// key) and frees/normalises the rest. Shared by list and hash recovery.
fn scan(pool: &DurablePool) -> (Vec<(u64, *mut LfNode)>, RecoveredStats) {
    let mut members: Vec<(u64, *mut LfNode)> = Vec::new();
    let mut stats = RecoveredStats::default();
    for slot in pool.iter_slots() {
        let node = slot as *mut LfNode;
        unsafe {
            if (*node).is_member() {
                members.push(((*node).key.load(Ordering::Relaxed), node));
                stats.members += 1;
            } else {
                // Invalid or deleted: normalise to the free pattern so a
                // later crash still classifies it as free, then reuse.
                pool.normalize_slot(slot);
                pool.free(slot);
                stats.reclaimed += 1;
            }
        }
    }
    // The persistent list must be a set (Claim B.12); a duplicate would
    // mean a validity-scheme violation.
    let mut keys: Vec<u64> = members.iter().map(|m| m.0).collect();
    keys.sort_unstable();
    keys.dedup();
    assert_eq!(keys.len(), members.len(), "duplicate keys in durable image");
    (members, stats)
}

/// Relink a sorted run of member nodes into a chain below `head_out`;
/// returns the head link value. No psyncs: membership is already durable,
/// and links are volatile by design.
unsafe fn relink_chain(members: &[(u64, *mut LfNode)]) -> u64 {
    let mut next_val = 0u64; // null, unmarked
    for &(_, node) in members.iter().rev() {
        (*node).next.store(next_val, Ordering::Relaxed);
        // Content is durable: arm the insert-flush flag so post-recovery
        // reads don't re-psync, and clear the delete flag.
        (*node).reset_flush_flags();
        (*node).set_insert_flushed();
        next_val = node as u64;
        debug_assert_eq!(next_val & MARK, 0);
    }
    next_val
}

/// Rebuild a link-free list from the durable areas of `id`.
pub fn recover_list(id: PoolId) -> (LfList, RecoveredStats) {
    let pool = Arc::new(DurablePool::adopt(id, 64, LfNode::init_free_pattern));
    let (mut members, stats) = scan(&pool);
    members.sort_unstable_by_key(|m| m.0);
    let head = unsafe { relink_chain(&members) };
    pool.persist_all_regions();
    let core = LfCore::from_parts(pool, Arc::new(Ebr::new()));
    (LfList::from_parts(head, core), stats)
}

/// Rebuild a link-free hash set from the durable areas of `id`.
pub fn recover_hash(id: PoolId, nbuckets: usize) -> (LfHash, RecoveredStats) {
    let pool = Arc::new(DurablePool::adopt(id, 64, LfNode::init_free_pattern));
    let (mut members, stats) = scan(&pool);
    let core = LfCore::from_parts(pool, Arc::new(Ebr::new()));
    let hash = LfHash::from_parts(nbuckets, core);
    let mask = (hash.nbuckets() - 1) as u64;
    // Sort by (bucket, key) then relink one chain per bucket.
    members.sort_unstable_by_key(|m| ((mix64(m.0) & mask), m.0));
    let mut i = 0;
    while i < members.len() {
        let b = (mix64(members[i].0) & mask) as usize;
        let mut j = i;
        while j < members.len() && (mix64(members[j].0) & mask) as usize == b {
            j += 1;
        }
        let head_val = unsafe { relink_chain(&members[i..j]) };
        hash.buckets[b].store(head_val, Ordering::Relaxed);
        i = j;
    }
    hash.core.pool.persist_all_regions();
    (hash, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{self, CrashPolicy};
    use crate::sets::ConcurrentSet;

    #[test]
    fn recover_list_after_pessimistic_crash() {
        let _sim = pmem::sim_session();
        let l = LfList::new();
        let id = l.pool_id();
        for k in 0..50u64 {
            assert!(l.insert(k, k + 1000));
        }
        for k in (0..50u64).step_by(3) {
            assert!(l.remove(k));
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);

        let (l2, stats) = recover_list(id);
        // Every completed insert/remove was psync'd, so the recovered set
        // must match exactly.
        for k in 0..50u64 {
            if k % 3 == 0 {
                assert!(!l2.contains(k), "removed key {k} resurrected");
            } else {
                assert_eq!(l2.get(k), Some(k + 1000), "key {k} lost");
            }
        }
        assert_eq!(stats.members as usize, (0..50).filter(|k| k % 3 != 0).count());
        // Post-recovery the structure is fully operational.
        assert!(l2.insert(999, 1));
        assert!(l2.remove(1));
    }

    #[test]
    fn recover_hash_after_random_eviction_crash() {
        let _sim = pmem::sim_session();
        let h = LfHash::new(32);
        let id = h.pool_id();
        for k in 0..200u64 {
            assert!(h.insert(k, k));
        }
        for k in 100..150u64 {
            assert!(h.remove(k));
        }
        h.crash_preserve();
        drop(h);
        // Random eviction may persist *extra* lines, never fewer: acked
        // ops must still be exact.
        pmem::crash_pools(CrashPolicy::random(0.5, 42), &[id]);

        let (h2, stats) = recover_hash(id, 32);
        for k in 0..200u64 {
            let expect = !(100..150).contains(&k);
            assert_eq!(h2.contains(k), expect, "key {k}");
        }
        assert_eq!(stats.members, 150);
        assert!(stats.reclaimed > 0);
        // Reclaimed slots are reusable.
        for k in 1000..1100u64 {
            assert!(h2.insert(k, k));
        }
    }

    #[test]
    fn crash_during_reclamation_neither_leaks_nor_resurrects() {
        let _sim = pmem::sim_session();
        let l = LfList::new();
        let id = l.pool_id();
        for k in 0..20u64 {
            assert!(l.insert(k, k));
        }
        assert!(l.remove(7));
        // Drive reclamation to completion: the slot is freed and its
        // generation word bumped — but the bump is NOT persisted (it
        // rides the next psync of that line, which never comes before
        // this crash). Recovery must not care: it classifies by the
        // validity scheme (gen is metadata, never key/validity bits).
        unsafe { l.core.ebr.drain_all() };
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);

        let (l2, stats) = recover_list(id);
        assert!(!l2.contains(7), "freed slot re-linked as a member");
        assert_eq!(stats.members, 19);
        // No leak: every non-member slot of the single area — including
        // the freed one whose gen bump was lost — is reclaimable again.
        assert_eq!(stats.reclaimed, crate::alloc::area::SLOTS_PER_AREA - 19);
        assert!(l2.insert(7, 77), "reclaimed slots must be reusable");
        assert_eq!(l2.get(7), Some(77));
    }

    #[test]
    fn unflushed_insert_does_not_survive_pessimistic_crash() {
        let _sim = pmem::sim_session();
        // Build a list, then hand-craft an in-flight insert: linked and
        // valid in volatile memory but never psync'd.
        let l = LfList::new();
        let id = l.pool_id();
        assert!(l.insert(1, 1)); // psync'd
        unsafe {
            let node = l.core.pool.alloc() as *mut super::LfNode;
            (*node).make_invalid();
            (*node).reset_flush_flags();
            (*node).key.store(2, std::sync::atomic::Ordering::Relaxed);
            (*node).value.store(2, std::sync::atomic::Ordering::Relaxed);
            (*node).next.store(0, std::sync::atomic::Ordering::Relaxed);
            (*node).make_valid(); // valid in cache, never flushed
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l2, _) = recover_list(id);
        assert!(l2.contains(1));
        assert!(!l2.contains(2), "unflushed insert must not survive");
    }

    #[test]
    fn double_crash_no_ghosts() {
        let _sim = pmem::sim_session();
        let l = LfList::new();
        let id = l.pool_id();
        for k in 0..20u64 {
            l.insert(k, k);
        }
        for k in 0..10u64 {
            l.remove(k);
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l2, _) = recover_list(id);
        // Crash again immediately: normalisation of reclaimed slots was
        // persisted by recovery, so the second recovery sees the same set.
        l2.crash_preserve();
        drop(l2);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l3, stats) = recover_list(id);
        for k in 0..20u64 {
            assert_eq!(l3.contains(k), k >= 10, "key {k} after double crash");
        }
        assert_eq!(stats.members, 10);
    }
}
