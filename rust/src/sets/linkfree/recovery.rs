//! Link-free recovery (paper §3.5) via the shared engine
//! ([`crate::sets::recovery`]): this module is only the validity rule and
//! link-word shape ([`LfClassify`]) — **valid & unmarked ⇒ member**,
//! everything else (interrupted insert, deleted, never-used) is
//! normalised to the free pattern and reclaimed; members are relinked in
//! place with zero psyncs and the areas persisted once in bulk, so a
//! second crash cannot resurrect ghosts. Generation words are allocator
//! metadata: never read by classification, never written by
//! normalisation, no restoration needed.

use crate::alloc::{DurablePool, Ebr};
use crate::pmem::PoolId;
use crate::sets::recovery::{self as engine, Classify, PhaseTimings};
use crate::sets::tagged::MARK;
use crate::util::mix64;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::list::{LfCore, LfList};
use super::node::LfNode;
use super::LfHash;

pub use crate::sets::recovery::RecoveredStats;

/// The link-free validity rule for the engine (also reused by the
/// accelerated recovery path for relinking).
pub(crate) struct LfClassify;

impl Classify for LfClassify {
    const FAMILY: &'static str = "link-free";
    const NULL_LINK: u64 = 0; // null, unmarked

    unsafe fn classify(&self, slot: *mut u8) -> Option<(u64, usize)> {
        let node = slot as *mut LfNode;
        if (*node).is_member() {
            Some(((*node).key.load(Ordering::Relaxed), node as usize))
        } else {
            None
        }
    }

    unsafe fn link_word(&self, node: usize) -> u64 {
        debug_assert_eq!(node as u64 & MARK, 0);
        node as u64
    }

    unsafe fn link(&self, node: usize, next: u64) {
        let n = node as *mut LfNode;
        (*n).next.store(next, Ordering::Relaxed);
        // Content is durable: arm the insert-flush flag so post-recovery
        // reads don't re-psync, and clear the delete flag.
        (*n).reset_flush_flags();
        (*n).set_insert_flushed();
    }
}

/// Rebuild a link-free list from the durable areas of `id`.
pub fn recover_list(id: PoolId) -> (LfList, RecoveredStats) {
    let (l, s, _) = recover_list_timed(id, engine::default_threads());
    (l, s)
}

/// [`recover_list`] with an explicit recovery worker count.
pub fn recover_list_timed(id: PoolId, threads: usize) -> (LfList, RecoveredStats, PhaseTimings) {
    let pool = Arc::new(DurablePool::adopt(id, 64, LfNode::init_free_pattern));
    let mut rec = engine::scan(&pool, &LfClassify, threads);
    rec.sort_by_key();
    // A crash mid-compaction legitimately leaves a migrated copy AND its
    // source valid with the same key; keep one, demote the other.
    unsafe { rec.dedup_duplicates(&LfClassify, &pool) };
    let head = unsafe { rec.relink_chain(&LfClassify) };
    pool.persist_all_regions();
    let core = LfCore::from_parts(pool, Arc::new(Ebr::new()));
    (LfList::from_parts(head, core), rec.stats, rec.timings)
}

/// Rebuild a link-free hash set from the durable areas of `id`.
pub fn recover_hash(id: PoolId, nbuckets: usize) -> (LfHash, RecoveredStats) {
    let (h, s, _) = recover_hash_timed(id, nbuckets, engine::default_threads());
    (h, s)
}

/// [`recover_hash`] with an explicit recovery worker count (bucket-
/// partitioned relink: no two workers touch the same chain).
pub fn recover_hash_timed(
    id: PoolId,
    nbuckets: usize,
    threads: usize,
) -> (LfHash, RecoveredStats, PhaseTimings) {
    let pool = Arc::new(DurablePool::adopt(id, 64, LfNode::init_free_pattern));
    let mut rec = engine::scan(&pool, &LfClassify, threads);
    let core = LfCore::from_parts(pool, Arc::new(Ebr::new()));
    let hash = LfHash::from_parts(nbuckets, core);
    let mask = (hash.nbuckets() - 1) as u64;
    let bucket_of = |k: u64| (mix64(k) & mask) as usize;
    rec.sort_by_bucket(bucket_of);
    unsafe { rec.dedup_duplicates(&LfClassify, &hash.core.pool) };
    for (b, head) in unsafe { rec.relink_buckets(&LfClassify, &bucket_of) } {
        hash.buckets[b].store(head, Ordering::Relaxed);
    }
    hash.core.pool.persist_all_regions();
    (hash, rec.stats, rec.timings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{self, CrashPolicy};
    use crate::sets::ConcurrentSet;

    #[test]
    fn recover_list_after_pessimistic_crash() {
        let _sim = pmem::sim_session();
        let l = LfList::new();
        let id = l.pool_id();
        for k in 0..50u64 {
            assert!(l.insert(k, k + 1000));
        }
        for k in (0..50u64).step_by(3) {
            assert!(l.remove(k));
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);

        let (l2, stats) = recover_list(id);
        // Every completed insert/remove was psync'd, so the recovered set
        // must match exactly.
        for k in 0..50u64 {
            if k % 3 == 0 {
                assert!(!l2.contains(k), "removed key {k} resurrected");
            } else {
                assert_eq!(l2.get(k), Some(k + 1000), "key {k} lost");
            }
        }
        assert_eq!(stats.members as usize, (0..50).filter(|k| k % 3 != 0).count());
        // Post-recovery the structure is fully operational.
        assert!(l2.insert(999, 1));
        assert!(l2.remove(1));
    }

    #[test]
    fn recover_hash_after_random_eviction_crash() {
        let _sim = pmem::sim_session();
        let h = LfHash::new(32);
        let id = h.pool_id();
        for k in 0..200u64 {
            assert!(h.insert(k, k));
        }
        for k in 100..150u64 {
            assert!(h.remove(k));
        }
        h.crash_preserve();
        drop(h);
        // Random eviction may persist *extra* lines, never fewer: acked
        // ops must still be exact.
        pmem::crash_pools(CrashPolicy::random(0.5, 42), &[id]);

        let (h2, stats) = recover_hash(id, 32);
        for k in 0..200u64 {
            let expect = !(100..150).contains(&k);
            assert_eq!(h2.contains(k), expect, "key {k}");
        }
        assert_eq!(stats.members, 150);
        assert!(stats.reclaimed > 0);
        // Reclaimed slots are reusable.
        for k in 1000..1100u64 {
            assert!(h2.insert(k, k));
        }
    }

    #[test]
    fn crash_during_reclamation_neither_leaks_nor_resurrects() {
        let _sim = pmem::sim_session();
        let l = LfList::new();
        let id = l.pool_id();
        for k in 0..20u64 {
            assert!(l.insert(k, k));
        }
        assert!(l.remove(7));
        // Drive reclamation to completion: the slot is freed and its
        // generation word bumped — but the bump is NOT persisted (it
        // rides the next psync of that line, which never comes before
        // this crash). Recovery must not care: it classifies by the
        // validity scheme (gen is metadata, never key/validity bits).
        unsafe { l.core.ebr.drain_all() };
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);

        let (l2, stats) = recover_list(id);
        assert!(!l2.contains(7), "freed slot re-linked as a member");
        assert_eq!(stats.members, 19);
        // No leak: every non-member slot of the single area — including
        // the freed one whose gen bump was lost — is reclaimable again.
        assert_eq!(stats.reclaimed, crate::alloc::area::SLOTS_PER_AREA - 19);
        assert!(l2.insert(7, 77), "reclaimed slots must be reusable");
        assert_eq!(l2.get(7), Some(77));
        // Reuse must come from the caller-side free-list the engine filled
        // (parallel workers normalise but never free): if recovery had
        // stranded the reclaimed slots in dead worker threads' per-tid
        // lists, this insert would have grown a second area.
        assert_eq!(
            l2.core.pool.regions().len(),
            1,
            "post-recovery insert must reuse reclaimed slots, not grow a fresh area"
        );
    }

    #[test]
    fn unflushed_insert_does_not_survive_pessimistic_crash() {
        let _sim = pmem::sim_session();
        // Build a list, then hand-craft an in-flight insert: linked and
        // valid in volatile memory but never psync'd.
        let l = LfList::new();
        let id = l.pool_id();
        assert!(l.insert(1, 1)); // psync'd
        unsafe {
            let node = l.core.pool.alloc() as *mut super::LfNode;
            (*node).make_invalid();
            (*node).reset_flush_flags();
            (*node).key.store(2, std::sync::atomic::Ordering::Relaxed);
            (*node).value.store(2, std::sync::atomic::Ordering::Relaxed);
            (*node).next.store(0, std::sync::atomic::Ordering::Relaxed);
            (*node).make_valid(); // valid in cache, never flushed
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l2, _) = recover_list(id);
        assert!(l2.contains(1));
        assert!(!l2.contains(2), "unflushed insert must not survive");
    }

    #[test]
    fn double_crash_no_ghosts() {
        let _sim = pmem::sim_session();
        let l = LfList::new();
        let id = l.pool_id();
        for k in 0..20u64 {
            l.insert(k, k);
        }
        for k in 0..10u64 {
            l.remove(k);
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l2, _) = recover_list(id);
        // Crash again immediately: normalisation of reclaimed slots was
        // persisted by recovery, so the second recovery sees the same set.
        l2.crash_preserve();
        drop(l2);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l3, stats) = recover_list(id);
        for k in 0..20u64 {
            assert_eq!(l3.contains(k), k >= 10, "key {k} after double crash");
        }
        assert_eq!(stats.members, 10);
    }
}
