//! Tagged-pointer helpers.
//!
//! All list links are `AtomicU64` words holding a node address plus low
//! tag bits (nodes are at least 8-byte aligned, durable nodes 64-byte):
//!
//! * **link-free / volatile**: bit 0 = Harris deletion mark on the node
//!   *owning* the link ("mark a node" = set bit 0 of its `next`).
//! * **log-free**: bit 0 = mark, bit 1 = *dirty* (link not yet persisted;
//!   link-and-persist clears it after a psync).
//! * **SOFT**: bits 0–1 = the owning node's 4-way state
//!   (paper §2.3 / Listing 10's `createRef`/`getState`).
//!
//! A *link cell* (`*const AtomicU64`) stands for a position in a list: a
//! list head, a hash bucket slot, or some node's `next` field. Operating
//! on link cells instead of predecessor nodes lets a hash bucket be one
//! 8-byte word instead of a 64-byte sentinel node; Harris's correctness
//! argument carries over because a marked predecessor's `next` value has
//! bit 0 set and therefore fails any CAS expecting a clean pointer.

/// Harris deletion mark (bit 0).
pub const MARK: u64 = 0b01;
/// Log-free "link not persisted" bit (bit 1).
pub const DIRTY: u64 = 0b10;
/// Mask selecting the pointer part for 2 tag bits.
pub const PTR_MASK: u64 = !0b11;

#[inline(always)]
pub fn is_marked(v: u64) -> bool {
    v & MARK != 0
}

#[inline(always)]
pub fn is_dirty(v: u64) -> bool {
    v & DIRTY != 0
}

#[inline(always)]
pub fn ptr_of<T>(v: u64) -> *mut T {
    (v & PTR_MASK) as *mut T
}

#[inline(always)]
pub fn tag_of(v: u64) -> u64 {
    v & 0b11
}

#[inline(always)]
pub fn compose<T>(p: *mut T, tag: u64) -> u64 {
    debug_assert_eq!(p as u64 & 0b11, 0);
    p as u64 | tag
}

/// SOFT volatile-node states (paper §2.3), stored in the low 2 bits of the
/// owning node's `next`. `Inserted = 0` so that a zero-initialised bucket
/// cell reads as an empty list with an "inserted" head.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(u64)]
pub enum State {
    Inserted = 0b00,
    IntendToInsert = 0b01,
    IntendToDelete = 0b10,
    Deleted = 0b11,
}

impl State {
    #[inline(always)]
    pub fn of(v: u64) -> State {
        match v & 0b11 {
            0b00 => State::Inserted,
            0b01 => State::IntendToInsert,
            0b10 => State::IntendToDelete,
            _ => State::Deleted,
        }
    }

    /// Is the key logically in the set (paper: "inserted" or "inserted
    /// with intention to delete")?
    #[inline(always)]
    pub fn in_set(self) -> bool {
        matches!(self, State::Inserted | State::IntendToDelete)
    }
}

// ---------------- generation-tagged hint words ----------------
//
// Bucket entry hints (`sets::resizable`) publish a node pointer *and* the
// slot's allocation generation in one 64-bit word so the pair is read and
// CAS'd atomically: low `HINT_PTR_BITS` bits = ptr >> 3 (slots are at
// least 8-byte aligned; 44 bits cover the 47-bit user address space),
// high bits = the generation, truncated to `HINT_GEN_BITS`. A reader
// re-derives the slot's current generation from the pointer and rejects
// the hint on mismatch — the slot was freed (and possibly reused) since
// publication. Truncation leaves a 2^20-reallocation wraparound window;
// combined with the state check that still follows, a false match needs
// the same slot to be recycled an exact multiple of 2^20 times between
// publish and use while the cell is never refreshed — treated as
// impossible in practice (DESIGN.md §Reclamation).

/// Bits of `ptr >> 3` kept in a packed hint word.
pub const HINT_PTR_BITS: u32 = 44;
/// Bits of generation kept in a packed hint word.
pub const HINT_GEN_BITS: u32 = 64 - HINT_PTR_BITS;
const HINT_PTR_MASK: u64 = (1u64 << HINT_PTR_BITS) - 1;
/// Mask a full generation down to its packed truncation.
pub const HINT_GEN_MASK: u64 = (1u64 << HINT_GEN_BITS) - 1;

/// Pack a node pointer and its slot generation into one hint word.
/// Never 0 for a non-null pointer (0 stays the "empty cell" sentinel).
///
/// The address-range check is a hard assert (publish-time only, never on
/// the validation hot path): silently truncating an address above 2^47 —
/// possible under five-level paging — would unpack into unrelated memory.
#[inline(always)]
pub fn pack_hint<T>(p: *mut T, gen: u64) -> u64 {
    debug_assert_eq!(p as u64 & 0b111, 0, "hint targets must be 8-byte aligned");
    assert!(
        (p as u64) >> (HINT_PTR_BITS + 3) == 0,
        "address exceeds the packable 47-bit user address range"
    );
    ((p as u64) >> 3) | ((gen & HINT_GEN_MASK) << HINT_PTR_BITS)
}

/// The pointer half of a packed hint word.
#[inline(always)]
pub fn hint_ptr<T>(w: u64) -> *mut T {
    ((w & HINT_PTR_MASK) << 3) as *mut T
}

/// The (truncated) generation half of a packed hint word.
#[inline(always)]
pub fn hint_gen(w: u64) -> u64 {
    w >> HINT_PTR_BITS
}

/// Does the packed word's generation match the slot's current (full)
/// generation?
#[inline(always)]
pub fn hint_gen_matches(w: u64, full_gen: u64) -> bool {
    hint_gen(w) == (full_gen & HINT_GEN_MASK)
}

/// The one seqlock-shaped gen-validation protocol shared by every hint
/// and tower validator (resizable hash cells, both skip lists): check the
/// slot's current generation against the published `expected`, run the
/// payload check (state/key reads), then re-check the generation. Either
/// mismatch means the slot was reclaimed (and possibly reused) since
/// publication → `None`. A stable match brackets the payload reads within
/// one slot incarnation (DESIGN.md §Reclamation). With `--features
/// untagged-hints` both gen checks compile out, restoring the pre-tag
/// state-only heuristic — the churn harness's negative control. Keeping
/// the protocol here, once, means an ordering fix cannot be applied to
/// one family and silently missed in another.
#[inline(always)]
pub fn gen_validated<T>(
    gen_of: impl Fn() -> u64,
    expected: u64,
    payload: impl FnOnce() -> Option<T>,
) -> Option<T> {
    let tagged = !cfg!(feature = "untagged-hints");
    if tagged && gen_of() != expected {
        return None; // slot reclaimed since publication
    }
    let v = payload()?;
    if tagged && gen_of() != expected {
        return None; // reclaimed under our feet mid-validation
    }
    Some(v)
}

/// CAS that swaps only the state bits, preserving the pointer — the
/// paper's `stateCAS` (Listing 10). Returns true on success.
#[inline]
pub fn state_cas(link: &std::sync::atomic::AtomicU64, old: State, new: State) -> bool {
    use std::sync::atomic::Ordering;
    let cur = link.load(Ordering::Acquire);
    if State::of(cur) != old {
        return false;
    }
    let want = (cur & PTR_MASK) | new as u64;
    link.compare_exchange(cur, want, Ordering::AcqRel, Ordering::Acquire)
        .is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn compose_decompose() {
        let p = 0x1000 as *mut u8;
        let v = compose(p, MARK);
        assert!(is_marked(v));
        assert!(!is_dirty(v));
        assert_eq!(ptr_of::<u8>(v), p);
        let v2 = compose(p, MARK | DIRTY);
        assert!(is_dirty(v2));
        assert_eq!(tag_of(v2), 0b11);
    }

    #[test]
    fn state_roundtrip() {
        for s in [State::Inserted, State::IntendToInsert, State::IntendToDelete, State::Deleted] {
            let v = compose(0x40 as *mut u8, s as u64);
            assert_eq!(State::of(v), s);
        }
        assert!(State::Inserted.in_set());
        assert!(State::IntendToDelete.in_set());
        assert!(!State::IntendToInsert.in_set());
        assert!(!State::Deleted.in_set());
    }

    #[test]
    fn hint_word_roundtrip_and_mismatch() {
        let p = 0x7f12_3456_7f40 as *mut u8; // 8-aligned, 47-bit address
        for gen in [0u64, 1, 7, HINT_GEN_MASK, HINT_GEN_MASK + 1] {
            let w = pack_hint(p, gen);
            assert_eq!(hint_ptr::<u8>(w), p, "pointer survives packing (gen {gen})");
            assert!(hint_gen_matches(w, gen));
            assert!(!hint_gen_matches(w, gen + 1), "a bumped gen must mismatch");
        }
        // Truncation wraps at 2^HINT_GEN_BITS (documented hazard window).
        let w = pack_hint(p, 3);
        assert!(hint_gen_matches(w, 3 + (1u64 << HINT_GEN_BITS)));
        // Null pointer with gen 0 packs to the empty-cell sentinel.
        assert_eq!(pack_hint::<u8>(std::ptr::null_mut(), 0), 0);
    }

    #[test]
    fn state_cas_swaps_only_state() {
        let link = AtomicU64::new(compose(0x1000 as *mut u8, State::Inserted as u64));
        assert!(state_cas(&link, State::Inserted, State::IntendToDelete));
        let v = link.load(Ordering::Relaxed);
        assert_eq!(ptr_of::<u8>(v), 0x1000 as *mut u8);
        assert_eq!(State::of(v), State::IntendToDelete);
        // Wrong expectation fails.
        assert!(!state_cas(&link, State::Inserted, State::Deleted));
    }
}
