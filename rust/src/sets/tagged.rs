//! Tagged-pointer helpers.
//!
//! All list links are `AtomicU64` words holding a node address plus low
//! tag bits (nodes are at least 8-byte aligned, durable nodes 64-byte):
//!
//! * **link-free / volatile**: bit 0 = Harris deletion mark on the node
//!   *owning* the link ("mark a node" = set bit 0 of its `next`).
//! * **log-free**: bit 0 = mark, bit 1 = *dirty* (link not yet persisted;
//!   link-and-persist clears it after a psync).
//! * **SOFT**: bits 0–1 = the owning node's 4-way state
//!   (paper §2.3 / Listing 10's `createRef`/`getState`).
//!
//! A *link cell* (`*const AtomicU64`) stands for a position in a list: a
//! list head, a hash bucket slot, or some node's `next` field. Operating
//! on link cells instead of predecessor nodes lets a hash bucket be one
//! 8-byte word instead of a 64-byte sentinel node; Harris's correctness
//! argument carries over because a marked predecessor's `next` value has
//! bit 0 set and therefore fails any CAS expecting a clean pointer.

/// Harris deletion mark (bit 0).
pub const MARK: u64 = 0b01;
/// Log-free "link not persisted" bit (bit 1).
pub const DIRTY: u64 = 0b10;
/// Mask selecting the pointer part for 2 tag bits.
pub const PTR_MASK: u64 = !0b11;

#[inline(always)]
pub fn is_marked(v: u64) -> bool {
    v & MARK != 0
}

#[inline(always)]
pub fn is_dirty(v: u64) -> bool {
    v & DIRTY != 0
}

#[inline(always)]
pub fn ptr_of<T>(v: u64) -> *mut T {
    (v & PTR_MASK) as *mut T
}

#[inline(always)]
pub fn tag_of(v: u64) -> u64 {
    v & 0b11
}

#[inline(always)]
pub fn compose<T>(p: *mut T, tag: u64) -> u64 {
    debug_assert_eq!(p as u64 & 0b11, 0);
    p as u64 | tag
}

/// SOFT volatile-node states (paper §2.3), stored in the low 2 bits of the
/// owning node's `next`. `Inserted = 0` so that a zero-initialised bucket
/// cell reads as an empty list with an "inserted" head.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(u64)]
pub enum State {
    Inserted = 0b00,
    IntendToInsert = 0b01,
    IntendToDelete = 0b10,
    Deleted = 0b11,
}

impl State {
    #[inline(always)]
    pub fn of(v: u64) -> State {
        match v & 0b11 {
            0b00 => State::Inserted,
            0b01 => State::IntendToInsert,
            0b10 => State::IntendToDelete,
            _ => State::Deleted,
        }
    }

    /// Is the key logically in the set (paper: "inserted" or "inserted
    /// with intention to delete")?
    #[inline(always)]
    pub fn in_set(self) -> bool {
        matches!(self, State::Inserted | State::IntendToDelete)
    }
}

/// CAS that swaps only the state bits, preserving the pointer — the
/// paper's `stateCAS` (Listing 10). Returns true on success.
#[inline]
pub fn state_cas(link: &std::sync::atomic::AtomicU64, old: State, new: State) -> bool {
    use std::sync::atomic::Ordering;
    let cur = link.load(Ordering::Acquire);
    if State::of(cur) != old {
        return false;
    }
    let want = (cur & PTR_MASK) | new as u64;
    link.compare_exchange(cur, want, Ordering::AcqRel, Ordering::Acquire)
        .is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn compose_decompose() {
        let p = 0x1000 as *mut u8;
        let v = compose(p, MARK);
        assert!(is_marked(v));
        assert!(!is_dirty(v));
        assert_eq!(ptr_of::<u8>(v), p);
        let v2 = compose(p, MARK | DIRTY);
        assert!(is_dirty(v2));
        assert_eq!(tag_of(v2), 0b11);
    }

    #[test]
    fn state_roundtrip() {
        for s in [State::Inserted, State::IntendToInsert, State::IntendToDelete, State::Deleted] {
            let v = compose(0x40 as *mut u8, s as u64);
            assert_eq!(State::of(v), s);
        }
        assert!(State::Inserted.in_set());
        assert!(State::IntendToDelete.in_set());
        assert!(!State::IntendToInsert.in_set());
        assert!(!State::Deleted.in_set());
    }

    #[test]
    fn state_cas_swaps_only_state() {
        let link = AtomicU64::new(compose(0x1000 as *mut u8, State::Inserted as u64));
        assert!(state_cas(&link, State::Inserted, State::IntendToDelete));
        let v = link.load(Ordering::Relaxed);
        assert_eq!(ptr_of::<u8>(v), 0x1000 as *mut u8);
        assert_eq!(State::of(v), State::IntendToDelete);
        // Wrong expectation fails.
        assert!(!state_cas(&link, State::Inserted, State::Deleted));
    }
}
