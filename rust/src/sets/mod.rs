//! The durable set algorithms.
//!
//! Four families, one trait:
//!
//! | family | module | durability | psyncs/update | psyncs/read | hash growth |
//! |---|---|---|---|---|---|
//! | **link-free** (paper §3) | [`linkfree`] | durable linearizable | ~1 (flag-elided) | ≤1 (0 quiescent) | [`resizable`] |
//! | **SOFT** (paper §4) | [`soft`] | durable linearizable | exactly 1 | 0 | [`resizable`] |
//! | **log-free** (David et al. ATC'18, baseline) | [`logfree`] | durable linearizable | ~2 | ≤2 (0 clean) | [`resizable`] |
//! | **volatile** (Harris 2001, ablation) | [`volatile`] | none | 0 | 0 | fixed |
//!
//! Each family provides a sorted linked list and a hash set built from the
//! same core (a bucket is a bare link cell — see [`tagged`]), plus a
//! recovery procedure rebuilding the volatile structure from the durable
//! areas after a crash.
//!
//! Hash sets of the three durable families are **resizable**
//! ([`ResizableHash`]): one family list in `mix64(key)` order plus a
//! lock-free doubling array of bucket entry hints. Growth triggers when
//! the average chain length crosses [`resizable::GROW_LOAD`], migration is
//! split-ordered-style first-touch hint population (zero psyncs, nothing
//! ever moves), and the bucket-count epoch is persisted in a root cell so
//! recovery restores the table size. The fixed-bucket variants
//! ([`linkfree::LfHash`], [`soft::SoftHash`], [`logfree::LogFreeHash`])
//! remain for the paper's load-factor-1 evaluation and the XLA-accelerated
//! recovery path.

pub mod linkfree;
pub mod logfree;
pub mod resizable;
pub mod soft;
pub mod tagged;
pub mod volatile;

pub use resizable::{ResizableHash, ResizableLfHash, ResizableLogFreeHash, ResizableSoftHash};

/// The paper's set interface: unique `u64` keys with one word of data.
///
/// * `insert` adds `key -> value`; false if the key was present.
/// * `remove` deletes `key`; false if it was absent.
/// * `contains` is read-only (wait-free in all four families).
pub trait ConcurrentSet: Send + Sync {
    fn insert(&self, key: u64, value: u64) -> bool;
    fn remove(&self, key: u64) -> bool;
    fn contains(&self, key: u64) -> bool;

    /// Value lookup (same traversal as `contains`).
    fn get(&self, key: u64) -> Option<u64>;

    /// Non-linearizable size estimate (testing/metrics only).
    fn len_approx(&self) -> usize;

    /// Durable pool identity, if this set persists anything (used by the
    /// coordinator to recover shards after a crash).
    fn durable_pool(&self) -> Option<crate::pmem::PoolId> {
        None
    }

    /// Keep durable regions alive across a simulated crash (no-op for
    /// volatile sets).
    fn prepare_crash(&self) {}
}

/// Algorithm family selector used by benches, the coordinator and the CLI.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Family {
    LinkFree,
    Soft,
    LogFree,
    Volatile,
}

impl Family {
    pub const ALL: [Family; 4] = [Family::LinkFree, Family::Soft, Family::LogFree, Family::Volatile];

    /// The three durable families compared in the paper's evaluation.
    pub const DURABLE: [Family; 3] = [Family::LinkFree, Family::Soft, Family::LogFree];

    pub fn name(&self) -> &'static str {
        match self {
            Family::LinkFree => "link-free",
            Family::Soft => "soft",
            Family::LogFree => "log-free",
            Family::Volatile => "volatile",
        }
    }

    pub fn parse(s: &str) -> Option<Family> {
        match s.to_ascii_lowercase().as_str() {
            "link-free" | "linkfree" | "lf" => Some(Family::LinkFree),
            "soft" => Some(Family::Soft),
            "log-free" | "logfree" => Some(Family::LogFree),
            "volatile" | "harris" => Some(Family::Volatile),
            _ => None,
        }
    }
}

impl std::fmt::Display for Family {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Construct a list of the given family behind the common trait.
pub fn new_list(family: Family) -> Box<dyn ConcurrentSet> {
    match family {
        Family::LinkFree => Box::new(linkfree::LfList::new()),
        Family::Soft => Box::new(soft::SoftList::new()),
        Family::LogFree => Box::new(logfree::LogFreeList::new()),
        Family::Volatile => Box::new(volatile::VolatileList::new()),
    }
}

/// Construct a hash set of the given family with `nbuckets` *initial*
/// buckets. Durable families get the resizable table (the array doubles
/// under load); the volatile ablation keeps its fixed table.
pub fn new_hash(family: Family, nbuckets: usize) -> Box<dyn ConcurrentSet> {
    match family {
        Family::LinkFree => Box::new(ResizableHash::new_linkfree(nbuckets)),
        Family::Soft => Box::new(ResizableHash::new_soft(nbuckets)),
        Family::LogFree => Box::new(ResizableHash::new_logfree(nbuckets)),
        Family::Volatile => Box::new(volatile::VolatileHash::new(nbuckets)),
    }
}
