//! The durable set algorithms.
//!
//! Four families, one trait:
//!
//! | family | module | durability | psyncs/update | psyncs/read |
//! |---|---|---|---|---|
//! | **link-free** (paper §3) | [`linkfree`] | durable linearizable | ~1 (flag-elided) | ≤1 |
//! | **SOFT** (paper §4) | [`soft`] | durable linearizable | exactly 1 | 0 |
//! | **log-free** (David et al. ATC'18, baseline) | [`logfree`] | durable linearizable | ~2 | ≤2 |
//! | **volatile** (Harris 2001, ablation) | [`volatile`] | none | 0 | 0 |
//!
//! Each family provides a sorted linked list and a fixed-bucket hash set
//! built from the same core (a bucket is a bare link cell — see
//! [`tagged`]), plus a recovery procedure rebuilding the volatile
//! structure from the durable areas after a crash.

pub mod linkfree;
pub mod logfree;
pub mod soft;
pub mod tagged;
pub mod volatile;

/// The paper's set interface: unique `u64` keys with one word of data.
///
/// * `insert` adds `key -> value`; false if the key was present.
/// * `remove` deletes `key`; false if it was absent.
/// * `contains` is read-only (wait-free in all four families).
pub trait ConcurrentSet: Send + Sync {
    fn insert(&self, key: u64, value: u64) -> bool;
    fn remove(&self, key: u64) -> bool;
    fn contains(&self, key: u64) -> bool;

    /// Value lookup (same traversal as `contains`).
    fn get(&self, key: u64) -> Option<u64>;

    /// Non-linearizable size estimate (testing/metrics only).
    fn len_approx(&self) -> usize;

    /// Durable pool identity, if this set persists anything (used by the
    /// coordinator to recover shards after a crash).
    fn durable_pool(&self) -> Option<crate::pmem::PoolId> {
        None
    }

    /// Keep durable regions alive across a simulated crash (no-op for
    /// volatile sets).
    fn prepare_crash(&self) {}
}

/// Algorithm family selector used by benches, the coordinator and the CLI.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Family {
    LinkFree,
    Soft,
    LogFree,
    Volatile,
}

impl Family {
    pub const ALL: [Family; 4] = [Family::LinkFree, Family::Soft, Family::LogFree, Family::Volatile];

    /// The three durable families compared in the paper's evaluation.
    pub const DURABLE: [Family; 3] = [Family::LinkFree, Family::Soft, Family::LogFree];

    pub fn name(&self) -> &'static str {
        match self {
            Family::LinkFree => "link-free",
            Family::Soft => "soft",
            Family::LogFree => "log-free",
            Family::Volatile => "volatile",
        }
    }

    pub fn parse(s: &str) -> Option<Family> {
        match s.to_ascii_lowercase().as_str() {
            "link-free" | "linkfree" | "lf" => Some(Family::LinkFree),
            "soft" => Some(Family::Soft),
            "log-free" | "logfree" => Some(Family::LogFree),
            "volatile" | "harris" => Some(Family::Volatile),
            _ => None,
        }
    }
}

impl std::fmt::Display for Family {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Construct a list of the given family behind the common trait.
pub fn new_list(family: Family) -> Box<dyn ConcurrentSet> {
    match family {
        Family::LinkFree => Box::new(linkfree::LfList::new()),
        Family::Soft => Box::new(soft::SoftList::new()),
        Family::LogFree => Box::new(logfree::LogFreeList::new()),
        Family::Volatile => Box::new(volatile::VolatileList::new()),
    }
}

/// Construct a hash set of the given family with `nbuckets` buckets.
pub fn new_hash(family: Family, nbuckets: usize) -> Box<dyn ConcurrentSet> {
    match family {
        Family::LinkFree => Box::new(linkfree::LfHash::new(nbuckets)),
        Family::Soft => Box::new(soft::SoftHash::new(nbuckets)),
        Family::LogFree => Box::new(logfree::LogFreeHash::new(nbuckets)),
        Family::Volatile => Box::new(volatile::VolatileHash::new(nbuckets)),
    }
}
