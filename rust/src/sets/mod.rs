//! The durable set algorithms.
//!
//! Five families, one trait:
//!
//! | family | module | durability | psyncs/update | psyncs/read | fences/op, K-batch | hash growth | compaction migrate (DESIGN.md §Allocator) | `contains_batch` | `range`/`scan` | durcheck hooks (DESIGN.md §Checking) |
//! |---|---|---|---|---|---|---|---|---|---|---|
//! | **link-free** (paper §3) | [`linkfree`] | durable linearizable | ~1 (flag-elided) | ≤1 (0 quiescent) | ~1/K | [`resizable`] | copy + volatile pred swing; delete record deferred one EBR grace period (crash in window ⇒ recovery dedup) | coalesced ([`ResizableHash`]: one pin, okey-sorted probes; [`linkfree::LfSkipList`]: one pin, sorted probe run) | [`linkfree::LfSkipList`] (flush-free merge-walk) | validity flips + delete marks noted as durable stores |
//! | **SOFT** (paper §4) | [`soft`] | durable linearizable | exactly 1 | 0 | 1/K | [`resizable`] | fresh `PNode` + `pptr` swap; old destroyed + freed immediately (readers never dereference `pptr`) | coalesced ([`ResizableHash`] / [`soft::SoftSkipList`]) | [`soft::SoftSkipList`] (flush-free merge-walk) | pnode create/destroy noted; `pptr` publish order asserted |
//! | **log-free** (David et al. ATC'18, baseline) | [`logfree`] | durable linearizable | ~2 | ≤2 (0 clean) | ~1/K (flushes stay ~2/op) | [`resizable`] | copy + link-and-persist pred swing (atomic durable handoff, no duplicate window) | coalesced ([`ResizableHash`]) | — (hash order only) | link-and-persist stores noted; link-target publish order asserted |
//! | **nvtraverse** (Friedman et al. PLDI'20) | [`nvtraverse`] | durable linearizable (buffered for pure reads — DESIGN.md §Families) | 1 (destination-only) | **0 always** | 1/K | [`resizable`] | link-free machinery (shared durable format) | coalesced ([`ResizableHash`]) | — (hash order only) | delete marks noted; flush-before-unlink on every detach |
//! | **volatile** (Harris 2001, ablation) | [`volatile`] | none | 0 | 0 | 0 | fixed | — (nothing durable to compact) | default loop | — | — (no durable stores) |
//!
//! Each family provides a sorted linked list and a hash set built from the
//! same core (a bucket is a bare link cell — see [`tagged`]), plus a
//! recovery procedure rebuilding the volatile structure from the durable
//! areas after a crash. Recovery routes through the shared parallel
//! engine ([`recovery`]): a family contributes only its validity rule and
//! link-word shape; area scanning, classification and chain relinking are
//! engine-owned and multi-threaded (DESIGN.md §Recovery).
//!
//! Hash sets of the durable families are **resizable**
//! ([`ResizableHash`]): one family list in `mix64(key)` order plus a
//! lock-free doubling array of bucket entry hints. Growth triggers when
//! the average chain length crosses [`resizable::GROW_LOAD`], migration is
//! split-ordered-style first-touch hint population (zero psyncs, nothing
//! ever moves), and the bucket-count epoch is persisted in a root cell so
//! recovery restores the table size. The fixed-bucket variants
//! ([`linkfree::LfHash`], [`soft::SoftHash`], [`logfree::LogFreeHash`])
//! remain for the paper's load-factor-1 evaluation and the XLA-accelerated
//! recovery path.
//!
//! # Batch semantics (group commit)
//!
//! [`ConcurrentSet::apply_batch`] applies a sequence of [`SetOp`]s and
//! returns one [`OpResult`] per op. The durable families override it to
//! run the ops under a [`crate::pmem::PsyncScope`]: every op still
//! *flushes* its durable writes at the usual points (so the crash
//! simulator's per-op durability, the helping rules, and the flush-flag /
//! link-and-persist protocols are untouched — a concurrent reader that
//! observes an unfenced write re-flushes and fences *outside* the scope
//! before depending on it), but the batch issuer's per-op fences are
//! elided and replaced by **one trailing fence** (DESIGN.md §Batching).
//!
//! What is deferred: only the *issuer's* serialization point, i.e. the
//! instant its acks become claimable-durable. `apply_batch` returns after
//! the trailing fence, so by the time any result is observable the whole
//! batch is durable — per-ack durable linearizability is preserved, the
//! psync cost drops from K fences to 1 for a K-op batch, and a crash
//! before the trailing fence simply loses (a suffix of) the unacked
//! batch, never an acked op. Fence accounting for batched updates is
//! therefore `~1/K` psyncs/op (`bench --fig batch` measures it).

pub mod linkfree;
pub mod logfree;
pub mod nvtraverse;
pub mod recovery;
pub mod resizable;
pub mod soft;
pub mod tagged;
pub mod volatile;

pub use recovery::{PhaseTimings, RecoveredStats};
pub use resizable::{
    ResizableHash, ResizableLfHash, ResizableLogFreeHash, ResizableNvHash, ResizableSoftHash,
};

/// One operation of a batch — the wire protocol's verbs over the set API.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SetOp {
    Insert(u64, u64),
    Remove(u64),
    Contains(u64),
    Get(u64),
}

impl SetOp {
    /// The key the op addresses (shard routing).
    pub fn key(&self) -> u64 {
        match *self {
            SetOp::Insert(k, _) | SetOp::Remove(k) | SetOp::Contains(k) | SetOp::Get(k) => k,
        }
    }

    /// True for ops that may mutate (and therefore psync).
    pub fn is_update(&self) -> bool {
        matches!(self, SetOp::Insert(..) | SetOp::Remove(_))
    }
}

/// Result of one batched op, by op kind.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum OpResult {
    /// `Insert` (true = newly inserted) / `Remove` (true = was present).
    Applied(bool),
    /// `Contains`.
    Found(bool),
    /// `Get`.
    Value(Option<u64>),
}

/// The paper's set interface: unique `u64` keys with one word of data.
///
/// * `insert` adds `key -> value`; false if the key was present.
/// * `remove` deletes `key`; false if it was absent.
/// * `contains` is read-only (wait-free in all five families).
pub trait ConcurrentSet: Send + Sync {
    fn insert(&self, key: u64, value: u64) -> bool;
    fn remove(&self, key: u64) -> bool;
    fn contains(&self, key: u64) -> bool;

    /// Value lookup (same traversal as `contains`).
    fn get(&self, key: u64) -> Option<u64>;

    /// Non-linearizable size estimate (testing/metrics only).
    fn len_approx(&self) -> usize;

    /// Membership of every key in `keys`, in input order, as **one**
    /// virtual-call sweep — the server's read lane issues a whole
    /// contains run through a single dispatch instead of one per line.
    /// The default loops over [`ConcurrentSet::contains`]; families whose
    /// reads share per-call overhead (EBR pin, entry lookup) override it
    /// with a coalesced sweep. Reads never psync, so no scope is taken:
    /// a batch of reads costs zero fences and zero flushes in every
    /// family (SOFT unconditionally; link-free/log-free at quiescence).
    fn contains_batch(&self, keys: &[u64]) -> Vec<bool> {
        keys.iter().map(|&k| self.contains(k)).collect()
    }

    /// Value lookup for every key in `keys`, in input order — the read
    /// lane's `GET` sweep, same contract as [`ConcurrentSet::contains_batch`].
    fn get_batch(&self, keys: &[u64]) -> Vec<Option<u64>> {
        keys.iter().map(|&k| self.get(k)).collect()
    }

    /// Apply one batch op (the shared dispatch used by `apply_batch`).
    fn apply_one(&self, op: SetOp) -> OpResult {
        match op {
            SetOp::Insert(k, v) => OpResult::Applied(self.insert(k, v)),
            SetOp::Remove(k) => OpResult::Applied(self.remove(k)),
            SetOp::Contains(k) => OpResult::Found(self.contains(k)),
            SetOp::Get(k) => OpResult::Value(self.get(k)),
        }
    }

    /// Apply `ops` in order, returning one result per op. The default is a
    /// plain loop (always correct); the durable families override it with
    /// [`apply_batch_coalesced`] so the whole batch shares **one** trailing
    /// fence (see the module docs' batch-semantics section). Results are
    /// only returned after every op in the batch is durable.
    fn apply_batch(&self, ops: &[SetOp]) -> Vec<OpResult> {
        ops.iter().map(|&op| self.apply_one(op)).collect()
    }

    /// Durable pool identity, if this set persists anything (used by the
    /// coordinator to recover shards after a crash).
    fn durable_pool(&self) -> Option<crate::pmem::PoolId> {
        None
    }

    /// Keep durable regions alive across a simulated crash (no-op for
    /// volatile sets).
    fn prepare_crash(&self) {}

    /// Bucket-array growth statistics (resizable hash sets only).
    fn growth_stats(&self) -> Option<GrowthStats> {
        None
    }

    /// One background maintenance step: area compaction + memory return
    /// and bucket-array shrink ([`resizable::ResizableHash::maintain_tick`]).
    /// The caller must be the set's **sole updater** for the duration of
    /// the call (the shard worker runs it from idle ticks, where the
    /// single-writer-per-shard discipline provides exactly that);
    /// concurrent *readers* are always safe. Returns true if any work
    /// was done. The default (fixed tables, lists, skip lists) does
    /// nothing.
    fn maintain(&self) -> bool {
        false
    }

    /// The ordered view of this set, if it maintains key order
    /// (skip-list-backed structures). Hash shards return `None`; the
    /// wire layer rejects `RANGE`/`SCAN` for them at classification time.
    fn as_ordered(&self) -> Option<&dyn OrderedSet> {
        None
    }
}

/// One ordered query of a burst: a closed key interval or a cursor page.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RangeQuery {
    /// All pairs with `lo <= key <= hi`, in key order.
    Range(u64, u64),
    /// Up to `n` pairs with `key > cursor`, in key order (cursor paging:
    /// pass the last key of the previous page to continue).
    Scan(u64, usize),
}

impl RangeQuery {
    /// Smallest key the query can match (`u64::MAX` for an exhausted
    /// scan cursor — such a query matches nothing).
    pub fn lo(&self) -> u64 {
        match *self {
            RangeQuery::Range(lo, _) => lo,
            RangeQuery::Scan(cursor, _) => cursor.saturating_add(1),
        }
    }

    /// Whether `key` is still below the query's window (the walk has not
    /// reached it yet).
    pub fn starts_after(&self, key: u64) -> bool {
        key < self.lo()
    }

    /// Whether the query accepts `key`, given `taken` pairs already
    /// collected for it.
    pub fn accepts(&self, key: u64, taken: usize) -> bool {
        match *self {
            RangeQuery::Range(lo, hi) => lo <= key && key <= hi,
            RangeQuery::Scan(cursor, n) => key > cursor && taken < n,
        }
    }

    /// Whether the query can accept no further key `>= key` (the walk may
    /// retire it).
    pub fn done(&self, key: u64, taken: usize) -> bool {
        match *self {
            RangeQuery::Range(_, hi) => key > hi,
            RangeQuery::Scan(cursor, n) => taken >= n || cursor == u64::MAX,
        }
    }
}

/// Key-ordered extension of [`ConcurrentSet`], implemented by the
/// skip-list families. All traversals are lock-free, EBR-pinned and
/// **psync-free**: an ordered read walks the volatile bottom level and
/// never helps-flushes (NVTraverse's destination-only principle — reads
/// have no destination to persist), so a scan of any length costs zero
/// fences and zero flushes.
pub trait OrderedSet: ConcurrentSet {
    /// All `(key, value)` pairs with `lo <= key <= hi`, in key order.
    fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)>;

    /// Up to `n` pairs with `key > cursor`, in key order. An empty result
    /// means the cursor is exhausted; otherwise the last returned key is
    /// the next cursor.
    fn scan(&self, cursor: u64, n: usize) -> Vec<(u64, u64)>;

    /// Resolve a whole burst of ordered queries in one traversal where
    /// possible (the **merge-walk**): results in query order, each in key
    /// order. The default loops; the skip lists override it with one EBR
    /// pin + one tower descent at the smallest `lo` + a single forward
    /// bottom-level walk serving every query window.
    fn range_batch(&self, queries: &[RangeQuery]) -> Vec<Vec<(u64, u64)>> {
        queries
            .iter()
            .map(|q| match *q {
                RangeQuery::Range(lo, hi) => self.range(lo, hi),
                RangeQuery::Scan(cursor, n) => self.scan(cursor, n),
            })
            .collect()
    }
}

/// Apply a batch under one [`crate::pmem::PsyncScope`]: per-op fences are
/// elided and one trailing fence commits the whole batch. This is the
/// override body shared by all durable families.
pub fn apply_batch_coalesced<S: ConcurrentSet + ?Sized>(set: &S, ops: &[SetOp]) -> Vec<OpResult> {
    let _scope = crate::pmem::psync_scope();
    ops.iter().map(|&op| set.apply_one(op)).collect()
}

/// Growth statistics of a resizable hash set (exposed per shard through
/// `coordinator::Metrics` and the server's `STATS` line).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GrowthStats {
    /// Current bucket-array size.
    pub buckets: usize,
    /// Doublings since construction/recovery.
    pub doublings: u64,
    /// Approximate live items (striped-counter sum).
    pub items: usize,
}

impl GrowthStats {
    /// Average chain length (items per bucket).
    pub fn chain_load(&self) -> f64 {
        self.items as f64 / self.buckets.max(1) as f64
    }
}

/// Algorithm family selector used by benches, the coordinator and the CLI.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Family {
    LinkFree,
    Soft,
    LogFree,
    NvTraverse,
    Volatile,
}

impl Family {
    pub const ALL: [Family; 5] = [
        Family::LinkFree,
        Family::Soft,
        Family::LogFree,
        Family::NvTraverse,
        Family::Volatile,
    ];

    /// The durable families: the paper's three plus the NVTraverse
    /// follow-on (the fences/op ablation compares all four).
    pub const DURABLE: [Family; 4] =
        [Family::LinkFree, Family::Soft, Family::LogFree, Family::NvTraverse];

    pub fn name(&self) -> &'static str {
        match self {
            Family::LinkFree => "link-free",
            Family::Soft => "soft",
            Family::LogFree => "log-free",
            Family::NvTraverse => "nvtraverse",
            Family::Volatile => "volatile",
        }
    }

    pub fn parse(s: &str) -> Option<Family> {
        match s.to_ascii_lowercase().as_str() {
            "link-free" | "linkfree" | "lf" => Some(Family::LinkFree),
            "soft" => Some(Family::Soft),
            "log-free" | "logfree" => Some(Family::LogFree),
            "nvtraverse" | "nv-traverse" | "nv" => Some(Family::NvTraverse),
            "volatile" | "harris" => Some(Family::Volatile),
            _ => None,
        }
    }
}

impl std::fmt::Display for Family {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Construct a list of the given family behind the common trait.
pub fn new_list(family: Family) -> Box<dyn ConcurrentSet> {
    match family {
        Family::LinkFree => Box::new(linkfree::LfList::new()),
        Family::Soft => Box::new(soft::SoftList::new()),
        Family::LogFree => Box::new(logfree::LogFreeList::new()),
        Family::NvTraverse => Box::new(nvtraverse::NvList::new()),
        Family::Volatile => Box::new(volatile::VolatileList::new()),
    }
}

/// Construct a hash set of the given family with `nbuckets` *initial*
/// buckets. Durable families get the resizable table (the array doubles
/// under load); the volatile ablation keeps its fixed table.
pub fn new_hash(family: Family, nbuckets: usize) -> Box<dyn ConcurrentSet> {
    match family {
        Family::LinkFree => Box::new(ResizableHash::new_linkfree(nbuckets)),
        Family::Soft => Box::new(ResizableHash::new_soft(nbuckets)),
        Family::LogFree => Box::new(ResizableHash::new_logfree(nbuckets)),
        Family::NvTraverse => Box::new(ResizableHash::new_nvtraverse(nbuckets)),
        Family::Volatile => Box::new(volatile::VolatileHash::new(nbuckets)),
    }
}

/// Construct a key-ordered (skip-list) store of the given family. Only
/// the link-free and SOFT families have durable skip lists; the config
/// layer rejects `structure=skiplist` for the others before this is
/// reachable.
pub fn new_skiplist(family: Family) -> Box<dyn ConcurrentSet> {
    match family {
        Family::LinkFree => Box::new(linkfree::LfSkipList::new()),
        Family::Soft => Box::new(soft::SoftSkipList::new()),
        Family::LogFree | Family::NvTraverse | Family::Volatile => {
            panic!("no skip-list structure for family {family} (config validates this)")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_batch_matches_single_op_semantics() {
        for family in Family::ALL {
            let set = new_hash(family, 16);
            let ops = vec![
                SetOp::Insert(1, 10),
                SetOp::Insert(1, 11),
                SetOp::Get(1),
                SetOp::Contains(2),
                SetOp::Remove(1),
                SetOp::Remove(1),
                SetOp::Get(1),
            ];
            let res = set.apply_batch(&ops);
            assert_eq!(
                res,
                vec![
                    OpResult::Applied(true),
                    OpResult::Applied(false),
                    OpResult::Value(Some(10)),
                    OpResult::Found(false),
                    OpResult::Applied(true),
                    OpResult::Applied(false),
                    OpResult::Value(None),
                ],
                "{family}"
            );
        }
    }

    #[test]
    fn batched_updates_share_one_trailing_fence() {
        // SOFT pays exactly 1 fence per successful update; a K-batch must
        // pay exactly 1 trailing fence total (the 1/K headline). The other
        // families elide *at least* their per-op fences the same way.
        let set = new_hash(Family::Soft, 1 << 10);
        for k in 0..32u64 {
            assert!(set.insert(k, k)); // warm up allocator areas
        }
        let ops: Vec<SetOp> = (100..164u64).map(|k| SetOp::Insert(k, k * 3)).collect();
        let a = crate::pmem::stats::thread_snapshot();
        let res = set.apply_batch(&ops);
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert!(res.iter().all(|r| *r == OpResult::Applied(true)));
        assert_eq!(d.fences, 1, "64 batched soft inserts = one trailing fence");
        assert_eq!(d.elided, 64, "each op's own fence is elided");
        assert_eq!(d.flushes, 64, "flushes still happen per-op");
    }

    #[test]
    fn contains_and_get_batch_match_singles_and_stay_psync_free() {
        for family in Family::ALL {
            let set = new_hash(family, 16);
            for k in (0..200u64).step_by(2) {
                assert!(set.insert(k, k + 1));
            }
            let keys: Vec<u64> = (0..200u64).collect();
            let a = crate::pmem::stats::thread_snapshot();
            let present = set.contains_batch(&keys);
            let values = set.get_batch(&keys);
            let d = crate::pmem::stats::thread_snapshot().since(&a);
            for (i, &k) in keys.iter().enumerate() {
                assert_eq!(present[i], k % 2 == 0, "{family}: contains_batch key {k}");
                assert_eq!(
                    values[i],
                    if k % 2 == 0 { Some(k + 1) } else { None },
                    "{family}: get_batch key {k}"
                );
            }
            assert_eq!(d.fences, 0, "{family}: batched reads must not fence");
            assert_eq!(d.flushes, 0, "{family}: batched reads must not flush");
        }
    }

    #[test]
    fn batched_reads_cost_nothing() {
        let set = new_hash(Family::Soft, 64);
        for k in 0..64u64 {
            assert!(set.insert(k, k + 1));
        }
        let ops: Vec<SetOp> = (0..64u64).map(SetOp::Get).collect();
        let a = crate::pmem::stats::thread_snapshot();
        let res = set.apply_batch(&ops);
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        for (k, r) in res.iter().enumerate() {
            assert_eq!(*r, OpResult::Value(Some(k as u64 + 1)));
        }
        assert_eq!(d.fences, 0, "a read-only batch owes no trailing fence");
        assert_eq!(d.flushes, 0);
    }

    #[test]
    fn range_query_windows() {
        let r = RangeQuery::Range(10, 20);
        assert_eq!(r.lo(), 10);
        assert!(r.starts_after(9) && !r.starts_after(10));
        assert!(r.accepts(10, 0) && r.accepts(20, 1000) && !r.accepts(21, 0));
        assert!(r.done(21, 0) && !r.done(20, 0));
        let s = RangeQuery::Scan(10, 2);
        assert_eq!(s.lo(), 11);
        assert!(!s.accepts(10, 0) && s.accepts(11, 0) && s.accepts(u64::MAX, 1));
        assert!(s.done(0, 2), "page full retires the scan");
        let exhausted = RangeQuery::Scan(u64::MAX, 5);
        assert_eq!(exhausted.lo(), u64::MAX);
        assert!(!exhausted.accepts(u64::MAX, 0), "cursor MAX matches nothing");
        assert!(exhausted.done(0, 0));
    }

    #[test]
    fn ordered_view_gated_to_skiplists() {
        for family in Family::ALL {
            let hash = new_hash(family, 16);
            assert!(hash.as_ordered().is_none(), "{family}: hash order is not key order");
        }
        for family in [Family::LinkFree, Family::Soft] {
            let set = new_skiplist(family);
            for k in (0..100u64).step_by(2) {
                assert!(set.insert(k, k + 1));
            }
            let ord = set.as_ordered().expect("skip lists are ordered");
            let a = crate::pmem::stats::thread_snapshot();
            let win = ord.range(10, 20);
            let page = ord.scan(9, 3);
            let both = ord.range_batch(&[RangeQuery::Range(10, 20), RangeQuery::Scan(9, 3)]);
            let d = crate::pmem::stats::thread_snapshot().since(&a);
            let expect: Vec<(u64, u64)> =
                (10..=20u64).filter(|k| k % 2 == 0).map(|k| (k, k + 1)).collect();
            assert_eq!(win, expect, "{family}");
            assert_eq!(page, vec![(10, 11), (12, 13), (14, 15)], "{family}");
            assert_eq!(both, vec![win.clone(), page.clone()], "{family}: merge-walk == singles");
            assert_eq!(d.fences, 0, "{family}: ordered reads must not fence");
            assert_eq!(d.flushes, 0, "{family}: ordered reads must not flush");
        }
    }
}
