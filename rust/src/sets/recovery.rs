//! The unified recovery engine: one area-scan framework for every durable
//! family.
//!
//! The paper's recovery story — no log, no durable links, just scan the
//! allocator areas and re-classify every slot by the family's validity
//! scheme — is exactly what makes recovery *parallel* for free: areas are
//! independent by construction (per-thread pools of fixed-size slots), so
//! disjoint area ranges can be scanned, classified and normalised by a
//! worker pool with no synchronisation beyond the final merge (the
//! free-list pushes run centralised afterwards — see [`scan`]). Relinking
//! partitions the same way: chains are rebuilt from one sorted member
//! run, so workers own disjoint contiguous segments (single list) or
//! disjoint bucket ranges (fixed hash) and never write the same link
//! cell.
//!
//! The engine owns area iteration, parallel classification + reclamation,
//! member sorting and parallel relink; a family contributes only its
//! validity rule and link-word shape through [`Classify`]. The three
//! durable families' recovery modules, both skip lists and the resizable
//! hashes all route through here (DESIGN.md §Recovery).
//!
//! **Generation words** (`alloc::area::slot_gen`) are allocator metadata
//! for hint/tower ABA validation: classification never reads them,
//! normalisation never writes them, and they need no restoration — they
//! survive in the adopted regions and `DurablePool::free` re-bumps them
//! for every reclaimed slot.
//!
//! **Psync discipline.** Scanning, sorting and relinking issue *zero*
//! psyncs — member content is already durable and links are volatile by
//! design (log-free persists its relinked chains with the same single
//! bulk persist it always paid). The only psyncs of a recovery are the
//! final `persist_all_regions` + anchor persists that the sequential path
//! always issued, all on the coordinating thread; the differential tests
//! (`rust/tests/recovery_parallel.rs`) pin parallel == sequential fence
//! and flush counts exactly.

use crate::alloc::DurablePool;
use crate::pmem::region::RegionTag;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// What recovery found in the durable areas.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveredStats {
    /// Slots relinked as set members.
    pub members: usize,
    /// Slots reclaimed to free-lists (never-used, deleted, or interrupted
    /// inserts — the paper's "memory leaks fixed by the validity scheme").
    pub reclaimed: usize,
}

/// Wall-clock cost of each recovery phase (per pool; the coordinator sums
/// them across shards for `RecoveryReport`).
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimings {
    /// Area scan: classification + reclamation (log-free: plus the anchor
    /// walk that discovers reachability).
    pub scan: Duration,
    /// Sorting the member run (and the set-uniqueness check).
    pub sort: Duration,
    /// Rebuilding the volatile chains.
    pub relink: Duration,
}

impl PhaseTimings {
    pub fn total(&self) -> Duration {
        self.scan + self.sort + self.relink
    }
}

impl std::ops::AddAssign for PhaseTimings {
    fn add_assign(&mut self, rhs: PhaseTimings) {
        self.scan += rhs.scan;
        self.sort += rhs.sort;
        self.relink += rhs.relink;
    }
}

/// A family's contribution to the engine: its validity rule and the shape
/// of its link words. Member handles are `usize`-packed node pointers
/// (durable nodes for link-free/log-free, fresh volatile SNodes for SOFT)
/// so they can cross the worker-pool threads.
///
/// # Safety contract
/// `classify` is called exactly once per slot of the adopted pool;
/// `link`/`link_word` only on handles `classify` returned. `link_word`
/// must be pure (workers call it for a segment boundary *before* the
/// owning worker has linked that node).
pub trait Classify: Sync {
    /// Family tag for diagnostics/assertions.
    const FAMILY: &'static str;

    /// Chain-terminator link word (null pointer in the family's encoding).
    const NULL_LINK: u64;

    /// Classify one durable slot: `Some((sort key, member handle))` for a
    /// member; `None` for a slot the engine must normalise and reclaim.
    ///
    /// # Safety
    /// `slot` points at a live slot of the pool being scanned.
    unsafe fn classify(&self, slot: *mut u8) -> Option<(u64, usize)>;

    /// The word a predecessor (or a head/bucket cell) stores to reference
    /// `node`. Must not read or write `node`'s link cell.
    ///
    /// # Safety
    /// `node` is a member handle returned by [`Classify::classify`].
    unsafe fn link_word(&self, node: usize) -> u64;

    /// Store `next` as `node`'s successor, plus family fixups (flush
    /// flags, state bits). Zero psyncs: membership is already durable.
    ///
    /// # Safety
    /// `node` is a member handle returned by [`Classify::classify`];
    /// called exactly once per member, by exactly one worker.
    unsafe fn link(&self, node: usize, next: u64);

    /// Map a member handle the engine decided to *demote* (it is a
    /// same-key duplicate — a crash mid-compaction leaves both the source
    /// and the migrated copy valid) back to its durable slot, releasing
    /// any volatile side allocation the handle carried. Default: the
    /// handle IS the durable slot (link-free / log-free); SOFT overrides
    /// to free the fresh SNode and return its `pptr`.
    ///
    /// # Safety
    /// `handle` came from this classifier's [`Classify::classify`] (or the
    /// planned materialise) during the current recovery, and is dropped
    /// from the member run by the caller.
    unsafe fn demote_duplicate(&self, handle: usize) -> *mut u8 {
        handle as *mut u8
    }
}

/// Upper bound on engine workers (scoped threads share the process tid
/// table with EBR and the allocator; 32 is far past the scan's memory-
/// bandwidth saturation point).
pub const MAX_RECOVERY_THREADS: usize = 32;

/// Below this many members a parallel relink is pure spawn overhead.
const PAR_RELINK_MIN: usize = 4096;

/// Below this many members the member-run sort stays single-threaded
/// (aligned with [`PAR_RELINK_MIN`] so one scale threshold governs both
/// post-scan phases; the single-threaded sort only *shows* at millions of
/// slots, but engaging the parallel path at test scale keeps it honest).
const PAR_SORT_MIN: usize = 4096;

/// Recovery worker count: `DURASETS_RECOVERY_THREADS` if set, else the
/// machine's available parallelism, clamped to [1, MAX_RECOVERY_THREADS].
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("DURASETS_RECOVERY_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.clamp(1, MAX_RECOVERY_THREADS);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, MAX_RECOVERY_THREADS)
}

/// The classified image of one pool: the member run (key, handle) plus
/// stats and per-phase timings. Produced by [`scan`]; consumed by the
/// sort + relink methods.
pub struct Scan {
    /// `(sort key, member handle)` — unsorted until a sort method runs.
    pub members: Vec<(u64, usize)>,
    pub stats: RecoveredStats,
    pub timings: PhaseTimings,
    family: &'static str,
    threads: usize,
}

/// Contiguous `parts`-way partition of `0..len` (bounds for segment and
/// worker assignment; empty ranges are skipped by callers).
fn segments(len: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, len.max(1));
    let chunk = len.div_ceil(parts);
    (0..parts)
        .map(|w| (w * chunk, ((w + 1) * chunk).min(len)))
        .filter(|(s, e)| s < e)
        .collect()
}

/// Scan every slot of `pool`'s durable areas, classifying through `c`:
/// members are collected, everything else is normalised to the family's
/// free pattern and reclaimed. With `threads > 1` the areas — independent
/// fixed-size allocations — are distributed over a worker pool through an
/// atomic area cursor; workers classify and normalise with no locking.
///
/// The occupancy bitmaps are rebuilt in the same pass: each worker zeroes
/// its area's (untrusted, possibly stale) bitmap header, then sets the
/// bit of every classified member — a reclaimed slot simply keeps its
/// clear bit, which *is* the new allocator's free state, so the old
/// per-slot `free` push is gone entirely. [`DurablePool::rebuild_index`]
/// then derives the volatile upper level (fill counts, lookup, class
/// stacks) from the finished bitmaps. Gen bumps + durability-obligation
/// forfeits for reclaimed slots still run centralised on the calling
/// thread (no psyncs; parity with the sequential path).
pub fn scan<C: Classify>(pool: &DurablePool, c: &C, threads: usize) -> Scan {
    let t0 = Instant::now();
    let slot_size = pool.slot_size();
    let areas: Vec<crate::pmem::region::RegionRef> = pool
        .regions()
        .into_iter()
        .filter(|r| r.tag == RegionTag::Slots)
        .collect();

    // One worker's pass over one area: rebuild the bitmap, classify
    // members, normalise and collect (not yet gen-bumped) the rest.
    let scan_area = |r: &crate::pmem::region::RegionRef,
                     members: &mut Vec<(u64, usize)>,
                     reclaim: &mut Vec<usize>| {
        unsafe { crate::alloc::area::clear_region_bitmap(r) };
        let n = (r.len - r.hdr) / slot_size;
        let base = r.base as usize + r.hdr;
        for i in 0..n {
            let slot = (base + i * slot_size) as *mut u8;
            unsafe {
                match c.classify(slot) {
                    Some(m) => {
                        crate::alloc::area::mark_region_slot_live(r, slot);
                        members.push(m);
                    }
                    None => {
                        pool.normalize_slot(slot);
                        reclaim.push(slot as usize);
                    }
                }
            }
        }
    };

    let threads = threads.clamp(1, MAX_RECOVERY_THREADS);
    let mut members: Vec<(u64, usize)> = Vec::new();
    let mut reclaim: Vec<usize> = Vec::new();
    if threads <= 1 || areas.len() <= 1 {
        for r in &areas {
            scan_area(r, &mut members, &mut reclaim);
        }
    } else {
        let cursor = AtomicUsize::new(0);
        let workers = threads.min(areas.len());
        let outs: Vec<(Vec<(u64, usize)>, Vec<usize>)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let cursor = &cursor;
                    let areas = &areas;
                    let scan_area = &scan_area;
                    s.spawn(move || {
                        let mut local = Vec::new();
                        let mut rec = Vec::new();
                        loop {
                            let a = cursor.fetch_add(1, Ordering::Relaxed);
                            if a >= areas.len() {
                                break;
                            }
                            scan_area(&areas[a], &mut local, &mut rec);
                        }
                        (local, rec)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (local, rec) in outs {
            members.extend(local);
            reclaim.extend(rec);
        }
    }
    // Centralised reclamation bookkeeping (no psyncs): the clear bit is
    // the free state; the gen bump + obligation forfeit mirror what the
    // old free-list push did for each reclaimed slot.
    for &slot in &reclaim {
        unsafe {
            crate::alloc::area::slot_gen(slot as *const u8, slot_size)
                .fetch_add(1, Ordering::Release);
        }
        crate::pmem::check::note_freed(slot as *const u8, slot_size);
    }
    pool.rebuild_index();

    let stats = RecoveredStats { members: members.len(), reclaimed: reclaim.len() };
    Scan {
        members,
        stats,
        timings: PhaseTimings { scan: t0.elapsed(), ..Default::default() },
        family: C::FAMILY,
        threads,
    }
}

/// Build a [`Scan`] from a *precomputed* membership plan — the
/// accelerated classification path, where an XLA artifact already decided
/// `member[i]` per slot. `materialise` turns a member slot into its run
/// entry (the slot itself for link-free; a fresh volatile node for SOFT);
/// non-members are normalised and reclaimed exactly as in [`scan`], with
/// frees on the calling thread. The returned [`Scan`] then shares the
/// exact path's sort/relink machinery, so the two paths cannot diverge.
pub fn scan_planned(
    pool: &DurablePool,
    slots: &[usize],
    is_member: impl Fn(usize) -> bool,
    materialise: impl FnMut(usize, *mut u8) -> (u64, usize),
    family: &'static str,
    threads: usize,
) -> Scan {
    let t0 = Instant::now();
    let slot_size = pool.slot_size();
    // Same bitmap rebuild as [`scan`]: zero every area header, set member
    // bits (region found by binary search — the slot list is flat), and
    // derive the upper index at the end.
    let mut areas: Vec<crate::pmem::region::RegionRef> = pool
        .regions()
        .into_iter()
        .filter(|r| r.tag == RegionTag::Slots)
        .collect();
    areas.sort_unstable_by_key(|r| r.base as usize);
    for r in &areas {
        unsafe { crate::alloc::area::clear_region_bitmap(r) };
    }
    let region_of = |addr: usize| -> &crate::pmem::region::RegionRef {
        let i = areas.partition_point(|r| (r.base as usize) <= addr);
        debug_assert!(i > 0);
        &areas[i - 1]
    };
    let mut materialise = materialise;
    let mut members = Vec::new();
    let mut reclaimed = 0usize;
    for (i, &s) in slots.iter().enumerate() {
        let slot = s as *mut u8;
        if is_member(i) {
            unsafe { crate::alloc::area::mark_region_slot_live(region_of(s), slot) };
            members.push(materialise(i, slot));
        } else {
            unsafe {
                pool.normalize_slot(slot);
                crate::alloc::area::slot_gen(slot as *const u8, slot_size)
                    .fetch_add(1, Ordering::Release);
            }
            crate::pmem::check::note_freed(slot as *const u8, slot_size);
            reclaimed += 1;
        }
    }
    pool.rebuild_index();
    let stats = RecoveredStats { members: members.len(), reclaimed };
    Scan {
        members,
        stats,
        timings: PhaseTimings { scan: t0.elapsed(), ..Default::default() },
        family,
        threads: threads.clamp(1, MAX_RECOVERY_THREADS),
    }
}

/// The durable image must be a *set* (paper Claim B.12 for link-free; the
/// walk/flag schemes of the others give the same invariant). Run must be
/// sorted so equal keys are adjacent — one pass suffices. Shared by
/// [`Scan`] and the accelerated recovery paths.
pub fn assert_unique_sorted(members: &[(u64, usize)], family: &str) {
    for w in members.windows(2) {
        assert_ne!(
            w[0].0, w[1].0,
            "{}: duplicate key {} in durable image",
            family, w[0].0
        );
    }
}

/// Merge two sorted runs into `out` (`out.len() == a.len() + b.len()`),
/// comparing by `key`. Ties prefer `a` (stability across runs; keys are
/// unique in valid images anyway — `assert_unique_sorted` enforces it).
fn merge_into<K: Ord>(
    a: &[(u64, usize)],
    b: &[(u64, usize)],
    out: &mut [(u64, usize)],
    key: &impl Fn(u64) -> K,
) {
    debug_assert_eq!(out.len(), a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    for slot in out.iter_mut() {
        *slot = if j >= b.len() || (i < a.len() && key(a[i].0) <= key(b[j].0)) {
            let x = a[i];
            i += 1;
            x
        } else {
            let x = b[j];
            j += 1;
            x
        };
    }
}

/// Parallel merge sort over the member run: contiguous chunks are sorted
/// on a scoped worker pool, then log₂(chunks) rounds of pairwise merges —
/// each round's merges are independent (disjoint output ranges carved
/// with `split_at_mut`) and also run on scoped workers. Falls back to
/// `sort_unstable_by_key` below [`PAR_SORT_MIN`] or with one thread.
/// Zero psyncs by construction: this is pure volatile compute over the
/// already-durable member run, so the engine's fence/flush pins
/// (`rust/tests/recovery_parallel.rs`) hold bit-identically.
fn par_sort_by<K, F>(v: &mut Vec<(u64, usize)>, threads: usize, key: F)
where
    K: Ord,
    F: Fn(u64) -> K + Sync,
{
    let len = v.len();
    let threads = threads.clamp(1, MAX_RECOVERY_THREADS);
    if threads <= 1 || len < PAR_SORT_MIN {
        v.sort_unstable_by_key(|m| key(m.0));
        return;
    }
    let chunk = len.div_ceil(threads.min(len));
    std::thread::scope(|s| {
        for c in v.chunks_mut(chunk) {
            let key = &key;
            s.spawn(move || c.sort_unstable_by_key(|m| key(m.0)));
        }
    });
    let mut runs: Vec<(usize, usize)> =
        (0..len).step_by(chunk).map(|s| (s, (s + chunk).min(len))).collect();
    let mut src = std::mem::take(v);
    let mut dst = vec![(0u64, 0usize); len];
    while runs.len() > 1 {
        let mut next: Vec<(usize, usize)> = Vec::with_capacity(runs.len().div_ceil(2));
        std::thread::scope(|s| {
            // Carve disjoint output windows off the scratch buffer; runs
            // are contiguous from 0, so windows line up with run bounds.
            let mut out_rest: &mut [(u64, usize)] = &mut dst;
            let mut i = 0;
            while i < runs.len() {
                let (s0, e0) = runs[i];
                let (s1, e1) = if i + 1 < runs.len() { runs[i + 1] } else { (e0, e0) };
                let (out, rest) = std::mem::take(&mut out_rest).split_at_mut(e1 - s0);
                out_rest = rest;
                next.push((s0, e1));
                let src = &src;
                let key = &key;
                s.spawn(move || merge_into(&src[s0..e0], &src[s1..e1], out, key));
                i += 2;
            }
        });
        std::mem::swap(&mut src, &mut dst);
        runs = next;
    }
    *v = src;
}

/// Below this many members the skip-list index rebuild stays
/// single-threaded (aligned with the other post-scan thresholds).
const PAR_INDEX_MIN: usize = 4096;

/// Rebuild a skip list's volatile tower index from recovered `(key,
/// node-ptr)` pairs across `threads` scoped workers. Both families'
/// `index_insert` is a CAS-based bottom-up insertion over the volatile
/// towers, safe under concurrent calls, and `random_height` is
/// deterministic in the key — so a parallel rebuild produces the *same
/// tower set* as the old sequential walk, in whatever interleaving. Zero
/// psyncs by construction: towers are pure volatile compute, so the
/// engine's fence/flush pins (`rust/tests/recovery_parallel.rs`) hold
/// bit-identically at any thread count. Node pointers travel as `usize`
/// (raw pointers aren't `Send`; the nodes themselves are shared-readable
/// during rebuild).
pub fn par_index_rebuild(
    pairs: &[(u64, usize)],
    threads: usize,
    insert: impl Fn(u64, usize) + Sync,
) {
    let threads = threads.clamp(1, MAX_RECOVERY_THREADS);
    if threads <= 1 || pairs.len() < PAR_INDEX_MIN {
        for &(key, node) in pairs {
            insert(key, node);
        }
        return;
    }
    let chunk = pairs.len().div_ceil(threads);
    std::thread::scope(|s| {
        for c in pairs.chunks(chunk) {
            let insert = &insert;
            s.spawn(move || {
                for &(key, node) in c {
                    insert(key, node);
                }
            });
        }
    });
}

impl Scan {
    /// Sort the member run by key (single-chain shapes: lists, skip-list
    /// bottom levels, the resizable families' okey order). Parallel merge
    /// sort on the engine's worker budget past [`PAR_SORT_MIN`].
    pub fn sort_by_key(&mut self) {
        let t0 = Instant::now();
        par_sort_by(&mut self.members, self.threads, |k| k);
        self.timings.sort += t0.elapsed();
    }

    /// Drop same-key duplicates from the sorted run, keeping the first of
    /// each key and demoting the rest back to free slots. A clean image
    /// has none (paper Claim B.12) — but a crash *during a compaction
    /// migration* legitimately leaves both the source node and its
    /// migrated copy valid (the copy-then-relink window), and recovery
    /// resolves that here: the duplicate is freed through the pool (bit
    /// cleared, accounting fixed — the scan set its bit and counted it),
    /// zero psyncs. Ends with the uniqueness assertion the sorts used to
    /// carry, so a genuinely corrupt image still fails loudly.
    ///
    /// # Safety
    /// `c` is the classifier the scan ran with; the run is sorted so equal
    /// keys are adjacent; [`DurablePool::rebuild_index`] has run (the
    /// engine's scans guarantee it).
    pub unsafe fn dedup_duplicates<C: Classify>(&mut self, c: &C, pool: &DurablePool) -> usize {
        let t0 = Instant::now();
        let mut dropped = 0usize;
        let mut i = 1;
        while i < self.members.len() {
            if self.members[i].0 == self.members[i - 1].0 {
                let (_, handle) = self.members.remove(i);
                let slot = c.demote_duplicate(handle);
                // Free contract: the slot must re-enter circulation
                // recoverable-as-free, and a demoted duplicate still
                // carries member flags — normalise first (persisted by
                // the recovery flow's final persist_all_regions).
                pool.normalize_slot(slot);
                pool.free(slot);
                dropped += 1;
            } else {
                i += 1;
            }
        }
        assert_unique_sorted(&self.members, self.family);
        self.stats.members -= dropped;
        self.stats.reclaimed += dropped;
        self.timings.sort += t0.elapsed();
        dropped
    }

    /// Sort the member run by `(bucket, key)` (fixed-bucket hash shapes).
    /// Duplicate keys stay adjacent (same key ⇒ same bucket), so the
    /// set-uniqueness check still holds. Parallel past [`PAR_SORT_MIN`].
    pub fn sort_by_bucket(&mut self, bucket_of: impl Fn(u64) -> usize + Sync) {
        let t0 = Instant::now();
        par_sort_by(&mut self.members, self.threads, |k| (bucket_of(k), k));
        self.timings.sort += t0.elapsed();
    }

    /// Relink the (key-sorted) member run into one chain; returns the head
    /// link word. Parallel: workers own disjoint contiguous segments and
    /// stitch at the boundaries — worker `w`'s tail links to the
    /// `link_word` of segment `w+1`'s first member, which is pure, so no
    /// worker ever writes another worker's link cells. Zero psyncs.
    ///
    /// # Safety
    /// `c` must be the same classifier the scan ran with, and the run must
    /// be sorted.
    pub unsafe fn relink_chain<C: Classify>(&mut self, c: &C) -> u64 {
        let t0 = Instant::now();
        // Safety net for callers that skipped dedup: a duplicate here
        // would double-link one key.
        assert_unique_sorted(&self.members, self.family);
        let head = relink_chain_run(c, &self.members, self.threads);
        self.timings.relink += t0.elapsed();
        head
    }

    /// Relink the (`(bucket, key)`-sorted) member run into one chain per
    /// bucket; returns `(bucket, head word)` pairs in ascending bucket
    /// order (buckets with no members are omitted — callers start from
    /// empty tables). Parallel: whole bucket groups are assigned to
    /// workers, so no two workers ever touch the same chain. Zero psyncs.
    ///
    /// # Safety
    /// As [`Scan::relink_chain`]; `bucket_of` must match the sort.
    pub unsafe fn relink_buckets<C: Classify>(
        &mut self,
        c: &C,
        bucket_of: &(impl Fn(u64) -> usize + Sync),
    ) -> Vec<(usize, u64)> {
        let t0 = Instant::now();
        assert_unique_sorted(&self.members, self.family);
        // Bucket-group boundaries over the sorted run.
        let mut groups: Vec<(usize, usize, usize)> = Vec::new(); // (bucket, start, end)
        let mut i = 0;
        while i < self.members.len() {
            let b = bucket_of(self.members[i].0);
            let mut j = i + 1;
            while j < self.members.len() && bucket_of(self.members[j].0) == b {
                j += 1;
            }
            groups.push((b, i, j));
            i = j;
        }

        let relink_groups = |gs: &[(usize, usize, usize)]| -> Vec<(usize, u64)> {
            gs.iter()
                .map(|&(b, s, e)| (b, unsafe { relink_segment(c, &self.members[s..e], C::NULL_LINK) }))
                .collect()
        };

        let heads = if self.threads <= 1 || self.members.len() < PAR_RELINK_MIN || groups.len() <= 1
        {
            relink_groups(&groups)
        } else {
            let bounds = segments(groups.len(), self.threads);
            let outs: Vec<Vec<(usize, u64)>> = std::thread::scope(|s| {
                let handles: Vec<_> = bounds
                    .iter()
                    .map(|&(gs, ge)| {
                        let relink_groups = &relink_groups;
                        let groups = &groups;
                        s.spawn(move || relink_groups(&groups[gs..ge]))
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            outs.into_iter().flatten().collect()
        };
        self.timings.relink += t0.elapsed();
        heads
    }
}

/// Relink one contiguous sorted segment, terminating at `tail_next`;
/// returns the link word referencing the segment's first member (or
/// `tail_next` when empty).
///
/// # Safety
/// Handles in `seg` came from `c`'s classify; each is linked exactly once.
unsafe fn relink_segment<C: Classify>(c: &C, seg: &[(u64, usize)], tail_next: u64) -> u64 {
    let mut next = tail_next;
    for &(_, node) in seg.iter().rev() {
        c.link(node, next);
        next = c.link_word(node);
    }
    next
}

/// Parallel single-chain relink over a sorted run (shared by [`Scan`] and
/// the accelerated recovery paths).
///
/// # Safety
/// As [`Scan::relink_chain`].
pub unsafe fn relink_chain_run<C: Classify>(c: &C, members: &[(u64, usize)], threads: usize) -> u64 {
    if members.is_empty() {
        return C::NULL_LINK;
    }
    if threads <= 1 || members.len() < PAR_RELINK_MIN {
        return relink_segment(c, members, C::NULL_LINK);
    }
    let bounds = segments(members.len(), threads);
    std::thread::scope(|s| {
        for &(start, end) in &bounds {
            // The boundary word: the link_word of the next segment's first
            // member (pure — that worker has not linked it yet).
            let tail_next = if end == members.len() {
                C::NULL_LINK
            } else {
                c.link_word(members[end].1)
            };
            let seg = &members[start..end];
            s.spawn(move || unsafe {
                relink_segment(c, seg, tail_next);
            });
        }
    });
    c.link_word(members[0].1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segments_cover_and_are_disjoint() {
        for (len, parts) in [(0usize, 4usize), (1, 4), (7, 3), (4096, 8), (10, 64)] {
            let segs = segments(len, parts);
            let mut covered = 0;
            let mut prev_end = 0;
            for &(s, e) in &segs {
                assert!(s < e, "empty segment ({s},{e}) for len={len} parts={parts}");
                assert_eq!(s, prev_end, "gap/overlap at {s} for len={len} parts={parts}");
                covered += e - s;
                prev_end = e;
            }
            assert_eq!(covered, len);
            assert!(segs.len() <= parts.max(1));
        }
    }

    #[test]
    fn par_sort_matches_sequential_sort() {
        let mut rng = crate::util::rng::Xoshiro256::new(0x50_B7);
        for &(n, threads) in
            &[(0usize, 8usize), (1, 8), (100, 8), (PAR_SORT_MIN - 1, 8), (20_000, 8), (20_000, 3)]
        {
            let mut a: Vec<(u64, usize)> =
                (0..n).map(|i| (rng.next_u64() % 50_000, i)).collect();
            let mut b = a.clone();
            par_sort_by(&mut a, threads, |k| k);
            b.sort_unstable_by_key(|m| m.0);
            // Duplicate keys allowed here (sort only; uniqueness is the
            // caller's assert): compare the key sequence, and the handle
            // multiset via length + per-key membership.
            assert_eq!(
                a.iter().map(|m| m.0).collect::<Vec<_>>(),
                b.iter().map(|m| m.0).collect::<Vec<_>>(),
                "n={n} threads={threads}"
            );
            let mut ah: Vec<usize> = a.iter().map(|m| m.1).collect();
            let mut bh: Vec<usize> = b.iter().map(|m| m.1).collect();
            ah.sort_unstable();
            bh.sort_unstable();
            assert_eq!(ah, bh, "n={n} threads={threads}: handles lost/duplicated");
        }
    }

    #[test]
    fn par_sort_composite_key_orders_by_bucket_then_key() {
        let mut v: Vec<(u64, usize)> = (0..10_000u64).rev().map(|k| (k, k as usize)).collect();
        let bucket_of = |k: u64| (k % 7) as usize;
        par_sort_by(&mut v, 8, |k| (bucket_of(k), k));
        for w in v.windows(2) {
            let (a, b) = (w[0].0, w[1].0);
            assert!(
                (bucket_of(a), a) < (bucket_of(b), b),
                "composite order violated: {a} !< {b}"
            );
        }
    }

    #[test]
    fn default_threads_honors_env_and_clamps() {
        // Can't set env safely under parallel tests; just pin the range.
        let t = default_threads();
        assert!((1..=MAX_RECOVERY_THREADS).contains(&t));
    }

    #[test]
    fn phase_timings_accumulate() {
        let mut a = PhaseTimings {
            scan: Duration::from_millis(2),
            sort: Duration::from_millis(3),
            relink: Duration::from_millis(5),
        };
        a += PhaseTimings { scan: Duration::from_millis(1), ..Default::default() };
        assert_eq!(a.scan, Duration::from_millis(3));
        assert_eq!(a.total(), Duration::from_millis(11));
    }
}
