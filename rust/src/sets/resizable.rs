//! Lock-free **resizable** durable hash sets — dynamic bucket-array growth
//! for all three durable families.
//!
//! # Design (split-ordered, without moving a single node)
//!
//! A fixed-bucket table keyed by `mix64(key) & (n-1)` cannot grow without
//! physically re-chaining nodes between buckets, and any scheme that moves
//! nodes needs extra fences or freeze bits the families don't have. This
//! layer takes the split-ordered route instead, adapted to the repo's
//! link-cell protocol:
//!
//! * The structure is **one** list of the family, ordered by
//!   `okey = mix64(key)` (a bijection, so user keys stay unique and
//!   [`crate::util::mix64_inv`] recovers them). Bucket `j` of a table with
//!   `n = 2^L` buckets owns the contiguous okey range
//!   `[j << (64-L), (j+1) << (64-L))` — the *high* hash bits, so doubling
//!   splits every bucket's range exactly in half.
//! * The bucket array holds volatile **entry hints**: tagged pointers to a
//!   linked node whose okey lies at/near the bucket's start. An operation
//!   starts its window search at the hint's own link cell (`&node.next`),
//!   exactly like the skip lists' `find_from` fast path; a stale hint is
//!   detected (deleted/marked/mid-insert state, or okey ≥ search okey) and
//!   falls back to the bucket's ancestors (clear the lowest set index bit,
//!   ≤ log n hops) and finally the list head. Hints are repopulated by
//!   successful inserts.
//! * **Growth** doubles the array when the item count crosses
//!   `GROW_LOAD · n`: allocate, seed both child cells from the parent cell
//!   (safe: hints are only *used* after validation), publish with one CAS.
//!   Migration is therefore pure hint population that piggybacks on normal
//!   operations, costs **zero psyncs**, and never blocks: reads and
//!   updates proceed through the parent hint or head meanwhile.
//! * The **bucket-count epoch** is persisted in a named root cell
//!   (`resizable.<family>.<pool>`), so recovery rebuilds the right table
//!   size: recover the family's list (members relinked in okey order —
//!   exactly this structure's chain), read the epoch, start with empty
//!   hints. The cell encodes `(seq << 8) | (log2n + 1)`: growth max-CASes
//!   the size byte, a **shrink** bumps `seq` so the word stays monotone
//!   while the size drops.
//! * **Compaction + shrink** ride the shard worker's idle tick through
//!   [`ResizableHash::maintain_tick`]: low-fill allocator areas are
//!   claimed off the allocation index, their surviving nodes migrated to
//!   fresh slots with each family's crash-safe copy protocol (every
//!   window a power loss can hit leaves either the original, the copy,
//!   or a same-key duplicate pair that recovery's dedup collapses — the
//!   acked member set is exact at every flush boundary), bucket hints
//!   into the range are dropped, and after EBR grace periods the empty
//!   area is retired and its memory returned to the OS. Sustained low
//!   load halves the bucket array under the same tick (hysteresis keeps
//!   shrinks and doublings from ping-ponging). Maintenance requires the
//!   shard worker's serialization against updates; concurrent readers
//!   are safe throughout.
//!
//! Durability is untouched: the only durable state is the family's own
//! node protocol plus the epoch cell (persisted once per doubling), so
//! updates keep their 1 (SOFT) / ~1 (link-free) / ~2 (log-free) psyncs and
//! `contains`/`get` stay psync-free — asserted by tests below.
//!
//! ## Hint validation is generation-checked (shared with the skip lists)
//!
//! A hint may point at a node that was unlinked, reclaimed and
//! re-allocated after the hint was stored. Hints are therefore published
//! as a packed `(ptr, gen)` word ([`crate::sets::tagged::pack_hint`]):
//! `gen` is the slot's allocation generation, bumped by the pool on every
//! free (which, via EBR retire, only happens after a grace period).
//! Validation under the EBR pin is a seqlock-shaped read — gen, then
//! state + okey, then gen again. A gen mismatch means "the slot was
//! reclaimed since publication": fall back to an ancestor bucket or the
//! head instead of hoping the state check catches the reincarnation. A
//! stable matching gen proves the state/okey reads saw a single slot
//! incarnation — the one the publisher observed *linked* — so the state
//! check's verdict is about the right node: free-pattern, deleted and
//! mid-operation nodes are rejected (SOFT: pre-link `IntendToInsert`;
//! link-free: pre-link invalid; log-free: pre-link `DIRTY`), and a node
//! that passes is linked at its key's sorted position in the single
//! family list — a correct window start, as in Harris traversals. The
//! full argument (including why the closing gen read cannot miss a
//! concurrent bump, and the truncation wraparound window) lives in
//! DESIGN.md §Reclamation. Building with `--features untagged-hints`
//! compiles the gen checks out — the configuration the reclamation-churn
//! harness uses to demonstrate the pre-tag ABA misvalidation.

use crate::alloc::{AreaClaim, DurablePool, Ebr};
use crate::pmem::root::{root_cell, RootCell};
use crate::pmem::PoolId;
use crate::sets::linkfree::{LfList, LfNode, RecoveredStats};
use crate::sets::logfree::{load_link_persisted, LogFreeList, LogFreeNode};
use crate::sets::nvtraverse::NvList;
use crate::sets::soft::{snode_gen, SNode, SoftList};
use crate::sets::tagged::{
    gen_validated, hint_gen, hint_ptr, is_marked, pack_hint, ptr_of, DIRTY, HINT_GEN_MASK, MARK,
};
use crate::sets::{ConcurrentSet, GrowthStats};
use crate::util::tid::tid;
use crate::util::{mix64, mix64_inv, CACHE_LINE};
use crossbeam_utils::CachePadded;
use std::sync::atomic::{AtomicI64, AtomicPtr, AtomicU64, Ordering};
use std::sync::Mutex;

/// Average chain length that triggers a doubling.
pub const GROW_LOAD: usize = 4;

/// Shrink trigger: the item count must stay below `GROW_LOAD << log2n /
/// SHRINK_DIV` for [`SHRINK_STREAK`] consecutive maintenance ticks. The
/// divisor leaves the halved table still 4x under its own grow trigger,
/// so a shrink can never ping-pong with a doubling.
const SHRINK_DIV: i64 = 8;

/// Consecutive low-load maintenance ticks before the table halves.
const SHRINK_STREAK: u32 = 4;

/// Never shrink below 2 buckets.
const SHRINK_MIN_LOG2: u32 = 1;

/// Compaction claims an area only when at least this many of its slots
/// are free (75%: migrating the survivors costs at most a quarter of the
/// area's capacity in copies).
const COMPACT_MIN_FREE: usize = (crate::alloc::area::SLOTS_PER_AREA / 4) * 3;

/// Areas claimed per maintenance tick / maximum claims mid-drain.
const COMPACT_CLAIMS_PER_TICK: usize = 4;
const COMPACT_MAX_DRAINS: usize = 8;

/// Hard cap on the bucket-array size (2^24 cells = 128 MiB of hints).
const MAX_LOG2: u32 = 24;

/// Stripes of the item counter (tid-indexed; two live threads share a
/// stripe only past 64 threads, which just costs a shared fetch_add).
const STRIPES: usize = 64;

/// A stripe publishes its local balance to the shared word once it
/// reaches this magnitude, bounding shared-word contention to 1/32 of
/// updates and the growth trigger's drift to ±32 per live thread.
const STRIPE_SPILL: i64 = 32;

/// Striped insert/remove balance (sloppy counter). The ROADMAP follow-up:
/// the previous single `AtomicI64` was one contended line on every update
/// at high core counts. Invariant: `shared + Σ stripes` is exactly the
/// net number of successful inserts minus removes (each `add` moves value
/// between a stripe and the shared word atomically in sum), so
/// [`StripedItems::sum`] is exact whenever the structure is quiescent.
struct StripedItems {
    shared: CachePadded<AtomicI64>,
    stripes: Box<[CachePadded<AtomicI64>]>,
}

impl StripedItems {
    fn new(initial: i64) -> Self {
        StripedItems {
            shared: CachePadded::new(AtomicI64::new(initial)),
            stripes: (0..STRIPES).map(|_| CachePadded::new(AtomicI64::new(0))).collect(),
        }
    }

    /// Add `d` to the calling thread's stripe. When the stripe spills into
    /// the shared word, returns the refreshed shared estimate (the growth
    /// trigger's cue); otherwise `None`.
    fn add(&self, d: i64) -> Option<i64> {
        let s = &self.stripes[tid() % STRIPES];
        let local = s.fetch_add(d, Ordering::Relaxed) + d;
        if local.abs() >= STRIPE_SPILL {
            s.fetch_sub(local, Ordering::Relaxed);
            Some(self.shared.fetch_add(local, Ordering::Relaxed) + local)
        } else {
            None
        }
    }

    /// Shared word + all stripes (exact at quiescence).
    fn sum(&self) -> i64 {
        self.shared.load(Ordering::Relaxed)
            + self.stripes.iter().map(|s| s.load(Ordering::Relaxed)).sum::<i64>()
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for crate::sets::linkfree::LfList {}
    impl Sealed for crate::sets::soft::SoftList {}
    impl Sealed for crate::sets::logfree::LogFreeList {}
    impl Sealed for crate::sets::nvtraverse::NvList {}
}

/// Family plumbing for [`ResizableHash`] (sealed; implemented by the three
/// durable list types). The methods mirror the cores' hint-aware entry
/// points; all of this is an implementation detail of the resizable layer.
pub trait ResizableFamily: sealed::Sealed + Send + Sync + 'static {
    #[doc(hidden)]
    type Node;
    #[doc(hidden)]
    const FAMILY: &'static str;

    #[doc(hidden)]
    fn head_cell(&self) -> *const AtomicU64;
    #[doc(hidden)]
    fn ebr(&self) -> &Ebr;
    #[doc(hidden)]
    fn insert_from(&self, start: *const AtomicU64, okey: u64, value: u64) -> bool;
    #[doc(hidden)]
    fn remove_from(&self, start: *const AtomicU64, okey: u64) -> bool;
    #[doc(hidden)]
    fn get_from(&self, start: *const AtomicU64, okey: u64) -> Option<u64>;
    #[doc(hidden)]
    fn count(&self) -> usize;
    #[doc(hidden)]
    fn snapshot_okey(&self) -> Vec<(u64, u64)>;
    #[doc(hidden)]
    fn pool(&self) -> PoolId;
    #[doc(hidden)]
    fn durable(&self) -> &DurablePool;
    #[doc(hidden)]
    fn preserve(&self);

    /// Relocate every member whose durable slot lies in `[lo, hi)` to a
    /// fresh slot, per the family's compaction protocol. Returns the
    /// migrated count and (link-free only) the unlinked originals whose
    /// durable delete records are deferred to [`Self::finish_migration`]
    /// after a grace period. Caller must serialize against updates.
    #[doc(hidden)]
    unsafe fn migrate_range(&self, lo: usize, hi: usize) -> (usize, Vec<usize>);

    /// Write the deferred originals' durable delete records and retire
    /// them (no-op for families whose migration has none).
    #[doc(hidden)]
    unsafe fn finish_migration(&self, originals: &[usize]) {
        debug_assert!(originals.is_empty());
    }

    /// The link cell owned by `node` (its `next` word).
    #[doc(hidden)]
    unsafe fn node_link(node: *mut Self::Node) -> *const AtomicU64;
    /// Current allocation generation of `node`'s slot (Acquire; the
    /// `(ptr, gen)` hint tag — see the module docs).
    #[doc(hidden)]
    unsafe fn node_gen(node: *mut Self::Node) -> u64;
    /// `Some(okey)` iff `node` currently looks linked-and-alive (rejects
    /// free-pattern, deleted and mid-operation nodes).
    #[doc(hidden)]
    unsafe fn node_key_if_linked(node: *mut Self::Node) -> Option<u64>;
    /// The linked node holding exactly `okey`, searched from `start`.
    #[doc(hidden)]
    unsafe fn find_linked(&self, start: *const AtomicU64, okey: u64) -> Option<*mut Self::Node>;
}

impl ResizableFamily for LfList {
    type Node = LfNode;
    const FAMILY: &'static str = "linkfree";

    fn head_cell(&self) -> *const AtomicU64 {
        &self.head
    }

    fn ebr(&self) -> &Ebr {
        self.core.ebr.as_ref()
    }

    fn insert_from(&self, start: *const AtomicU64, okey: u64, value: u64) -> bool {
        self.core.insert_from(start, &self.head, okey, value)
    }

    fn remove_from(&self, start: *const AtomicU64, okey: u64) -> bool {
        self.core.remove_from(start, &self.head, okey)
    }

    fn get_from(&self, start: *const AtomicU64, okey: u64) -> Option<u64> {
        self.core.get_from(start, &self.head, okey)
    }

    fn count(&self) -> usize {
        self.core.count(&self.head)
    }

    fn snapshot_okey(&self) -> Vec<(u64, u64)> {
        self.core.snapshot(&self.head)
    }

    fn pool(&self) -> PoolId {
        self.pool_id()
    }

    fn durable(&self) -> &DurablePool {
        &self.core.pool
    }

    fn preserve(&self) {
        self.crash_preserve();
    }

    unsafe fn migrate_range(&self, lo: usize, hi: usize) -> (usize, Vec<usize>) {
        let originals = self.core.migrate_range(&self.head, lo, hi);
        (originals.len(), originals)
    }

    unsafe fn finish_migration(&self, originals: &[usize]) {
        self.core.finish_migration(originals);
    }

    unsafe fn node_link(node: *mut LfNode) -> *const AtomicU64 {
        &(*node).next
    }

    unsafe fn node_gen(node: *mut LfNode) -> u64 {
        crate::alloc::slot_gen(node as *const u8, CACHE_LINE).load(Ordering::Acquire)
    }

    unsafe fn node_key_if_linked(node: *mut LfNode) -> Option<u64> {
        // Free pattern is valid+marked; a deleted node is marked; a
        // mid-insert node is invalid until its link CAS succeeds.
        if is_marked((*node).next.load(Ordering::Acquire)) || !(*node).is_valid() {
            return None;
        }
        Some((*node).key.load(Ordering::Acquire))
    }

    unsafe fn find_linked(&self, start: *const AtomicU64, okey: u64) -> Option<*mut LfNode> {
        let mut curr = ptr_of::<LfNode>((*start).load(Ordering::Acquire));
        while !curr.is_null() {
            let k = (*curr).key.load(Ordering::Relaxed);
            if k > okey {
                return None;
            }
            let next = (*curr).next.load(Ordering::Acquire);
            if k == okey {
                return if is_marked(next) { None } else { Some(curr) };
            }
            curr = ptr_of::<LfNode>(next);
        }
        None
    }
}

impl ResizableFamily for NvList {
    type Node = LfNode;
    const FAMILY: &'static str = "nvtraverse";

    fn head_cell(&self) -> *const AtomicU64 {
        &self.head
    }

    fn ebr(&self) -> &Ebr {
        self.core.inner.ebr.as_ref()
    }

    fn insert_from(&self, start: *const AtomicU64, okey: u64, value: u64) -> bool {
        self.core.insert_from(start, &self.head, okey, value)
    }

    fn remove_from(&self, start: *const AtomicU64, okey: u64) -> bool {
        self.core.remove_from(start, &self.head, okey)
    }

    fn get_from(&self, start: *const AtomicU64, okey: u64) -> Option<u64> {
        self.core.get_from(start, &self.head, okey)
    }

    fn count(&self) -> usize {
        self.core.inner.count(&self.head)
    }

    fn snapshot_okey(&self) -> Vec<(u64, u64)> {
        self.core.inner.snapshot(&self.head)
    }

    fn pool(&self) -> PoolId {
        self.pool_id()
    }

    fn durable(&self) -> &DurablePool {
        &self.core.inner.pool
    }

    fn preserve(&self) {
        self.crash_preserve();
    }

    // Compaction uses the link-free durable-copy machinery unchanged
    // (shared format; the duplicate window is closed by recovery dedup).
    unsafe fn migrate_range(&self, lo: usize, hi: usize) -> (usize, Vec<usize>) {
        let originals = self.core.inner.migrate_range(&self.head, lo, hi);
        (originals.len(), originals)
    }

    unsafe fn finish_migration(&self, originals: &[usize]) {
        self.core.inner.finish_migration(originals);
    }

    unsafe fn node_link(node: *mut LfNode) -> *const AtomicU64 {
        &(*node).next
    }

    unsafe fn node_gen(node: *mut LfNode) -> u64 {
        crate::alloc::slot_gen(node as *const u8, CACHE_LINE).load(Ordering::Acquire)
    }

    unsafe fn node_key_if_linked(node: *mut LfNode) -> Option<u64> {
        // Free pattern is valid+marked; a deleted node is marked; a
        // mid-insert node is invalid until its link CAS succeeds.
        if is_marked((*node).next.load(Ordering::Acquire)) || !(*node).is_valid() {
            return None;
        }
        Some((*node).key.load(Ordering::Acquire))
    }

    unsafe fn find_linked(&self, start: *const AtomicU64, okey: u64) -> Option<*mut LfNode> {
        let mut curr = ptr_of::<LfNode>((*start).load(Ordering::Acquire));
        while !curr.is_null() {
            let k = (*curr).key.load(Ordering::Relaxed);
            if k > okey {
                return None;
            }
            let next = (*curr).next.load(Ordering::Acquire);
            if k == okey {
                return if is_marked(next) { None } else { Some(curr) };
            }
            curr = ptr_of::<LfNode>(next);
        }
        None
    }
}

impl ResizableFamily for SoftList {
    type Node = SNode;
    const FAMILY: &'static str = "soft";

    fn head_cell(&self) -> *const AtomicU64 {
        &self.head
    }

    fn ebr(&self) -> &Ebr {
        self.core.ebr.as_ref()
    }

    fn insert_from(&self, start: *const AtomicU64, okey: u64, value: u64) -> bool {
        self.core.insert_from(start, &self.head, okey, value)
    }

    fn remove_from(&self, start: *const AtomicU64, okey: u64) -> bool {
        self.core.remove_from(start, &self.head, okey)
    }

    fn get_from(&self, start: *const AtomicU64, okey: u64) -> Option<u64> {
        self.core.get_from(start, &self.head, okey)
    }

    fn count(&self) -> usize {
        self.core.count(&self.head)
    }

    fn snapshot_okey(&self) -> Vec<(u64, u64)> {
        self.core.snapshot_from(&self.head)
    }

    fn pool(&self) -> PoolId {
        self.pool_id()
    }

    fn durable(&self) -> &DurablePool {
        &self.core.dpool
    }

    fn preserve(&self) {
        self.crash_preserve();
    }

    unsafe fn migrate_range(&self, lo: usize, hi: usize) -> (usize, Vec<usize>) {
        (self.core.migrate_range(&self.head, lo, hi), Vec::new())
    }

    unsafe fn node_link(node: *mut SNode) -> *const AtomicU64 {
        &(*node).next
    }

    unsafe fn node_gen(node: *mut SNode) -> u64 {
        snode_gen(node)
    }

    unsafe fn node_key_if_linked(node: *mut SNode) -> Option<u64> {
        // Reclaimed SNodes keep their Deleted state; allocated-but-unlinked
        // ones are written as IntendToInsert. Only in-set states pass.
        let s = crate::sets::tagged::State::of((*node).next.load(Ordering::Acquire));
        if s.in_set() {
            Some((*node).key)
        } else {
            None
        }
    }

    unsafe fn find_linked(&self, start: *const AtomicU64, okey: u64) -> Option<*mut SNode> {
        let mut curr = ptr_of::<SNode>((*start).load(Ordering::Acquire));
        while !curr.is_null() && (*curr).key < okey {
            curr = ptr_of::<SNode>((*curr).next.load(Ordering::Acquire));
        }
        if !curr.is_null() && (*curr).key == okey {
            Some(curr)
        } else {
            None
        }
    }
}

impl ResizableFamily for LogFreeList {
    type Node = LogFreeNode;
    const FAMILY: &'static str = "logfree";

    fn head_cell(&self) -> *const AtomicU64 {
        self.head.word()
    }

    fn ebr(&self) -> &Ebr {
        self.core.ebr.as_ref()
    }

    fn insert_from(&self, start: *const AtomicU64, okey: u64, value: u64) -> bool {
        self.core.insert_from(start, self.head.word(), okey, value)
    }

    fn remove_from(&self, start: *const AtomicU64, okey: u64) -> bool {
        self.core.remove_from(start, self.head.word(), okey)
    }

    fn get_from(&self, start: *const AtomicU64, okey: u64) -> Option<u64> {
        self.core.get_from(start, self.head.word(), okey)
    }

    fn count(&self) -> usize {
        self.core.count(self.head.word())
    }

    fn snapshot_okey(&self) -> Vec<(u64, u64)> {
        self.core.snapshot_from(self.head.word())
    }

    fn pool(&self) -> PoolId {
        self.pool_id()
    }

    fn durable(&self) -> &DurablePool {
        &self.core.pool
    }

    fn preserve(&self) {
        self.crash_preserve();
    }

    unsafe fn migrate_range(&self, lo: usize, hi: usize) -> (usize, Vec<usize>) {
        (self.core.migrate_range(self.head.word(), lo, hi), Vec::new())
    }

    unsafe fn node_link(node: *mut LogFreeNode) -> *const AtomicU64 {
        &(*node).next
    }

    unsafe fn node_gen(node: *mut LogFreeNode) -> u64 {
        crate::alloc::slot_gen(node as *const u8, CACHE_LINE).load(Ordering::Acquire)
    }

    unsafe fn node_key_if_linked(node: *mut LogFreeNode) -> Option<u64> {
        // Free pattern and deleted nodes are marked; a mid-insert node
        // keeps DIRTY on its own link until published.
        if (*node).next.load(Ordering::Acquire) & (MARK | DIRTY) != 0 {
            return None;
        }
        Some((*node).key.load(Ordering::Acquire))
    }

    unsafe fn find_linked(
        &self,
        start: *const AtomicU64,
        okey: u64,
    ) -> Option<*mut LogFreeNode> {
        // Hint publication must only hand out nodes whose inbound link is
        // durable: walk with link-and-persist loads, which psync any dirty
        // link before relying on it (readers entering at the hint then
        // inherit a durably-justified position).
        let mut curr = ptr_of::<LogFreeNode>(load_link_persisted(&*start));
        while !curr.is_null() && (*curr).key.load(Ordering::Relaxed) < okey {
            curr = ptr_of::<LogFreeNode>(load_link_persisted(&(*curr).next));
        }
        if !curr.is_null()
            && (*curr).key.load(Ordering::Relaxed) == okey
            && !is_marked((*curr).next.load(Ordering::Acquire))
        {
            Some(curr)
        } else {
            None
        }
    }
}

/// One published bucket array. Old tables are retired (kept allocated) on
/// growth because readers may still hold references; they are freed when
/// the hash drops.
struct Table {
    log2n: u32,
    cells: Box<[AtomicU64]>,
}

impl Table {
    fn alloc(log2n: u32) -> *mut Table {
        let n = 1usize << log2n;
        Box::into_raw(Box::new(Table {
            log2n,
            cells: (0..n).map(|_| AtomicU64::new(0)).collect(),
        }))
    }

    #[inline]
    fn nbuckets(&self) -> usize {
        1usize << self.log2n
    }

    #[inline]
    fn bucket_of(&self, okey: u64) -> usize {
        if self.log2n == 0 {
            0
        } else {
            (okey >> (64 - self.log2n)) as usize
        }
    }

    /// First okey of bucket `j`'s range.
    #[inline]
    fn bucket_lo(&self, j: usize) -> u64 {
        if self.log2n == 0 {
            0
        } else {
            (j as u64) << (64 - self.log2n)
        }
    }
}

/// One claimed area working its way through the compaction pipeline:
/// migrated at claim time, then (after an EBR grace period so no reader
/// still holds a hint word or chain position into the range) the
/// link-free originals get their durable delete records, and finally the
/// empty area is retired and its memory returned.
struct Drain {
    claim: AreaClaim,
    /// Unlinked originals awaiting their deferred delete records
    /// (link-free only; empty for SOFT/log-free).
    originals: Vec<usize>,
    /// EBR epoch stamped when this phase began; the next phase runs only
    /// at `stamp + 2` or later.
    stamp: u64,
    /// The originals' delete records are written and retired.
    finished: bool,
}

/// Compaction/shrink state driven by [`ResizableHash::maintain_tick`].
struct CompactState {
    draining: Vec<Drain>,
    /// Consecutive low-load ticks (shrink hysteresis).
    low_streak: u32,
}

/// A lock-free durable hash set that grows its bucket array on demand.
/// See the module docs for the design; construct via the per-family
/// constructors or [`crate::sets::new_hash`].
pub struct ResizableHash<F: ResizableFamily> {
    inner: F,
    table: AtomicPtr<Table>,
    /// Superseded tables, freed on drop (readers may hold them).
    retired: Mutex<Vec<*mut Table>>,
    /// Striped live-item balance driving the growth trigger and
    /// `len_approx` (exact at quiescence).
    items: StripedItems,
    /// Doublings since construction/recovery (growth stats).
    doublings: AtomicU64,
    /// Durable bucket-count epoch: `(seq << 8) | (log2n + 1)`, low byte
    /// 0 = never written. The sequence number keeps the word monotone
    /// across shrinks (which lower the low byte); pre-shrink images are
    /// plain `log2n + 1`, i.e. `seq == 0`.
    epoch: RootCell,
    /// Compaction pipeline (see [`Drain`]); `try_lock` so concurrent
    /// maintenance calls fall through instead of queueing.
    compact: Mutex<CompactState>,
}

unsafe impl<F: ResizableFamily> Send for ResizableHash<F> {}
unsafe impl<F: ResizableFamily> Sync for ResizableHash<F> {}

/// Resizable link-free hash set.
pub type ResizableLfHash = ResizableHash<LfList>;
/// Resizable SOFT hash set.
pub type ResizableSoftHash = ResizableHash<SoftList>;
/// Resizable log-free hash set.
pub type ResizableLogFreeHash = ResizableHash<LogFreeList>;
/// Resizable NVTraverse hash set.
pub type ResizableNvHash = ResizableHash<NvList>;

impl ResizableHash<LfList> {
    pub fn new_linkfree(nbuckets: usize) -> Self {
        Self::with_inner(LfList::new(), nbuckets)
    }
}

impl ResizableHash<SoftList> {
    pub fn new_soft(nbuckets: usize) -> Self {
        Self::with_inner(SoftList::new(), nbuckets)
    }
}

impl ResizableHash<LogFreeList> {
    pub fn new_logfree(nbuckets: usize) -> Self {
        Self::with_inner(LogFreeList::new(), nbuckets)
    }
}

impl ResizableHash<NvList> {
    pub fn new_nvtraverse(nbuckets: usize) -> Self {
        Self::with_inner(NvList::new(), nbuckets)
    }
}

impl<F: ResizableFamily> ResizableHash<F> {
    fn with_inner(inner: F, nbuckets: usize) -> Self {
        let log2n = nbuckets
            .next_power_of_two()
            .max(1)
            .trailing_zeros()
            .min(MAX_LOG2);
        let epoch = root_cell(&format!("resizable.{}.{}", F::FAMILY, inner.pool().0));
        let h = ResizableHash {
            inner,
            table: AtomicPtr::new(Table::alloc(log2n)),
            retired: Mutex::new(Vec::new()),
            items: StripedItems::new(0),
            doublings: AtomicU64::new(0),
            epoch,
            compact: Mutex::new(CompactState { draining: Vec::new(), low_streak: 0 }),
        };
        h.persist_epoch(log2n);
        h
    }

    /// Wrap a recovered list, restoring the persisted bucket-count epoch
    /// (falling back to `default_nbuckets` for pre-epoch images). The
    /// items balance is re-seeded from the recovered chain so the growth
    /// trigger keeps working after recovery. Crate-visible so the
    /// accelerated recovery path (`runtime::recovery_accel`) can wrap the
    /// list it classified and relinked through the XLA artifacts.
    pub(crate) fn adopt(inner: F, default_nbuckets: usize) -> Self {
        let epoch = root_cell(&format!("resizable.{}.{}", F::FAMILY, inner.pool().0));
        let stored = epoch.word().load(Ordering::SeqCst);
        let log2n = if stored & 0xff > 0 {
            (((stored & 0xff) - 1) as u32).min(MAX_LOG2)
        } else {
            default_nbuckets
                .next_power_of_two()
                .max(1)
                .trailing_zeros()
                .min(MAX_LOG2)
        };
        let members = inner.count() as i64;
        let h = ResizableHash {
            inner,
            table: AtomicPtr::new(Table::alloc(log2n)),
            retired: Mutex::new(Vec::new()),
            items: StripedItems::new(members),
            doublings: AtomicU64::new(0),
            epoch,
            compact: Mutex::new(CompactState { draining: Vec::new(), low_streak: 0 }),
        };
        h.persist_epoch(log2n);
        h
    }

    fn persist_epoch(&self, log2n: u32) {
        // Monotone max-CAS on the size byte within the current sequence:
        // a doubling winner that stalls before recording its epoch must
        // not later overwrite a larger value some newer doubling already
        // persisted (the recovered table would be wrong-sized). Shrinks
        // bump the sequence instead ([`Self::persist_epoch_shrunk`]).
        let word = self.epoch.word();
        let mut cur = word.load(Ordering::SeqCst);
        loop {
            if cur & 0xff >= log2n as u64 + 1 {
                return;
            }
            let want = (cur & !0xff) | (log2n as u64 + 1);
            match word.compare_exchange(cur, want, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
        self.epoch.persist();
    }

    /// Record a *smaller* table durably. Lowering the size byte would
    /// break the monotone-max discipline, so the sequence in the high
    /// bits is bumped instead — the new word always exceeds the old one,
    /// and any stale grower's max-CAS within the superseded sequence
    /// loses to it.
    fn persist_epoch_shrunk(&self, log2n: u32) {
        let word = self.epoch.word();
        let mut cur = word.load(Ordering::SeqCst);
        loop {
            let want = (((cur >> 8) + 1) << 8) | (log2n as u64 + 1);
            match word.compare_exchange(cur, want, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
        self.epoch.persist();
    }

    /// Current bucket count (monotonically non-decreasing).
    pub fn nbuckets(&self) -> usize {
        unsafe { (*self.table.load(Ordering::Acquire)).nbuckets() }
    }

    pub fn pool_id(&self) -> PoolId {
        self.inner.pool()
    }

    pub fn crash_preserve(&self) {
        self.inner.preserve();
    }

    /// All (user key, value) pairs, unordered (test/debug only).
    pub fn snapshot(&self) -> Vec<(u64, u64)> {
        self.inner
            .snapshot_okey()
            .into_iter()
            .map(|(okey, v)| (mix64_inv(okey), v))
            .collect()
    }

    /// Gen-checked validation of a packed hint word: `Some((node, okey))`
    /// iff the word still names the slot incarnation it was published
    /// with *and* that node looks linked. The seqlock shape (gen, state +
    /// key, gen again) is [`gen_validated`] — a free→alloc of the slot
    /// anywhere in that window forces a mismatch (the bump is
    /// Release-published before any passing state can be, see DESIGN.md
    /// §Reclamation). Caller holds an EBR pin. With `--features
    /// untagged-hints` the gen checks compile out, restoring the old
    /// probabilistic state-only validation (the churn harness uses this
    /// to demonstrate the ABA misvalidation).
    unsafe fn validate_hint(word: u64) -> Option<(*mut F::Node, u64)> {
        if word == 0 {
            return None;
        }
        let node = hint_ptr::<F::Node>(word);
        gen_validated(
            || unsafe { F::node_gen(node) } & HINT_GEN_MASK,
            hint_gen(word),
            || unsafe { F::node_key_if_linked(node) },
        )
        .map(|k| (node, k))
    }

    /// Entry point for `okey`: the best validated hint link of its bucket
    /// or an ancestor bucket, else the list head. Caller holds an EBR pin.
    fn entry(&self, okey: u64) -> (*const AtomicU64, *mut Table, usize) {
        let t = self.table.load(Ordering::Acquire);
        let tr = unsafe { &*t };
        let j = tr.bucket_of(okey);
        let mut b = j;
        loop {
            let word = tr.cells[b].load(Ordering::Acquire);
            match unsafe { Self::validate_hint(word) } {
                Some((node, k)) => {
                    // Any linked node strictly below the search key is a
                    // correct window start (single list); the bucket walk
                    // only bounds how far the window search travels.
                    if k < okey {
                        return (unsafe { F::node_link(node) }, t, j);
                    }
                }
                None if word != 0 => {
                    // Lazy repair, mirroring the skip lists' stale-tower
                    // unlink: a dead hint (reclaimed or unlinked target)
                    // would otherwise force the ancestor/head fallback on
                    // every read of this bucket until some insert happens
                    // to republish it. Losing the CAS just means another
                    // reader repaired it first.
                    let _ = tr.cells[b].compare_exchange(
                        word,
                        0,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    );
                }
                None => {}
            }
            if b == 0 {
                break;
            }
            // Ancestor bucket: clear the lowest set bit — its okey range
            // starts earlier and encloses ours, ≤ log n hops to 0.
            b &= b - 1;
        }
        (self.inner.head_cell(), t, j)
    }

    /// Does bucket `cell` want `okey`'s node as its hint? True when the
    /// cell is empty/stale, still carries a coarser ancestor's hint
    /// (`k < bucket_lo` — kept from a doubling; the bucket never truly
    /// splits until it is replaced), or points later than `okey`.
    unsafe fn hint_wants(cell: &AtomicU64, bucket_lo: u64, okey: u64) -> bool {
        match Self::validate_hint(cell.load(Ordering::Acquire)) {
            Some((_, k)) => k < bucket_lo || k > okey,
            None => true,
        }
    }

    /// Install `node` (observed linked under the current pin, so its gen
    /// names this incarnation) as bucket `cell`'s packed hint unless a
    /// hint that is inside the bucket's own range and at-or-before `okey`
    /// is already present.
    unsafe fn publish_hint(cell: &AtomicU64, node: *mut F::Node, bucket_lo: u64, okey: u64) {
        let packed = pack_hint(node, F::node_gen(node));
        loop {
            let cur = cell.load(Ordering::Acquire);
            if let Some((_, k)) = Self::validate_hint(cur) {
                if k >= bucket_lo && k <= okey {
                    return;
                }
            }
            if cell
                .compare_exchange(cur, packed, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return;
            }
        }
    }

    /// The coalesced read sweep behind `contains_batch`/`get_batch`: one
    /// EBR pin for the whole run (instead of one per key) and probes in
    /// okey order, so consecutive lookups walk cache-adjacent windows of
    /// the single family list and revisit the same bucket hints. Zero
    /// psyncs — this is the plain read path, batched. Holding one pin
    /// across the run delays reclamation by at most one sweep, the same
    /// order as any long traversal.
    fn read_sweep(&self, keys: &[u64], mut sink: impl FnMut(usize, Option<u64>)) {
        let mut probes: Vec<(u64, usize)> =
            keys.iter().enumerate().map(|(i, &k)| (mix64(k), i)).collect();
        probes.sort_unstable();
        let _g = self.inner.ebr().pin();
        for &(okey, i) in &probes {
            let (start, _, _) = self.entry(okey);
            sink(i, self.inner.get_from(start, okey));
        }
    }

    /// Double the bucket array while `items` is past the load trigger.
    /// Lock-free: losers of the publish CAS free their candidate and
    /// re-check; the winner persists the new epoch (one psync per
    /// doubling). Loops because the striped counter only spills its
    /// estimate every [`STRIPE_SPILL`] updates — one cue may owe several
    /// doublings.
    fn maybe_grow(&self, items: i64) {
        loop {
            let t = self.table.load(Ordering::Acquire);
            let tr = unsafe { &*t };
            if tr.log2n >= MAX_LOG2 || items < (GROW_LOAD as i64) << tr.log2n {
                return;
            }
            let new = Table::alloc(tr.log2n + 1);
            {
                let nr = unsafe { &*new };
                for i in 0..tr.nbuckets() {
                    // Seed both children from the parent hint: hints are
                    // validated before use, so a lower-half hint in the upper
                    // child merely causes one fallback hop until repopulated.
                    let h = tr.cells[i].load(Ordering::Relaxed);
                    nr.cells[2 * i].store(h, Ordering::Release);
                    nr.cells[2 * i + 1].store(h, Ordering::Release);
                }
            }
            if self
                .table
                .compare_exchange(t, new, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                self.retired.lock().unwrap().push(t);
                self.doublings.fetch_add(1, Ordering::Relaxed);
                self.persist_epoch(tr.log2n + 1);
            } else {
                unsafe { drop(Box::from_raw(new)) };
            }
        }
    }

    /// Drop every published hint whose target slot lies in `[lo, hi)`,
    /// in the live table and all retired ones (an in-flight reader may
    /// still probe a superseded table). A lost CAS means a reader's lazy
    /// repair already cleared the cell; nothing can republish into the
    /// range because no linked node lives there after migration.
    fn clear_hints_in_range(&self, lo: usize, hi: usize) {
        let clear = |t: &Table| {
            for cell in t.cells.iter() {
                let w = cell.load(Ordering::Acquire);
                if w != 0 {
                    let p = hint_ptr::<u8>(w) as usize;
                    if p >= lo && p < hi {
                        let _ = cell.compare_exchange(w, 0, Ordering::AcqRel, Ordering::Acquire);
                    }
                }
            }
        };
        clear(unsafe { &*self.table.load(Ordering::Acquire) });
        for &t in self.retired.lock().unwrap().iter() {
            clear(unsafe { &*t });
        }
    }

    /// One compaction/shrink tick — the idle-time maintenance pass the
    /// shard worker drives between requests. Returns true if it made
    /// progress (migrated, retired an area, or shrank the table).
    ///
    /// The pipeline per claimed area (each arrow is >= one full EBR
    /// grace period, so no reader still holds a cleared hint word or a
    /// chain position into the range):
    ///
    /// 1. claim (off the allocation index) -> migrate survivors (copy
    ///    durably first; dedup-covered crash windows) -> clear hints;
    /// 2. write the link-free originals' deferred delete records and
    ///    retire them; clear hints again;
    /// 3. once the occupancy bitmap reads empty (the EBR frees landed),
    ///    retire the area: regions drop it and the memory is returned.
    ///
    /// **Serialization contract:** must not run concurrently with
    /// updates on this set (readers are fine). The shard worker owns
    /// all updates to its sets, so its idle tick satisfies this by
    /// construction; library users must provide the same guarantee.
    pub fn maintain_tick(&self) -> bool {
        let ebr = self.inner.ebr();
        // Advance the epoch and collect our own limbo so retired
        // originals actually free (their bitmap bits clear) and the
        // drains below converge even on an otherwise idle set.
        ebr.try_collect();
        let mut st = match self.compact.try_lock() {
            Ok(g) => g,
            Err(_) => return false,
        };
        let pool = self.inner.durable();
        let mut progressed = false;

        // Phases 2/3: advance in-flight drains.
        let epoch = ebr.global_epoch();
        let mut i = 0;
        while i < st.draining.len() {
            if epoch < st.draining[i].stamp + 2 {
                i += 1;
                continue;
            }
            if !st.draining[i].finished {
                let d = &mut st.draining[i];
                self.clear_hints_in_range(d.claim.lo, d.claim.hi);
                {
                    let _scope = crate::pmem::psync_scope();
                    unsafe { self.inner.finish_migration(&d.originals) };
                }
                d.originals.clear();
                d.finished = true;
                d.stamp = epoch;
                progressed = true;
                i += 1;
            } else if pool.area_is_empty(&st.draining[i].claim) {
                let d = st.draining.swap_remove(i);
                self.clear_hints_in_range(d.claim.lo, d.claim.hi);
                pool.retire_area(d.claim, ebr);
                progressed = true;
            } else {
                // Waiting on EBR frees to land in the bitmap.
                i += 1;
            }
        }

        // Phase 1: claim + migrate fresh low-fill areas.
        if st.draining.len() < COMPACT_MAX_DRAINS {
            let room = COMPACT_MAX_DRAINS - st.draining.len();
            for claim in pool
                .claim_compaction_targets(room.min(COMPACT_CLAIMS_PER_TICK), COMPACT_MIN_FREE)
            {
                let (lo, hi) = (claim.lo, claim.hi);
                let originals = {
                    let _scope = crate::pmem::psync_scope();
                    unsafe { self.inner.migrate_range(lo, hi) }.1
                };
                self.clear_hints_in_range(lo, hi);
                crate::alloc::note_compaction();
                st.draining.push(Drain {
                    claim,
                    originals,
                    stamp: ebr.global_epoch(),
                    finished: false,
                });
                progressed = true;
            }
        }

        if self.maybe_shrink(&mut st) {
            progressed = true;
        }
        progressed
    }

    /// Halve the bucket array after [`SHRINK_STREAK`] consecutive ticks
    /// of sustained low load. Same publish discipline as a doubling
    /// (retire the old table, persist the epoch — via the shrink rule).
    fn maybe_shrink(&self, st: &mut CompactState) -> bool {
        let t = self.table.load(Ordering::Acquire);
        let tr = unsafe { &*t };
        let items = self.items.sum().max(0);
        if tr.log2n <= SHRINK_MIN_LOG2 || items >= ((GROW_LOAD as i64) << tr.log2n) / SHRINK_DIV
        {
            st.low_streak = 0;
            return false;
        }
        st.low_streak += 1;
        if st.low_streak < SHRINK_STREAK {
            return false;
        }
        st.low_streak = 0;
        let new = Table::alloc(tr.log2n - 1);
        {
            let nr = unsafe { &*new };
            for j in 0..nr.nbuckets() {
                // The left child's range starts where the merged bucket's
                // does; its hint (validated before use, like any other)
                // seeds the merge.
                nr.cells[j].store(tr.cells[2 * j].load(Ordering::Relaxed), Ordering::Release);
            }
        }
        if self
            .table
            .compare_exchange(t, new, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            self.retired.lock().unwrap().push(t);
            self.persist_epoch_shrunk(tr.log2n - 1);
            true
        } else {
            unsafe { drop(Box::from_raw(new)) };
            false
        }
    }
}

impl<F: ResizableFamily> ConcurrentSet for ResizableHash<F> {
    fn insert(&self, key: u64, value: u64) -> bool {
        let okey = mix64(key);
        let inserted = {
            let _g = self.inner.ebr().pin();
            let (start, t, j) = self.entry(okey);
            let ok = self.inner.insert_from(start, okey, value);
            if ok {
                unsafe {
                    // First-touch bucket initialization / refinement. Check
                    // whether the cell even wants this node first: in steady
                    // state it already holds an in-range hint, and the
                    // locate walk would be pure waste.
                    let cell = &(*t).cells[j];
                    let lo = (*t).bucket_lo(j);
                    if Self::hint_wants(cell, lo, okey) {
                        if let Some(node) = self.inner.find_linked(start, okey) {
                            Self::publish_hint(cell, node, lo, okey);
                        }
                    }
                }
            }
            ok
        };
        if inserted {
            // Striped: only a stripe spill refreshes the shared estimate
            // and re-checks the growth trigger.
            if let Some(estimate) = self.items.add(1) {
                self.maybe_grow(estimate);
            }
        }
        inserted
    }

    fn remove(&self, key: u64) -> bool {
        let okey = mix64(key);
        let removed = {
            let _g = self.inner.ebr().pin();
            let (start, _, _) = self.entry(okey);
            self.inner.remove_from(start, okey)
        };
        if removed {
            self.items.add(-1);
        }
        removed
    }

    fn contains(&self, key: u64) -> bool {
        self.get(key).is_some()
    }

    fn get(&self, key: u64) -> Option<u64> {
        let okey = mix64(key);
        let _g = self.inner.ebr().pin();
        let (start, _, _) = self.entry(okey);
        self.inner.get_from(start, okey)
    }

    fn len_approx(&self) -> usize {
        // Striped-counter sum: O(stripes) instead of the old O(n) chain
        // walk, and exact at quiescence (see StripedItems).
        self.items.sum().max(0) as usize
    }

    fn contains_batch(&self, keys: &[u64]) -> Vec<bool> {
        let mut out = vec![false; keys.len()];
        self.read_sweep(keys, |i, v| out[i] = v.is_some());
        out
    }

    fn get_batch(&self, keys: &[u64]) -> Vec<Option<u64>> {
        let mut out = vec![None; keys.len()];
        self.read_sweep(keys, |i, v| out[i] = v);
        out
    }

    fn apply_batch(&self, ops: &[crate::sets::SetOp]) -> Vec<crate::sets::OpResult> {
        // Group commit across the hint layer: per-op family psyncs and the
        // (rare) epoch psync of a doubling all share one trailing fence.
        crate::sets::apply_batch_coalesced(self, ops)
    }

    fn durable_pool(&self) -> Option<PoolId> {
        Some(self.inner.pool())
    }

    fn prepare_crash(&self) {
        self.inner.preserve();
    }

    fn growth_stats(&self) -> Option<GrowthStats> {
        Some(GrowthStats {
            buckets: self.nbuckets(),
            doublings: self.doublings.load(Ordering::Relaxed),
            items: self.items.sum().max(0) as usize,
        })
    }

    fn maintain(&self) -> bool {
        self.maintain_tick()
    }
}

impl<F: ResizableFamily> Drop for ResizableHash<F> {
    fn drop(&mut self) {
        unsafe {
            drop(Box::from_raw(self.table.load(Ordering::Relaxed)));
            for &t in self.retired.lock().unwrap().iter() {
                drop(Box::from_raw(t));
            }
        }
    }
}

/// Recover a resizable link-free hash from the durable areas of `id`.
pub fn recover_linkfree(id: PoolId, default_nbuckets: usize) -> (ResizableLfHash, RecoveredStats) {
    let (h, s, _) = recover_linkfree_timed(id, default_nbuckets, crate::sets::recovery::default_threads());
    (h, s)
}

/// [`recover_linkfree`] with an explicit recovery worker count: the whole
/// durable image is the family list in okey order, so the engine's
/// parallel scan + segmented chain relink apply directly.
pub fn recover_linkfree_timed(
    id: PoolId,
    default_nbuckets: usize,
    threads: usize,
) -> (ResizableLfHash, RecoveredStats, crate::sets::recovery::PhaseTimings) {
    let (list, stats, t) = crate::sets::linkfree::recover_list_timed(id, threads);
    (ResizableHash::adopt(list, default_nbuckets), stats, t)
}

/// Recover a resizable SOFT hash from the durable areas of `id`.
pub fn recover_soft(id: PoolId, default_nbuckets: usize) -> (ResizableSoftHash, RecoveredStats) {
    let (h, s, _) = recover_soft_timed(id, default_nbuckets, crate::sets::recovery::default_threads());
    (h, s)
}

/// [`recover_soft`] with an explicit recovery worker count.
pub fn recover_soft_timed(
    id: PoolId,
    default_nbuckets: usize,
    threads: usize,
) -> (ResizableSoftHash, RecoveredStats, crate::sets::recovery::PhaseTimings) {
    let (list, stats, t) = crate::sets::soft::recover_list_timed(id, threads);
    (ResizableHash::adopt(list, default_nbuckets), stats, t)
}

/// Recover a resizable log-free hash from pool `id` (durable anchor: the
/// list's root cell, walked link-by-link as for the plain list).
pub fn recover_logfree(
    id: PoolId,
    default_nbuckets: usize,
) -> (ResizableLogFreeHash, RecoveredStats) {
    let (h, s, _) = recover_logfree_timed(id, default_nbuckets, crate::sets::recovery::default_threads());
    (h, s)
}

/// [`recover_logfree`] with an explicit recovery worker count.
pub fn recover_logfree_timed(
    id: PoolId,
    default_nbuckets: usize,
    threads: usize,
) -> (ResizableLogFreeHash, RecoveredStats, crate::sets::recovery::PhaseTimings) {
    let (list, stats, t) = crate::sets::logfree::recover_list_timed(id, threads);
    (ResizableHash::adopt(list, default_nbuckets), stats, t)
}

/// Recover a resizable NVTraverse hash from the durable areas of `id`.
pub fn recover_nvtraverse(
    id: PoolId,
    default_nbuckets: usize,
) -> (ResizableNvHash, RecoveredStats) {
    let (h, s, _) =
        recover_nvtraverse_timed(id, default_nbuckets, crate::sets::recovery::default_threads());
    (h, s)
}

/// [`recover_nvtraverse`] with an explicit recovery worker count (same
/// engine path as link-free: shared durable format).
pub fn recover_nvtraverse_timed(
    id: PoolId,
    default_nbuckets: usize,
    threads: usize,
) -> (ResizableNvHash, RecoveredStats, crate::sets::recovery::PhaseTimings) {
    let (list, stats, t) = crate::sets::nvtraverse::recover_list_timed(id, threads);
    (ResizableHash::adopt(list, default_nbuckets), stats, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{self, CrashPolicy};
    use std::collections::BTreeSet;

    fn model_check<F: ResizableFamily>(h: &ResizableHash<F>, seed: u64) {
        use crate::util::rng::Xoshiro256;
        let initial = h.nbuckets();
        let mut model = BTreeSet::new();
        let mut rng = Xoshiro256::new(seed);
        for _ in 0..30_000 {
            let k = rng.below(1024);
            match rng.below(4) {
                0 | 1 => assert_eq!(h.insert(k, k ^ 0xF00D), model.insert(k), "insert {k}"),
                2 => assert_eq!(h.remove(k), model.remove(&k), "remove {k}"),
                _ => assert_eq!(h.contains(k), model.contains(&k), "contains {k}"),
            }
        }
        assert_eq!(h.len_approx(), model.len());
        let mut snap: Vec<u64> = h.snapshot().iter().map(|kv| kv.0).collect();
        snap.sort_unstable();
        let want: Vec<u64> = model.iter().copied().collect();
        assert_eq!(snap, want, "snapshot must equal the model set");
        assert!(
            h.nbuckets() >= initial * 4,
            "expected >= 2 doublings, got {} -> {}",
            initial,
            h.nbuckets()
        );
    }

    #[test]
    fn linkfree_grows_and_matches_model() {
        model_check(&ResizableHash::new_linkfree(2), 0x51A);
    }

    #[test]
    fn soft_grows_and_matches_model() {
        model_check(&ResizableHash::new_soft(2), 0x51B);
    }

    #[test]
    fn logfree_grows_and_matches_model() {
        model_check(&ResizableHash::new_logfree(2), 0x51C);
    }

    #[test]
    fn nvtraverse_grows_and_matches_model() {
        model_check(&ResizableHash::new_nvtraverse(2), 0x51D);
    }

    fn assert_zero_psync_reads<F: ResizableFamily>(h: &ResizableHash<F>) {
        for k in 0..200u64 {
            assert!(h.insert(k, k + 1));
        }
        // First read pass may repopulate nothing durable either, but the
        // families' flush flags settle on the update path; pin the steady
        // state: reads are psync-free.
        for k in 0..200u64 {
            assert_eq!(h.get(k), Some(k + 1));
        }
        let a = pmem::stats::thread_snapshot();
        for k in 0..200u64 {
            assert!(h.contains(k));
            assert_eq!(h.get(k), Some(k + 1));
        }
        for k in 1000..1100u64 {
            assert!(!h.contains(k));
        }
        let d = pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 0, "{}: reads must not psync", F::FAMILY);
        assert_eq!(d.flushes, 0, "{}: reads must not flush", F::FAMILY);
    }

    #[test]
    fn reads_stay_psync_free_across_growth() {
        // 200 items over 2 initial buckets: multiple doublings happen
        // during the insert phase; reads afterwards must still cost zero.
        assert_zero_psync_reads(&ResizableHash::new_linkfree(2));
        assert_zero_psync_reads(&ResizableHash::new_soft(2));
        assert_zero_psync_reads(&ResizableHash::new_logfree(2));
        assert_zero_psync_reads(&ResizableHash::new_nvtraverse(2));
    }

    fn assert_update_budget<F: ResizableFamily>(h: &ResizableHash<F>, per_update: u64) {
        // Tables sized 1<<10 with 64 items never grow, so this measures
        // the pure hint-layer overhead: none allowed.
        for k in 0..64u64 {
            h.insert(k, k);
        }
        let a = pmem::stats::thread_snapshot();
        assert!(h.insert(500, 1));
        assert!(h.remove(500));
        let d = pmem::stats::thread_snapshot().since(&a);
        assert_eq!(
            d.fences,
            2 * per_update,
            "{}: the hash layer must not add fences to the update protocol",
            F::FAMILY
        );
    }

    #[test]
    fn update_psync_budget_unchanged_by_resizable_layer() {
        // The hint layer must not add fences to any family's update
        // protocol (growth itself pays 1 per doubling, measured apart):
        // SOFT = 1/update, link-free = 1 (flag-elided), log-free = 2,
        // nvtraverse = 1 (destination-only).
        assert_update_budget(&ResizableHash::new_soft(1 << 10), 1);
        assert_update_budget(&ResizableHash::new_linkfree(1 << 10), 1);
        assert_update_budget(&ResizableHash::new_logfree(1 << 10), 2);
        assert_update_budget(&ResizableHash::new_nvtraverse(1 << 10), 1);
    }

    fn crash_recover_roundtrip<F, R>(mk: impl FnOnce() -> ResizableHash<F>, recover: R)
    where
        F: ResizableFamily,
        R: FnOnce(PoolId, usize) -> (ResizableHash<F>, RecoveredStats),
    {
        let _sim = pmem::sim_session();
        let h = mk();
        let id = h.pool_id();
        for k in 0..300u64 {
            assert!(h.insert(k, k * 3));
        }
        for k in 0..60u64 {
            assert!(h.remove(k));
        }
        let grown = h.nbuckets();
        assert!(grown >= 8, "test must exercise growth (got {grown})");
        h.crash_preserve();
        drop(h);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);

        let (h2, stats) = recover(id, 2);
        assert_eq!(stats.members, 240);
        assert_eq!(
            h2.nbuckets(),
            grown,
            "bucket-count epoch must survive the crash"
        );
        for k in 0..300u64 {
            assert_eq!(h2.get(k), if k < 60 { None } else { Some(k * 3) }, "key {k}");
        }
        // Fully operational after recovery, including further growth.
        for k in 1000..3000u64 {
            assert!(h2.insert(k, k));
        }
        assert!(h2.nbuckets() > grown, "recovered table must keep growing");
    }

    #[test]
    fn linkfree_recovers_size_and_contents() {
        crash_recover_roundtrip(|| ResizableHash::new_linkfree(2), recover_linkfree);
    }

    #[test]
    fn soft_recovers_size_and_contents() {
        crash_recover_roundtrip(|| ResizableHash::new_soft(2), recover_soft);
    }

    #[test]
    fn logfree_recovers_size_and_contents() {
        crash_recover_roundtrip(|| ResizableHash::new_logfree(2), recover_logfree);
    }

    #[test]
    fn nvtraverse_recovers_size_and_contents() {
        crash_recover_roundtrip(|| ResizableHash::new_nvtraverse(2), recover_nvtraverse);
    }

    #[test]
    fn growth_stats_and_striped_count_are_exact_at_quiescence() {
        let h = ResizableHash::new_soft(2);
        assert_eq!(h.growth_stats().unwrap().doublings, 0);
        for k in 0..300u64 {
            assert!(h.insert(k, k));
        }
        for k in 0..40u64 {
            assert!(h.remove(k));
        }
        let g = h.growth_stats().unwrap();
        assert!(g.doublings >= 2, "expected >= 2 doublings, saw {}", g.doublings);
        assert_eq!(g.buckets, h.nbuckets());
        assert_eq!(g.items, 260, "striped counter must be exact at quiescence");
        assert_eq!(h.len_approx(), 260);
        assert!(g.chain_load() > 0.0);
    }

    /// Deterministic replay of the hint/slot ABA schedule the generation
    /// tag closes: publish a hint, reclaim its target through a full EBR
    /// grace period (gen bump), re-allocate the same slot and hand-craft
    /// a "linked-looking" state in it (exactly what a concurrent
    /// re-incarnation mid-insert can transiently present). The tagged
    /// build must reject the stale hint *before* looking at the slot's
    /// contents; an `--features untagged-hints` build demonstrably
    /// accepts it — the old misvalidation.
    #[test]
    fn stale_hint_to_reallocated_slot_is_rejected_by_generation() {
        let h = ResizableHash::new_linkfree(1);
        let k1 = 42u64;
        assert!(h.insert(k1, 7));
        // The successful insert published bucket 0's hint -> k1's node.
        let table = h.table.load(Ordering::Acquire);
        let cell_word = unsafe { (*table).cells[0].load(Ordering::Acquire) };
        assert_ne!(cell_word, 0, "insert must publish the first-touch hint");
        let node = crate::sets::tagged::hint_ptr::<LfNode>(cell_word);

        // Unlink + retire, then force reclamation: the slot returns to the
        // free-list and its generation is bumped.
        assert!(h.remove(k1));
        unsafe { h.inner.ebr().drain_all() };

        // Re-allocate the same slot (LIFO free-list, same thread) and
        // fabricate a linked-looking incarnation: valid, unmarked next,
        // small okey — everything the state-only validation trusts.
        let slot = h.inner.core.pool.alloc() as *mut LfNode;
        assert_eq!(slot, node, "the freed slot must be handed back");
        unsafe {
            (*slot).key.store(1, Ordering::Relaxed);
            (*slot).value.store(99, Ordering::Relaxed);
            (*slot).next.store(0, Ordering::Relaxed); // unmarked null
            (*slot).make_valid();
        }

        // Probe through the stale hint.
        {
            let _g = h.inner.ebr().pin();
            let (start, _, _) = h.entry(u64::MAX);
            if cfg!(feature = "untagged-hints") {
                assert!(
                    std::ptr::eq(start, unsafe {
                        <LfList as ResizableFamily>::node_link(slot)
                    }),
                    "untagged validation accepts the reincarnated slot (the ABA hazard)"
                );
            } else {
                assert!(
                    std::ptr::eq(start, h.inner.head_cell()),
                    "generation mismatch must force the head fallback"
                );
            }
        }

        // Return the fabricated slot so teardown accounting stays clean.
        unsafe {
            LfNode::init_free_pattern(slot as *mut u8);
        }
        h.inner.core.pool.free(slot as *mut u8);
    }

    /// The coalesced read sweep: input-order results, correctness across
    /// growth (probes through hints of a multiply-doubled table), and the
    /// psync-free pin.
    #[test]
    fn read_sweep_matches_singles_across_growth() {
        let h = ResizableHash::new_soft(2);
        for k in 0..600u64 {
            assert!(h.insert(k * 3, k));
        }
        assert!(h.nbuckets() > 2, "sweep must probe a grown table");
        let keys: Vec<u64> = (0..1000u64).collect();
        let a = crate::pmem::stats::thread_snapshot();
        let present = h.contains_batch(&keys);
        let values = h.get_batch(&keys);
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 0, "read sweep must not fence");
        assert_eq!(d.flushes, 0, "read sweep must not flush");
        for (i, &k) in keys.iter().enumerate() {
            let want = (k % 3 == 0 && k / 3 < 600).then_some(k / 3);
            assert_eq!(values[i], want, "get_batch key {k}");
            assert_eq!(present[i], want.is_some(), "contains_batch key {k}");
        }
    }

    /// Regression: `len_approx` sums per-tid stripes while spills are in
    /// flight — a transiently negative balance must clamp at 0, never
    /// wrap into an astronomic usize.
    #[test]
    fn len_approx_clamps_under_concurrent_churn() {
        use std::sync::atomic::{AtomicBool, AtomicU64};
        use std::sync::Arc;
        let h = Arc::new(ResizableHash::new_linkfree(2));
        let stop = Arc::new(AtomicBool::new(false));
        let progress = Arc::new(AtomicU64::new(0));
        let workers: Vec<_> = (0..4u64)
            .map(|t| {
                let h = h.clone();
                let stop = stop.clone();
                let progress = progress.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::util::rng::Xoshiro256::new(0xC1A_u64 + t);
                    let mut net = 0i64;
                    while !stop.load(Ordering::Relaxed) {
                        // Thread-owned keys: k ≡ t (mod 4).
                        let k = rng.below(128) * 4 + t;
                        if rng.below(2) == 0 {
                            if h.insert(k, t) {
                                net += 1;
                            }
                        } else if h.remove(k) {
                            net -= 1;
                        }
                        progress.fetch_add(1, Ordering::Relaxed);
                    }
                    net
                })
            })
            .collect();
        // Hammer the read while stripes spill: at most 4*128 keys can be
        // live, so anything huge is a wrapped negative sum. Gate on the
        // workers' op counter so the polls provably overlap live churn
        // (spill windows included) instead of finishing before the
        // workers even spin up.
        while progress.load(Ordering::Relaxed) < 60_000 {
            let n = h.len_approx();
            assert!(n <= 10_000, "len_approx wrapped/overflowed: {n}");
        }
        stop.store(true, Ordering::Relaxed);
        let net: i64 = workers.into_iter().map(|w| w.join().unwrap()).sum();
        assert_eq!(
            h.len_approx() as i64,
            net,
            "striped counter must be exact at quiescence"
        );
    }

    #[test]
    fn zipfian_skew_over_growing_keyspace() {
        // The scenario fixed tables silently degrade on: a zipf-skewed
        // stream over a keyspace much larger than the initial table.
        use crate::util::rng::Xoshiro256;
        use crate::workload::zipf::Zipf;
        let h = ResizableHash::new_soft(4);
        let z = Zipf::new(100_000, 0.9);
        let mut rng = Xoshiro256::new(0x21F);
        let mut model = BTreeSet::new();
        for _ in 0..40_000 {
            let k = z.sample(rng.next_u64());
            match rng.below(3) {
                0 => assert_eq!(h.insert(k, k), model.insert(k)),
                1 => assert_eq!(h.remove(k), model.remove(&k)),
                _ => assert_eq!(h.contains(k), model.contains(&k)),
            }
        }
        assert_eq!(h.len_approx(), model.len());
        assert!(h.nbuckets() > 4, "skewed growth must still trigger resizes");
    }

    /// Drive the multi-tick compaction pipeline (each phase needs EBR
    /// grace periods between ticks) on an otherwise idle set.
    fn run_maintenance<F: ResizableFamily>(h: &ResizableHash<F>, ticks: usize) {
        for _ in 0..ticks {
            let _ = h.maintain_tick();
        }
    }

    /// Fill ~3 areas, delete 90%, then maintain: low-fill areas must be
    /// compacted away and their regions returned, with every surviving
    /// key (and the allocator) fully functional afterwards.
    fn compaction_returns_areas<F: ResizableFamily>(h: ResizableHash<F>) {
        for k in 0..9000u64 {
            assert!(h.insert(k, k + 5));
        }
        let peak = h.inner.durable().regions().len();
        assert!(peak >= 3, "{}: test must span several areas (got {peak})", F::FAMILY);
        for k in 0..9000u64 {
            if k % 10 != 0 {
                assert!(h.remove(k));
            }
        }
        run_maintenance(&h, 64);
        let now = h.inner.durable().regions().len();
        assert!(
            now < peak,
            "{}: compaction must return areas ({peak} -> {now})",
            F::FAMILY
        );
        for k in 0..9000u64 {
            let want = (k % 10 == 0).then_some(k + 5);
            assert_eq!(h.get(k), want, "{}: key {k} after compaction", F::FAMILY);
        }
        // The survivors' relocated slots and the remaining areas keep
        // working: churn on top of the compacted image.
        for k in 20_000..21_000u64 {
            assert!(h.insert(k, k));
        }
        for k in 20_000..21_000u64 {
            assert_eq!(h.get(k), Some(k));
        }
    }

    #[test]
    fn linkfree_compaction_returns_areas() {
        compaction_returns_areas(ResizableHash::new_linkfree(2));
    }

    #[test]
    fn soft_compaction_returns_areas() {
        compaction_returns_areas(ResizableHash::new_soft(2));
    }

    #[test]
    fn logfree_compaction_returns_areas() {
        compaction_returns_areas(ResizableHash::new_logfree(2));
    }

    #[test]
    fn nvtraverse_compaction_returns_areas() {
        compaction_returns_areas(ResizableHash::new_nvtraverse(2));
    }

    #[test]
    fn migration_preserves_reader_view_between_ticks() {
        // A reader that validated a bucket hint before a maintain tick
        // must keep getting exact answers after migration moved the
        // bucket's nodes (the original stays traversable until the
        // deferred delete records land, two grace periods later).
        let h = ResizableHash::new_linkfree(2);
        for k in 0..9000u64 {
            assert!(h.insert(k, k));
        }
        for k in 4500..9000u64 {
            assert!(h.remove(k));
        }
        // Interleave reads with single ticks: every pipeline phase runs
        // while reads are in flight between ticks.
        for round in 0..24u64 {
            let _ = h.maintain_tick();
            for k in (round * 100)..(round * 100 + 100) {
                assert_eq!(h.get(k), (k < 4500).then_some(k), "key {k} round {round}");
            }
        }
    }

    #[test]
    fn sustained_low_load_shrinks_table_and_epoch_recovers() {
        let _sim = pmem::sim_session();
        let h = ResizableHash::new_linkfree(2);
        let id = h.pool_id();
        for k in 0..600u64 {
            assert!(h.insert(k, k * 2));
        }
        let grown = h.nbuckets();
        assert!(grown >= 64, "must grow first (got {grown})");
        for k in 0..590u64 {
            assert!(h.remove(k));
        }
        run_maintenance(&h, 64);
        let shrunk = h.nbuckets();
        assert!(
            shrunk < grown,
            "sustained low load must shrink the table ({grown} -> {shrunk})"
        );
        assert!(shrunk >= 2, "never below the floor");
        for k in 0..600u64 {
            assert_eq!(h.get(k), (k >= 590).then_some(k * 2), "key {k} after shrink");
        }
        // The shrunk size is durable: the seq-bumped epoch must win over
        // the larger pre-shrink value after a crash.
        h.crash_preserve();
        drop(h);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (h2, stats) = recover_linkfree(id, 2);
        assert_eq!(stats.members, 10);
        assert_eq!(h2.nbuckets(), shrunk, "shrunk epoch must survive the crash");
        // And the recovered table still grows again under load.
        for k in 1000..3000u64 {
            assert!(h2.insert(k, k));
        }
        assert!(h2.nbuckets() > shrunk);
    }
}
