//! SOFT recovery (paper §4.6).
//!
//! Only PNodes survive a crash — every intention state is lost with the
//! volatile heap, so membership is decided purely by the three persistent
//! flags: member ⇔ `validStart == validEnd != deleted`. For each member a
//! fresh volatile node is built (pValidity := `validStart`, state :=
//! "inserted") and linked — with zero psyncs — into a new structure.
//! Invalid/deleted PNodes are normalised and reclaimed.

use crate::alloc::{DurablePool, Ebr, VolatilePool};
use crate::pmem::PoolId;
use crate::sets::tagged::State;
use crate::util::mix64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::list::{SoftCore, SoftList};
use super::node::{SNode, SNODE_SIZE};
use super::pnode::PNode;
use super::SoftHash;

pub use crate::sets::linkfree::RecoveredStats;

/// Scan PNode areas: rebuild volatile nodes for members, reclaim the rest.
fn scan(core: &SoftCore) -> (Vec<(u64, *mut SNode)>, RecoveredStats) {
    let mut members = Vec::new();
    let mut stats = RecoveredStats::default();
    for slot in core.dpool.iter_slots() {
        let pn = slot as *mut PNode;
        unsafe {
            if (*pn).is_member() {
                let vn = core.vpool.alloc() as *mut SNode;
                std::ptr::write(
                    vn,
                    SNode {
                        key: (*pn).key.load(Ordering::Relaxed),
                        value: (*pn).value.load(Ordering::Relaxed),
                        pptr: pn,
                        p_validity: (*pn).current_validity(),
                        next: AtomicU64::new(State::Inserted as u64),
                    },
                );
                members.push(((*vn).key, vn));
                stats.members += 1;
            } else {
                core.dpool.normalize_slot(slot);
                core.dpool.free(slot);
                stats.reclaimed += 1;
            }
        }
    }
    let mut keys: Vec<u64> = members.iter().map(|m| m.0).collect();
    keys.sort_unstable();
    keys.dedup();
    assert_eq!(keys.len(), members.len(), "duplicate keys in durable image");
    (members, stats)
}

unsafe fn relink_chain(members: &[(u64, *mut SNode)]) -> u64 {
    let mut next_val = State::Inserted as u64; // null ptr, inserted state
    for &(_, node) in members.iter().rev() {
        // Each node: state "inserted", pointing at the previous chain head.
        (*node).next.store(next_val, Ordering::Relaxed);
        next_val = node as u64 | State::Inserted as u64;
    }
    next_val
}

/// Rebuild a SOFT list from the durable areas of `id`.
pub fn recover_list(id: PoolId) -> (SoftList, RecoveredStats) {
    let core = SoftCore::from_parts(
        Arc::new(DurablePool::adopt(id, 64, PNode::init_free_pattern)),
        Arc::new(VolatilePool::new(SNODE_SIZE)),
        Arc::new(Ebr::new()),
    );
    let (mut members, stats) = scan(&core);
    members.sort_unstable_by_key(|m| m.0);
    let head = unsafe { relink_chain(&members) };
    core.dpool.persist_all_regions();
    (SoftList::from_parts(head, core), stats)
}

/// Rebuild a SOFT hash set from the durable areas of `id`.
pub fn recover_hash(id: PoolId, nbuckets: usize) -> (SoftHash, RecoveredStats) {
    let core = SoftCore::from_parts(
        Arc::new(DurablePool::adopt(id, 64, PNode::init_free_pattern)),
        Arc::new(VolatilePool::new(SNODE_SIZE)),
        Arc::new(Ebr::new()),
    );
    let (mut members, stats) = scan(&core);
    let hash = SoftHash::from_parts(nbuckets, core);
    let mask = (hash.nbuckets() - 1) as u64;
    members.sort_unstable_by_key(|m| ((mix64(m.0) & mask), m.0));
    let mut i = 0;
    while i < members.len() {
        let b = (mix64(members[i].0) & mask) as usize;
        let mut j = i;
        while j < members.len() && (mix64(members[j].0) & mask) as usize == b {
            j += 1;
        }
        let head_val = unsafe { relink_chain(&members[i..j]) };
        hash.buckets[b].store(head_val, Ordering::Relaxed);
        i = j;
    }
    hash.core.dpool.persist_all_regions();
    (hash, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{self, CrashPolicy};
    use crate::sets::ConcurrentSet;

    #[test]
    fn soft_list_survives_pessimistic_crash() {
        let _sim = pmem::sim_session();
        let l = SoftList::new();
        let id = l.pool_id();
        for k in 0..60u64 {
            assert!(l.insert(k, k * 2));
        }
        for k in (0..60u64).step_by(4) {
            assert!(l.remove(k));
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);

        let (l2, stats) = recover_list(id);
        for k in 0..60u64 {
            if k % 4 == 0 {
                assert!(!l2.contains(k), "removed key {k} resurrected");
            } else {
                assert_eq!(l2.get(k), Some(k * 2), "key {k} lost");
            }
        }
        assert_eq!(stats.members, 45);
        // Fully operational after recovery, including PNode reuse.
        assert!(l2.insert(0, 1));
        assert!(l2.remove(1));
        assert!(l2.insert(1000, 1));
    }

    #[test]
    fn soft_hash_survives_random_eviction_crash() {
        let _sim = pmem::sim_session();
        let h = SoftHash::new(16);
        let id = h.pool_id();
        for k in 0..150u64 {
            assert!(h.insert(k, k));
        }
        for k in 0..50u64 {
            assert!(h.remove(k));
        }
        h.crash_preserve();
        drop(h);
        pmem::crash_pools(CrashPolicy::random(0.3, 7), &[id]);
        let (h2, stats) = recover_hash(id, 16);
        for k in 0..150u64 {
            assert_eq!(h2.contains(k), k >= 50, "key {k}");
        }
        assert_eq!(stats.members, 100);
    }

    #[test]
    fn crash_during_reclamation_neither_leaks_nor_resurrects() {
        let _sim = pmem::sim_session();
        let l = SoftList::new();
        let id = l.pool_id();
        for k in 0..20u64 {
            assert!(l.insert(k, k * 2));
        }
        assert!(l.remove(7)); // destroy() persisted; pair retired
        // Complete reclamation: PNode freed, generation bumped — the bump
        // is not yet persisted (no later psync touches the line before
        // the crash). Recovery classifies purely by the three flags.
        unsafe { l.core.ebr.drain_all() };
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);

        let (l2, stats) = recover_list(id);
        assert!(!l2.contains(7), "freed slot re-linked as a member");
        assert_eq!(stats.members, 19);
        assert_eq!(
            stats.reclaimed,
            crate::alloc::area::SLOTS_PER_AREA - 19,
            "the freed slot must be reclaimed again, not leaked"
        );
        assert!(l2.insert(7, 70), "reclaimed slots must be reusable");
        assert_eq!(l2.get(7), Some(70));
    }

    #[test]
    fn interrupted_soft_insert_dies_interrupted_remove_survives() {
        let _sim = pmem::sim_session();
        let l = SoftList::new();
        let id = l.pool_id();
        assert!(l.insert(1, 10));
        // Hand-craft an in-flight insert: PNode created but *not* psync'd
        // (simulates a crash inside create, before the flush).
        unsafe {
            let pn = l.core.dpool.alloc() as *mut super::PNode;
            let pv = (*pn).alloc();
            // Write flags/content without the trailing psync: working
            // memory has them, the shadow does not.
            let p = &*pn;
            p.key.store(2, Ordering::Relaxed);
            p.value.store(20, Ordering::Relaxed);
            let _ = pv;
        }
        // Hand-craft an in-flight remove: destroy persisted, but the
        // volatile state never reached "deleted" (thread died first).
        assert!(l.insert(3, 30));
        unsafe {
            // Find key 3's pnode via the volatile list.
            let mut curr =
                crate::sets::tagged::ptr_of::<SNode>(l.head.load(Ordering::Acquire));
            while !curr.is_null() && (*curr).key != 3 {
                curr = crate::sets::tagged::ptr_of::<SNode>((*curr).next.load(Ordering::Acquire));
            }
            assert!(!curr.is_null());
            (*(*curr).pptr).destroy((*curr).p_validity); // persisted removal
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l2, _) = recover_list(id);
        assert!(l2.contains(1));
        assert!(!l2.contains(2), "unpersisted insert must not survive");
        assert!(!l2.contains(3), "persisted (intention-completed) remove must hold");
    }
}
