//! SOFT recovery (paper §4.6) via the shared engine
//! ([`crate::sets::recovery`]): only PNodes survive a crash, so
//! membership is purely the three persistent flags — member ⇔
//! `validStart == validEnd != deleted` — and each member gets a fresh
//! volatile node (pValidity := `validStart`, state "inserted"), linked
//! with zero psyncs; invalid/deleted PNodes are normalised and reclaimed.
//! [`SoftClassify`] is the flag rule plus that SNode materialisation;
//! scan workers allocate from their own thread's slab, so the parallel
//! scan stays allocation-lock-free.

use crate::alloc::{DurablePool, Ebr, VolatilePool};
use crate::pmem::PoolId;
use crate::sets::recovery::{self as engine, Classify, PhaseTimings};
use crate::sets::tagged::State;
use crate::util::mix64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::list::{SoftCore, SoftList};
use super::node::{SNode, SNODE_SIZE};
use super::pnode::PNode;
use super::SoftHash;

pub use crate::sets::recovery::RecoveredStats;

/// The SOFT flag rule for the engine. Member handles are the *fresh
/// volatile* SNodes (built during classification), not the PNodes.
pub(crate) struct SoftClassify<'a> {
    pub core: &'a SoftCore,
}

impl Classify for SoftClassify<'_> {
    const FAMILY: &'static str = "soft";
    const NULL_LINK: u64 = State::Inserted as u64; // null ptr, inserted state

    unsafe fn classify(&self, slot: *mut u8) -> Option<(u64, usize)> {
        let pn = slot as *mut PNode;
        if (*pn).is_member() {
            let vn = self.core.vpool.alloc() as *mut SNode;
            std::ptr::write(
                vn,
                SNode {
                    key: (*pn).key.load(Ordering::Relaxed),
                    value: (*pn).value.load(Ordering::Relaxed),
                    pptr: pn,
                    p_validity: (*pn).current_validity(),
                    next: AtomicU64::new(State::Inserted as u64),
                },
            );
            Some(((*vn).key, vn as usize))
        } else {
            None
        }
    }

    unsafe fn link_word(&self, node: usize) -> u64 {
        node as u64 | State::Inserted as u64
    }

    unsafe fn link(&self, node: usize, next: u64) {
        (*(node as *mut SNode)).next.store(next, Ordering::Relaxed);
    }

    /// A demoted duplicate's handle is its fresh SNode: release it back
    /// to the slab and hand the engine the durable PNode to free.
    unsafe fn demote_duplicate(&self, handle: usize) -> *mut u8 {
        let vn = handle as *mut SNode;
        let pn = (*vn).pptr as *mut u8;
        self.core.vpool.free(vn as *mut u8);
        pn
    }
}

/// Adopt `id`'s durable areas into a fresh SoftCore (also used by the
/// accelerated recovery path, so the pool/slab setup cannot diverge).
pub(crate) fn adopt_core(id: PoolId) -> SoftCore {
    SoftCore::from_parts(
        Arc::new(DurablePool::adopt(id, 64, PNode::init_free_pattern)),
        Arc::new(VolatilePool::new(SNODE_SIZE)),
        Arc::new(Ebr::new()),
    )
}

/// Rebuild a SOFT list from the durable areas of `id`.
pub fn recover_list(id: PoolId) -> (SoftList, RecoveredStats) {
    let (l, s, _) = recover_list_timed(id, engine::default_threads());
    (l, s)
}

/// [`recover_list`] with an explicit recovery worker count.
pub fn recover_list_timed(id: PoolId, threads: usize) -> (SoftList, RecoveredStats, PhaseTimings) {
    let core = adopt_core(id);
    let mut rec = engine::scan(&core.dpool, &SoftClassify { core: &core }, threads);
    rec.sort_by_key();
    unsafe { rec.dedup_duplicates(&SoftClassify { core: &core }, &core.dpool) };
    let head = unsafe { rec.relink_chain(&SoftClassify { core: &core }) };
    core.dpool.persist_all_regions();
    (SoftList::from_parts(head, core), rec.stats, rec.timings)
}

/// Rebuild a SOFT hash set from the durable areas of `id`.
pub fn recover_hash(id: PoolId, nbuckets: usize) -> (SoftHash, RecoveredStats) {
    let (h, s, _) = recover_hash_timed(id, nbuckets, engine::default_threads());
    (h, s)
}

/// [`recover_hash`] with an explicit recovery worker count (bucket-
/// partitioned relink).
pub fn recover_hash_timed(
    id: PoolId,
    nbuckets: usize,
    threads: usize,
) -> (SoftHash, RecoveredStats, PhaseTimings) {
    let core = adopt_core(id);
    let mut rec = engine::scan(&core.dpool, &SoftClassify { core: &core }, threads);
    let hash = SoftHash::from_parts(nbuckets, core);
    let mask = (hash.nbuckets() - 1) as u64;
    let bucket_of = |k: u64| (mix64(k) & mask) as usize;
    rec.sort_by_bucket(bucket_of);
    unsafe { rec.dedup_duplicates(&SoftClassify { core: &hash.core }, &hash.core.dpool) };
    for (b, head) in unsafe { rec.relink_buckets(&SoftClassify { core: &hash.core }, &bucket_of) } {
        hash.buckets[b].store(head, Ordering::Relaxed);
    }
    hash.core.dpool.persist_all_regions();
    (hash, rec.stats, rec.timings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{self, CrashPolicy};
    use crate::sets::ConcurrentSet;

    #[test]
    fn soft_list_survives_pessimistic_crash() {
        let _sim = pmem::sim_session();
        let l = SoftList::new();
        let id = l.pool_id();
        for k in 0..60u64 {
            assert!(l.insert(k, k * 2));
        }
        for k in (0..60u64).step_by(4) {
            assert!(l.remove(k));
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);

        let (l2, stats) = recover_list(id);
        for k in 0..60u64 {
            if k % 4 == 0 {
                assert!(!l2.contains(k), "removed key {k} resurrected");
            } else {
                assert_eq!(l2.get(k), Some(k * 2), "key {k} lost");
            }
        }
        assert_eq!(stats.members, 45);
        // Fully operational after recovery, including PNode reuse.
        assert!(l2.insert(0, 1));
        assert!(l2.remove(1));
        assert!(l2.insert(1000, 1));
    }

    #[test]
    fn soft_hash_survives_random_eviction_crash() {
        let _sim = pmem::sim_session();
        let h = SoftHash::new(16);
        let id = h.pool_id();
        for k in 0..150u64 {
            assert!(h.insert(k, k));
        }
        for k in 0..50u64 {
            assert!(h.remove(k));
        }
        h.crash_preserve();
        drop(h);
        pmem::crash_pools(CrashPolicy::random(0.3, 7), &[id]);
        let (h2, stats) = recover_hash(id, 16);
        for k in 0..150u64 {
            assert_eq!(h2.contains(k), k >= 50, "key {k}");
        }
        assert_eq!(stats.members, 100);
    }

    #[test]
    fn crash_during_reclamation_neither_leaks_nor_resurrects() {
        let _sim = pmem::sim_session();
        let l = SoftList::new();
        let id = l.pool_id();
        for k in 0..20u64 {
            assert!(l.insert(k, k * 2));
        }
        assert!(l.remove(7)); // destroy() persisted; pair retired
        // Complete reclamation: PNode freed, generation bumped — the bump
        // is not yet persisted (no later psync touches the line before
        // the crash). Recovery classifies purely by the three flags.
        unsafe { l.core.ebr.drain_all() };
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);

        let (l2, stats) = recover_list(id);
        assert!(!l2.contains(7), "freed slot re-linked as a member");
        assert_eq!(stats.members, 19);
        assert_eq!(
            stats.reclaimed,
            crate::alloc::area::SLOTS_PER_AREA - 19,
            "the freed slot must be reclaimed again, not leaked"
        );
        assert!(l2.insert(7, 70), "reclaimed slots must be reusable");
        assert_eq!(l2.get(7), Some(70));
    }

    #[test]
    fn interrupted_soft_insert_dies_interrupted_remove_survives() {
        let _sim = pmem::sim_session();
        let l = SoftList::new();
        let id = l.pool_id();
        assert!(l.insert(1, 10));
        // Hand-craft an in-flight insert: PNode created but *not* psync'd
        // (simulates a crash inside create, before the flush).
        unsafe {
            let pn = l.core.dpool.alloc() as *mut super::PNode;
            let pv = (*pn).alloc();
            // Write flags/content without the trailing psync: working
            // memory has them, the shadow does not.
            let p = &*pn;
            p.key.store(2, Ordering::Relaxed);
            p.value.store(20, Ordering::Relaxed);
            let _ = pv;
        }
        // Hand-craft an in-flight remove: destroy persisted, but the
        // volatile state never reached "deleted" (thread died first).
        assert!(l.insert(3, 30));
        unsafe {
            // Find key 3's pnode via the volatile list.
            let mut curr =
                crate::sets::tagged::ptr_of::<SNode>(l.head.load(Ordering::Acquire));
            while !curr.is_null() && (*curr).key != 3 {
                curr = crate::sets::tagged::ptr_of::<SNode>((*curr).next.load(Ordering::Acquire));
            }
            assert!(!curr.is_null());
            (*(*curr).pptr).destroy((*curr).p_validity); // persisted removal
        }
        l.crash_preserve();
        drop(l);
        pmem::crash_pools(CrashPolicy::PESSIMISTIC, &[id]);
        let (l2, _) = recover_list(id);
        assert!(l2.contains(1));
        assert!(!l2.contains(2), "unpersisted insert must not survive");
        assert!(!l2.contains(3), "persisted (intention-completed) remove must hold");
    }
}
