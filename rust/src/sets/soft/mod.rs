//! **SOFT** — Sets with an Optimal Flushing Technique (paper §4).
//!
//! Each key has two representations: a persistent node ([`PNode`]) in the
//! durable areas holding key/value/3 validity flags, and a volatile node
//! taking part in the linked structure, carrying a 4-way state in the low
//! bits of its own `next` ("intention" states trigger helping). Updates
//! persist the PNode *before* the volatile linearization, so each update
//! costs exactly one psync — the Cohen et al. 2018 lower bound — and
//! reads cost zero.

mod hash;
pub(crate) mod list;
mod node;
mod pnode;
mod recovery;
mod skiplist;

pub(crate) use list::SoftCore;

pub use hash::SoftHash;
pub use list::SoftList;
pub use node::{snode_gen, SNode, SNODE_SIZE};
pub use pnode::PNode;
// The accelerated recovery path reuses the family's relink rule and
// core constructor.
#[cfg(feature = "accel")]
pub(crate) use recovery::{adopt_core as recovery_adopt_core, SoftClassify};
pub use recovery::{
    recover_hash, recover_hash_timed, recover_list, recover_list_timed, RecoveredStats,
};
pub use skiplist::{recover_skiplist, recover_skiplist_timed, SoftSkipList};
