//! SOFT durable **skip list** — the symmetric extension (paper §2:
//! "Both schemes are applicable to linked lists, hash tables, skip lists
//! and binary search trees").
//!
//! Same shape as the link-free skip list: durable state is only the
//! bottom-level PNodes (one psync per update, zero per read — unchanged);
//! the tower index is a volatile hint structure over the volatile SNodes,
//! published as `(node, gen)` pairs (`gen` = the SNode's slab-slot
//! allocation generation, `alloc::volatile`), validated under the EBR pin
//! — generation, then key + state, then generation again (seqlock close;
//! DESIGN.md §Reclamation) — and rebuilt at recovery.

use crate::alloc::{Ebr, VolatilePool};
use crate::pmem::PoolId;
use crate::sets::tagged::{gen_validated, ptr_of, State};
use crate::sets::RangeQuery;
use crate::util::rng::Xoshiro256;
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::list::SoftCore;
use super::node::{snode_gen, SNode, SNODE_SIZE};
use super::recovery::RecoveredStats;

const MAX_LEVEL: usize = 16;
const BRANCHING: u64 = 4;

struct Tower {
    key: u64,
    node: *mut SNode,
    /// `node`'s slab-slot generation when the tower was built: the target
    /// was linked then, so a later mismatch proves it was reclaimed.
    gen: u64,
    nexts: [AtomicU64; MAX_LEVEL],
}

/// Durable lock-free skip list (SOFT family).
pub struct SoftSkipList {
    head: AtomicU64,
    index: [AtomicU64; MAX_LEVEL],
    core: SoftCore,
    graveyard: UnsafeCell<Vec<*mut Tower>>,
    grave_lock: std::sync::Mutex<()>,
}

unsafe impl Send for SoftSkipList {}
unsafe impl Sync for SoftSkipList {}

impl SoftSkipList {
    pub fn new() -> Self {
        Self::from_core(SoftCore::new())
    }

    fn from_core(core: SoftCore) -> Self {
        const Z: AtomicU64 = AtomicU64::new(0);
        SoftSkipList {
            head: AtomicU64::new(0),
            index: [Z; MAX_LEVEL],
            core,
            graveyard: UnsafeCell::new(Vec::new()),
            grave_lock: std::sync::Mutex::new(()),
        }
    }

    pub fn pool_id(&self) -> PoolId {
        self.core.dpool.id()
    }

    pub fn crash_preserve(&self) {
        self.core.dpool.preserve();
    }

    fn random_height(key: u64) -> usize {
        let mut h = 1;
        let mut r = Xoshiro256::new(key ^ 0x50F7_5C1A);
        while h < MAX_LEVEL && r.below(BRANCHING) == 0 {
            h += 1;
        }
        h
    }

    /// A tower target is stale when its SNode's slab slot was reclaimed
    /// since the tower was built (generation mismatch — the shared
    /// seqlock protocol [`gen_validated`] brackets the key/state reads,
    /// so they are certainly about the indexed incarnation) or its state
    /// is "deleted" (unlink pending/done).
    unsafe fn stale(t: *const Tower) -> bool {
        let node = (*t).node;
        gen_validated(
            || unsafe { snode_gen(node) },
            (*t).gen,
            || unsafe {
                ((*node).key == (*t).key
                    && State::of((*node).next.load(Ordering::Acquire)) != State::Deleted)
                    .then_some(())
            },
        )
        .is_none()
    }

    /// Best validated hint link for `key`, or the head. Under an EBR pin.
    unsafe fn hint_link(&self, key: u64) -> *const AtomicU64 {
        let mut best: *const AtomicU64 = &self.head;
        let mut level = MAX_LEVEL;
        let mut pred_nexts: &[AtomicU64; MAX_LEVEL] = &self.index;
        while level > 0 {
            level -= 1;
            loop {
                let t_tag = pred_nexts[level].load(Ordering::Acquire);
                let t = ptr_of::<Tower>(t_tag);
                if t.is_null() {
                    break;
                }
                if Self::stale(t) {
                    let succ = (*t).nexts[level].load(Ordering::Acquire) & !1;
                    let _ = pred_nexts[level].compare_exchange(
                        t_tag,
                        succ,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    );
                    continue;
                }
                if (*t).key >= key {
                    break;
                }
                best = &(*(*t).node).next as *const AtomicU64;
                pred_nexts = &(*t).nexts;
            }
        }
        best
    }

    /// `node` was observed linked under the caller's pin, so the slot
    /// generation read here names exactly that incarnation.
    unsafe fn index_insert(&self, key: u64, node: *mut SNode) {
        let height = Self::random_height(key);
        if height <= 1 {
            return;
        }
        const Z: AtomicU64 = AtomicU64::new(0);
        let tower = Box::into_raw(Box::new(Tower {
            key,
            node,
            gen: snode_gen(node),
            nexts: [Z; MAX_LEVEL],
        }));
        {
            let _g = self.grave_lock.lock().unwrap();
            (*self.graveyard.get()).push(tower);
        }
        for level in 0..height {
            loop {
                let (pred_nexts, succ_tag) = self.index_window(key, level);
                (*tower).nexts[level].store(succ_tag & !1, Ordering::Release);
                if pred_nexts[level]
                    .compare_exchange(succ_tag, tower as u64, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    break;
                }
            }
        }
    }

    unsafe fn index_window(&self, key: u64, level: usize) -> (&[AtomicU64; MAX_LEVEL], u64) {
        let mut pred_nexts: &[AtomicU64; MAX_LEVEL] = &self.index;
        loop {
            let t_tag = pred_nexts[level].load(Ordering::Acquire);
            let t = ptr_of::<Tower>(t_tag);
            if t.is_null() || (*t).key >= key {
                return (pred_nexts, t_tag);
            }
            pred_nexts = &(*t).nexts;
        }
    }

    pub fn snapshot(&self) -> Vec<(u64, u64)> {
        self.core.snapshot_from(&self.head)
    }
}

impl Default for SoftSkipList {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for SoftSkipList {
    fn drop(&mut self) {
        unsafe {
            self.core.ebr.drain_all();
            for &t in (*self.graveyard.get()).iter() {
                drop(Box::from_raw(t));
            }
        }
    }
}

impl crate::sets::ConcurrentSet for SoftSkipList {
    fn insert(&self, key: u64, value: u64) -> bool {
        let g = self.core.ebr.pin();
        let start = unsafe { self.hint_link(key) };
        let inserted = self.core.insert_from(start, &self.head, key, value);
        if inserted {
            unsafe {
                // Locate the (volatile) node we just inserted to index it;
                // a racing remove just leaves a stale, lazily-culled tower.
                let mut curr = ptr_of::<SNode>((*start).load(Ordering::Acquire));
                while !curr.is_null() && (*curr).key < key {
                    curr = ptr_of::<SNode>((*curr).next.load(Ordering::Acquire));
                }
                if !curr.is_null() && (*curr).key == key {
                    self.index_insert(key, curr);
                }
            }
        }
        drop(g);
        inserted
    }

    fn remove(&self, key: u64) -> bool {
        let g = self.core.ebr.pin();
        let start = unsafe { self.hint_link(key) };
        let r = self.core.remove_from(start, &self.head, key);
        drop(g);
        r
    }

    fn contains(&self, key: u64) -> bool {
        self.get(key).is_some()
    }

    fn get(&self, key: u64) -> Option<u64> {
        let g = self.core.ebr.pin();
        let start = unsafe { self.hint_link(key) };
        let r = self.core.get_from(start, &self.head, key);
        drop(g);
        r
    }

    fn len_approx(&self) -> usize {
        self.core.count(&self.head)
    }

    /// Coalesced membership burst: one EBR pin for the whole run, probes
    /// issued in sorted key order so consecutive tower descents walk
    /// warm index nodes (mirrors the `ResizableHash` override).
    fn contains_batch(&self, keys: &[u64]) -> Vec<bool> {
        let mut out = vec![false; keys.len()];
        let mut order: Vec<usize> = (0..keys.len()).collect();
        order.sort_unstable_by_key(|&i| keys[i]);
        let g = self.core.ebr.pin();
        for &i in &order {
            let start = unsafe { self.hint_link(keys[i]) };
            out[i] = self.core.get_from(start, &self.head, keys[i]).is_some();
        }
        drop(g);
        out
    }

    /// Coalesced lookup burst; see [`SoftSkipList::contains_batch`].
    fn get_batch(&self, keys: &[u64]) -> Vec<Option<u64>> {
        let mut out = vec![None; keys.len()];
        let mut order: Vec<usize> = (0..keys.len()).collect();
        order.sort_unstable_by_key(|&i| keys[i]);
        let g = self.core.ebr.pin();
        for &i in &order {
            let start = unsafe { self.hint_link(keys[i]) };
            out[i] = self.core.get_from(start, &self.head, keys[i]);
        }
        drop(g);
        out
    }

    fn apply_batch(&self, ops: &[crate::sets::SetOp]) -> Vec<crate::sets::OpResult> {
        crate::sets::apply_batch_coalesced(self, ops)
    }

    fn as_ordered(&self) -> Option<&dyn crate::sets::OrderedSet> {
        Some(self)
    }

    fn durable_pool(&self) -> Option<PoolId> {
        Some(self.pool_id())
    }

    fn prepare_crash(&self) {
        self.crash_preserve();
    }
}

impl crate::sets::OrderedSet for SoftSkipList {
    fn range(&self, lo: u64, hi: u64) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        if lo > hi {
            return out;
        }
        let g = self.core.ebr.pin();
        unsafe {
            let start = self.hint_link(lo);
            self.core.walk_from(start, &self.head, lo, |k, v| {
                if k > hi {
                    return false;
                }
                out.push((k, v));
                true
            });
        }
        drop(g);
        out
    }

    fn scan(&self, cursor: u64, n: usize) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        if n == 0 || cursor == u64::MAX {
            return out;
        }
        let lo = cursor + 1;
        let g = self.core.ebr.pin();
        unsafe {
            let start = self.hint_link(lo);
            self.core.walk_from(start, &self.head, lo, |k, v| {
                out.push((k, v));
                out.len() < n
            });
        }
        drop(g);
        out
    }

    /// The merge-walk — one EBR pin, one tower descent, one bottom-level
    /// pass for the whole burst; see the link-free twin for the window
    /// retirement argument.
    fn range_batch(&self, queries: &[RangeQuery]) -> Vec<Vec<(u64, u64)>> {
        let mut results: Vec<Vec<(u64, u64)>> = vec![Vec::new(); queries.len()];
        let mut order: Vec<usize> = (0..queries.len())
            .filter(|&i| !matches!(queries[i], RangeQuery::Scan(u64::MAX, _) | RangeQuery::Scan(_, 0)))
            .collect();
        order.sort_unstable_by_key(|&i| queries[i].lo());
        if order.is_empty() {
            return results;
        }
        let min_lo = queries[order[0]].lo();
        let g = self.core.ebr.pin();
        unsafe {
            let start = self.hint_link(min_lo);
            let mut front = 0usize;
            self.core.walk_from(start, &self.head, min_lo, |k, v| {
                while front < order.len() {
                    let qi = order[front];
                    if queries[qi].done(k, results[qi].len()) {
                        front += 1;
                    } else {
                        break;
                    }
                }
                if front >= order.len() {
                    return false;
                }
                for &qi in &order[front..] {
                    let q = &queries[qi];
                    if q.starts_after(k) {
                        break;
                    }
                    if q.accepts(k, results[qi].len()) {
                        results[qi].push((k, v));
                    }
                }
                true
            });
        }
        drop(g);
        results
    }
}

/// Recover a SOFT skip list: bottom level via the standard PNode scan
/// (fresh volatile nodes, zero psyncs), index rebuilt randomized.
pub fn recover_skiplist(id: PoolId) -> (SoftSkipList, RecoveredStats) {
    let (s, stats, _) = recover_skiplist_timed(id, crate::sets::recovery::default_threads());
    (s, stats)
}

/// [`recover_skiplist`] with an explicit recovery worker count: the scan +
/// chain relink parallelise through the engine, and the tower index is
/// rebuilt across the same worker budget
/// ([`crate::sets::recovery::par_index_rebuild`] — CAS-based
/// `index_insert` with key-deterministic heights, so any interleaving
/// yields the same towers, with zero psyncs).
pub fn recover_skiplist_timed(
    id: PoolId,
    threads: usize,
) -> (SoftSkipList, RecoveredStats, crate::sets::recovery::PhaseTimings) {
    let (list, stats, timings) = super::recover_list_timed(id, threads);
    // Adopt the recovered chain without dropping the list (its Drop would
    // free every linked node pair).
    let (head_val, core0) = list.into_parts();
    let core = SoftCore::from_parts(core0.dpool, core0.vpool, Arc::new(Ebr::new()));
    let skip = SoftSkipList::from_core(core);
    skip.head.store(head_val, Ordering::Relaxed);
    // One cheap sequential pass collects (key, node) off the chain; the
    // tower CASes — the actual O(n log n) work — fan out over workers.
    let mut pairs: Vec<(u64, usize)> = Vec::new();
    unsafe {
        let mut curr = ptr_of::<SNode>(head_val);
        while !curr.is_null() {
            pairs.push(((*curr).key, curr as usize));
            curr = ptr_of::<SNode>((*curr).next.load(Ordering::Relaxed));
        }
    }
    crate::sets::recovery::par_index_rebuild(&pairs, threads, |key, node| unsafe {
        skip.index_insert(key, node as *mut SNode)
    });
    (skip, stats, timings)
}

/// Keep the volatile pool type name referenced for docs symmetry.
#[allow(dead_code)]
fn _types(_: &VolatilePool) -> usize {
    SNODE_SIZE
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{self, CrashPolicy};
    use crate::sets::ConcurrentSet;

    #[test]
    fn sequential_and_psync_bound() {
        let s = SoftSkipList::new();
        for k in 0..2000u64 {
            assert!(s.insert(k, k));
        }
        // The index must not change SOFT's durability cost: still exactly
        // one psync per update, zero per read.
        let a = crate::pmem::stats::thread_snapshot();
        assert!(s.insert(5000, 1));
        assert!(s.remove(5000));
        assert!(s.contains(1234));
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 2, "1 psync insert + 1 psync remove + 0 read");
        for k in 0..2000u64 {
            assert_eq!(s.get(k), Some(k));
        }
        for k in (0..2000u64).step_by(2) {
            assert!(s.remove(k));
        }
        assert_eq!(s.len_approx(), 1000);
    }

    #[test]
    fn model_equivalence_random_ops() {
        use crate::util::rng::Xoshiro256;
        let s = SoftSkipList::new();
        let mut model = std::collections::BTreeSet::new();
        let mut rng = Xoshiro256::new(0x50F7);
        for _ in 0..30_000 {
            let k = rng.below(512);
            match rng.below(3) {
                0 => assert_eq!(s.insert(k, k), model.insert(k)),
                1 => assert_eq!(s.remove(k), model.remove(&k)),
                _ => assert_eq!(s.contains(k), model.contains(&k)),
            }
        }
        let snap: Vec<u64> = s.snapshot().iter().map(|kv| kv.0).collect();
        let want: Vec<u64> = model.iter().copied().collect();
        assert_eq!(snap, want);
    }

    #[test]
    fn concurrent_stress() {
        use std::sync::Arc;
        let s = Arc::new(SoftSkipList::new());
        let handles: Vec<_> = (0..6u64)
            .map(|t| {
                let s = s.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::util::rng::Xoshiro256::new(t + 17);
                    let mut net = 0i64;
                    for _ in 0..4000 {
                        let k = rng.below(256);
                        match rng.below(3) {
                            0 => {
                                if s.insert(k, t) {
                                    net += 1;
                                }
                            }
                            1 => {
                                if s.remove(k) {
                                    net -= 1;
                                }
                            }
                            _ => {
                                let _ = s.contains(k);
                            }
                        }
                    }
                    net
                })
            })
            .collect();
        let net: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(s.len_approx() as i64, net);
        let snap = s.snapshot();
        for w in snap.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn merge_walk_matches_singles_and_stays_psync_free() {
        use crate::sets::OrderedSet;
        let s = SoftSkipList::new();
        for k in (0..4000u64).step_by(2) {
            assert!(s.insert(k, k + 1));
        }
        let queries = [
            RangeQuery::Range(100, 160),
            RangeQuery::Scan(99, 7),
            RangeQuery::Range(3990, 5000),
            RangeQuery::Scan(u64::MAX, 4),
            RangeQuery::Range(9, 3),
        ];
        let singles: Vec<Vec<(u64, u64)>> = queries
            .iter()
            .map(|q| match *q {
                RangeQuery::Range(lo, hi) => s.range(lo, hi),
                RangeQuery::Scan(c, n) => s.scan(c, n),
            })
            .collect();
        let before = crate::pmem::stats::thread_snapshot();
        let merged = s.range_batch(&queries);
        let d = crate::pmem::stats::thread_snapshot().since(&before);
        assert_eq!(merged, singles, "merge-walk must equal per-query results");
        assert_eq!(
            merged[0],
            (100..=160).step_by(2).map(|k| (k, k + 1)).collect::<Vec<_>>()
        );
        assert_eq!(
            merged[1],
            (100..114).step_by(2).map(|k| (k, k + 1)).collect::<Vec<_>>()
        );
        assert!(merged[3].is_empty() && merged[4].is_empty());
        assert_eq!((d.fences, d.flushes), (0, 0), "ordered reads must be psync-free");
    }

    #[test]
    fn batched_point_reads_match_singles() {
        let s = SoftSkipList::new();
        for k in (0..1000u64).step_by(3) {
            s.insert(k, k * 7);
        }
        let keys: Vec<u64> = vec![999, 0, 3, 500, 501, 3, 702, 1];
        assert_eq!(
            s.contains_batch(&keys),
            keys.iter().map(|&k| s.contains(k)).collect::<Vec<_>>()
        );
        assert_eq!(
            s.get_batch(&keys),
            keys.iter().map(|&k| s.get(k)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn scan_after_crash_recovery_matches_survivors() {
        use crate::sets::OrderedSet;
        let _sim = pmem::sim_session();
        let s = SoftSkipList::new();
        let id = s.pool_id();
        for k in 0..400u64 {
            assert!(s.insert(k, k * 2));
        }
        for k in (0..400u64).step_by(5) {
            assert!(s.remove(k));
        }
        s.crash_preserve();
        drop(s);
        pmem::crash_pools(CrashPolicy::random(0.3, 10), &[id]);
        let (s2, _) = recover_skiplist(id);
        let survivors: Vec<(u64, u64)> =
            (0..400u64).filter(|k| k % 5 != 0).map(|k| (k, k * 2)).collect();
        assert_eq!(s2.range(0, u64::MAX), survivors, "recovered range scan");
        let mut paged = Vec::new();
        let mut cursor = 0u64; // survivors all have key > 0 (0 % 5 == 0 was removed)
        loop {
            let page = s2.scan(cursor, 64);
            if page.is_empty() {
                break;
            }
            cursor = page.last().unwrap().0;
            paged.extend(page);
        }
        assert_eq!(paged, survivors, "recovered cursor scan");
    }

    #[test]
    fn soft_skiplist_crash_recovery() {
        let _sim = pmem::sim_session();
        let s = SoftSkipList::new();
        let id = s.pool_id();
        for k in 0..400u64 {
            assert!(s.insert(k, k * 2));
        }
        for k in (0..400u64).step_by(5) {
            assert!(s.remove(k));
        }
        s.crash_preserve();
        drop(s);
        pmem::crash_pools(CrashPolicy::random(0.3, 9), &[id]);
        let (s2, stats) = recover_skiplist(id);
        assert_eq!(stats.members as usize, (0..400).filter(|k| k % 5 != 0).count());
        for k in 0..400u64 {
            if k % 5 == 0 {
                assert!(!s2.contains(k));
            } else {
                assert_eq!(s2.get(k), Some(k * 2));
            }
        }
        assert!(s2.insert(9999, 1));
    }
}
