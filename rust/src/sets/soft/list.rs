//! SOFT sorted list (paper Listings 9–12).
//!
//! Update logic: persist the PNode first (`create`/`destroy`, the single
//! psync), then linearize on the volatile structure by swapping the 2-bit
//! state — "the state a thread sees in SOFT already resides in the NVRAM"
//! (paper §2.3). Intention states make competing threads help, which is
//! what caps the psync count at one per update for the whole system.

use crate::alloc::{DurablePool, Ebr, VolatilePool};
use crate::sets::tagged::{compose, ptr_of, state_cas, tag_of, State, PTR_MASK};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::node::{SNode, SNODE_SIZE};
use super::pnode::PNode;

/// Shared engine for SOFT containers.
pub(crate) struct SoftCore {
    pub dpool: Arc<DurablePool>,
    pub vpool: Arc<VolatilePool>,
    pub ebr: Arc<Ebr>,
}

unsafe fn free_pnode(ptr: *mut u8, ctx: usize) {
    (*(ctx as *const DurablePool)).free(ptr);
}

unsafe fn free_vnode(ptr: *mut u8, ctx: usize) {
    (*(ctx as *const VolatilePool)).free(ptr);
}

/// Window returned by `find`: the link cell before `curr`, the exact
/// tagged word observed in it (the CAS expectation), `curr`, and `curr`'s
/// state at observation time.
pub(crate) struct Window {
    pred_link: *const AtomicU64,
    pred_val: u64,
    curr: *mut SNode,
    curr_state: State,
}

impl SoftCore {
    pub fn new() -> Self {
        SoftCore {
            dpool: Arc::new(DurablePool::new(64, PNode::init_free_pattern)),
            vpool: Arc::new(VolatilePool::new(SNODE_SIZE)),
            ebr: Arc::new(Ebr::new()),
        }
    }

    pub fn from_parts(dpool: Arc<DurablePool>, vpool: Arc<VolatilePool>, ebr: Arc<Ebr>) -> Self {
        SoftCore { dpool, vpool, ebr }
    }

    unsafe fn retire_pair(&self, vnode: *mut SNode) {
        let pnode = (*vnode).pptr;
        self.ebr
            .retire(pnode as *mut u8, Arc::as_ptr(&self.dpool) as usize, free_pnode);
        self.ebr
            .retire(vnode as *mut u8, Arc::as_ptr(&self.vpool) as usize, free_vnode);
    }

    /// Physically unlink a "deleted"-state node (paper Listing 9 `trim`).
    /// No psync: the PNode's removal was persisted before the state became
    /// deleted, so an unflushed unlink can never resurrect anything.
    unsafe fn trim(&self, pred_link: *const AtomicU64, pred_val: u64, curr: *mut SNode) -> bool {
        debug_assert_eq!(ptr_of::<SNode>(pred_val), curr);
        let succ = (*curr).next.load(Ordering::Acquire) & PTR_MASK;
        let new_val = succ | tag_of(pred_val);
        (*pred_link)
            .compare_exchange(pred_val, new_val, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Paper Listing 9 `find`. Caller holds an EBR guard.
    unsafe fn find(&self, head: *const AtomicU64, key: u64) -> Window {
        self.find_from(head, head, key)
    }

    /// `find` starting from a validated hint link (skip-list fast path);
    /// retries fall back to `head`.
    pub(crate) unsafe fn find_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
    ) -> Window {
        let mut from = start;
        'retry: loop {
            let mut pred_link = std::mem::replace(&mut from, head);
            let mut pred_val = (*pred_link).load(Ordering::Acquire);
            // Hint staleness (TOCTOU): the hint node may have reached the
            // "deleted" state after validation. Its frozen `next` would
            // make us traverse an unlinked suffix — and, worse, a CAS
            // expectation captured *with* the deleted bits would succeed
            // against the dead cell. Reject and restart from the head.
            if !std::ptr::eq(pred_link, head) && State::of(pred_val) == State::Deleted {
                continue 'retry;
            }
            let mut curr = ptr_of::<SNode>(pred_val);
            loop {
                if curr.is_null() {
                    return Window { pred_link, pred_val, curr, curr_state: State::Inserted };
                }
                let curr_val = (*curr).next.load(Ordering::Acquire);
                let c_state = State::of(curr_val);
                if c_state == State::Deleted {
                    let new_val = (curr_val & PTR_MASK) | tag_of(pred_val);
                    if (*pred_link)
                        .compare_exchange(pred_val, new_val, Ordering::AcqRel, Ordering::Acquire)
                        .is_err()
                    {
                        continue 'retry;
                    }
                    pred_val = new_val;
                    curr = ptr_of::<SNode>(curr_val);
                } else {
                    if (*curr).key >= key {
                        return Window { pred_link, pred_val, curr, curr_state: c_state };
                    }
                    pred_link = &(*curr).next as *const AtomicU64;
                    pred_val = curr_val;
                    curr = ptr_of::<SNode>(curr_val);
                }
            }
        }
    }

    /// Paper Listing 11.
    pub fn insert(&self, head: *const AtomicU64, key: u64, value: u64) -> bool {
        self.insert_from(head, head, key, value)
    }

    /// Insert whose first window search starts at a validated hint link.
    pub(crate) fn insert_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
        value: u64,
    ) -> bool {
        let _g = self.ebr.pin();
        let mut alloc_v: *mut SNode = std::ptr::null_mut();
        let mut from = start;
        let (result_node, result) = loop {
            unsafe {
                let w = self.find_from(std::mem::replace(&mut from, head), head, key);
                if !w.curr.is_null() && (*w.curr).key == key {
                    if w.curr_state != State::IntendToInsert {
                        // Key durably present (or being deleted — still
                        // logically present): plain failure.
                        if !alloc_v.is_null() {
                            self.dpool.free((*alloc_v).pptr as *mut u8);
                            self.vpool.free(alloc_v as *mut u8);
                        }
                        return false;
                    }
                    // Pending insert by someone else: help it finish
                    // below, then fail.
                    break (w.curr, false);
                }
                if alloc_v.is_null() {
                    let pnode = self.dpool.alloc() as *mut PNode;
                    let v = self.vpool.alloc() as *mut SNode;
                    let pv = (*pnode).alloc();
                    // The pre-link node must never present an "inserted"
                    // state: a stale bucket hint probing a recycled slot
                    // rejects IntendToInsert, but would accept Inserted(0)
                    // and start a traversal at an unlinked node.
                    std::ptr::write(
                        v,
                        SNode {
                            key,
                            value,
                            pptr: pnode,
                            p_validity: pv,
                            next: AtomicU64::new(State::IntendToInsert as u64),
                        },
                    );
                    alloc_v = v;
                }
                // Link with state "intention to insert": visible for
                // helping but not yet logically in the set. (Release: the
                // volatile SNode rides the same publish discipline as the
                // durable words — durlint R2 flags relaxed link stores.)
                (*alloc_v)
                    .next
                    .store(compose(w.curr, State::IntendToInsert as u64), Ordering::Release);
                let new_val = (alloc_v as u64) | tag_of(w.pred_val);
                if (*w.pred_link)
                    .compare_exchange(w.pred_val, new_val, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    break (alloc_v, true);
                }
            }
        };
        unsafe {
            // Completion (paper lines 30–33): persist the PNode, then
            // publish the state. Both are idempotent — any helper may race.
            (*(*result_node).pptr).create(
                (*result_node).key,
                (*result_node).value,
                (*result_node).p_validity,
            );
            // Inserted is the durable publish: the PNode's create psync
            // must have completed (durcheck flags a still-dirty PNode).
            crate::pmem::check::note_publish((*result_node).pptr as *const u8);
            loop {
                let v = (*result_node).next.load(Ordering::Acquire);
                if State::of(v) != State::IntendToInsert {
                    break;
                }
                state_cas(&(*result_node).next, State::IntendToInsert, State::Inserted);
            }
            if !result && !alloc_v.is_null() {
                self.dpool.free((*alloc_v).pptr as *mut u8);
                self.vpool.free(alloc_v as *mut u8);
            }
        }
        result
    }

    /// Paper Listing 12.
    pub fn remove(&self, head: *const AtomicU64, key: u64) -> bool {
        self.remove_from(head, head, key)
    }

    /// Remove whose window search starts at a validated hint link.
    pub(crate) fn remove_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
    ) -> bool {
        let _g = self.ebr.pin();
        unsafe {
            let w = self.find_from(start, head, key);
            if w.curr.is_null() || (*w.curr).key != key {
                return false;
            }
            if w.curr_state == State::IntendToInsert {
                // Not yet guaranteed durable — logically absent.
                return false;
            }
            let curr = w.curr;
            // Compete for the "intention to delete" transition; exactly
            // one remover wins and reports success.
            let mut result = false;
            loop {
                let v = (*curr).next.load(Ordering::Acquire);
                if State::of(v) != State::Inserted {
                    break;
                }
                if state_cas(&(*curr).next, State::Inserted, State::IntendToDelete) {
                    result = true;
                    break;
                }
            }
            // Help persist + complete regardless of who won (idempotent).
            (*(*curr).pptr).destroy((*curr).p_validity);
            // Deleted is the durable publish of the removal record.
            crate::pmem::check::note_publish((*curr).pptr as *const u8);
            loop {
                let v = (*curr).next.load(Ordering::Acquire);
                if State::of(v) != State::IntendToDelete {
                    break;
                }
                state_cas(&(*curr).next, State::IntendToDelete, State::Deleted);
            }
            if result {
                // Winner physically disconnects (reduces contention) and
                // owns reclamation.
                if !self.trim(w.pred_link, w.pred_val, curr) {
                    let _ = self.find(head, key);
                }
                self.retire_pair(curr);
            }
            result
        }
    }

    /// Paper Listing 10: wait-free, zero psyncs.
    pub fn get(&self, head: *const AtomicU64, key: u64) -> Option<u64> {
        self.get_from(head, head, key)
    }

    /// Wait-free read starting from a validated hint link (or the head).
    pub(crate) fn get_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        key: u64,
    ) -> Option<u64> {
        let _g = self.ebr.pin();
        unsafe {
            let mut from = start;
            // Same TOCTOU as find_from: a deleted hint's frozen suffix can
            // miss nodes inserted at the unlink point.
            if !std::ptr::eq(start, head)
                && State::of((*start).load(Ordering::Acquire)) == State::Deleted
            {
                from = head;
            }
            let mut curr = ptr_of::<SNode>((*from).load(Ordering::Acquire));
            while !curr.is_null() && (*curr).key < key {
                curr = ptr_of::<SNode>((*curr).next.load(Ordering::Acquire));
            }
            if curr.is_null() || (*curr).key != key {
                return None;
            }
            let s = State::of((*curr).next.load(Ordering::Acquire));
            if s.in_set() {
                Some((*curr).value)
            } else {
                None
            }
        }
    }

    /// Free every node still linked below `head` (its SNode/PNode pair
    /// both return to their pools) and clear the head.
    ///
    /// # Safety
    /// Callable only when no thread is inside an operation on the owning
    /// structure (single-threaded teardown).
    pub(crate) unsafe fn free_chain(&self, head: &AtomicU64) {
        let mut curr = ptr_of::<SNode>(head.load(Ordering::Relaxed));
        while !curr.is_null() {
            let next = ptr_of::<SNode>((*curr).next.load(Ordering::Relaxed));
            self.dpool.free((*curr).pptr as *mut u8);
            self.vpool.free(curr as *mut u8);
            curr = next;
        }
        head.store(0, Ordering::Relaxed);
    }

    /// Flush-free ordered walk from a validated hint link (or `head`):
    /// visits every in-set `(key, value)` with `key >= lo` in key order
    /// until `visit` returns false. SOFT reads are unconditionally
    /// psync-free, so this is just [`SoftCore::get_from`]'s traversal
    /// generalized to a window (include iff `State::in_set`). Caller
    /// must hold an EBR guard across the walk.
    pub(crate) unsafe fn walk_from(
        &self,
        start: *const AtomicU64,
        head: *const AtomicU64,
        lo: u64,
        mut visit: impl FnMut(u64, u64) -> bool,
    ) {
        let mut from = start;
        // Same hint TOCTOU as get_from: a deleted hint's frozen suffix
        // can miss nodes inserted at the unlink point.
        if !std::ptr::eq(start, head)
            && State::of((*start).load(Ordering::Acquire)) == State::Deleted
        {
            from = head;
        }
        let mut curr = ptr_of::<SNode>((*from).load(Ordering::Acquire));
        while !curr.is_null() {
            let v = (*curr).next.load(Ordering::Acquire);
            if State::of(v).in_set() {
                let k = (*curr).key;
                if k >= lo && !visit(k, (*curr).value) {
                    return;
                }
            }
            curr = ptr_of::<SNode>(v);
        }
    }

    /// Compaction: re-home every member whose *PNode* lies in `[lo, hi)`
    /// onto a freshly allocated PNode (the claimed area is off the
    /// allocation index). The volatile chain is untouched — each SNode
    /// keeps its position and only its `pptr`/`p_validity` move, which
    /// no reader ever dereferences (reads are answered from the SNode).
    ///
    /// Per node: `create` the copy (durable, one psync), swap the SNode's
    /// plumbing, `destroy` the original (durable, one psync) and free it
    /// directly — with updates serialized out and readers never touching
    /// `pptr`, nothing else can reference the old PNode. A crash between
    /// create and destroy leaves two member PNodes with the same key;
    /// recovery's dedup keeps one. Returns the migrated count.
    ///
    /// # Safety
    /// Caller must serialize this against *updates* on the list (the
    /// shard worker's idle tick does); concurrent readers are safe.
    pub(crate) unsafe fn migrate_range(
        &self,
        head: *const AtomicU64,
        lo: usize,
        hi: usize,
    ) -> usize {
        let mut moved = 0;
        let mut curr = ptr_of::<SNode>((*head).load(Ordering::Acquire));
        while !curr.is_null() {
            let v = (*curr).next.load(Ordering::Acquire);
            let p_old = (*curr).pptr;
            if State::of(v).in_set() && (p_old as usize) >= lo && (p_old as usize) < hi {
                let p_new = self.dpool.alloc() as *mut PNode;
                debug_assert!((p_new as usize) < lo || (p_new as usize) >= hi);
                let pv_new = (*p_new).alloc();
                (*p_new).create((*curr).key, (*curr).value, pv_new);
                let pv_old = (*curr).p_validity;
                (*curr).pptr = p_new;
                (*curr).p_validity = pv_new;
                (*p_old).destroy(pv_old);
                self.dpool.free(p_old as *mut u8);
                moved += 1;
            }
            curr = ptr_of::<SNode>(v);
        }
        moved
    }

    /// In-set node count from one head (test/metrics only).
    pub fn count(&self, head: *const AtomicU64) -> usize {
        self.snapshot_from(head).len()
    }

    /// Ordered (key, value) snapshot of in-set nodes (test/debug only).
    pub fn snapshot_from(&self, head: *const AtomicU64) -> Vec<(u64, u64)> {
        let _g = self.ebr.pin();
        let mut out = Vec::new();
        unsafe {
            let mut curr = ptr_of::<SNode>((*head).load(Ordering::Acquire));
            while !curr.is_null() {
                let v = (*curr).next.load(Ordering::Acquire);
                if State::of(v).in_set() {
                    out.push(((*curr).key, (*curr).value));
                }
                curr = ptr_of::<SNode>(v);
            }
        }
        out
    }
}

/// The SOFT sorted-list set.
pub struct SoftList {
    pub(crate) head: AtomicU64,
    pub(crate) core: SoftCore,
}

unsafe impl Send for SoftList {}
unsafe impl Sync for SoftList {}

impl SoftList {
    pub fn new() -> Self {
        SoftList { head: AtomicU64::new(0), core: SoftCore::new() }
    }

    pub(crate) fn from_parts(head_value: u64, core: SoftCore) -> Self {
        SoftList { head: AtomicU64::new(head_value), core }
    }

    /// Dismantle without running `Drop` (the chain's nodes stay alive):
    /// used when another structure adopts the chain, e.g. skip-list or
    /// resizable-hash recovery re-wrapping a recovered list.
    pub(crate) fn into_parts(self) -> (u64, SoftCore) {
        let me = std::mem::ManuallyDrop::new(self);
        // Deferred frees are unlinked pairs — safe to flush here; only the
        // *linked* nodes must survive for the adopter.
        unsafe { me.core.ebr.drain_all() };
        let head = me.head.load(Ordering::Relaxed);
        // Safety: `me` is ManuallyDrop, so the core is never dropped (or
        // read) again through it.
        let core = unsafe { std::ptr::read(&me.core) };
        (head, core)
    }

    pub fn pool_id(&self) -> crate::pmem::PoolId {
        self.core.dpool.id()
    }

    pub fn crash_preserve(&self) {
        self.core.dpool.preserve();
    }

    pub fn snapshot(&self) -> Vec<(u64, u64)> {
        self.core.snapshot_from(&self.head)
    }
}

impl Default for SoftList {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for SoftList {
    fn drop(&mut self) {
        unsafe {
            // Deferred frees first (all unlinked), then every still-linked
            // SNode/PNode pair — `drain_all` alone leaked the live chain
            // (the pools reclaimed the bytes, but the slots were never
            // returned, which matters whenever the pools are shared or
            // outlive this handle).
            self.core.ebr.drain_all();
            self.core.free_chain(&self.head);
        }
    }
}

impl crate::sets::ConcurrentSet for SoftList {
    fn insert(&self, key: u64, value: u64) -> bool {
        self.core.insert(&self.head, key, value)
    }
    fn remove(&self, key: u64) -> bool {
        self.core.remove(&self.head, key)
    }
    fn contains(&self, key: u64) -> bool {
        self.core.get(&self.head, key).is_some()
    }
    fn get(&self, key: u64) -> Option<u64> {
        self.core.get(&self.head, key)
    }
    fn len_approx(&self) -> usize {
        self.core.count(&self.head)
    }
    fn apply_batch(&self, ops: &[crate::sets::SetOp]) -> Vec<crate::sets::OpResult> {
        // Group commit: one trailing fence for the batch instead of the
        // one-psync-per-update (helpers outside the scope still fence).
        crate::sets::apply_batch_coalesced(self, ops)
    }
    fn durable_pool(&self) -> Option<crate::pmem::PoolId> {
        Some(self.pool_id())
    }
    fn prepare_crash(&self) {
        self.crash_preserve();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sets::ConcurrentSet;

    #[test]
    fn sequential_semantics() {
        let l = SoftList::new();
        assert!(!l.contains(5));
        assert!(l.insert(5, 50));
        assert!(!l.insert(5, 51));
        assert_eq!(l.get(5), Some(50));
        assert!(l.insert(3, 30));
        assert!(l.insert(7, 70));
        assert_eq!(l.snapshot(), vec![(3, 30), (5, 50), (7, 70)]);
        assert!(l.remove(5));
        assert!(!l.remove(5));
        assert!(!l.contains(5));
        assert_eq!(l.len_approx(), 2);
    }

    #[test]
    fn optimal_flushing_bound() {
        // The paper's headline property: exactly one psync per successful
        // update, zero per read (and zero for failed ops that need no
        // helping).
        let l = SoftList::new();
        for k in 0..32u64 {
            l.insert(k, k); // warm up: areas allocated
        }
        let a = crate::pmem::stats::thread_snapshot();
        assert!(l.insert(100, 1));
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 1, "insert must psync exactly once");

        let a = crate::pmem::stats::thread_snapshot();
        assert!(l.remove(100));
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 1, "remove must psync exactly once");

        let a = crate::pmem::stats::thread_snapshot();
        for k in 0..32u64 {
            let _ = l.contains(k);
        }
        assert!(!l.insert(5, 5));
        assert!(!l.remove(999));
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 0, "reads and plain failures must not psync");
    }

    /// Find key `key`'s volatile node by walking the chain (test helper).
    unsafe fn node_of(l: &SoftList, key: u64) -> *mut SNode {
        use crate::sets::tagged::ptr_of;
        let mut curr = ptr_of::<SNode>(l.head.load(std::sync::atomic::Ordering::Acquire));
        while !curr.is_null() && (*curr).key != key {
            curr = ptr_of::<SNode>((*curr).next.load(std::sync::atomic::Ordering::Acquire));
        }
        assert!(!curr.is_null(), "key {key} not found");
        curr
    }

    #[test]
    fn failed_ops_that_help_psync_exactly_once() {
        // Paper Listing 11/12 semantics: an insert that finds a pending
        // IntendToInsert, or a remove that finds IntendToDelete, must help
        // the pending op complete — which costs exactly the helped op's
        // one psync — and then report failure. Plain failures stay free
        // (asserted in optimal_flushing_bound).
        use crate::sets::tagged::{state_cas, State};
        let l = SoftList::new();
        assert!(l.insert(7, 70));
        assert!(l.insert(9, 90));

        // Rewind key 7 to IntendToInsert (as if its inserter stalled
        // between linking and completing).
        unsafe {
            let n = node_of(&l, 7);
            assert!(state_cas(&(*n).next, State::Inserted, State::IntendToInsert));
        }
        let a = crate::pmem::stats::thread_snapshot();
        assert!(!l.insert(7, 71), "pending insert means the key wins, we fail");
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 1, "helping a pending insert = its one create psync");
        assert_eq!(l.get(7), Some(70), "helper completed the original insert");

        // Push key 9 to IntendToDelete without persisting the removal (as
        // if its remover stalled between the state CAS and destroy).
        unsafe {
            let n = node_of(&l, 9);
            assert!(state_cas(&(*n).next, State::Inserted, State::IntendToDelete));
        }
        let a = crate::pmem::stats::thread_snapshot();
        assert!(!l.remove(9), "the stalled remover owns the removal; we fail");
        let d = crate::pmem::stats::thread_snapshot().since(&a);
        assert_eq!(d.fences, 1, "helping a pending remove = its one destroy psync");
        assert!(!l.contains(9), "helper completed the original remove");
    }

    #[test]
    fn drop_returns_every_linked_pair_to_the_pools() {
        let l = SoftList::new();
        for k in 0..700u64 {
            assert!(l.insert(k, k));
        }
        for k in 0..200u64 {
            assert!(l.remove(k)); // retired pairs drain in Drop
        }
        let dpool = l.core.dpool.clone();
        let vpool = l.core.vpool.clone();
        drop(l);
        assert_eq!(dpool.outstanding(), 0, "PNode slots leaked on drop");
        assert_eq!(vpool.outstanding(), 0, "SNode slots leaked on drop");
    }

    #[test]
    fn matches_btreeset_model_random_ops() {
        use crate::util::rng::Xoshiro256;
        let l = SoftList::new();
        let mut model = std::collections::BTreeSet::new();
        let mut rng = Xoshiro256::new(0xBEE5);
        for _ in 0..20_000 {
            let k = rng.below(64);
            match rng.below(3) {
                0 => assert_eq!(l.insert(k, k), model.insert(k)),
                1 => assert_eq!(l.remove(k), model.remove(&k)),
                _ => assert_eq!(l.contains(k), model.contains(&k)),
            }
        }
        let snap: Vec<u64> = l.snapshot().iter().map(|kv| kv.0).collect();
        let want: Vec<u64> = model.iter().copied().collect();
        assert_eq!(snap, want);
    }

    #[test]
    fn concurrent_contention_net_count() {
        use std::sync::Arc;
        let l = Arc::new(SoftList::new());
        let handles: Vec<_> = (0..8u64)
            .map(|t| {
                let l = l.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::util::rng::Xoshiro256::new(t + 99);
                    let mut net = 0i64;
                    for _ in 0..3000 {
                        let k = rng.below(16);
                        if rng.below(2) == 0 {
                            if l.insert(k, t) {
                                net += 1;
                            }
                        } else if l.remove(k) {
                            net -= 1;
                        }
                    }
                    net
                })
            })
            .collect();
        let net: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(l.len_approx() as i64, net);
        let snap = l.snapshot();
        for w in snap.windows(2) {
            assert!(w[0].0 < w[1].0, "list must stay strictly sorted");
        }
    }

    #[test]
    fn pnode_slots_are_recycled() {
        let l = SoftList::new();
        // Insert/remove far more keys than one area holds; the pool must
        // not grow past a couple of areas if reclamation works.
        for round in 0..40u64 {
            for k in 0..512u64 {
                assert!(l.insert(k, round));
            }
            for k in 0..512u64 {
                assert!(l.remove(k));
            }
        }
        let areas = l.core.dpool.regions().len();
        assert!(areas <= 4, "PNode slots are not being recycled: {areas} areas");
    }
}
