//! SOFT volatile node (paper Listing 8).

use std::sync::atomic::AtomicU64;

use super::pnode::PNode;

/// The volatile half of a SOFT key. Lives in the volatile slab pool, dies
/// at a crash, is rebuilt by recovery. Its 4-way state (paper §2.3) is the
/// low 2 bits of its own `next` link.
///
/// Deliberately *not* padded to a cache line: the paper observes that
/// SOFT's extra PNode pointer makes ~1.5 volatile nodes share a line and
/// pays traversal cache misses for it — that effect is part of the
/// evaluation (§6: why link-free wins long lists).
#[repr(C)]
pub struct SNode {
    pub key: u64,
    pub value: u64,
    pub pptr: *mut PNode,
    /// The validity value this PNode lifecycle uses (paper `pValidity`).
    pub p_validity: bool,
    /// Tagged link: bits 0–1 = this node's [`State`](crate::sets::tagged::State).
    pub next: AtomicU64,
}

/// Slab slot size for volatile nodes. (The slab's *stride* is
/// `SNODE_SIZE + 8`: the pool appends a generation word per slot — see
/// [`crate::alloc::volatile`]; the node layout itself is unchanged.)
pub const SNODE_SIZE: usize = std::mem::size_of::<SNode>();

// Keep the node itself at 40 bytes (un-padded, bigger than a link-free
// node — the paper's SOFT cache-miss effect). The slab stride adds the
// 8-byte generation word, so density is ~1.33 nodes/line.
const _: () = assert!(SNODE_SIZE == 40, "keep the paper's un-padded SNode layout");
const _: () = assert!(std::mem::align_of::<SNode>() == 8);

/// Current allocation generation of an SNode's slab slot (bumped by the
/// volatile pool on each free — the `(ptr, gen)` hint/tower tag).
///
/// # Safety
/// `node` must point into a live [`crate::alloc::VolatilePool`] slot of
/// size `SNODE_SIZE`.
#[inline(always)]
pub unsafe fn snode_gen(node: *const SNode) -> u64 {
    crate::alloc::vslot_gen(node as *const u8, SNODE_SIZE)
        .load(std::sync::atomic::Ordering::Acquire)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snode_is_40_bytes() {
        // 8 key + 8 value + 8 pptr + 1(+7 pad) p_validity + 8 next.
        assert_eq!(SNODE_SIZE, 40);
    }
}
